"""Paper §6.4 case study: training a SKI (KISS-GP) Gaussian Process with
FastKron-accelerated conjugate-gradient solves.

    PYTHONPATH=src python examples/gp_training.py [--p 16] [--d 3] [--epochs 5]

End-to-end: synthetic regression data -> SKI interpolation onto a D-dim
grid of P points/dim -> kernel K = (x)_d RBF_1d -> per epoch, CG-solve
(K + noise I)^-1 V with M=16 probe rows (the paper's setting) and update
the noise hyperparameter from the residual.  The hot op of every CG
iteration is a Kron-Matmul; --backend switches the engine so the speedup
of FastKron over the shuffle algorithm shows up as epoch time.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.gp import (
    KronKernel,
    conjugate_gradient,
    gp_train_epoch,
    interp_matrix,
    rbf_kernel_1d,
)


def make_data(key, n: int, d: int):
    kx, ky = jax.random.split(key)
    x = jax.random.uniform(kx, (n, d))
    f = jnp.sin(4 * x.sum(-1)) + 0.5 * jnp.cos(7 * x[:, 0])
    y = f + 0.1 * jax.random.normal(ky, (n,))
    return x, y


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--p", type=int, default=16, help="grid points per dim")
    ap.add_argument("--d", type=int, default=3, help="input dims")
    ap.add_argument("--n", type=int, default=512, help="training points")
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--cg-iters", type=int, default=10)
    ap.add_argument("--backend", default="fastkron",
                    choices=["fastkron", "shuffle"])
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    x, y = make_data(key, args.n, args.d)
    grid = jnp.linspace(0, 1, args.p)
    kernel = KronKernel(tuple(rbf_kernel_1d(grid) for _ in range(args.d)))
    w = interp_matrix(x, [args.p] * args.d)          # (n, P^D)
    print(f"SKI: n={args.n} pts -> grid {args.p}^{args.d} "
          f"({kernel.dim} inducing), backend={args.backend}")

    # project targets onto the grid (W^T y) and train with M=16 probe rows
    wty = (w.T @ y)[None, :]                          # (1, dim)
    probes = jax.random.normal(jax.random.fold_in(key, 1), (15, kernel.dim))
    v = jnp.concatenate([wty, probes], axis=0)        # (16, dim) as in paper

    noise = 0.1
    epoch = jax.jit(
        lambda v, noise: gp_train_epoch(
            kernel, v, noise=noise, cg_iters=args.cg_iters,
            backend=args.backend,
        )
    )
    # warmup/compile
    jax.block_until_ready(epoch(v, noise)[0])

    t_total = 0.0
    for e in range(args.epochs):
        t0 = time.perf_counter()
        sol, resid = epoch(v, noise)
        jax.block_until_ready(sol)
        dt = time.perf_counter() - t0
        t_total += dt
        # crude hyperparameter step: match noise to residual scale
        noise = float(jnp.clip(0.9 * noise + 0.1 * resid.mean()
                               / max(kernel.dim, 1) * 100, 1e-3, 1.0))
        print(f"epoch {e}: {dt*1e3:7.1f} ms  cg_resid={float(resid[0]):.3e} "
              f"noise={noise:.4f}")

    # posterior mean at training points: mu = W K alpha  (alpha = K^-1 W^T y)
    alpha = sol[0]
    mu = w @ kernel.matmul(alpha[None, :], backend=args.backend)[0]
    rmse = float(jnp.sqrt(jnp.mean((mu / jnp.maximum(mu.std(), 1e-9)
                                    * y.std() - y) ** 2)))
    print(f"train RMSE (scale-matched): {rmse:.3f}  "
          f"avg epoch: {t_total/args.epochs*1e3:.1f} ms")
    print("re-run with --backend shuffle to compare engines")


if __name__ == "__main__":
    main()
