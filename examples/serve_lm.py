"""Batched serving example: prefill a batch of prompts, stream tokens.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma-2b --batch 4

Demonstrates the serving path the decode_32k / long_500k dry-run cells
lower at production scale: jitted prefill builds the KV/SSM cache for the
whole batch, a jitted one-token serve_step (cache donated -> in-place ring
update) runs the autoregressive loop.  Works for every registered arch
(--arch mamba2-130m serves with O(1) recurrent state, --arch mixtral-8x22b
with a window-bounded ring cache).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.data import SyntheticLM
from repro.models import model as M
from repro.models.config import reduced


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), dtype="float32")
    max_len = args.prompt_len + args.gen
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.prompt_len,
                       batch=args.batch)
    prompts, _ = data.global_batch(0)
    n_fe = cfg.n_frontend_tokens
    embeds = (jax.random.normal(jax.random.PRNGKey(7),
                                (args.batch, n_fe, cfg.d_model))
              if n_fe else None)

    prefill = jax.jit(lambda p, t, e: M.prefill(cfg, p, t, max_len + n_fe, e))
    step = jax.jit(
        lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos),
        donate_argnums=(1,),
    )

    t0 = time.time()
    logits, cache = prefill(params, prompts, embeds)
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    key = jax.random.PRNGKey(1)
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1).astype(jnp.int32)
    rows = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(n_fe + args.prompt_len + i)
        logits, cache = step(params, cache, tok, pos)
        key = jax.random.fold_in(key, i)
        lg = logits[:, -1, : cfg.vocab]
        if args.temperature > 0:
            tok = jax.random.categorical(key, lg / args.temperature)[:, None]
        else:
            tok = jnp.argmax(lg, axis=-1)[:, None]
        tok = tok.astype(jnp.int32)
        rows.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(rows, axis=1)
    print(f"decode {args.gen-1} steps: {dt:.2f}s "
          f"({args.batch*(args.gen-1)/dt:.1f} tok/s)")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {prompts[b, -6:].tolist()} => {gen[b].tolist()}")


if __name__ == "__main__":
    main()
