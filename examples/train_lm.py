"""End-to-end driver: train a small LM with Kron-compressed FFNs.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --dense  # baseline

Uses the public API only: ModelConfig -> train_state_init ->
make_train_step -> SyntheticLM batches -> CheckpointManager.  The model is
a ~5M-param qwen3-family transformer whose FFN projections are KronLinear
factors (the paper's ML-compression use case): --dense trains the same
architecture with dense FFNs so the parameter saving and loss trade-off
are directly visible.  Scale up with --d-model/--layers on real hardware
(--preset 100m gives the ~100M-param config).
"""
from __future__ import annotations

import argparse
import time
from dataclasses import replace

import jax

from repro.configs import get_config
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.models.config import reduced
from repro.optim import OptConfig
from repro.train import make_train_step, train_state_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--dense", action="store_true", help="dense-FFN baseline")
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.preset == "100m":
        args.d_model, args.layers, args.seq = 768, 12, 512

    cfg = reduced(
        get_config("qwen3_4b"),
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=max(2, args.d_model // 64),
        n_kv_heads=max(2, args.d_model // 128),
        head_dim=64,
        d_ff=args.d_model * 4,
        vocab=2048,
        vocab_pad_multiple=128,
        dtype="float32",
        kron_ffn=not args.dense,
    )
    n_params = cfg.param_count()
    print(f"model: {cfg.n_layers}L d={cfg.d_model} "
          f"ffn={'kron' if cfg.kron_ffn else 'dense'} "
          f"~{n_params/1e6:.1f}M params (dense-FFN equivalent "
          f"{cfg.param_count()/1e6:.1f}M)")

    opt_cfg = OptConfig(lr=3e-3, warmup_steps=20, decay_steps=args.steps)
    state = train_state_init(cfg, opt_cfg, jax.random.PRNGKey(0))
    real = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"actual parameter count: {real/1e6:.2f}M")

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0,))
    mgr = CheckpointManager(args.ckpt_dir, keep=2, async_save=True) \
        if args.ckpt_dir else None

    t0 = time.time()
    for i in range(args.steps):
        toks, labels = data.global_batch(i)
        state, metrics = step_fn(state, {"tokens": toks, "labels": labels})
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e}", flush=True)
        if mgr and (i + 1) % 50 == 0:
            mgr.save(i + 1, state._asdict())
    if mgr:
        mgr.wait()
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({args.steps*args.batch*args.seq/dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
