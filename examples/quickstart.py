"""Quickstart: the FastKron public API in 2 minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    KronProblem,
    kron_matmul,
    kron_matmul_naive,
    kron_matmul_shuffle,
    make_plan,
)
from repro.core.layers import (
    KronLinearSpec,
    kron_linear_apply,
    kron_linear_init,
)


def main() -> None:
    key = jax.random.PRNGKey(0)

    # --- 1. Kron-Matmul without materializing the Kronecker matrix --------
    # Y = X (F1 (x) F2 (x) F3),  X: (M, 8*8*8), Fi: (8, 8)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (32, 512))
    factors = [
        jax.random.normal(jax.random.fold_in(k2, i), (8, 8)) for i in range(3)
    ]
    y = kron_matmul(x, factors)
    print(f"kron_matmul: {x.shape} x (8x8)^3 -> {y.shape}")

    # the 512x512 Kronecker matrix is never built; verify vs the oracle:
    y_ref = kron_matmul_naive(x, factors)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-5)
    print("matches the materialized oracle")

    # --- 2. Execution plans (fusion + tile autotuning) --------------------
    prob = KronProblem(32, (8, 8, 8), (8, 8, 8))
    plan = make_plan(prob)
    print(f"autotuned plan: {plan.describe()}")
    print(f"algorithm FLOPs: {prob.flops/1e6:.1f} MFLOP "
          f"(naive would be {2*32*512*512/1e6:.1f})")

    # --- 3. It differentiates (the VJP is itself Kron-shaped) -------------
    grads = jax.grad(
        lambda fs: jnp.sum(kron_matmul(x, fs) ** 2)
    )(tuple(factors))
    print(f"factor grads: {[tuple(g.shape) for g in grads]}")

    # --- 4. KronLinear: compressed projections for models -----------------
    spec = KronLinearSpec.balanced(512, 512, n_factors=2)
    params = kron_linear_init(key, spec)
    out = kron_linear_apply(params, x)
    dense_params = 512 * 512
    print(f"KronLinear 512->512: {spec.n_params} params "
          f"(dense: {dense_params}, {dense_params/spec.n_params:.0f}x smaller), "
          f"out {out.shape}")

    # --- 5. Faithful baselines are importable too --------------------------
    y_shuffle = kron_matmul_shuffle(x, factors)
    np.testing.assert_allclose(y, y_shuffle, rtol=1e-4, atol=1e-5)
    print("shuffle-algorithm baseline agrees — see benchmarks/ for speedups")


if __name__ == "__main__":
    main()
