"""Quickstart: the FastKron public API in 2 minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    KronOp,
    KronProblem,
    kron_matmul_naive,
    kron_matmul_shuffle,
)
from repro.core.layers import (
    KronLinearSpec,
    kron_linear_apply,
    kron_linear_init,
)


def main() -> None:
    key = jax.random.PRNGKey(0)

    # --- 1. Kron-Matmul without materializing the Kronecker matrix --------
    # Y = X (F1 (x) F2 (x) F3),  X: (M, 8*8*8), Fi: (8, 8).  The KronOp
    # handle resolves its execution plan ONCE at construction; every call
    # after that is plan lookup-free.
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (32, 512))
    factors = tuple(
        jax.random.normal(jax.random.fold_in(k2, i), (8, 8)) for i in range(3)
    )
    op = KronOp((8, 8, 8), (8, 8, 8), m=32)
    y = op(x, factors)
    print(f"KronOp: {x.shape} x (8x8)^3 -> {y.shape}")
    print(f"resolved handle: {op.describe()}")

    # the 512x512 Kronecker matrix is never built; verify vs the oracle:
    y_ref = kron_matmul_naive(x, list(factors))
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    print("matches the materialized oracle")

    # --- 2. Size/cost queries (the handle API's query surface) ------------
    prob = KronProblem(32, (8, 8, 8), (8, 8, 8))
    print(f"out_shape: {op.out_shape(x.shape)}, cost: {op.cost()}")
    print(f"algorithm FLOPs: {prob.flops/1e6:.1f} MFLOP "
          f"(naive would be {2*32*512*512/1e6:.1f})")

    # --- 3. It differentiates (the VJP is itself Kron-shaped) -------------
    grads = jax.grad(lambda fs: jnp.sum(op(x, fs) ** 2))(factors)
    print(f"factor grads: {[tuple(g.shape) for g in grads]}")

    # --- 4. Batched / vmap: one launch for B independent problems ---------
    opb = op.with_batch(4, shared_factors=False)
    xb = jax.random.normal(k1, (4, 8, 512))
    fb = tuple(
        jax.random.normal(jax.random.fold_in(k2, 10 + i), (4, 8, 8))
        for i in range(3)
    )
    yb = opb(xb, fb)
    yv = jax.vmap(lambda xi, fi: op(xi, fi))(xb, fb)  # same batch-grid path
    np.testing.assert_allclose(yb, yv, rtol=1e-4, atol=1e-4)
    print(f"batched op == vmap(op): {yb.shape}")

    # --- 5. KronLinear: compressed projections for models -----------------
    spec = KronLinearSpec.balanced(512, 512, n_factors=2)
    params = kron_linear_init(key, spec)
    out = kron_linear_apply(params, x)
    dense_params = 512 * 512
    print(f"KronLinear 512->512: {spec.n_params} params "
          f"(dense: {dense_params}, {dense_params/spec.n_params:.0f}x smaller), "
          f"out {out.shape}")

    # --- 6. Faithful baselines are importable too --------------------------
    y_shuffle = kron_matmul_shuffle(x, list(factors))
    np.testing.assert_allclose(y, y_shuffle, rtol=1e-4, atol=1e-4)
    print("shuffle-algorithm baseline agrees — see benchmarks/ for speedups")


if __name__ == "__main__":
    main()
