#!/usr/bin/env python
"""Docs lint: every ```python snippet in README.md / docs/ must EXECUTE, and
every internal markdown link must resolve.

    python tools/check_docs.py [files...]

Run by CI (see .github/workflows/ci.yml).  Rules:

  * Fenced blocks whose info string is exactly ``python`` are executed in a
    fresh subprocess with ``PYTHONPATH=src`` from the repo root, on the CPU
    backend with 8 forced host devices (so distributed snippets exercise a
    real multi-device mesh, same as tests/test_distributed.py).
  * Blocks marked ``python no-run`` (or any other info string: ``bash``,
    ``text``, ``json``, ...) are skipped — use ``no-run`` for illustrative
    fragments that need context the snippet doesn't set up.
  * Links ``[text](target)`` where target is not http(s)/mailto/anchor must
    point at an existing file (anchors after ``#`` are stripped; paths
    resolve relative to the containing document).

Exit status: 0 iff every snippet ran green and every internal link resolves.
"""
from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parent.parent

FENCE_RE = re.compile(r"^```(\S*)[ \t]*(.*)$")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
EXTERNAL = ("http://", "https://", "mailto:")

SNIPPET_ENV = {
    "PYTHONPATH": str(ROOT / "src"),
    "JAX_PLATFORMS": "cpu",
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
}
SNIPPET_TIMEOUT_S = 600


def doc_files(argv: list[str]) -> list[pathlib.Path]:
    if argv:
        return [pathlib.Path(a).resolve() for a in argv]
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def extract_snippets(text: str) -> list[tuple[int, str, str]]:
    """(start_line, info_string, body) for every fenced block."""
    out = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m and lines[i].startswith("```") and m.group(1):
            info = (m.group(1) + " " + m.group(2)).strip()
            body: list[str] = []
            j = i + 1
            while j < len(lines) and not lines[j].startswith("```"):
                body.append(lines[j])
                j += 1
            out.append((i + 1, info, "\n".join(body)))
            i = j + 1
        else:
            i += 1
    return out


def run_snippet(doc: pathlib.Path, line: int, code: str) -> str | None:
    """Returns an error string, or None on success."""
    with tempfile.NamedTemporaryFile(
        "w", suffix=".py", prefix="docsnippet_", delete=False
    ) as f:
        f.write(code + "\n")
        path = f.name
    try:
        proc = subprocess.run(
            [sys.executable, path],
            env={**os.environ, **SNIPPET_ENV},
            cwd=ROOT,
            capture_output=True,
            text=True,
            timeout=SNIPPET_TIMEOUT_S,
        )
    except subprocess.TimeoutExpired:
        return f"{doc.relative_to(ROOT)}:{line}: snippet timed out"
    finally:
        os.unlink(path)
    if proc.returncode != 0:
        tail = (proc.stderr or proc.stdout).strip().splitlines()[-12:]
        return (
            f"{doc.relative_to(ROOT)}:{line}: snippet failed "
            f"(exit {proc.returncode})\n    " + "\n    ".join(tail)
        )
    return None


def check_links(doc: pathlib.Path, text: str) -> list[str]:
    errors = []
    in_fence = False
    for n, raw in enumerate(text.splitlines(), 1):
        if raw.startswith("```"):
            in_fence = not in_fence
        if in_fence:
            continue
        for target in LINK_RE.findall(raw):
            if target.startswith(EXTERNAL) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            cand = (doc.parent / rel).resolve()
            if not cand.exists():
                errors.append(
                    f"{doc.relative_to(ROOT)}:{n}: broken link -> {target}"
                )
    return errors


def main(argv: list[str]) -> int:
    errors: list[str] = []
    n_snippets = 0
    for doc in doc_files(argv):
        text = doc.read_text()
        errors += check_links(doc, text)
        for line, info, body in extract_snippets(text):
            if info != "python":
                continue
            n_snippets += 1
            print(f"[docs-lint] run {doc.relative_to(ROOT)}:{line} ...",
                  flush=True)
            err = run_snippet(doc, line, body)
            if err:
                errors.append(err)
    if errors:
        print("\n".join(errors), file=sys.stderr)
        print(f"[docs-lint] FAILED ({len(errors)} problem(s))", file=sys.stderr)
        return 1
    print(f"[docs-lint] OK: {n_snippets} snippet(s) ran, links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
