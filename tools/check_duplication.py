#!/usr/bin/env python
"""Kernel-duplication lock: the legacy fused paths must STAY shims.

    python tools/check_duplication.py

Run by CI next to the api-lock step (see .github/workflows/ci.yml).  The
StageProgram refactor collapsed the twelve fused Kron-Matmul paths into the
one emitter in ``src/repro/kernels/emit.py``; the six ``fused_kron*``
wrappers in ``ops.py`` and the ``*_pallas`` entry points in ``kron_fused.py``
/ ``kron_fused_t.py`` survive only as compatibility shims.  This check fails
CI if any of them grows a non-shim body again:

  * every ``fused_kron*`` function in the legacy modules must delegate to
    ``emit`` (reference the emitter) and contain NO loops (a stage/chain loop
    is the signature of a reduplicated kernel body);
  * its body must stay small (<= MAX_SHIM_STATEMENTS statements);
  * the legacy modules must not reacquire ``pallas_call`` kernels of their
    own — the only module allowed to build Pallas kernels for fused chains
    is ``emit.py``.

Exit status: 0 iff every legacy symbol is still a shim.
"""
from __future__ import annotations

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
KERNELS = ROOT / "src" / "repro" / "kernels"

# Modules whose fused_kron* symbols are locked to shim form.
LEGACY_MODULES = ["ops.py", "kron_fused.py", "kron_fused_t.py"]
MAX_SHIM_STATEMENTS = 25


def _body_statements(fn: ast.FunctionDef) -> int:
    return sum(1 for _ in ast.walk(fn) if isinstance(_, ast.stmt)) - 1


def _has_loop(fn: ast.FunctionDef) -> bool:
    return any(
        isinstance(node, (ast.For, ast.While, ast.AsyncFor))
        for node in ast.walk(fn)
    )


def _references_emit(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id == "emit":
            return True
        if isinstance(node, ast.Attribute) and node.attr in (
            "run_stage", "run_stage_grad", "run_program"
        ):
            return True
    return False


def check_module(path: pathlib.Path) -> tuple[list[str], int]:
    errors: list[str] = []
    n_checked = 0
    text = path.read_text()
    tree = ast.parse(text)
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        if not node.name.startswith("fused_kron"):
            continue
        n_checked += 1
        where = f"{path.relative_to(ROOT)}:{node.lineno}: {node.name}"
        if _has_loop(node):
            errors.append(
                f"{where} contains a loop — a reduplicated stage/chain body; "
                "route it through kernels/emit.py instead"
            )
        if not _references_emit(node):
            errors.append(
                f"{where} does not delegate to the emitter (no `emit` "
                "reference) — legacy fused paths must stay shims"
            )
        n = _body_statements(node)
        if n > MAX_SHIM_STATEMENTS:
            errors.append(
                f"{where} has {n} statements (> {MAX_SHIM_STATEMENTS}) — "
                "grew a non-shim body"
            )
    if "pallas_call" in text:
        errors.append(
            f"{path.relative_to(ROOT)}: builds its own pallas_call — fused "
            "Pallas kernels belong in kernels/emit.py only"
        )
    return errors, n_checked


def main() -> int:
    errors: list[str] = []
    n_checked = 0
    for name in LEGACY_MODULES:
        path = KERNELS / name
        if not path.exists():
            errors.append(f"missing legacy module {name}")
            continue
        mod_errors, mod_n = check_module(path)
        errors.extend(mod_errors)
        n_checked += mod_n
    if not (KERNELS / "emit.py").exists():
        errors.append("kernels/emit.py vanished — the unified emitter is gone")
    if errors:
        print("\n".join(errors), file=sys.stderr)
        print(f"[dup-lock] FAILED ({len(errors)} problem(s))", file=sys.stderr)
        return 1
    print(
        f"[dup-lock] OK: {n_checked} legacy fused_kron* symbol(s) across "
        f"{len(LEGACY_MODULES)} module(s) are still emitter shims"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
