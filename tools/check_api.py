#!/usr/bin/env python
"""API surface lock: the public surface must match docs/api.md, both ways.

    PYTHONPATH=src python tools/check_api.py

Run by CI next to the docs-lint step (see .github/workflows/ci.yml).  Rules:

  * Every module in ``LOCKED`` must define ``__all__``, and every symbol in
    it must be mentioned in docs/api.md (inside backticks — a heading, a
    signature, or prose).  An exported-but-undocumented symbol fails CI:
    growing the public surface requires documenting it.
  * Every non-dotted backticked identifier in a docs/api.md HEADING must
    resolve to an attribute of some locked module.  A documented-but-
    vanished symbol fails CI: shrinking or renaming the surface requires
    updating the docs.

Exit status: 0 iff the surface and the reference agree.
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
API_MD = ROOT / "docs" / "api.md"

LOCKED = [
    "repro.core",
    "repro.core.engine",
    "repro.core.fastkron",
    "repro.core.distributed",
    "repro.core.autotune",
    "repro.core.layers",
    "repro.gp.ski",
    "repro.kernels.ops",
    "repro.kernels.emit",
    "repro.launch.scheduler",
    "repro.optim.shampoo",
    "repro.runtime.guard",
    "repro.runtime.chaos",
    "repro.runtime.telemetry",
]

_IDENT = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")


def _prose_lines(text: str):
    """Lines outside ``` fences (fenced code would desync backtick pairing);
    code-block identifiers are exercised by tools/check_docs.py instead."""
    in_fence = False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence:
            yield line


def documented_names(text: str) -> set[str]:
    """Every identifier mentioned inside backticks in the doc's prose."""
    names: set[str] = set()
    for line in _prose_lines(text):
        for tok in re.findall(r"`([^`]+)`", line):
            m = _IDENT.match(tok.strip())
            if m:
                base = tok.strip().split("(")[0]
                names.add(m.group(0))
                names.update(p for p in base.split(".") if _IDENT.fullmatch(p))
    return names


def heading_symbols(text: str) -> list[tuple[int, str]]:
    """(line, identifier) for backticked names in headings — the doc's claim
    of what exists.  Dotted tokens (module paths) are skipped; they are
    checked by importing LOCKED."""
    out = []
    for n, line in enumerate(text.splitlines(), 1):
        if not line.startswith("#"):
            continue
        for tok in re.findall(r"`([^`]+)`", line):
            head = tok.strip().split("(")[0]
            if "." in head:
                continue
            if _IDENT.fullmatch(head):
                out.append((n, head))
    return out


def main() -> int:
    errors: list[str] = []
    text = API_MD.read_text()
    documented = documented_names(text)

    mods = {}
    for name in LOCKED:
        try:
            mods[name] = importlib.import_module(name)
        except Exception as e:  # import failure IS a surface break
            errors.append(f"{name}: cannot import ({e})")
    n_symbols = 0
    for name, mod in mods.items():
        exported = getattr(mod, "__all__", None)
        if exported is None:
            errors.append(f"{name}: locked module has no __all__")
            continue
        for sym in exported:
            n_symbols += 1
            if not hasattr(mod, sym):
                errors.append(f"{name}.__all__ lists {sym!r} but the module "
                              "does not define it")
            if sym not in documented:
                errors.append(
                    f"{name}.{sym} is public (__all__) but never mentioned "
                    "in docs/api.md — document it or un-export it"
                )

    universe: set[str] = set()
    for mod in mods.values():
        universe.update(getattr(mod, "__all__", ()))
        universe.update(dir(mod))
    for line, sym in heading_symbols(text):
        if sym not in universe:
            errors.append(
                f"docs/api.md:{line}: heading documents `{sym}` but no "
                "locked module exports it — vanished/renamed symbol"
            )

    if errors:
        print("\n".join(errors), file=sys.stderr)
        print(f"[api-lock] FAILED ({len(errors)} problem(s))", file=sys.stderr)
        return 1
    print(f"[api-lock] OK: {n_symbols} public symbol(s) across "
          f"{len(mods)} module(s) match docs/api.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
