"""int8 KV cache: accuracy envelope + decode/prefill consistency."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models.attention import QuantKVCache, _dequantize_kv, _quantize_kv
from repro.models.config import reduced


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32))
    q, s = _quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 16, 4, 1)
    back = _dequantize_kv(q, s, jnp.float32)
    # absmax int8: max error = scale/2 = absmax/254 per (token, head)
    err = jnp.max(jnp.abs(back - x), axis=-1)
    bound = jnp.max(jnp.abs(x), axis=-1) / 127.0
    assert bool(jnp.all(err <= bound + 1e-6))


@pytest.mark.parametrize("arch", ["gemma_2b", "mixtral_8x22b"])
def test_quant_decode_close_to_exact(arch):
    """prefill+decode with int8 cache tracks the fp32 cache closely."""
    cfg = reduced(get_config(arch), dtype="float32")
    cfg_q = replace(cfg, kv_quant=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    max_len = 32

    _, cache = M.prefill(cfg, params, tokens, max_len)
    _, cache_q = M.prefill(cfg_q, params, tokens, max_len)
    # quantized cache leaves are int8
    leaves = jax.tree.leaves(cache_q)
    assert any(l.dtype == jnp.int8 for l in leaves)

    last = tokens[:, -1:]
    lg, _ = M.decode_step(cfg, params, cache, last, jnp.int32(24))
    lg_q, _ = M.decode_step(cfg_q, params, cache_q, last, jnp.int32(24))
    # logits agree to int8-KV tolerance; argmax agrees
    np.testing.assert_allclose(np.asarray(lg_q), np.asarray(lg),
                               rtol=0.1, atol=0.15)
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(lg, -1)), np.asarray(jnp.argmax(lg_q, -1))
    )


def test_quant_cache_half_the_bytes():
    cfg = reduced(get_config("gemma_2b"), dtype="float32")
    cfg_q = replace(cfg, kv_quant=True)
    c = M.init_cache(cfg, batch=2, max_len=64)
    c_q = M.init_cache(cfg_q, batch=2, max_len=64)

    def nbytes(t):
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(t))

    # f32 cache in tests: int8+f32 scales ~ (1 + 4/hd)/4 of it; vs bf16
    # production cache the ratio is (1 + 4/hd)/2.
    assert nbytes(c_q) < 0.45 * nbytes(c)
