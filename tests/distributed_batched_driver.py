"""Multi-device driver for BATCHED distributed Kron-Matmul tests (PR 3).

Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(set by tests/test_distributed.py) so the parent pytest process keeps its
single-device view.  Prints 'OK <name>' per passing check; exits nonzero on
failure.

Checks, per the acceptance criteria:
  * shared- and per-sample-factor batched results match the LOOPED
    per-problem ``kron_matmul_distributed`` reference (fwd + grads) on a
    >= 4-device model axis;
  * the batched path emits exactly ONE all_to_all per relocation round for
    the whole batch (the looped path emits B per round), pinned via compiled
    HLO counts AND the batch-aware ``comm_elems_per_device`` accounting;
  * consumers: ``gp_train_epoch_batched(mesh=...)`` and the
    ``layers.kron_distributed`` scope agree with their local counterparts.
"""
import math
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.distributed import (  # noqa: E402
    comm_elems_per_device,
    kron_matmul_batched_distributed,
    kron_matmul_distributed,
    plan_rounds,
    sharded_input_batched,
)
from repro.runtime.hlo_analysis import collective_stats  # noqa: E402

G_M, G_K = 2, 4


def _mk(b, m, ps, qs, *, per_sample, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ps) + 1)
    x = jax.random.normal(keys[0], (b, m, math.prod(ps)), jnp.float32)
    shape = (lambda p, q: (b, p, q)) if per_sample else (lambda p, q: (p, q))
    fs = tuple(
        jax.random.normal(k, shape(p, q), jnp.float32)
        for k, p, q in zip(keys[1:], ps, qs)
    )
    return x, fs


def _looped(x, fs, mesh, *, per_sample):
    """The per-problem reference the batched path replaces: one distributed
    dispatch per sample, reassembled with stack."""
    b = x.shape[0]
    return jnp.stack([
        kron_matmul_distributed(
            x[i], tuple(f[i] for f in fs) if per_sample else fs, mesh
        )
        for i in range(b)
    ])


def main() -> None:
    devs = jax.devices()
    assert len(devs) == 8, f"expected 8 devices, got {len(devs)}"
    mesh = jax.make_mesh((G_M, G_K), ("data", "model"))

    cases = [
        (8, 8, (4, 4, 4), (4, 4, 4)),     # rounds [2, 1] on G_K=4
        (4, 4, (2, 2, 2, 2), (2, 2, 2, 2)),  # Q=2: G_K|Q^L forces L>=2
        (6, 4, (4, 2, 4), (4, 4, 2)),     # rectangular mix, B not a pow2
    ]

    # --- correctness: batched == looped per-problem reference (fwd) --------
    for b, m, ps, qs in cases:
        for per_sample in (False, True):
            x, fs = _mk(b, m, ps, qs, per_sample=per_sample, seed=hash((b, ps)) % 997)
            xs = sharded_input_batched(x, mesh)
            got = kron_matmul_batched_distributed(
                xs, fs, mesh, shared_factors=not per_sample
            )
            want = _looped(x, fs, mesh, per_sample=per_sample)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
            )
            mode = "per_sample" if per_sample else "shared"
            print(f"OK fwd {mode} b={b} m={m} ps={ps} qs={qs}")

    # --- correctness: grads (fwd + bwd through the collective) -------------
    b, m, ps, qs = 8, 8, (4, 4, 4), (4, 4, 4)
    for per_sample in (False, True):
        x, fs = _mk(b, m, ps, qs, per_sample=per_sample, seed=7)

        def loss_b(x, fs, per_sample=per_sample):
            y = kron_matmul_batched_distributed(
                x, fs, mesh, shared_factors=not per_sample
            )
            return (y * jnp.cos(y)).sum()  # x-dependent cotangent

        def loss_l(x, fs, per_sample=per_sample):
            y = _looped(x, fs, mesh, per_sample=per_sample)
            return (y * jnp.cos(y)).sum()

        gx, gf = jax.grad(loss_b, argnums=(0, 1))(x, fs)
        gx_r, gf_r = jax.grad(loss_l, argnums=(0, 1))(x, fs)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r),
                                   rtol=1e-4, atol=1e-4)
        for a, r in zip(gf, gf_r):
            np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                       rtol=1e-4, atol=1e-4)
        print(f"OK grads {'per_sample' if per_sample else 'shared'}")

    # --- one collective per round for the WHOLE batch ----------------------
    b, m, ps, qs = 8, 8, (4, 4, 4), (4, 4, 4)
    x, fs = _mk(b, m, ps, qs, per_sample=True, seed=3)
    xs = sharded_input_batched(x, mesh)
    rev_ps, rev_qs = list(reversed(ps)), list(reversed(qs))
    k_loc = math.prod(ps) // G_K
    rounds = plan_rounds(k_loc, rev_ps, rev_qs, G_K)

    fn_b = jax.jit(lambda x, fs: kron_matmul_batched_distributed(
        x, fs, mesh, shared_factors=False))
    st_b = collective_stats(fn_b.lower(xs, fs).compile().as_text())
    assert st_b.count_by_op.get("all-to-all", 0) == len(rounds), (
        f"batched path must emit one all-to-all per round "
        f"({len(rounds)} rounds), got {st_b.count_by_op}"
    )
    fn_l = jax.jit(lambda x, fs: _looped(x, fs, mesh, per_sample=True))
    st_l = collective_stats(fn_l.lower(x, fs).compile().as_text())
    assert st_l.count_by_op.get("all-to-all", 0) == b * len(rounds), (
        f"looped reference should emit B collectives per round, "
        f"got {st_l.count_by_op}"
    )
    print(f"OK collective-count batched={len(rounds)} looped={b * len(rounds)}")

    # --- batch-aware analytic comm accounting ------------------------------
    m_loc = m // G_M
    per_problem = comm_elems_per_device(m_loc, k_loc, rev_ps, rev_qs, G_K)
    whole_batch = comm_elems_per_device(
        m_loc, k_loc, rev_ps, rev_qs, G_K, batch=b
    )
    assert whole_batch == b * per_problem, (whole_batch, per_problem)
    # HLO payloads scale the same way: bytes(batched) == B * bytes(one problem)
    bytes_one = collective_stats(
        jax.jit(lambda x, fs: kron_matmul_distributed(x, fs, mesh))
        .lower(x[0], tuple(f[0] for f in fs)).compile().as_text()
    ).total_bytes
    assert st_b.total_bytes == b * bytes_one, (st_b.total_bytes, bytes_one)
    print(f"OK comm-accounting elems/dev={whole_batch} "
          f"(= {b} x {per_problem}), hlo {st_b.total_bytes}B = {b} x {bytes_one}B")

    # --- consumer: gp_train_epoch_batched(mesh=...) ------------------------
    from repro.gp.ski import (
        BatchedKronKernel, KronKernel, gp_train_epoch_batched, rbf_kernel_1d,
    )

    grid = jnp.linspace(0.0, 1.0, 4)
    kb = 4
    kernels = [
        KronKernel((rbf_kernel_1d(grid, 0.1 + 0.1 * i),
                    rbf_kernel_1d(grid, 0.3),
                    rbf_kernel_1d(grid, 0.2)))
        for i in range(kb)
    ]
    bk = BatchedKronKernel.stack(kernels)
    v = jax.random.normal(jax.random.PRNGKey(5), (kb, 8, bk.dim), jnp.float32)
    sol_d, res_d = gp_train_epoch_batched(bk, v, cg_iters=5, mesh=mesh)
    sol_l, res_l = gp_train_epoch_batched(bk, v, cg_iters=5)
    np.testing.assert_allclose(np.asarray(sol_d), np.asarray(sol_l),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(res_d), np.asarray(res_l),
                               rtol=1e-4, atol=1e-4)
    print("OK gp-batched-mesh")

    # --- consumer: layers.kron_distributed scope ---------------------------
    from repro.core.layers import (
        KronLinearSpec, kron_distributed, kron_linear_apply, kron_linear_init,
    )

    spec = KronLinearSpec((4, 4, 4), (4, 4, 4))
    params = kron_linear_init(jax.random.PRNGKey(9), spec)
    xb = jax.random.normal(jax.random.PRNGKey(11), (4, 8, spec.d_in))
    y_local = kron_linear_apply(params, xb)
    with kron_distributed(mesh):
        y_dist = kron_linear_apply(params, xb)
        st = collective_stats(
            jax.jit(lambda p, x: kron_linear_apply(p, x))
            .lower(params, xb).compile().as_text()
        )
    assert st.count_by_op.get("all-to-all", 0) >= 1, st.count_by_op
    np.testing.assert_allclose(np.asarray(y_dist), np.asarray(y_local),
                               rtol=1e-5, atol=1e-5)
    # fallback: a width the model axis cannot host stays local, no error
    xs_bad = jax.random.normal(jax.random.PRNGKey(12), (4, 8, 6))
    ps_bad = kron_linear_init(jax.random.PRNGKey(13), KronLinearSpec((3, 2), (3, 2)))
    with kron_distributed(mesh):
        y_bad = kron_linear_apply(ps_bad, xs_bad)
    np.testing.assert_allclose(
        np.asarray(y_bad), np.asarray(kron_linear_apply(ps_bad, xs_bad)),
        rtol=1e-5, atol=1e-5,
    )
    print("OK layers-distributed-scope")

    print("ALL-OK")


if __name__ == "__main__":
    sys.exit(main())
