"""Kron-factored Shampoo optimizer: correctness, degradation, telemetry.

The contracts pinned here (docs/optim.md):

* identity roots reproduce the grafted-AdamW step EXACTLY — the shared
  fallback target for warmup, stale intervals, and failed refreshes;
* the shape-grouped batched KronOp apply is bitwise identical to the
  looped per-layer reference (tiles never split the contraction dim);
* a layer's preconditioned update is invariant to the other members of
  its shape group (ordering, company) — per-sample factors really are
  per-sample;
* state round-trips through the checkpoint manager;
* a chaos-injected ``root_refresh`` fault degrades the layer to grafted
  AdamW for the interval and lands in guard health — never crashes;
* telemetry off adds zero compiled HLO to the optimizer path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.optim import adamw
from repro.optim import shampoo as sh
from repro.optim.adamw import OptConfig, opt_init, opt_update
from repro.optim.shampoo import ShampooConfig
from repro.runtime import chaos, guard, telemetry


@pytest.fixture(autouse=True)
def _fresh_state():
    guard.reset_health()
    telemetry.reset()
    yield
    guard.reset_health()
    telemetry.reset()


def _params(seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 6)
    return {
        "embed": jax.random.normal(ks[0], (48, 16)) * 0.1,
        "stack": {
            "w1": jax.random.normal(ks[1], (2, 16, 32)) * 0.1,
            "w2": jax.random.normal(ks[2], (2, 32, 16)) * 0.1,
            "wq": jax.random.normal(ks[3], (2, 16, 16)) * 0.1,
            "ln": jnp.ones((2, 16)),  # stacked norm: (S, d) -> AdamW path
        },
        "head": jax.random.normal(ks[4], (16, 32)) * 0.1,
        "bias": jnp.zeros((16,)),
    }


def _grads(params, seed=1):
    leaves, treedef = jax.tree.flatten(params)
    ks = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(
        treedef, [jax.random.normal(k, l.shape) for k, l in zip(ks, leaves)]
    )


# ---------------------------------------------------------------------------
# Eligibility / grouping
# ---------------------------------------------------------------------------


def test_rank_shortlist():
    cfg = ShampooConfig()
    groups = sh.shape_groups(_params(), cfg)
    member_paths = {p for members in groups.values() for p, _ in members}
    # 1-D bias and the (S, d) stacked norm fall back to AdamW
    assert "bias" not in member_paths
    assert "stack/ln" not in member_paths
    # stacked 3-D leaves contribute S samples to their group
    assert ("head", 1) in groups[(16, 32)]
    assert ("stack/w1", 2) in groups[(16, 32)]
    # vocab-sized dims beyond the shortlist fall back too
    small = dataclasses.replace(cfg, max_precond_dim=20)
    g2 = sh.shape_groups(_params(), small)
    assert "embed" not in {p for m in g2.values() for p, _ in m}


def test_prebuild_includes_optimizer_ops():
    from repro.configs import get_config
    from repro.models.config import reduced
    from repro.train.steps import prebuild_kron_ops

    cfg = reduced(
        get_config("qwen3_4b"), n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
        vocab_pad_multiple=32, dtype="float32",
    )
    ops = prebuild_kron_ops(cfg, opt_cfg=ShampooConfig())
    assert ops, "shampoo opt_cfg must prewarm the shape-group ops"
    assert all(op.batch is not None and not op.shared_factors for op in ops)
    assert prebuild_kron_ops(cfg, opt_cfg=OptConfig()) == ()


# ---------------------------------------------------------------------------
# Correctness: identity roots == grafted AdamW, batched == looped == dense
# ---------------------------------------------------------------------------


def test_identity_roots_match_adamw_exactly():
    """Fresh roots are identity -> the whole step IS the AdamW step, for
    eligible and ineligible leaves alike (the degradation target)."""
    params, grads = _params(), _grads(_params())
    acfg = OptConfig()
    scfg = ShampooConfig(precond_every=50)
    ast = opt_init(params, acfg)
    sst = sh.shampoo_init(params, scfg)
    # step 2: past the step==1 refresh, roots still identity
    ast["step"] = jnp.asarray(1, jnp.int32)
    sst["step"] = jnp.asarray(1, jnp.int32)
    ap, ast2, am = opt_update(grads, ast, params, acfg)
    sp, sst2, sm = sh.shampoo_update(grads, sst, params, scfg)
    for a, s_ in zip(jax.tree.leaves(ap), jax.tree.leaves(sp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(s_))
    for k in ("m", "v"):
        for a, s_ in zip(jax.tree.leaves(ast2[k]), jax.tree.leaves(sst2[k])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(s_))
    assert float(am["grad_norm"]) == float(sm["grad_norm"])


def _refreshed_state(params, grads, cfg):
    """One step from init: the step==1 refresh computes real roots."""
    st = sh.shampoo_init(params, cfg)
    _, st1, _ = sh.shampoo_update(grads, st, params, cfg)
    return st1


def test_batched_apply_bitwise_equals_looped():
    params = _params()
    cfg = ShampooConfig()
    kron = _refreshed_state(params, _grads(params), cfg)["kron"]
    ups = {
        path: jax.random.normal(
            jax.random.PRNGKey(hash(path) % 2**31),
            (
                e["ok"].shape[0],
                e["lroot"].shape[-1],
                e["rroot"].shape[-1],
            ),
        )
        for path, e in kron.items()
    }
    yb = sh.precondition(ups, kron)
    yl = sh.precondition(ups, kron, looped=True)
    assert set(yb) == set(yl)
    for path in yb:
        np.testing.assert_array_equal(np.asarray(yb[path]), np.asarray(yl[path]))


def test_precondition_matches_dense_reference():
    """The KronOp apply computes Lroot^T u Rroot per layer."""
    params = _params()
    cfg = ShampooConfig()
    kron = _refreshed_state(params, _grads(params), cfg)["kron"]
    ups = {
        path: jnp.ones(
            (e["ok"].shape[0], e["lroot"].shape[-1], e["rroot"].shape[-1])
        )
        for path, e in kron.items()
    }
    out = sh.precondition(ups, kron)
    for path, e in kron.items():
        ref = jnp.einsum(
            "spk,spq,sqj->skj", e["lroot"], ups[path], e["rroot"]
        )
        np.testing.assert_allclose(
            np.asarray(out[path]), np.asarray(ref), rtol=2e-5, atol=2e-5
        )


def test_update_invariant_to_group_ordering():
    """Per-sample factors: a layer's preconditioned update must not depend
    on the order (or company) of the other layers in its shape group."""
    params = _params()
    cfg = ShampooConfig()
    kron = _refreshed_state(params, _grads(params), cfg)["kron"]
    ups = {
        path: jax.random.normal(
            jax.random.PRNGKey(i),
            (
                e["ok"].shape[0],
                e["lroot"].shape[-1],
                e["rroot"].shape[-1],
            ),
        )
        for i, (path, e) in enumerate(kron.items())
    }
    fwd = sh.precondition(ups, kron)
    # reversed insertion order permutes every group's member stacking
    rev_paths = list(kron)[::-1]
    kron_r = {p: kron[p] for p in rev_paths}
    ups_r = {p: ups[p] for p in rev_paths}
    rev = sh.precondition(ups_r, kron_r)
    for path in fwd:
        np.testing.assert_array_equal(
            np.asarray(fwd[path]), np.asarray(rev[path])
        )
    # and each layer alone reproduces its grouped result bitwise
    for path in fwd:
        alone = sh.precondition({path: ups[path]}, {path: kron[path]})
        np.testing.assert_array_equal(
            np.asarray(fwd[path]), np.asarray(alone[path])
        )


def test_property_group_permutation_invariance():
    hyp = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis"
    )
    from hypothesis import given, settings, strategies as st

    cfg = ShampooConfig()

    @given(st.permutations(list(range(4))), st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def prop(perm, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 8)
        params = {f"w{i}": jax.random.normal(ks[i], (8, 12)) for i in range(4)}
        grads = {
            f"w{i}": jax.random.normal(ks[4 + i], (8, 12)) for i in range(4)
        }
        kron = _refreshed_state(params, grads, cfg)["kron"]
        ups = {p: g.reshape(1, 8, 12) for p, g in grads.items()}
        base = sh.precondition(ups, kron)
        names = [f"w{i}" for i in perm]
        permuted = sh.precondition(
            {n: ups[n] for n in names}, {n: kron[n] for n in names}
        )
        for p in base:
            np.testing.assert_array_equal(
                np.asarray(base[p]), np.asarray(permuted[p])
            )

    prop()


def test_inverse_root_methods_agree():
    # rank-deficient on purpose: the early-training shape (an EMA of a few
    # gradient outer products) that the lambda_max-relative ridge exists for
    g = jax.random.normal(jax.random.PRNGKey(3), (24, 16))
    s = g @ g.T
    re, oke = sh.inverse_quarter_root(s, method="eigh")
    rn, okn = sh.inverse_quarter_root(s, method="newton", iters=30)
    assert bool(oke) and bool(okn)
    scale = float(jnp.max(jnp.abs(re)))
    np.testing.assert_allclose(
        np.asarray(re), np.asarray(rn), atol=1e-4 * scale
    )
    # actually an inverse quarter root: root^4 (S + ridge I) ~ I
    ridge = sh._ridge_of(s, 1e-2)
    r4 = re @ re @ re @ re
    np.testing.assert_allclose(
        np.asarray(r4 @ (s + ridge * jnp.eye(24))), np.eye(24),
        atol=5e-3,
    )


# ---------------------------------------------------------------------------
# Refresh cadence, staleness, checkpoint round-trip
# ---------------------------------------------------------------------------


def test_refresh_cadence_and_stale_counter():
    params = _params()
    cfg = ShampooConfig(precond_every=3)
    st = sh.shampoo_init(params, cfg)
    step = jax.jit(lambda g, s: sh.shampoo_update(g, s, params, cfg))
    stales = []
    for i in range(7):
        _, st, m = step(_grads(params, seed=i), st)
        stales.append(int(m["precond_stale_steps"]))
    # refreshes at steps 1, 3, 6 -> stale resets there, counts up between
    assert stales == [0, 1, 0, 1, 2, 0, 1]
    assert all(bool(e["ok"].all()) for e in st["kron"].values())


def test_state_roundtrips_through_checkpoint(tmp_path):
    params = _params()
    cfg = ShampooConfig()
    st = _refreshed_state(params, _grads(params), cfg)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(7, st)
    target = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), st
    )
    back = mgr.restore(target)
    flat_a = jax.tree_util.tree_flatten_with_path(st)[0]
    flat_b = jax.tree_util.tree_flatten_with_path(back)[0]
    assert [k for k, _ in flat_a] == [k for k, _ in flat_b]
    for (_, a), (_, b) in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Guard: chaos-injected refresh failure, numerics policy
# ---------------------------------------------------------------------------


def test_chaos_root_refresh_degrades_layer_not_step():
    params, grads = _params(), _grads(_params())
    cfg = ShampooConfig()
    st = sh.shampoo_init(params, cfg)
    with chaos.inject("root_refresh:times=1") as specs:
        newp, st1, m = sh.shampoo_update(grads, st, params, cfg)
    assert specs[0].fired == 1
    # the step completed; exactly one leaf lost its refresh for the interval
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(newp))
    down = [p for p, e in st1["kron"].items() if not bool(e["ok"].any())]
    up = [p for p, e in st1["kron"].items() if bool(e["ok"].all())]
    assert len(down) == 1 and up
    # degraded leaf: roots still identity (kept), stale kept counting
    e = st1["kron"][down[0]]
    np.testing.assert_array_equal(
        np.asarray(e["lroot"]), np.asarray(st["kron"][down[0]]["lroot"])
    )
    assert int(e["stale"].max()) == 1
    # and the event is in guard health
    assert guard.health_report()["events"]["root_refresh_degraded"] >= 1
    # the degraded layer's update IS the grafted-AdamW fallback: bitwise
    # equal to a plain AdamW step on the same grads (fresh state both ways)
    ap, _, _ = opt_update(grads, opt_init(params, OptConfig()), params,
                          OptConfig())
    by_path_sh = {
        sh._leaf_path(kp): l
        for kp, l in jax.tree_util.tree_flatten_with_path(newp)[0]
    }
    by_path_ad = {
        sh._leaf_path(kp): l
        for kp, l in jax.tree_util.tree_flatten_with_path(ap)[0]
    }
    np.testing.assert_array_equal(
        np.asarray(by_path_sh[down[0]]), np.asarray(by_path_ad[down[0]])
    )
    # while a healthy preconditioned layer diverged from plain AdamW
    assert not np.array_equal(
        np.asarray(by_path_sh[up[0]]), np.asarray(by_path_ad[up[0]])
    )


def test_numerics_policy_warn_and_raise():
    params = _params()
    grads = _grads(params)
    # poison one eligible leaf -> its statistics (and roots) go non-finite
    grads["head"] = grads["head"].at[0, 0].set(jnp.nan)
    cfg = ShampooConfig()
    st = sh.shampoo_init(params, cfg)
    with guard.numerics("warn"):
        with pytest.warns(guard.GuardWarning, match="inverse-root"):
            _, st1, _ = sh.shampoo_update(grads, st, params, cfg)
    assert guard.health_report()["events"]["root_refresh_degraded"] >= 1
    guard.reset_health()
    with guard.numerics("raise"):
        with pytest.raises(guard.NumericsError):
            sh.shampoo_update(grads, st, params, cfg)
    # off: silent, but the poisoned layer still degrades via its ok flag
    _, st2, m = sh.shampoo_update(grads, st, params, cfg)
    assert not bool(st2["kron"]["head"]["ok"].any())
    assert float(m["precond_ok_frac"]) < 1.0


# ---------------------------------------------------------------------------
# Telemetry: spans + zero-compiled-HLO pin on the optimizer path
# ---------------------------------------------------------------------------


def test_spans_and_histograms_when_active():
    params, grads = _params(), _grads(_params())
    cfg = ShampooConfig()
    st = sh.shampoo_init(params, cfg)
    telemetry.configure()
    sh.shampoo_update(grads, st, params, cfg)
    snap = telemetry.snapshot()
    assert "span.optim.root_refresh" in snap["histograms"]
    assert "span.optim.precondition" in snap["histograms"]


def test_telemetry_off_adds_zero_hlo_to_optimizer_step():
    params, grads = _params(), _grads(_params())
    cfg = ShampooConfig(precond_every=2)
    st = sh.shampoo_init(params, cfg)

    def compiled_text():
        return (
            jax.jit(lambda g, s: sh.shampoo_update(g, s, params, cfg))
            .lower(grads, st)
            .compile()
            .as_text()
        )

    off_before = compiled_text()
    assert "kronscope" not in off_before
    telemetry.configure()
    on = compiled_text()
    telemetry.reset()
    off_after = compiled_text()
    assert off_before == off_after
    assert "kronscope" not in off_after
    del on  # annotation side of the pin is covered in test_telemetry


# ---------------------------------------------------------------------------
# Dispatch, shardings, memory report
# ---------------------------------------------------------------------------


def test_opt_for_dispatch():
    assert sh.opt_for(OptConfig()) == (opt_init, opt_update)
    init_fn, update_fn = sh.opt_for(ShampooConfig())
    assert init_fn is sh.shampoo_init and update_fn is sh.shampoo_update


def test_opt_state_shardings_structure():
    from repro.train.steps import opt_state_shardings

    params = _params()
    cfg = ShampooConfig()
    st = sh.shampoo_init(params, cfg)
    PSH = object()
    p_shard = jax.tree.map(lambda _: PSH, params)
    REP = object()
    shard = opt_state_shardings(st, p_shard, REP)
    assert all(s is PSH for s in jax.tree.leaves(shard["m"]))
    assert all(s is PSH for s in jax.tree.leaves(shard["v"]))
    assert shard["step"] is REP
    assert all(s is REP for s in jax.tree.leaves(shard["kron"]))


def test_state_memory_report():
    params = _params()
    st = sh.shampoo_init(params, ShampooConfig(state_dtype="bfloat16"))
    rep = sh.state_memory_report(st)
    total = sum(
        int(l.size) * jnp.dtype(l.dtype).itemsize for l in jax.tree.leaves(st)
    )
    assert rep["total_bytes"] == total == sum(rep["by_dtype"].values())
    assert rep["by_dtype"]["bfloat16"] > 0  # m/v + statistics in bf16
    assert rep["by_dtype"]["float32"] > 0   # roots stay f32


def test_bf16_state_dtype_halves_mv():
    params = _params()
    st32 = sh.shampoo_init(params, ShampooConfig())
    st16 = sh.shampoo_init(params, ShampooConfig(state_dtype="bfloat16"))
    b32 = sh.state_memory_report({"m": st32["m"], "v": st32["v"]})
    b16 = sh.state_memory_report({"m": st16["m"], "v": st16["v"]})
    assert b16["total_bytes"] * 2 == b32["total_bytes"]


# ---------------------------------------------------------------------------
# End to end: the acceptance training run (slow)
# ---------------------------------------------------------------------------


def _tiny_cfg():
    from repro.configs import get_config
    from repro.models.config import reduced

    return reduced(
        get_config("qwen3_4b"), n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
        vocab_pad_multiple=32, dtype="float32",
    )


@pytest.mark.slow
def test_shampoo_reaches_adamw_loss_at_same_steps():
    """Fixed seed, reduced config, 80 steps: the Kron-preconditioned run
    must reach a loss <= AdamW's (the BENCH_optim acceptance bar)."""
    from repro.data import SyntheticLM
    from repro.train.steps import make_train_step, train_state_init

    cfg = _tiny_cfg()

    def run(ocfg, steps=80):
        data = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch=8)
        state = train_state_init(cfg, ocfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, ocfg))
        for i in range(steps):
            toks, labels = data.global_batch(i)
            state, m = step(
                state,
                {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)},
            )
        return float(m["loss"])

    kw = dict(lr=3e-3, warmup_steps=5, decay_steps=80)
    adamw_loss = run(OptConfig(**kw))
    shampoo_loss = run(
        ShampooConfig(
            precond_every=10, stats_beta=0.95, matrix_eps=3e-2, **kw
        )
    )
    assert shampoo_loss <= adamw_loss, (shampoo_loss, adamw_loss)


@pytest.mark.slow
def test_shampoo_jit_train_step_refreshes_in_graph():
    """The refresh is a lax.cond inside ONE compiled step: no retraces
    across the cadence boundary (zero mid-training re-plans)."""
    from repro.data import SyntheticLM
    from repro.train.steps import make_train_step, train_state_init

    cfg = _tiny_cfg()
    ocfg = ShampooConfig(lr=1e-3, warmup_steps=2, decay_steps=20,
                         precond_every=3)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch=4)
    state = train_state_init(cfg, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, ocfg))
    for i in range(7):
        toks, labels = data.global_batch(i)
        state, m = step(
            state,
            {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)},
        )
    assert step._cache_size() == 1
    assert all(bool(e["ok"].all()) for e in state.opt["kron"].values())
