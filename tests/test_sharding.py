"""Unit tests for the sharding rules + HLO cost analyzer."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime.hlo_analysis import collective_stats, shape_bytes
from repro.runtime.hlo_cost import analyze
from repro.runtime.sharding import cache_spec, param_spec


@pytest.fixture(scope="module")
def mesh():
    # single-device mesh still exercises the rule logic (sizes are 1)
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_spec_roles(mesh):
    # matrices: (fsdp, tp) in / (tp, fsdp) out
    assert param_spec("stack/pos0/mixer/wq", (8, 64, 64), mesh) == P(None, "data", "model")
    assert param_spec("stack/pos0/mixer/wo", (8, 64, 64), mesh) == P(None, "model", "data")
    assert param_spec("stack/pos0/ffn/w2", (64, 64), mesh) == P("model", "data")
    # embed vocab-over-TP
    assert param_spec("embed", (512, 64), mesh) == P("model", None)
    # KronLinear factors replicated
    assert param_spec("stack/pos0/ffn/w1/factors/0", (8, 8), mesh) == P(None, None)
    # norms replicated
    assert param_spec("final_norm", (64,), mesh) == P(None)


def test_param_spec_moe_expert_vs_tp(mesh):
    big = jax.make_mesh((1, 1), ("data", "model"))
    # E divisible by tp (1) -> expert parallel
    assert param_spec("ffn/ew1", (4, 8, 16), big) == P("model", "data", None)


def test_param_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # dims of size 7 can't shard over axes of size 1? size-1 axes divide
    # everything; rules still apply. Use the path where dim % size != 0 by
    # constructing spec directly via _fit semantics: with 1-device axes all
    # divisible — assert shape-length consistency instead.
    spec = param_spec("stack/pos0/mixer/wq", (3, 7, 5), mesh)
    assert len(spec) == 3


def test_cache_spec_batch_vs_seq_sharding(mesh):
    # batch shardable -> batch-major
    assert cache_spec("stack/pos0/k", (2, 4, 128, 8, 64), mesh, batch=4) == P(
        None, "data", None, None, "model"
    )
    assert cache_spec("stack/pos0/pos", (2, 128), mesh, batch=4) == P(None, None)
    # The B=1 sequence-parallel branch needs a multi-device axis to
    # differentiate (on a size-1 mesh everything divides); it is exercised
    # end-to-end by the jamba/mamba2 long_500k dry-run cells (66/66 log).


def test_shape_bytes():
    assert shape_bytes("f32[2,3]") == 24
    assert shape_bytes("(f32[2,3]{1,0}, bf16[4])") == 24 + 8
    assert shape_bytes("pred[10]") == 10
    assert shape_bytes("token[]") == 0


def test_collective_stats_parsing():
    hlo = """
ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %ar = f32[8]{0} all-reduce(%a), replica_groups={}
  %ag = f32[16]{0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[8]{0} slice(%ag), slice={[0:8]}
}
"""
    st = collective_stats(hlo)
    assert st.bytes_by_op["all-reduce"] == 32
    assert st.bytes_by_op["all-gather"] == 64
    assert st.total_count == 2


def test_hlo_cost_trip_weighting():
    """The analyzer weights while bodies by known_trip_count (the bug in
    compiled.cost_analysis() it exists to fix).  Run hermetically in a
    subprocess: suite-global jax config (x64 from other modules) changes
    the compiled module shape."""
    import pathlib
    import subprocess
    import sys

    script = (
        "import jax, jax.numpy as jnp\n"
        "from repro.runtime.hlo_cost import analyze\n"
        "w = jnp.zeros((32, 32))\n"
        "def f(x):\n"
        "    def body(c, _):\n"
        "        return c @ w, None\n"
        "    return jax.lax.scan(body, x, None, length=7)[0]\n"
        "lowered = jax.jit(f).lower(jnp.zeros((32, 32)))\n"
        "txt = lowered.compile().as_text()\n"
        "c = analyze(txt)\n"
        "assert c.dot_flops == 7 * 2 * 32**3, c.dot_flops\n"
        "raw = lowered.compile().cost_analysis()\n"
        "if isinstance(raw, (list, tuple)):\n"
        "    raw = raw[0]  # jax < 0.5 wraps the dict in a list\n"
        "assert raw['flops'] < 2 * 2 * 32**3, raw['flops']  # ~1 iter, not 7\n"
        "print('TRIP-OK')\n"
    )
    import os

    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "TRIP-OK" in proc.stdout


def test_hlo_cost_no_loops_matches_xla():
    x = jnp.zeros((64, 64), jnp.float32)
    txt = jax.jit(lambda a: a @ a).lower(x).compile().as_text()
    c = analyze(txt)
    assert c.dot_flops == 2 * 64**3
