"""Deterministic simulation tests of the pure continuous-batching scheduler.

Everything here runs device-free: ``repro.launch.scheduler`` imports no jax,
and these tests import none either — the module IS importable and testable
on a machine with no accelerator and no jax install.  The contracts pinned
(docs/serving.md):

  * bucket coalescing picks the smallest admissible bucket;
  * no request starves beyond the bounded wait (``max_wait``);
  * slots recycle on EOS and on ``max_new``;
  * a prefill never preempts a decode batch mid-step (one action per step);
  * seeded end-to-end replay is bit-identical (same seed => same trace).
"""
import dataclasses
import sys

import pytest

from repro.launch.scheduler import (
    Request,
    SchedulerConfig,
    SchedulerState,
    audit,
    new_state,
    poisson_trace,
    sim_token,
    simulate,
    step,
)

CFG = SchedulerConfig(buckets=(16, 32, 64), max_slots=4, max_prefill=2,
                      max_wait=6)


def drain(state, events=()):
    """step() once with events, return (state, actions)."""
    return step(state, list(events))


def test_module_is_jax_free():
    # The whole point of the pure core: simulation tests need no device.
    mod = sys.modules["repro.launch.scheduler"]
    src = open(mod.__file__).read()
    assert "import jax" not in src
    assert "jax" not in {m.split(".")[0] for m in sys.modules
                         if sys.modules[m] is mod}


# ---------------------------------------------------------------------------
# Bucket policy
# ---------------------------------------------------------------------------


def test_bucket_for_picks_smallest_admissible():
    cfg = SchedulerConfig(buckets=(16, 32, 64))
    assert cfg.bucket_for(1) == 16
    assert cfg.bucket_for(16) == 16
    assert cfg.bucket_for(17) == 32
    assert cfg.bucket_for(64) == 64
    assert cfg.bucket_for(65) is None


def test_overlong_prompt_rejected_not_queued():
    s = new_state(CFG)
    s, acts = drain(s, [("arrive", Request(0, prompt_len=999, max_new=4))])
    assert ("reject", 0, "prompt_too_long") in acts
    assert audit(s)[0] == "rejected"


def test_prefill_pads_to_smallest_bucket_of_group():
    s = new_state(CFG)
    reqs = [Request(0, 17, 4), Request(1, 30, 4)]
    s, acts = drain(s, [("arrive", r) for r in reqs])
    pre = [a for a in acts if a[0] == "prefill"]
    assert pre == [("prefill", 32, (0, 1))]


def test_mixed_buckets_are_not_coalesced_together():
    # 4-token and 30-token prompts must go to separate prefill launches
    # (bucket 16 vs bucket 32) — padding the short one to 32 would waste
    # compute AND hit an unplanned shape.
    s = new_state(CFG)
    s, acts = drain(s, [("arrive", Request(0, 4, 8)),
                        ("arrive", Request(1, 30, 8))])
    pre = [a for a in acts if a[0] == "prefill"]
    assert len(pre) == 1 and len(pre[0][2]) == 1
    # The other bucket's singleton group coalesce-waits (decode is now
    # busy) but must launch within the starvation bound — as its own
    # prefill, never merged into the first bucket's shape.
    pre2 = []
    for _ in range(CFG.max_wait + 2):
        s, acts = drain(s)
        pre2 = [a for a in acts if a[0] == "prefill"]
        if pre2:
            break
    assert len(pre2) == 1
    assert {pre[0][1], pre2[0][1]} == {16, 32}


def test_invalid_config_rejected():
    with pytest.raises(ValueError):
        SchedulerConfig(buckets=(32, 16))
    with pytest.raises(ValueError):
        SchedulerConfig(buckets=())
    with pytest.raises(ValueError):
        SchedulerConfig(max_slots=0)


# ---------------------------------------------------------------------------
# Coalescing vs starvation
# ---------------------------------------------------------------------------


def test_waits_to_coalesce_while_decode_busy():
    # One request decoding, one queued: group of 1 < min(max_prefill, free)
    # and the engine is busy, so the scheduler holds the prefill to coalesce.
    s = new_state(CFG)
    s, _ = drain(s, [("arrive", Request(0, 4, 8))])   # prefill fires (idle)
    s, _ = drain(s)                                   # admit -> decoding
    s, acts = drain(s, [("arrive", Request(1, 4, 8))])
    assert [a[0] for a in acts] == ["decode"]
    assert audit(s)[1] == "queued"


def test_bounded_starvation_wait():
    # A lone queued request must be scheduled within max_wait steps even
    # though its group never fills, and even while decode stays busy.
    cfg = dataclasses.replace(CFG, max_wait=3)
    s = new_state(cfg)
    s, _ = drain(s, [("arrive", Request(0, 4, 50))])
    s, _ = drain(s)
    arrive_t = s.step_idx
    s, acts = drain(s, [("arrive", Request(1, 4, 50))])
    waited = 0
    while not any(a[0] == "prefill" and 1 in a[2] for a in acts):
        s, acts = drain(s)
        waited = s.step_idx - arrive_t
        assert waited <= cfg.max_wait + 1, "request starved past max_wait"
    assert waited >= cfg.max_wait - 1  # it did coalesce-wait, then gave up


def test_idle_engine_prefills_immediately():
    # Nothing decoding: waiting to coalesce would only add latency.
    s = new_state(CFG)
    s, acts = drain(s, [("arrive", Request(0, 4, 8))])
    assert any(a[0] == "prefill" for a in acts)


# ---------------------------------------------------------------------------
# Slot recycling
# ---------------------------------------------------------------------------


def _admit_n(s, n, max_new=50, start_rid=0):
    """Drive n requests into decode slots; returns state."""
    events = [("arrive", Request(start_rid + k, 4, max_new))
              for k in range(n)]
    s, _ = drain(s, events)
    for _ in range(n + s.cfg.max_wait + 2):
        if sum(x is not None for x in s.slots) == n:
            break
        s, _ = drain(s)
    return s


def test_slot_recycles_on_eos():
    s = _admit_n(new_state(CFG), 2)
    occupied = {x.rid for x in s.slots if x is not None}
    assert occupied == {0, 1}
    s, acts = drain(s, [("eos", 0)])
    assert ("finish", 0, "eos") in acts
    assert audit(s)[0] == "finished"
    # The freed slot is immediately reusable: a new arrival + forced
    # schedule lands in a slot while rid 1 keeps decoding.
    s, _ = drain(s, [("arrive", Request(7, 4, 50))])
    for _ in range(CFG.max_wait + 2):
        s, _ = drain(s)
        if any(x is not None and x.rid == 7 for x in s.slots):
            break
    assert {x.rid for x in s.slots if x is not None} == {1, 7}


def test_slot_recycles_on_max_new():
    s = new_state(CFG)
    s, _ = drain(s, [("arrive", Request(0, 4, 2))])  # prefill = token 1
    s, acts = drain(s)  # admit; freshly admitted slot decodes same step
    assert ("admit", 0, 0) in acts
    assert ("decode", (0,)) in acts                  # token 2 == max_new
    assert ("finish", 0, "max_new") in acts
    assert all(x is None for x in s.slots)


def test_max_new_one_finishes_at_admission():
    s = new_state(CFG)
    s, _ = drain(s, [("arrive", Request(0, 4, 1))])
    s, acts = drain(s)
    assert ("admit", 0, 0) in acts
    assert ("finish", 0, "max_new") in acts
    assert all(x is None for x in s.slots)


def test_stale_eos_after_max_new_is_ignored():
    s = new_state(CFG)
    s, _ = drain(s, [("arrive", Request(0, 4, 2))])
    s, _ = drain(s)
    s, _ = drain(s)  # max_new finish
    s, acts = drain(s, [("eos", 0)])  # late EOS for a finished request
    assert not any(a[0] == "finish" for a in acts)
    assert audit(s)[0] == "finished"


# ---------------------------------------------------------------------------
# Prefill/decode separation
# ---------------------------------------------------------------------------


def test_one_launch_per_step_prefill_xor_decode():
    # Under sustained load, every step emits at most one prefill OR one
    # decode — never both (a prefill can't preempt a decode mid-step).
    cfg = SchedulerConfig(buckets=(16,), max_slots=2, max_prefill=1,
                          max_wait=0)
    reqs = [Request(i, 4, 6, arrival=i // 2) for i in range(10)]
    res = simulate(cfg, reqs, seed=3)
    by_step = {}
    for t, a in res.trace:
        if a[0] in ("prefill", "decode"):
            by_step.setdefault(t, []).append(a[0])
    assert by_step, "no launches recorded"
    for t, kinds in by_step.items():
        assert len(kinds) == 1, f"step {t} launched {kinds}"


def test_admission_joins_inflight_decode_batch():
    # Request 1 arrives while 0 is mid-decode and must join 0's batch
    # (continuous batching) rather than wait for 0 to drain.
    cfg = dataclasses.replace(CFG, max_wait=1)
    s = _admit_n(new_state(cfg), 1)
    s, _ = drain(s, [("arrive", Request(1, 4, 50))])
    seen_joint = False
    for _ in range(6):
        s, acts = drain(s)
        if ("decode", (0, 1)) in acts or ("decode", (1, 0)) in acts:
            seen_joint = True
            break
    assert seen_joint, "new request never joined the in-flight decode batch"
    assert audit(s)[0] == "decoding" and audit(s)[1] == "decoding"


# ---------------------------------------------------------------------------
# Seeded end-to-end replay
# ---------------------------------------------------------------------------


def test_poisson_trace_deterministic():
    a = poisson_trace(seed=11, rate=0.5, n=20)
    b = poisson_trace(seed=11, rate=0.5, n=20)
    assert a == b
    c = poisson_trace(seed=12, rate=0.5, n=20)
    assert a != c
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))


def test_seeded_replay_bit_identical():
    cfg = SchedulerConfig(buckets=(16, 32, 64), max_slots=4, max_prefill=2,
                          max_wait=4)
    reqs = poisson_trace(seed=42, rate=0.7, n=30, prompt_lens=(2, 60),
                         max_new=(1, 10))
    r1 = simulate(cfg, reqs, seed=42)
    r2 = simulate(cfg, reqs, seed=42)
    assert r1.trace == r2.trace          # the replay artifact, bit-for-bit
    assert r1.tokens == r2.tokens
    assert r1.metrics == r2.metrics
    assert r1.queue_depth == r2.queue_depth
    # And a different seed genuinely perturbs the run (gen lengths change).
    r3 = simulate(cfg, reqs, seed=43)
    assert r1.trace != r3.trace


def test_simulation_completes_all_requests():
    reqs = poisson_trace(seed=5, rate=1.5, n=40, prompt_lens=(1, 64),
                         max_new=(1, 12))
    res = simulate(CFG, reqs, seed=5)
    assert len(res.metrics) == 40
    for rid, m in res.metrics.items():
        assert "finish_step" in m, f"rid {rid} never finished"
        assert m["reason"] in ("eos", "max_new")
        # TTFT ordering: arrive <= first token <= finish.
        assert m["arrival_step"] <= m["first_token_step"] <= m["finish_step"]
        assert len(res.tokens[rid]) >= 1


def test_sim_tokens_depend_only_on_rid_and_index():
    # Same requests, radically different co-batching (slots=1 vs slots=4):
    # every request's token sequence must be identical.  This is the pure-
    # layer version of the batch-independence property test_properties.py
    # checks against the real model.
    reqs = poisson_trace(seed=9, rate=1.0, n=16, max_new=(1, 8))
    solo = simulate(dataclasses.replace(CFG, max_slots=1, max_prefill=1),
                    reqs, seed=9)
    packed = simulate(CFG, reqs, seed=9)
    assert solo.tokens == packed.tokens
    assert sim_token(3, 0) == sim_token(3, 0) != sim_token(4, 0)
