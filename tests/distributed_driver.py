"""Multi-device driver for distributed Kron-Matmul tests.

Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(set by tests/test_distributed.py) so the parent pytest process keeps its
single-device view.  Prints 'OK <name>' per passing check; exits nonzero on
failure.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import kron as K  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    comm_elems_per_device,
    kron_matmul_distributed,
    plan_rounds,
    sharded_input,
)


from repro.runtime.hlo_analysis import collective_bytes as _hlo_bytes  # noqa: E402


def collective_bytes(fn, *args) -> int:
    """Sum collective payload bytes in the compiled HLO."""
    return _hlo_bytes(jax.jit(fn).lower(*args).compile().as_text())


def main() -> None:
    devs = jax.devices()
    assert len(devs) == 8, f"expected 8 devices, got {len(devs)}"
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    # --- correctness: batched relocation == naive oracle -------------------
    cases = [
        (8, (2, 2, 2, 2), (2, 2, 2, 2)),   # P=Q=2, K=16, K_loc=4
        (4, (4, 4, 4), (4, 4, 4)),         # P=Q=4, K=64, K_loc=16
        (8, (2, 4, 2), (4, 2, 4)),         # rectangular mix
        (2, (8, 8), (8, 8)),
    ]
    import math

    for m, ps, qs in cases:
        key = jax.random.PRNGKey(hash((m, ps)) % 2**31)
        keys = jax.random.split(key, len(ps) + 1)
        x = jax.random.normal(keys[0], (m, math.prod(ps)), jnp.float32)
        factors = [
            jax.random.normal(k_, (p, q), jnp.float32)
            for k_, p, q in zip(keys[1:], ps, qs)
        ]
        want = K.kron_matmul_naive(x, factors)
        xs = sharded_input(x, mesh)
        got = kron_matmul_distributed(xs, factors, mesh)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
        got_pi = kron_matmul_distributed(xs, factors, mesh, per_iteration=True)
        np.testing.assert_allclose(np.asarray(got_pi), want, rtol=1e-4, atol=1e-4)
        print(f"OK correctness m={m} ps={ps} qs={qs}")

    # --- output sharding preserved -----------------------------------------
    xs = sharded_input(jnp.ones((8, 16)), mesh)
    y = kron_matmul_distributed(xs, [jnp.eye(2)] * 4, mesh)
    assert y.sharding.spec == P("data", "model"), y.sharding
    print("OK sharding")

    # --- round planning matches paper formula ------------------------------
    # K_loc=16, P=2: N_local = log_2 16 = 4 (all four factors in one round)
    assert plan_rounds(16, [2, 2, 2, 2], [2, 2, 2, 2], 4) == [4]
    # K_loc=4, P=2: rounds of 2
    assert plan_rounds(4, [2, 2, 2, 2], [2, 2, 2, 2], 4) == [2, 2]
    # G_K | Q^L constraint: Q=2, G_K=4 forces L>=2 even though P|K_loc at L=1
    assert plan_rounds(16, [2, 2], [2, 2], 4) == [2]
    print("OK round-planning")

    # --- comm volume: batched strictly less than per-iteration -------------
    # P=Q=4, K=256, G_K=4 -> K_loc=64: FastKron rounds [3,1] (N_local=log_4 64
    # =3) vs per-iteration [1,1,1,1]: 2 relocations vs 4.
    m, ps, qs = 8, (4, 4, 4, 4), (4, 4, 4, 4)
    x = jnp.ones((m, 256))
    factors = [jnp.eye(4) for _ in ps]
    xs = sharded_input(x, mesh)

    def run_batched(x_, fs):
        return kron_matmul_distributed(x_, fs, mesh)

    def run_periter(x_, fs):
        return kron_matmul_distributed(x_, fs, mesh, per_iteration=True)

    cb = collective_bytes(run_batched, xs, factors)
    cp = collective_bytes(run_periter, xs, factors)
    assert 0 < cb < cp, f"batched={cb} periter={cp}"
    # Analytic: per device per round sends M_loc*C*(G_K-1)/G_K elems.
    m_loc, k_loc = m // 2, 256 // 4
    analytic_batched = comm_elems_per_device(
        m_loc, k_loc, list(reversed(ps)), list(reversed(qs)), 4
    )
    analytic_periter = comm_elems_per_device(
        m_loc, k_loc, list(reversed(ps)), list(reversed(qs)), 4,
        rounds=plan_rounds(k_loc, list(reversed(ps)), list(reversed(qs)), 4, minimal=True),
    )
    assert analytic_batched < analytic_periter
    print(f"OK comm-volume batched={cb}B periter={cp}B "
          f"(analytic elems/dev {analytic_batched} vs {analytic_periter})")

    # --- G_M axis is communication-free (rows embarrassingly parallel) ------
    mesh_dp = jax.make_mesh((8, 1), ("data", "model"))
    xs_dp = sharded_input(jnp.ones((8, 256)), mesh_dp)
    cb_dp = collective_bytes(lambda x_, fs: kron_matmul_distributed(x_, fs, mesh_dp),
                             xs_dp, factors)
    assert cb_dp == 0, f"expected no comm for G_K=1, got {cb_dp}"
    print("OK no-comm-on-data-axis")

    print("ALL-OK")


if __name__ == "__main__":
    main()
