"""Correctness of the core Kron-Matmul algorithms vs the naive oracle."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kron as K
from repro.core import fastkron, autotune
from repro.core.kron import KronProblem

jax.config.update("jax_enable_x64", True)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape).astype(dtype)


def make_problem(seed, m, ps, qs, dtype=jnp.float32):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ps) + 1)
    x = _rand(keys[0], (m, math.prod(ps)), dtype)
    factors = [_rand(k, (p, q), dtype) for k, p, q in zip(keys[1:], ps, qs)]
    return x, factors


UNIFORM_CASES = [
    (2, (2, 2), (2, 2)),
    (4, (2, 2, 2), (2, 2, 2)),
    (3, (4, 4, 4), (4, 4, 4)),
    (8, (8, 8), (8, 8)),
    (1, (16, 16), (16, 16)),
    (5, (3, 3, 3), (3, 3, 3)),
]
RECT_CASES = [
    (4, (4, 2), (2, 4)),          # rectangular factors
    (2, (8, 2, 4), (2, 8, 4)),    # mixed shapes
    (3, (5, 3), (2, 7)),          # odd sizes
    (6, (52,), (50,)),            # single factor, paper Table 4 row 6 shape
    (1, (2, 3, 5), (5, 3, 2)),
]


@pytest.mark.parametrize("m,ps,qs", UNIFORM_CASES + RECT_CASES)
def test_shuffle_matches_oracle(m, ps, qs):
    x, factors = make_problem(0, m, ps, qs)
    want = K.kron_matmul_naive(x, factors)
    got = K.kron_matmul_shuffle(x, factors)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,ps,qs", UNIFORM_CASES + RECT_CASES)
def test_ftmmt_matches_oracle(m, ps, qs):
    x, factors = make_problem(1, m, ps, qs)
    want = K.kron_matmul_naive(x, factors)
    got = K.kron_matmul_ftmmt(x, factors)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,ps,qs", UNIFORM_CASES + RECT_CASES)
def test_fastkron_alg_matches_oracle(m, ps, qs):
    x, factors = make_problem(2, m, ps, qs)
    want = K.kron_matmul_naive(x, factors)
    got = K.kron_matmul_fastkron(x, factors)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,ps,qs", UNIFORM_CASES + RECT_CASES)
def test_public_api_matches_oracle(m, ps, qs):
    x, factors = make_problem(3, m, ps, qs)
    want = K.kron_matmul_naive(x, factors)
    got = fastkron.kron_matmul(x, factors)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    got_unfused = fastkron.kron_matmul_unfused(x, factors)
    np.testing.assert_allclose(got_unfused, want, rtol=1e-5, atol=1e-5)


def test_public_api_batched_leading_dims():
    x, factors = make_problem(4, 6, (4, 4), (4, 4))
    x3 = x.reshape(2, 3, 16)
    got = fastkron.kron_matmul(x3, factors)
    want = fastkron.kron_matmul(x, factors).reshape(2, 3, 16)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_pair_factors_preserves_product():
    x, factors = make_problem(5, 4, (4, 4, 4, 4), (4, 4, 4, 4))
    paired = K.pair_factors(factors, max_p=16)
    assert len(paired) == 2
    want = K.kron_matmul_naive(x, factors)
    got = K.kron_matmul_fastkron(x, paired)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gradients_match_dense_oracle():
    """grad through kron_matmul == grad through materialized dense matmul."""
    x, factors = make_problem(7, 4, (4, 2, 3), (3, 2, 4))
    factors = tuple(factors)

    def loss_kron(x, factors):
        y = fastkron.kron_matmul(x, factors)
        return jnp.sum(y * jnp.sin(y))

    def loss_dense(x, factors):
        y = x @ K.kron_matrix(factors)
        return jnp.sum(y * jnp.sin(y))

    gx1, gf1 = jax.grad(loss_kron, argnums=(0, 1))(x, factors)
    gx2, gf2 = jax.grad(loss_dense, argnums=(0, 1))(x, factors)
    np.testing.assert_allclose(gx1, gx2, rtol=1e-4, atol=1e-5)
    for a, b in zip(gf1, gf2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_problem_flops_formula():
    prob = KronProblem.uniform(m=16, p=8, q=8, n=3)
    # uniform P==Q: each of 3 iterations is 2*M*K*Q FLOPs with K=P^3
    assert prob.flops == 3 * 2 * 16 * 8**3 * 8
    assert prob.k == 8**3 and prob.k_out == 8**3


def test_intermediate_elems_monotone_growth():
    prob = KronProblem(4, (2, 2), (8, 8))
    # K grows 2->...  max intermediate is final 64*... check consistency
    assert prob.intermediate_elems == max(4 * 0 + 2 * 2, (2 * 2 // 2) * 8 * 8 // 8 * 8) or True
    # exact: start K=4; iter1: (4//2)*8=16; iter2: (16//2)*8=64
    assert prob.intermediate_elems == 64


def test_plan_describe_and_stages_cover_all_factors():
    prob = KronProblem.uniform(m=16, p=8, q=8, n=5)
    plan = autotune.make_plan(prob)
    covered = sorted(i for st in plan.stages for i in st.factor_ids)
    assert covered == list(range(5))
    assert isinstance(plan.describe(), str)


def test_plan_no_prekron_when_disabled():
    prob = KronProblem.uniform(m=16, p=8, q=8, n=4)
    plan = autotune.make_plan(prob, enable_prekron=False)
    assert not any(st.prekron for st in plan.stages)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64, jnp.bfloat16])
def test_dtypes(dtype):
    x, factors = make_problem(8, 4, (8, 8), (8, 8), dtype)
    got = fastkron.kron_matmul(x, factors)
    want = K.kron_matmul_naive(
        x.astype(jnp.float64), [f.astype(jnp.float64) for f in factors]
    )
    # bf16 rounds the intermediate between the two sliced multiplies -> two
    # quantization stages; 2^-8 relative per stage over a 64-term contraction.
    tol = dict(rtol=1e-1, atol=1e-1) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got, np.float64), want, **tol)
    assert got.dtype == dtype
