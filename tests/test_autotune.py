"""Autotuner (C5): analytic model sanity + measured ranking."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.autotune import (
    TileConfig,
    candidate_tiles,
    make_plan,
    measure_best,
    predict_seconds,
    tune_sliced,
    vmem_elems,
)
from repro.core.kron import KronProblem


def test_candidates_respect_vmem():
    cands = candidate_tiles(m=1024, s=4096, p=64, q=64)
    assert cands
    for c in cands:
        assert vmem_elems(c, 64) * 4 <= 16 * 1024 * 1024 * 3 // 4


def test_predict_prefers_deeper_contraction():
    """The model must know the MXU: P=128 beats P=8 at equal FLOPs/byte."""
    cfg = TileConfig(8, 64, 8)
    t_small = predict_seconds(1024, 512, 8, 8, cfg)
    t_big = predict_seconds(1024, 32, 128, 128, TileConfig(8, 32, 128))
    # big-P case has 16x the FLOPs but >=16x the MXU utilization
    assert t_big < t_small * 32


def test_tune_sliced_returns_dividing_tiles():
    for (m, s, p, q) in [(1024, 512, 8, 8), (16, 64, 64, 64), (7, 9, 3, 5)]:
        c = tune_sliced(m, s, p, q)
        assert m % c.t_m == 0 and s % c.t_s == 0 and q % c.t_q == 0


def test_plan_fusion_groups_small_p():
    # P=4, N=6: fusion should chain multiple factors per stage
    plan = make_plan(KronProblem.uniform(64, 4, 4, 6), enable_prekron=False)
    assert any(len(st.factor_ids) > 1 for st in plan.stages)


def test_plan_no_fusion_when_disabled():
    plan = make_plan(
        KronProblem.uniform(64, 4, 4, 6),
        enable_prekron=False,
        enable_fusion=False,
    )
    assert all(len(st.factor_ids) == 1 for st in plan.stages)


def test_measure_best_ranks_by_wallclock():
    """measure_best picks the candidate whose closure is actually fastest."""
    x = jnp.zeros((256, 256))

    def fn_of_cfg(cfg):
        if cfg.t_m == 1:  # deliberately slow candidate
            return lambda: sum(x @ x for _ in range(8)) / 8
        return lambda: x @ x

    best, dt = measure_best(
        fn_of_cfg, [TileConfig(1, 1, 1), TileConfig(8, 8, 8)], warmup=1, iters=2
    )
    assert best.t_m == 8 and dt > 0
