"""Autotuner (C5): analytic model sanity + measured ranking + plan cache."""
import math
import os

import jax
import jax.numpy as jnp
import pytest

from repro.core.autotune import (
    TileConfig,
    candidate_tiles,
    load_plan_cache,
    make_plan,
    measure_best,
    plan_cache_key,
    plan_from_json,
    plan_to_json,
    predict_seconds,
    tune_sliced,
    vmem_elems,
)
from repro.core.kron import KronProblem
from repro.kernels.kron_fused import fused_growth


def test_candidates_respect_vmem():
    cands = candidate_tiles(m=1024, s=4096, p=64, q=64)
    assert cands
    for c in cands:
        assert vmem_elems(c, 64) * 4 <= 16 * 1024 * 1024 * 3 // 4


def test_predict_prefers_deeper_contraction():
    """The model must know the MXU: P=128 beats P=8 at equal FLOPs/byte."""
    cfg = TileConfig(8, 64, 8)
    t_small = predict_seconds(1024, 512, 8, 8, cfg)
    t_big = predict_seconds(1024, 32, 128, 128, TileConfig(8, 32, 128))
    # big-P case has 16x the FLOPs but >=16x the MXU utilization
    assert t_big < t_small * 32


def test_tune_sliced_returns_dividing_tiles():
    for (m, s, p, q) in [(1024, 512, 8, 8), (16, 64, 64, 64), (7, 9, 3, 5)]:
        c = tune_sliced(m, s, p, q)
        assert m % c.t_m == 0 and s % c.t_s == 0 and q % c.t_q == 0


def test_plan_fusion_groups_small_p():
    # P=4, N=6: fusion should chain multiple factors per stage
    plan = make_plan(KronProblem.uniform(64, 4, 4, 6), enable_prekron=False)
    assert any(len(st.factor_ids) > 1 for st in plan.stages)


def test_plan_no_fusion_when_disabled():
    plan = make_plan(
        KronProblem.uniform(64, 4, 4, 6),
        enable_prekron=False,
        enable_fusion=False,
    )
    assert all(len(st.factor_ids) == 1 for st in plan.stages)


def test_plan_stages_respect_vmem_budget():
    """Every fused stage's (t_m, T_K, growth) must fit the kernel's VMEM
    budget — including expanding chains where Q-tiling provides the relief."""
    budget = 2 * 1024 * 1024
    for prob in [
        KronProblem.uniform(64, 4, 4, 6),
        KronProblem.uniform(256, 16, 16, 4),
        KronProblem(64, (2, 2, 2, 2, 2), (8, 8, 8, 8, 8)),    # growth, untiled
        KronProblem(64, (2, 2, 2, 2, 2), (32, 32, 32, 32, 32)),  # Q-tiled
        KronProblem(32, (4, 2, 8), (8, 4, 2)),
    ]:
        plan = make_plan(prob, enable_prekron=False, vmem_budget_elems=budget)
        ps = list(reversed(prob.ps))
        qs = list(reversed(prob.qs))
        for st in plan.stages:
            if len(st.factor_ids) <= 1:
                continue
            sps = [ps[i] for i in st.factor_ids]
            sqs = [qs[i] for i in st.factor_ids]
            t_k = st.tiles.t_s * math.prod(sps)
            growth = fused_growth(sps, sqs, st.t_qs)
            assert st.tiles.t_m * t_k * growth <= budget, (
                prob, st, t_k, growth
            )


def test_plan_q_tiling_extends_fusion_on_expanding_chains():
    """Expanding chains (Q >> P) fuse further than the untiled budget allows
    because the plan Q-tiles the growing factors."""
    prob = KronProblem(64, (2, 2, 2, 2, 2), (32, 32, 32, 32, 32))
    plan = make_plan(prob, enable_prekron=False)
    assert any(
        len(st.factor_ids) > 1 and st.t_qs is not None for st in plan.stages
    ), plan.describe()


def test_plan_has_mirrored_bwd_stages():
    prob = KronProblem(16, (4, 2, 3), (3, 2, 4))
    plan = make_plan(prob, enable_prekron=False)
    assert plan.bwd_stages is not None
    fwd_ids = [st.factor_ids for st in plan.stages]
    bwd_ids = [st.factor_ids for st in plan.bwd_stages]
    assert bwd_ids == list(reversed(fwd_ids))


def test_plan_json_roundtrip():
    prob = KronProblem(64, (2, 2, 2, 2, 2), (8, 8, 8, 8, 8))
    plan = make_plan(prob, enable_prekron=False)
    assert plan_from_json(plan_to_json(plan)) == plan


def test_measured_plan_cache_hit_skips_measurement(tmp_path):
    """tune="measure" persists the winner; the second call must not measure
    (we poison measure_best to prove the cache path is taken)."""
    import repro.core.autotune as at

    cache = str(tmp_path / "plans.json")
    prob = KronProblem(8, (4, 4), (4, 4))
    plan1 = make_plan(prob, tune="measure", backend="xla", cache_path=cache)
    assert os.path.exists(cache)
    key = plan_cache_key(prob, 4, "xla")
    entries = load_plan_cache(cache)
    assert key in entries and entries[key]["seconds"] > 0

    orig = at.measure_best
    at.measure_best = lambda *a, **k: (_ for _ in ()).throw(
        AssertionError("measure_best called on cache hit")
    )
    try:
        plan2 = make_plan(prob, tune="measure", backend="xla", cache_path=cache)
    finally:
        at.measure_best = orig
    assert plan2 == plan1


@pytest.mark.parametrize(
    "garbage",
    [
        "not json at all {{{",
        '{"version": 1, "entries"',          # truncated mid-write
        '{"version": 99, "entries": {}}',    # wrong schema version
        '[1, 2, 3]',                         # valid JSON, wrong shape
        '{"version": 1, "entries": [1]}',    # entries not a dict
        '{"version": 1, "entries": {"k": {"seconds": 1}}}',  # entry sans plan
        "",                                  # empty file
    ],
)
def test_plan_cache_recovers_from_corrupt_file(tmp_path, garbage):
    """A corrupt/truncated cache (e.g. a concurrent writer died) degrades to
    an empty cache on load, and the next measured plan rewrites it whole."""
    cache = tmp_path / "plans.json"
    cache.write_text(garbage)
    assert load_plan_cache(str(cache)) == {}
    prob = KronProblem(8, (4, 4), (4, 4))
    plan = make_plan(prob, tune="measure", backend="xla", cache_path=str(cache))
    assert plan.stages
    entries = load_plan_cache(str(cache))
    key = plan_cache_key(prob, 4, "xla")
    assert key in entries  # cache healthy again


def test_plan_cache_save_merges_concurrent_entries(tmp_path):
    """Two writers that loaded the same snapshot don't clobber each other:
    save merges the on-disk entries written in between."""
    from repro.core.autotune import save_plan_cache

    cache = str(tmp_path / "plans.json")
    save_plan_cache(cache, {"a": {"plan": {"stages": []}, "seconds": 1}})
    # second writer, unaware of 'a', saves only 'b'
    save_plan_cache(cache, {"b": {"plan": {"stages": []}, "seconds": 2}})
    entries = load_plan_cache(cache)
    assert set(entries) == {"a", "b"}


def test_measured_plan_records_candidate_set(tmp_path):
    """The unified measured path (single AND batched through one
    _measured_plan) records the candidate set it ranked in the cache entry —
    with the batched sweep widened over t_b divisors."""
    from repro.core.autotune import make_batched_plan

    cache = str(tmp_path / "plans.json")
    prob = KronProblem(8, (4, 4), (4, 4))
    make_plan(prob, tune="measure", backend="xla", cache_path=cache)
    make_batched_plan(
        prob, 8, shared_factors=False, tune="measure", backend="xla",
        cache_path=cache,
    )
    entries = load_plan_cache(cache)
    single_key = plan_cache_key(prob, 4, "xla")
    batched_key = plan_cache_key(
        prob, 4, "xla", enable_prekron=False, batch=8, shared_factors=False
    )
    assert set(entries) == {single_key, batched_key}
    for key in entries:
        assert len(entries[key]["candidates"]) >= 2, entries[key]
    # widened t_b sweep: batched candidates cover multiple batch tiles
    tbs = {
        c.split("t_b=")[1].split("]")[0]
        for c in entries[batched_key]["candidates"]
        if "t_b=" in c
    }
    assert len(tbs) > 1, entries[batched_key]["candidates"]


def test_measure_best_ranks_by_wallclock():
    """measure_best picks the candidate whose closure is actually fastest."""
    x = jnp.zeros((256, 256))

    def fn_of_cfg(cfg):
        if cfg.t_m == 1:  # deliberately slow candidate
            return lambda: sum(x @ x for _ in range(8)) / 8
        return lambda: x @ x

    best, dt = measure_best(
        fn_of_cfg, [TileConfig(1, 1, 1), TileConfig(8, 8, 8)], warmup=1, iters=2
    )
    assert best.t_m == 8 and dt > 0
