"""Multi-device driver for the slab-pipelined distributed rounds (PR 10).

Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(set by tests/test_distributed.py) so the parent pytest process keeps its
single-device view.  Prints 'OK <name>' per passing check; exits nonzero on
failure.

Checks, per the acceptance criteria:
  * the slabbed schedule is BITWISE identical (fwd and grads) to the serial
    schedule on both mesh runners — shared factors (single spine) and
    per-sample factors (batched spine) — at n_slabs in {2, 4};
  * compiled-HLO pin: the slabbed schedule emits exactly
    ``rounds * n_slabs`` all-to-alls, the serial schedule stays at ONE per
    round, and a non-divisor request clamps to the largest row divisor;
  * comm accounting under slabbing: the per-slab telemetry gauges sum to
    the SAME ``comm_elems_per_device`` total as the serial schedule per
    round — no double count, no missing slab;
  * ``KronOp.cost()``'s overlap term (``comm_hidden_elems``) reconciles
    with the per-slab telemetry gauges through ``KronOp.profile()``;
  * the measured distributed tuner ranks slabbed vs serial candidates on
    the emitted program and persists the plan under the ``;gk=`` cache key
    (old cache entries without ``n_slabs`` still load).
"""
import json
import math
import os
import sys
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import autotune  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    comm_elems_per_device,
    comm_hidden_elems,
    plan_rounds,
    run_batched_distributed_rounds,
    run_distributed_rounds,
    sharded_input,
    sharded_input_batched,
)
from repro.core.engine import KronOp  # noqa: E402
from repro.kernels.emit import effective_slabs  # noqa: E402
from repro.runtime import telemetry  # noqa: E402
from repro.runtime.hlo_analysis import collective_stats  # noqa: E402

G_M, G_K = 2, 4


def _bitwise(a, b) -> bool:
    return bool((np.asarray(a) == np.asarray(b)).all())


def main() -> None:
    devs = jax.devices()
    assert len(devs) == 8, f"expected 8 devices, got {len(devs)}"
    mesh = jax.make_mesh((G_M, G_K), ("data", "model"))

    M, PS, QS = 16, (4, 4, 4), (4, 4, 4)
    K = math.prod(PS)
    rev_ps, rev_qs = list(reversed(PS)), list(reversed(QS))
    k_loc = K // G_K
    rounds = plan_rounds(k_loc, rev_ps, rev_qs, G_K)
    keys = jax.random.split(jax.random.PRNGKey(0), len(PS) + 2)

    # --- single spine (shared factors): bitwise fwd + grads ----------------
    x = jax.random.normal(keys[0], (M, K), jnp.float32)
    fs = tuple(
        jax.random.normal(k, (p, q), jnp.float32)
        for k, p, q in zip(keys[1:], PS, QS)
    )
    xs = sharded_input(x, mesh)

    def loss_single(x, fs, n):
        y = run_distributed_rounds(x, fs, mesh, n_slabs=n)
        return (y * jnp.cos(y)).sum()  # x-dependent cotangent

    y_ser = run_distributed_rounds(xs, fs, mesh)
    g_ser = jax.grad(loss_single, argnums=(0, 1))(xs, fs, 1)
    for n in (2, 4):
        y_n = run_distributed_rounds(xs, fs, mesh, n_slabs=n)
        assert _bitwise(y_n, y_ser), f"single fwd n_slabs={n} not bitwise"
        g_n = jax.grad(loss_single, argnums=(0, 1))(xs, fs, n)
        assert _bitwise(g_n[0], g_ser[0]), f"single dx n_slabs={n} not bitwise"
        for a, r in zip(g_n[1], g_ser[1]):
            assert _bitwise(a, r), f"single dF n_slabs={n} not bitwise"
        print(f"OK single-bitwise n_slabs={n}")

    # --- batched spine (per-sample factors): bitwise fwd + grads -----------
    B = 4
    xb = jax.random.normal(keys[0], (B, M, K), jnp.float32)
    fb = tuple(
        jax.random.normal(k, (B, p, q), jnp.float32)
        for k, p, q in zip(keys[1:], PS, QS)
    )
    xbs = sharded_input_batched(xb, mesh)

    def loss_batched(x, fs, n):
        y = run_batched_distributed_rounds(x, fs, mesh, t_b=2, n_slabs=n)
        return (y * jnp.cos(y)).sum()

    yb_ser = run_batched_distributed_rounds(xbs, fb, mesh, t_b=2)
    gb_ser = jax.grad(loss_batched, argnums=(0, 1))(xbs, fb, 1)
    for n in (2, 4):
        yb_n = run_batched_distributed_rounds(xbs, fb, mesh, t_b=2, n_slabs=n)
        assert _bitwise(yb_n, yb_ser), f"batched fwd n_slabs={n} not bitwise"
        gb_n = jax.grad(loss_batched, argnums=(0, 1))(xbs, fb, n)
        assert _bitwise(gb_n[0], gb_ser[0]), f"batched dx n_slabs={n}"
        for a, r in zip(gb_n[1], gb_ser[1]):
            assert _bitwise(a, r), f"batched dF n_slabs={n} not bitwise"
        print(f"OK batched-bitwise n_slabs={n}")

    # --- HLO pin: rounds * n_slabs all-to-alls slabbed, one per round serial
    def a2a_count(n):
        fn = jax.jit(
            lambda x, fs: run_distributed_rounds(x, fs, mesh, n_slabs=n)
        )
        st = collective_stats(fn.lower(xs, fs).compile().as_text())
        return st.count_by_op.get("all-to-all", 0), st.total_bytes

    c1, bytes_ser = a2a_count(1)
    assert c1 == len(rounds), (c1, rounds)
    for n in (2, 4):
        cn, bytes_n = a2a_count(n)
        assert cn == len(rounds) * n, (cn, len(rounds), n)
        # per-slab payloads sum to the serial total, in the HLO too
        assert bytes_n == bytes_ser, (bytes_n, bytes_ser)
    # non-divisor request clamps: m_loc = 8 rows, n=3 -> 2 slabs
    c3, _ = a2a_count(3)
    assert effective_slabs(M // G_M, 3) == 2
    assert c3 == len(rounds) * 2, c3
    print(f"OK hlo-pin serial={c1} slabbed={{2: {len(rounds) * 2}, "
          f"4: {len(rounds) * 4}}} clamp(3)->2")

    # --- comm accounting: per-slab gauges sum to the serial total ----------
    m_loc = M // G_M
    total = comm_elems_per_device(m_loc, k_loc, rev_ps, rev_qs, G_K)
    assert total == comm_elems_per_device(
        m_loc, k_loc, rev_ps, rev_qs, G_K, n_slabs=4
    ), "comm_elems_per_device must be slab-invariant"
    telemetry.configure()
    try:
        run_distributed_rounds(xs, fs, mesh, n_slabs=4)
        summary = telemetry.comm_summary()
        assert sorted(summary) == list(range(len(rounds))), summary
        observed = 0
        for k, rec in summary.items():
            assert len(rec["slabs"]) == 4, (k, rec)
            assert sum(rec["slabs"]) == rec["total"], (k, rec)
            observed += rec["total"]
        assert observed == total, (observed, total)
        hidden_pred = comm_hidden_elems(
            m_loc, k_loc, rev_ps, rev_qs, G_K, n_slabs=4
        )
        hidden_obs = sum(r["hidden"] for r in summary.values())
        assert hidden_obs == hidden_pred, (hidden_obs, hidden_pred)
        print(f"OK comm-accounting total={total} hidden={hidden_pred} "
              f"(gauges sum per slab, no double count)")
    finally:
        telemetry.disable()

    # --- KronOp: cost() overlap term reconciles through profile() ----------
    op = KronOp(PS, QS, mesh=mesh, n_slabs=2)
    y_op = op(xs, fs)
    assert _bitwise(y_op, y_ser), "KronOp slabbed fwd not bitwise vs serial"
    cost = op.cost(M)
    assert cost.n_slabs == 2 and cost.rounds == len(rounds)
    assert cost.comm_elems_per_device == total
    assert cost.comm_hidden_elems == comm_hidden_elems(
        m_loc, k_loc, rev_ps, rev_qs, G_K, n_slabs=2
    )
    assert 0 < cost.comm_hidden_elems < cost.comm_elems_per_device
    assert cost.critical_path_s > 0
    telemetry.configure()
    try:
        op(xs, fs)  # records the per-slab gauges for this schedule
        report = op.profile(x, fs, warmup=0, iters=1)
        comm = report["comm"]
        assert comm["n_slabs"] == 2 and comm["hidden_elems"] > 0
        assert comm["telemetry_hidden_elems"] == comm["hidden_elems"], comm
        print(f"OK cost-telemetry-reconcile hidden={comm['hidden_elems']}")
    finally:
        telemetry.disable()

    # auto stays serial on latency-dominated (small) problems: the default
    # schedule — and every existing HLO pin — is unchanged.
    op_auto = KronOp(PS, QS, mesh=mesh)
    assert op_auto._resolve_n_slabs(m_loc) == 1
    fn_auto = jax.jit(lambda x, fs: op_auto(x, fs))
    st = collective_stats(fn_auto.lower(xs, fs).compile().as_text())
    assert st.count_by_op.get("all-to-all", 0) == len(rounds)
    print("OK auto-serial-small")

    # --- measured tuner ranks slabbed vs serial on the emitted program -----
    with tempfile.TemporaryDirectory() as td:
        cache = os.path.join(td, "plans.json")
        prob = autotune.KronProblem(m_loc, PS, QS)
        plan = autotune.make_batched_plan(
            prob, B, shared_factors=False, tune="measure", g_k=G_K,
            cache_path=cache, mesh=mesh,
        )
        assert plan.n_slabs >= 1
        with open(cache) as fh:
            entries = json.load(fh)["entries"]
        gk_keys = [k for k in entries if k.endswith(f";gk={G_K}")]
        assert gk_keys, f"measured dist plan not cached under ;gk=: {entries}"
        # old entries (no n_slabs field) still load as serial
        d = autotune.plan_to_json(plan)
        d.pop("n_slabs")
        assert autotune.plan_from_json(d).n_slabs == 1
        # second resolve is a cache hit returning the same schedule
        plan2 = autotune.make_batched_plan(
            prob, B, shared_factors=False, tune="measure", g_k=G_K,
            cache_path=cache, mesh=mesh,
        )
        assert plan2.n_slabs == plan.n_slabs and plan2.t_b == plan.t_b
        print(f"OK measured-tuner n_slabs={plan.n_slabs} t_b={plan.t_b} "
              f"cached={gk_keys[0].split(';')[-1]}")

    print("ALL-OK")


if __name__ == "__main__":
    sys.exit(main())
