"""StageProgram IR + unified emitter (the one-kernel-template refactor).

Acceptance pins:
  * ``transpose`` is mechanical (involution on structure) and
    ``emit(transpose(prog))`` is the x-cotangent of ``emit(prog)``;
  * ``autotune.lower`` lowers any KronPlan into a program whose emission
    matches the dense oracle on BOTH backends;
  * per-stage heterogeneity works end to end: a mixed-shape ``ps=(8,16,32)``
    chain with per-stage ``acc_dtype`` flows plan -> program -> emitter ->
    VJP on xla and pallas-interpret.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.autotune import lower, make_plan
from repro.core.engine import KronOp
from repro.core.kron import KronProblem, kron_matrix
from repro.kernels import emit

jax.config.update("jax_enable_x64", True)


def _mk(seed, m, ps, qs, batch=None, dtype=jnp.float32):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ps) + 1)
    lead = () if batch is None else (batch,)
    x = jax.random.normal(keys[0], (*lead, m, math.prod(ps))).astype(dtype)
    fs = tuple(
        jax.random.normal(k, (*lead, p, q)).astype(dtype)
        for k, p, q in zip(keys[1:], ps, qs)
    )
    return x, fs


# ---------------------------------------------------------------------------
# IR structure
# ---------------------------------------------------------------------------


def test_instr_kind_direction_consistency():
    i = emit.StageInstr(kind=emit.MULTIPLY, ps=(4,), qs=(4,))
    assert i.direction == "fwd"
    t = i.transpose()
    assert t.kind == emit.TRANSPOSED_MULTIPLY and t.direction == "bwd"
    assert t.transpose().kind == emit.MULTIPLY
    pk = emit.StageInstr(kind=emit.PREKRON, ps=(2, 2), qs=(2, 2))
    assert pk.transpose().kind == emit.PREKRON
    assert pk.transpose().direction == "bwd"
    with pytest.raises(ValueError):
        emit.StageInstr(kind="frobnicate", ps=(4,), qs=(4,))
    with pytest.raises(ValueError):
        emit.StageInstr(kind=emit.MULTIPLY, ps=(4,), qs=(4, 4))


def test_transpose_swaps_tuned_bwd_tile():
    i = emit.StageInstr(
        kind=emit.MULTIPLY, ps=(4, 4), qs=(4, 4), t_m=8, t_m_bwd=2
    )
    t = i.transpose()
    assert (t.t_m, t.t_m_bwd) == (2, 8)
    assert t.transpose().t_m == 8  # involution restores the forward tile


def test_program_covers_factors_exactly_once():
    mk = lambda ids: emit.StageInstr(
        kind=emit.MULTIPLY, ps=(4,) * len(ids), qs=(4,) * len(ids),
        factor_ids=ids,
    )
    emit.StageProgram((mk((0, 1)), mk((2,))), 3)  # ok
    with pytest.raises(ValueError):
        emit.StageProgram((mk((0, 1)),), 3)  # missing factor 2
    with pytest.raises(ValueError):
        emit.StageProgram((mk((0,)), mk((0,))), 1)  # duplicate


def test_transpose_reverses_instruction_order():
    prob = KronProblem(8, (4, 2, 3), (3, 2, 4))
    plan = make_plan(prob, enable_prekron=False)
    prog = lower(plan, prob.ps, prob.qs)
    t = emit.transpose(prog)
    assert [i.factor_ids for i in t.instrs] == [
        i.factor_ids for i in reversed(prog.instrs)
    ]
    assert all(i.direction == "bwd" for i in t.instrs)


def test_lower_carries_plan_fields():
    prob = KronProblem(8, (4, 4, 4), (4, 4, 4))
    plan = make_plan(prob, enable_prekron=False)
    prog = lower(plan, prob.ps, prob.qs)
    assert prog.n_factors == 3
    assert not prog.batched
    for st, ins in zip(plan.stages, prog.instrs):
        assert ins.factor_ids == st.factor_ids
        assert ins.t_m == st.tiles.t_m
        assert ins.t_k == st.tiles.t_s * math.prod(ins.ps)
        assert ins.t_qs == st.t_qs
    bprog = lower(plan, prob.ps, prob.qs, batched=True)
    assert all(i.t_b == plan.t_b for i in bprog.instrs)


# ---------------------------------------------------------------------------
# Emission correctness + transpose-is-vjp
# ---------------------------------------------------------------------------


CHAINS = [
    (8, (4, 4), (4, 4)),
    (4, (4, 2, 3), (3, 2, 4)),
    (8, (8, 16, 32), (8, 16, 32)),     # the mixed-shape acceptance chain
    (6, (5, 3), (2, 7)),
]


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("m,ps,qs", CHAINS)
def test_emitted_program_matches_dense_oracle(backend, m, ps, qs):
    x, fs = _mk(0, m, ps, qs, dtype=jnp.float64)
    plan = make_plan(KronProblem(m, ps, qs), enable_prekron=False)
    prog = lower(plan, ps, qs)
    got = emit.emit(prog, backend=backend)(x, fs)
    np.testing.assert_allclose(
        got, x @ kron_matrix(list(fs)), rtol=1e-9, atol=1e-9
    )


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("m,ps,qs", CHAINS)
def test_transpose_program_is_vjp(backend, m, ps, qs):
    """emit(transpose(prog)) == the jax.vjp x-cotangent of emit(prog).

    The vjp reference differentiates the XLA emission (interpret-mode
    pallas_call is not linearizable under jax.vjp — the engine never
    differentiates THROUGH kernels, it runs transposed programs); the
    transposed program is then emitted on BOTH backends against it."""
    x, fs = _mk(1, m, ps, qs, dtype=jnp.float64)
    plan = make_plan(KronProblem(m, ps, qs), enable_prekron=False)
    prog = lower(plan, ps, qs)
    y, vjp = jax.vjp(lambda x: emit.emit(prog, backend="xla")(x, fs), x)
    dy = jax.random.normal(jax.random.PRNGKey(2), y.shape, jnp.float64)
    (want,) = vjp(dy)
    got = emit.emit(emit.transpose(prog), backend=backend)(dy, fs)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_batched_transpose_program_is_vjp(backend):
    b, m, ps, qs = 4, 4, (4, 8), (8, 4)
    x, fs = _mk(3, m, ps, qs, batch=b)
    plan = autotune.make_batched_plan(
        KronProblem(m, ps, qs), b, shared_factors=False
    )
    prog = lower(plan, ps, qs, batched=True)
    y, vjp = jax.vjp(lambda x: emit.emit(prog, backend="xla")(x, fs), x)
    dy = jax.random.normal(jax.random.PRNGKey(4), y.shape, jnp.float32)
    (want,) = vjp(dy)
    got = emit.emit(emit.transpose(prog), backend=backend)(dy, fs)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_prekron_program_round_trip(backend):
    m, ps, qs = 4, (2, 3, 2), (3, 2, 2)
    x, fs = _mk(5, m, ps, qs, dtype=jnp.float64)
    plan = make_plan(
        KronProblem(m, ps, qs), enable_prekron=True, prekron_max_p=4
    )
    assert any(st.prekron for st in plan.stages), plan.describe()
    prog = lower(plan, ps, qs)
    assert any(i.kind == emit.PREKRON for i in prog.instrs)
    fwd = emit.emit(prog, backend=backend)
    np.testing.assert_allclose(
        fwd(x, fs), x @ kron_matrix(list(fs)), rtol=1e-9, atol=1e-9
    )
    y, vjp = jax.vjp(lambda x: emit.emit(prog, backend="xla")(x, fs), x)
    dy = jnp.ones_like(y)
    (want,) = vjp(dy)
    got = emit.emit(emit.transpose(prog), backend=backend)(dy, fs)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# Mixed per-stage (p, q) + acc_dtype end to end (the proof scenario)
# ---------------------------------------------------------------------------


def _per_stage_acc_plan(m, ps, qs):
    """One stage per factor with a DIFFERENT acc dtype on each stage."""
    plan = make_plan(
        KronProblem(m, ps, qs), enable_prekron=False, enable_fusion=False
    )
    accs = ["float32", "float64", None]
    stages = tuple(
        dataclasses.replace(st, acc_dtype=accs[i % 3])
        for i, st in enumerate(plan.stages)
    )
    bwd = tuple(
        dataclasses.replace(st, acc_dtype=accs[(len(stages) - 1 - i) % 3])
        for i, st in enumerate(plan.bwd_stages)
    )
    return autotune.KronPlan(stages, bwd, plan.t_b)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_mixed_shape_mixed_acc_chain_end_to_end(backend):
    """ps=(8,16,32) with per-stage acc_dtype through the WHOLE stack:
    plan -> program -> emitter -> VJP, forward and full gradients."""
    m, ps, qs = 4, (8, 16, 32), (8, 16, 32)
    plan = _per_stage_acc_plan(m, ps, qs)
    prog = lower(plan, ps, qs)
    assert {i.acc_dtype for i in prog.instrs} == {"float32", "float64", None}
    x, fs = _mk(7, m, ps, qs)
    op = KronOp(ps, qs, m=m, backend=backend, plan=plan)
    got = op(x, fs)
    want = x @ kron_matrix(list(fs))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    gx, gf = jax.grad(lambda x, fs: (op(x, fs) ** 2).sum(), argnums=(0, 1))(x, fs)
    gx2, gf2 = jax.grad(
        lambda x, fs: ((x @ kron_matrix(list(fs))) ** 2).sum(), argnums=(0, 1)
    )(x, fs)
    np.testing.assert_allclose(gx, gx2, rtol=1e-2, atol=1e-2)
    for a, b in zip(gf, gf2):
        np.testing.assert_allclose(a, b, rtol=1e-2, atol=1e-2)


def test_make_plan_acc_dtype_stamps_stages_and_caches_separately():
    prob = KronProblem(8, (4, 4), (4, 4))
    plan = make_plan(prob, acc_dtype="float64", enable_prekron=False)
    assert all(st.acc_dtype == "float64" for st in plan.stages)
    assert all(st.acc_dtype == "float64" for st in plan.bwd_stages)
    # plan-cache keys must distinguish acc policies (and default stays stable)
    k_default = autotune.plan_cache_key(prob, 4, "xla")
    k_acc = autotune.plan_cache_key(prob, 4, "xla", acc_dtype="float64")
    assert k_default != k_acc and "acc=" not in k_default
    # JSON round-trip keeps the per-stage policy
    assert autotune.plan_from_json(autotune.plan_to_json(plan)) == plan


def test_mixed_shape_batched_per_sample(backend="xla"):
    """The same mixed-shape chain through the batched per-sample spine."""
    b, m, ps, qs = 2, 4, (8, 16, 32), (4, 8, 16)
    x, fs = _mk(8, m, ps, qs, batch=b)
    op = KronOp(ps, qs, batch=b, shared_factors=False, backend=backend)
    got = op(x, fs)
    want = np.stack(
        [np.asarray(x[i] @ kron_matrix([f[i] for f in fs])) for i in range(b)]
    )
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Unified executor plumbing
# ---------------------------------------------------------------------------


def test_lower_carries_single_stage_q_tile_for_huge_q():
    """Single-multiply stages keep their tuned Q-tile through lowering: a
    huge-Q factor whose full-Q growth would fail the chain template's VMEM
    check must lower to an emittable instruction (the kron_sliced kernel's
    t_q semantics, now expressed as a length-1 t_qs)."""
    m, ps, qs = 64, (2, 2), (4096, 4096)
    plan = make_plan(KronProblem(m, ps, qs), enable_fusion=False,
                     enable_prekron=False)
    prog = lower(plan, ps, qs)
    assert any(i.t_qs is not None for i in prog.instrs), prog.describe()
    for ins in prog.instrs:
        growth = emit.fused_growth(ins.ps, ins.qs, ins.t_qs)
        assert ins.t_m * ins.t_k * growth <= emit.VMEM_BUDGET_ELEMS, (
            prog.describe()
        )
    # Numeric pin of the length-1-t_qs chain template (the path lowering
    # now routes those stages through) at a size cheap enough to interpret.
    x, fs = _mk(10, 4, (4,), (64,), dtype=jnp.float64)
    instr = emit.StageInstr(
        kind=emit.MULTIPLY, ps=(4,), qs=(64,), t_m=2, t_k=8, t_qs=(16,)
    )
    got = emit.run_stage(x, tuple(reversed(fs)), instr, backend="pallas")
    np.testing.assert_allclose(
        got, x @ kron_matrix(list(fs)), rtol=1e-9, atol=1e-9
    )


def test_plan_growth_repair_keeps_fused_stages_emittable():
    """The planner's fusion grouping must never emit a stage whose minimal
    tile exceeds the VMEM budget: the first factor used to be admitted with
    full Q unchecked, blowing the early-prefix growth (review finding)."""
    for ps, qs in [((2048, 2), (2048, 2048)), ((2, 2), (2048, 2048))]:
        prob = KronProblem(8, ps, qs)
        plan = make_plan(prob, enable_prekron=False)
        prog = lower(plan, ps, qs)
        for ins in prog.instrs:
            if len(ins.ps) <= 1:
                continue
            growth = emit.fused_growth(ins.ps, ins.qs, ins.t_qs)
            assert ins.t_m * ins.t_k * growth <= emit.VMEM_BUDGET_ELEMS, (
                prog.describe()
            )


def test_unbatched_is_batch_of_one_on_pallas():
    """t_b=None and an explicit B=1 batch emit the same numbers — batch is a
    grid axis, not a code path."""
    m, ps, qs = 4, (4, 4), (4, 4)
    x, fs = _mk(9, m, ps, qs)
    instr = emit.StageInstr(kind=emit.MULTIPLY, ps=ps, qs=qs, t_m=2, t_k=16)
    single = emit.run_stage(x, tuple(reversed(fs)), instr, backend="pallas")
    batched = emit.run_stage(
        x[None], tuple(f[None] for f in reversed(fs)),
        dataclasses.replace(instr, t_b=1), backend="pallas",
    )
    np.testing.assert_array_equal(np.asarray(single), np.asarray(batched[0]))


def test_run_stage_raises_on_vmem_overflow():
    x = jnp.zeros((8, 1 << 14), jnp.float32)
    f = jnp.zeros((2, 2), jnp.float32)
    instr = emit.StageInstr(
        kind=emit.MULTIPLY, ps=(2, 2), qs=(2, 2), t_m=8, t_k=1 << 14
    )
    with pytest.raises(ValueError):
        emit.run_stage(
            x, (f, f), instr, backend="pallas", vmem_budget_elems=1024
        )


def test_run_program_validates_factor_count():
    prog = emit.StageProgram(
        (emit.StageInstr(kind=emit.MULTIPLY, ps=(4,), qs=(4,), factor_ids=(0,)),),
        1,
    )
    with pytest.raises(ValueError):
        emit.run_program(jnp.zeros((2, 4)), (jnp.zeros((4, 4)),) * 2, prog)
