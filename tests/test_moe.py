"""MoE routing unit tests vs a dense compute-all-experts oracle."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import MoEConfig, ModelConfig
from repro.models.moe import _capacity, moe_apply, moe_init


def _cfg(e=4, k=2, cf=2.0, n_shared=0):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab=32,
        moe=MoEConfig(n_experts=e, top_k=k, d_expert=8, n_shared=n_shared,
                      capacity_factor=cf),
        dtype="float32",
    )


def _oracle(cfg, p, x):
    """Dense oracle: y = sum over top-k experts of w_e * FFN_e(x)."""
    mc = cfg.moe
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, mc.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    # compute all experts densely
    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, p["ew1"])) * jnp.einsum(
        "bsd,edf->bsef", x, p["ew3"]
    )
    all_out = jnp.einsum("bsef,efd->bsed", h, p["ew2"])  # (B,S,E,D)
    mask = jax.nn.one_hot(top_i, mc.n_experts)  # (B,S,k,E)
    w = jnp.einsum("bske,bsk->bse", mask, top_p)
    return jnp.einsum("bsed,bse->bsd", all_out, w)


def test_matches_dense_oracle_no_drops():
    cfg = _cfg(cf=2.0)  # capacity == S: nothing dropped
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    got, aux = moe_apply(cfg, p, x)
    want = _oracle(cfg, p, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_drops_occur_with_tiny_capacity():
    cfg = _cfg(cf=0.1)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    got, _ = moe_apply(cfg, p, x)
    want = _oracle(cfg, p, x)
    # with cf=0.1 captured tokens differ from the oracle for at least one row
    assert not np.allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)
    assert bool(jnp.all(jnp.isfinite(got)))


def test_shared_experts_added():
    cfg = _cfg(n_shared=1)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16))
    got, _ = moe_apply(cfg, p, x)
    # shared expert contribution == plain FFN on x
    from repro.models.ffn import ffn_apply

    routed, _ = moe_apply(cfg, {**p, "shared": jax.tree.map(jnp.zeros_like, p["shared"])}, x)
    shared_only = ffn_apply(cfg, p["shared"], x)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(routed + shared_only), rtol=1e-4, atol=1e-5
    )


def test_capacity_formula():
    mc = MoEConfig(n_experts=8, top_k=2, d_expert=4, capacity_factor=1.25)
    c = _capacity(1024, mc)
    assert c % 8 == 0 and c >= 1024 * 2 * 1.25 / 8
    assert _capacity(1, mc) == 2  # decode: min(8, s*k) slots

    mc_big = MoEConfig(n_experts=4, top_k=2, d_expert=4, capacity_factor=2.0)
    assert _capacity(16, mc_big) >= 16  # cf=E/k: capacity>=S, dropless


def test_grad_finite_through_routing():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))

    def loss(p):
        y, aux = moe_apply(cfg, p, x)
        return jnp.sum(y**2) + aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # router must receive gradient (via combine weights + aux loss)
    assert float(jnp.max(jnp.abs(g["router"]))) > 0
