"""Batched Kron-Matmul subsystem: batch-grid kernels, batched plans, and the
``kron_matmul_batched`` entry point.

Acceptance (PR-2): ``kron_matmul_batched`` matches the per-sample reference
loop to fp32 tolerance for BOTH factor-sharing modes on the XLA path and the
Pallas interpreter path, and the generic ``jax.vmap(kron_matmul)`` fallback
can never silently diverge from the per-sample loop either.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, fastkron
from repro.core.autotune import KronPlan, Stage, TileConfig, make_batched_plan
from repro.core.kron import KronProblem, kron_matrix
from repro.kernels import ops
from repro.kernels.kron_fused import fused_growth, fused_kron_batched_pallas
from repro.kernels.kron_fused_t import (
    fused_kron_bwd_batched_pallas,
    fused_kron_t_batched_pallas,
)
from repro.kernels.ref import fused_kron_ref


def _mk_batched(seed, b, m, ps, qs):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ps) + 1)
    x = jax.random.normal(keys[0], (b, m, math.prod(ps)), jnp.float32)
    factors_last_first = [
        jax.random.normal(k, (b, p, q), jnp.float32)
        for k, p, q in zip(keys[1:], ps, qs)
    ]
    return x, factors_last_first


def _ref_loop(x, fls_batched):
    """Per-sample oracle: fused_kron_ref on each sample's factor slices."""
    return np.stack([
        np.asarray(
            fused_kron_ref(x[i], [f[i] for f in reversed(fls_batched)])
        )
        for i in range(x.shape[0])
    ])


# ---------------------------------------------------------------------------
# Batch-grid Pallas kernels vs per-sample oracle
# ---------------------------------------------------------------------------


BATCHED_CASES = [
    # (b, m, ps, qs, t_b, t_m, t_k, t_qs)
    (2, 4, (4, 4), (4, 4), 1, 2, 16, None),
    (4, 4, (4, 4), (4, 4), 2, 2, 16, None),      # t_b > 1: multi-sample block
    (4, 4, (4, 4), (4, 4), 4, 4, None, None),    # whole batch in one block
    (2, 2, (4, 4, 4), (4, 4, 4), 2, 2, 64, None),
    (4, 4, (4, 8), (8, 4), 2, 2, 32, None),      # rectangular chain
    (2, 4, (4, 4), (4, 4), 2, 2, 16, (2, 2)),    # Q-tiled + batched
]


@pytest.mark.parametrize("b,m,ps,qs,t_b,t_m,t_k,t_qs", BATCHED_CASES)
def test_fused_batched_kernel_matches_per_sample_ref(b, m, ps, qs, t_b, t_m, t_k, t_qs):
    x, fls = _mk_batched(0, b, m, ps, qs)
    got = fused_kron_batched_pallas(
        x, *fls, t_b=t_b, t_m=t_m, t_k=t_k, t_qs=t_qs, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got), _ref_loop(x, fls), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("b,m,ps,qs,t_b,t_m,t_k,t_qs", BATCHED_CASES)
def test_fused_t_batched_kernel_is_per_sample_vjp(b, m, ps, qs, t_b, t_m, t_k, t_qs):
    x, fls = _mk_batched(1, b, m, ps, qs)
    y = _ref_loop(x, fls)
    dy = jax.random.normal(jax.random.PRNGKey(2), y.shape, jnp.float32)
    got = fused_kron_t_batched_pallas(
        dy, *fls, t_b=t_b, t_m=t_m, t_k=t_k, t_qs=t_qs, interpret=True
    )
    for i in range(b):
        f_fwd = lambda xi: fused_kron_ref(xi, [f[i] for f in reversed(fls)])
        _, vjp = jax.vjp(f_fwd, x[i])
        (want,) = vjp(dy[i])
        np.testing.assert_allclose(got[i], want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "b,m,ps,qs,t_b,t_m,t_k",
    [
        (2, 4, (4, 4), (4, 4), 1, 2, 16),
        (4, 4, (4, 4), (4, 4), 2, 2, 16),
        (2, 2, (4, 4, 4), (4, 4, 4), 2, 2, 64),
        (4, 4, (4, 8), (8, 4), 4, 2, 32),
    ],
)
def test_fused_bwd_batched_kernel_matches_autodiff(b, m, ps, qs, t_b, t_m, t_k):
    """Per-sample (dx, factor grads) from the one-kernel batched backward."""
    x, fls = _mk_batched(3, b, m, ps, qs)
    y = _ref_loop(x, fls)
    dy = jax.random.normal(jax.random.PRNGKey(4), y.shape, jnp.float32)
    dx, dfs = fused_kron_bwd_batched_pallas(
        x, dy, *fls, t_b=t_b, t_m=t_m, t_k=t_k, interpret=True
    )
    for i in range(b):
        def loss(xi, fi):
            return (fused_kron_ref(xi, list(reversed(fi))) * dy[i]).sum()

        dx_want, dfs_want = jax.grad(loss, argnums=(0, 1))(
            x[i], [f[i] for f in fls]
        )
        np.testing.assert_allclose(dx[i], dx_want, rtol=1e-4, atol=1e-4)
        for got_f, want_f in zip(dfs, dfs_want):
            np.testing.assert_allclose(got_f[i], want_f, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ops_batched_dispatch(backend):
    b, m, ps, qs = 4, 4, (4, 4), (4, 4)
    x, fls = _mk_batched(5, b, m, ps, qs)
    got = ops.fused_kron_batched(x, fls, backend=backend, t_b=2, t_m=2, t_k=16)
    np.testing.assert_allclose(
        np.asarray(got), _ref_loop(x, fls), rtol=1e-5, atol=1e-5
    )


def test_emit_batched_xla_scan_path():
    """The scan-over-batch-tiles branch of the unified XLA executor (taken
    when the batch working set exceeds the cache budget) matches the untiled
    batched chain — forward, transposed, and stage backward."""
    from repro.kernels import emit

    b, m, ps, qs = 8, 4, (4, 4), (4, 4)
    x, fls = _mk_batched(6, b, m, ps, qs)
    want = _ref_loop(x, fls)
    budget = emit.XLA_CACHE_BUDGET_BYTES
    try:
        emit.XLA_CACHE_BUDGET_BYTES = 0  # force the scan branch
        got = emit._chain_xla.__wrapped__(x, tuple(fls), t_b=2, direction="fwd")
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
        dy = jax.random.normal(jax.random.PRNGKey(7), want.shape, jnp.float32)
        dx, dfs = emit._grad_xla.__wrapped__(x, dy, tuple(fls), t_b=2)
        assert dx.shape == x.shape
        assert all(d.shape == f.shape for d, f in zip(dfs, fls))
        gt = emit._chain_xla.__wrapped__(dy, tuple(fls), t_b=2, direction="bwd")
        assert gt.shape == x.shape
    finally:
        emit.XLA_CACHE_BUDGET_BYTES = budget


# ---------------------------------------------------------------------------
# kron_matmul_batched: both sharing modes, both backends, fwd + grad
# ---------------------------------------------------------------------------


API_CASES = [
    (4, 8, (4, 4), (4, 4)),
    (2, 4, (4, 4, 4), (4, 4, 4)),
    (8, 2, (4, 8), (8, 4)),       # rectangular, B > M
    (3, 5, (4, 4), (4, 4)),       # batch with no nice divisors
]


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("b,m,ps,qs", API_CASES)
def test_batched_shared_matches_per_sample_loop(backend, b, m, ps, qs):
    keys = jax.random.split(jax.random.PRNGKey(10), len(ps) + 1)
    x = jax.random.normal(keys[0], (b, m, math.prod(ps)), jnp.float32)
    fs = tuple(
        jax.random.normal(k, (p, q), jnp.float32)
        for k, p, q in zip(keys[1:], ps, qs)
    )
    got = fastkron.kron_matmul_batched(
        x, fs, shared_factors=True, backend=backend
    )
    want = np.stack([
        np.asarray(fastkron.kron_matmul(x[i], fs, backend=backend))
        for i in range(b)
    ])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("b,m,ps,qs", API_CASES)
def test_batched_per_sample_matches_loop(backend, b, m, ps, qs):
    x, fls = _mk_batched(11, b, m, ps, qs)
    fb = tuple(fls)  # application order == reversed problem order; the API
    # takes PROBLEM order, so build problem-order batched factors instead.
    fb = tuple(reversed(fb))
    got = fastkron.kron_matmul_batched(
        x, fb, shared_factors=False, backend=backend
    )
    want = np.stack([
        np.asarray(
            fastkron.kron_matmul(x[i], [f[i] for f in fb], backend=backend)
        )
        for i in range(b)
    ])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_batched_per_sample_grads_match_loop(backend):
    b, m, ps, qs = 4, 8, (4, 4), (4, 4)
    x, fls = _mk_batched(12, b, m, ps, qs)
    fb = tuple(reversed(fls))

    def loss(x, fb):
        y = fastkron.kron_matmul_batched(
            x, fb, shared_factors=False, backend=backend
        )
        return jnp.sum(y * jnp.sin(y))

    def loss_ref(x, fb):
        t = 0.0
        for i in range(b):
            y = x[i] @ kron_matrix([f[i] for f in fb])
            t = t + jnp.sum(y * jnp.sin(y))
        return t

    gx, gf = jax.grad(loss, argnums=(0, 1))(x, fb)
    gx2, gf2 = jax.grad(loss_ref, argnums=(0, 1))(x, fb)
    np.testing.assert_allclose(gx, gx2, rtol=1e-4, atol=1e-4)
    for a, b_ in zip(gf, gf2):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_batched_per_sample_x_only_grad_skips_factor_grads():
    """symbolic_zeros on the batched path: closed-over factors produce exact
    zero cotangents without running the batched factor-grad stage."""
    from repro.kernels import emit

    b, m, ps, qs = 2, 4, (4, 4), (4, 4)
    x, fls = _mk_batched(13, b, m, ps, qs)
    fb = tuple(reversed(fls))
    calls = []
    orig = emit.run_stage_grad
    try:
        emit.run_stage_grad = lambda *a, **k: calls.append(1) or orig(*a, **k)
        gx = jax.grad(
            lambda x: fastkron.kron_matmul_batched(
                x, fb, shared_factors=False
            ).sum()
        )(x)
    finally:
        emit.run_stage_grad = orig
    assert not calls, "batched factor-grad stage ran despite unperturbed factors"
    for i in range(b):
        want = jax.grad(lambda xi: jnp.sum(xi @ kron_matrix([f[i] for f in fb])))(x[i])
        np.testing.assert_allclose(gx[i], want, rtol=1e-5, atol=1e-5)


def test_batched_pallas_backward_on_q_tiled_plan():
    """Batched grads on plans whose fused stages are only legal via Q-tiling:
    the one-kernel batched stage backward overflows VMEM and the per-factor
    fallback (which must never overflow in turn) takes over — for full grads
    AND the dx-only transposed chain."""
    b, m, ps, qs = 2, 8, (2, 2, 2), (64, 64, 64)
    prob = KronProblem(m, ps, qs)
    plan = make_batched_plan(prob, b, shared_factors=False)
    assert any(st.t_qs is not None for st in plan.stages), plan.describe()
    keys = jax.random.split(jax.random.PRNGKey(16), len(ps) + 1)
    x = jax.random.normal(keys[0], (b, m, math.prod(ps)), jnp.float32)
    fb = tuple(
        jax.random.normal(k, (b, p, q), jnp.float32)
        for k, p, q in zip(keys[1:], ps, qs)
    )

    def want_grads(argnums):
        def loss_ref(x, fb):
            t = 0.0
            for i in range(b):
                t = t + ((x[i] @ kron_matrix([f[i] for f in fb])) ** 2).sum()
            return t

        return jax.grad(loss_ref, argnums=argnums)(x, fb)

    for backend in ("xla", "pallas"):
        def loss(x, fb):
            y = fastkron.kron_matmul_batched(
                x, fb, shared_factors=False, backend=backend, plan=plan
            )
            return (y ** 2).sum()

        # loose-ish rtol: the (64,64,64) expansion makes grads O(1e6) in f32,
        # where accumulation-order noise alone reaches ~1e-4 relative.
        got = jax.grad(loss, argnums=(0, 1))(x, fb)
        want = want_grads((0, 1))
        np.testing.assert_allclose(got[0], want[0], rtol=5e-4, atol=1e-3)
        for a, w in zip(got[1], want[1]):
            np.testing.assert_allclose(a, w, rtol=5e-4, atol=1e-2)
        # dx-only: the transposed chain path with its own overflow fallback
        gx = jax.grad(lambda x: loss(x, fb))(x)
        np.testing.assert_allclose(gx, want_grads(0), rtol=5e-4, atol=1e-3)


def test_batched_plan_none_runs_unfused_loop():
    b, m, ps, qs = 2, 4, (4, 4), (4, 4)
    x, fls = _mk_batched(14, b, m, ps, qs)
    fb = tuple(reversed(fls))
    got = fastkron.kron_matmul_batched(x, fb, shared_factors=False, plan=None)
    np.testing.assert_allclose(
        np.asarray(got), _ref_loop(x, fls), rtol=1e-5, atol=1e-5
    )


def test_batched_shape_validation():
    x = jnp.zeros((2, 4, 16))
    f2 = jnp.zeros((4, 4))
    f3 = jnp.zeros((2, 4, 4))
    with pytest.raises(ValueError):
        fastkron.kron_matmul_batched(x, [f3, f3], shared_factors=True)
    with pytest.raises(ValueError):
        fastkron.kron_matmul_batched(x, [f2, f2], shared_factors=False)
    with pytest.raises(ValueError):  # factor batch mismatch
        fastkron.kron_matmul_batched(
            x, [jnp.zeros((3, 4, 4)), f3], shared_factors=False
        )
    with pytest.raises(ValueError):  # wrong K
        fastkron.kron_matmul_batched(
            jnp.zeros((2, 4, 17)), [f3, f3], shared_factors=False
        )


# ---------------------------------------------------------------------------
# vmap lowering (satellite): the custom batching rule routes jax.vmap through
# the batch-grid kernels — pinned at jaxpr level (which primitive fires) and
# at HLO level (the vmap lowering IS the batched entry point's lowering).
# ---------------------------------------------------------------------------


def _hlo_dot_count(fn, *args) -> tuple[int, str]:
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return txt.count(" dot("), txt


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_vmap_kron_matmul_matches_per_sample_loop(backend):
    b, m, ps, qs = 4, 8, (4, 4), (4, 4)
    x, fls = _mk_batched(15, b, m, ps, qs)
    fb = tuple(reversed(fls))
    got = jax.vmap(
        lambda xi, fi: fastkron.kron_matmul(xi, fi, backend=backend)
    )(x, fb)
    want = np.stack([
        np.asarray(
            fastkron.kron_matmul(x[i], [f[i] for f in fb], backend=backend)
        )
        for i in range(b)
    ])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
    # ... and the dedicated batched path agrees with the vmap lowering.
    batched = fastkron.kron_matmul_batched(
        x, fb, shared_factors=False, backend=backend
    )
    np.testing.assert_allclose(np.asarray(batched), want, rtol=1e-4, atol=1e-4)


def test_vmap_over_x_only_collapses_into_rows():
    """vmap over x with SHARED factors: the batching rule collapses B into M
    and re-binds the single-problem primitive — no batched primitive, and
    the compiled dots run on the collapsed (B*M) row count."""
    b, m, ps, qs = 4, 8, (4, 4), (4, 4)
    keys = jax.random.split(jax.random.PRNGKey(20), len(ps) + 1)
    x = jax.random.normal(keys[0], (b, m, math.prod(ps)), jnp.float32)
    fs = tuple(
        jax.random.normal(k, (p, q), jnp.float32)
        for k, p, q in zip(keys[1:], ps, qs)
    )
    fn = jax.vmap(lambda xi: fastkron.kron_matmul(xi, fs))
    got = fn(x)
    want = np.stack([
        np.asarray(fastkron.kron_matmul(x[i], fs)) for i in range(b)
    ])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
    # jaxpr pin: single-problem primitive on the collapsed rows, and the
    # batched primitive does NOT fire.
    jx = str(jax.make_jaxpr(fn)(x))
    assert "kron_matmul[" in jx, jx
    assert "kron_matmul_batched" not in jx, jx
    assert f"({b * m}, {math.prod(ps)})" in jx.replace("f32[", "(").replace(
        "]", ")"
    ) or f"f32[{b * m},{math.prod(ps)}]" in jx, jx
    # HLO pin: the lowering equals the collapsed single-problem call.
    n_vmap, txt = _hlo_dot_count(fn, x)
    n_flat, _ = _hlo_dot_count(
        lambda x2: fastkron.kron_matmul(x2, fs), x.reshape(b * m, -1)
    )
    assert n_vmap == n_flat, (n_vmap, n_flat)
    assert f"f32[{b * m}," in txt, "expected collapsed-row dots in HLO"


def test_vmap_over_x_and_factors_routes_to_batch_grid():
    """vmap over (x, factors): the rule binds the BATCHED primitive, and the
    compiled HLO is the same as kron_matmul_batched's — the batch-grid
    kernels, not the generic fallback."""
    b, m, ps, qs = 4, 8, (4, 4), (4, 4)
    x, fls = _mk_batched(21, b, m, ps, qs)
    fb = tuple(reversed(fls))
    fn = jax.vmap(lambda xi, fi: fastkron.kron_matmul(xi, fi))
    jx = str(jax.make_jaxpr(fn)(x, fb))
    assert "kron_matmul_batched[" in jx, jx
    got = fn(x, fb)
    np.testing.assert_allclose(
        np.asarray(got), _ref_loop(x, fls), rtol=1e-4, atol=1e-4
    )
    # HLO pin: identical dot structure to the dedicated batched entry point
    # (same plan, same executor — vmap IS the batched path).
    n_vmap, txt_v = _hlo_dot_count(fn, x, fb)
    n_batched, txt_b = _hlo_dot_count(
        lambda x2, f2: fastkron.kron_matmul_batched(
            x2, f2, shared_factors=False
        ),
        x, fb,
    )
    assert n_vmap == n_batched, (n_vmap, n_batched)


def test_nested_vmap_folds_into_one_batch_axis():
    """vmap(vmap(...)) folds the outer axis into the existing batch: one
    batched primitive on C*B samples, numerics matching the double loop."""
    c, b, m, ps, qs = 2, 2, 4, (4, 4), (4, 4)
    x, fls = _mk_batched(22, c * b, m, ps, qs)
    fb = tuple(reversed(fls))
    xn = x.reshape(c, b, m, -1)
    fn_ = tuple(f.reshape(c, b, *f.shape[1:]) for f in fb)
    fn = jax.vmap(jax.vmap(lambda xi, fi: fastkron.kron_matmul(xi, fi)))
    got = fn(xn, fn_)
    want = _ref_loop(x, fls).reshape(c, b, m, -1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
    jx = str(jax.make_jaxpr(fn)(xn, fn_))
    assert jx.count("kron_matmul_batched[") == 1, jx
    # grads through the nested-vmap lowering agree with the flat batched path
    gx = jax.grad(lambda xn: (fn(xn, fn_) ** 2).sum())(xn)
    gx_flat = jax.grad(
        lambda x2: (
            fastkron.kron_matmul_batched(x2, fb, shared_factors=False) ** 2
        ).sum()
    )(x)
    np.testing.assert_allclose(
        np.asarray(gx).reshape(c * b, m, -1), np.asarray(gx_flat),
        rtol=1e-4, atol=1e-3,
    )


# ---------------------------------------------------------------------------
# Batched plans
# ---------------------------------------------------------------------------


def test_batched_plan_shared_collapses_batch_into_m():
    prob = KronProblem(64, (16, 16, 16), (16, 16, 16))
    plan = make_batched_plan(prob, 8, shared_factors=True, enable_prekron=False)
    collapsed = autotune.make_plan(
        KronProblem(512, (16, 16, 16), (16, 16, 16)), enable_prekron=False
    )
    assert plan == collapsed
    assert plan.t_b == 1  # collapse path: no batch-grid tile


def test_batched_plan_per_sample_picks_batch_tile():
    prob = KronProblem(8, (16, 16, 16), (16, 16, 16))
    plan = make_batched_plan(prob, 8, shared_factors=False)
    assert plan.t_b > 1
    assert 8 % plan.t_b == 0


def test_batched_plan_respects_vmem_budget():
    """Every stage block, scaled by t_b, fits the budget — the M-tile is
    traded down when the batch tile would otherwise not fit."""
    budget = 64 * 1024
    for prob, batch in [
        (KronProblem(64, (16, 16), (16, 16)), 8),
        (KronProblem(256, (4, 4, 4), (4, 4, 4)), 16),
        (KronProblem(32, (2, 2, 2, 2, 2), (8, 8, 8, 8, 8)), 4),
    ]:
        plan = make_batched_plan(
            prob, batch, shared_factors=False, vmem_budget_elems=budget
        )
        ps = list(reversed(prob.ps))
        qs = list(reversed(prob.qs))
        for st in plan.stages:
            sps = [ps[i] for i in st.factor_ids]
            sqs = [qs[i] for i in st.factor_ids]
            t_k = st.tiles.t_s * math.prod(sps)
            growth = fused_growth(sps, sqs, st.t_qs)
            assert plan.t_b * st.tiles.t_m * t_k * growth <= budget, (
                prob, batch, plan.describe()
            )


def test_batched_plan_trades_m_tile_for_batch_axis():
    """With a budget that fits only one (t_m=8) tile, growing the batch axis
    must come out of the M-tile."""
    prob = KronProblem(64, (16, 16), (16, 16))
    single = autotune.make_plan(prob, enable_prekron=False)
    budget = max(
        single.stages[0].tiles.t_m * single.stages[0].tiles.t_s * 256, 4096
    )
    plan = make_batched_plan(
        prob, 8, shared_factors=False, vmem_budget_elems=budget
    )
    assert plan.t_b > 1
    assert max(st.tiles.t_m for st in plan.stages) < max(
        st.tiles.t_m for st in single.stages
    )


def test_batched_plan_cache_key_includes_batch():
    prob = KronProblem(8, (4, 4), (4, 4))
    k0 = autotune.plan_cache_key(prob, 4, "xla")
    k8 = autotune.plan_cache_key(prob, 4, "xla", batch=8, shared_factors=False)
    k16 = autotune.plan_cache_key(prob, 4, "xla", batch=16, shared_factors=False)
    ks = autotune.plan_cache_key(prob, 4, "xla", batch=8, shared_factors=True)
    assert len({k0, k8, k16, ks}) == 4


def test_batched_plan_json_roundtrip_keeps_t_b():
    prob = KronProblem(8, (4, 4), (4, 4))
    plan = make_batched_plan(prob, 8, shared_factors=False)
    assert autotune.plan_from_json(autotune.plan_to_json(plan)) == plan
    # legacy entries without t_b deserialize to the unbatched default
    legacy = autotune.plan_to_json(plan)
    del legacy["t_b"]
    assert autotune.plan_from_json(legacy).t_b == 1


def test_measured_batched_plan_caches_on_batch(tmp_path):
    cache = str(tmp_path / "plans.json")
    prob = KronProblem(4, (4, 4), (4, 4))
    plan1 = make_batched_plan(
        prob, 4, shared_factors=False, tune="measure", backend="xla",
        cache_path=cache,
    )
    key = autotune.plan_cache_key(
        prob, 4, "xla", enable_prekron=False, batch=4, shared_factors=False
    )
    entries = autotune.load_plan_cache(cache)
    assert key in entries
    orig = autotune.measure_best
    autotune.measure_best = lambda *a, **k: (_ for _ in ()).throw(
        AssertionError("measure_best called on cache hit")
    )
    try:
        plan2 = make_batched_plan(
            prob, 4, shared_factors=False, tune="measure", backend="xla",
            cache_path=cache,
        )
    finally:
        autotune.measure_best = orig
    assert plan2 == plan1
