"""Execution guard layer: taxonomy, chaos harness, degradation ladder,
numerics guards, plan-cache robustness (docs/robustness.md).

The ladder tests assert the PR's acceptance triple for every rung: (a) the
typed error is recorded in health state, (b) execution completes on the
fallback rung, (c) the output is BITWISE-identical to the unfaulted
reference — degradation must be numerically invisible.
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, engine
from repro.kernels import emit
from repro.runtime import chaos, guard


@pytest.fixture(autouse=True)
def _fresh_guard_state():
    guard.reset_health()
    guard.set_numerics_policy(None)
    yield
    guard.reset_health()
    guard.set_numerics_policy(None)


def _problem(ps, qs, m=16, seed=0):
    rng = np.random.RandomState(seed)
    k = int(np.prod(ps))
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    fs = tuple(
        jnp.asarray(rng.randn(p, q), jnp.float32) for p, q in zip(ps, qs)
    )
    return x, fs


def _batched_problem(ps, qs, b=2, m=8, seed=0):
    rng = np.random.RandomState(seed)
    k = int(np.prod(ps))
    x = jnp.asarray(rng.randn(b, m, k), jnp.float32)
    fs = tuple(
        jnp.asarray(rng.randn(b, p, q), jnp.float32) for p, q in zip(ps, qs)
    )
    return x, fs


# ---------------------------------------------------------------------------
# Taxonomy
# ---------------------------------------------------------------------------


def test_taxonomy_subclasses_builtin_types():
    """Every typed error still satisfies the except-clause contract of the
    ad-hoc error it replaced — old callers keep working."""
    assert issubclass(guard.PlanError, ValueError)
    assert issubclass(guard.VmemOverflowError, ValueError)
    assert issubclass(guard.LoweringError, ValueError)
    assert issubclass(guard.CollectiveError, RuntimeError)
    assert issubclass(guard.PlanCacheError, OSError)
    assert issubclass(guard.NumericsError, FloatingPointError)
    for t in (
        guard.PlanError, guard.VmemOverflowError, guard.LoweringError,
        guard.CollectiveError, guard.PlanCacheError, guard.NumericsError,
    ):
        assert issubclass(t, guard.KronError)


def test_emit_raises_typed_errors():
    x, fs = _problem((4, 4), (4, 4))
    with pytest.raises(guard.VmemOverflowError):
        emit.chain_pallas(
            x[None], *(f[None] for f in fs), t_m=16, t_k=16,
            vmem_budget_elems=8,
        )
    with pytest.raises(guard.LoweringError):
        emit.chain_pallas(x[None], *(f[None] for f in fs), t_m=16, t_k=6)
    with pytest.raises(guard.PlanError):
        autotune.make_plan(
            autotune.KronProblem(16, (4, 4), (4, 4)), tune="nonsense"
        )


# ---------------------------------------------------------------------------
# Chaos harness
# ---------------------------------------------------------------------------


def test_chaos_spec_parsing():
    specs = chaos.parse_spec("stage_execute,collective:p=0.5:seed=7:times=2")
    assert specs[0].site == "stage_execute" and specs[0].p == 1.0
    assert specs[1].site == "collective"
    assert (specs[1].p, specs[1].seed, specs[1].times) == (0.5, 7, 2)
    with pytest.raises(guard.PlanError):
        chaos.parse_spec("not_a_site")
    with pytest.raises(guard.PlanError):
        chaos.parse_spec("collective:frequency=2")


def test_chaos_inject_fires_typed_error_and_counts():
    with chaos.inject("plan_cache_load:times=1") as specs:
        with pytest.raises(guard.PlanCacheError):
            chaos.maybe_fail("plan_cache_load")
        chaos.maybe_fail("plan_cache_load")  # times=1 exhausted: no-op
        chaos.maybe_fail("collective")  # different site: no-op
    assert specs[0].seen == 2 and specs[0].fired == 1
    chaos.maybe_fail("plan_cache_load")  # outside the block: inactive


def test_chaos_probabilistic_firing_is_deterministic():
    def pattern():
        hits = []
        with chaos.inject("collective:p=0.5:seed=11"):
            for _ in range(32):
                try:
                    chaos.maybe_fail("collective")
                    hits.append(0)
                except guard.CollectiveError:
                    hits.append(1)
        return hits

    first = pattern()
    assert pattern() == first  # same seed -> identical replay
    assert 0 < sum(first) < 32  # actually probabilistic


def test_chaos_after_skips_initial_hits():
    with chaos.inject("stage_execute:after=2"):
        chaos.maybe_fail("stage_execute")
        chaos.maybe_fail("stage_execute")
        with pytest.raises(guard.VmemOverflowError):
            chaos.maybe_fail("stage_execute")


def test_chaos_env_layer(monkeypatch):
    monkeypatch.setenv("FASTKRON_CHAOS", "collective:times=1")
    chaos.reload_env()
    try:
        with pytest.raises(guard.CollectiveError):
            chaos.maybe_fail("collective")
        chaos.maybe_fail("collective")
    finally:
        monkeypatch.delenv("FASTKRON_CHAOS")
        chaos.reload_env()


# ---------------------------------------------------------------------------
# run_ladder unit behavior (no jax)
# ---------------------------------------------------------------------------


def _flaky(fail_first_n, calls=[0]):
    def fn():
        calls[0] += 1
        if calls[0] <= fail_first_n:
            raise guard.VmemOverflowError("boom")
        return "ok"

    return fn


def test_run_ladder_degrades_and_reraises():
    with pytest.warns(guard.GuardWarning, match="degrading to rung 1"):
        out = guard.run_ladder(
            "k1",
            (("a", _flaky(99, [0])), ("b", lambda: "fallback")),
        )
    assert out == "fallback"
    h = guard.health("k1")
    assert h.degraded_calls == 1 and h.errors == {"VmemOverflowError": 1}
    # every rung failing re-raises the last typed error
    with pytest.raises(guard.VmemOverflowError):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            guard.run_ladder(
                "k2", (("a", _flaky(99, [0])), ("b", _flaky(99, [0])))
            )


def test_run_ladder_pins_after_patience_and_recovers_counter():
    def failing():
        raise guard.VmemOverflowError("no vmem")

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for _ in range(3):
            out = guard.run_ladder(
                "k3", (("a", failing), ("b", lambda: "ok")), patience=3
            )
            assert out == "ok"
    h = guard.health("k3")
    assert h.pinned and h.rung == 1
    # pinned: the failing rung is skipped entirely (no new error recorded)
    n_err = h.errors["VmemOverflowError"]
    assert guard.run_ladder("k3", (("a", failing), ("b", lambda: "ok"))) == "ok"
    assert guard.health("k3").errors["VmemOverflowError"] == n_err
    # success at the start rung resets the consecutive counter
    assert guard.health("k3").consecutive == 0


def test_run_ladder_success_resets_consecutive():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        guard.run_ladder("k4", (("a", _flaky(1, [0])), ("b", lambda: "ok")),
                         patience=3)
    assert guard.health("k4").consecutive == 1
    guard.run_ladder("k4", (("a", lambda: "ok"), ("b", lambda: "ok")))
    assert guard.health("k4").consecutive == 0 and not guard.health("k4").pinned


def test_non_kron_errors_propagate_through_ladder():
    def buggy():
        raise TypeError("a real bug, not a capacity failure")

    with pytest.raises(TypeError):
        guard.run_ladder("k5", (("a", buggy), ("b", lambda: "ok")))
    assert guard.health("k5").degraded_calls == 0


# ---------------------------------------------------------------------------
# The KronOp degradation ladder (rungs 0 -> 1 -> 2, bitwise parity)
# ---------------------------------------------------------------------------


def test_ladder_rung1_per_factor_bitwise():
    op = engine.kron_op_for((4, 4, 4), (4, 4, 4), m=16)
    x, fs = _problem((4, 4, 4), (4, 4, 4))
    ref = op(x, fs)
    guard.reset_health()
    with pytest.warns(guard.GuardWarning, match="degrading to rung 1"):
        with chaos.inject("stage_execute:times=1"):
            y = op(x, fs)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(y))  # (c)
    [(key, h)] = [
        (k, h) for k, h in guard.health_entries() if k[0] == "kron"
    ]
    assert h.errors.get("VmemOverflowError") == 1  # (a) typed error recorded
    assert h.degraded_calls == 1 and h.calls == 1  # (b) completed degraded
    assert "guard[" in op.describe() and "VmemOverflowError" in op.describe()


def test_ladder_rung2_xla_scan_bitwise():
    op = engine.kron_op_for((2, 4, 8), (2, 4, 8), m=16)
    x, fs = _problem((2, 4, 8), (2, 4, 8), seed=1)
    ref = op(x, fs)
    guard.reset_health()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.GuardWarning)
        with chaos.inject("stage_execute,per_factor"):
            y = op(x, fs)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(y))
    [h] = [h for k, h in guard.health_entries() if k[0] == "kron"]
    assert h.errors.get("VmemOverflowError", 0) >= 2  # both rungs recorded
    assert h.degraded_calls == 1


def test_ladder_batched_per_sample_bitwise():
    op = engine.kron_op_for(
        (4, 4), (4, 4), batch=2, m=8, shared_factors=False
    )
    x, fs = _batched_problem((4, 4), (4, 4))
    ref = op(x, fs)
    guard.reset_health()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.GuardWarning)
        with chaos.inject("stage_execute:times=1"):
            y1 = op(x, fs)  # rung 1: per-factor batched
        with chaos.inject("stage_execute:times=1,per_factor"):
            y2 = op(x, fs)  # rung 2: xla chain
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(y2))
    [h] = [h for k, h in guard.health_entries() if k[0] == "kron"]
    assert h.degraded_calls == 2


def test_ladder_pins_op_after_patience():
    op = engine.kron_op_for((8, 8), (8, 8), m=16)
    x, fs = _problem((8, 8), (8, 8), seed=2)
    ref = op(x, fs)
    guard.reset_health()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.GuardWarning)
        with chaos.inject("stage_execute:times=%d" % guard.DEFAULT_PATIENCE):
            for _ in range(guard.DEFAULT_PATIENCE):
                np.testing.assert_array_equal(
                    np.asarray(ref), np.asarray(op(x, fs))
                )
    [(key, h)] = [(k, h) for k, h in guard.health_entries() if k[0] == "kron"]
    assert h.pinned and h.rung == 1
    assert "pinned" in op.describe()
    # pinned: later calls start at rung 1 (no chaos active, still correct)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(op(x, fs)))
    assert guard.health(key).errors.get("VmemOverflowError") == 3


def test_gradients_survive_stage_chaos():
    """The backward per-factor fallbacks (now KronError-typed) still produce
    correct grads when the fused stage backward is chaos-failed."""
    op = engine.kron_op_for((4, 4), (4, 4), m=8)
    x, fs = _problem((4, 4), (4, 4), m=8, seed=3)

    def loss(x, fs):
        return jnp.sum(op(x, fs) ** 2)

    ref = jax.grad(loss, argnums=(0, 1))(x, fs)
    guard.reset_health()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.GuardWarning)
        with chaos.inject("stage_execute:times=1"):
            got = jax.grad(loss, argnums=(0, 1))(x, fs)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


# ---------------------------------------------------------------------------
# Numerics guards
# ---------------------------------------------------------------------------


def test_numerics_policy_resolution(monkeypatch):
    assert guard.numerics_policy() == "off"
    monkeypatch.setenv("FASTKRON_NUMERICS", "warn")
    assert guard.numerics_policy() == "warn"
    guard.set_numerics_policy("raise")
    assert guard.numerics_policy() == "raise"
    guard.set_numerics_policy(None)
    assert guard.numerics_policy() == "warn"  # back to env
    with pytest.raises(guard.PlanError):
        guard.set_numerics_policy("maybe")
    with guard.numerics("off"):
        assert guard.numerics_policy() == "off"
    assert guard.numerics_policy() == "warn"


@pytest.mark.parametrize("policy", ["off", "warn", "raise"])
def test_numerics_guard_at_program_boundary(policy):
    op = engine.kron_op_for((4, 4), (4, 4), m=8)
    x, fs = _problem((4, 4), (4, 4), m=8, seed=4)
    x = x.at[0, 0].set(jnp.inf)
    with guard.numerics(policy):
        if policy == "raise":
            with pytest.raises(guard.NumericsError):
                op(x, fs)
        elif policy == "warn":
            with pytest.warns(guard.GuardWarning, match="non-finite"):
                y = op(x, fs)
            assert not bool(jnp.isfinite(y).all())
            assert guard.health_report()["events"].get("nonfinite")
        else:
            y = op(x, fs)  # off: no check, inf flows through silently
            assert not bool(jnp.isfinite(y).all())


def test_numerics_guard_finite_inputs_clean():
    op = engine.kron_op_for((4, 4), (4, 4), m=8)
    x, fs = _problem((4, 4), (4, 4), m=8, seed=5)
    with guard.numerics("raise"):
        y = op(x, fs)
    assert bool(jnp.isfinite(y).all())
    assert not guard.health_report()["events"]


def test_numerics_guard_under_jit_smoke():
    """Traced values route through jax.debug.callback — the jitted call must
    still complete and produce the same output as eager."""
    op = engine.kron_op_for((4, 4), (4, 4), m=8)
    x, fs = _problem((4, 4), (4, 4), m=8, seed=6)
    ref = op(x, fs)
    with guard.numerics("warn"):
        y = jax.jit(lambda x, fs: op(x, fs))(x, fs)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(y))


# ---------------------------------------------------------------------------
# Plan-cache robustness (satellite: retry + PlanCacheError routing)
# ---------------------------------------------------------------------------


def test_plan_cache_corruption_warns_and_rebuilds(tmp_path):
    path = str(tmp_path / "plans.json")
    with open(path, "w") as f:
        f.write('{"version": 1, "entries": {truncated')
    with pytest.warns(guard.GuardWarning, match="rebuilding"):
        assert autotune.load_plan_cache(path) == {}
    assert guard.health_report()["events"].get("plan_cache_rebuild") == 1
    # warn-once: a second load of the same path stays quiet
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert autotune.load_plan_cache(path) == {}


def test_plan_cache_missing_file_is_silent(tmp_path):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert autotune.load_plan_cache(str(tmp_path / "nope.json")) == {}
    assert not guard.health_report()["events"]


def test_plan_cache_save_retries_through_contention(tmp_path):
    path = str(tmp_path / "plans.json")
    entries = {"k": {"plan": {"stages": [], "t_b": 1}}}
    # two injected failures, three attempts: the save must land
    with chaos.inject("plan_cache_save:times=2") as specs:
        autotune.save_plan_cache(path, entries)
    assert specs[0].fired == 2
    assert autotune.load_plan_cache(path) == entries


def test_plan_cache_save_exhausted_warns_not_raises(tmp_path):
    path = str(tmp_path / "plans.json")
    with chaos.inject("plan_cache_save"):  # every attempt fails
        with pytest.warns(guard.GuardWarning, match="not persisted"):
            autotune.save_plan_cache(path, {"k": {"plan": {}}})
    assert not os.path.exists(path)
    assert guard.health_report()["events"].get("plan_cache_save_failed") == 1


def test_chaos_cache_load_routes_through_rebuild(tmp_path):
    path = str(tmp_path / "plans.json")
    autotune.save_plan_cache(path, {"k": {"plan": {"stages": [], "t_b": 1}}})
    with chaos.inject("plan_cache_load:times=1"):
        with pytest.warns(guard.GuardWarning, match="rebuilding"):
            assert autotune.load_plan_cache(path) == {}
    # injection exhausted: the intact on-disk file reads back fine
    assert autotune.load_plan_cache(path) != {}


# ---------------------------------------------------------------------------
# Health report plumbing
# ---------------------------------------------------------------------------


def test_health_report_shape_and_reset():
    guard.record_event("nonfinite")
    guard.health("some-op").record(guard.PlanError("x"))
    rep = guard.health_report()
    assert rep["events"]["nonfinite"] == 1
    assert rep["ops"]["'some-op'"]["errors"] == {"PlanError": 1}
    guard.reset_health()
    rep = guard.health_report()
    assert not rep["events"] and not rep["ops"]
