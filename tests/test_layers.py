"""KronLinear layer: forward/grad vs materialized dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layers import (
    KronLinearSpec,
    balanced_factorization,
    kron_linear_apply,
    kron_linear_init,
    kron_linear_materialize,
)


def test_balanced_factorization_known():
    assert balanced_factorization(2048, 2) == (64, 32)
    assert balanced_factorization(768, 2) == (32, 24)
    assert balanced_factorization(14336, 2) == (128, 112)
    assert balanced_factorization(7, 1) == (7,)


def test_balanced_factorization_edge_cases():
    import math

    # d=1: every bucket stays 1
    assert balanced_factorization(1, 1) == (1,)
    assert balanced_factorization(1, 3) == (1, 1, 1)
    # prime d: one bucket gets it all
    assert balanced_factorization(13, 2) == (13, 1)
    assert balanced_factorization(97, 4) == (97, 1, 1, 1)
    # n greater than the number of prime factors: pad with 1s, stay exact
    assert balanced_factorization(6, 4) == (3, 2, 1, 1)
    for d, n in [(2048, 5), (360, 4), (97, 3), (1, 2)]:
        out = balanced_factorization(d, n)
        assert len(out) == n and math.prod(out) == d
        assert out == tuple(sorted(out, reverse=True))


def test_balanced_factorization_rejects_bad_dims():
    with pytest.raises(ValueError):
        balanced_factorization(0, 2)
    with pytest.raises(ValueError):
        balanced_factorization(-8, 2)
    with pytest.raises(ValueError):
        balanced_factorization(8, 0)


@pytest.mark.parametrize("use_bias", [False, True])
def test_forward_matches_dense(use_bias):
    spec = KronLinearSpec.balanced(64, 48, n_factors=2, use_bias=use_bias)
    params = kron_linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 64))
    got = kron_linear_apply(params, x)
    want = x @ kron_linear_materialize(params)
    if use_bias:
        want = want + params["bias"]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_param_count_compression():
    spec = KronLinearSpec.balanced(4096, 4096, n_factors=2)
    dense = 4096 * 4096
    assert spec.n_params < dense / 1000  # 64*64*2 = 8192 params vs 16.7M


def test_grad_flows_and_matches_dense():
    spec = KronLinearSpec.balanced(32, 32, n_factors=2)
    params = kron_linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))

    def loss_kron(params):
        return jnp.sum(kron_linear_apply(params, x) ** 2)

    def loss_dense(params):
        return jnp.sum((x @ kron_linear_materialize(params)) ** 2)

    g1 = jax.grad(loss_kron)(params)
    g2 = jax.grad(loss_dense)(params)
    for a, b in zip(g1["factors"], g2["factors"]):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_init_variance_matches_dense_scaling():
    spec = KronLinearSpec.balanced(1024, 1024, n_factors=2)
    params = kron_linear_init(jax.random.PRNGKey(42), spec)
    w = kron_linear_materialize(params)
    # Var(W) should be ~1/d_in so that y = xW preserves scale.
    assert np.var(np.asarray(w)) == pytest.approx(1.0 / 1024, rel=0.3)


def test_leading_dims():
    spec = KronLinearSpec.balanced(16, 16, n_factors=2)
    params = kron_linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 16))
    y = kron_linear_apply(params, x)
    assert y.shape == (2, 3, 16)
    # the (B, T, d) route goes through the batched entry point and must match
    # the per-sample application exactly
    for i in range(2):
        np.testing.assert_allclose(
            y[i], kron_linear_apply(params, x[i]), rtol=1e-5, atol=1e-5
        )


def test_kron_linear_apply_batched_per_sample_factors():
    """Per-expert KronLinear: one factor set per batch element."""
    from repro.core.layers import kron_linear_apply_batched

    b = 3
    spec = KronLinearSpec.balanced(16, 16, n_factors=2, use_bias=True)
    per = [
        kron_linear_init(jax.random.PRNGKey(i), spec) for i in range(b)
    ]
    params = {
        "factors": tuple(
            jnp.stack([p["factors"][i] for p in per])
            for i in range(len(spec.ps))
        ),
        "bias": jnp.stack([p["bias"] + i for i, p in enumerate(per)]),
    }
    x = jax.random.normal(jax.random.PRNGKey(9), (b, 4, 16))
    y = kron_linear_apply_batched(params, x)
    assert y.shape == (b, 4, 16)
    for i in range(b):
        want = x[i] @ kron_linear_materialize(per[i]) + params["bias"][i]
        np.testing.assert_allclose(y[i], want, rtol=1e-5, atol=1e-5)
