"""Training substrate: loss decreases, microbatch equivalence, optimizer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models.config import reduced
from repro.optim import OptConfig, lr_at, opt_init, opt_update
from repro.train import make_train_step, train_state_init


def _tiny_cfg():
    return reduced(get_config("qwen3_4b"), n_layers=2, d_model=32, n_heads=2,
                   n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
                   vocab_pad_multiple=32, dtype="float32")


def test_loss_decreases():
    cfg = _tiny_cfg()
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, decay_steps=100, clip_norm=1.0)
    state = train_state_init(cfg, opt_cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch=8)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    losses = []
    for i in range(30):
        toks, labels = data.global_batch(i)
        state, metrics = step(state, {"tokens": toks, "labels": labels})
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
    assert np.isfinite(losses).all()


def test_microbatch_accumulation_matches_full_batch():
    cfg = _tiny_cfg()
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
    state = train_state_init(cfg, opt_cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=8)
    toks, labels = data.global_batch(0)
    batch = {"tokens": toks, "labels": labels}

    s1, m1 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=1))(state, batch)
    s4, m4 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=4))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


def test_lr_schedule():
    cfg = OptConfig(lr=1.0, warmup_steps=10, decay_steps=110, min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr_at(cfg, jnp.int32(110))) == pytest.approx(0.1)
    assert 0.1 < float(lr_at(cfg, jnp.int32(60))) < 1.0


@pytest.mark.parametrize("compress", [None, "bf16", "int8"])
def test_optimizer_convergence_quadratic(compress):
    """AdamW (with and without compressed grads) minimizes a quadratic."""
    opt_cfg = OptConfig(lr=0.1, warmup_steps=0, decay_steps=1000,
                        weight_decay=0.0, compress=compress)
    params = {"w": jnp.ones((8, 8)) * 5.0}
    state = opt_init(params, opt_cfg)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        return opt_update(grads, state, params, opt_cfg)

    for _ in range(200):
        params, state, _ = step(params, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


def test_bf16_optimizer_state_dtype():
    opt_cfg = OptConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    state = opt_init(params, opt_cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((4, 4))}
    _, new_state, _ = opt_update(grads, state, params, opt_cfg)
    assert new_state["v"]["w"].dtype == jnp.bfloat16


def test_data_determinism_and_shard_slicing():
    data = SyntheticLM(vocab=100, seq_len=16, batch=8, seed=3)
    t1, l1 = data.global_batch(5)
    t2, l2 = data.global_batch(5)
    np.testing.assert_array_equal(t1, t2)
    # labels are next-token
    np.testing.assert_array_equal(np.asarray(t1[:, 1:]), np.asarray(l1[:, :-1]))
    # host slices tile the global batch
    a, _ = data.host_slice(5, 0, 2)
    b, _ = data.host_slice(5, 1, 2)
    np.testing.assert_array_equal(np.concatenate([a, b]), np.asarray(t1))
