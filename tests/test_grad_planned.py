"""Gradients through the PLANNED Kron-Matmul path.

Covers the PR-1 acceptance criteria:
  * jax.grad of kron_matmul matches dense-oracle and numerical gradients for
    non-uniform (P_i, Q_i) shapes, on both xla and pallas (interpret)
    backends;
  * with a plan active, the traced backward executes ZERO unfused per-factor
    fallbacks — every chain op goes through the fused stage dispatchers.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, fastkron
from repro.core import kron as K
from repro.core.kron import KronProblem
from repro.kernels import emit, ops

jax.config.update("jax_enable_x64", True)


def make_problem(seed, m, ps, qs, dtype=jnp.float64):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ps) + 1)
    x = jax.random.normal(keys[0], (m, math.prod(ps))).astype(dtype)
    factors = tuple(
        jax.random.normal(k, (p, q)).astype(dtype)
        for k, p, q in zip(keys[1:], ps, qs)
    )
    return x, factors


NONUNIFORM_CASES = [
    (4, (4, 2, 3), (3, 2, 4)),
    (8, (8, 2, 4), (2, 8, 4)),
    (2, (2, 2, 2, 2), (3, 2, 2, 3)),
    (3, (5, 3), (2, 7)),
    (6, (52,), (50,)),
]


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("m,ps,qs", NONUNIFORM_CASES)
def test_planned_grads_match_dense_oracle(backend, m, ps, qs):
    x, factors = make_problem(0, m, ps, qs)

    def loss_kron(x, factors):
        y = fastkron.kron_matmul(x, factors, backend=backend)
        return jnp.sum(y * jnp.sin(y))

    def loss_dense(x, factors):
        y = x @ K.kron_matrix(factors)
        return jnp.sum(y * jnp.sin(y))

    gx1, gf1 = jax.grad(loss_kron, argnums=(0, 1))(x, factors)
    gx2, gf2 = jax.grad(loss_dense, argnums=(0, 1))(x, factors)
    np.testing.assert_allclose(gx1, gx2, rtol=1e-9, atol=1e-9)
    for a, b in zip(gf1, gf2):
        np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_planned_grads_match_numerical(backend):
    """Central-difference check of d(loss)/d(x) and d(loss)/d(F^i)."""
    m, ps, qs = 3, (3, 2), (2, 4)
    x, factors = make_problem(1, m, ps, qs)

    def loss(x, factors):
        return jnp.sum(jnp.tanh(fastkron.kron_matmul(x, factors, backend=backend)))

    gx, gf = jax.grad(loss, argnums=(0, 1))(x, factors)
    eps = 1e-6

    def num_grad(f, arr):
        out = np.zeros_like(np.asarray(arr))
        flat = np.asarray(arr).ravel()
        for i in range(flat.size):
            dv = np.zeros_like(flat)
            dv[i] = eps
            d = dv.reshape(arr.shape)
            out.ravel()[i] = (f(arr + d) - f(arr - d)) / (2 * eps)
        return out

    np.testing.assert_allclose(
        gx, num_grad(lambda a: float(loss(a, factors)), x), rtol=1e-5, atol=1e-6
    )
    for i in range(len(factors)):
        def f_of(fi, i=i):
            fs = factors[:i] + (fi,) + factors[i + 1 :]
            return float(loss(x, fs))

        np.testing.assert_allclose(
            gf[i], num_grad(f_of, factors[i]), rtol=1e-5, atol=1e-6
        )


def test_grad_wrt_x_only_skips_factor_grads():
    """symbolic_zeros: when factors are closed-over constants, the backward
    returns exact zeros for them without running the factor-grad stage
    backward (emit.run_stage_grad)."""
    x, factors = make_problem(2, 4, (4, 4), (4, 4))
    calls = []
    orig = emit.run_stage_grad
    try:
        emit.run_stage_grad = lambda *a, **k: calls.append(1) or orig(*a, **k)
        gx = jax.grad(lambda x: fastkron.kron_matmul(x, factors).sum())(x)
    finally:
        emit.run_stage_grad = orig
    assert not calls, "factor-grad stage ran despite unperturbed factors"
    want = jax.grad(lambda x: jnp.sum(x @ K.kron_matrix(factors)))(x)
    np.testing.assert_allclose(gx, want, rtol=1e-9, atol=1e-9)


class _OpCounter:
    """Counts the engine's calls into the unified emitter (and any per-factor
    sliced fallbacks through ops) during tracing.  Chain instructions are
    keyed by their data-flow direction: ``chain_fwd`` is the forward /
    remat template, ``chain_bwd`` the transposed one, ``stage_grad`` the
    one-kernel factor-gradient stage backward."""

    def __init__(self):
        self.counts = {
            "sliced_multiply": 0,
            "sliced_multiply_t": 0,
            "chain_fwd": 0,
            "chain_bwd": 0,
            "stage_grad": 0,
        }

    def __enter__(self):
        self._orig_stage = emit.run_stage
        self._orig_grad = emit.run_stage_grad
        self._orig_ops = {
            n: getattr(ops, n) for n in ("sliced_multiply", "sliced_multiply_t")
        }

        def stage(y, fs, instr, *a, _o=self._orig_stage, **k):
            key = "chain_fwd" if instr.direction == "fwd" else "chain_bwd"
            self.counts[key] += 1
            return _o(y, fs, instr, *a, **k)

        def grad(*a, _o=self._orig_grad, **k):
            self.counts["stage_grad"] += 1
            return _o(*a, **k)

        emit.run_stage = stage
        emit.run_stage_grad = grad
        for n in self._orig_ops:
            def wrapper(*a, _n=n, **k):
                self.counts[_n] += 1
                return self._orig_ops[_n](*a, **k)

            setattr(ops, n, wrapper)
        return self.counts

    def __exit__(self, *exc):
        emit.run_stage = self._orig_stage
        emit.run_stage_grad = self._orig_grad
        for n, fn in self._orig_ops.items():
            setattr(ops, n, fn)


def test_planned_backward_has_zero_unfused_fallbacks():
    """Acceptance: with a plan whose stages are fused, tracing
    jax.grad(kron_matmul) issues NO per-factor sliced ops — every chain op
    is an emitted stage instruction (fwd, remat, and bwd)."""
    m, ps, qs = 8, (4, 4, 4), (4, 4, 4)
    x, factors = make_problem(3, m, ps, qs, dtype=jnp.float32)
    prob = KronProblem(m, ps, qs)
    plan = autotune.make_plan(prob, enable_prekron=False)
    assert all(len(st.factor_ids) > 1 for st in plan.stages), plan.describe()

    # Lower (not just trace): the op engine's forward runs behind the
    # kron_matmul primitive, whose stage loop is emitted at lowering time
    # (value_and_grad keeps the primal live so it isn't DCE'd away).
    with _OpCounter() as counts:
        jax.jit(
            jax.value_and_grad(
                lambda x, fs: fastkron.kron_matmul(x, fs, plan=plan).sum(),
                argnums=(0, 1),
            )
        ).lower(x, factors)
    assert counts["sliced_multiply"] == 0, counts
    assert counts["sliced_multiply_t"] == 0, counts
    assert counts["chain_fwd"] >= 1, counts  # primal + stage-input remat
    assert counts["stage_grad"] == len(plan.stages), counts

    # grad wrt x only: the chain cotangent runs through the TRANSPOSED
    # program (emit.transpose of the forward — no factor-grad stage at all).
    with _OpCounter() as counts:
        jax.jit(
            jax.grad(lambda x: fastkron.kron_matmul(x, factors, plan=plan).sum())
        ).lower(x)
    assert counts["sliced_multiply"] == 0, counts
    assert counts["sliced_multiply_t"] == 0, counts
    assert counts["chain_bwd"] == len(plan.stages), counts
    assert counts["stage_grad"] == 0, counts


def test_unfused_baseline_backward_unchanged():
    """plan=None keeps the paper-faithful per-factor backward (the fig_bwd
    baseline): per-factor ops ARE issued."""
    x, factors = make_problem(4, 4, (4, 4), (4, 4), dtype=jnp.float32)
    calls = []
    orig = ops.sliced_multiply_t
    try:
        ops.sliced_multiply_t = lambda *a, **k: calls.append(1) or orig(*a, **k)
        jax.make_jaxpr(
            jax.grad(
                lambda x, fs: fastkron.kron_matmul(x, fs, plan=None).sum(),
                argnums=(0, 1),
            )
        )(x, factors)
    finally:
        ops.sliced_multiply_t = orig
    assert len(calls) == len(factors)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_prekron_stage_grads(backend):
    """Plans with pre-kronized stages still produce correct factor grads."""
    m, ps, qs = 4, (2, 3, 2), (3, 2, 2)
    x, factors = make_problem(5, m, ps, qs)
    plan = autotune.make_plan(
        KronProblem(m, ps, qs), enable_prekron=True, prekron_max_p=4
    )
    assert any(st.prekron for st in plan.stages), plan.describe()

    def loss_kron(x, factors):
        y = fastkron.kron_matmul(x, factors, backend=backend, plan=plan)
        return jnp.sum(y * y)

    def loss_dense(x, factors):
        y = x @ K.kron_matrix(factors)
        return jnp.sum(y * y)

    g1 = jax.grad(loss_kron, argnums=(0, 1))(x, factors)
    g2 = jax.grad(loss_dense, argnums=(0, 1))(x, factors)
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-9, atol=1e-9)
    for a, b in zip(g1[1], g2[1]):
        np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-9)


def test_pallas_backward_on_q_tiled_plan():
    """Training grads must work on the pallas backend for plans whose fused
    stages are only legal via Q-tiling (the fused one-kernel backward cannot
    hold the gradient pairs; the stage falls back to per-factor planned ops)."""
    m, ps, qs = 8, (2, 2, 2), (64, 64, 64)
    prob = KronProblem(m, ps, qs)
    plan = autotune.make_plan(prob, enable_prekron=False)
    assert any(st.t_qs is not None for st in plan.stages), plan.describe()
    x, factors = make_problem(9, m, ps, qs, dtype=jnp.float32)

    def loss(backend):
        return lambda x, fs: (
            fastkron.kron_matmul(x, fs, backend=backend, plan=plan) ** 2
        ).sum()

    want = jax.grad(
        lambda x, fs: (fastkron.kron_matmul(x, fs, plan=None) ** 2).sum(),
        argnums=(0, 1),
    )(x, factors)
    for backend in ("xla", "pallas"):
        got = jax.grad(loss(backend), argnums=(0, 1))(x, factors)
        np.testing.assert_allclose(got[0], want[0], rtol=1e-4, atol=1e-3)
        for a, b in zip(got[1], want[1]):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-2)


def test_plan_cache_key_covers_plan_kwargs(tmp_path):
    """A measured-cache hit must honor the caller's plan constraints: a plan
    cached with fusion/prekron on must not be served to a caller that
    disabled them."""
    cache = str(tmp_path / "plans.json")
    prob = KronProblem(8, (4, 4), (4, 4))
    fused = autotune.make_plan(prob, tune="measure", backend="xla", cache_path=cache)
    plain = autotune.make_plan(
        prob, tune="measure", backend="xla", cache_path=cache,
        enable_fusion=False, enable_prekron=False,
    )
    assert all(
        len(st.factor_ids) == 1 and not st.prekron for st in plain.stages
    ), (fused.describe(), plain.describe())


def test_planned_grad_under_jit_and_vmap():
    x, factors = make_problem(6, 6, (4, 4), (4, 4), dtype=jnp.float32)
    g = jax.jit(
        jax.grad(lambda x, fs: fastkron.kron_matmul(x, fs).sum(), argnums=(0, 1))
    )(x, factors)
    want = jax.grad(
        lambda x, fs: jnp.sum(x @ K.kron_matrix(fs)), argnums=(0, 1)
    )(x, factors)
    np.testing.assert_allclose(g[0], want[0], rtol=1e-5, atol=1e-5)
    for a, b in zip(g[1], want[1]):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
