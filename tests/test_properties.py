"""Property-based tests (hypothesis) for Kron-Matmul system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import kron as K
from repro.core import fastkron
from repro.core.layers import balanced_factorization

jax.config.update("jax_enable_x64", True)

SETTINGS = dict(max_examples=25, deadline=None)

dims = st.integers(min_value=1, max_value=6)


@st.composite
def kron_problems(draw, max_n=3, max_dim=6, max_m=5):
    n = draw(st.integers(1, max_n))
    ps = tuple(draw(dims) for _ in range(n))
    qs = tuple(draw(dims) for _ in range(n))
    m = draw(st.integers(1, max_m))
    seed = draw(st.integers(0, 2**31 - 1))
    keys = jax.random.split(jax.random.PRNGKey(seed), n + 1)
    x = jax.random.normal(keys[0], (m, math.prod(ps)), jnp.float64)
    factors = [
        jax.random.normal(k, (p, q), jnp.float64)
        for k, p, q in zip(keys[1:], ps, qs)
    ]
    return x, factors


@given(kron_problems())
@settings(**SETTINGS)
def test_all_algorithms_agree(prob):
    """shuffle == ftmmt == fastkron == naive for arbitrary shapes."""
    x, factors = prob
    want = K.kron_matmul_naive(x, factors)
    for fn in (K.kron_matmul_shuffle, K.kron_matmul_ftmmt, K.kron_matmul_fastkron):
        np.testing.assert_allclose(fn(x, factors), want, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(
        fastkron.kron_matmul(x, factors), want, rtol=1e-9, atol=1e-9
    )


@given(kron_problems(max_n=2), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_linearity(prob, seed):
    """Kron-Matmul is linear in X: f(aX1 + X2) = a f(X1) + f(X2)."""
    x, factors = prob
    x2 = jax.random.normal(jax.random.PRNGKey(seed), x.shape, jnp.float64)
    a = 2.5
    lhs = K.kron_matmul_fastkron(a * x + x2, factors)
    rhs = a * K.kron_matmul_fastkron(x, factors) + K.kron_matmul_fastkron(x2, factors)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-9)


@given(kron_problems(max_n=2, max_dim=4), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_composition(prob, seed):
    """(X (A1(x)A2)) (B1(x)B2) == X ((A1@B1) (x) (A2@B2))  [mixed-product]."""
    x, factors = prob
    keys = jax.random.split(jax.random.PRNGKey(seed), len(factors))
    second = [
        jax.random.normal(k, (f.shape[1], f.shape[1]), jnp.float64)
        for k, f in zip(keys, factors)
    ]
    lhs = K.kron_matmul_fastkron(K.kron_matmul_fastkron(x, factors), second)
    rhs = K.kron_matmul_fastkron(x, [a @ b for a, b in zip(factors, second)])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-9)


@given(kron_problems(max_n=3))
@settings(**SETTINGS)
def test_pair_factors_invariant(prob):
    """pair_factors never changes the computed product."""
    x, factors = prob
    paired = K.pair_factors(factors, max_p=100, max_pair_dim=10000)
    np.testing.assert_allclose(
        K.kron_matmul_fastkron(x, paired),
        K.kron_matmul_fastkron(x, factors),
        rtol=1e-9,
        atol=1e-9,
    )


@given(st.integers(1, 4096), st.integers(1, 4))
@settings(max_examples=50, deadline=None)
def test_balanced_factorization_exact(d, n):
    fs = balanced_factorization(d, n)
    assert len(fs) == n and math.prod(fs) == d


@given(kron_problems(max_n=2, max_dim=4))
@settings(max_examples=10, deadline=None)
def test_identity_factors(prob):
    """Kron of identities is identity: X (I (x) I) == X."""
    x, factors = prob
    eyes = [jnp.eye(f.shape[0], dtype=jnp.float64) for f in factors]
    np.testing.assert_allclose(
        K.kron_matmul_fastkron(x, eyes), x, rtol=1e-12, atol=1e-12
    )


@given(kron_problems(max_n=2, max_dim=4))
@settings(max_examples=10, deadline=None)
def test_transpose_vjp_consistency(prob):
    """<Y g, f(X)> == <g, X f^T(Y)> : VJP wrt X equals Kron with F^T."""
    x, factors = prob
    y = K.kron_matmul_fastkron(x, factors)
    g = jnp.ones_like(y)
    (gx,) = jax.grad(lambda x_: jnp.vdot(K.kron_matmul_fastkron(x_, factors), g), argnums=(0,))(x)
    want = K.kron_matmul_naive(g, [f.T for f in factors])
    np.testing.assert_allclose(gx, want, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# StageProgram transposition (the unified emitter's backward derivation)
# ---------------------------------------------------------------------------


@st.composite
def stage_programs(draw, max_n=3, max_dim=6, max_m=5):
    """A random planned problem, including mixed per-stage shapes and —
    whenever a small-P pair exists — PREKRON stages (prekron_max_p high
    enough that the planner actually emits them)."""
    from repro.core.autotune import lower, make_plan
    from repro.core.kron import KronProblem

    x, factors = draw(kron_problems(max_n=max_n, max_dim=max_dim, max_m=max_m))
    prekron = draw(st.booleans())
    ps = tuple(int(f.shape[0]) for f in factors)
    qs = tuple(int(f.shape[1]) for f in factors)
    plan = make_plan(
        KronProblem(int(x.shape[0]), ps, qs),
        enable_prekron=prekron,
        prekron_max_p=6,
    )
    return x, factors, lower(plan, ps, qs)


@given(stage_programs())
@settings(max_examples=20, deadline=None)
def test_program_transpose_is_vjp_xla(case):
    """emit(transpose(prog)) == the jax.vjp x-cotangent of emit(prog) for
    random shapes (mixed-shape chains and prekron stages included)."""
    from repro.kernels import emit

    x, factors, prog = case
    fwd = emit.emit(prog, backend="xla")
    y, vjp = jax.vjp(lambda x_: fwd(x_, factors), x)
    dy = jax.random.normal(jax.random.PRNGKey(7), y.shape, jnp.float64)
    (want,) = vjp(dy)
    got = emit.emit(emit.transpose(prog), backend="xla")(dy, factors)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


@given(stage_programs(max_n=2, max_dim=4, max_m=4))
@settings(max_examples=8, deadline=None)
def test_program_transpose_is_vjp_pallas_interpret(case):
    """The same property with the transposed program emitted through the
    Pallas-interpret backend (the vjp reference stays on XLA: interpret-mode
    pallas_call is not linearizable, and the engine never differentiates
    through kernels — it runs transposed programs)."""
    from repro.kernels import emit

    x, factors, prog = case
    y, vjp = jax.vjp(
        lambda x_: emit.emit(prog, backend="xla")(x_, factors), x
    )
    dy = jax.random.normal(jax.random.PRNGKey(8), y.shape, jnp.float64)
    (want,) = vjp(dy)
    got = emit.emit(emit.transpose(prog), backend="pallas")(dy, factors)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# Continuous-batching scheduler invariants (launch/scheduler.py — pure,
# device-free; see tests/test_scheduler.py for the example-based suite)
# ---------------------------------------------------------------------------

from repro.launch import scheduler as S  # noqa: E402


@st.composite
def sched_configs(draw):
    n_buckets = draw(st.integers(1, 3))
    base = draw(st.sampled_from([4, 8, 16]))
    buckets = tuple(base * (2 ** i) for i in range(n_buckets))
    return S.SchedulerConfig(
        buckets=buckets,
        max_slots=draw(st.integers(1, 6)),
        max_prefill=draw(st.integers(1, 4)),
        max_wait=draw(st.integers(0, 6)),
    )


@st.composite
def arrival_traces(draw, cfg=None):
    if cfg is None:
        cfg = draw(sched_configs())
    n = draw(st.integers(1, 20))
    reqs = []
    t = 0
    for rid in range(n):
        t += draw(st.integers(0, 3))
        # some prompts deliberately overflow the largest bucket (rejects)
        prompt_len = draw(st.integers(1, max(cfg.buckets) + 4))
        reqs.append(S.Request(
            rid=rid,
            prompt_len=prompt_len,
            max_new=draw(st.integers(1, 8)),
            arrival=t,
        ))
    return cfg, tuple(reqs)


@given(arrival_traces(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_scheduler_conservation(case, seed):
    """For ANY arrival trace: after every step each request is in exactly
    one of queued/prefilling/decoding/finished/rejected (S.audit raises on
    double-occupancy), nothing is lost, and the run terminates."""
    cfg, reqs = case
    res = S.simulate(cfg, reqs, seed=seed, check=True)  # audits every step
    assert len(res.metrics) == len(reqs)
    for rid, m in res.metrics.items():
        assert "finish_step" in m, f"rid {rid} lost (never finished/rejected)"


@given(arrival_traces(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_scheduler_no_token_before_prefill(case, seed):
    """No decode action may include a request before its prefill launched,
    and the first token never precedes arrival."""
    cfg, reqs = case
    res = S.simulate(cfg, reqs, seed=seed)
    prefilled_at: dict[int, int] = {}
    for t, act in res.trace:
        if act[0] == "prefill":
            for rid in act[2]:
                assert rid not in prefilled_at
                prefilled_at[rid] = t
        elif act[0] == "decode":
            for rid in act[1]:
                assert rid in prefilled_at and prefilled_at[rid] < t, (
                    f"rid {rid} decoded at step {t} before its prefill"
                )
    for rid, m in res.metrics.items():
        if "first_token_step" in m:
            assert m["first_token_step"] >= m["arrival_step"]


@given(arrival_traces(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_scheduler_output_independent_of_cobatching(case, seed):
    """Per-request output is independent of what it was co-batched with:
    the same trace served with max_slots=1/max_prefill=1 (every request
    effectively batch-of-one) yields identical per-request tokens."""
    import dataclasses as dc

    cfg, reqs = case
    packed = S.simulate(cfg, reqs, seed=seed)
    solo_cfg = dc.replace(cfg, max_slots=1, max_prefill=1)
    solo = S.simulate(solo_cfg, reqs, seed=seed)
    assert packed.tokens == solo.tokens
