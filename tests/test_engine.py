"""KronOp: the unified handle-based execution API (engine PR).

Acceptance:
  * a KronOp resolves its plan at construction and matches the dense oracle
    (forward and gradients) on both backends;
  * two ops with the same signature SHARE one plan object, and the engine's
    plan memoization is bounded (no ``maxsize=None`` left on the spine);
  * every legacy ``kron_matmul*`` entry point is a deprecation shim whose
    numerics match the op path exactly (bitwise — same code path);
  * ``.out_shape`` / ``.cost()`` / ``.with_batch`` / ``.with_mesh`` behave
    as the handle API promises;
  * the batched executor runs per-sample PRE-KRONIZATION stages
    (``make_batched_plan(shared_factors=False, enable_prekron=True)``),
    forward and backward.
"""
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KronOp, engine, fastkron
from repro.core.autotune import make_batched_plan, make_plan
from repro.core.engine import kron_op_for
from repro.core.kron import KronProblem, kron_matrix
from repro.core.layers import KronLinear, KronLinearSpec, kron_linear_materialize


def _mk(seed, m, ps, qs, batch=None):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ps) + 1)
    lead = () if batch is None else (batch,)
    x = jax.random.normal(keys[0], (*lead, m, math.prod(ps)), jnp.float32)
    fs = tuple(
        jax.random.normal(k, (*lead, p, q), jnp.float32)
        for k, p, q in zip(keys[1:], ps, qs)
    )
    return x, fs


# ---------------------------------------------------------------------------
# The op itself
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize(
    "m,ps,qs",
    [(8, (4, 4), (4, 4)), (4, (4, 2, 3), (3, 2, 4)), (6, (5, 3), (2, 7))],
)
def test_op_matches_dense_oracle(backend, m, ps, qs):
    x, fs = _mk(0, m, ps, qs)
    op = KronOp(ps, qs, m=m, backend=backend)
    got = op(x, fs)
    want = x @ kron_matrix(list(fs))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert got.shape == op.out_shape(x.shape)

    gx, gf = jax.grad(lambda x, fs: (op(x, fs) ** 2).sum(), argnums=(0, 1))(x, fs)
    gx2, gf2 = jax.grad(
        lambda x, fs: ((x @ kron_matrix(list(fs))) ** 2).sum(), argnums=(0, 1)
    )(x, fs)
    np.testing.assert_allclose(gx, gx2, rtol=1e-4, atol=1e-4)
    for a, b in zip(gf, gf2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_op_resolves_plan_at_construction_and_shares_it():
    """Two ops with one signature hold ONE plan object (bounded module
    memo), and the op's own call path never re-plans."""
    op1 = KronOp((16, 16), (16, 16), m=32)
    op2 = KronOp((16, 16), (16, 16), m=32)
    assert op1 is not op2
    assert op1.plan is op2.plan
    # kron_op_for goes further: same signature -> same op object.
    assert kron_op_for((16, 16), (16, 16)) is kron_op_for((16, 16), (16, 16))


def test_engine_plan_memos_are_bounded():
    """The old unbounded lru_cache(maxsize=None) memos are gone: every cache
    on the engine spine declares a finite maxsize."""
    for cache in (
        engine._resolve_plan,
        engine._resolve_batched_plan,
        engine._kron_fn,
        engine._lowered,
        engine.kron_op_for,
    ):
        assert cache.cache_info().maxsize is not None, cache
    assert not hasattr(fastkron, "_plan_for")
    assert not hasattr(fastkron, "_build_kron_fn")
    assert not hasattr(fastkron, "_batched_plan_for")


def test_op_repeated_calls_hit_op_owned_state():
    """After the first call, the op serves plan+fn from its own tables —
    the module-level plan memo is not consulted again."""
    op = KronOp((4, 4), (4, 4))
    x, fs = _mk(1, 8, (4, 4), (4, 4))
    op(x, fs)
    before = engine._resolve_plan.cache_info()
    for _ in range(3):
        op(x, fs)
    after = engine._resolve_plan.cache_info()
    assert (after.hits, after.misses) == (before.hits, before.misses)


def test_out_shape_and_cost():
    op = KronOp((4, 4), (8, 8), m=16)
    assert op.out_shape((16, 16)) == (16, 64)
    assert op.out_shape((2, 3, 16)) == (2, 3, 64)
    with pytest.raises(ValueError):
        op.out_shape((16, 15))
    c = op.cost()
    assert c.flops == KronProblem(16, (4, 4), (8, 8)).flops
    assert c.comm_elems_per_device == 0 and c.rounds == 0
    # batched per-sample: B independent problems
    opb = op.with_batch(4, shared_factors=False)
    assert opb.cost(m=16).flops == 4 * KronProblem(16, (4, 4), (8, 8)).flops
    assert opb.out_shape((4, 16, 16)) == (4, 16, 64)
    with pytest.raises(ValueError):
        opb.out_shape((3, 16, 16))  # wrong leading batch


def test_with_batch_and_with_mesh_derivations():
    op = KronOp((4, 4), (4, 4))
    opb = op.with_batch(8, shared_factors=False)
    assert (opb.batch, opb.shared_factors) == (8, False)
    assert (opb.ps, opb.qs) == (op.ps, op.qs)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opd = op.with_mesh(mesh)
    assert opd.mesh is mesh and opd.rounds is not None
    assert opd.cost(m=8).rounds == len(opd.rounds)
    # infeasible round schedule fails AT CONSTRUCTION (fail fast), not at call
    if jax.device_count() >= 2:
        bad = jax.make_mesh((1, jax.device_count()), ("data", "model"))
        ps = (3, 3)  # prod(Q)=9 never divisible by an even G_K
        if jax.device_count() % 2 == 0:
            with pytest.raises(ValueError):
                KronOp(ps, ps, mesh=bad)


def test_mesh_op_on_trivial_mesh_matches_local():
    """The mesh spine is the same math: a 1x1 mesh reproduces the local op
    bit-for-bit shapes/numerics (collectives degenerate away)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x, fs = _mk(2, 8, (4, 4), (4, 4))
    op = KronOp((4, 4), (4, 4), mesh=mesh)
    got = op(x, fs)
    want = KronOp((4, 4), (4, 4))(x, fs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Deprecation shims (satellite): warn once, numerics identical
# ---------------------------------------------------------------------------


def test_legacy_shims_warn_once_and_match_op_exactly():
    x, fs = _mk(3, 8, (4, 4), (4, 4))
    xb, fb = _mk(4, 8, (4, 4), (4, 4), batch=4)
    op = KronOp((4, 4), (4, 4))
    opb = op.with_batch(4, shared_factors=False)

    engine._DEPRECATION_WARNED.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        y1 = fastkron.kron_matmul(x, fs)
        y1_again = fastkron.kron_matmul(x, fs)
        y2 = fastkron.kron_matmul_batched(xb, fb, shared_factors=False)
    dep = [d for d in w if issubclass(d.category, DeprecationWarning)]
    names = [str(d.message).split(" ", 1)[0] for d in dep]
    # one warning per entry point, not per call
    assert names.count("kron_matmul") == 1, names
    assert names.count("kron_matmul_batched") == 1, names
    assert all("KronOp" in str(d.message) for d in dep)
    # the shim IS the op path: bitwise-identical results
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(op(x, fs)))
    np.testing.assert_array_equal(np.asarray(y1_again), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(opb(xb, fb)))


def test_distributed_shims_warn_once():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.core import distributed

    x, fs = _mk(5, 8, (4, 4), (4, 4))
    engine._DEPRECATION_WARNED.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        y = distributed.kron_matmul_distributed(x, fs, mesh)
        distributed.kron_matmul_distributed(x, fs, mesh)
    dep = [d for d in w if issubclass(d.category, DeprecationWarning)]
    assert len(dep) == 1 and "kron_matmul_distributed" in str(dep[0].message)
    want = KronOp((4, 4), (4, 4), mesh=mesh)(x, fs)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))


# ---------------------------------------------------------------------------
# Per-sample pre-kronization (satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_batched_per_sample_prekron_stage(backend):
    """make_batched_plan(shared_factors=False, enable_prekron=True) emits
    prekron stages and the batched executor runs them: forward AND full
    gradients match the looped dense reference."""
    b, m, ps, qs = 4, 8, (4, 4, 4), (4, 4, 4)
    plan = make_batched_plan(
        KronProblem(m, ps, qs), b, shared_factors=False, enable_prekron=True,
        prekron_max_p=4,
    )
    assert any(st.prekron for st in plan.stages), plan.describe()
    x, fb = _mk(6, m, ps, qs, batch=b)
    op = KronOp(ps, qs, batch=b, shared_factors=False, backend=backend, plan=plan)

    def loss(x, fb):
        return (op(x, fb) ** 2).sum()

    def loss_ref(x, fb):
        t = 0.0
        for i in range(b):
            t = t + ((x[i] @ kron_matrix([f[i] for f in fb])) ** 2).sum()
        return t

    np.testing.assert_allclose(
        np.asarray(op(x, fb)),
        np.stack([np.asarray(x[i] @ kron_matrix([f[i] for f in fb]))
                  for i in range(b)]),
        rtol=1e-4, atol=1e-4,
    )
    got = jax.grad(loss, argnums=(0, 1))(x, fb)
    want = jax.grad(loss_ref, argnums=(0, 1))(x, fb)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-4, atol=1e-3)
    for a, wf in zip(got[1], want[1]):
        np.testing.assert_allclose(a, wf, rtol=1e-4, atol=1e-3)
    # dx-only (symbolic-zeros) path through the prekron transposed branch
    gx = jax.grad(lambda x: loss(x, fb))(x)
    np.testing.assert_allclose(gx, want[0], rtol=1e-4, atol=1e-3)


def test_batched_plan_prekron_passthrough():
    """The per-sample planner honors enable_prekron instead of hard-coding
    it off (the executor now has the per-sample explicit-kron stage)."""
    prob = KronProblem(8, (4, 4, 4), (4, 4, 4))
    off = make_batched_plan(prob, 4, shared_factors=False)
    on = make_batched_plan(
        prob, 4, shared_factors=False, enable_prekron=True, prekron_max_p=4
    )
    assert not any(st.prekron for st in off.stages)
    assert any(st.prekron for st in on.stages)


# ---------------------------------------------------------------------------
# KronLinear holds its op
# ---------------------------------------------------------------------------


def test_kron_linear_module_holds_op():
    spec = KronLinearSpec((4, 4), (4, 4), use_bias=True)
    lin = KronLinear(jax.random.PRNGKey(0), spec)
    # plan built at init and shared with every other op of this signature
    assert lin.op.plan is kron_op_for(spec.ps, spec.qs).plan
    x = jax.random.normal(jax.random.PRNGKey(1), (8, spec.d_in))
    w = kron_linear_materialize(lin.params)
    np.testing.assert_allclose(
        lin(x), x @ w + lin.params["bias"], rtol=1e-4, atol=1e-4
    )
    # batches collapse into the op's row axis — same module, any rank
    xb = jax.random.normal(jax.random.PRNGKey(2), (2, 8, spec.d_in))
    np.testing.assert_allclose(
        lin(xb), xb @ w + lin.params["bias"], rtol=1e-4, atol=1e-4
    )


def test_prebuild_kron_ops_warms_the_shared_plan_memo():
    """Serving prebuild resolves the (batch*seq_len)-row plan up front: the
    layer apply's own plan lookup must be a HIT, not a fresh tile search."""
    from dataclasses import dataclass

    from repro.train.steps import prebuild_kron_ops

    @dataclass
    class Cfg:
        kron_ffn: bool = True
        kron_factors: int = 2
        d_model: int = 64
        d_ff: int = 256
        dtype: str = "float32"

    engine._resolve_plan.cache_clear()
    ops = prebuild_kron_ops(Cfg(), batch=4, seq_len=8)
    assert len(ops) == 2
    assert engine._resolve_plan.cache_info().misses >= 2  # plans built NOW
    before = engine._resolve_plan.cache_info().misses
    # what kron_linear_apply resolves at trace time for (4, 8, d) inputs:
    for op in ops:
        engine._resolve_plan(
            4 * 8, op.ps, op.qs, 4, "auto", engine._auto_prekron(),
            "analytic", None,
        )
    assert engine._resolve_plan.cache_info().misses == before  # all hits


def test_with_batch_drops_the_row_hint():
    """m means total rows on a single op but rows-per-sample on a batched
    op — the derivation must not eagerly plan for the wrong shape."""
    op = KronOp((4, 4), (4, 4), m=32)
    opb = op.with_batch(4, shared_factors=False)
    assert opb._m is None
    assert not opb._plans  # nothing eagerly resolved for a bogus shape


def test_op_describe_smoke():
    op = KronOp((4, 4), (4, 4), batch=8, shared_factors=False)
    d = op.describe()
    assert "KronOp" in d and "per-sample" in d and "t_b" in d
