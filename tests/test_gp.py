"""GP (SKI) substrate: CG correctness + backend equivalence (paper §6.4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.gp import (
    BatchedKronKernel,
    KronKernel,
    conjugate_gradient,
    gp_train_epoch,
    gp_train_epoch_batched,
    interp_matrix,
    rbf_kernel_1d,
)


def _kernel(p=8, d=2, ls=0.3):
    grid = jnp.linspace(0, 1, p)
    return KronKernel(tuple(rbf_kernel_1d(grid, ls) for _ in range(d)))


def test_kron_kernel_matmul_matches_dense():
    k = _kernel()
    v = jax.random.normal(jax.random.PRNGKey(0), (4, k.dim))
    want = v @ jnp.kron(k.factors[0], k.factors[1])
    np.testing.assert_allclose(k.matmul(v), want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(k.matmul(v, backend="shuffle"), want,
                               rtol=1e-4, atol=1e-5)


def test_cg_solves_spd_system():
    k = _kernel(p=6, d=2)
    noise = 0.5
    dense = jnp.kron(k.factors[0], k.factors[1]) + noise * jnp.eye(k.dim)
    b = jax.random.normal(jax.random.PRNGKey(1), (3, k.dim))
    x, resid = conjugate_gradient(
        lambda r: r @ dense, b, iters=60
    )
    np.testing.assert_allclose(x @ dense, b, rtol=1e-3, atol=1e-3)
    assert float(resid.max()) < 1e-2


def test_gp_epoch_backends_agree():
    k = _kernel(p=8, d=3)
    v = jax.random.normal(jax.random.PRNGKey(2), (16, k.dim))  # paper M=16
    x1, _ = gp_train_epoch(k, v, backend="fastkron")
    x2, _ = gp_train_epoch(k, v, backend="shuffle")
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                               rtol=1e-4, atol=1e-5)


def test_batched_kernel_matmul_matches_per_kernel():
    """Multi-kernel batched MVM == per-kernel loop (per-sample factors)."""
    kernels = [_kernel(p=6, d=2, ls=0.2 + 0.1 * i) for i in range(4)]
    bk = BatchedKronKernel.stack(kernels)
    assert bk.batch == 4 and bk.dim == kernels[0].dim
    v = jax.random.normal(jax.random.PRNGKey(4), (4, 8, bk.dim))
    got = bk.matmul(v)
    for i, k in enumerate(kernels):
        np.testing.assert_allclose(
            got[i], k.matmul(v[i]), rtol=1e-5, atol=1e-5
        )


def test_batched_gp_epoch_matches_per_kernel_solves():
    """One batched CG over B kernels == B independent gp_train_epoch solves."""
    kernels = [_kernel(p=6, d=2, ls=0.25 + 0.05 * i) for i in range(3)]
    bk = BatchedKronKernel.stack(kernels)
    v = jax.random.normal(jax.random.PRNGKey(5), (3, 8, bk.dim))
    x_b, r_b = gp_train_epoch_batched(bk, v, noise=0.3, cg_iters=12)
    for i, k in enumerate(kernels):
        x_i, r_i = gp_train_epoch(k, v[i], noise=0.3, cg_iters=12)
        np.testing.assert_allclose(x_b[i], x_i, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(r_b[i], r_i, rtol=1e-3, atol=1e-5)


def test_interp_matrix_partition_of_unity():
    x = jax.random.uniform(jax.random.PRNGKey(3), (32, 2))
    w = interp_matrix(x, [8, 8])
    assert w.shape == (32, 64)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert float((w >= 0).mean()) == 1.0
