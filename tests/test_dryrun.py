"""Dry-run machinery test on a small (2x4) mesh in a subprocess.

Validates the full path — build_cell -> jit(in/out shardings) -> lower ->
compile -> trip-weighted roofline record — without the 512-device
production mesh (exercised by launch/dryrun.py itself; its 66/66 log is
in experiments/).
"""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import repro.launch.mesh as mesh_mod

def small_mesh(*, multi_pod=False):
    assert not multi_pod
    return jax.make_mesh((2, 4), ("data", "model"))

mesh_mod.make_production_mesh = small_mesh
from repro.launch import dryrun
rec = dryrun.run_cell("mamba2_130m", "decode_32k", False, None)
assert rec["chips"] == 8
assert rec["per_device"]["hlo_flops"] > 0
assert rec["per_device"]["hlo_bytes"] > 0
assert rec["roofline"]["dominant"] in ("compute_s", "memory_s", "collective_s")
assert rec["fits_hbm"]
print("DRYRUN-TEST-OK", json.dumps(rec["roofline"]["dominant"]))
"""


@pytest.mark.slow
def test_dryrun_cell_on_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    assert "DRYRUN-TEST-OK" in proc.stdout
