"""Per-architecture smoke tests: reduced same-family configs, one forward +
one grad step + prefill/decode consistency on CPU.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model as M
from repro.models.config import reduced

BATCH, SEQ = 2, 32


def _reduced(arch):
    cfg = reduced(get_config(arch), dtype="float32")
    return cfg


def _inputs(cfg, key, batch=BATCH, seq=SEQ):
    k1, k2 = jax.random.split(key)
    n_fe = cfg.n_frontend_tokens
    tokens = jax.random.randint(k1, (batch, seq - n_fe), 0, cfg.vocab)
    embeds = (
        jax.random.normal(k2, (batch, n_fe, cfg.d_model), jnp.float32)
        if n_fe
        else None
    )
    return tokens, embeds


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens, embeds = _inputs(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(lambda p, t, e: M.forward(cfg, p, t, e))(
        params, tokens, embeds
    )
    assert logits.shape == (BATCH, SEQ, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step_finite(arch):
    cfg = _reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens, embeds = _inputs(cfg, jax.random.PRNGKey(1))
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, aux = M.forward(cfg, p, tokens, embeds)
        n_fe = cfg.n_frontend_tokens
        lg = logits[:, n_fe:, :]
        ll = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # some gradient must be nonzero
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """logits from [prefill(t<n) + decode(t_n)] == forward(all)[n]."""
    cfg = _reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens, embeds = _inputs(cfg, jax.random.PRNGKey(1))
    seq = tokens.shape[1] + cfg.n_frontend_tokens
    max_len = seq + 4

    full_logits, _ = M.forward(cfg, params, tokens, embeds)

    # prefill on all but the last token, then decode it
    pre_tokens = tokens[:, :-1]
    pre_logits, cache = M.prefill(cfg, params, pre_tokens, max_len, embeds)
    np.testing.assert_allclose(
        np.asarray(pre_logits),
        np.asarray(full_logits[:, :-1]),
        rtol=2e-3,
        atol=2e-3,
    )
    last = tokens[:, -1:]
    dec_logits, _ = M.decode_step(
        cfg, params, cache, last, jnp.int32(seq - 1)
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]),
        np.asarray(full_logits[:, -1]),
        rtol=2e-3,
        atol=2e-3,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_multi_step_decode_finite(arch):
    cfg = _reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens, embeds = _inputs(cfg, jax.random.PRNGKey(1))
    max_len = SEQ + 8
    _, cache = M.prefill(cfg, params, tokens, max_len, embeds)
    step = jax.jit(
        lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos)
    )
    tok = tokens[:, -1:]
    for i in range(3):
        logits, cache = step(params, cache, tok, jnp.int32(SEQ + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        tok = jnp.clip(tok, 0, cfg.vocab - 1)
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_param_count_sane():
    # full-size param counts should be near the public numbers
    cfg = get_config("qwen2_5_32b")
    n = cfg.param_count()
    assert 30e9 < n < 36e9, n
    cfg = get_config("jamba_1_5_large_398b")
    assert 370e9 < cfg.param_count() < 420e9
    assert 80e9 < cfg.param_count(active_only=True) < 110e9
    cfg = get_config("mamba2_130m")
    assert 0.1e9 < cfg.param_count() < 0.2e9


def test_layer_plans():
    jamba = get_config("jamba_1_5_large_398b")
    plan = jamba.layer_plan()
    assert sum(1 for s in plan if s.kind == "attn") == 9  # 1:7 interleave
    assert sum(1 for s in plan if s.moe) == 36  # every other layer
    assert jamba.period == 8 and jamba.n_periods == 9

    ds = get_config("deepseek_moe_16b")
    plan = ds.layer_plan()
    assert not plan[0].moe and all(s.moe for s in plan[1:])
    assert ds.prelude_len == 1 and ds.n_periods == 27

    m2 = get_config("mamba2_130m")
    assert all(s.kind == "mamba" for s in m2.layer_plan())
