"""Mamba2/SSD unit tests: chunked scan vs naive per-step recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import MambaConfig, ModelConfig
from repro.models.ssm import (
    mamba_cache_init,
    mamba_decode,
    mamba_forward,
    mamba_init,
    _proj_conv,
    _expand_groups,
)


def _cfg(chunk=8, d_state=16, head_dim=16, n_groups=1):
    return ModelConfig(
        name="t", family="ssm", n_layers=1, d_model=32, n_heads=0,
        n_kv_heads=0, d_ff=0, vocab=32,
        mamba=MambaConfig(d_state=d_state, d_conv=4, expand=2,
                          head_dim=head_dim, n_groups=n_groups, chunk=chunk),
        dtype="float32",
    )


def _naive_ssd(cfg, p, x):
    """Literal per-step recurrence: h_t = exp(dt A) h + dt B (x) x; y = C.h."""
    mc = cfg.mamba
    b, s, _ = x.shape
    din = mc.d_inner(cfg.d_model)
    nh = mc.n_heads(cfg.d_model)
    z, xh, bh, ch, dt, _ = _proj_conv(cfg, p, x)
    bh = _expand_groups(bh, nh).astype(jnp.float32)
    ch = _expand_groups(ch, nh).astype(jnp.float32)
    xh = xh.astype(jnp.float32)
    a = -jnp.exp(p["a_log"])
    h = jnp.zeros((b, nh, mc.d_state, mc.head_dim))
    ys = []
    for t in range(s):
        dec = jnp.exp(dt[:, t] * a)  # (B,H)
        h = h * dec[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhnp", dt[:, t], bh[:, t], xh[:, t]
        )
        ys.append(jnp.einsum("bhn,bhnp->bhp", ch[:, t], h) + xh[:, t] * p["d_skip"][:, None])
    y = jnp.stack(ys, axis=1).reshape(b, s, din).astype(x.dtype)
    from repro.models.common import rms_norm

    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["wo"]


@pytest.mark.parametrize("chunk,s", [(8, 32), (4, 32), (16, 16), (8, 24)])
def test_chunked_matches_naive(chunk, s):
    cfg = _cfg(chunk=chunk)
    p = mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, 32)) * 0.5
    got = mamba_forward(cfg, p, x)
    want = _naive_ssd(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_multi_group_broadcast():
    cfg = _cfg(n_groups=2, head_dim=8)  # d_inner=64 -> 8 heads, 2 groups
    p = mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32)) * 0.5
    got = mamba_forward(cfg, p, x)
    want = _naive_ssd(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_decode_chain_matches_forward():
    cfg = _cfg(chunk=8)
    p = mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    full = mamba_forward(cfg, p, x)
    cache = mamba_cache_init(cfg, 2, jnp.float32)
    outs = []
    for t in range(16):
        y, cache = mamba_decode(cfg, p, x[:, t : t + 1], cache)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=2e-4, atol=2e-4)


def test_prefill_state_continues_decode():
    cfg = _cfg(chunk=8)
    p = mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 32)) * 0.5
    # forward on first 16 with state, then decode 8 more
    _, (conv_tail, h) = mamba_forward(cfg, p, x[:, :16], return_state=True)
    from repro.models.ssm import MambaCache

    cache = MambaCache(conv=conv_tail, h=h)
    outs = []
    for t in range(16, 24):
        y, cache = mamba_decode(cfg, p, x[:, t : t + 1], cache)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    want = mamba_forward(cfg, p, x)[:, 16:]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_grad_finite():
    cfg = _cfg(chunk=8)
    p = mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))

    def loss(p):
        return jnp.sum(mamba_forward(cfg, p, x) ** 2)

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
