"""Multi-device chaos driver: fault-injected distributed Kron-Matmul (PR 6).

Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(set by tests/test_distributed.py).  Prints 'OK <name>' per passing check;
exits nonzero on failure.

Checks, per the acceptance criteria:
  * ``chaos.inject("round_chain")`` forces the per-factor VMEM fallback in
    ``distributed.py::_local_multiply_round`` (previously only reachable by
    accident): the degraded result is BITWISE-identical to the unfaulted
    distributed reference, the compiled HLO still has exactly ONE all-to-all
    per relocation round (the fallback is strictly local), and the typed
    error is recorded in guard health;
  * ``chaos.inject("collective")`` fails the relocation itself: the KronOp
    mesh ladder degrades to local execution, records ``CollectiveError``,
    and still matches the unfaulted mesh result;
  * ``chaos.inject("slab_collective")`` fails one slab's all_to_all inside
    a pipelined round (PR 10): the three-rung ladder degrades slabbed ->
    serial rounds with BITWISE recovery (the serial schedule is immune to
    the slab site), and with the serial relocation failing too it degrades
    the rest of the way to local execution.
"""
import math
import os
import sys
import warnings

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import engine  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    kron_matmul_batched_distributed,
    plan_rounds,
    sharded_input_batched,
)
from repro.runtime import chaos, guard  # noqa: E402
from repro.runtime.hlo_analysis import collective_stats  # noqa: E402

G_M, G_K = 2, 4
B, M, PS, QS = 8, 8, (4, 4, 4), (4, 4, 4)


def _mk(seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(PS) + 1)
    x = jax.random.normal(keys[0], (B, M, math.prod(PS)), jnp.float32)
    fs = tuple(
        jax.random.normal(k, (B, p, q), jnp.float32)
        for k, p, q in zip(keys[1:], PS, QS)
    )
    return x, fs


def main() -> None:
    devs = jax.devices()
    assert len(devs) == 8, f"expected 8 devices, got {len(devs)}"
    mesh = jax.make_mesh((G_M, G_K), ("data", "model"))
    x, fs = _mk(seed=3)
    xs = sharded_input_batched(x, mesh)
    rounds = plan_rounds(
        math.prod(PS) // G_K, list(reversed(PS)), list(reversed(QS)), G_K
    )

    # --- round_chain chaos: the per-factor VMEM fallback, on purpose -------
    ref = kron_matmul_batched_distributed(xs, fs, mesh, shared_factors=False)
    guard.reset_health()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.GuardWarning)
        with chaos.inject("round_chain") as specs:
            got = kron_matmul_batched_distributed(
                xs, fs, mesh, shared_factors=False
            )
    assert specs[0].fired >= len(rounds), (specs[0].fired, rounds)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    events = guard.health_report()["events"]
    assert events.get("round_per_factor", 0) >= len(rounds), events
    assert events.get("round_per_factor:VmemOverflowError", 0) >= 1, events
    print(f"OK round-chain-fallback bitwise rounds={len(rounds)}")

    # --- degraded rounds still pay ONE collective per round ----------------
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.GuardWarning)
        with chaos.inject("round_chain"):
            st = collective_stats(
                jax.jit(
                    lambda x, fs: kron_matmul_batched_distributed(
                        x, fs, mesh, shared_factors=False
                    )
                ).lower(xs, fs).compile().as_text()
            )
    assert st.count_by_op.get("all-to-all", 0) == len(rounds), (
        f"degraded path must keep one all-to-all per round "
        f"({len(rounds)} rounds), got {st.count_by_op}"
    )
    print(f"OK collective-count degraded={st.count_by_op.get('all-to-all')}")

    # --- collective chaos: the mesh ladder degrades to local execution -----
    op = engine.kron_op_for(
        PS, QS, batch=B, m=M, shared_factors=False, mesh=mesh
    )
    mesh_ref = op(xs, fs)
    guard.reset_health()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.GuardWarning)
        with chaos.inject("collective:times=1"):
            got_local = op(xs, fs)
    np.testing.assert_allclose(
        np.asarray(mesh_ref), np.asarray(got_local), rtol=1e-5, atol=1e-5
    )
    entries = [(k, h) for k, h in guard.health_entries() if k[0] == "mesh"]
    assert entries, "mesh ladder recorded no health entry"
    [(key, h)] = entries
    assert h.errors.get("CollectiveError") == 1, h.errors
    assert h.degraded_calls == 1 and h.calls == 1, h.summary()
    assert "guard[" in op.describe(), op.describe()
    # injection exhausted: the next call runs the mesh rung again, cleanly
    got_back = op(xs, fs)
    np.testing.assert_array_equal(np.asarray(mesh_ref), np.asarray(got_back))
    assert guard.health(key).consecutive == 0
    print("OK mesh-ladder-local-fallback")

    # --- slab_collective chaos: slabbed -> serial rounds, bitwise ----------
    from repro.core.distributed import sharded_input

    x1 = jax.random.normal(jax.random.PRNGKey(17), (M, math.prod(PS)))
    f1 = tuple(
        jax.random.normal(k, (p, q), jnp.float32)
        for k, p, q in zip(jax.random.split(jax.random.PRNGKey(19), len(PS)),
                           PS, QS)
    )
    x1s = sharded_input(x1, mesh)
    op_slab = engine.KronOp(PS, QS, mesh=mesh, n_slabs=2)
    slab_ref = op_slab(x1s, f1)
    guard.reset_health()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always", guard.GuardWarning)
        with chaos.inject("slab_collective:times=1") as specs:
            got_serial = op_slab(x1s, f1)
    assert specs[0].fired == 1, specs[0]
    # the serial-rounds rung is IMMUNE to the slab site: recovery is one
    # rung down, not local, and bitwise (slabbed == serial by construction)
    np.testing.assert_array_equal(np.asarray(slab_ref), np.asarray(got_serial))
    msgs = [str(w.message) for w in caught]
    assert any(
        "rung 0 (mesh-slabbed)" in m and "rung 1 (mesh-rounds)" in m
        for m in msgs
    ), msgs
    entries = [(k, h) for k, h in guard.health_entries() if k[0] == "mesh"]
    [(key, h)] = entries
    assert h.errors.get("CollectiveError") == 1, h.errors
    assert h.degraded_calls == 1 and h.calls == 1, h.summary()
    # injection exhausted: the slabbed rung runs cleanly again
    np.testing.assert_array_equal(
        np.asarray(slab_ref), np.asarray(op_slab(x1s, f1))
    )
    assert guard.health(key).consecutive == 0
    print("OK slab-ladder-serial-fallback bitwise")

    # --- slab + serial collectives both failing: all the way to local ------
    guard.reset_health()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.GuardWarning)
        with chaos.inject("slab_collective:times=1,collective:times=1"):
            got_local2 = op_slab(x1s, f1)
    np.testing.assert_allclose(
        np.asarray(slab_ref), np.asarray(got_local2), rtol=1e-5, atol=1e-5
    )
    [(key, h)] = [(k, h) for k, h in guard.health_entries() if k[0] == "mesh"]
    assert h.errors.get("CollectiveError") == 2, h.errors
    assert h.degraded_calls == 1 and h.calls == 1, h.summary()
    print("OK slab-ladder-local-fallback")

    print("ALL-OK")


if __name__ == "__main__":
    sys.exit(main())
