"""KronScope telemetry spine: spans, metrics, exports, profiling, and the
zero-overhead-off pin (docs/observability.md).

The structural contract mirrors the guard layer's (EXPERIMENTS.md
§Robustness): telemetry OFF must cost one truthiness check per site and add
NOTHING to compiled HLO — pinned here by comparing compiled text with
telemetry off, on, and off-again.  Telemetry ON must capture the whole
spine: spans nest and export as valid Chrome-trace JSON, guard/chaos
degradations land in the JSONL sink as events, and ``KronOp.profile``
reconciles measured stage times against the planner's analytic cost model.
"""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.runtime import chaos, guard, telemetry
from repro.runtime.events import EventSink, get_logger
from repro.runtime.fault import StragglerMonitor


@pytest.fixture(autouse=True)
def _fresh_state():
    guard.reset_health()
    telemetry.reset()
    yield
    guard.reset_health()
    telemetry.reset()


def _problem(ps, qs, m=16, seed=0):
    rng = np.random.RandomState(seed)
    k = int(np.prod(ps))
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    fs = tuple(
        jnp.asarray(rng.randn(p, q), jnp.float32) for p, q in zip(ps, qs)
    )
    return x, fs


def _read_jsonl(path):
    with open(path) as f:
        return [json.loads(line) for line in f]


# ---------------------------------------------------------------------------
# Spans + exports
# ---------------------------------------------------------------------------


def test_span_nesting_and_exports(tmp_path):
    jl = tmp_path / "t.jsonl"
    tr = tmp_path / "t.trace.json"
    telemetry.configure(jsonl=str(jl), trace=str(tr))
    with telemetry.span("outer", tag="a"):
        with telemetry.span("inner"):
            pass
    snap = telemetry.shutdown()
    assert snap["spans"] == 2
    assert not telemetry.active()

    # JSONL: one valid object per line; inner completed first, nested deeper
    recs = _read_jsonl(jl)
    spans = {r["name"]: r for r in recs if r["kind"] == "span"}
    assert spans["inner"]["depth"] == 1 and spans["outer"]["depth"] == 0
    assert spans["outer"]["dur"] >= spans["inner"]["dur"]
    assert spans["outer"]["attrs"] == {"tag": "a"}

    # Chrome trace: complete ("X") events with microsecond ts/dur
    trace = json.load(open(tr))
    events = trace["traceEvents"]
    assert {e["name"] for e in events} == {"outer", "inner"}
    for e in events:
        assert e["ph"] == "X"
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)


def test_span_off_is_shared_noop():
    s1 = telemetry.span("anything", x=1)
    s2 = telemetry.span("else")
    assert s1 is s2  # one shared object: no per-site allocation when off
    with s1:
        pass


def test_op_call_records_program_and_stage_spans(tmp_path):
    op = engine.KronOp((4, 4), (4, 4))
    x, fs = _problem((4, 4), (4, 4))
    telemetry.configure(jsonl=str(tmp_path / "op.jsonl"))
    op(x, fs)
    snap = telemetry.shutdown()
    hists = snap["histograms"]
    assert "span.program" in hists
    assert "span.stage" in hists


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_histogram_percentiles():
    telemetry.configure()
    for v in range(1, 101):
        telemetry.observe("lat", float(v))
    p = telemetry.percentiles("lat")
    assert p["count"] == 100 and p["min"] == 1.0 and p["max"] == 100.0
    assert p["p50"] == 50.0 and p["p95"] == 95.0 and p["p99"] == 99.0
    assert abs(p["mean"] - 50.5) < 1e-9


def test_counters_gauges_and_snapshot():
    telemetry.configure()
    telemetry.counter_inc("c", 2)
    telemetry.counter_inc("c")
    telemetry.gauge_set("g", 3.5)
    telemetry.event("ping", detail="x")
    snap = telemetry.snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["counters"]["event.ping"] == 1
    assert snap["gauges"]["g"] == 3.5
    assert snap["events"] == 1


def test_metrics_noop_when_off():
    telemetry.counter_inc("c")
    telemetry.gauge_set("g", 1.0)
    telemetry.observe("h", 1.0)
    telemetry.event("e")
    assert telemetry.percentiles("h") is None
    assert telemetry.snapshot() == {}
    assert telemetry.summary_line() == "kronscope[off]"


# ---------------------------------------------------------------------------
# Cost-model drift + KronOp.profile
# ---------------------------------------------------------------------------


def test_stage_drift_flags_outlier():
    # Stage 0 matches the whole-program calibration ratio exactly after
    # normalisation?  No: overall ratio is 11/2 = 5.5x, so stage 0 sits at
    # 1/5.5 (too fast vs its predicted share -> flagged) and stage 1 at
    # 10/5.5 = 1.8x (inside the 2x band -> clean).
    assert engine._stage_drift([1.0, 10.0], [1.0, 1.0], 2.0) == [True, False]
    # A uniform slowdown is calibration, not drift: nothing flags.
    assert engine._stage_drift([5.0, 5.0], [1.0, 1.0], 2.0) == [False, False]
    assert engine._stage_drift([], [], 2.0) == []


def test_profile_reconciles_with_cost_model():
    m, ps, qs = 32, (4, 4, 4), (4, 4, 4)
    op = engine.KronOp(ps, qs)
    x, fs = _problem(ps, qs, m=m)
    report = op.profile(x, fs, warmup=1, iters=2)
    assert len(report["stages"]) >= 1
    # stage flop accounting must agree exactly with the analytic model
    assert sum(s["flops"] for s in report["stages"]) == op.cost(m).flops
    assert report["cost_flops"] == op.cost(m).flops
    assert report["measured_s"] > 0 and report["predicted_s"] > 0
    shares = [s["share_measured"] for s in report["stages"]]
    assert abs(sum(shares) - 1.0) < 1e-9
    for s in report["stages"]:
        assert s["measured_s"] > 0 and s["predicted_s"] > 0
        assert isinstance(s["drift_flagged"], bool)
    assert report["signature"]["m"] == m
    assert report["drift_threshold"] == telemetry.DRIFT_THRESHOLD


def test_profile_stamps_registry_when_active(tmp_path):
    op = engine.KronOp((4, 4), (4, 4))
    x, fs = _problem((4, 4), (4, 4))
    telemetry.configure(jsonl=str(tmp_path / "p.jsonl"))
    op.profile(x, fs, warmup=0, iters=1)
    snap = telemetry.snapshot()
    assert snap["last_profile"] is not None
    assert snap["last_profile"]["stages"] == 1
    telemetry.shutdown()
    recs = _read_jsonl(tmp_path / "p.jsonl")
    assert any(
        r["kind"] == "event" and r["name"] == "profile" for r in recs
    )


def test_profile_unfused_raises():
    op = engine.KronOp((4, 4), (4, 4), plan=None)
    x, fs = _problem((4, 4), (4, 4))
    with pytest.raises(guard.PlanError, match="profile"):
        op.profile(x, fs)


# ---------------------------------------------------------------------------
# Guard/chaos integration: degradations land in the sink
# ---------------------------------------------------------------------------


def test_chaos_pallas_fault_emits_rung_fallback_event(tmp_path):
    # Explicit backend="pallas" keeps the pallas_lowering site reachable in
    # BOTH chaos-matrix legs: FASTKRON_FORCE_BACKEND only overrides "auto".
    op = engine.KronOp((4, 4), (4, 4), backend="pallas")
    x, fs = _problem((4, 4), (4, 4))
    ref = op(x, fs)
    guard.reset_health()
    jl = tmp_path / "chaos.jsonl"
    telemetry.configure(jsonl=str(jl))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", guard.GuardWarning)
        with chaos.inject("pallas_lowering:times=1"):
            y = op(x, fs)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(y))
    telemetry.shutdown()
    recs = _read_jsonl(jl)
    events = [r for r in recs if r["kind"] == "event"]
    names = [r["name"] for r in events]
    assert "chaos_injected" in names
    [fb] = [r for r in events if r["name"] == "rung_fallback"]
    assert fb["error"] == "LoweringError"
    assert fb["rung"] == 0
    [warned] = [r for r in events if r["name"] == "guard_warning"]
    assert "degrading" in warned["message"]


def test_health_report_merges_telemetry():
    assert "telemetry" not in guard.health_report()
    telemetry.configure()
    telemetry.counter_inc("plan_cache.hit", 4)
    report = guard.health_report()
    assert report["telemetry"]["counters"]["plan_cache.hit"] == 4


def test_describe_gains_summary_only_when_active():
    op = engine.KronOp((4, 4), (4, 4))
    assert "kronscope" not in op.describe()
    telemetry.configure()
    assert "kronscope[" in op.describe()
    telemetry.reset()
    assert "kronscope" not in op.describe()


def test_straggler_flag_becomes_event():
    telemetry.configure()
    mon = StragglerMonitor(action="callback", callback=lambda s, dt: None)
    for i in range(10):
        mon.observe(i, 1.0)
    mon.observe(10, 100.0)
    assert mon.flagged_steps
    assert telemetry.snapshot()["counters"]["event.straggler"] == 1


# ---------------------------------------------------------------------------
# Zero-overhead-off pin (the guard-style contract)
# ---------------------------------------------------------------------------


def test_telemetry_off_adds_zero_hlo():
    op = engine.KronOp((4, 4), (4, 4))
    x, fs = _problem((4, 4), (4, 4))

    def compiled_text():
        # fresh jit wrapper each call: no executable-cache aliasing between
        # the off/on/off lowering runs
        return (
            jax.jit(lambda x, fs: op(x, fs))
            .lower(x, fs)
            .compile()
            .as_text()
        )

    off_before = compiled_text()
    assert "kronscope" not in off_before

    telemetry.configure()
    on = compiled_text()
    assert "kronscope" in on  # named_scope reaches compiled metadata

    telemetry.reset()
    off_after = compiled_text()
    # bitwise-identical compiled HLO: enabling and disabling telemetry
    # leaves an untelemetered process exactly where it started
    assert off_after == off_before


def test_annotate_false_keeps_hlo_clean():
    op = engine.KronOp((4, 4), (4, 4))
    x, fs = _problem((4, 4), (4, 4))
    telemetry.configure(annotate=False)
    txt = (
        jax.jit(lambda x, fs: op(x, fs)).lower(x, fs).compile().as_text()
    )
    assert "kronscope" not in txt
    assert telemetry.snapshot()["spans"] >= 1  # host timing still on


# ---------------------------------------------------------------------------
# Event sink + logger + bench provenance
# ---------------------------------------------------------------------------


def test_event_sink_appends_valid_lines(tmp_path):
    path = tmp_path / "sink.jsonl"
    sink = EventSink(str(path))
    sink.emit({"a": 1})
    sink.emit({"b": [1, 2]})
    sink.close()
    assert _read_jsonl(path) == [{"a": 1}, {"b": [1, 2]}]
    assert sink.emitted == 2


def test_get_logger_prints_bare_message(capsys):
    get_logger("repro.fault").warning("[straggler-monitor] hello")
    assert capsys.readouterr().out == "[straggler-monitor] hello\n"


def test_bench_meta_and_old_schema_reader(tmp_path):
    from benchmarks.util import bench_meta, load_bench

    meta = bench_meta()
    for key in ("jax", "jaxlib", "device_kind", "platform", "date"):
        assert meta[key]
    assert "git_sha" in meta

    old = tmp_path / "BENCH_old.json"
    old.write_text(json.dumps({"speedup": 2.0}))
    rec = load_bench(str(old))
    assert rec["speedup"] == 2.0 and rec["meta"] == {}

    new = tmp_path / "BENCH_new.json"
    new.write_text(json.dumps({"speedup": 2.0, "meta": meta}))
    assert load_bench(str(new))["meta"]["jax"] == meta["jax"]
