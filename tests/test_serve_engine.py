"""ServeEngine against the real (reduced) model: the device side of the
continuous-batching stack (docs/serving.md).

tests/test_scheduler.py pins the pure policy; this file pins what the
engine does with it: per-request output independent of co-batching (checked
against batch-of-one runs of the SAME engine), chaos at the ``serve_admit``
site degrading through the guard ladder instead of dropping requests, and
the zero-re-plan contract (every steady-state serving shape resolved at
prewarm; the plan-memo miss counter does not move while serving).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import engine as E
from repro.launch.scheduler import Request, SchedulerConfig, poisson_trace
from repro.launch.serve import ServeEngine, batch_buckets
from repro.models import model as M
from repro.models.config import reduced
from repro.runtime import chaos, guard, telemetry


@pytest.fixture(autouse=True)
def _fresh_state():
    guard.reset_health()
    telemetry.reset()
    yield
    guard.reset_health()
    telemetry.reset()


@pytest.fixture(scope="module")
def small_model():
    # Dense reduced gemma-2b: no MoE capacity coupling across co-batched
    # rows, so per-request independence is exact, not approximate.
    cfg = reduced(get_config("gemma-2b"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


SCFG = SchedulerConfig(buckets=(8, 16), max_slots=3, max_prefill=2,
                       max_wait=3)


def _trace(n=6, seed=3):
    return poisson_trace(seed=seed, rate=0.8, n=n, prompt_lens=(2, 14),
                         max_new=(1, 5))


def test_batch_buckets_shape_set():
    assert batch_buckets(4) == (1, 2, 4)
    assert batch_buckets(3) == (1, 2, 3)
    assert batch_buckets(1) == (1,)


def test_engine_serves_trace_to_completion(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, SCFG, max_new=5)
    rep = eng.run(_trace())
    assert len(rep.metrics) == 6
    for rid, m in rep.metrics.items():
        assert m["reason"] in ("eos", "max_new")
        assert len(rep.tokens[rid]) >= 1
        assert m["arrival_wall"] <= m["first_token_wall"] <= m["finish_wall"]
    assert rep.total_tokens == sum(len(v) for v in rep.tokens.values())


def test_cobatched_output_matches_batch_of_one(small_model):
    """The acceptance property on the REAL model: tokens a request gets
    while sharing decode slots with others are bit-identical to the tokens
    it gets served alone (slots=1, prefill group of 1).  Exercises the
    per-slot position path, pad masking, and cache_take/cache_put."""
    cfg, params = small_model
    reqs = _trace()
    packed = ServeEngine(cfg, params, SCFG, max_new=5).run(reqs)
    solo_cfg = SchedulerConfig(buckets=SCFG.buckets, max_slots=1,
                               max_prefill=1, max_wait=SCFG.max_wait)
    solo = ServeEngine(cfg, params, solo_cfg, max_new=5).run(reqs)
    assert packed.tokens == solo.tokens


def test_chaos_serve_admit_degrades_not_drops(small_model, tmp_path):
    """An injected VmemOverflowError during the grouped bucket prefill must
    fall down the guard ladder to per-request prefills — a rung_fallback
    event in the telemetry sink, every request still served, and the SAME
    tokens as an uninjected run (the degraded path is a correctness
    no-op)."""
    cfg, params = small_model
    reqs = [Request(0, 6, 3, 0.0), Request(1, 7, 3, 0.0)]  # one group of 2
    want = ServeEngine(cfg, params, SCFG, max_new=3).run(reqs).tokens

    guard.reset_health()
    jl = tmp_path / "serve_chaos.jsonl"
    telemetry.configure(jsonl=str(jl))
    with chaos.inject("serve_admit:times=1") as specs:
        rep = ServeEngine(cfg, params, SCFG, max_new=3).run(reqs)
    assert specs[0].fired == 1
    assert rep.tokens == want                      # no dropped request
    assert all(m["reason"] in ("eos", "max_new")
               for m in rep.metrics.values())
    h = guard.health_report()["ops"]["'serve_admit:8'"]
    assert h["degraded_calls"] == 1
    telemetry.shutdown()
    events = [json.loads(l) for l in open(jl)]
    fallbacks = [e for e in events
                 if e.get("name") == "rung_fallback"
                 and "serve_admit" in e.get("key", "")]
    assert fallbacks and fallbacks[0]["rung_name"] == "bucket"
    assert any(e.get("name") == "chaos_injected" for e in events)


def test_zero_replans_during_steady_state_serving(small_model):
    """The PR-8 fix, pinned: after ``prewarm`` resolves one plan per
    (batch-bucket, len-bucket) prefill shape + the decode shape, an entire
    serving run adds ZERO plan-memo misses — no re-planning mid-serve."""
    import dataclasses

    cfg, params = small_model
    cfg = dataclasses.replace(cfg, kron_ffn=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, SCFG, max_new=5)
    ops = eng.prewarm()
    assert len(ops) == 2 * len(SCFG.buckets) * len(batch_buckets(
        SCFG.max_prefill)) + 2  # up/down per prefill shape + decode shape
    misses = (E._resolve_plan.cache_info().misses,
              E._resolve_batched_plan.cache_info().misses)
    rep = eng.run(_trace())
    assert len(rep.metrics) == 6
    after = (E._resolve_plan.cache_info().misses,
             E._resolve_batched_plan.cache_info().misses)
    assert after == misses, (
        f"steady-state serving re-planned: misses {misses} -> {after}"
    )


def test_engine_masks_padded_prefill_positions(small_model):
    """A prompt shorter than its bucket must not attend to the pad keys the
    bucketed prefill wrote: cache_to_slots masks them to pos=-1.  Checked
    by comparing against an unpadded batch-of-one prefill+decode."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, SCFG, max_new=4)
    rep = eng.run([Request(0, 5, 4, 0.0)])  # len 5 -> bucket 8 (3 pads)
    # reference: the engine's own prompt (RandomState(0), same draw order),
    # prefilled UNPADDED and decoded with the scalar-pos path
    tok = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, size=(1, 5)).astype(np.int32))
    logits, cache = M.prefill(cfg, params, tok, eng.max_len)
    ref = [int(jnp.argmax(logits[0, -1, : cfg.vocab]))]
    t = jnp.asarray([[ref[0]]], jnp.int32)
    for i in range(3):
        logits, cache = M.decode_step(cfg, params, cache, t, jnp.int32(5 + i))
        nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab]))
        ref.append(nxt)
        t = jnp.asarray([[nxt]], jnp.int32)
    assert rep.tokens[0] == ref
