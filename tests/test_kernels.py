"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle.

Shapes sweep the paper's regimes: small P (fusion territory), large P
(MXU-aligned), rectangular P!=Q, plus tile-edge cases where the block size
equals / divides the dims unevenly enough to exercise the grid.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.kron_fused import fused_kron_pallas, max_n_fused
from repro.kernels.kron_sliced import sliced_multiply_pallas
from repro.kernels.ref import fused_kron_ref, sliced_multiply_ref


def _mk(seed, m, k, p, q, dtype=jnp.float32):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (m, k)).astype(dtype)
    f = jax.random.normal(k2, (p, q)).astype(dtype)
    return x, f


SLICED_SHAPES = [
    # (m, p, q, s)  with K = s*p
    (2, 2, 2, 2),
    (8, 8, 8, 64),
    (16, 8, 8, 8),
    (4, 16, 16, 16),
    (8, 32, 32, 4),
    (2, 64, 64, 2),
    (8, 128, 128, 1),
    (8, 4, 8, 16),     # Q > P (expanding)
    (8, 8, 4, 16),     # Q < P (contracting)
    (1, 8, 8, 512),    # M=1 long row (paper GP case M small)
]


@pytest.mark.parametrize("m,p,q,s", SLICED_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sliced_kernel_matches_ref(m, p, q, s, dtype):
    x, f = _mk(0, m, s * p, p, q, dtype)
    got = sliced_multiply_pallas(x, f, interpret=True)
    want = sliced_multiply_ref(x, f)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


@pytest.mark.parametrize(
    "m,p,q,s,t_m,t_s,t_q",
    [
        (8, 8, 8, 64, 2, 16, 4),   # all three grid dims > 1
        (8, 8, 8, 64, 8, 64, 8),   # single block
        (4, 16, 8, 32, 2, 8, 2),   # rectangular + tiled
        (16, 4, 4, 16, 4, 4, 1),   # t_q = 1 edge
    ],
)
def test_sliced_kernel_tilings(m, p, q, s, t_m, t_s, t_q):
    x, f = _mk(1, m, s * p, p, q)
    got = sliced_multiply_pallas(x, f, t_m=t_m, t_s=t_s, t_q=t_q, interpret=True)
    want = sliced_multiply_ref(x, f)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_sliced_kernel_rejects_bad_tiles():
    x, f = _mk(2, 8, 64, 8, 8)
    with pytest.raises(ValueError):
        sliced_multiply_pallas(x, f, t_m=3, interpret=True)  # 8 % 3 != 0


FUSED_CASES = [
    # (m, ps, qs, t_m, t_k)   factors given in application order (F^N first)
    (2, (4, 4), (4, 4), 2, 16),
    (4, (8, 8), (8, 8), 2, 64),
    (2, (4, 4, 4), (4, 4, 4), 2, 64),
    (2, (2, 2, 2, 2), (2, 2, 2, 2), 2, 16),
    (4, (4, 8), (8, 4), 2, 32),        # rectangular chain
    (2, (8, 8), (8, 8), 2, None),      # t_k = full K
]


@pytest.mark.parametrize("m,ps,qs,t_m,t_k", FUSED_CASES)
def test_fused_kernel_matches_ref(m, ps, qs, t_m, t_k):
    kdim = math.prod(ps)
    keys = jax.random.split(jax.random.PRNGKey(3), len(ps) + 1)
    x = jax.random.normal(keys[0], (m, kdim), jnp.float32)
    factors_last_first = [
        jax.random.normal(k, (p, q), jnp.float32)
        for k, p, q in zip(keys[1:], ps, qs)
    ]
    got = fused_kron_pallas(x, *factors_last_first, t_m=t_m, t_k=t_k, interpret=True)
    # ref applies last factor of the problem first; factors_last_first[0] is
    # F^N, so the problem-order list is reversed(factors_last_first).
    want = fused_kron_ref(x, list(reversed(factors_last_first)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_fused_kernel_vmem_guard():
    x = jnp.zeros((8, 1 << 14), jnp.float32)
    f = jnp.zeros((2, 2), jnp.float32)
    with pytest.raises(ValueError):
        fused_kron_pallas(
            x, f, f, t_m=8, t_k=1 << 14, interpret=True, vmem_budget_elems=1024
        )


def test_max_n_fused_matches_paper_formula():
    # paper: N_fused = floor(log_P T_K)
    assert max_n_fused(128, 4) == 3   # 4^3=64 <=128, 4^4=256 no
    assert max_n_fused(512, 8) == 3
    assert max_n_fused(8, 8) == 1
    assert max_n_fused(7, 8) == 0


TRANSPOSED_SHAPES = [
    (2, 2, 2, 2),
    (8, 8, 8, 64),
    (4, 16, 8, 16),    # rectangular
    (8, 4, 8, 32),
    (1, 8, 8, 512),
]


@pytest.mark.parametrize("m,p,q,s", TRANSPOSED_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sliced_t_kernel_matches_ref(m, p, q, s, dtype):
    """Backward kernel (beyond-paper): dX for one sliced multiply."""
    from repro.kernels.kron_sliced_t import sliced_multiply_t_pallas
    from repro.kernels.ref import sliced_multiply_t_ref

    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    dy = jax.random.normal(k1, (m, q * s)).astype(dtype)
    f = jax.random.normal(k2, (p, q)).astype(dtype)
    got = sliced_multiply_t_pallas(dy, f, interpret=True)
    want = sliced_multiply_t_ref(dy, f)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


@pytest.mark.parametrize(
    "t_m,t_s,t_q", [(2, 16, 4), (8, 64, 8), (4, 8, 2), (8, 64, 1)]
)
def test_sliced_t_kernel_q_accumulation(t_m, t_s, t_q):
    """Output blocks accumulate across the innermost Q-tile grid dim."""
    from repro.kernels.kron_sliced_t import sliced_multiply_t_pallas
    from repro.kernels.ref import sliced_multiply_t_ref

    m, p, q, s = 8, 8, 8, 64
    k1, k2 = jax.random.split(jax.random.PRNGKey(10))
    dy = jax.random.normal(k1, (m, q * s), jnp.float32)
    f = jax.random.normal(k2, (p, q), jnp.float32)
    got = sliced_multiply_t_pallas(dy, f, t_m=t_m, t_s=t_s, t_q=t_q, interpret=True)
    np.testing.assert_allclose(got, sliced_multiply_t_ref(dy, f), rtol=1e-5, atol=1e-5)


def test_forward_backward_kernel_roundtrip():
    """sliced_t(sliced(x, I_perm)) recovers x for orthonormal factors."""
    from repro.kernels.kron_sliced import sliced_multiply_pallas
    from repro.kernels.kron_sliced_t import sliced_multiply_t_pallas

    x = jax.random.normal(jax.random.PRNGKey(11), (4, 64), jnp.float32)
    # orthonormal F: F F^T = I, so the transposed op inverts the forward
    f = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(12), (8, 8)))[0]
    y = sliced_multiply_pallas(x, f, interpret=True)
    back = sliced_multiply_t_pallas(y, f, interpret=True)
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ops_sliced_t_dispatch(backend):
    from repro.kernels.ref import sliced_multiply_t_ref

    k1, k2 = jax.random.split(jax.random.PRNGKey(13))
    dy = jax.random.normal(k1, (4, 128), jnp.float32)
    f = jax.random.normal(k2, (8, 8), jnp.float32)
    got = ops.sliced_multiply_t(dy, f, backend=backend)
    np.testing.assert_allclose(got, sliced_multiply_t_ref(dy, f), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ops_dispatch_both_backends(backend):
    x, f = _mk(4, 8, 128, 8, 8)
    got = ops.sliced_multiply(x, f, backend=backend)
    want = sliced_multiply_ref(x, f)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ops_fused_dispatch_both_backends(backend):
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(keys[0], (4, 64), jnp.float32)
    f1 = jax.random.normal(keys[1], (4, 4), jnp.float32)
    f2 = jax.random.normal(keys[2], (4, 4), jnp.float32)
    got = ops.fused_kron(x, [f1, f2], backend=backend, t_m=2, t_k=16)
    want = fused_kron_ref(x, [f2, f1])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Q-tiled fused forward + fused transposed / backward kernels
# ---------------------------------------------------------------------------


def _mk_chain(seed, m, ps, qs):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ps) + 1)
    x = jax.random.normal(keys[0], (m, math.prod(ps)), jnp.float32)
    factors_last_first = [
        jax.random.normal(k, (p, q), jnp.float32)
        for k, p, q in zip(keys[1:], ps, qs)
    ]
    return x, factors_last_first


@pytest.mark.parametrize(
    "m,ps,qs,t_m,t_k,t_qs",
    [
        (4, (4, 4), (4, 4), 2, 16, (2, 2)),
        (4, (2, 2), (8, 8), 2, 4, (4, 2)),       # expanding chain, tiled Q
        (2, (4, 4, 4), (4, 4, 4), 2, 64, (2, 4, 1)),
        (4, (4, 8), (8, 4), 2, 32, (4, 2)),      # rectangular
    ],
)
def test_fused_kernel_q_tiling_matches_ref(m, ps, qs, t_m, t_k, t_qs):
    x, fls = _mk_chain(20, m, ps, qs)
    got = fused_kron_pallas(x, *fls, t_m=t_m, t_k=t_k, t_qs=t_qs, interpret=True)
    want = fused_kron_ref(x, list(reversed(fls)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_kernel_q_tiling_lifts_vmem_restriction():
    """Problems where t_m*t_k*growth exceeds the budget become legal by
    tiling Q (acceptance criterion for the Q-tile grid axis)."""
    x, fls = _mk_chain(21, 8, (2, 2), (16, 16))
    # full Q: growth = 256/4 = 64 -> 8*4*64 = 2048 elems > 1024 budget
    with pytest.raises(ValueError):
        fused_kron_pallas(x, *fls, t_m=8, t_k=4, interpret=True,
                          vmem_budget_elems=1024)
    got = fused_kron_pallas(x, *fls, t_m=8, t_k=4, t_qs=(4, 4), interpret=True,
                            vmem_budget_elems=1024)
    np.testing.assert_allclose(
        got, fused_kron_ref(x, list(reversed(fls))), rtol=1e-5, atol=1e-5
    )


FUSED_T_CASES = [
    (4, (4, 4), (4, 4), 2, 16, None),
    (4, (4, 4), (4, 4), 2, 16, (2, 2)),      # accumulation over Q-tiles
    (2, (4, 4, 4), (4, 4, 4), 2, 64, None),
    (4, (4, 8), (8, 4), 2, 32, (2, 2)),
    (8, (2, 2), (8, 8), 4, 4, (4, 2)),
]


@pytest.mark.parametrize("m,ps,qs,t_m,t_k,t_qs", FUSED_T_CASES)
def test_fused_t_kernel_matches_ref(m, ps, qs, t_m, t_k, t_qs):
    from repro.kernels.kron_fused_t import fused_kron_t_pallas
    from repro.kernels.ref import fused_kron_t_ref

    x, fls = _mk_chain(22, m, ps, qs)
    y = fused_kron_ref(x, list(reversed(fls)))
    dy = jax.random.normal(jax.random.PRNGKey(23), y.shape, jnp.float32)
    got = fused_kron_t_pallas(dy, *fls, t_m=t_m, t_k=t_k, t_qs=t_qs, interpret=True)
    # fused_kron_t_ref takes problem order (F^1 first == fls reversed)
    want = fused_kron_t_ref(dy, list(reversed(fls)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_t_is_vjp_of_fused():
    """fused_kron_t computes exactly the input cotangent of fused_kron."""
    from repro.kernels.kron_fused_t import fused_kron_t_pallas

    x, fls = _mk_chain(24, 4, (4, 4), (4, 4))
    f_fwd = lambda x: fused_kron_ref(x, list(reversed(fls)))
    y, vjp = jax.vjp(f_fwd, x)
    dy = jax.random.normal(jax.random.PRNGKey(25), y.shape, jnp.float32)
    (want,) = vjp(dy)
    got = fused_kron_t_pallas(dy, *fls, t_m=2, t_k=16, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize(
    "m,ps,qs,t_m,t_k",
    [
        (4, (4, 4), (4, 4), 2, 16),
        (2, (4, 4, 4), (4, 4, 4), 2, 64),
        (4, (4, 8), (8, 4), 2, 32),
    ],
)
def test_fused_bwd_kernel_matches_autodiff(m, ps, qs, t_m, t_k):
    """One-kernel stage backward (dx + all factor grads) vs autodiff oracle."""
    from repro.kernels.kron_fused_t import fused_kron_bwd_pallas

    x, fls = _mk_chain(26, m, ps, qs)
    y = fused_kron_ref(x, list(reversed(fls)))
    dy = jax.random.normal(jax.random.PRNGKey(27), y.shape, jnp.float32)

    def loss(x, fls):
        return (fused_kron_ref(x, list(reversed(fls))) * dy).sum()

    dx_want, dfs_want = jax.grad(loss, argnums=(0, 1))(x, fls)
    dx, dfs = fused_kron_bwd_pallas(x, dy, *fls, t_m=t_m, t_k=t_k, interpret=True)
    np.testing.assert_allclose(dx, dx_want, rtol=1e-4, atol=1e-4)
    for got, want in zip(dfs, dfs_want):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ops_fused_t_dispatch(backend):
    from repro.kernels.ref import fused_kron_t_ref

    x, fls = _mk_chain(28, 8, (4, 4), (4, 4))
    y = fused_kron_ref(x, list(reversed(fls)))
    dy = jax.random.normal(jax.random.PRNGKey(29), y.shape, jnp.float32)
    got = ops.fused_kron_t(dy, fls, backend=backend, t_m=2, t_k=16)
    np.testing.assert_allclose(
        got, fused_kron_t_ref(dy, list(reversed(fls))), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("m", [4, 32])  # 32 exercises the xla M-tiled scan
def test_ops_fused_bwd_dispatch(backend, m):
    x, fls = _mk_chain(30, m, (4, 4), (4, 4))
    y = fused_kron_ref(x, list(reversed(fls)))
    dy = jax.random.normal(jax.random.PRNGKey(31), y.shape, jnp.float32)

    def loss(x, fls):
        return (fused_kron_ref(x, list(reversed(fls))) * dy).sum()

    dx_want, dfs_want = jax.grad(loss, argnums=(0, 1))(x, fls)
    dx, dfs = ops.fused_kron_bwd(x, dy, fls, backend=backend, t_m=2, t_k=16)
    np.testing.assert_allclose(dx, dx_want, rtol=1e-4, atol=1e-4)
    for got, want in zip(dfs, dfs_want):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Legacy fused_kron* shim surface (StageProgram refactor): each wrapper warns
# ONCE per process and its numerics are the emitter path's bit for bit.
# ---------------------------------------------------------------------------


def test_legacy_fused_shims_warn_once_and_match_emitter():
    import warnings

    from repro.kernels import emit

    x, fls = _mk_chain(40, 8, (4, 4), (4, 4))
    y = fused_kron_ref(x, list(reversed(fls)))
    dy = jax.random.normal(jax.random.PRNGKey(41), y.shape, jnp.float32)
    xb = jnp.stack([x, x + 1])
    flsb = [jnp.stack([f, f * 0.5]) for f in fls]
    dyb = jnp.stack([dy, dy])

    ops._SHIM_WARNED.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        y1 = ops.fused_kron(x, fls, t_m=2, t_k=16)
        ops.fused_kron(x, fls, t_m=2, t_k=16)  # 2nd call: no 2nd warning
        y2 = ops.fused_kron_t(dy, fls, t_m=2, t_k=16)
        y3 = ops.fused_kron_bwd(x, dy, fls, t_m=2, t_k=16)
        y4 = ops.fused_kron_batched(xb, flsb, t_b=1, t_m=2, t_k=16)
        y5 = ops.fused_kron_t_batched(dyb, flsb, t_b=1, t_m=2, t_k=16)
        y6 = ops.fused_kron_bwd_batched(xb, dyb, flsb, t_b=1, t_m=2, t_k=16)
    dep = [d for d in w if issubclass(d.category, DeprecationWarning)]
    names = sorted(str(d.message).split()[0] for d in dep)
    assert names == sorted(
        f"kernels.ops.{n}" for n in (
            "fused_kron", "fused_kron_t", "fused_kron_bwd",
            "fused_kron_batched", "fused_kron_t_batched",
            "fused_kron_bwd_batched",
        )
    ), names  # one warning per entry point, not per call
    assert all("StageInstr" in str(d.message) for d in dep)

    # Numerical identity: the shim IS the emitter path.
    mk = lambda kind, t_b=None: emit.StageInstr(
        kind=kind, ps=(4, 4), qs=(4, 4), t_m=2, t_k=16, t_b=t_b
    )
    np.testing.assert_array_equal(
        np.asarray(y1), np.asarray(emit.run_stage(x, tuple(fls), mk(emit.MULTIPLY)))
    )
    np.testing.assert_array_equal(
        np.asarray(y2),
        np.asarray(emit.run_stage(dy, tuple(fls), mk(emit.TRANSPOSED_MULTIPLY))),
    )
    dx, dfs = emit.run_stage_grad(x, dy, tuple(fls), mk(emit.MULTIPLY))
    np.testing.assert_array_equal(np.asarray(y3[0]), np.asarray(dx))
    for a, b in zip(y3[1], dfs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(y4),
        np.asarray(emit.run_stage(xb, tuple(flsb), mk(emit.MULTIPLY, 1))),
    )
    np.testing.assert_array_equal(
        np.asarray(y5),
        np.asarray(
            emit.run_stage(dyb, tuple(flsb), mk(emit.TRANSPOSED_MULTIPLY, 1))
        ),
    )
    dxb, dfsb = emit.run_stage_grad(xb, dyb, tuple(flsb), mk(emit.MULTIPLY, 1))
    np.testing.assert_array_equal(np.asarray(y6[0]), np.asarray(dxb))
    for a, b in zip(y6[1], dfsb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
