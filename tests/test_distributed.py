"""Multi-device distributed Kron-Matmul tests (8 fake CPU devices).

Runs tests/distributed_driver.py in a subprocess so the XLA device-count
flag never leaks into this pytest process (jax locks device count on first
init — see launch/dryrun.py for the same pattern).
"""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run_driver(name: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / name)],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL-OK" in proc.stdout
    return proc.stdout


@pytest.mark.slow
def test_distributed_driver_all_checks():
    _run_driver("distributed_driver.py")


@pytest.mark.slow
def test_chaos_distributed_driver_all_checks():
    """Fault-injected distributed rounds (PR 6): chaos forces the per-factor
    VMEM fallback in ``_local_multiply_round`` (bitwise parity + still one
    all-to-all per round) and a failed collective degrades the KronOp mesh
    ladder to local execution with the CollectiveError recorded in health.
    PR 10 adds the ``slab_collective`` site: a failed slab all_to_all
    degrades the three-rung ladder slabbed -> serial rounds (bitwise) and,
    with the serial relocation failing too, the rest of the way to local."""
    out = _run_driver("chaos_distributed_driver.py")
    assert "OK round-chain-fallback" in out
    assert "OK mesh-ladder-local-fallback" in out
    assert "OK slab-ladder-serial-fallback bitwise" in out
    assert "OK slab-ladder-local-fallback" in out


@pytest.mark.slow
def test_overlap_distributed_driver_all_checks():
    """Slab-pipelined distributed rounds (PR 10): slabbed schedule bitwise
    (fwd + grads) vs serial on both mesh runners, the ``rounds * n_slabs``
    all-to-all HLO pin, per-slab comm-gauge accounting summing to the serial
    ``comm_elems_per_device`` total, cost()/telemetry overlap reconciliation
    through ``KronOp.profile()``, and the measured distributed tuner's
    ``;gk=`` plan-cache key — on a forced 8-device (2, 4) host mesh."""
    out = _run_driver("overlap_distributed_driver.py")
    assert "OK comm-accounting" in out
    assert "OK cost-telemetry-reconcile" in out
    assert "OK measured-tuner" in out


@pytest.mark.slow
def test_distributed_batched_driver_all_checks():
    """Batched distributed rounds (PR 3): shared + per-sample correctness
    (fwd + grads) vs the looped per-problem reference, one collective per
    round for the whole batch, batch-aware comm accounting, and the gp /
    layers consumers — all on a forced 8-device (2, 4) host mesh."""
    out = _run_driver("distributed_batched_driver.py")
    assert "OK collective-count" in out
    assert "OK comm-accounting" in out
