"""Fault tolerance: checkpoint atomicity/keep-k/resume, straggler monitor,
elastic re-meshing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models.config import reduced
from repro.optim import OptConfig
from repro.runtime.fault import StragglerMonitor, elastic_mesh
from repro.train import make_train_step, train_state_init


def _tiny():
    cfg = reduced(get_config("gemma_2b"), n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=1, head_dim=16, d_ff=64, vocab=64,
                  vocab_pad_multiple=32, dtype="float32")
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, decay_steps=50)
    return cfg, opt_cfg


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.float32(1.5)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree))
    assert mgr.all_steps() == [2, 3]  # keep-k pruned step 1
    got = mgr.restore(tree, step=3)
    np.testing.assert_array_equal(got["a"], np.arange(6).reshape(2, 3) + 3)


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"x": jnp.ones(3)})
    # simulate a crash mid-save: stray tmp dir
    os.makedirs(tmp_path / "step_000000002.tmp")
    assert mgr.latest_step() == 1
    mgr.save(3, {"x": jnp.ones(3) * 3})  # gc removes the orphan
    assert not (tmp_path / "step_000000002.tmp").exists()


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(7, {"x": jnp.arange(10)})
    mgr.wait()
    got = mgr.restore({"x": jnp.zeros(10, jnp.int32)})
    np.testing.assert_array_equal(got["x"], np.arange(10))


def test_training_resume_bitexact(tmp_path):
    """train 6 steps == train 3, checkpoint, restore, train 3 more."""
    cfg, opt_cfg = _tiny()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=4)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    def run(state, a, b):
        for i in range(a, b):
            toks, labels = data.global_batch(i)
            state, _ = step_fn(state, {"tokens": toks, "labels": labels})
        return state

    s_full = run(train_state_init(cfg, opt_cfg, jax.random.PRNGKey(0)), 0, 6)

    s_half = run(train_state_init(cfg, opt_cfg, jax.random.PRNGKey(0)), 0, 3)
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(3, s_half._asdict())
    restored = mgr.restore(s_half._asdict())
    from repro.train import TrainState

    s_resumed = run(TrainState(**restored), 3, 6)

    for a, b in zip(jax.tree.leaves(s_full.params), jax.tree.leaves(s_resumed.params)):
        np.testing.assert_allclose(a, b, rtol=0, atol=0)


def test_cross_mesh_restore(tmp_path):
    """Checkpoint saved unsharded restores onto an explicit mesh sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path), keep=1)
    w = jnp.arange(16.0).reshape(4, 4)
    mgr.save(1, {"w": w})
    mesh = jax.make_mesh((1,), ("data",))
    target = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    target = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, P("data", None))
        ),
        {"w": target},
    )
    got = mgr.restore(target)
    np.testing.assert_array_equal(got["w"], np.asarray(w))
    assert got["w"].sharding.mesh.shape == {"data": 1}


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold_sigma=3.0, patience=1, warmup_steps=5)
    for i in range(20):
        mon.observe(i, 0.1 + 0.001 * (i % 3))
    assert not mon.flagged_steps
    assert mon.observe(20, 1.0)  # 10x outlier
    assert mon.flagged_steps and mon.flagged_steps[-1][0] == 20


def test_straggler_monitor_raises_after_patience():
    mon = StragglerMonitor(threshold_sigma=2.0, patience=2, warmup_steps=3,
                           action="raise")
    for i in range(10):
        mon.observe(i, 0.1)
    mon.observe(10, 5.0)
    with pytest.raises(RuntimeError, match="straggler"):
        mon.observe(11, 5.0)


def test_straggler_monitor_rearms_after_firing():
    """Regression: the consecutive counter must reset when the action fires.
    Before the fix, every slow step past the first patience window re-fired
    the action — a callback storm (or an immediate re-raise) instead of one
    action per window."""
    fired = []
    mon = StragglerMonitor(threshold_sigma=2.0, patience=2, warmup_steps=3,
                           action="callback",
                           callback=lambda step, dt: fired.append(step))
    for i in range(10):
        mon.observe(i, 0.1)
    mon.observe(10, 5.0)          # slow 1/2: below patience
    mon.observe(11, 5.0)          # slow 2/2: fires, must re-arm
    assert fired == [11]
    mon.observe(12, 5.0)          # slow 1/2 of the NEXT window: no re-fire
    assert fired == [11]
    mon.observe(13, 5.0)          # slow 2/2 again: second window fires
    assert fired == [11, 13]
    # a raise-action monitor survives to raise AGAIN a full window later
    mon2 = StragglerMonitor(threshold_sigma=2.0, patience=2, warmup_steps=3,
                            action="raise")
    for i in range(10):
        mon2.observe(i, 0.1)
    mon2.observe(10, 5.0)
    with pytest.raises(RuntimeError):
        mon2.observe(11, 5.0)
    mon2.observe(12, 5.0)         # re-armed: 1/2, no raise
    with pytest.raises(RuntimeError):
        mon2.observe(13, 5.0)


@pytest.mark.parametrize("n,model,want", [
    (512, 16, (32, 16)),
    (256, 16, (16, 16)),
    (12, 16, (3, 4)),     # lost devices: model falls to 4
    (7, 16, (7, 1)),      # prime count: pure DP
])
def test_elastic_mesh_shapes(n, model, want):
    # shape math only (can't build >1-device mesh here): replicate logic
    m = 1
    while m * 2 <= model and n % (m * 2) == 0:
        m *= 2
    assert (n // m, m) == want


def test_elastic_mesh_single_device():
    mesh = elastic_mesh(1, want_model=16)
    assert dict(mesh.shape) == {"data": 1, "model": 1}
