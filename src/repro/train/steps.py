"""Train / prefill / serve step builders.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function: CE loss + MoE aux, grads, AdamW update.  ``microbatches > 1``
accumulates gradients over a ``lax.scan`` of batch slices — the activation-
memory lever that lets 100B+ configs fit the 256-chip dry-run mesh.

``make_serve_step`` is the decode-shape entry point the dry run lowers for
``decode_32k`` / ``long_500k`` (one new token against a KV/SSM cache).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig
from ..optim.adamw import OptConfig
from ..optim.shampoo import ShampooConfig, opt_for
from ..optim import shampoo as _shampoo
from ..runtime.sharding import constrain_like_params


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


def prebuild_kron_ops(
    cfg: ModelConfig, *, batch: int | None = None, seq_len: int | None = None,
    mesh=None, prefill_shapes: Sequence[tuple[int, int]] = (),
    decode_batch: int | None = None, opt_cfg: OptConfig | None = None,
) -> tuple:
    """Construct the ``KronOp`` handles behind every Kron-compressed
    projection in ``cfg`` before the first jitted step.

    With ``batch`` and ``seq_len`` given (serving knows both), the plan for
    the ``(batch*seq_len)``-row collapsed problem is resolved HERE — the
    tile search lands in the engine's shared bounded plan memo, which is
    exactly what the layer applies hit at trace time, so the first trace
    does no Python-side planning.  Without them (training builds steps
    before seeing a batch) this constructs and returns the op handles;
    their plans resolve once, on first call, through the same shared memo.
    ``mesh``: also pre-validate the distributed ops a ``kron_distributed``
    scope would route to (shapes the mesh cannot host are skipped — the
    scope falls back to the local path for those).

    ``prefill_shapes``: extra ``(batch, seq_len)`` pairs to pre-resolve —
    the continuous-batching engine prefills each padding bucket at its own
    shape, and a shape missing here re-plans at trace time mid-serve (the
    PR-8 fix; tests/test_serve_engine.py pins zero steady-state misses).
    ``decode_batch``: also resolve the decode-step shape (rows = slots*1).
    ``opt_cfg``: with a ``ShampooConfig``, ALSO construct the optimizer's
    shape-grouped preconditioner-apply ops (one batched per-sample op per
    same-shape layer group, sized from ``jax.eval_shape`` of the params) —
    the training analogue of the serving prewarm, so the first train step
    never plans a preconditioner op mid-trace.
    """
    opt_ops: tuple = ()
    if isinstance(opt_cfg, ShampooConfig):
        import functools
        shapes = jax.eval_shape(
            functools.partial(M.init_params, cfg), jax.random.PRNGKey(0)
        )
        opt_ops = _shampoo.prewarm(shapes, opt_cfg)
    if not getattr(cfg, "kron_ffn", False):
        return opt_ops
    from ..core.engine import kron_op_for
    from ..core.layers import KronLinearSpec

    dtype_bytes = {"bfloat16": 2, "float16": 2, "float64": 8}.get(
        str(getattr(cfg, "dtype", "float32")), 4
    )
    up = KronLinearSpec.balanced(cfg.d_model, cfg.d_ff, cfg.kron_factors)
    down = KronLinearSpec.balanced(cfg.d_ff, cfg.d_model, cfg.kron_factors)
    shapes: list[tuple[int, int]] = []
    if batch is not None and seq_len is not None:
        shapes.append((int(batch), int(seq_len)))
    shapes.extend((int(b), int(s)) for b, s in prefill_shapes)
    if decode_batch is not None:
        shapes.append((int(decode_batch), 1))
    ops = []
    for spec in (up, down):
        for b, s in dict.fromkeys(shapes):
            # A serving shape: (B, T, d) collapses to B*T rows — resolve
            # that plan now (m is rows per sample for a batched op).
            ops.append(kron_op_for(
                spec.ps, spec.qs, m=s, batch=b,
                shared_factors=True, dtype_bytes=dtype_bytes,
            ))
        if not shapes:
            ops.append(kron_op_for(spec.ps, spec.qs))
        if mesh is not None:
            try:
                ops.append(kron_op_for(spec.ps, spec.qs, mesh=mesh))
            except ValueError:
                pass  # no legal round schedule — scope will run local
    return tuple(ops) + opt_ops


def train_state_init(cfg: ModelConfig, opt_cfg: OptConfig, key: jax.Array) -> TrainState:
    params = M.init_params(cfg, key)
    init_fn, _ = opt_for(opt_cfg)
    return TrainState(params, init_fn(params, opt_cfg), jnp.zeros((), jnp.int32))


def opt_state_shardings(opt_state: Any, param_shardings: Any, replicated) -> Any:
    """Shardings for an optimizer-state pytree: ``m``/``v``/``err`` mirror
    the parameter shardings (FSDP'd params => ZeRO-3 partitioned state),
    everything else (``step``, Shampoo's ``kron`` statistics subtree) is
    replicated — the kron subtree is ``O(p^2 + q^2)`` per layer, small next
    to the ``p*q`` parameters it preconditions."""
    out = {}
    for key in opt_state:
        if key in ("m", "v", "err"):
            out[key] = param_shardings
        else:
            out[key] = jax.tree.map(lambda _: replicated, opt_state[key])
    return out


def loss_fn(
    cfg: ModelConfig,
    params: Any,
    tokens: jax.Array,
    labels: jax.Array,
    embeds: jax.Array | None = None,
    aux_weight: float = 0.01,
):
    logits, aux = M.forward(cfg, params, tokens, embeds)
    n_fe = cfg.n_frontend_tokens if embeds is not None else 0
    logits = logits[:, n_fe:, :]
    ll = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1).mean()
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptConfig,
    *,
    microbatches: int = 1,
    with_embeds: bool = False,
    acc_dtype=jnp.float32,
):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: dict(tokens (B,S), labels (B,S)[, embeds (B,n_fe,D)]).
    ``acc_dtype``: gradient-accumulator dtype (bf16 halves the buffer for
    100B+ models; error < 2^-8 relative per add, fine for <=32 microbatches).
    """
    # Construct the op handles up front (model projections AND, for a
    # ShampooConfig, the optimizer's shape-group preconditioner ops); their
    # plans resolve once through the shared bounded memo (the first trace
    # reuses, not re-plans).
    prebuild_kron_ops(cfg, opt_cfg=opt_cfg)
    _, update_fn = opt_for(opt_cfg)

    def grads_of(params, tokens, labels, embeds):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, labels, embeds), has_aux=True
        )(params)
        return loss, parts, constrain_like_params(grads)

    def train_step(state: TrainState, batch: dict):
        params = state.params
        tokens, labels = batch["tokens"], batch["labels"]
        embeds = batch.get("embeds") if with_embeds else None

        if microbatches == 1:
            loss, parts, grads = grads_of(params, tokens, labels, embeds)
        else:
            b = tokens.shape[0]
            mb = b // microbatches

            def split(x):
                return x.reshape(microbatches, mb, *x.shape[1:])

            mb_batch = (split(tokens), split(labels),
                        split(embeds) if embeds is not None else None)

            def acc_body(carry, xs):
                g_acc, l_acc = carry
                t, l, e = xs
                loss, _, grads = grads_of(params, t, l, e)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), g_acc, grads
                )
                return (g_acc, l_acc + loss), None

            g0 = constrain_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
            )
            if mb_batch[2] is None:
                xs = (mb_batch[0], mb_batch[1],
                      jnp.zeros((microbatches, 0), jnp.float32))
                def acc_body2(carry, x):
                    t, l, _ = x
                    return acc_body(carry, (t, l, None))
                (grads, loss), _ = jax.lax.scan(acc_body2, (g0, 0.0), xs)
            else:
                (grads, loss), _ = jax.lax.scan(acc_body, (g0, 0.0), mb_batch)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            parts = {"nll": loss, "aux": jnp.zeros((), jnp.float32)}

        new_params, new_opt, opt_metrics = update_fn(
            grads, state.opt, params, opt_cfg
        )
        metrics = {"loss": loss, **parts, **opt_metrics}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int, *, with_embeds: bool = False):
    def prefill_step(params, tokens, embeds=None):
        return M.prefill(cfg, params, tokens, max_len,
                         embeds if with_embeds else None)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, cache, tokens (B,1), pos) -> (next_token_logits, cache)."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = M.decode_step(cfg, params, cache, tokens, pos)
        return logits, cache

    return serve_step


__all__ = [
    "TrainState",
    "train_state_init",
    "prebuild_kron_ops",
    "opt_state_shardings",
    "loss_fn",
    "make_train_step",
    "make_prefill_step",
    "make_serve_step",
]
