"""Training/serving steps: loss, train_step (with microbatch accumulation),
prefill_step, serve_step."""
from .steps import (  # noqa: F401
    TrainState,
    loss_fn,
    make_train_step,
    make_prefill_step,
    make_serve_step,
    opt_state_shardings,
    prebuild_kron_ops,
    train_state_init,
)
