"""GQA/MQA/MHA attention: RoPE, qk-norm, QKV-bias, sliding-window, KV cache.

Memory discipline: the full-sequence path never materializes the (S, S)
score matrix — queries are processed in chunks of ``q_chunk`` under
``lax.scan`` with only one (B, H, q_chunk, S) block live (flash-attention
style blocking, single level; sufficient since S fits HBM row-wise).  GQA
keeps K/V un-repeated via a grouped einsum, so TP sharding of q-heads never
forces a KV all-gather.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..runtime.sharding import constrain, tp_size
from .common import apply_rope, dense_init, rms_norm
from .config import ModelConfig

NEG_INF = -1e9


def _use_context_parallel(cfg: ModelConfig) -> bool:
    """Head-parallel TP needs n_heads % tp == 0; when it fails (qwen2.5:
    40 heads, qwen2-7b: 28, gemma: 8 on a 16-way model axis) XLA falls
    back to sharding head_dim — every score block then needs an f32 psum
    (measured 1.4 TB/device/step on qwen2.5-32b train).  Context
    parallelism instead shards the QUERY sequence over the model axis:
    scores are computed fully locally with replicated (small) K/V; the
    added comm is one K/V gather plus an S->feature reshard before the
    output projection.  Fleet measurement (EXPERIMENTS.md §Perf C1):
    -34..-79 % dominant term where q-heads don't divide; +8..+26 %
    REGRESSION when applied to archs where only KV heads don't divide
    (qwen3/llava/mixtral/jamba: q-head TP is fine and kv is cheap to
    split on head_dim) — hence the q-heads-only trigger."""
    tp = tp_size()
    return tp > 1 and cfg.n_heads % tp != 0


def attn_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,Hkv,hd), RoPE'd."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,Sq,H,hd), k (B,Sk,Hkv,hd) -> (B,Hkv,G,Sq,Sk) in f32."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    return jnp.einsum(
        "bqhgd,bkhd->bhgqk",
        qg.astype(jnp.float32),
        k.astype(jnp.float32),
    ) / math.sqrt(hd)


def _grouped_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs (B,Hkv,G,Sq,Sk), v (B,Sk,Hkv,hd) -> (B,Sq,H,hd)."""
    b, hkv, g, sq, sk = probs.shape
    hd = v.shape[-1]
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hkv * g, hd)


def attn_forward(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    q_chunk: int = 1024,
    return_kv: bool = False,
):
    """Causal full-sequence attention (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    qb = min(q_chunk, s)
    if s % qb:
        qb = math.gcd(s, qb)
    nq = s // qb
    k_pos = positions  # (B, S) or (S,)
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos, (b, s))

    ctx_parallel = _use_context_parallel(cfg)
    if ctx_parallel:
        # context parallelism: queries S-sharded over the model axis, K/V
        # replicated (Hkv*hd is small) — scores stay fully local
        q = constrain(q, "batch", "tp", None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)

    qc = q.reshape(b, nq, qb, *q.shape[2:])
    pc = k_pos.reshape(b, nq, qb)

    def chunk_attn(qi, qpos):
        """One q-chunk: (B, qb, H, hd), (B, qb) -> (B, qb, H, hd)."""
        if ctx_parallel:
            qi = constrain(qi, "batch", "tp", None, None)
        scores = _grouped_scores(qi, k)  # (B,Hkv,G,qb,S)
        causal = k_pos[:, None, None, None, :] <= qpos[:, None, None, :, None]
        if cfg.sliding_window:
            causal &= (
                k_pos[:, None, None, None, :]
                > qpos[:, None, None, :, None] - cfg.sliding_window
            )
        scores = jnp.where(causal, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        # cast INSIDE the chunk: the stacked scan output (and any reshard
        # XLA inserts before the out-projection) must ride bf16, not the
        # f32 accumulator type (measured 2x collective bytes otherwise)
        out = _grouped_out(probs, v).astype(qi.dtype)
        if ctx_parallel:
            out = constrain(out, "batch", "tp", None, None)
        return out

    # flash-attention-style backward: recompute each chunk's (qb, S) score
    # block instead of saving it — otherwise nq f32 blocks survive per layer
    chunk_attn = jax.checkpoint(chunk_attn)

    def body(_, args):
        return None, chunk_attn(*args)

    _, outs = jax.lax.scan(
        body, None, (jnp.swapaxes(qc, 0, 1), jnp.swapaxes(pc, 0, 1))
    )  # (nq, B, qb, H, hd)
    out = jnp.swapaxes(outs, 0, 1).reshape(b, s, cfg.n_heads, cfg.head_dim_)
    y = (out.reshape(b, s, -1).astype(x.dtype)) @ p["wo"]
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array        # (B, L, Hkv, hd)  model dtype, or int8 when quantized
    v: jax.Array
    pos: jax.Array      # (L,) absolute position of each slot, -1 = empty


class QuantKVCache(NamedTuple):
    """int8 KV cache (cfg.kv_quant): per-(token, head) absmax scales.

    Halves the dominant serving buffer (the paper-style memory-movement
    lever applied to decode: the cache is read in full every token, so
    bytes == time).  Standard int8-KV accuracy envelope (~2^-7 relative)."""

    k: jax.Array        # (B, L, Hkv, hd) int8
    v: jax.Array        # int8
    k_scale: jax.Array  # (B, L, Hkv, 1) f32
    v_scale: jax.Array
    pos: jax.Array


def _quantize_kv(t: jax.Array):
    """(..., hd) -> int8 values + f32 absmax scale over hd."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def cache_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window:
        return min(max_len, cfg.sliding_window)
    return max_len


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    l = cache_len(cfg, max_len)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    if cfg.kv_quant:
        return QuantKVCache(
            k=jnp.zeros((batch, l, hkv, hd), jnp.int8),
            v=jnp.zeros((batch, l, hkv, hd), jnp.int8),
            k_scale=jnp.zeros((batch, l, hkv, 1), jnp.float32),
            v_scale=jnp.zeros((batch, l, hkv, 1), jnp.float32),
            pos=jnp.full((l,), -1, jnp.int32),
        )
    return KVCache(
        k=jnp.zeros((batch, l, hkv, hd), dtype),
        v=jnp.zeros((batch, l, hkv, hd), dtype),
        pos=jnp.full((l,), -1, jnp.int32),
    )


def attn_decode(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,          # (B, 1, D)
    cache: KVCache,
    pos: jax.Array,        # int32 scalar, or (B,) for per-slot positions
) -> tuple[jax.Array, KVCache]:
    """One incremental token against the KV cache.

    Two position modes.  Scalar ``pos`` (the one-shot path): every row is
    at the same position and ``cache.pos`` is shared, shape (L,).  Vector
    ``pos`` of shape (B,) (the continuous-batching path, docs/serving.md):
    each decode slot runs its OWN clock — requests admitted mid-flight sit
    at different positions — and ``cache.pos`` must be per-row, (B, L)
    (see ``model.cache_to_slots``).  Positions are request-relative in
    that mode, so RoPE numerics match a batch-of-one run exactly.
    """
    b = x.shape[0]
    l = cache.k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    positions = pos[:, None] if per_slot else jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    slot = (pos % l).astype(jnp.int32)  # ring buffer (== pos w/o SWA)
    zero = jnp.int32(0)
    quant = isinstance(cache, QuantKVCache)

    if per_slot:
        rows = jnp.arange(b)

        def scatter(buf, new):
            # row i writes its own slot: (B, L, ...)[i, slot[i]] = new[i, 0]
            return buf.at[rows, slot].set(new[:, 0].astype(buf.dtype))

    else:

        def scatter(buf, new):
            return jax.lax.dynamic_update_slice(
                buf, new.astype(buf.dtype), (zero, slot) + (zero,) * (buf.ndim - 2)
            )

    if quant:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        kk = scatter(cache.k, kq)
        vv = scatter(cache.v, vq)
        kss = scatter(cache.k_scale, ks)
        vss = scatter(cache.v_scale, vs)
        k = _dequantize_kv(kk, kss, x.dtype)
        v = _dequantize_kv(vv, vss, x.dtype)
    else:
        kk = vv = kss = vss = None
        k = scatter(cache.k, k_new)
        v = scatter(cache.v, v_new)
    if per_slot:
        cpos = cache.pos.at[rows, slot].set(pos)        # (B, L)
        valid = (cpos >= 0) & (cpos <= pos[:, None])
        if cfg.sliding_window:
            valid &= cpos > pos[:, None] - cfg.sliding_window
        vmask = valid[:, None, None, None, :]
    else:
        cpos = jax.lax.dynamic_update_slice(
            cache.pos, jnp.full((1,), pos, jnp.int32), (slot,)
        )
        valid = (cpos >= 0) & (cpos <= pos)
        if cfg.sliding_window:
            valid &= cpos > pos - cfg.sliding_window
        vmask = valid[None, None, None, None, :]
    scores = _grouped_scores(q, k)  # (B,Hkv,G,1,L)
    scores = jnp.where(vmask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _grouped_out(probs, v).astype(x.dtype)  # (B,1,H,hd)
    y = out.reshape(b, 1, -1) @ p["wo"]
    if quant:
        return y, QuantKVCache(kk, vv, kss, vss, cpos)
    return y, KVCache(k, v, cpos)


def attn_prefill_cache(
    cfg: ModelConfig,
    k: jax.Array,
    v: jax.Array,
    positions: jax.Array,
    max_len: int,
):
    """Build a cache from full-sequence K/V (used by prefill)."""
    b, s = k.shape[:2]
    l = cache_len(cfg, max_len)
    if s >= l:
        # keep the last l entries (ring layout: slot = pos % l)
        kk, vv = k[:, s - l :], v[:, s - l :]
        pp = positions[s - l :] if positions.ndim == 1 else positions[0, s - l :]
        # ring order
        slots = pp % l
        order = jnp.argsort(slots)
        kk, vv, pp = kk[:, order], vv[:, order], pp[order].astype(jnp.int32)
    else:
        pad = l - s
        kk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pp = positions if positions.ndim == 1 else positions[0]
        pp = jnp.pad(pp.astype(jnp.int32), (0, pad), constant_values=-1)
    if cfg.kv_quant:
        kq, ks = _quantize_kv(kk)
        vq, vs = _quantize_kv(vv)
        return QuantKVCache(kq, vq, ks, vs, pp)
    return KVCache(kk, vv, pp)


__all__ = [
    "attn_init",
    "attn_forward",
    "attn_decode",
    "attn_cache_init",
    "attn_prefill_cache",
    "KVCache",
    "QuantKVCache",
    "cache_len",
]
