"""Gated FFN (SwiGLU / GeGLU) + the Kron-compressed variant (paper feature).

``kron_ffn`` swaps the three dense projections for KronLinear factors —
the paper's ML-compression use case (Table 4 rows 6-8): parameters drop
from ``3*d*f`` to ``3*sum(P_i*Q_i)`` and every projection becomes a
FastKron Kron-Matmul.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.layers import (
    KronLinearSpec,
    kron_linear_apply,
    kron_linear_init,
)
from .common import act_fn, dense_init
from .config import ModelConfig


def ffn_init(key: jax.Array, cfg: ModelConfig, dtype, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.kron_ffn:
        up = KronLinearSpec.balanced(d, f, cfg.kron_factors)
        down = KronLinearSpec.balanced(f, d, cfg.kron_factors)
        return {
            "w1": kron_linear_init(k1, up, dtype),
            "w3": kron_linear_init(k2, up, dtype),
            "w2": kron_linear_init(k3, down, dtype),
        }
    return {
        "w1": dense_init(k1, d, f, dtype),
        "w3": dense_init(k2, d, f, dtype),
        "w2": dense_init(k3, f, d, dtype),
    }


def ffn_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    act = act_fn(cfg.ffn_act)
    if cfg.kron_ffn:
        h = act(kron_linear_apply(p["w1"], x)) * kron_linear_apply(p["w3"], x)
        return kron_linear_apply(p["w2"], h)
    h = act(x @ p["w1"]) * (x @ p["w3"])
    return h @ p["w2"]


__all__ = ["ffn_init", "ffn_apply"]
