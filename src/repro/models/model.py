"""Model assembly: embedding -> (prelude + scanned periodic stack) -> head.

The layer stack is scanned over the config's repeating *period* (Jamba:
9 scan steps of an 8-layer period; dense models: n_layers steps of 1), with
``jax.checkpoint`` around the scan body (full remat: only period boundaries
live during backward).  Irregular prefixes (DeepSeek's dense first layer)
are applied unscanned as the "prelude".

Three entry points:
  forward(cfg, params, tokens, embeds=None)        -> logits (train)
  prefill(cfg, params, tokens, max_len, ...)       -> (logits, cache)
  decode_step(cfg, params, cache, tokens, pos)     -> (logits, cache)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ffn as ffn_mod
from . import moe as moe_mod
from . import ssm
from ..runtime.sharding import constrain
from .common import embed_init, rms_norm
from .config import LayerSpec, ModelConfig


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _layer_init(key: jax.Array, cfg: ModelConfig, spec: LayerSpec, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if spec.kind == "attn":
        p["mixer"] = attn.attn_init(k1, cfg, dtype)
    else:
        p["mixer"] = ssm.mamba_init(k1, cfg, dtype)
    if spec.moe:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = moe_mod.moe_init(k2, cfg, dtype)
    elif cfg.d_ff:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = ffn_mod.ffn_init(k3, cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    plan = cfg.layer_plan()
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    params: dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, cfg.padded_vocab, cfg.d_model, dtype).T
    # prelude
    pre = cfg.prelude_len
    params["prelude"] = [
        _layer_init(jax.random.fold_in(k_layers, 1000 + i), cfg, plan[i], dtype)
        for i in range(pre)
    ]
    # periodic stack: one stacked entry per position in the period
    period, n_periods = cfg.period, cfg.n_periods
    stack = {}
    for pos in range(period):
        spec = plan[pre + pos]
        ks = jax.random.split(jax.random.fold_in(k_layers, pos), n_periods)
        stack[f"pos{pos}"] = jax.vmap(
            lambda kk: _layer_init(kk, cfg, spec, dtype)
        )(ks)
    params["stack"] = stack
    return params


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def _layer_forward(cfg, spec: LayerSpec, p, x, positions):
    """Full-sequence layer.  Returns (x, aux, kv_for_cache|state|None)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    cache_out = None
    if spec.kind == "attn":
        mix, kv = attn.attn_forward(cfg, p["mixer"], h, positions, return_kv=True)
        cache_out = kv
    else:
        mix, state = ssm.mamba_forward(cfg, p["mixer"], h, return_state=True)
        cache_out = state
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if spec.moe:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y, aux = moe_mod.moe_apply(cfg, p["ffn"], h2)
        x = x + y
    elif cfg.d_ff:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + ffn_mod.ffn_apply(cfg, p["ffn"], h2)
    return x, aux, cache_out


def _layer_decode(cfg, spec: LayerSpec, p, x, cache, pos):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == "attn":
        mix, cache = attn.attn_decode(cfg, p["mixer"], h, cache, pos)
    else:
        mix, cache = ssm.mamba_decode(cfg, p["mixer"], h, cache)
    x = x + mix
    if spec.moe:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y, _ = moe_mod.moe_apply(cfg, p["ffn"], h2)
        x = x + y
    elif cfg.d_ff:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + ffn_mod.ffn_apply(cfg, p["ffn"], h2)
    return x, cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed(cfg, params, tokens, embeds):
    x = params["embed"][tokens]  # (B, S, D) gather
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * math.sqrt(cfg.d_model)).astype(x.dtype)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return constrain(x, "batch", None, None)


def _head(cfg, params, x):
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    # mask padded vocab rows so they never win the softmax
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e9, logits.astype(jnp.float32))
    return constrain(logits.astype(jnp.float32), "batch", None, "tp")


# ---------------------------------------------------------------------------
# Forward (train) / prefill / decode
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    embeds: jax.Array | None = None,
):
    """Teacher-forced forward.  Returns (logits, aux_loss)."""
    x = _embed(cfg, params, tokens, embeds)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    plan = cfg.layer_plan()
    aux_total = jnp.zeros((), jnp.float32)

    for i, p_l in enumerate(params["prelude"]):
        x, aux, _ = _layer_forward(cfg, plan[i], p_l, x, positions)
        aux_total = aux_total + aux

    pre, period = cfg.prelude_len, cfg.period
    specs = tuple(plan[pre : pre + period])

    def one_layer(spec, p_l, x):
        y, aux, _ = _layer_forward(cfg, spec, p_l, x, positions)
        return y, aux

    if cfg.remat:
        # nested remat: the scan body is checkpointed (only period
        # boundaries survive the forward) AND each layer inside is
        # checkpointed (the period backward re-materializes one layer at a
        # time instead of all `period` layers at once — 8x live-memory cut
        # for Jamba's 8-layer period).
        one_layer = jax.checkpoint(one_layer, static_argnums=(0,))

    def body(carry, p_period):
        x, aux_acc = carry
        # Scan-carry boundaries are the remat-saved activations (one per
        # period, ALL live through the backward pass).  Pinning them
        # ("batch", None, "tp") stores each boundary d_model-sharded over
        # the model axis — Megatron-sequence-parallel-style — cutting the
        # dominant training buffer TP-fold (observed 16x: 10.7 GB -> 0.7 GB
        # per device on qwen2.5-32b).  The all-gather to recompute is one
        # (B_mb, S, D) gather per period per direction, already part of the
        # collective roofline term.
        x = constrain(x, "batch", None, "tp")
        for pos in range(period):
            x, aux = one_layer(specs[pos], p_period[f"pos{pos}"], x)
            aux_acc = aux_acc + aux
        return (constrain(x, "batch", None, "tp"), aux_acc), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["stack"])
    return _head(cfg, params, x), aux_total


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    plan = cfg.layer_plan()

    def one(spec: LayerSpec):
        if spec.kind == "attn":
            return attn.attn_cache_init(cfg, batch, max_len, dtype)
        return ssm.mamba_cache_init(cfg, batch, dtype)

    pre, period, n_periods = cfg.prelude_len, cfg.period, cfg.n_periods
    prelude = [one(plan[i]) for i in range(pre)]
    stack = {
        f"pos{pos}": jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n_periods,) + l.shape),
            one(plan[pre + pos]),
        )
        for pos in range(period)
    }
    return {"prelude": prelude, "stack": stack}


# ---------------------------------------------------------------------------
# Slot-form caches (continuous batching, docs/serving.md)
#
# ``prefill``/``init_cache`` build caches whose attention ``pos`` leaf is
# SHARED across the batch, shape (L,) — every row at the same position.
# Continuous batching mixes requests at different positions in one decode
# batch, so the serving engine converts to "slot form": pos per-row, (B, L),
# after which EVERY cache leaf carries the batch on one uniform axis
# (prelude: axis 0; scanned stack: axis 1, behind the n_periods axis) and
# whole requests can be moved between caches with a gather + scatter.
# ---------------------------------------------------------------------------


def _is_cache(x) -> bool:
    return isinstance(x, (attn.KVCache, attn.QuantKVCache))


def cache_to_slots(cache: dict, true_lens: jax.Array | None = None) -> dict:
    """Broadcast shared attention ``pos`` leaves to per-row (B, L).

    ``true_lens`` (B,) marks each row's real prompt length: a bucketed
    prefill pads every prompt to the bucket, and the pad tokens' K/V land
    in cache entries with position >= true_len — those entries are masked
    to pos = -1 (empty) so later decode steps never attend to pad keys.
    """

    def one(c, stacked: bool):
        if not _is_cache(c):
            return c  # MambaCache: batch-leading already, nothing shared
        pos = c.pos
        if stacked:  # (n_periods, L) -> (n_periods, B, L)
            b, l = c.k.shape[1], c.k.shape[2]
            if pos.ndim == 2:
                pos = jnp.broadcast_to(pos[:, None, :], (pos.shape[0], b, l))
        else:  # (L,) -> (B, L)
            b, l = c.k.shape[0], c.k.shape[1]
            if pos.ndim == 1:
                pos = jnp.broadcast_to(pos[None, :], (b, l))
        if true_lens is not None:
            tl = jnp.asarray(true_lens, jnp.int32)  # (B,)
            keep = pos < (tl[None, :, None] if stacked else tl[:, None])
            pos = jnp.where(keep, pos, -1)
        return c._replace(pos=pos.astype(jnp.int32))

    return {
        "prelude": [one(c, False) for c in cache["prelude"]],
        "stack": {
            k: jax.tree.map(lambda c: one(c, True), v, is_leaf=_is_cache)
            for k, v in cache["stack"].items()
        },
    }


def cache_take(cache: dict, row) -> dict:
    """Extract one request's cache rows as a batch-1 slot-form cache.
    Requires slot form (``cache_to_slots``); ``row`` may be traced."""
    row = jnp.asarray(row, jnp.int32)
    return {
        "prelude": jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, row, 1, axis=0),
            cache["prelude"],
        ),
        "stack": jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, row, 1, axis=1),
            cache["stack"],
        ),
    }


def cache_put(dst: dict, src: dict, slot) -> dict:
    """Write a batch-1 slot-form cache (``cache_take`` of a prefill) into
    decode slot ``slot`` of ``dst`` — the admission primitive of the
    continuous-batching engine.  Cache lengths L must match (both sides
    built with the same ``max_len``)."""
    slot = jnp.asarray(slot, jnp.int32)
    return {
        "prelude": jax.tree.map(
            lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                d, s.astype(d.dtype), slot, axis=0
            ),
            dst["prelude"], src["prelude"],
        ),
        "stack": jax.tree.map(
            lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                d, s.astype(d.dtype), slot, axis=1
            ),
            dst["stack"], src["stack"],
        ),
    }


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    max_len: int,
    embeds: jax.Array | None = None,
):
    """Full-sequence pass that also builds the decode cache."""
    x = _embed(cfg, params, tokens, embeds)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    plan = cfg.layer_plan()

    def to_cache(spec: LayerSpec, raw):
        if spec.kind == "attn":
            k, v = raw
            return attn.attn_prefill_cache(cfg, k, v, positions, max_len)
        conv_tail, h = raw
        return ssm.MambaCache(conv=conv_tail, h=h)

    prelude_cache = []
    for i, p_l in enumerate(params["prelude"]):
        x, _, raw = _layer_forward(cfg, plan[i], p_l, x, positions)
        prelude_cache.append(to_cache(plan[i], raw))

    pre, period = cfg.prelude_len, cfg.period
    specs = tuple(plan[pre : pre + period])

    def body(x, p_period):
        caches = {}
        for pos in range(period):
            x, _, raw = _layer_forward(
                cfg, specs[pos], p_period[f"pos{pos}"], x, positions
            )
            caches[f"pos{pos}"] = to_cache(specs[pos], raw)
        return x, caches

    x, stack_cache = jax.lax.scan(body, x, params["stack"])
    return _head(cfg, params, x), {"prelude": prelude_cache, "stack": stack_cache}


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,   # (B, 1)
    pos: jax.Array,      # scalar int32, or (B,) per-slot (slot-form cache)
):
    """One incremental token.  Returns (logits (B,1,V), new_cache).

    Scalar ``pos``: all rows at the same position (one-shot serving).
    Vector ``pos`` (B,): each decode slot on its own clock — requires the
    cache in slot form (``cache_to_slots``); see ``attn.attn_decode``."""
    x = _embed(cfg, params, tokens, None)
    plan = cfg.layer_plan()

    new_prelude = []
    for i, (p_l, c_l) in enumerate(zip(params["prelude"], cache["prelude"])):
        x, c_l = _layer_decode(cfg, plan[i], p_l, x, c_l, pos)
        new_prelude.append(c_l)

    pre, period = cfg.prelude_len, cfg.period
    specs = tuple(plan[pre : pre + period])

    def body(x, xs):
        p_period, c_period = xs
        new_c = {}
        for pos_i in range(period):
            x, c = _layer_decode(
                cfg, specs[pos_i], p_period[f"pos{pos_i}"], x,
                c_period[f"pos{pos_i}"], pos,
            )
            new_c[f"pos{pos_i}"] = c
        return x, new_c

    x, new_stack = jax.lax.scan(body, x, (params["stack"], cache["stack"]))
    return _head(cfg, params, x), {"prelude": new_prelude, "stack": new_stack}


__all__ = [
    "init_params",
    "forward",
    "prefill",
    "decode_step",
    "init_cache",
    "cache_to_slots",
    "cache_take",
    "cache_put",
]
