"""Model zoo: composable LM definitions covering the 10 assigned architectures.

Pure-functional JAX: ``init_params(cfg, key)`` builds a pytree;
``forward`` / ``prefill`` / ``decode_step`` are pure functions of it.
Layer stacks are scanned over the config's repeating layer *period* so a
72-layer hybrid lowers as 9 scan steps, not 72 inlined blocks.
"""
from .config import ModelConfig, MoEConfig, MambaConfig, LayerSpec  # noqa: F401
