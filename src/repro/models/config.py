"""Model configuration covering all assigned architecture families.

One frozen dataclass describes dense / GQA / MoE / SSM / hybrid / frontend-
stub models.  ``layer_plan`` expands it into the per-layer kinds; the stack
is scanned over the repeating *period* of that plan (hybrids like Jamba have
period 8: 1 attention + 7 mamba, MoE on odd positions).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Literal


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                     # per-expert FFN hidden dim
    n_shared: int = 0                 # DeepSeek-style always-on experts
    capacity_factor: float = 1.25
    every: int = 1                    # MoE layer period (Jamba: 2)
    offset: int = 0                   # first MoE layer index within period
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128                  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class LayerSpec:
    kind: Literal["attn", "mamba"]
    moe: bool


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                       # 0 => attention-free
    n_kv_heads: int
    d_ff: int                          # dense-FFN hidden (0 => no dense FFN)
    vocab: int
    head_dim: int | None = None        # default d_model // n_heads
    # attention
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int | None = None
    # ffn
    ffn_act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU)
    # moe / ssm / hybrid
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    attn_layer_period: int | None = None   # hybrid: 1 attn per this many
    attn_layer_offset: int = 0
    moe_skip_first: int = 0            # DeepSeek: first layer is dense
    # embeddings
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    embed_scale: bool = False          # Gemma multiplies embeds by sqrt(d)
    # modality frontend stub ([vlm]/[audio]): forward takes precomputed
    # frame/patch embeddings alongside (or instead of) token ids.
    frontend: str | None = None        # None | "vision" | "audio"
    n_frontend_tokens: int = 0         # patch/frame tokens prepended
    # kron compression (the paper's technique as a first-class feature)
    kron_ffn: bool = False
    kron_proj: bool = False
    kron_factors: int = 2
    # numerics / runtime
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 128      # pad embedding rows for TP
    remat: bool = True
    kv_quant: bool = False             # int8 KV cache (serving memory)

    # -- derived ----------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def attn_free(self) -> bool:
        return self.n_heads == 0

    def layer_plan(self) -> list[LayerSpec]:
        plan = []
        for i in range(self.n_layers):
            if self.attn_free:
                kind = "mamba"
            elif self.attn_layer_period is not None:
                kind = (
                    "attn"
                    if i % self.attn_layer_period == self.attn_layer_offset
                    else "mamba"
                )
            else:
                kind = "attn"
            moe = (
                self.moe is not None
                and i >= self.moe_skip_first
                and i % self.moe.every == self.moe.offset % self.moe.every
            )
            plan.append(LayerSpec(kind, moe))
        return plan

    @property
    def period(self) -> int:
        """Smallest repeating suffix period of the layer plan (after the
        irregular prefix ``prelude_len``)."""
        plan = self.layer_plan()[self.prelude_len:]
        n = len(plan)
        cand = 1
        if self.attn_layer_period:
            cand = math.lcm(cand, self.attn_layer_period)
        if self.moe:
            cand = math.lcm(cand, self.moe.every)
        # verify
        if n % cand == 0 and all(
            plan[i] == plan[i % cand] for i in range(n)
        ):
            return cand
        return n  # fallback: no scan sharing (single period)

    @property
    def prelude_len(self) -> int:
        """Leading layers that break the periodic pattern (unscanned)."""
        return self.moe_skip_first if self.moe is not None else 0

    @property
    def n_periods(self) -> int:
        return (self.n_layers - self.prelude_len) // self.period

    # -- parameter counting (for roofline MODEL_FLOPS) ---------------------

    def param_count(self, *, active_only: bool = False) -> int:
        d, hd = self.d_model, self.head_dim_
        total = self.padded_vocab * d  # embed
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        for spec in self.layer_plan():
            if spec.kind == "attn":
                total += d * self.n_heads * hd  # wq
                total += 2 * d * self.n_kv_heads * hd  # wk, wv
                total += self.n_heads * hd * d  # wo
            else:
                mc = self.mamba
                din = mc.d_inner(d)
                nh = mc.n_heads(d)
                conv_dim = din + 2 * mc.n_groups * mc.d_state
                total += d * (2 * din + 2 * mc.n_groups * mc.d_state + nh)
                total += conv_dim * mc.d_conv
                total += din * d  # out_proj
                total += 3 * nh  # A, D, dt_bias
            if spec.moe:
                mc = self.moe
                e = mc.top_k if active_only else mc.n_experts
                total += 3 * d * mc.d_expert * e + d * mc.n_experts  # router
                if mc.n_shared:
                    total += 3 * d * mc.d_expert * mc.n_shared
            elif self.d_ff:
                total += 3 * d * self.d_ff
        return total


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    base = dict(
        n_layers=max(2, cfg.period + cfg.prelude_len),
        d_model=64,
        n_heads=0 if cfg.attn_free else 4,
        n_kv_heads=0 if cfg.attn_free else max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16 if not cfg.attn_free else None,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        vocab_pad_multiple=32,
    )
    if cfg.moe is not None:
        # capacity_factor = E/k makes capacity == S: routing never drops, so
        # prefill+decode is bit-consistent with the full forward (drop
        # behaviour is unit-tested separately in tests/test_moe.py).
        base["moe"] = replace(
            cfg.moe, n_experts=4, top_k=2, d_expert=32,
            n_shared=min(cfg.moe.n_shared, 1), capacity_factor=2.0,
        )
    if cfg.mamba is not None:
        base["mamba"] = replace(
            cfg.mamba, d_state=16, head_dim=16, chunk=8,
        )
    if cfg.attn_layer_period is not None:
        base["n_layers"] = cfg.attn_layer_period
        base["attn_layer_offset"] = min(cfg.attn_layer_offset, base["n_layers"] - 1)
    if cfg.n_frontend_tokens:
        base["n_frontend_tokens"] = 4
    base.update(overrides)
    return replace(cfg, **base)


__all__ = ["ModelConfig", "MoEConfig", "MambaConfig", "LayerSpec", "reduced"]
