"""Mixture-of-Experts: token-choice top-k routing with capacity buckets.

Covers Mixtral (8e top-2), DeepSeek-MoE (2 shared + 64 routed top-6,
fine-grained) and Jamba (16e top-2, every other layer).

TPU-native formulation: instead of the (T, E, C) one-hot dispatch einsum
(O(T*E*C) memory) or a dense compute-all-experts pass (E/k x FLOPs waste),
tokens are ranked within their expert via an argsort, scattered into
(E, C, D) capacity buckets, processed with per-expert stacked-weight
einsums (``ecd,edf->ecf`` — MXU-friendly, expert axis shardable for expert
parallelism), and gathered back weighted by router probs.  Routing happens
per sequence (vmap over batch) so no collective crosses the batch axis.

Tokens beyond capacity are dropped (standard Switch-style accounting);
capacity_factor=1.25 default.  An auxiliary load-balancing loss is returned
for the trainer.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .common import dense_init
from .config import ModelConfig, MoEConfig
from .ffn import ffn_apply, ffn_init


def moe_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    mc = cfg.moe
    d, f, e = cfg.d_model, mc.d_expert, mc.n_experts
    ks = jax.random.split(key, 5)
    std = d ** -0.5
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "ew1": (jax.random.truncated_normal(ks[1], -2, 2, (e, d, f)) * std).astype(dtype),
        "ew3": (jax.random.truncated_normal(ks[2], -2, 2, (e, d, f)) * std).astype(dtype),
        "ew2": (jax.random.truncated_normal(ks[3], -2, 2, (e, f, d)) * (f ** -0.5)).astype(dtype),
    }
    if mc.n_shared:
        p["shared"] = ffn_init(ks[4], cfg, dtype, d_ff=mc.n_shared * f)
    return p


def _capacity(s: int, mc: MoEConfig) -> int:
    c = int(s * mc.top_k * mc.capacity_factor / mc.n_experts) + 1
    return min(max(8, -(-c // 8) * 8), s * mc.top_k)  # mult of 8, <= all slots


def _route_one_seq(x, router_logits, mc: MoEConfig, capacity: int):
    """x: (S, D); router_logits: (S, E) f32.  Returns (S, D) output + aux."""
    s, d = x.shape
    e, k = mc.n_experts, mc.top_k
    probs = jax.nn.softmax(router_logits, axis=-1)  # (S, E)
    top_p, top_i = jax.lax.top_k(probs, k)  # (S, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    e_flat = top_i.reshape(-1)  # (S*k,)
    w_flat = top_p.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(s), k)  # token of each slot

    # rank of each slot within its expert (stable by token order)
    order = jnp.argsort(e_flat, stable=True)  # (S*k,)
    sorted_e = e_flat[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))  # (E,)
    rank_sorted = jnp.arange(s * k) - seg_start[sorted_e]
    rank = jnp.zeros((s * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))

    keep = rank < capacity
    slot_e = jnp.where(keep, e_flat, 0)
    slot_c = jnp.where(keep, rank, 0)

    # dispatch as a GATHER, not a scatter: scatter the (tiny, int32) token
    # ids into the (E, C) index map, then gather rows of x by it.  XLA's
    # SPMD partitioner replicates large scatter updates (measured f32
    # all-reduces of the full (S*k, D) dispatch per layer, §Perf C3); the
    # index scatter is E*C*4 bytes, and gathers partition cleanly.
    src = jnp.full((e, capacity), -1, jnp.int32)
    src = src.at[slot_e, slot_c].set(
        jnp.where(keep, t_flat, -1).astype(jnp.int32), mode="drop"
    )
    return src, (slot_e, slot_c, w_flat, keep)


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array):
    """x: (B, S, D) -> (y, aux_loss)."""
    mc = cfg.moe
    b, s, d = x.shape
    capacity = _capacity(s, mc)

    router_logits = (x.astype(jnp.float32) @ p["router"])  # (B, S, E)

    # Routing is vmapped but touches only int32 index maps; ALL big-tensor
    # movement is batched take_along_axis gathers with pinned shardings —
    # XLA's scatter partitioner replicates large updates (measured f32
    # all-reduces of the whole (S*k, D) dispatch per layer, §Perf C3),
    # while gathers partition cleanly.
    src, metas = jax.vmap(
        lambda xi, li: _route_one_seq(xi, li, mc, capacity)
    )(x, router_logits)  # src: (B, E, C) int32
    slot_e, slot_c, w_flat, keep = metas

    from ..runtime.sharding import constrain

    e_tp = None  # expert axis role: "tp" when expert-parallel applies
    try:
        from ..runtime.sharding import ambient_mesh, _axes, _size

        mesh = ambient_mesh()
        if mesh is not None:
            _, tp_name = _axes(mesh)
            if mc.n_experts % _size(mesh, tp_name) == 0:
                e_tp = "tp"
    except Exception:
        pass

    e = mc.n_experts
    # dispatch: (B, E*C, D) gather from token-major x
    valid = src >= 0
    buckets = jnp.take_along_axis(
        x, jnp.clip(src.reshape(b, e * capacity), 0)[..., None], axis=1
    ).reshape(b, e, capacity, d)
    buckets = jnp.where(valid[..., None], buckets, jnp.zeros((), x.dtype))
    buckets = constrain(buckets, "batch", e_tp, None, None)

    act = jax.nn.silu if cfg.ffn_act == "silu" else partial(
        jax.nn.gelu, approximate=True
    )
    h = act(jnp.einsum("becd,edf->becf", buckets, p["ew1"])) * jnp.einsum(
        "becd,edf->becf", buckets, p["ew3"]
    )
    h = constrain(h, "batch", e_tp, None, "tp" if e_tp is None else None)
    buckets_out = jnp.einsum("becf,efd->becd", h, p["ew2"]).astype(x.dtype)
    buckets_out = constrain(buckets_out, "batch", e_tp, None, None)

    # combine: slot-major gather back + token-major reshape-sum (slots are
    # token-major by construction, so no scatter is ever needed)
    flat_idx = (slot_e * capacity + slot_c).astype(jnp.int32)  # (B, S*k)
    gathered = jnp.take_along_axis(
        buckets_out.reshape(b, e * capacity, d), flat_idx[..., None], axis=1
    )  # (B, S*k, D)
    gathered = constrain(gathered, "batch", None, None)
    contrib = gathered * jnp.where(keep, w_flat, 0.0)[..., None].astype(x.dtype)
    y = contrib.reshape(b, s, mc.top_k, d).sum(axis=2)

    # Switch-style load-balance aux: E * sum_e (frac_tokens_e * frac_prob_e)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top1 = jnp.argmax(router_logits, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top1, mc.n_experts, dtype=jnp.float32), axis=(0, 1)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = mc.n_experts * jnp.sum(frac_tokens * frac_probs)

    if mc.n_shared:
        y = y + ffn_apply(cfg, p["shared"], x)
    return y, aux


__all__ = ["moe_init", "moe_apply"]
