"""Mamba2 (SSD — state-space duality) block: chunked train/prefill scan +
O(1)-state recurrent decode.  [arXiv:2405.21060]

Recurrence (per head h, A scalar per head):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t (x) x_t      h: (N, P)
    y_t = C_t . h_t + D * x_t

Training uses the chunked SSD form: within a chunk the output is a masked
(C B^T)-weighted matmul (MXU-friendly); across chunks a short ``lax.scan``
carries the (H, N, P) state.  Projections are kept separate (z/x/B/C/dt)
rather than fused so each is cleanly TP-shardable.

Jamba note (DESIGN.md §Arch-applicability): Jamba-1.5 ships Mamba-1 layers;
we use this SSD block for its mamba positions — the TPU-native successor
formulation with the same state-space interface.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..runtime.sharding import constrain, tp_size
from .common import dense_init, rms_norm
from .config import ModelConfig


def _dims(cfg: ModelConfig):
    mc = cfg.mamba
    d = cfg.d_model
    din = mc.d_inner(d)
    nh = mc.n_heads(d)
    return mc, d, din, nh, mc.d_state, mc.n_groups


def mamba_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    mc, d, din, nh, n, g = _dims(cfg)
    conv_dim = din + 2 * g * n
    ks = jax.random.split(key, 8)
    # dt in [1e-3, 1e-1] log-uniform; store inverse-softplus as bias
    dt = jnp.exp(
        jax.random.uniform(ks[0], (nh,)) * (math.log(0.1) - math.log(1e-3))
        + math.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # softplus^-1
    a_init = jax.random.uniform(ks[1], (nh,), minval=1.0, maxval=16.0)
    return {
        "wz": dense_init(ks[2], d, din, dtype),
        "wx": dense_init(ks[3], d, din, dtype),
        "wb": dense_init(ks[4], d, g * n, dtype),
        "wc": dense_init(ks[5], d, g * n, dtype),
        "wdt": dense_init(ks[6], d, nh, dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "a_log": jnp.log(a_init).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "conv_w": (jax.random.normal(ks[7], (mc.d_conv, conv_dim)) *
                   (mc.d_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "norm": jnp.zeros((din,), dtype),
        "wo": dense_init(jax.random.fold_in(key, 99), din, d, dtype),
    }


def _proj_conv(cfg, p, x, conv_state=None):
    """Project + causal depthwise conv.  x: (B,S,D).
    Returns z, xh (B,S,H,P), bh/ch (B,S,G,N), dt (B,S,H) and new conv tail."""
    mc, d, din, nh, n, g = _dims(cfg)
    b, s, _ = x.shape
    z = x @ p["wz"]                       # (B,S,din)
    xbc = jnp.concatenate([x @ p["wx"], x @ p["wb"], x @ p["wc"]], axis=-1)
    width = mc.d_conv
    if conv_state is None:
        pad = jnp.zeros((b, width - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state
    xbc_pad = jnp.concatenate([pad, xbc], axis=1)  # (B, S+w-1, C)
    # causal depthwise conv as a sum of shifted slices (w is tiny: 4)
    out = jnp.zeros_like(xbc)
    for i in range(width):
        out = out + xbc_pad[:, i : i + s] * p["conv_w"][i]
    xbc = jax.nn.silu(out + p["conv_b"])
    new_tail = xbc_pad[:, -(width - 1):] if width > 1 else pad
    xh = xbc[..., :din].reshape(b, s, nh, mc.head_dim)
    bh = xbc[..., din : din + g * n].reshape(b, s, g, n)
    ch = xbc[..., din + g * n :].reshape(b, s, g, n)
    dt = jax.nn.softplus(
        (x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"]
    )  # (B,S,H) f32
    return z, xh, bh, ch, dt, new_tail


def _expand_groups(t: jax.Array, nh: int) -> jax.Array:
    """(B,S,G,N) -> (B,S,H,N) broadcasting each group over H/G heads."""
    b, s, g, n = t.shape
    rep = nh // g
    return jnp.broadcast_to(t[:, :, :, None, :], (b, s, g, rep, n)).reshape(
        b, s, nh, n
    )


def mamba_forward(
    cfg: ModelConfig, p: dict, x: jax.Array, *, return_state: bool = False
):
    """Chunked SSD scan.  x: (B,S,D) with chunk | S (pad upstream)."""
    mc, d, din, nh, n, g = _dims(cfg)
    b, s, _ = x.shape
    z, xh, bh, ch, dt, conv_tail = _proj_conv(cfg, p, x)
    # SSD streams ride the MODEL dtype (bf16 at scale — halves the dominant
    # HBM traffic, §Perf C2); only the decay/cumsum math and the carried
    # state stay f32.  Weight values are bounded (w <= dt_max), bf16-safe.
    sdt = x.dtype
    bh = _expand_groups(bh, nh).astype(sdt)
    ch = _expand_groups(ch, nh).astype(sdt)
    xh32 = xh.astype(sdt)
    a = -jnp.exp(p["a_log"])              # (H,) negative
    da = dt * a                           # (B,S,H) log-decay per step, f32

    lc = min(mc.chunk, s)
    if s % lc:
        lc = math.gcd(s, lc)
    nc = s // lc
    ph = mc.head_dim

    # NOTE §Perf C2 it3 (refuted): sharding the chunk axis over "tp" (SSD
    # context parallelism) was tried here and REVERTED — XLA inserted
    # resharding copies around the inter-chunk scan that cost more HBM
    # traffic than the head-dim fallback it replaced (1.93s -> 2.36s).

    def chunk(arr, feat_shape):
        return arr.reshape(b, nc, lc, *feat_shape)

    xc = chunk(xh32, (nh, ph))
    bc = chunk(bh, (nh, n))
    cc = chunk(ch, (nh, n))
    dac = chunk(da, (nh,))
    dtc = chunk(dt, (nh,))

    cum = jnp.cumsum(dac, axis=2)          # (B,nc,lc,H) inclusive, f32
    total = cum[:, :, -1:, :]              # (B,nc,1,H)

    # intra-chunk: Y[i] = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j X_j
    smat = jnp.einsum("bclhn,bckhn->bchlk", cc, bc)  # (B,nc,H,lc,lc)
    cum_t = jnp.swapaxes(cum, 2, 3)        # (B,nc,H,lc)
    logw = cum_t[..., :, None] - cum_t[..., None, :]  # cum_i - cum_j
    mask = jnp.tril(jnp.ones((lc, lc), bool))
    # mask in log space BEFORE exp: keeps gradients NaN-free (no inf * 0)
    logw = jnp.where(mask, logw, -1e30)
    dt_j = jnp.swapaxes(dtc, 2, 3)[..., None, :]      # (B,nc,H,1,lc)
    w = (jnp.exp(logw) * dt_j).astype(sdt)
    y_intra = jnp.einsum(
        "bchlk,bckhp->bclhp", smat * w, xc,
        preferred_element_type=jnp.float32,
    )

    # chunk states: S_c = sum_j exp(total - cum_j) dt_j B_j (x) X_j  (H,N,P)
    decay_to_end = (jnp.exp(total - cum) * dtc).astype(sdt)  # (B,nc,lc,H)
    sstate = jnp.einsum(
        "bclh,bclhn,bclhp->bchnp", decay_to_end, bc, xc,
        preferred_element_type=jnp.float32,
    )

    # inter-chunk scan over nc: h_c = h_{c-1} * exp(total_c) + S_c
    chunk_decay = jnp.exp(total[:, :, 0, :])  # (B,nc,H)

    def scan_body(h, inp):
        s_c, dec = inp                     # (B,H,N,P), (B,H)
        h_prev = h
        h = h * dec[..., None, None] + s_c
        return h, h_prev

    h0 = jnp.zeros((b, nh, n, ph), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        scan_body,
        h0,
        (jnp.swapaxes(sstate, 0, 1), jnp.swapaxes(chunk_decay, 0, 1)),
    )
    h_prevs = jnp.swapaxes(h_prevs, 0, 1)  # (B,nc,H,N,P) state entering chunk

    # inter contribution: Y[i] += C_i . (h_prev * exp(cum_i))
    y_inter = jnp.einsum(
        "bclhn,bchnp->bclhp",
        (cc * jnp.exp(cum).astype(sdt)[..., None]),
        h_prevs,
        preferred_element_type=jnp.float32,
    )

    y = (y_intra + y_inter).reshape(b, s, nh, ph)
    y = y + xh32 * p["d_skip"][:, None]
    y = y.reshape(b, s, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["wo"]
    if return_state:
        return out, (conv_tail, h_final)
    return out


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, conv_dim)
    h: jax.Array      # (B, H, N, P) f32


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype) -> MambaCache:
    mc, d, din, nh, n, g = _dims(cfg)
    conv_dim = din + 2 * g * n
    return MambaCache(
        conv=jnp.zeros((batch, mc.d_conv - 1, conv_dim), dtype),
        h=jnp.zeros((batch, nh, n, mc.head_dim), jnp.float32),
    )


def mamba_decode(
    cfg: ModelConfig, p: dict, x: jax.Array, cache: MambaCache
) -> tuple[jax.Array, MambaCache]:
    """One token.  x: (B, 1, D)."""
    mc, d, din, nh, n, g = _dims(cfg)
    b = x.shape[0]
    z, xh, bh, ch, dt, conv_tail = _proj_conv(cfg, p, x, conv_state=cache.conv)
    bh = _expand_groups(bh, nh).astype(jnp.float32)[:, 0]   # (B,H,N)
    ch = _expand_groups(ch, nh).astype(jnp.float32)[:, 0]
    xh32 = xh.astype(jnp.float32)[:, 0]                      # (B,H,P)
    dt = dt[:, 0]                                            # (B,H)
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt * a)                                    # (B,H)
    h = cache.h * dec[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, bh, xh32
    )
    y = jnp.einsum("bhn,bhnp->bhp", ch, h) + xh32 * p["d_skip"][:, None]
    y = y.reshape(b, 1, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["wo"], MambaCache(conv=conv_tail, h=h)


__all__ = [
    "mamba_init",
    "mamba_forward",
    "mamba_decode",
    "mamba_cache_init",
    "MambaCache",
]
