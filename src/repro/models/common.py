"""Shared model components: norms, RoPE, initializers, activations."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm: variance reduction in f32, tensor-wide math in the input
    dtype.  Keeping the (B,S,D)-wide intermediates bf16 matters at scale:
    XLA places the TP boundary collectives on whatever dtype the adjacent
    tensors carry — an all-f32 norm was measured to turn every residual
    psum/gather into f32 (2x collective bytes; EXPERIMENTS.md §Perf C1.it2)."""
    dt = x.dtype
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(dt)
    return x * inv * (1.0 + scale).astype(dt)


def dense_init(key: jax.Array, d_in: int, d_out: int, dtype) -> jax.Array:
    """Truncated-normal fan-in init (LeCun) — standard for LM projections."""
    std = d_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * std).astype(
        dtype
    )


def embed_init(key: jax.Array, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[
        name
    ]


__all__ = ["rms_norm", "dense_init", "embed_init", "apply_rope", "rope_freqs", "act_fn"]
