"""repro — FastKron on TPU: a JAX/Pallas Kron-Matmul training/inference framework.

Reproduction of "Fast Kronecker Matrix-Matrix Multiplication on GPUs"
(Jangda & Yadav, PPoPP 2024), adapted TPU-native and integrated as a
first-class feature (KronLinear) of a multi-pod LM framework.
"""

__version__ = "0.1.0"
