"""Data substrate: deterministic, shard-aware synthetic token pipeline."""
from .pipeline import SyntheticLM, make_batch  # noqa: F401
