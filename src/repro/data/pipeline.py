"""Deterministic synthetic LM data: learnable structure, shard-aware, O(1)
state (any batch index is reproducible from (seed, step) — restart-safe).

The stream is a noisy affine recurrence over token ids:
    t_{i+1} = (a * t_i + b + eta_i) mod vocab,   eta ~ {0, +-1, jump}
which a causal LM can compress far below uniform entropy — so training
tests can assert "loss decreases" without shipping a corpus.

Shard-awareness: ``SyntheticLM.global_batch(step)`` returns the full global
array (placed with the trainer's input sharding); per-host slicing for a
multi-process launch takes ``host_slice(step, proc_idx, n_procs)`` — the
same (seed, step) always yields the same global batch regardless of
topology, which is what makes elastic restarts deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


def make_batch(
    key: jax.Array, batch: int, seq_len: int, vocab: int
) -> tuple[jax.Array, jax.Array]:
    """Returns (tokens, labels) of shape (batch, seq_len) int32."""
    k1, k2, k3 = jax.random.split(key, 3)
    a = 5
    start = jax.random.randint(k1, (batch, 1), 0, vocab)
    noise = jax.random.bernoulli(k2, 0.1, (batch, seq_len + 1)).astype(jnp.int32)
    jumps = jax.random.randint(k3, (batch, seq_len + 1), 0, vocab) * noise

    def step(t, inp):
        t = (a * t + 7 + inp[:, None]) % vocab
        return t, t[:, 0]

    _, toks = jax.lax.scan(step, start, jnp.swapaxes(jumps, 0, 1))
    toks = jnp.swapaxes(toks, 0, 1)  # (batch, seq+1)
    return toks[:, :-1].astype(jnp.int32), toks[:, 1:].astype(jnp.int32)


@dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def global_batch(self, step: int) -> tuple[jax.Array, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return make_batch(key, self.batch, self.seq_len, self.vocab)

    def host_slice(
        self, step: int, proc_idx: int, n_procs: int
    ) -> tuple[jax.Array, jax.Array]:
        toks, labels = self.global_batch(step)
        per = self.batch // n_procs
        sl = slice(proc_idx * per, (proc_idx + 1) * per)
        return toks[sl], labels[sl]


__all__ = ["SyntheticLM", "make_batch"]
