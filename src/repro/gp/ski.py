"""Structured Kernel Interpolation (SKI / KISS-GP) with Kron-Matmul solves.

Paper §6.4: SKI approximates a GP kernel as ``W (K^1 (x) ... (x) K^D) W^T``
where each ``K^i`` is a 1-D kernel on a grid of P inducing points and ``W``
is a sparse interpolation matrix.  Training computes ``K^-1 V`` by
conjugate gradients whose hot operation is the Kron-Matmul of the CG
residual block with the Kronecker kernel — exactly what FastKron
accelerates (paper: up to 1.95x single-GPU, 6.2x on 16 GPUs).

The CG batch is M=16 rows as in the paper's experiments.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import cached_property, partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from ..core import kron as K
from ..core.engine import KronOp, kron_op_for


def rbf_kernel_1d(grid: jax.Array, lengthscale: float = 0.2) -> jax.Array:
    """(P, P) RBF kernel on a 1-D grid, jittered for PSD."""
    d = grid[:, None] - grid[None, :]
    k = jnp.exp(-0.5 * (d / lengthscale) ** 2)
    return k + 1e-4 * jnp.eye(grid.shape[0])


@dataclass(frozen=True)
class KronKernel:
    """K = (x)_i factors[i], each (P_i, P_i) PSD."""

    factors: tuple[jax.Array, ...]

    @property
    def dim(self) -> int:
        return math.prod(f.shape[0] for f in self.factors)

    @cached_property
    def op(self) -> KronOp:
        """The kernel's resolved KronOp — built once, reused by every CG
        iteration's MVM (cached_property writes through the frozen
        dataclass's __dict__)."""
        shapes = tuple(int(f.shape[0]) for f in self.factors)
        return kron_op_for(shapes, shapes)

    def matmul(self, v: jax.Array, *, backend: str = "fastkron") -> jax.Array:
        """v: (M, prod P) -> v @ K  (symmetric K: right-multiply == solve op)."""
        if backend == "fastkron":
            return self.op(v, self.factors)
        if backend == "shuffle":
            return K.kron_matmul_shuffle(v, list(self.factors))
        if backend == "naive":
            return K.kron_matmul_naive(v, list(self.factors))
        raise ValueError(backend)


@dataclass(frozen=True)
class BatchedKronKernel:
    """B independent Kronecker kernels with common factor shapes — the
    multi-kernel solve regime (one kernel per task / output / lengthscale in
    a hyperparameter sweep).  ``factors[i]: (B, P_i, P_i)``; every CG
    iteration's MVM runs all B kernels in ONE batched Kron-Matmul launch
    (per-sample factors) instead of a Python loop of B solves.
    """

    factors: tuple[jax.Array, ...]

    @property
    def batch(self) -> int:
        return int(self.factors[0].shape[0])

    @property
    def dim(self) -> int:
        return math.prod(int(f.shape[1]) for f in self.factors)

    @cached_property
    def op(self) -> KronOp:
        """The batched (per-sample-factors) KronOp, built once per kernel
        stack; ``op.with_mesh`` derivations are shared through the engine's
        bounded op cache."""
        shapes = tuple(int(f.shape[1]) for f in self.factors)
        return kron_op_for(
            shapes, shapes, batch=self.batch, shared_factors=False
        )

    def matmul(self, v: jax.Array, *, mesh=None) -> jax.Array:
        """v: (B, M, prod P) -> per-sample v_b @ K_b.

        ``mesh``: an optional ``(data, model)`` jax Mesh — the MVM then runs
        the mesh-derived op (v sharded rows-over-data / cols-over-model, ONE
        collective round per stage for all B kernels) instead of the
        single-device batched launch."""
        if mesh is not None:
            shapes = tuple(int(f.shape[1]) for f in self.factors)
            op = kron_op_for(
                shapes, shapes, batch=self.batch, shared_factors=False,
                mesh=mesh,
            )
            return op(v, self.factors)
        return self.op(v, self.factors)

    @classmethod
    def stack(cls, kernels: Sequence[KronKernel]) -> "BatchedKronKernel":
        """Stack same-shaped single kernels into one batched kernel."""
        n = len(kernels[0].factors)
        return cls(
            tuple(
                jnp.stack([k.factors[i] for k in kernels]) for i in range(n)
            )
        )


def interp_matrix(x: jax.Array, grid_sizes: Sequence[int]) -> jax.Array:
    """SKI's sparse W as a dense stand-in (test scale): nearest-two linear
    interpolation per dimension, Kronecker-composed per point.

    x: (n, D) in [0,1]^D.  Returns (n, prod P)."""
    n, d = x.shape
    ws = None
    for j, p in enumerate(grid_sizes):
        pos = jnp.clip(x[:, j] * (p - 1), 0, p - 1 - 1e-6)
        lo = jnp.floor(pos).astype(jnp.int32)
        frac = pos - lo
        w = jnp.zeros((n, p))
        w = w.at[jnp.arange(n), lo].set(1 - frac)
        w = w.at[jnp.arange(n), lo + 1].set(frac)
        ws = w if ws is None else jax.vmap(jnp.kron)(ws, w)
    return ws


def conjugate_gradient(
    matvec: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    *,
    iters: int = 10,
    tol: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Batched CG on rows of b: solves A x = b with A given as row-matvec.

    Fixed iteration count (paper: 10 CG iterations per epoch) under
    lax.scan so it jits once.  Returns (x, final residual norm per row).
    """
    x0 = jnp.zeros_like(b)
    r0 = b - matvec(x0)
    p0 = r0

    def body(carry, _):
        x, r, p, rs = carry
        ap = matvec(p)
        denom = jnp.sum(p * ap, axis=-1, keepdims=True)
        alpha = rs / jnp.maximum(denom, 1e-20)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.sum(r * r, axis=-1, keepdims=True)
        beta = rs_new / jnp.maximum(rs, 1e-20)
        p = r + beta * p
        return (x, r, p, rs_new), None

    rs0 = jnp.sum(r0 * r0, axis=-1, keepdims=True)
    (x, r, _, _), _ = jax.lax.scan(body, (x0, r0, p0, rs0), None, length=iters)
    return x, jnp.sqrt(jnp.sum(r * r, axis=-1))


def gp_train_epoch(
    kernel: KronKernel,
    v: jax.Array,
    *,
    noise: float = 0.1,
    cg_iters: int = 10,
    backend: str = "fastkron",
) -> tuple[jax.Array, jax.Array]:
    """One paper-style training epoch: solve (K + noise*I)^-1 V with CG.

    v: (M, dim) probe/batch block (M=16 in the paper's runs)."""

    def matvec(rows):
        return kernel.matmul(rows, backend=backend) + noise * rows

    return conjugate_gradient(matvec, v, iters=cg_iters)


def gp_train_epoch_batched(
    kernel: BatchedKronKernel,
    v: jax.Array,
    *,
    noise: float = 0.1,
    cg_iters: int = 10,
    mesh=None,
) -> tuple[jax.Array, jax.Array]:
    """Multi-kernel epoch: solve ``(K_b + noise*I)^-1 V_b`` for all B kernels
    at once.  ``v: (B, M, dim)``; CG runs on the whole stack (its reductions
    are per-row), so each iteration is one batched Kron-Matmul launch.

    ``mesh``: optional ``(data, model)`` Mesh — every CG iteration's MVM then
    runs the distributed batched path (paper §5 round schedule, one
    collective per stage for the whole kernel stack; the CG axpy/reduction
    arithmetic stays element-wise and sharding-transparent)."""

    def matvec(rows):
        return kernel.matmul(rows, mesh=mesh) + noise * rows

    return conjugate_gradient(matvec, v, iters=cg_iters)


__all__ = [
    "rbf_kernel_1d",
    "KronKernel",
    "BatchedKronKernel",
    "interp_matrix",
    "conjugate_gradient",
    "gp_train_epoch",
    "gp_train_epoch_batched",
]
