"""Gaussian-Process substrate for the paper's §6.4 case study (SKI/KISS-GP)."""
from .ski import (  # noqa: F401
    BatchedKronKernel,
    KronKernel,
    conjugate_gradient,
    gp_train_epoch,
    gp_train_epoch_batched,
    interp_matrix,
    rbf_kernel_1d,
)
