"""Fault-tolerance substrate: atomic sharded checkpointing."""
from .manager import CheckpointManager  # noqa: F401
