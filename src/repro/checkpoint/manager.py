"""Atomic, keep-k, optionally-async checkpointing with cross-mesh restore.

Layout:  <dir>/step_<n>/           (written as step_<n>.tmp then renamed)
             manifest.json         tree structure + shapes + dtypes
             leaf_<i>.npy          one file per pytree leaf

Fault-tolerance properties:
  * atomicity — a crash mid-save leaves only a ``.tmp`` dir that restore
    ignores and the next save garbage-collects;
  * keep-k    — bounded disk, oldest deleted after a successful rename;
  * async     — save thread copies to host then writes off the critical
    path (``wait()`` joins before the next save);
  * elasticity — restore takes a *target* pytree of ShapeDtypeStructs with
    NamedShardings for the CURRENT mesh: leaves are loaded full and
    device_put against the new topology, so a job checkpointed on one mesh
    restarts on another (different device count / axis split).

Multi-host note: this container is single-process; on a real pod each leaf
would be written as per-shard files by the shard-owning hosts (same
manifest format, ``process_index`` suffix) — the manifest already records
the byte layout needed.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            elif hasattr(k, "name"):
                parts.append(str(k.name))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        # snapshot to host while the device state is live
        host = [
            (path, np.asarray(jax.device_get(leaf)))
            for path, leaf in _leaf_paths(tree)
        ]
        treedef = jax.tree_util.tree_structure(tree)
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, str(treedef))
            )
            self._thread.start()
        else:
            self._write(step, host, str(treedef))

    def _write(self, step: int, host: list, treedef_repr: str) -> None:
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": [], "treedef": treedef_repr}
        for i, (path, arr) in enumerate(host):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"path": path, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)
        # orphaned tmp dirs from crashes
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: int | None = None) -> Any:
        """``target``: pytree of arrays or ShapeDtypeStructs (optionally with
        ``.sharding`` NamedShardings for the current mesh)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {l["path"]: l for l in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten(target)
        named = _leaf_paths(target)
        assert len(named) == len(flat)
        out = []
        for (path, tgt) in named:
            meta = by_path.get(path)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {path!r}")
            arr = np.load(os.path.join(d, meta["file"]))
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"shape mismatch for {path}: ckpt {arr.shape} vs target {tgt.shape}"
                )
            sharding = getattr(tgt, "sharding", None)
            dtype = tgt.dtype
            if sharding is not None and hasattr(sharding, "mesh"):
                out.append(jax.device_put(arr.astype(dtype), sharding))
            else:
                out.append(jnp.asarray(arr, dtype))
        return jax.tree_util.tree_unflatten(treedef, out)


__all__ = ["CheckpointManager"]
