"""Structured event output: the JSONL sink + the shared ``repro`` logger.

This is the thin I/O half of the KronScope telemetry spine
(``repro.runtime.telemetry``): telemetry decides WHAT to record, this module
decides WHERE it goes.  Two destinations:

* ``EventSink`` — an append-only JSONL file (one JSON object per line), the
  ``--telemetry out.jsonl`` target of the launchers and benchmark driver.
  Opened lazily on the first emit so configuring telemetry without ever
  recording costs no filesystem work; every write is a single line so a
  killed process leaves a valid prefix, never a torn file.

* ``get_logger`` — the shared ``repro`` logger hierarchy.  The root
  ``repro`` logger gets ONE stdout handler with a bare ``%(message)s``
  format, so routing the launchers' prints through it keeps their stdout
  byte-identical while making the stream capturable/redirectable like any
  stdlib logger (docs/observability.md).
"""
from __future__ import annotations

import json
import logging
import sys
import threading


class EventSink:
    """Append-only JSONL sink: one JSON object per ``emit``, one per line."""

    def __init__(self, path: str):
        self.path = str(path)
        self._f = None
        self._lock = threading.Lock()
        self.emitted = 0

    def emit(self, record: dict) -> None:
        line = json.dumps(record, default=str, sort_keys=True)
        with self._lock:
            if self._f is None:
                self._f = open(self.path, "a")
            self._f.write(line + "\n")
            self.emitted += 1

    def flush(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.flush()

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


_LOGGER_LOCK = threading.Lock()


class _StdoutHandler(logging.StreamHandler):
    """StreamHandler that resolves ``sys.stdout`` at emit time, not at
    construction — so the logger follows stdout redirection exactly like the
    ``print`` calls it replaced (the byte-identical-output promise)."""

    def __init__(self):
        super().__init__(sys.stdout)

    @property
    def stream(self):
        return sys.stdout

    @stream.setter
    def stream(self, value):  # base __init__/setStream assign; late-bound
        pass


def get_logger(name: str = "repro") -> logging.Logger:
    """The shared ``repro`` logger (or a child, e.g. ``repro.fault``).

    The root ``repro`` logger is configured once per process with a single
    stdout handler and a bare message format — callers that previously
    ``print``-ed keep identical stdout output, but operators can now raise
    the level, add handlers, or silence the hierarchy wholesale.
    """
    root = logging.getLogger("repro")
    with _LOGGER_LOCK:
        if not root.handlers:
            handler = _StdoutHandler()
            handler.setFormatter(logging.Formatter("%(message)s"))
            root.addHandler(handler)
            root.setLevel(logging.INFO)
            root.propagate = False
    return logging.getLogger(name) if name else root


__all__ = ["EventSink", "get_logger"]
