"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a while-loop body ONCE, so any scanned
model (layers under ``lax.scan``, microbatch accumulation, q-chunked
attention) is under-reported by the trip count — 24-100x here.  This module
re-derives FLOPs / HBM bytes / collective payloads from ``compiled.as_text()``
with every computation weighted by the product of enclosing
``known_trip_count``s (XLA records them in each while's backend_config).

Accounting conventions (per device, since post-SPMD HLO is per-participant):
  * dot flops      = 2 * prod(output shape) * prod(contracting dims)
  * elementwise    = prod(output shape) (add/mul/exp/...; matches XLA's
                     1-flop-per-element convention); reduce = input elems
  * bytes accessed = operands + outputs of every instruction in NON-fusion
                     computations (fusion internals live in registers/VMEM;
                     the fusion boundary is what touches HBM)
  * collectives    = payload bytes by op kind (from hlo_analysis), weighted
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from .hlo_analysis import COLLECTIVE_OPS, shape_bytes

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(?P<type>\([^=]*?\)|[\w\[\]{},: ]+?)\s*"
    r"(?P<op>[\w\-]+)\((?P<args>.*)$"
)
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_SHAPE_DIMS = re.compile(r"\w+\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "negate", "abs", "compare", "select", "and", "or",
    "xor", "not", "sign", "floor", "ceil", "round-nearest-afz", "clamp",
    "cosine", "sine", "logistic", "atan2", "remainder", "cbrt", "erf",
}
_FREE = {
    "parameter", "tuple", "get-tuple-element", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "custom-call", "rng-bit-generator", "rng-get-and-update-state",
    "get-dimension-size", "domain", "opt-barrier",
}


@dataclass
class Instr:
    name: str
    op: str
    type_str: str
    args: str


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    types: dict[str, str] = field(default_factory=dict)


_COMMENT = re.compile(r"/\*.*?\*/")


def _parse(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        line = _COMMENT.sub("", line)  # strip /*index=N*/ tuple comments
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and ("->" in line):
                cur = Computation(m.group(1))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group(1), m.group("op"), m.group("type"), m.group("args"))
            cur.instrs.append(ins)
            cur.types[ins.name] = ins.type_str
    return comps


def _dims(type_str: str) -> list[int]:
    m = _SHAPE_DIMS.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",") if d]


def _elems(type_str: str) -> int:
    out = 1
    for d in _dims(type_str):
        out *= d
    return out


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out = _elems(ins.type_str)
    k = 1
    cm = _CONTRACT.search(ins.args)
    ops = _OPERAND.findall(ins.args.split(")", 1)[0])
    if cm and ops:
        lhs_t = comp.types.get(ops[0], "")
        dims = _dims(lhs_t)
        for ci in (int(c) for c in cm.group(1).split(",") if c):
            if ci < len(dims):
                k *= dims[ci]
    return 2.0 * out * k


@dataclass
class HloCost:
    flops: float = 0.0
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict[str, float] = field(default_factory=dict)
    collective_counts: dict[str, float] = field(default_factory=dict)
    trip_weighted: bool = True

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str) -> HloCost:
    comps = _parse(text)
    if not comps:
        return HloCost()

    # computations reached via fusion/to_apply are "internal": their bytes
    # never touch HBM; their flops count at the call site's weight.
    fusion_internal: set[str] = set()
    for comp in comps.values():
        for ins in comp.instrs:
            for m in _CALLS.finditer(ins.args):
                fusion_internal.add(m.group(1))
            for m in _TO_APPLY.finditer(ins.args):
                fusion_internal.add(m.group(1))

    # entry = computation not referenced anywhere
    referenced: set[str] = set(fusion_internal)
    for comp in comps.values():
        for ins in comp.instrs:
            for pat in (_BODY, _COND):
                m = pat.search(ins.args)
                if m:
                    referenced.add(m.group(1))
            m = _BRANCHES.search(ins.args)
            if m:
                referenced.update(
                    s.strip().lstrip("%") for s in m.group(1).split(",")
                )
    entries = [n for n in comps if n not in referenced]

    weights: dict[str, float] = {n: 0.0 for n in comps}

    def visit(name: str, w: float) -> None:
        comp = comps.get(name)
        if comp is None:
            return
        weights[name] = weights.get(name, 0.0) + w
        for ins in comp.instrs:
            if ins.op == "while":
                trip = 1
                tm = _TRIP.search(ins.args)
                if tm:
                    trip = int(tm.group(1))
                bm, cm_ = _BODY.search(ins.args), _COND.search(ins.args)
                if bm:
                    visit(bm.group(1), w * trip)
                if cm_:
                    visit(cm_.group(1), w * (trip + 1))
            elif ins.op == "conditional":
                m = _BRANCHES.search(ins.args)
                if m:
                    for s in m.group(1).split(","):
                        visit(s.strip().lstrip("%"), w)  # upper bound
            else:
                for m in _CALLS.finditer(ins.args):
                    visit(m.group(1), w)
                # reducers (to_apply) are per-element; folded into reduce cost

    for e in entries:
        visit(e, 1.0)

    cost = HloCost()
    for name, comp in comps.items():
        w = weights.get(name, 0.0)
        if w == 0.0:
            continue
        is_internal = name in fusion_internal
        for ins in comp.instrs:
            # flops
            if ins.op in ("dot", "dot-general"):
                f = _dot_flops(ins, comp) * w
                cost.flops += f
                cost.dot_flops += f
            elif ins.op == "convolution":
                cost.flops += 2.0 * _elems(ins.type_str) * w  # lower bound
            elif ins.op in _ELEMENTWISE:
                cost.flops += _elems(ins.type_str) * w
            elif ins.op in ("reduce", "reduce-window"):
                ops = _OPERAND.findall(ins.args.split(")", 1)[0])
                in_elems = _elems(comp.types.get(ops[0], "")) if ops else 0
                cost.flops += in_elems * w
            # collectives
            base = ins.op.removesuffix("-start")
            if base in COLLECTIVE_OPS:
                b = shape_bytes(ins.type_str) * w
                cost.collective_bytes[base] = (
                    cost.collective_bytes.get(base, 0.0) + b
                )
                cost.collective_counts[base] = (
                    cost.collective_counts.get(base, 0.0) + w
                )
            # bytes: fusion boundaries only
            if not is_internal and ins.op not in _FREE:
                b = shape_bytes(ins.type_str)
                for opnd in _OPERAND.findall(ins.args.split("),", 1)[0]):
                    b += shape_bytes(comp.types.get(opnd, ""))
                cost.bytes_accessed += b * w
    return cost


__all__ = ["analyze", "HloCost"]
