"""Deterministic, seeded fault injection for the Kron-Matmul spine.

Tests (and brave operators) force failures at named **sites** inside the
execution path so every degradation rung in ``repro.runtime.guard`` is
exercised on purpose instead of by accident:

=================  ========================================================
site               where ``maybe_fail`` is called
=================  ========================================================
``pallas_lowering``  ``kernels/emit.py`` before building a pallas chain
``stage_execute``    ``kernels/emit.py`` ``run_stage``/``run_stage_grad``
``per_factor``       ``core/engine.py`` per-factor sliced rung
``round_chain``      ``core/distributed.py`` fused chain in a mesh round
``collective``       ``core/distributed.py`` before the all_to_all
``slab_collective``  ``core/distributed.py`` one slab's all_to_all in a
                     pipelined round (fires only when ``n_slabs > 1``)
``plan_cache_load``  ``core/autotune.py`` cache read
``plan_cache_save``  ``core/autotune.py`` cache write attempt
``root_refresh``     ``optim/shampoo.py`` inverse-root refresh
=================  ========================================================

Activation is layered: ``inject(spec)`` pushes a parsed spec onto a stack
for a ``with`` block; the ``FASTKRON_CHAOS`` env var forms a base layer
read at import.  A spec string is a comma list of clauses::

    site[:key=value]*          e.g.  "stage_execute"
                                     "collective:p=0.5:seed=7"
                                     "plan_cache_save:times=2,round_chain"

Keys: ``p`` (firing probability, default 1.0), ``seed`` (determinism,
default 0), ``times`` (fire at most N times, default unlimited), ``after``
(skip the first N eligible hits, default 0).  Firing for ``p < 1`` is a
pure function of ``(seed, site, hit-index)`` — a given spec replays
identically run to run, which is what lets chaos tests assert bitwise
parity with an unfaulted reference.

When no spec is active ``maybe_fail`` is a single truthiness check — the
hot path pays nothing.
"""
from __future__ import annotations

import dataclasses
import os
import random
import threading
from contextlib import contextmanager

from repro.runtime import guard, telemetry

# site -> error type raised when the site fires
SITE_ERRORS = {
    "pallas_lowering": guard.LoweringError,
    "stage_execute": guard.VmemOverflowError,
    "per_factor": guard.VmemOverflowError,
    "round_chain": guard.VmemOverflowError,
    "collective": guard.CollectiveError,
    # Slab pipeline: fires per slab relocation when a round is slab-
    # pipelined (n_slabs > 1) — the guard ladder must degrade slabbed →
    # serial rounds → local, never corrupt the round schedule.
    "slab_collective": guard.CollectiveError,
    # Serving: fires inside the engine's bucketed prefill, before a group
    # is admitted to decode slots — the guard ladder must degrade to a
    # smaller prefill chunk, never drop the request (docs/serving.md).
    "serve_admit": guard.VmemOverflowError,
    # Optimizer: fires inside the Shampoo inverse-root refresh — the
    # affected layers must degrade to grafted AdamW for the interval, never
    # crash the training step (docs/optim.md).
    "root_refresh": guard.NumericsError,
    "plan_cache_load": guard.PlanCacheError,
    "plan_cache_save": guard.PlanCacheError,
}


@dataclasses.dataclass
class ChaosSpec:
    """One injection clause: fire ``site`` with probability ``p``."""

    site: str
    p: float = 1.0
    seed: int = 0
    times: int | None = None  # max firings; None = unlimited
    after: int = 0            # skip this many eligible hits first
    seen: int = 0             # eligible hits observed (mutates)
    fired: int = 0            # actual failures raised (mutates)

    def should_fire(self) -> bool:
        idx = self.seen
        self.seen += 1
        if idx < self.after:
            return False
        if self.times is not None and self.fired >= self.times:
            return False
        if self.p >= 1.0:
            fire = True
        else:
            # deterministic per (seed, site, hit-index): replays identically
            # (str seeds hash stably across processes, unlike tuples)
            rng = random.Random(f"{self.seed}:{self.site}:{idx}")
            fire = rng.random() < self.p
        if fire:
            self.fired += 1
        return fire


def parse_spec(text: str) -> list[ChaosSpec]:
    """Parse a ``FASTKRON_CHAOS``-style spec string (format in moduledoc)."""
    specs: list[ChaosSpec] = []
    for clause in text.split(","):
        clause = clause.strip()
        if not clause:
            continue
        parts = clause.split(":")
        site = parts[0].strip()
        if site not in SITE_ERRORS:
            raise guard.PlanError(
                f"unknown chaos site {site!r}: want one of "
                f"{sorted(SITE_ERRORS)}"
            )
        kwargs: dict = {}
        for kv in parts[1:]:
            if "=" not in kv:
                raise guard.PlanError(f"bad chaos clause {clause!r}: {kv!r}")
            k, v = kv.split("=", 1)
            k = k.strip()
            if k == "p":
                kwargs[k] = float(v)
            elif k in ("seed", "times", "after"):
                kwargs[k] = int(v)
            else:
                raise guard.PlanError(
                    f"unknown chaos key {k!r} in clause {clause!r}"
                )
        specs.append(ChaosSpec(site=site, **kwargs))
    return specs


_LOCK = threading.Lock()
_ACTIVE: list[list[ChaosSpec]] = []


def _env_layer() -> list[ChaosSpec]:
    text = os.environ.get("FASTKRON_CHAOS", "")
    return parse_spec(text) if text else []


_ENV: list[ChaosSpec] = _env_layer()


def reload_env() -> list[ChaosSpec]:
    """Re-read ``FASTKRON_CHAOS`` (tests that mutate the env after import)."""
    global _ENV
    _ENV = _env_layer()
    return _ENV


@contextmanager
def inject(spec: str | list[ChaosSpec]):
    """Activate a chaos spec for the dynamic extent of the ``with`` block.

    Yields the parsed ``ChaosSpec`` list so callers can inspect ``seen`` /
    ``fired`` counters afterwards.  Layers stack: nested ``inject`` blocks
    are all consulted.
    """
    specs = parse_spec(spec) if isinstance(spec, str) else list(spec)
    with _LOCK:
        _ACTIVE.append(specs)
    try:
        yield specs
    finally:
        with _LOCK:
            _ACTIVE.remove(specs)


def active() -> bool:
    """True when any injection layer (env or ``inject``) is live."""
    return bool(_ACTIVE) or bool(_ENV)


def maybe_fail(site: str) -> None:
    """Raise the site's typed error if an active spec says so.  No-op (one
    truthiness check) when no chaos is active."""
    if not _ACTIVE and not _ENV:
        return
    for layer in list(_ACTIVE) + ([_ENV] if _ENV else []):
        for spec in layer:
            if spec.site == site and spec.should_fire():
                telemetry.event("chaos_injected", site=site, fired=spec.fired)
                raise SITE_ERRORS[site](
                    f"chaos-injected fault at site {site!r} "
                    f"(firing {spec.fired}/{spec.times or 'inf'})"
                )


__all__ = [
    "ChaosSpec",
    "SITE_ERRORS",
    "parse_spec",
    "inject",
    "active",
    "maybe_fail",
    "reload_env",
]
