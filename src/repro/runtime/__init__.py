"""Runtime substrate: mesh/sharding helpers, HLO analysis, fault tolerance,
the execution guard layer (``guard``: error taxonomy + degradation ladder +
numerics policy) and its deterministic fault-injection harness (``chaos``)."""
