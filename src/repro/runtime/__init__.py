"""Runtime substrate: mesh/sharding helpers, HLO analysis, fault tolerance,
the execution guard layer (``guard``: error taxonomy + degradation ladder +
numerics policy), its deterministic fault-injection harness (``chaos``),
and the KronScope telemetry spine (``telemetry``: spans, metrics, per-stage
profiling, cost-model drift; ``events``: JSONL sink + shared logger)."""
