"""Runtime substrate: mesh/sharding helpers, HLO analysis, fault tolerance."""
