"""KronScope: the process-local tracing/metrics spine (docs/observability.md).

One telemetry layer for the whole Kron-Matmul execution path — plan →
emit → execute → collectives — so every later perf PR starts from measured
evidence instead of scattered prints.  Three pieces:

* **Spans** — ``span("round", k=2)`` times a region host-side
  (``perf_counter``) and, while telemetry is active, wraps it in
  ``jax.named_scope`` + ``jax.profiler.TraceAnnotation`` so the region is
  attributable in compiled HLO metadata and XLA device profiles.  Spans
  nest; each completed span also feeds the ``span.<name>`` histogram.

* **Metrics** — a registry of counters (``counter_inc``), gauges
  (``gauge_set``) and histograms (``observe``, with p50/p95/p99 via
  ``percentiles``), fed by the existing subsystems: plan-cache hit/miss
  (autotune), ladder rung transitions and chaos injections (guard/chaos),
  straggler flags (runtime.fault), per-round ``comm_elems_per_device``
  (distributed), decode tokens/s and step latency (the launchers).

* **Export** — every span and event streams to a JSONL sink
  (``repro.runtime.events.EventSink``) and completed spans export as a
  Chrome-trace JSON (``chrome://tracing`` / Perfetto) via
  ``write_chrome_trace``; ``--telemetry out.jsonl --trace out.trace.json``
  on the launchers and ``benchmarks/run.py`` wires both.

Disabled (the default) the layer is inert: every instrumentation site costs
one module-global truthiness check, NO ``named_scope``/``TraceAnnotation``
enters traced code, and compiled HLO is bitwise-identical to a build without
telemetry — pinned by ``tests/test_telemetry.py`` exactly like the guard
layer's zero-overhead pin (EXPERIMENTS.md §Robustness).  Like guard health,
activation is trace-time state: functions compiled before ``configure()``
keep their un-annotated executables.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time

from .events import EventSink

# Bounded in-memory buffers: telemetry must never become the memory leak it
# exists to find.  Oldest entries drop first; drops are counted, not silent.
SPAN_BUFFER = 65536
HIST_BUFFER = 8192

DRIFT_THRESHOLD = 2.0  # default measured/predicted per-stage drift ratio flag


class _Telemetry:
    """The live telemetry state; exists only while telemetry is active."""

    def __init__(self, jsonl=None, trace=None, annotate: bool = True):
        self.t0 = time.perf_counter()
        self.started_at = time.strftime("%Y-%m-%dT%H:%M:%S")
        self.annotate = bool(annotate)
        self.sink = EventSink(jsonl) if jsonl else None
        self.trace_path = str(trace) if trace else None
        self.lock = threading.RLock()
        self.tls = threading.local()
        self.counters: dict[str, int] = {}
        self.gauges: dict[str, float] = {}
        self.hists: dict[str, list[float]] = {}
        self.spans: list[dict] = []
        self.dropped_spans = 0
        self.n_events = 0
        self.last_profile: dict | None = None

    def stack(self) -> list:
        s = getattr(self.tls, "stack", None)
        if s is None:
            s = self.tls.stack = []
        return s


_STATE: _Telemetry | None = None


def active() -> bool:
    """True while telemetry is configured — the one check every site pays."""
    return _STATE is not None


def configure(jsonl=None, trace=None, *, annotate: bool = True) -> None:
    """Activate telemetry for the process.

    ``jsonl``: path for the JSONL event stream (None = in-memory only).
    ``trace``: path ``shutdown()`` writes the Chrome trace to.
    ``annotate``: wrap spans in ``jax.named_scope``/``TraceAnnotation``
    (disable to keep compiled HLO pristine while still timing host-side).
    Reconfiguring replaces the previous state (its sink is closed).
    """
    global _STATE
    old, _STATE = _STATE, _Telemetry(jsonl, trace, annotate=annotate)
    if old is not None and old.sink is not None:
        old.sink.close()


def disable() -> None:
    """Deactivate without exporting; the sink is closed, buffers dropped."""
    global _STATE
    old, _STATE = _STATE, None
    if old is not None and old.sink is not None:
        old.sink.close()


def reset() -> None:
    """Tests: drop all telemetry state and deactivate."""
    disable()


def shutdown() -> dict | None:
    """Finalize: write the Chrome trace (if configured), flush and close the
    JSONL sink, deactivate.  Returns the final ``snapshot()`` (None if
    telemetry was not active) — the launchers print it as their one merged
    exit report through ``guard.health_report()``."""
    st = _STATE
    if st is None:
        return None
    snap = snapshot()
    if st.trace_path:
        write_chrome_trace(st.trace_path)
    disable()
    return snap


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------


class _NullSpan:
    """Shared no-op span: the entire off-path cost of a ``span()`` site."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("state", "name", "attrs", "depth", "t_start", "_ns", "_ta")

    def __init__(self, state: _Telemetry, name: str, attrs: dict):
        self.state = state
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        st = self.state
        stack = st.stack()
        self.depth = len(stack)
        stack.append(self.name)
        if st.annotate:
            import jax

            self._ns = jax.named_scope(f"kronscope.{self.name}")
            self._ns.__enter__()
            self._ta = jax.profiler.TraceAnnotation(f"kronscope.{self.name}")
            self._ta.__enter__()
        else:
            self._ns = self._ta = None
        self.t_start = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self.t_start
        st = self.state
        if self._ta is not None:
            self._ta.__exit__(*exc)
            self._ns.__exit__(*exc)
        stack = st.stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        rec = {
            "name": self.name,
            "ts": self.t_start - st.t0,
            "dur": dur,
            "depth": self.depth,
            "tid": threading.get_ident(),
        }
        if self.attrs:
            rec["attrs"] = self.attrs
        with st.lock:
            st.spans.append(rec)
            if len(st.spans) > SPAN_BUFFER:
                del st.spans[0]
                st.dropped_spans += 1
            _observe_locked(st, f"span.{self.name}", dur)
        if st.sink is not None:
            st.sink.emit({"kind": "span", **rec})
        return False


def span(name: str, **attrs):
    """Context manager timing a region; a shared no-op when inactive.

    Active: records host wall time, nests (depth tracked per thread), wraps
    the region in ``jax.named_scope`` + ``jax.profiler.TraceAnnotation``
    (prefix ``kronscope.``), streams to the JSONL sink, and feeds the
    ``span.<name>`` histogram.
    """
    st = _STATE
    if st is None:
        return _NULL_SPAN
    return _Span(st, name, attrs)


def record_span(name: str, start: float, dur: float, **attrs) -> None:
    """Inject a completed span directly, bypassing the nesting stack.

    ``span()`` assumes strictly nested regions (one per-thread stack) —
    per-REQUEST lifetimes in the serving engine overlap arbitrarily (a
    request admitted mid-decode outlives requests that started before it),
    so the engine times them itself and injects the finished interval here.
    ``start`` is an absolute ``time.perf_counter()`` stamp; the span lands
    in the same buffer/sink/histogram pipeline as ``span()`` (depth 0).
    No-op while inactive."""
    st = _STATE
    if st is None:
        return
    rec = {
        "name": name,
        "ts": float(start) - st.t0,
        "dur": float(dur),
        "depth": 0,
        "tid": threading.get_ident(),
    }
    if attrs:
        rec["attrs"] = attrs
    with st.lock:
        st.spans.append(rec)
        if len(st.spans) > SPAN_BUFFER:
            del st.spans[0]
            st.dropped_spans += 1
        _observe_locked(st, f"span.{name}", float(dur))
    if st.sink is not None:
        st.sink.emit({"kind": "span", **rec})


# ---------------------------------------------------------------------------
# Events
# ---------------------------------------------------------------------------


def event(name: str, **fields) -> None:
    """Record a structured event: counted (``event.<name>``) and streamed to
    the JSONL sink.  One truthiness check when inactive."""
    st = _STATE
    if st is None:
        return
    with st.lock:
        st.n_events += 1
        key = f"event.{name}"
        st.counters[key] = st.counters.get(key, 0) + 1
    if st.sink is not None:
        st.sink.emit(
            {"kind": "event", "name": name,
             "ts": time.perf_counter() - st.t0, **fields}
        )


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def counter_inc(name: str, n: int = 1) -> None:
    """Add ``n`` to counter ``name`` (no-op while inactive)."""
    st = _STATE
    if st is None:
        return
    with st.lock:
        st.counters[name] = st.counters.get(name, 0) + n


def gauge_set(name: str, value) -> None:
    """Set gauge ``name`` to ``value`` (last write wins; no-op inactive)."""
    st = _STATE
    if st is None:
        return
    with st.lock:
        st.gauges[name] = value


def observe(name: str, value: float) -> None:
    """Record one sample into histogram ``name`` (no-op while inactive)."""
    st = _STATE
    if st is None:
        return
    with st.lock:
        _observe_locked(st, name, float(value))


def _observe_locked(st: _Telemetry, name: str, value: float) -> None:
    h = st.hists.get(name)
    if h is None:
        h = st.hists[name] = []
    h.append(value)
    if len(h) > HIST_BUFFER:
        del h[0]


def _pcts(values: list[float]) -> dict:
    v = sorted(values)
    n = len(v)

    def at(q: float) -> float:
        return v[min(n - 1, int(q * (n - 1)))]

    return {
        "count": n,
        "min": v[0],
        "max": v[-1],
        "mean": sum(v) / n,
        "p50": at(0.50),
        "p95": at(0.95),
        "p99": at(0.99),
    }


def percentiles(name: str) -> dict | None:
    """``{count, min, max, mean, p50, p95, p99}`` for histogram ``name``
    (index-based percentiles on the retained samples), or None."""
    st = _STATE
    if st is None:
        return None
    with st.lock:
        h = st.hists.get(name)
        return _pcts(h) if h else None


def snapshot() -> dict:
    """The full registry as plain data: counters, gauges, histogram
    summaries, span/event totals, and the last ``KronOp.profile`` stamp.
    ``guard.health_report()`` embeds this so launchers print ONE report."""
    st = _STATE
    if st is None:
        return {}
    with st.lock:
        return {
            "started_at": st.started_at,
            "counters": dict(st.counters),
            "gauges": dict(st.gauges),
            "histograms": {k: _pcts(v) for k, v in st.hists.items() if v},
            "spans": len(st.spans) + st.dropped_spans,
            "events": st.n_events,
            "last_profile": st.last_profile,
        }


def comm_summary() -> dict:
    """Aggregate the distributed comm gauges into per-round structure.

    The mesh rounds gauge ``comm.round<k>.elems_per_device`` (round total)
    and, when the round is slab-pipelined, ``comm.round<k>.slab<s>.
    elems_per_device`` per slab.  Returns ``{round: {"total": float,
    "slabs": [per-slab payloads in slab order], "hidden": float}}`` where
    ``hidden`` is the overlap accounting the gauges imply — everything except
    one exposed slab per round (0 for serial rounds).  ``KronOp.profile()``
    reconciles ``KronCost.comm_hidden_elems`` against this; ``{}`` while
    inactive or before any mesh round ran."""
    st = _STATE
    if st is None:
        return {}
    pat = re.compile(r"^comm\.round(\d+)\.(?:slab(\d+)\.)?elems_per_device$")
    rounds: dict[int, dict] = {}
    with st.lock:
        items = list(st.gauges.items())
    for name, value in items:
        m = pat.match(name)
        if m is None:
            continue
        k = int(m.group(1))
        rec = rounds.setdefault(k, {"total": 0.0, "slabs": {}})
        if m.group(2) is None:
            rec["total"] = float(value)
        else:
            rec["slabs"][int(m.group(2))] = float(value)
    out: dict[int, dict] = {}
    for k, rec in sorted(rounds.items()):
        slabs = [rec["slabs"][s] for s in sorted(rec["slabs"])]
        hidden = rec["total"] - max(slabs) if len(slabs) > 1 else 0.0
        out[k] = {"total": rec["total"], "slabs": slabs, "hidden": hidden}
    return out


def summary_line() -> str:
    """One-line state summary (``KronOp.describe()`` appends this while
    telemetry is active)."""
    st = _STATE
    if st is None:
        return "kronscope[off]"
    with st.lock:
        prof = st.last_profile["at"] if st.last_profile else "never"
        return (
            f"kronscope[spans={len(st.spans) + st.dropped_spans} "
            f"events={st.n_events} last_profile={prof}]"
        )


def mark_profile(report: dict) -> None:
    """Stamp the latest ``KronOp.profile`` run (timestamp + headline fields)
    into the registry and emit a ``profile`` event."""
    st = _STATE
    if st is None:
        return
    stamp = {
        "at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "signature": report.get("signature"),
        "measured_s": report.get("measured_s"),
        "stages": len(report.get("stages", ())),
        "drift_flagged": report.get("drift_flagged"),
    }
    with st.lock:
        st.last_profile = stamp
    event("profile", **stamp)


# ---------------------------------------------------------------------------
# Chrome-trace (Perfetto) export
# ---------------------------------------------------------------------------


def write_chrome_trace(path: str | None = None) -> str | None:
    """Export completed spans as Chrome trace-event JSON (``chrome://tracing``
    / Perfetto: ``{"traceEvents": [{"ph": "X", ...}]}``, timestamps in µs).
    ``path=None`` uses the ``trace=`` path from ``configure``.  Returns the
    written path (None if inactive or no path is known)."""
    st = _STATE
    if st is None:
        return None
    path = str(path) if path else st.trace_path
    if not path:
        return None
    pid = os.getpid()
    with st.lock:
        events = [
            {
                "name": s["name"],
                "ph": "X",
                "ts": s["ts"] * 1e6,
                "dur": s["dur"] * 1e6,
                "pid": pid,
                "tid": s["tid"],
                "args": {**s.get("attrs", {}), "depth": s["depth"]},
            }
            for s in st.spans
        ]
    payload = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"producer": "kronscope", "started_at": st.started_at},
    }
    with open(path, "w") as f:
        json.dump(payload, f)
    return path


__all__ = [
    "active",
    "configure",
    "disable",
    "reset",
    "shutdown",
    "span",
    "record_span",
    "event",
    "counter_inc",
    "gauge_set",
    "observe",
    "percentiles",
    "snapshot",
    "comm_summary",
    "summary_line",
    "mark_profile",
    "write_chrome_trace",
    "DRIFT_THRESHOLD",
    "SPAN_BUFFER",
    "HIST_BUFFER",
]
