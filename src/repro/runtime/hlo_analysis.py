"""HLO-text analysis: collective traffic + roofline terms from compiled jits.

``cost_analysis()`` reports FLOPs and HBM bytes but NOT collective payloads;
those are parsed out of the compiled HLO here (the instructed methodology for
the §Roofline deliverable).  Works on both ``lowered.as_text()`` (stablehlo —
not used) and ``compiled.as_text()`` (post-SPMD HLO — what we parse).

Per-device semantics: post-SPMD HLO shapes are per-participant, so summed
operand bytes of a collective are the bytes each device contributes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

COLLECTIVE_OPS = (
    "all-to-all",
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# e.g.:  %all-to-all.1 = (f32[4,1]{...}, ...) all-to-all(%a, %b), replica_groups=...
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<type>\(.*?\)|[\w\[\]{},:/ ]*?)\s*"
    r"(?P<op>[\w\-]+)\("
)


def shape_bytes(type_str: str) -> int:
    """Total bytes of every tensor literal in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    """Per-collective-op byte counts (per participating device)."""

    bytes_by_op: dict[str, int] = field(default_factory=dict)
    count_by_op: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def summary(self) -> str:
        if not self.bytes_by_op:
            return "no collectives"
        parts = [
            f"{op}: n={self.count_by_op[op]} {self.bytes_by_op[op]/1e6:.2f}MB"
            for op in sorted(self.bytes_by_op)
        ]
        return ", ".join(parts)


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Parse collective ops + their output payload bytes from HLO text.

    Output-shape bytes are used (== received payload per device; for
    all-reduce it equals the contributed bytes; for all-gather it counts the
    gathered result, the conventional accounting for ring-bandwidth cost).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "(" not in line or "=" not in line:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        base = op.rstrip("0123456789.").removeprefix("%")
        # normalize fused/start variants: all-gather-start, all-reduce-scatter..
        for coll in COLLECTIVE_OPS:
            if base == coll or base == coll + "-start":
                b = shape_bytes(m.group("type"))
                stats.bytes_by_op[coll] = stats.bytes_by_op.get(coll, 0) + b
                stats.count_by_op[coll] = stats.count_by_op.get(coll, 0) + 1
                break
    return stats


def collective_bytes(hlo_text: str) -> int:
    return collective_stats(hlo_text).total_bytes


__all__ = ["collective_stats", "collective_bytes", "shape_bytes", "CollectiveStats",
           "COLLECTIVE_OPS"]
