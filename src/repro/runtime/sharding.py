"""Logical-axis sharding rules: param/optimizer/input/cache PartitionSpecs.

Scheme (MaxText-style FSDP x TP, pod axis folded into batch/FSDP):
  * batch           -> ("pod","data") when present, else "data"
  * TP (heads, d_ff, experts, vocab) -> "model"
  * FSDP (the non-TP matrix dim)     -> "data" (+"pod" when it must: 100B+)
  * everything guarded by divisibility — a rule that does not divide falls
    back axis-by-axis to replication, so ANY (cfg, mesh) pair lowers.

Roles are inferred from parameter path names, not per-arch tables, so new
architectures inherit sane shardings.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axes(mesh: Mesh) -> tuple[tuple[str, ...], str]:
    """Returns (batch/fsdp axes, tp axis)."""
    names = mesh.axis_names
    tp = "model" if "model" in names else names[-1]
    batch = tuple(n for n in names if n != tp)
    return batch, tp


def _size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fit(mesh: Mesh, spec: tuple, shape: tuple[int, ...]) -> P:
    """Drop axes that do not divide their dim; keep the rest."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is not None and dim % _size(mesh, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


# role patterns: last path component (or two) -> (spec builder)
_MATRIX_IN_OUT = re.compile(r"\b(wq|wk|wv|w1|w3|wz|wx|wb|wc|wdt)$")
_MATRIX_OUT_IN = re.compile(r"\b(wo|w2)$")


def param_spec(
    path: str, shape: tuple[int, ...], mesh: Mesh,
    *, fsdp_pods: bool = False, tied_embed: bool = False,
) -> P:
    """PartitionSpec for one parameter leaf, by path role + divisibility."""
    batch_axes, tp = _axes(mesh)
    fsdp = batch_axes if fsdp_pods else (batch_axes[-1],)
    fsdp = fsdp if len(fsdp) > 1 else fsdp[0]
    nd = len(shape)

    def lead_pad(spec: tuple) -> P:
        """Stacked (scan) leaves carry extra leading dims -> None."""
        pad = (None,) * (nd - len(spec))
        return _fit(mesh, pad + spec, shape)

    if "factors" in path:                    # KronLinear factors: tiny, replicate
        return lead_pad(())
    if path.endswith("embed"):
        # (V, D) with vocab over TP: the lookup lowers to a masked local
        # gather + one (B,S,D) psum per step, and for tied heads the table
        # is already V-sharded for the logits matmul.  (A D-over-TP table
        # would make the gather collective-free, but XLA 0.8's partitioner
        # emits invalid IR for the backward dynamic-slice in that layout —
        # see DESIGN.md §8 note.)
        return lead_pad((tp, None))
    if path.endswith("lm_head"):
        return lead_pad((fsdp, tp))          # (D, V)
    if path.endswith("router"):
        return lead_pad((fsdp, None))
    if re.search(r"\bew[123]$", path):       # MoE expert stacks (E, D, F)/(E, F, D)
        e = shape[-3]
        if e % _size(mesh, tp) == 0:
            return lead_pad((tp, fsdp, None))   # expert parallelism
        # TP inside each expert instead (Mixtral: 8 experts < 16-way model)
        if path.endswith("ew2"):
            return lead_pad((None, tp, fsdp))
        return lead_pad((None, fsdp, tp))
    if path.endswith("conv_w"):
        return lead_pad((None, tp))
    if _MATRIX_OUT_IN.search(path):
        return lead_pad((tp, fsdp))
    if _MATRIX_IN_OUT.search(path):
        return lead_pad((fsdp, tp))
    if nd >= 2:
        return lead_pad((fsdp, tp))
    # 1-D (biases, norms, A/D/dt): TP only if the dim divides
    if shape and shape[-1] % _size(mesh, tp) == 0 and shape[-1] >= 1024:
        return lead_pad((tp,))
    return lead_pad(())


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_shardings(
    params_shape: Any, mesh: Mesh,
    *, fsdp_pods: bool = False, tied_embed: bool = False,
) -> Any:
    """Pytree of NamedShardings matching a pytree of ShapeDtypeStructs."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh,
            param_spec(_path_str(kp), leaf.shape, mesh,
                       fsdp_pods=fsdp_pods, tied_embed=tied_embed),
        ),
        params_shape,
    )


def batch_spec(mesh: Mesh) -> P:
    batch_axes, _ = _axes(mesh)
    ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    return P(ax)


def ambient_mesh() -> Mesh | None:
    """The mesh installed by ``with mesh:`` around the current trace, if any."""
    try:
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    return None


def constrain_like_params(tree: Any) -> Any:
    """Pin a params-shaped pytree (gradients, accumulators) to the params'
    sharding rules.  Without this, XLA's backward pass is free to choose
    layouts for the scan's stacked-gradient accumulators — observed to pick
    partially-replicated ones that inflate per-device memory 3x+.
    No-op outside a mesh context."""
    mesh = ambient_mesh()
    if mesh is None:
        return tree
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: jax.lax.with_sharding_constraint(
            leaf, param_spec(_path_str(kp), leaf.shape, mesh)
        ),
        tree,
    )


def tp_size() -> int:
    """Model-axis size of the ambient mesh (1 outside a mesh context)."""
    mesh = ambient_mesh()
    if mesh is None:
        return 1
    _, tp = _axes(mesh)
    return _size(mesh, tp)


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """Mesh-agnostic activation sharding constraint.

    ``logical`` names one role per dim: None (unsharded), "batch"
    ((pod,data)), or "tp" ("model").  No-op outside a mesh context and for
    non-dividing dims, so model code can call it unconditionally — the
    pinned scan carries / logits are what keep XLA's SPMD propagation from
    inventing pathological reshards (observed: involuntary full remat on
    the layer-stack carry).
    """
    mesh = ambient_mesh()
    if mesh is None:
        return x
    batch_axes, tp = _axes(mesh)
    bax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    spec = []
    for dim, role in zip(x.shape, logical):
        ax = {"batch": bax, "tp": tp, None: None}[role]
        if ax is not None and dim % _size(mesh, ax) == 0:
            spec.append(ax)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def token_sharding(mesh: Mesh, batch: int) -> NamedSharding:
    """(B, S) tokens: batch over (pod, data) if divisible."""
    batch_axes, _ = _axes(mesh)
    ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    if batch % _size(mesh, ax) == 0:
        return NamedSharding(mesh, P(ax, None))
    if batch % _size(mesh, batch_axes[-1]) == 0:
        return NamedSharding(mesh, P(batch_axes[-1], None))
    return NamedSharding(mesh, P(None, None))


def cache_spec(path: str, shape: tuple[int, ...], mesh: Mesh, batch: int) -> P:
    """KV / SSM cache leaves.

    Batch-shardable (decode_32k): (..., B, L, Hkv, hd) -> batch over data.
    B == 1 (long_500k): shard the cache LENGTH over the batch axes —
    flash-decoding-style sequence parallelism; XLA inserts the softmax
    reductions.
    """
    batch_axes, tp = _axes(mesh)
    bax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    nd = len(shape)
    leaf = path.rsplit("/", 1)[-1]

    def lead_pad(spec: tuple) -> P:
        pad = (None,) * (nd - len(spec))
        return _fit(mesh, pad + spec, shape)

    if leaf in ("k", "v"):
        if batch % _size(mesh, bax) == 0:
            return lead_pad((bax, None, None, tp))
        return lead_pad((None, bax, None, tp))   # sequence-parallel cache
    if leaf in ("k_scale", "v_scale"):           # int8-KV scales (B,L,Hkv,1)
        if batch % _size(mesh, bax) == 0:
            return lead_pad((bax, None, None, None))
        return lead_pad((None, bax, None, None))
    if leaf == "pos":
        return lead_pad(())
    if leaf == "conv":                           # (B, w-1, conv_dim)
        if batch % _size(mesh, bax) == 0:
            return lead_pad((bax, None, tp))
        return lead_pad((None, None, tp))
    if leaf == "h":                              # (B, H, N, P)
        if batch % _size(mesh, bax) == 0:
            return lead_pad((bax, tp, None, None))
        return lead_pad((None, tp, None, None))
    return lead_pad(())


def cache_shardings(cache_shape: Any, mesh: Mesh, batch: int) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh, cache_spec(_path_str(kp), leaf.shape, mesh, batch)
        ),
        cache_shape,
    )


__all__ = [
    "param_spec",
    "param_shardings",
    "cache_spec",
    "cache_shardings",
    "token_sharding",
    "batch_spec",
]
