"""Execution guard layer: error taxonomy, degradation ladder, numerics guards.

This module is the runtime's failure story for the whole Kron-Matmul
execution spine (docs/robustness.md).  Three pieces:

* **Error taxonomy** — ``KronError`` and its typed subclasses replace the
  ad-hoc ``ValueError``/``RuntimeError``/silent-``except`` sites across
  ``core/engine.py``, ``core/autotune.py``, ``core/distributed.py`` and
  ``kernels/emit.py``.  Every subclass ALSO derives from the builtin type
  the old code raised (``VmemOverflowError`` is a ``ValueError``,
  ``PlanCacheError`` is an ``OSError``, ...), so pre-existing ``except``
  clauses and caller contracts keep working while new code can catch the
  typed hierarchy.

* **Degradation ladder + circuit breaker** — ``run_ladder`` executes a
  sequence of rungs (for a ``KronOp``: pallas/planned chain -> per-factor
  sliced -> XLA scan executor) with per-key health state: the first failure
  degrades THE CALL with a once-per-process warning; ``patience`` repeated
  degraded calls PIN the key to the degraded rung so later calls skip the
  failing rung entirely.  Counters are exposed via ``health_report()`` and
  surfaced by ``KronOp.describe()``.  Health is process-local trace-time
  state: under ``jax.jit`` the decision is taken when the call is traced
  and baked into the compiled function.

* **Numerics guards** — ``check_finite`` instruments the ``StageProgram``
  boundary (both the Pallas and XLA executors run through it) with policy
  ``off | warn | raise`` (``FASTKRON_NUMERICS`` or
  ``set_numerics_policy``).  ``off`` is a single string compare — the
  guards-off overhead budget in EXPERIMENTS.md §Robustness.  Eager calls
  raise ``NumericsError`` synchronously; traced calls report through
  ``jax.debug.callback`` (a ``raise`` policy then surfaces when the
  computation is consumed).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import warnings
from typing import Callable, Sequence

from . import telemetry


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------


class KronError(Exception):
    """Base of every typed Kron-Matmul runtime error (docs/robustness.md)."""


class PlanError(KronError, ValueError):
    """Planning failed: invalid plan inputs, no legal round schedule, an
    unknown tune mode, or no measurable candidate."""


class VmemOverflowError(KronError, ValueError):
    """A kernel tile's live set exceeds the VMEM budget.  The signal the
    degradation ladder and the per-factor fallbacks key on."""


class LoweringError(KronError, ValueError):
    """A stage cannot be lowered to the kernel template: illegal tiling,
    non-dividing dims, malformed instruction."""


class CollectiveError(KronError, RuntimeError):
    """A distributed relocation round failed (or was chaos-injected to
    fail).  The mesh ladder degrades to local execution."""


class PlanCacheError(KronError, OSError):
    """Plan-cache IO failed: corrupt entry, lock/rename contention, or an
    injected fault.  Always degraded (warn + rebuild/retry), never fatal."""


class NumericsError(KronError, FloatingPointError):
    """A non-finite value crossed a guarded StageProgram boundary under
    policy ``raise``."""


class GuardWarning(UserWarning):
    """Warning category for every degradation the guard layer performs."""


# ---------------------------------------------------------------------------
# Once-per-process warning bookkeeping
# ---------------------------------------------------------------------------

_WARNED: set = set()
_LOCK = threading.Lock()


def warn_once(token, message: str) -> None:
    """Emit ``GuardWarning`` once per process per ``token``."""
    with _LOCK:
        if token in _WARNED:
            return
        _WARNED.add(token)
    telemetry.event("guard_warning", token=str(token), message=message)
    warnings.warn(message, GuardWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# Health state (circuit breaker)
# ---------------------------------------------------------------------------

DEFAULT_PATIENCE = 3


@dataclasses.dataclass
class OpHealth:
    """Mutable per-key circuit-breaker state (see ``run_ladder``)."""

    rung: int = 0            # rung calls currently START at
    pinned: bool = False     # True once patience pinned the key to ``rung``
    calls: int = 0
    degraded_calls: int = 0  # calls that completed below their start rung
    consecutive: int = 0     # consecutive calls that had to degrade
    errors: dict = dataclasses.field(default_factory=dict)  # type name -> n
    last_error: str | None = None

    def record(self, exc: BaseException) -> None:
        name = type(exc).__name__
        self.errors[name] = self.errors.get(name, 0) + 1
        self.last_error = f"{name}: {exc}"

    def summary(self) -> dict:
        return {
            "rung": self.rung,
            "pinned": self.pinned,
            "calls": self.calls,
            "degraded_calls": self.degraded_calls,
            "errors": dict(self.errors),
            "last_error": self.last_error,
        }


_HEALTH: dict = {}
_EVENTS: dict = {}  # free-form degradation counters (plan cache, rounds, ...)


def health(key) -> OpHealth:
    """Get-or-create the circuit-breaker state for ``key``."""
    h = _HEALTH.get(key)
    if h is None:
        h = _HEALTH[key] = OpHealth()
    return h


def health_entries():
    """Raw (key, OpHealth) items — for callers that filter by key structure
    (``KronOp.describe`` matches its own signature prefix)."""
    return list(_HEALTH.items())


def record_event(name: str, exc: BaseException | None = None) -> None:
    """Count a degradation event outside any ladder (plan-cache rebuilds,
    per-round fallbacks inside shard_map bodies, ...).  Active telemetry
    (``repro.runtime.telemetry``) receives the same event on its sink."""
    _EVENTS[name] = _EVENTS.get(name, 0) + 1
    if exc is not None:
        ename = f"{name}:{type(exc).__name__}"
        _EVENTS[ename] = _EVENTS.get(ename, 0) + 1
        telemetry.event(name, error=type(exc).__name__, detail=str(exc))
    else:
        telemetry.event(name)


def health_report() -> dict:
    """Snapshot of every guarded key's counters plus free-form event counts.

    ``{"ops": {str(key): summary_dict}, "events": {name: count}}`` — the
    process-wide answer to "has anything degraded, and why".  While
    telemetry is active a ``"telemetry"`` key carries its ``snapshot()``
    (counters, gauges, histogram percentiles) so launchers print ONE merged
    report instead of a guard dump plus a telemetry dump.
    """
    report = {
        "ops": {repr(k): h.summary() for k, h in _HEALTH.items()},
        "events": dict(_EVENTS),
    }
    if telemetry.active():
        report["telemetry"] = telemetry.snapshot()
    return report


def reset_health() -> None:
    """Clear all health state and once-per-process warning tokens (tests)."""
    _HEALTH.clear()
    _EVENTS.clear()
    with _LOCK:
        _WARNED.clear()


# ---------------------------------------------------------------------------
# The degradation ladder
# ---------------------------------------------------------------------------


def run_ladder(
    key,
    rungs: Sequence[tuple[str, Callable[[], object]]],
    *,
    patience: int = DEFAULT_PATIENCE,
    catch: tuple = (KronError,),
):
    """Execute ``rungs`` (ordered most- to least-performant) under the
    circuit breaker keyed by ``key``.

    Starts at the key's current rung; a ``catch``-matching failure records
    the typed error, warns once per process, and falls through to the next
    rung.  A call that completes below its start rung counts as degraded;
    ``patience`` consecutive degraded calls pin the key to the completing
    rung (later calls skip the failing rung without retrying it).  A call
    that completes at its start rung resets the consecutive counter.  If
    every rung fails the LAST error is re-raised — the ladder never
    swallows a total failure.
    """
    h = health(key)
    h.calls += 1
    start = h.rung
    last_exc = None
    for i in range(start, len(rungs)):
        name, fn = rungs[i]
        try:
            out = fn()
        except catch as e:  # typed failures only: real bugs propagate
            h.record(e)
            last_exc = e
            telemetry.event(
                "rung_fallback", key=repr(key), rung=i, rung_name=name,
                error=type(e).__name__,
            )
            if i + 1 < len(rungs):
                warn_once(
                    (key, i),
                    f"kron guard: {key} failed on rung {i} ({name}): "
                    f"{type(e).__name__}: {e} — degrading to rung {i + 1} "
                    f"({rungs[i + 1][0]})",
                )
            continue
        if i > start:
            h.degraded_calls += 1
            h.consecutive += 1
            if h.consecutive >= patience:
                h.rung = i
                h.pinned = True
                h.consecutive = 0
                telemetry.event(
                    "rung_pinned", key=repr(key), rung=i, rung_name=name
                )
                warn_once(
                    (key, "pinned", i),
                    f"kron guard: {key} degraded {patience} consecutive "
                    f"calls — pinned to rung {i} ({name})",
                )
        else:
            h.consecutive = 0
        return out
    assert last_exc is not None
    raise last_exc


# ---------------------------------------------------------------------------
# Numerics guards (StageProgram boundary)
# ---------------------------------------------------------------------------

NUMERICS_POLICIES = ("off", "warn", "raise")
_numerics_policy: str | None = None  # None -> env -> "off"


def numerics_policy() -> str:
    """The active non-finite-guard policy: ``off`` | ``warn`` | ``raise``."""
    if _numerics_policy is not None:
        return _numerics_policy
    env = os.environ.get("FASTKRON_NUMERICS", "off")
    return env if env in NUMERICS_POLICIES else "off"


def set_numerics_policy(policy: str | None) -> None:
    """Set the process-wide policy (``None`` re-reads ``FASTKRON_NUMERICS``)."""
    global _numerics_policy
    if policy is not None and policy not in NUMERICS_POLICIES:
        raise PlanError(
            f"unknown numerics policy {policy!r}: want one of {NUMERICS_POLICIES}"
        )
    _numerics_policy = policy


class numerics(object):
    """Context manager scoping a numerics policy (tests, launchers)."""

    def __init__(self, policy: str):
        self._policy = policy
        self._prev: str | None = None

    def __enter__(self):
        global _numerics_policy
        self._prev = _numerics_policy
        set_numerics_policy(self._policy)
        return self

    def __exit__(self, *exc):
        global _numerics_policy
        _numerics_policy = self._prev
        return False


def _handle_nonfinite(where: str, policy: str) -> None:
    msg = f"non-finite values at guarded boundary {where!r}"
    record_event("nonfinite", NumericsError(msg))
    if policy == "raise":
        raise NumericsError(msg)
    warn_once(("nonfinite", where), f"kron guard: {msg}")


def check_finite(y, where: str):
    """Non-finite guard at a StageProgram boundary; returns ``y`` unchanged.

    Policy ``off`` costs one string compare.  On a concrete (eager) array
    the check is synchronous: ``raise`` raises ``NumericsError`` on the
    spot.  On a traced value the reduced ``isfinite`` flag is inspected via
    ``jax.debug.callback``; a ``raise`` policy then surfaces when the jitted
    computation is consumed.  Runs identically for the Pallas and XLA
    executors because it guards their shared output, after any
    ``acc_dtype`` downcast — exactly the value the next stage consumes.
    """
    policy = numerics_policy()
    if policy == "off":
        return y
    import jax
    import jax.numpy as jnp

    ok = jnp.isfinite(y).all()
    if isinstance(ok, jax.core.Tracer):
        jax.debug.callback(
            lambda ok_, w=where, p=policy: None
            if bool(ok_)
            else _handle_nonfinite(w, p),
            ok,
        )
        return y
    if not bool(ok):
        _handle_nonfinite(where, policy)
    return y


__all__ = [
    "KronError",
    "PlanError",
    "VmemOverflowError",
    "LoweringError",
    "CollectiveError",
    "PlanCacheError",
    "NumericsError",
    "GuardWarning",
    "OpHealth",
    "run_ladder",
    "health",
    "health_entries",
    "health_report",
    "record_event",
    "reset_health",
    "warn_once",
    "check_finite",
    "numerics",
    "numerics_policy",
    "set_numerics_policy",
    "DEFAULT_PATIENCE",
    "NUMERICS_POLICIES",
]
