"""Fault-tolerance runtime: straggler monitor + elastic re-meshing.

StragglerMonitor — per-step wall-time EWMA/EWVAR; steps beyond
``mean + k*std`` are flagged.  On a real pod each host reports its step
time; a persistent straggler (same host flagged ``patience`` times) triggers
the configured action: "log", "callback" (e.g. request reschedule via the
cluster manager) or "raise" (fail fast so the job restarts from the last
checkpoint minus the bad node).

elastic_mesh — given whatever devices survive, pick the largest
(data, model) grid with model <= requested TP and data maximal; combined
with CheckpointManager.restore's cross-mesh device_put this is the elastic
restart path (tested in tests/test_fault.py with fake device counts).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable

import jax

from . import telemetry
from .events import get_logger


@dataclass
class StragglerMonitor:
    threshold_sigma: float = 3.0
    patience: int = 3
    alpha: float = 0.1           # EWMA decay
    action: str = "log"          # log | raise | callback
    callback: Callable[[int, float], None] | None = None
    warmup_steps: int = 5

    _mean: float = field(default=0.0, init=False)
    _var: float = field(default=0.0, init=False)
    _n: int = field(default=0, init=False)
    _consecutive: int = field(default=0, init=False)
    flagged_steps: list = field(default_factory=list, init=False)
    _t0: float = field(default=0.0, init=False)

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        """Record one step; returns True if flagged as straggling."""
        dt = time.perf_counter() - self._t0
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup_steps:
            # prime with running mean / mean squared deviation
            k = self._n
            delta = dt - self._mean
            self._mean += delta / k
            self._var += ((dt - self._mean) * delta - self._var) / k
            return False
        # floor the std at 5% of the mean: healthy jitter never flags
        std = max(math.sqrt(max(self._var, 0.0)), 0.05 * self._mean, 1e-9)
        is_slow = dt > self._mean + self.threshold_sigma * std
        if is_slow:
            self._consecutive += 1
            self.flagged_steps.append((step, dt))
            telemetry.event(
                "straggler", step=step, seconds=dt, mean_seconds=self._mean
            )
            if self._consecutive >= self.patience:
                # Re-arm BEFORE acting: the action fires once per patience
                # window, not on every slow step after the first window
                # (a raise would otherwise re-raise, a reschedule callback
                # would storm the cluster manager).
                self._consecutive = 0
                msg = (
                    f"straggler: step {step} took {dt:.3f}s "
                    f"(mean {self._mean:.3f}s +{self.threshold_sigma} sigma)"
                )
                if self.action == "raise":
                    raise RuntimeError(msg)
                if self.action == "callback" and self.callback:
                    self.callback(step, dt)
                else:
                    # shared ``repro`` logger: same stdout line as the old
                    # bare print (bare-message formatter), but a handler swap
                    # or level change now governs every subsystem at once
                    get_logger("repro.fault").warning(
                        f"[straggler-monitor] {msg}"
                    )
        else:
            self._consecutive = 0
            # EWMA update only on healthy steps (stragglers don't poison it)
            self._mean = (1 - self.alpha) * self._mean + self.alpha * dt
            delta = dt - self._mean
            self._var = (1 - self.alpha) * self._var + self.alpha * delta * delta
        return is_slow


def elastic_mesh(
    n_devices: int, *, want_model: int = 16, axis_names=("data", "model"),
    devices=None,
):
    """Largest (data, model) grid for however many devices survived.

    model = largest power-of-two divisor of n_devices that is <= want_model;
    data = n_devices // model.  Guarantees every device is used, so a job
    that loses a host restarts on the remaining N-k devices without config
    edits (weights re-sharded on restore).
    """
    model = 1
    while model * 2 <= want_model and n_devices % (model * 2) == 0:
        model *= 2
    data = n_devices // model
    return jax.make_mesh((data, model), axis_names, devices=devices)


__all__ = ["StragglerMonitor", "elastic_mesh"]
