"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284]

Backbone only (per assignment): the EnCodec tokenizer + multi-codebook
interleaving is the STUB — ``input_specs`` feeds flat code-token ids
(vocab 2048).  MHA (kv == heads == 32).  GeGLU stands in for the original
non-gated GELU MLP (gated form, same hidden dim — noted in DESIGN.md).
long_500k skipped: full attention.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    ffn_act="gelu",
    frontend="audio",
)
