"""deepseek-moe-16b [moe] — fine-grained MoE: 2 shared + 64 routed top-6,
first layer dense.  [arXiv:2401.06066]

Assigned d_ff=1408 is the per-expert (moe_intermediate) width; the dense
first layer uses the public 10944 intermediate.  MHA (kv=16).
"""
from ..models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    vocab=102400,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    moe_skip_first=1,
)
