"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1), tied embeddings,
embeddings scaled by sqrt(d).  [arXiv:2403.08295]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    ffn_act="gelu",
    tie_embeddings=True,
    embed_scale=True,
)
