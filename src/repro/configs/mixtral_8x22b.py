"""mixtral-8x22b [moe] — 8 experts top-2 every layer, sliding-window
attention (window 4096, per assignment).  [arXiv:2401.04088]

long_500k RUNS: the SWA ring cache is bounded by the window, decode is
O(window) per token.
"""
from ..models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,  # every layer is MoE
    vocab=32768,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16384),
)
