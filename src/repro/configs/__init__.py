"""Assigned-architecture registry: one module per arch, exact public configs.

``get_config(name)`` returns the full-size ModelConfig; ``SHAPES`` is the
assigned input-shape set; ``runnable_cells()`` enumerates the 40 (arch x
shape) dry-run cells with the documented long_500k skips (DESIGN.md §7).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

from ..models.config import ModelConfig

ARCHS = [
    "llava_next_mistral_7b",
    "qwen2_5_32b",
    "gemma_2b",
    "qwen2_7b",
    "qwen3_4b",
    "jamba_1_5_large_398b",
    "musicgen_large",
    "deepseek_moe_16b",
    "mixtral_8x22b",
    "mamba2_130m",
]

# canonical ids (as assigned) -> module names
ALIASES = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "qwen2.5-32b": "qwen2_5_32b",
    "gemma-2b": "gemma_2b",
    "qwen2-7b": "qwen2_7b",
    "qwen3-4b": "qwen3_4b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "musicgen-large": "musicgen_large",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mixtral-8x22b": "mixtral_8x22b",
    "mamba2-130m": "mamba2_130m",
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: run for SSM / hybrid / SWA archs,
# skip for pure full-attention archs (documented in DESIGN.md §7).
LONG_OK = {"jamba_1_5_large_398b", "mamba2_130m", "mixtral_8x22b"}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f".{mod_name}", __name__)
    return mod.CONFIG


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) cells; long_500k only where sub-quadratic."""
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            cells.append((arch, shape))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    return [
        (arch, "long_500k", "pure full attention - O(S^2) at 524k infeasible")
        for arch in ARCHS
        if arch not in LONG_OK
    ]


__all__ = [
    "ARCHS",
    "ALIASES",
    "SHAPES",
    "ShapeSpec",
    "LONG_OK",
    "get_config",
    "runnable_cells",
    "skipped_cells",
]
