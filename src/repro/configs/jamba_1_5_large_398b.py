"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave (period 8,
attention at offset 4), MoE 16e top-2 on every other layer.
[arXiv:2403.19887]

~398B total / ~94B active parameters.  Mamba positions use our SSD block
(DESIGN.md: Jamba-1.5 ships Mamba-1; SSD is the TPU-native successor with
the same state-space interface).  long_500k RUNS: decode state is O(1) for
the 63 mamba layers and the 9 attention layers hold the only KV.
"""
from ..models.config import MambaConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    attn_layer_period=8,
    attn_layer_offset=4,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, every=2, offset=1),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=128, n_groups=1),
)
