"""llava-next-mistral-7b [vlm] — Mistral-7B-v0.2 backbone + anyres vision stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf].  The vision tower is a STUB: the
dry-run's ``input_specs`` provides precomputed patch embeddings (anyres
tiling: base 576 + one 2x2 high-res grid row = 1152 patch tokens) that the
model prepends to the text embedding sequence.  long_500k skipped: full
attention (Mistral-v0.2 dropped SWA).
"""
from ..models.config import ModelConfig

N_PATCH_TOKENS = 1152  # anyres: 576 base + 576 grid tile @ 24x24 patches

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    rope_theta=1_000_000.0,
    ffn_act="silu",
    frontend="vision",
    n_frontend_tokens=N_PATCH_TOKENS,
)
