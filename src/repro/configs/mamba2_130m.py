"""mamba2-130m [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060]

24 layers of pure Mamba2 blocks (no FFN), d_state=128, head_dim=64
(d_inner=1536 -> 24 SSM heads), tied embeddings (GPT-NeoX tokenizer,
vocab 50280 padded to 50432 for TP).  long_500k RUNS: O(1) decode state.
"""
from ..models.config import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    # chunk=256: measured optimum of the SSD traffic trade-off (intra-chunk
    # tensors grow with lc, inter-chunk states shrink as 1/lc) — §Perf C2:
    # 64->3.71s, 128->2.24s, 256->1.93s, 512->1.99s HBM term on train_4k
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
)
