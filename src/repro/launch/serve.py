"""Batched serving launcher: prefill a batch of prompts, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16

Serving path: jitted prefill builds the KV/SSM cache for the whole batch,
then a jitted single-token serve_step runs the autoregressive loop (greedy
or temperature sampling).  Cache is donated each step (in-place ring-buffer
update on real hardware).  Reports prefill and decode tokens/s.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data import SyntheticLM
from ..models.config import reduced as reduce_cfg
from ..runtime import guard, telemetry
from ..runtime.events import get_logger
from ..runtime.fault import StragglerMonitor, elastic_mesh
from ..train import make_prefill_step, make_serve_step, prebuild_kron_ops


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--want-model-parallel", type=int, default=16)
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (halves serving memory)")
    ap.add_argument("--kron-ffn", action="store_true",
                    help="Kron-compressed FFN projections: prefill's (B, T, d) "
                         "activations run the batched Kron-Matmul path "
                         "(kron_matmul_batched, shared factors) — one launch "
                         "per projection for the whole serving batch")
    ap.add_argument("--distributed", action="store_true",
                    help="with --kron-ffn: route the batched Kron-FFN prefill "
                         "through kron_matmul_batched_distributed on the "
                         "serving mesh (one collective round per projection "
                         "stage for the whole batch; shapes the mesh cannot "
                         "host fall back to the local batched path)")
    ap.add_argument("--numerics", choices=list(guard.NUMERICS_POLICIES),
                    default=None,
                    help="non-finite guard at StageProgram boundaries "
                         "(default: FASTKRON_NUMERICS or off); serving "
                         "typically wants warn — degraded tokens are better "
                         "than a dead replica")
    ap.add_argument("--telemetry", metavar="OUT.jsonl", default=None,
                    help="KronScope JSONL event sink: spans, guard/chaos "
                         "events, per-round comm metrics, tokens/s gauges")
    ap.add_argument("--trace", metavar="OUT.trace.json", default=None,
                    help="Chrome-trace (Perfetto) export of the host-side "
                         "spans, written at exit")
    args = ap.parse_args()
    if args.distributed and not args.kron_ffn:
        ap.error("--distributed requires --kron-ffn (it distributes the "
                 "batched Kron-FFN prefill)")
    if args.numerics is not None:
        guard.set_numerics_policy(args.numerics)
    if args.telemetry or args.trace:
        telemetry.configure(jsonl=args.telemetry, trace=args.trace)
    log = get_logger("repro.serve")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg, dtype="float32")
    if args.kv_quant or args.kron_ffn:
        from dataclasses import replace

        cfg = replace(cfg, kv_quant=args.kv_quant or cfg.kv_quant,
                      kron_ffn=args.kron_ffn or cfg.kron_ffn)
    mesh = elastic_mesh(jax.device_count(), want_model=args.want_model_parallel)
    max_len = args.prompt_len + args.gen

    import contextlib

    from ..core.layers import kron_distributed

    dist_scope = (
        kron_distributed(mesh) if args.distributed else contextlib.nullcontext()
    )
    if cfg.kron_ffn:
        # One KronOp per FFN shape, its plan resolved for the serving
        # (batch, prompt-len) rows ONCE before the first trace and reused
        # across every request — the handle-based serving path.
        for op in prebuild_kron_ops(
            cfg, batch=args.batch, seq_len=args.prompt_len,
            mesh=mesh if args.distributed else None,
        ):
            print(f"kron-ffn {op.describe()}")
    with mesh, dist_scope:
        from ..models import model as M

        params = M.init_params(cfg, jax.random.PRNGKey(0))
        data = SyntheticLM(vocab=cfg.vocab, seq_len=args.prompt_len,
                           batch=args.batch)
        prompts, _ = data.global_batch(0)

        prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
        step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

        t0 = time.time()
        with telemetry.span("prefill", batch=args.batch,
                            prompt_len=args.prompt_len):
            logits, cache = prefill(params, prompts)
            jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        def sample(logits, key):
            lg = logits[:, -1, : cfg.vocab]
            if args.temperature <= 0:
                return jnp.argmax(lg, axis=-1).astype(jnp.int32)
            return jax.random.categorical(key, lg / args.temperature).astype(
                jnp.int32
            )

        key = jax.random.PRNGKey(1)
        tok = sample(logits, key)[:, None]
        out_tokens = [tok]
        # Straggler monitor on the decode loop: a persistently slow token
        # step on a serving replica is the same signal as a slow train step
        # on a pod — log it, don't kill the replica.
        mon = StragglerMonitor(action="log")
        t0 = time.time()
        for i in range(args.gen - 1):
            key = jax.random.fold_in(key, i)
            mon.start()
            with telemetry.span("decode_step", step=i):
                logits, cache = step(params, cache, tok,
                                     jnp.int32(args.prompt_len + i))
                tok = sample(logits, key)[:, None]
                jax.block_until_ready(tok)
            mon.stop(i)
            out_tokens.append(tok)
        t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    log.info(f"generated shape: {gen.shape}")
    log.info(f"sample row: {gen[0, :12].tolist()}")
    pre_tps = args.batch * args.prompt_len / max(t_prefill, 1e-9)
    dec_tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    telemetry.gauge_set("prefill.tokens_per_s", pre_tps)
    telemetry.gauge_set("decode.tokens_per_s", dec_tps)
    log.info(f"prefill: {t_prefill:.2f}s ({pre_tps:.0f} tok/s)  "
             f"decode: {t_decode:.2f}s ({dec_tps:.0f} tok/s)")
    if mon.flagged_steps:
        log.info(f"stragglers: {len(mon.flagged_steps)} decode step(s) flagged")
    # ONE merged exit report: guard health carries the telemetry snapshot
    # (counters, gauges, histogram percentiles) when KronScope is live.
    report = guard.health_report()
    if telemetry.active() or report["events"] or any(
        h["degraded_calls"] or h["errors"] for h in report["ops"].values()
    ):
        log.info(f"health: {report}")
    telemetry.shutdown()


if __name__ == "__main__":
    main()
