"""Serving launcher: one-shot batch, or continuous batching (docs/serving.md).

    # one-shot (legacy): prefill ONE fixed batch, decode --gen tokens
    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16

    # continuous batching: open-loop Poisson arrivals through the pure
    # scheduler (launch/scheduler.py), bucketed prefill, slot recycling
    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --arrival-rate 0.5 --requests 32 --gen 8 --kron-ffn

The continuous path is split in two layers.  ``launch.scheduler`` decides
(pure state machine, device-free); ``ServeEngine`` here executes — bucketed
prefill under the guard ladder (a ``VmemOverflowError`` on the grouped
prefill degrades to per-request prefills, never drops a request), admission
of prefilled requests into the in-flight decode batch via the slot-form
cache primitives (``model.cache_to_slots``/``cache_take``/``cache_put``),
and one fixed-shape decode step per scheduler step.  Every (batch-bucket,
len-bucket) prefill shape and the decode shape map to pre-resolved per-shape
``KronOp`` plans (``train.prebuild_kron_ops``, prewarmed at startup), so
steady-state serving does zero re-planning.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data import SyntheticLM
from ..models import model as M
from ..models.config import reduced as reduce_cfg
from ..runtime import chaos, guard, telemetry
from ..runtime.events import get_logger
from ..runtime.fault import StragglerMonitor, elastic_mesh
from ..train import make_prefill_step, make_serve_step, prebuild_kron_ops
from .scheduler import (
    Request,
    SchedulerConfig,
    new_state,
    poisson_trace,
    step as sched_step,
)


def batch_buckets(max_prefill: int) -> tuple[int, ...]:
    """Prefill BATCH padding buckets: powers of two up to ``max_prefill``
    (plus ``max_prefill`` itself).  A coalesced group of g requests is
    padded to the smallest bucket >= g, so every prefill launch hits one of
    a fixed, prewarmed set of (batch, seq) shapes — variable group sizes
    never cause a re-plan or a re-trace."""
    out = []
    b = 1
    while b < max_prefill:
        out.append(b)
        b *= 2
    out.append(max_prefill)
    return tuple(out)


def _pad_batch(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclasses.dataclass
class ServeReport:
    """What one ``ServeEngine.run`` produced."""

    tokens: dict[int, list[int]]          # rid -> emitted tokens
    metrics: dict[int, dict]              # rid -> wall-clock + step metrics
    steps: int
    duration_s: float
    total_tokens: int
    tokens_per_s: float
    ttft_s: list[float]                   # per finished request
    tpot_s: list[float]                   # per request with >= 2 tokens


class ServeEngine:
    """Executes scheduler actions against the real model.

    The decode batch has a FIXED shape: (max_slots, 1) tokens with a
    per-slot position vector (``model.decode_step`` vector-pos mode).
    Free slots decode garbage that is never read — the fixed shape is what
    keeps the whole serve loop on two compiled executables (one decode,
    one prefill per (batch-bucket, len-bucket) shape) and zero re-plans.
    """

    def __init__(self, cfg, params, scfg: SchedulerConfig, *, max_new: int,
                 temperature: float = 0.0, eos_id: int | None = None,
                 sample_seed: int = 1):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.max_new = int(max_new)
        self.temperature = float(temperature)
        self.eos_id = eos_id
        self.max_len = max(scfg.buckets) + self.max_new
        self.batch_buckets = batch_buckets(scfg.max_prefill)
        pf = make_prefill_step(cfg, max_len=self.max_len)

        def _pf_slots(params, tokens, true_lens):
            logits, cache = pf(params, tokens)
            # gather each row's last REAL position in-graph: one host
            # transfer of (batch, vocab) instead of per-request eager slices
            rows = logits[jnp.arange(tokens.shape[0]), true_lens - 1]
            return rows, M.cache_to_slots(cache, true_lens=true_lens)

        # everything on the per-request path is jitted — the eager
        # tree_maps in cache_take/cache_put dispatch one op per cache leaf
        # and would otherwise dominate admission cost.  Admission is a
        # single fused move (group-cache row i -> decode slot si), not a
        # take-then-put, so the row never materialises as its own buffers.
        self._prefill = jax.jit(_pf_slots)
        self._decode = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
        self._move = jax.jit(
            lambda dst, src, i, si: M.cache_put(dst, M.cache_take(src, i),
                                                si),
            donate_argnums=(0,))
        self._key = jax.random.PRNGKey(sample_seed)
        self.log = get_logger("repro.serve")

    def prewarm(self, mesh=None) -> tuple:
        """Resolve every serving ``KronOp`` plan before the first request:
        one per (batch-bucket, len-bucket) prefill shape plus the decode
        shape (the PR-8 fix — the old single-(batch*prompt) prebuild left
        every other bucket re-planning mid-serve)."""
        shapes = [(bb, lb) for lb in self.scfg.buckets
                  for bb in self.batch_buckets]
        return prebuild_kron_ops(
            self.cfg, prefill_shapes=shapes,
            decode_batch=self.scfg.max_slots, mesh=mesh,
        )

    def compile_shapes(self) -> int:
        """Compile every serving executable up front: one prefill per
        (batch-bucket, len-bucket) shape plus the fixed decode shape.
        Without this the first request to hit a cold shape absorbs an XLA
        compile into its TTFT.  Returns the number of executables built."""
        n = 0
        cache = M.cache_to_slots(
            M.init_cache(self.cfg, self.scfg.max_slots, self.max_len))
        for lb in self.scfg.buckets:
            for bb in self.batch_buckets:
                rows, c = self._prefill(
                    self.params, np.zeros((bb, lb), np.int32),
                    np.ones((bb,), np.int32))
                # admission move: one executable per batch-bucket
                cache = self._move(cache, c, 0, 0)
                jax.block_until_ready(rows)
                n += 1
        jax.block_until_ready(
            self._decode(self.params, cache,
                         jnp.zeros((self.scfg.max_slots, 1), jnp.int32),
                         jnp.zeros((self.scfg.max_slots,), jnp.int32))[0])
        return n + 1

    # -- model calls -------------------------------------------------------

    def _sample(self, lg: np.ndarray, rid: int, index: int) -> int:
        """Next token from one row of host logits.  The key depends only on
        (rid, index) — temperature sampling is per-request deterministic,
        independent of co-batching (the property tests pin this)."""
        lg = lg[: self.cfg.vocab]
        if self.temperature <= 0:
            return int(np.argmax(lg))
        key = jax.random.fold_in(jax.random.fold_in(self._key, rid), index)
        return int(jax.random.categorical(
            key, jnp.asarray(lg) / self.temperature))

    def _prefill_group(self, bucket: int, prompts: list[np.ndarray]):
        """Prefill ``prompts`` padded to ``bucket``; returns per-request
        (first_token_logits_row, batch-1 slot-form cache).

        Guard ladder: rung 0 runs the whole group as ONE (batch-bucket,
        bucket) launch (the fast path; ``serve_admit`` chaos site); rung 1
        degrades to per-request (1, bucket) launches — a capacity failure
        on the grouped shape costs throughput, never a request."""
        g = len(prompts)
        lens = [int(p.shape[0]) for p in prompts]

        def run(tokens: np.ndarray, true_lens: list[int]):
            rows, cache = self._prefill(
                self.params, tokens, np.asarray(true_lens, np.int32))
            return np.asarray(rows), cache

        def rung_bucket():
            chaos.maybe_fail("serve_admit")
            bb = _pad_batch(g, self.batch_buckets)
            tokens = np.zeros((bb, bucket), np.int32)
            for i, p in enumerate(prompts):
                tokens[i, : lens[i]] = p
            rows, cache = run(tokens, lens + [1] * (bb - g))
            return [(rows[i], (cache, i)) for i in range(g)]

        def rung_split():
            out = []
            for p, ln in zip(prompts, lens):
                tokens = np.zeros((1, bucket), np.int32)
                tokens[0, :ln] = p
                rows, cache = run(tokens, [ln])
                out.append((rows[0], (cache, 0)))
            return out

        return guard.run_ladder(
            f"serve_admit:{bucket}",
            [("bucket", rung_bucket), ("split", rung_split)],
        )

    # -- the serve loop ----------------------------------------------------

    def run(self, requests, *, max_steps: int = 100_000) -> ServeReport:
        """Drive ``requests`` (arrival in scheduler-step units, as from
        ``poisson_trace``) to completion.  Continuous batching: arrivals
        are fed open-loop, prefilled groups are admitted into the live
        decode batch, slots recycle on EOS/max-new."""
        scfg, cfg = self.scfg, self.cfg
        cache = M.cache_to_slots(M.init_cache(cfg, scfg.max_slots,
                                              self.max_len))
        state = new_state(scfg)
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        prompts: dict[int, np.ndarray] = {}
        rng = np.random.RandomState(0)
        for r in pending:
            prompts[r.rid] = rng.randint(
                0, cfg.vocab, size=(r.prompt_len,)).astype(np.int32)

        slot_rid: dict[int, int] = {}            # engine mirror of the slots
        slot_tok = np.zeros((scfg.max_slots, 1), np.int32)
        slot_pos = np.zeros((scfg.max_slots,), np.int32)
        prefilled: dict[int, tuple] = {}   # rid -> (token, (group cache, i))
        tokens: dict[int, list[int]] = {}
        metrics: dict[int, dict] = {}
        eos_next: list[tuple] = []
        mon = StragglerMonitor(action="log")
        n_done, i = 0, 0
        t_start = time.perf_counter()

        while n_done < len(pending) and state.step_idx < max_steps:
            t = state.step_idx
            events = list(eos_next)
            eos_next = []
            while i < len(pending) and int(pending[i].arrival) <= t:
                req = pending[i]
                events.append(("arrive", req))
                metrics[req.rid] = {"arrival_wall": time.perf_counter(),
                                    "arrival_step": t}
                i += 1
            state, actions = sched_step(state, events)
            telemetry.gauge_set("serve.queue_depth", len(state.queued))
            telemetry.observe("serve.queue_depth", float(len(state.queued)))

            for act in actions:
                kind = act[0]
                if kind == "reject":
                    _, rid, reason = act
                    metrics[rid]["reason"] = reason
                    metrics[rid]["finish_wall"] = time.perf_counter()
                    n_done += 1
                    self.log.info(f"reject rid={rid}: {reason}")
                elif kind == "prefill":
                    _, bucket, rids = act
                    with telemetry.span("serve.prefill", bucket=bucket,
                                        group=len(rids)):
                        outs = self._prefill_group(
                            bucket, [prompts[r] for r in rids])
                    now = time.perf_counter()
                    for rid, (lg, row) in zip(rids, outs):
                        tok = self._sample(np.asarray(lg), rid, 0)
                        prefilled[rid] = (tok, row)
                        tokens[rid] = [tok]
                        m = metrics[rid]
                        m["first_token_wall"] = now
                        m["first_token_step"] = t
                        telemetry.observe(
                            "serve.ttft_s", now - m["arrival_wall"])
                        if self.eos_id is not None and tok == self.eos_id:
                            eos_next.append(("eos", rid))
                elif kind == "admit":
                    _, rid, si = act
                    tok, (src, idx) = prefilled.pop(rid)
                    cache = self._move(cache, src, idx, si)
                    slot_rid[si] = rid
                    slot_tok[si, 0] = tok
                    slot_pos[si] = prompts[rid].shape[0]
                    metrics[rid]["admit_step"] = t
                elif kind == "decode":
                    (_, rids) = act
                    mon.start()
                    with telemetry.span("serve.decode_step", batch=len(rids)):
                        logits, cache = self._decode(
                            self.params, cache, slot_tok, slot_pos)
                        lg = np.asarray(logits)[:, -1, :]
                    mon.stop(t)
                    # greedy: ONE vectorized argmax for the whole batch —
                    # per-slot dispatches would dominate the tiny decode step
                    nxt_all = (np.argmax(lg[:, : cfg.vocab], axis=-1)
                               if self.temperature <= 0 else None)
                    for si, rid in list(slot_rid.items()):
                        nxt = (int(nxt_all[si]) if nxt_all is not None
                               else self._sample(lg[si], rid,
                                                 len(tokens[rid])))
                        tokens[rid].append(nxt)
                        slot_tok[si, 0] = nxt
                        slot_pos[si] += 1
                        if self.eos_id is not None and nxt == self.eos_id:
                            eos_next.append(("eos", rid))
                elif kind == "finish":
                    _, rid, reason = act
                    for si, r in list(slot_rid.items()):
                        if r == rid:
                            del slot_rid[si]
                    now = time.perf_counter()
                    m = metrics[rid]
                    m["finish_wall"] = now
                    m["finish_step"] = t
                    m["reason"] = reason
                    n_done += 1
                    telemetry.record_span(
                        "serve.request", m["arrival_wall"],
                        now - m["arrival_wall"], rid=rid, reason=reason,
                        tokens=len(tokens.get(rid, ())),
                    )
            if not actions and not events and i < len(pending):
                # idle gap before the next arrival: fast-forward the clock
                nxt_t = int(pending[i].arrival)
                state = dataclasses.replace(
                    state, step_idx=max(state.step_idx, nxt_t))

        duration = time.perf_counter() - t_start
        total = sum(len(v) for v in tokens.values())
        ttft, tpot = [], []
        for rid, m in metrics.items():
            if "first_token_wall" in m and "finish_wall" in m:
                ttft.append(m["first_token_wall"] - m["arrival_wall"])
                n = len(tokens[rid])
                if n >= 2:
                    tpot.append(
                        (m["finish_wall"] - m["first_token_wall"]) / (n - 1))
        tps = total / max(duration, 1e-9)
        telemetry.gauge_set("serve.tokens_per_s", tps)
        if mon.flagged_steps:
            self.log.info(
                f"stragglers: {len(mon.flagged_steps)} decode step(s) flagged")
        return ServeReport(
            tokens=tokens, metrics=metrics, steps=state.step_idx,
            duration_s=duration, total_tokens=total, tokens_per_s=tps,
            ttft_s=ttft, tpot_s=tpot,
        )


# ---------------------------------------------------------------------------
# Launcher modes
# ---------------------------------------------------------------------------


def _one_shot(args, cfg, log) -> None:
    """Legacy fixed-batch mode (and the fig_serve baseline): prefill one
    batch, decode ``--gen`` tokens, report tokens/s."""
    max_len = args.prompt_len + args.gen
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.prompt_len,
                       batch=args.batch)
    prompts, _ = data.global_batch(0)

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    with telemetry.span("prefill", batch=args.batch,
                        prompt_len=args.prompt_len):
        logits, cache = prefill(params, prompts)
        jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    def sample(logits, key):
        lg = logits[:, -1, : cfg.vocab]
        if args.temperature <= 0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg / args.temperature).astype(
            jnp.int32
        )

    key = jax.random.PRNGKey(1)
    tok = sample(logits, key)[:, None]
    out_tokens = [tok]
    # Straggler monitor on the decode loop: a persistently slow token
    # step on a serving replica is the same signal as a slow train step
    # on a pod — log it, don't kill the replica.
    mon = StragglerMonitor(action="log")
    t0 = time.time()
    for i in range(args.gen - 1):
        key = jax.random.fold_in(key, i)
        mon.start()
        with telemetry.span("decode_step", step=i):
            logits, cache = step(params, cache, tok,
                                 jnp.int32(args.prompt_len + i))
            tok = sample(logits, key)[:, None]
            jax.block_until_ready(tok)
        mon.stop(i)
        out_tokens.append(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    log.info(f"generated shape: {gen.shape}")
    log.info(f"sample row: {gen[0, :12].tolist()}")
    pre_tps = args.batch * args.prompt_len / max(t_prefill, 1e-9)
    dec_tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    telemetry.gauge_set("prefill.tokens_per_s", pre_tps)
    telemetry.gauge_set("decode.tokens_per_s", dec_tps)
    log.info(f"prefill: {t_prefill:.2f}s ({pre_tps:.0f} tok/s)  "
             f"decode: {t_decode:.2f}s ({dec_tps:.0f} tok/s)")
    if mon.flagged_steps:
        log.info(f"stragglers: {len(mon.flagged_steps)} decode step(s) flagged")


def _pcts(xs: list[float]) -> dict:
    if not xs:
        return {}
    v = sorted(xs)
    at = lambda q: v[min(len(v) - 1, int(q * (len(v) - 1)))]  # noqa: E731
    return {"p50": at(0.5), "p95": at(0.95), "p99": at(0.99)}


def _continuous(args, cfg, mesh, log) -> None:
    """Continuous-batching mode: Poisson open-loop arrivals at
    ``--arrival-rate`` requests per scheduler step."""
    scfg = SchedulerConfig(
        buckets=tuple(int(b) for b in args.buckets.split(",")),
        max_slots=args.slots, max_prefill=args.max_prefill,
        max_wait=args.max_wait,
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, scfg, max_new=args.gen,
                         temperature=args.temperature, eos_id=args.eos_id)
    if cfg.kron_ffn:
        for op in engine.prewarm(mesh=mesh if args.distributed else None):
            print(f"kron-ffn {op.describe()}")
    with telemetry.span("serve.compile_shapes"):
        n_exec = engine.compile_shapes()
    log.info(f"compiled {n_exec} serving executables "
             f"({len(scfg.buckets)}x{len(engine.batch_buckets)} prefill "
             f"shapes + decode)")
    reqs = poisson_trace(
        seed=args.seed, rate=args.arrival_rate, n=args.requests,
        prompt_lens=(max(1, args.prompt_len // 4), args.prompt_len),
        max_new=(max(1, args.gen // 4), args.gen),
    )
    rep = engine.run(reqs)
    done = [m for m in rep.metrics.values() if "finish_wall" in m]
    log.info(
        f"served {len(done)}/{args.requests} requests, "
        f"{rep.total_tokens} tokens in {rep.duration_s:.2f}s "
        f"({rep.tokens_per_s:.0f} tok/s, {rep.steps} scheduler steps)")
    log.info(f"ttft_s: {_pcts(rep.ttft_s)}  tpot_s: {_pcts(rep.tpot_s)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--want-model-parallel", type=int, default=16)
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (halves serving memory)")
    ap.add_argument("--kron-ffn", action="store_true",
                    help="Kron-compressed FFN projections: prefill's (B, T, d) "
                         "activations run the batched Kron-Matmul path "
                         "(kron_matmul_batched, shared factors) — one launch "
                         "per projection for the whole serving batch")
    ap.add_argument("--distributed", action="store_true",
                    help="with --kron-ffn: route the batched Kron-FFN prefill "
                         "through kron_matmul_batched_distributed on the "
                         "serving mesh (one collective round per projection "
                         "stage for the whole batch; shapes the mesh cannot "
                         "host fall back to the local batched path)")
    ap.add_argument("--numerics", choices=list(guard.NUMERICS_POLICIES),
                    default=None,
                    help="non-finite guard at StageProgram boundaries "
                         "(default: FASTKRON_NUMERICS or off); serving "
                         "typically wants warn — degraded tokens are better "
                         "than a dead replica")
    ap.add_argument("--telemetry", metavar="OUT.jsonl", default=None,
                    help="KronScope JSONL event sink: spans, guard/chaos "
                         "events, per-round comm metrics, tokens/s gauges")
    ap.add_argument("--trace", metavar="OUT.trace.json", default=None,
                    help="Chrome-trace (Perfetto) export of the host-side "
                         "spans, written at exit")
    # continuous-batching mode (docs/serving.md)
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="requests per scheduler step (Poisson open loop); "
                         "enables continuous batching")
    ap.add_argument("--requests", type=int, default=32,
                    help="number of requests in the arrival trace")
    ap.add_argument("--seed", type=int, default=0,
                    help="arrival-trace seed (same seed = same trace)")
    ap.add_argument("--buckets", default="16,32,64",
                    help="prompt padding buckets, comma-separated ascending")
    ap.add_argument("--slots", type=int, default=8,
                    help="decode slots (continuous-batching batch size)")
    ap.add_argument("--max-prefill", type=int, default=4,
                    help="max requests coalesced into one prefill")
    ap.add_argument("--max-wait", type=int, default=8,
                    help="starvation bound: force-schedule a queued request "
                         "after this many scheduler steps")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="token id treated as EOS (default: none; requests "
                         "run to their per-request max-new)")
    args = ap.parse_args()
    if args.distributed and not args.kron_ffn:
        ap.error("--distributed requires --kron-ffn (it distributes the "
                 "batched Kron-FFN prefill)")
    if args.numerics is not None:
        guard.set_numerics_policy(args.numerics)
    if args.telemetry or args.trace:
        telemetry.configure(jsonl=args.telemetry, trace=args.trace)
    log = get_logger("repro.serve")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg, dtype="float32")
    if args.kv_quant or args.kron_ffn:
        from dataclasses import replace

        cfg = replace(cfg, kv_quant=args.kv_quant or cfg.kv_quant,
                      kron_ffn=args.kron_ffn or cfg.kron_ffn)
    mesh = elastic_mesh(jax.device_count(), want_model=args.want_model_parallel)

    from ..core.layers import kron_distributed

    dist_scope = (
        kron_distributed(mesh) if args.distributed else contextlib.nullcontext()
    )
    with mesh, dist_scope:
        if args.arrival_rate is not None:
            _continuous(args, cfg, mesh, log)
        else:
            if cfg.kron_ffn:
                # One KronOp per FFN shape, its plan resolved for the serving
                # (batch, prompt-len) rows ONCE before the first trace and
                # reused across every request — the handle-based serving path.
                for op in prebuild_kron_ops(
                    cfg, batch=args.batch, seq_len=args.prompt_len,
                    mesh=mesh if args.distributed else None,
                ):
                    print(f"kron-ffn {op.describe()}")
            _one_shot(args, cfg, log)
    # ONE merged exit report: guard health carries the telemetry snapshot
    # (counters, gauges, histogram percentiles) when KronScope is live.
    report = guard.health_report()
    if telemetry.active() or report["events"] or any(
        h["degraded_calls"] or h["errors"] for h in report["ops"].values()
    ):
        log.info(f"health: {report}")
    telemetry.shutdown()


if __name__ == "__main__":
    main()
