"""Per-shape collective breakdown of one dry-run cell (hillclimb tooling).

    PYTHONPATH=src python -m repro.launch.collective_report --arch X --shape Y
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

import argparse

import jax

from ..runtime import hlo_cost as H
from ..runtime.hlo_analysis import shape_bytes


def report(arch: str, shape: str, multi_pod: bool = False, top: int = 15):
    from .mesh import make_production_mesh
    from .specs import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch, shape, mesh)
    donate = (0,) if cell.shape.kind == "train" else (
        (1,) if cell.shape.kind == "decode" else ())
    jitted = jax.jit(cell.fn, out_shardings=cell.out_shardings,
                     donate_argnums=donate)
    with mesh:
        txt = jitted.lower(*cell.in_specs).compile().as_text()
    comps = H._parse(txt)

    fusion_internal, referenced = set(), set()
    for c in comps.values():
        for i in c.instrs:
            for m in H._CALLS.finditer(i.args):
                fusion_internal.add(m.group(1))
            for m in H._TO_APPLY.finditer(i.args):
                fusion_internal.add(m.group(1))
    referenced |= fusion_internal
    for c in comps.values():
        for i in c.instrs:
            for pat in (H._BODY, H._COND):
                m = pat.search(i.args)
                if m:
                    referenced.add(m.group(1))
    entries = [n for n in comps if n not in referenced]
    weights: dict[str, float] = {}

    def visit(name, w):
        c = comps.get(name)
        if c is None:
            return
        weights[name] = weights.get(name, 0) + w
        for i in c.instrs:
            if i.op == "while":
                t = 1
                tm = H._TRIP.search(i.args)
                if tm:
                    t = int(tm.group(1))
                bm, cm = H._BODY.search(i.args), H._COND.search(i.args)
                if bm:
                    visit(bm.group(1), w * t)
                if cm:
                    visit(cm.group(1), w * (t + 1))
            else:
                for m in H._CALLS.finditer(i.args):
                    visit(m.group(1), w)

    for e in entries:
        visit(e, 1.0)

    rows = []
    for name, c in comps.items():
        w = weights.get(name, 0)
        if not w:
            continue
        for i in c.instrs:
            base = i.op.removesuffix("-start")
            if base in H.COLLECTIVE_OPS:
                rows.append((shape_bytes(i.type_str) * w, base,
                             i.type_str[:60], w, name[:40]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total collective bytes/device/step: {total/1e9:.2f} GB "
          f"({len(rows)} sites)")
    for r in rows[:top]:
        print(f"{r[0]/1e9:7.2f}GB {r[1]:<19} w={r[3]:<7.0f} {r[2]}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    a = ap.parse_args()
    report(a.arch, a.shape, a.multi_pod, a.top)
