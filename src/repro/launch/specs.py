"""input_specs: ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
device allocation) for every (arch x shape) dry-run cell, plus the jitted
step builder each cell lowers.

Cell kinds:
  train_4k    -> train_step(state, batch)
  prefill_32k -> prefill_step(params, tokens[, embeds])
  decode_32k / long_500k -> serve_step(params, cache, tokens, pos)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES, ShapeSpec, get_config
from ..models import model as M
from ..models.config import ModelConfig
from ..optim.adamw import OptConfig
from ..optim.shampoo import ShampooConfig, opt_for
from ..runtime.sharding import cache_shardings, param_shardings, token_sharding
from ..train.steps import (
    TrainState,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    opt_state_shardings,
)

# per-arch training overrides for the production meshes: activation memory
# (microbatches), optimizer-state dtype (100B+ models need bf16 m/v to
# fit 256 chips; DESIGN.md §8), and optimizer selection
# (``optimizer="shampoo"`` routes the cell through the Kron-factored
# preconditioner + its ``precond_every`` cadence; docs/optim.md)
TRAIN_OVERRIDES: dict[str, dict] = {
    "jamba-1.5-large-398b": dict(
        microbatches=16, state_dtype="bfloat16", acc_dtype="bfloat16"
    ),
    "mixtral-8x22b": dict(microbatches=8, state_dtype="bfloat16"),
    # mb=8 -> 4 after TP-sharded boundaries freed memory: halves the
    # per-microbatch FSDP weight regathers (§Perf C1 iteration 5)
    "qwen2.5-32b": dict(microbatches=4),
}
DEFAULT_MICROBATCHES = 4

# decode-cell overrides: int8 KV cache for the archs whose bf16 cache (plus
# XLA:CPU loop-carry copies) exceeds 16 GB/chip on the single-pod mesh —
# halves the dominant serving buffer (§Perf "beyond the three cells")
SERVE_OVERRIDES: dict[str, dict] = {
    "qwen2.5-32b": dict(kv_quant=True),
    "musicgen-large": dict(kv_quant=True),
    "jamba-1.5-large-398b": dict(kv_quant=True),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _with_sharding(tree_shapes: Any, tree_shardings: Any) -> Any:
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_shapes,
        tree_shardings,
    )


@dataclasses.dataclass
class Cell:
    """Everything the dry-run needs to lower one (arch x shape x mesh)."""

    arch: str
    shape: ShapeSpec
    cfg: ModelConfig
    fn: Any                 # callable to jit
    in_specs: tuple         # ShapeDtypeStructs with shardings
    in_shardings: tuple
    out_shardings: Any
    meta: dict


def _param_structs(cfg: ModelConfig, mesh: Mesh, *, fsdp_pods: bool):
    p_shape = jax.eval_shape(partial(M.init_params, cfg), jax.random.PRNGKey(0))
    p_shard = param_shardings(
        p_shape, mesh, fsdp_pods=fsdp_pods, tied_embed=cfg.tie_embeddings
    )
    return _with_sharding(p_shape, p_shard), p_shard


def _needs_pod_fsdp(cfg: ModelConfig, mesh: Mesh, state_dtype: str) -> bool:
    """Shard weights over pods too when one pod's HBM is tight for the
    state (params + m + v + grad/accumulator headroom)."""
    if "pod" not in mesh.axis_names:
        return False
    bytes_per_param = 2 + 2 + 2 * (4 if state_dtype == "float32" else 2)
    pod_devices = mesh.shape["data"] * mesh.shape["model"]
    return cfg.param_count() * bytes_per_param > 0.25 * pod_devices * 16e9


def build_cell(arch: str, shape_name: str, mesh: Mesh) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        sov = SERVE_OVERRIDES.get(cfg.name, {})
        if sov:
            cfg = dataclasses.replace(cfg, **sov)
    s, b = shape.seq_len, shape.global_batch
    ov = TRAIN_OVERRIDES.get(cfg.name, {})
    state_dtype = ov.get("state_dtype", "float32")
    fsdp_pods = _needs_pod_fsdp(cfg, mesh, state_dtype)
    params, p_shard = _param_structs(cfg, mesh, fsdp_pods=fsdp_pods)
    tok_sh = token_sharding(mesh, b)
    n_fe = cfg.n_frontend_tokens
    meta = dict(arch=arch, shape=shape_name, kind=shape.kind,
                mesh=dict(mesh.shape), fsdp_pods=fsdp_pods)

    if shape.kind == "train":
        if ov.get("optimizer") == "shampoo":
            opt_cfg: OptConfig = ShampooConfig(
                state_dtype=state_dtype,
                precond_every=ov.get("precond_every", 20),
            )
        else:
            opt_cfg = OptConfig(state_dtype=state_dtype)
        init_fn, _ = opt_for(opt_cfg)
        opt_shape = jax.eval_shape(partial(init_fn, cfg=opt_cfg), params)
        opt_shard = opt_state_shardings(
            opt_shape, p_shard, NamedSharding(mesh, P())
        )
        state = TrainState(
            params,
            _with_sharding(opt_shape, opt_shard),
            _sds((), jnp.int32),
        )
        state_sh = TrainState(p_shard, opt_shard, NamedSharding(mesh, P()))
        tokens = jax.ShapeDtypeStruct((b, s - n_fe), jnp.int32, sharding=tok_sh)
        labels = jax.ShapeDtypeStruct((b, s - n_fe), jnp.int32, sharding=tok_sh)
        batch = {"tokens": tokens, "labels": labels}
        batch_sh = {"tokens": tok_sh, "labels": tok_sh}
        if n_fe:
            e_sh = NamedSharding(mesh, P(tok_sh.spec[0], None, None))
            batch["embeds"] = jax.ShapeDtypeStruct(
                (b, n_fe, cfg.d_model), jnp.dtype(cfg.dtype), sharding=e_sh
            )
            batch_sh["embeds"] = e_sh
        microbatches = ov.get("microbatches", DEFAULT_MICROBATCHES)
        fn = make_train_step(
            cfg, opt_cfg, microbatches=microbatches, with_embeds=bool(n_fe),
            acc_dtype=jnp.dtype(ov.get("acc_dtype", "float32")),
        )
        meta.update(microbatches=microbatches, state_dtype=state_dtype,
                    optimizer=("shampoo" if isinstance(opt_cfg, ShampooConfig)
                               else "adamw"),
                    params=cfg.param_count(),
                    params_active=cfg.param_count(active_only=True))
        return Cell(arch, shape, cfg, fn, (state, batch),
                    (state_sh, batch_sh), (state_sh, None), meta)

    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((b, s - n_fe), jnp.int32, sharding=tok_sh)
        cache_shape = jax.eval_shape(lambda: M.init_cache(cfg, b, s))
        cache_sh = cache_shardings(cache_shape, mesh, b)
        fn = make_prefill_step(cfg, max_len=s, with_embeds=bool(n_fe))
        args = [params, tokens]
        shards = [p_shard, tok_sh]
        if n_fe:
            e_sh = NamedSharding(mesh, P(tok_sh.spec[0], None, None))
            args.append(jax.ShapeDtypeStruct(
                (b, n_fe, cfg.d_model), jnp.dtype(cfg.dtype), sharding=e_sh))
            shards.append(e_sh)
        meta.update(params=cfg.param_count())
        return Cell(arch, shape, cfg, fn, tuple(args), tuple(shards),
                    (None, cache_sh), meta)

    # decode: one new token against a seq_len-deep cache
    cache_shape = jax.eval_shape(lambda: M.init_cache(cfg, b, s))
    cache_sh = cache_shardings(cache_shape, mesh, b)
    cache = _with_sharding(cache_shape, cache_sh)
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=tok_sh)
    pos = _sds((), jnp.int32)
    fn = make_serve_step(cfg)
    meta.update(params=cfg.param_count())
    return Cell(arch, shape, cfg, fn,
                (params, cache, tokens, pos),
                (p_shard, cache_sh, tok_sh, NamedSharding(mesh, P())),
                (None, cache_sh), meta)


__all__ = ["build_cell", "Cell", "TRAIN_OVERRIDES"]
