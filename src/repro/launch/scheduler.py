"""Continuous-batching scheduler: a pure, deterministic state machine.

This module is the decision core of the serving engine (docs/serving.md).
It imports NO jax and touches NO device — every scheduling decision is a
pure function ``step(state, events) -> (state, actions)`` over frozen
dataclasses, so the whole policy is unit-testable as a simulation
(``simulate``) and bit-identical under replay with the same seed.  The
device side lives in ``launch/serve.py`` (``ServeEngine``), which executes
the emitted actions against the real model and feeds the observed events
(arrivals, EOS) back into the next ``step``.

Policy, in one paragraph: incoming prompts queue per **padding bucket**
(the smallest configured bucket that fits the prompt — each bucket shape
maps to one pre-resolved ``KronOp`` plan, see ``train.prebuild_kron_ops``).
A bucket group is launched as one prefill when it can fill the free decode
slots, when its oldest request has waited ``max_wait`` steps (the
starvation bound), or when the engine is idle.  Prefilled requests are
admitted into free decode **slots** on the next step (continuous batching);
slots recycle the moment a request finishes (EOS event or ``max_new``).
Each step emits at most ONE of ``prefill`` | ``decode`` — a prefill can
delay the next decode step but never preempts a decode batch mid-step.

Events (inputs to ``step``) are plain tuples::

    ("arrive", Request(...))    a new prompt entered the system
    ("eos", rid)                the model emitted EOS for ``rid`` during
                                the previous decode action

Actions (outputs of ``step``) are plain tuples, in execution order::

    ("reject", rid, reason)     prompt longer than the largest bucket
    ("admit", rid, slot)        a prefilled request took decode slot
    ("prefill", bucket, rids)   run one padded prefill for this group;
                                produces each request's FIRST token
    ("decode", rids)            one decode step over the occupied slots
                                (rids in slot order); one token per rid
    ("finish", rid, reason)     request left its slot ("eos" | "max_new")
"""
from __future__ import annotations

import dataclasses
import random
from dataclasses import dataclass, replace
from typing import Iterable, Sequence

FINISH_REASONS = ("eos", "max_new")
REQUEST_STATES = ("queued", "prefilling", "decoding", "finished", "rejected")


@dataclass(frozen=True)
class SchedulerConfig:
    """Static scheduling policy knobs.

    ``buckets``: ascending prompt padding buckets; a prompt is padded to the
    smallest bucket that fits it (one prefill plan per bucket shape).
    ``max_slots``: decode batch size == number of in-flight requests.
    ``max_prefill``: max requests coalesced into one prefill launch.
    ``max_wait``: starvation bound — a queued request whose bucket group is
    not yet full is force-scheduled once it has waited this many steps.
    """

    buckets: tuple[int, ...] = (16, 32, 64, 128)
    max_slots: int = 8
    max_prefill: int = 4
    max_wait: int = 8

    def __post_init__(self):
        b = tuple(int(x) for x in self.buckets)
        if not b or any(x <= 0 for x in b) or list(b) != sorted(set(b)):
            raise ValueError(
                f"buckets must be positive, strictly ascending: {self.buckets}"
            )
        object.__setattr__(self, "buckets", b)
        if self.max_slots <= 0 or self.max_prefill <= 0 or self.max_wait < 0:
            raise ValueError(
                "max_slots/max_prefill must be positive and max_wait >= 0: "
                f"{self.max_slots}, {self.max_prefill}, {self.max_wait}"
            )

    def bucket_for(self, prompt_len: int) -> int | None:
        """The smallest admissible padding bucket (None = prompt too long)."""
        for b in self.buckets:
            if prompt_len <= b:
                return b
        return None


@dataclass(frozen=True)
class Request:
    """One serving request as the scheduler sees it.

    ``arrival`` is in driver units (steps for the simulator, seconds for the
    wall-clock engine) and is carried through untouched — the scheduler
    itself only orders by event delivery."""

    rid: int
    prompt_len: int
    max_new: int
    arrival: float = 0.0


@dataclass(frozen=True)
class _Queued:
    req: Request
    since: int  # step the request entered the queue (starvation clock)


@dataclass(frozen=True)
class Slot:
    """An occupied decode slot: ``generated`` counts emitted tokens
    (the prefill's first token included)."""

    rid: int
    prompt_len: int
    bucket: int
    generated: int
    max_new: int


@dataclass(frozen=True)
class SchedulerState:
    """The complete scheduler state; every field is immutable data.

    Request lifecycle: queued -> prefilling -> (slot = decoding) ->
    finished; over-long prompts go straight to rejected.  ``prefilling``
    holds the group issued as last step's prefill action together with the
    slots reserved for it (``pending_slots``) — they are admitted at the
    START of the next step, so a prefill result is never mixed into a
    decode batch mid-step."""

    cfg: SchedulerConfig
    step_idx: int = 0
    queued: tuple[_Queued, ...] = ()
    prefilling: tuple[Request, ...] = ()
    pending_slots: tuple[int, ...] = ()
    pending_bucket: int = 0
    slots: tuple[Slot | None, ...] = ()
    finished: tuple[tuple[int, str], ...] = ()
    rejected: tuple[int, ...] = ()


def new_state(cfg: SchedulerConfig) -> SchedulerState:
    return SchedulerState(cfg=cfg, slots=(None,) * cfg.max_slots)


def audit(state: SchedulerState) -> dict[int, str]:
    """rid -> lifecycle state, for every request the scheduler has seen.
    Raises ``ValueError`` if any rid appears in two places (conservation
    violation) — the hypothesis property in tests/test_properties.py runs
    this after every step."""
    seen: dict[int, str] = {}

    def put(rid: int, where: str) -> None:
        if rid in seen:
            raise ValueError(
                f"conservation violated: rid {rid} is both {seen[rid]} "
                f"and {where}"
            )
        seen[rid] = where

    for q in state.queued:
        put(q.req.rid, "queued")
    for r in state.prefilling:
        put(r.rid, "prefilling")
    for s in state.slots:
        if s is not None:
            put(s.rid, "decoding")
    for rid, _ in state.finished:
        put(rid, "finished")
    for rid in state.rejected:
        put(rid, "rejected")
    return seen


def _pick_group(
    cfg: SchedulerConfig,
    queued: Sequence[_Queued],
    t: int,
    free: int,
    decoding: bool,
) -> tuple[int, list[_Queued]] | None:
    """The bucket group to prefill this step, or None.

    Groups queued requests by their smallest admissible bucket (queue
    order preserved).  A group is READY when it can fill the takeable
    slots (``min(max_prefill, free)``), when its head request has waited
    ``max_wait`` steps, or when nothing is decoding (idle engine — there
    is no batch to coalesce against, so waiting only adds latency).
    Among ready groups the one with the OLDEST head request wins
    (FIFO across buckets; ties break toward the smaller bucket)."""
    if free <= 0 or not queued:
        return None
    groups: dict[int, list[_Queued]] = {}
    for q in queued:
        b = cfg.bucket_for(q.req.prompt_len)
        assert b is not None  # over-long prompts were rejected at arrival
        groups.setdefault(b, []).append(q)
    take = min(cfg.max_prefill, free)
    ready = [
        (g[0].since, b, g)
        for b, g in groups.items()
        if len(g) >= take or (t - g[0].since) >= cfg.max_wait or not decoding
    ]
    if not ready:
        return None
    _, bucket, group = min(ready, key=lambda r: (r[0], r[1]))
    return bucket, group[:take]


def step(
    state: SchedulerState, events: Iterable[tuple]
) -> tuple[SchedulerState, tuple[tuple, ...]]:
    """One scheduling decision: ``(state, events) -> (state', actions)``.

    Pure and total: no clock, no randomness, no device.  Processing order
    within the step — admissions of last step's prefill group, then
    arrivals, then EOS finishes (freed slots are immediately reusable),
    then ONE of prefill | decode.  A decode action increments every
    occupied slot's ``generated`` and finishes slots reaching ``max_new``
    in the same step, so the engine never runs a wasted token."""
    cfg = state.cfg
    t = state.step_idx
    actions: list[tuple] = []
    queued = list(state.queued)
    slots = list(state.slots)
    finished = list(state.finished)
    rejected = list(state.rejected)

    # 1. Admissions: last step's prefill group takes its reserved slots.
    for req, si in zip(state.prefilling, state.pending_slots):
        actions.append(("admit", req.rid, si))
        slot = Slot(
            rid=req.rid, prompt_len=req.prompt_len,
            bucket=state.pending_bucket, generated=1, max_new=req.max_new,
        )
        if slot.generated >= slot.max_new:  # max_new == 1: prefill was all
            actions.append(("finish", req.rid, "max_new"))
            finished.append((req.rid, "max_new"))
        else:
            slots[si] = slot

    # 2. Arrivals queue (or are rejected when no bucket fits).
    eos_rids: list[int] = []
    for ev in events:
        if ev[0] == "arrive":
            req: Request = ev[1]
            if cfg.bucket_for(req.prompt_len) is None:
                actions.append(("reject", req.rid, "prompt_too_long"))
                rejected.append(req.rid)
            else:
                queued.append(_Queued(req, t))
        elif ev[0] == "eos":
            eos_rids.append(ev[1])
        else:
            raise ValueError(f"unknown event {ev!r}")

    # 3. EOS finishes recycle slots (stale EOS for an already-finished
    #    request — e.g. max_new fired the same decode — is ignored).
    for rid in eos_rids:
        for si, s in enumerate(slots):
            if s is not None and s.rid == rid:
                actions.append(("finish", rid, "eos"))
                finished.append((rid, "eos"))
                slots[si] = None
                break

    # 4. Schedule: one prefill OR one decode, never both.
    free = [si for si, s in enumerate(slots) if s is None]
    reserved = []
    prefilling: tuple[Request, ...] = ()
    pending_bucket = 0
    decoding = any(s is not None for s in slots)
    group = _pick_group(cfg, queued, t, len(free), decoding)
    if group is not None:
        bucket, entries = group
        reserved = free[: len(entries)]
        taken = {id(e) for e in entries}
        queued = [q for q in queued if id(q) not in taken]
        prefilling = tuple(e.req for e in entries)
        pending_bucket = bucket
        actions.append(("prefill", bucket, tuple(r.rid for r in prefilling)))
    elif decoding:
        rids = tuple(s.rid for s in slots if s is not None)
        actions.append(("decode", rids))
        for si, s in enumerate(slots):
            if s is None:
                continue
            s = replace(s, generated=s.generated + 1)
            if s.generated >= s.max_new:
                actions.append(("finish", s.rid, "max_new"))
                finished.append((s.rid, "max_new"))
                slots[si] = None
            else:
                slots[si] = s

    new = replace(
        state,
        step_idx=t + 1,
        queued=tuple(queued),
        prefilling=prefilling,
        pending_slots=tuple(reserved),
        pending_bucket=pending_bucket,
        slots=tuple(slots),
        finished=tuple(finished),
        rejected=tuple(rejected),
    )
    return new, tuple(actions)


# ---------------------------------------------------------------------------
# Synthetic open-loop arrival driver + device-free simulation
# ---------------------------------------------------------------------------


def poisson_trace(
    *,
    seed: int,
    rate: float,
    n: int,
    prompt_lens: tuple[int, int] = (4, 48),
    max_new: tuple[int, int] = (4, 16),
    start: float = 0.0,
) -> tuple[Request, ...]:
    """An open-loop Poisson arrival trace: ``n`` requests with exponential
    inter-arrival gaps at ``rate`` (requests per driver time unit), prompt
    lengths and token budgets uniform over the given inclusive ranges.
    Pure function of the arguments (``random.Random(seed)``) — the same
    seed replays the same trace, which is what makes the end-to-end replay
    test bit-identical."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = random.Random(seed)
    t = float(start)
    out = []
    for rid in range(n):
        t += rng.expovariate(rate)
        out.append(
            Request(
                rid=rid,
                prompt_len=rng.randint(*prompt_lens),
                max_new=rng.randint(*max_new),
                arrival=t,
            )
        )
    return tuple(out)


def sim_token(rid: int, index: int) -> int:
    """The simulated model: token ``index`` of request ``rid``.  A pure
    function of (rid, index) — so any dependence of a request's emitted
    sequence on its co-batched neighbours in a simulation is, by
    construction, a scheduler bug (wrong slot attribution)."""
    return (rid * 1000003 + index * 7919 + 12345) % 50021


@dataclass(frozen=True)
class SimResult:
    """Everything a deterministic simulation produced.

    ``trace``: the full ``(step_idx, action)`` sequence — the replay
    artifact two equal-seed runs must match bit-for-bit.
    ``tokens``: rid -> emitted token tuple.  ``metrics``: rid -> dict with
    ``arrival_step`` / ``first_token_step`` / ``admit_step`` /
    ``finish_step`` / ``reason``.  ``queue_depth``: per-step queue length.
    """

    trace: tuple[tuple[int, tuple], ...]
    tokens: dict[int, tuple[int, ...]]
    metrics: dict[int, dict]
    queue_depth: tuple[int, ...]
    steps: int


def simulate(
    cfg: SchedulerConfig,
    requests: Sequence[Request],
    *,
    seed: int = 0,
    max_steps: int = 100_000,
    check: bool = True,
) -> SimResult:
    """Run the scheduler against the simulated model, device-free.

    Arrivals become visible at ``step >= floor(req.arrival)`` (the trace's
    time unit is scheduler steps).  Each request's TRUE generation length
    is drawn deterministically from ``(seed, rid)`` — when it is below the
    request's ``max_new`` the driver feeds an ``eos`` event one step after
    the final token, exercising slot recycling on both finish paths.
    ``check=True`` audits conservation after every step."""
    pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
    gen_len = {
        r.rid: 1 + random.Random(f"{seed}:{r.rid}").randrange(r.max_new)
        for r in pending
    }
    state = new_state(cfg)
    trace: list[tuple[int, tuple]] = []
    tokens: dict[int, list[int]] = {}
    metrics: dict[int, dict] = {
        r.rid: {"arrival_step": int(r.arrival)} for r in pending
    }
    qdepth: list[int] = []
    eos_next: list[tuple] = []
    n_done = 0
    i = 0
    while n_done < len(pending) and state.step_idx < max_steps:
        t = state.step_idx
        events = list(eos_next)
        eos_next = []
        while i < len(pending) and int(pending[i].arrival) <= t:
            events.append(("arrive", pending[i]))
            i += 1
        state, actions = step(state, events)
        if check:
            audit(state)
        for act in actions:
            trace.append((t, act))
            kind = act[0]
            if kind == "prefill":
                for rid in act[2]:
                    tokens[rid] = [sim_token(rid, 0)]
                    metrics[rid]["first_token_step"] = t
                    if gen_len[rid] == 1:
                        eos_next.append(("eos", rid))
            elif kind == "admit":
                metrics[act[1]]["admit_step"] = t
            elif kind == "decode":
                for rid in act[1]:
                    idx = len(tokens[rid])
                    tokens[rid].append(sim_token(rid, idx))
                    if len(tokens[rid]) == gen_len[rid]:
                        eos_next.append(("eos", rid))
            elif kind in ("finish", "reject"):
                rid = act[1]
                metrics[rid]["finish_step"] = t
                metrics[rid]["reason"] = act[2]
                n_done += 1
        qdepth.append(len(state.queued))
        if not actions and not events and i < len(pending):
            # idle gap before the next arrival: fast-forward the clock
            nxt = int(pending[i].arrival)
            state = replace(state, step_idx=max(state.step_idx, nxt))
    return SimResult(
        trace=tuple(trace),
        tokens={rid: tuple(v) for rid, v in tokens.items()},
        metrics=metrics,
        queue_depth=tuple(qdepth),
        steps=state.step_idx,
    )


__all__ = [
    "SchedulerConfig",
    "Request",
    "Slot",
    "SchedulerState",
    "new_state",
    "step",
    "audit",
    "poisson_trace",
    "sim_token",
    "simulate",
    "SimResult",
    "FINISH_REASONS",
    "REQUEST_STATES",
]
