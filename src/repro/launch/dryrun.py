import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
# ^ MUST precede any jax import: jax locks the device count on first init.
# Only the dry-run gets 512 placeholder devices; tests/benches see 1.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Per cell this records (experiments/dryrun/*.json):
  * compiled.memory_analysis()  — proves the cell fits 16 GB/chip;
  * compiled.cost_analysis()    — per-device HLO FLOPs / bytes accessed;
  * collective payload bytes parsed from the compiled HLO text;
  * the three roofline terms (TPU v5e: 197 TF bf16, 819 GB/s HBM,
    50 GB/s/link ICI) + dominant bottleneck + MODEL_FLOPS/HLO_FLOPs.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from ..configs import LONG_OK, SHAPES, runnable_cells, skipped_cells
from ..runtime.hlo_cost import analyze as hlo_analyze

# hardware model (TPU v5e)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCI_BW = 25e9  # cross-pod (not separately parsed; noted in EXPERIMENTS.md)
HBM_PER_CHIP = 16e9


def mesh_tag(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def model_flops(cell, cfg) -> float:
    """6*N*D train / 2*N*D forward-only (global, per step)."""
    n_active = cfg.param_count(active_only=True)
    s, b = cell.shape.seq_len, cell.shape.global_batch
    if cell.shape.kind == "train":
        return 6.0 * n_active * s * b
    if cell.shape.kind == "prefill":
        return 2.0 * n_active * s * b
    return 2.0 * n_active * b  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str | None):
    from .mesh import make_production_mesh
    from .specs import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    cell = build_cell(arch, shape_name, mesh)

    t0 = time.time()
    donate = (0,) if cell.shape.kind == "train" else (
        (1,) if cell.shape.kind == "decode" else ()
    )
    jitted = jax.jit(cell.fn, out_shardings=cell.out_shardings,
                     donate_argnums=donate)
    with mesh:  # ambient mesh: activates the model's sharding constraints
        lowered = jitted.lower(*cell.in_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    raw_cost = compiled.cost_analysis()
    # jax < 0.5 returns a one-element list of dicts, newer versions the dict.
    if isinstance(raw_cost, (list, tuple)):
        raw_cost = raw_cost[0] if raw_cost else {}
    hlo = compiled.as_text()
    # trip-count-weighted analysis: compiled.cost_analysis() counts scan
    # bodies ONCE (verified), under-reporting layer stacks by 24-100x.
    cost = hlo_analyze(hlo)

    flops_dev = cost.flops
    bytes_dev = cost.bytes_accessed
    coll_bytes_dev = cost.total_collective_bytes

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_bytes_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cell, cell.cfg)
    mf_dev = mf / n_chips
    useful = mf_dev / flops_dev if flops_dev else 0.0

    mem_fields = {}
    for f in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            mem_fields[f] = int(v)
    peak_bytes = mem_fields.get("temp_size_in_bytes", 0) + max(
        mem_fields.get("argument_size_in_bytes", 0)
        + mem_fields.get("output_size_in_bytes", 0)
        - mem_fields.get("alias_size_in_bytes", 0),
        0,
    )

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag(multi_pod),
        "chips": n_chips,
        "meta": cell.meta,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_dot_flops": cost.dot_flops,
            "hlo_bytes": bytes_dev,
            "collective_bytes": coll_bytes_dev,
            "collectives": cost.collective_bytes,
            "collective_counts": cost.collective_counts,
            "raw_cost_analysis_flops": float(raw_cost.get("flops", 0.0)),
            "raw_cost_analysis_bytes": float(raw_cost.get("bytes accessed", 0.0)),
        },
        "memory_analysis": mem_fields,
        "peak_bytes_per_device": peak_bytes,
        "fits_hbm": peak_bytes < HBM_PER_CHIP,
        "roofline": {
            **{k: float(v) for k, v in terms.items()},
            "dominant": dominant,
            "model_flops_global": mf,
            "model_flops_per_device": mf_dev,
            "useful_flops_ratio": useful,
        },
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_tag(multi_pod)}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(record, f, indent=1)
    return record


def fmt_row(r) -> str:
    t = r["roofline"]
    return (
        f"{r['arch']:<24} {r['shape']:<12} {r['mesh']:<8} "
        f"comp={t['compute_s']*1e3:8.2f}ms mem={t['memory_s']*1e3:8.2f}ms "
        f"coll={t['collective_s']*1e3:8.2f}ms dom={t['dominant']:<13} "
        f"peak={r['peak_bytes_per_device']/1e9:5.2f}GB "
        f"fit={'Y' if r['fits_hbm'] else 'N'} useful={t['useful_flops_ratio']:.2f} "
        f"compile={r['compile_s']:.0f}s"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = runnable_cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        if args.shape == "long_500k" and args.arch.replace("-", "_").replace(".", "_") not in LONG_OK:
            print(f"SKIP {args.arch} long_500k (full attention)")
            return
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                rec = run_cell(arch, shape, mp, args.out)
                print(fmt_row(rec), flush=True)
            except Exception as e:
                failures.append((arch, shape, mp, repr(e)))
                print(f"FAIL {arch} {shape} {mesh_tag(mp)}: {e}", flush=True)
                if not args.continue_on_error:
                    traceback.print_exc()
                    sys.exit(1)
    for arch, shape, reason in skipped_cells():
        print(f"SKIP {arch:<24} {shape:<12} ({reason})")
    if failures:
        print(f"{len(failures)} FAILURES"); sys.exit(1)
    print("DRY-RUN OK")


if __name__ == "__main__":
    main()
