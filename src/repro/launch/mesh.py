"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets XLA_FLAGS before first
jax init and everything else must see the default single device.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods over DCI).

    Uses the first prod(shape) devices so a 512-placeholder dry-run can
    build the single-pod mesh too.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices for {shape}, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            f"sets this automatically)"
        )
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_debug_mesh(data: int = 2, model: int = 4):
    """Small mesh for multi-device CPU tests (8 fake devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


__all__ = ["make_production_mesh", "make_debug_mesh"]
