"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together every substrate: config -> mesh (elastic to whatever devices
exist) -> sharded init (or checkpoint restore, cross-mesh) -> synthetic data
pipeline -> jitted train_step (FSDP x TP, microbatch accumulation) ->
straggler monitor -> atomic async checkpoints.

On this CPU container use ``--reduced`` (tiny same-family config, 1 device).
On a real pod, remove ``--reduced`` and launch one process per host; the
same code path lowers the full config onto the production mesh (proven by
dryrun.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import get_config
from ..data import SyntheticLM
from ..models.config import reduced as reduce_cfg
from ..optim import OptConfig, ShampooConfig, state_memory_report
from ..runtime import guard, telemetry
from ..runtime.events import get_logger
from ..runtime.fault import StragglerMonitor, elastic_mesh
from ..runtime.sharding import param_shardings, token_sharding
from ..train import (
    TrainState, make_train_step, opt_state_shardings, train_state_init,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config for CPU demo runs")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--want-model-parallel", type=int, default=16)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--kron-ffn", action="store_true",
                    help="enable the paper's Kron-compressed FFN projections")
    ap.add_argument("--optimizer", choices=("adamw", "shampoo"),
                    default="adamw",
                    help="shampoo: Kron-factored preconditioning applied "
                         "through batched KronOp shape groups (docs/optim.md)")
    ap.add_argument("--precond-every", type=int, default=20,
                    help="shampoo inverse-root refresh cadence (steps)")
    ap.add_argument("--numerics", choices=list(guard.NUMERICS_POLICIES),
                    default=None,
                    help="non-finite guard at StageProgram boundaries "
                         "(default: FASTKRON_NUMERICS or off); training "
                         "typically wants raise — fail fast and restart from "
                         "the last checkpoint before the divergence")
    ap.add_argument("--telemetry", metavar="OUT.jsonl", default=None,
                    help="KronScope JSONL event sink: spans, guard/chaos "
                         "events, step-latency histograms, tokens/s gauges")
    ap.add_argument("--trace", metavar="OUT.trace.json", default=None,
                    help="Chrome-trace (Perfetto) export of the host-side "
                         "spans, written at exit")
    args = ap.parse_args()
    if args.numerics is not None:
        guard.set_numerics_policy(args.numerics)
    if args.telemetry or args.trace:
        telemetry.configure(jsonl=args.telemetry, trace=args.trace)
    log = get_logger("repro.train")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg, dtype="float32")
    if args.kron_ffn:
        from dataclasses import replace

        cfg = replace(cfg, kron_ffn=True)
    opt_kw = dict(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                  decay_steps=args.steps)
    if args.optimizer == "shampoo":
        opt_cfg: OptConfig = ShampooConfig(
            precond_every=args.precond_every, **opt_kw
        )
    else:
        opt_cfg = OptConfig(**opt_kw)

    mesh = elastic_mesh(jax.device_count(),
                        want_model=args.want_model_parallel)
    print(f"mesh: {dict(mesh.shape)} devices={jax.device_count()}")

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    mgr = CheckpointManager(args.ckpt_dir, keep=3, async_save=True) \
        if args.ckpt_dir else None

    with mesh:
        state = train_state_init(cfg, opt_cfg, jax.random.PRNGKey(0))
        p_shard = param_shardings(
            jax.eval_shape(lambda: state.params), mesh,
            tied_embed=cfg.tie_embeddings,
        )
        replicated = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        opt_shard = opt_state_shardings(state.opt, p_shard, replicated)
        state = TrainState(
            jax.device_put(state.params, p_shard),
            jax.device_put(state.opt, opt_shard),
            state.step,
        )
        start = 0
        if mgr and args.resume and mgr.latest_step() is not None:
            restored = mgr.restore(state._asdict())
            state = TrainState(**restored)
            start = int(state.step)
            print(f"resumed from step {start}")

        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, microbatches=args.microbatches),
            donate_argnums=(0,),
        )
        tok_sh = token_sharding(mesh, args.batch)
        mon = StragglerMonitor(action="log")
        shampoo_on = isinstance(opt_cfg, ShampooConfig)
        base_step_s = None  # rolling min of non-refresh steps (see below)
        t_start = time.time()
        for i in range(start, args.steps):
            toks, labels = data.global_batch(i)
            batch = {
                "tokens": jax.device_put(toks, tok_sh),
                "labels": jax.device_put(labels, tok_sh),
            }
            mon.start()
            t_step = time.perf_counter()
            with telemetry.span("train_step", step=i):
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
            dt_step = time.perf_counter() - t_step
            telemetry.observe("train.step_seconds", dt_step)
            if shampoo_on and telemetry.active():
                telemetry.gauge_set(
                    "optim.precond_stale_steps",
                    int(metrics["precond_stale_steps"]),
                )
                # the refresh is fused into the jitted step (lax.cond), so
                # its cost is observed as the refresh-step excess over the
                # rolling minimum of plain steps
                opt_step = int(state.opt["step"])
                is_refresh = (
                    opt_step == 1
                    or opt_step % max(opt_cfg.precond_every, 1) == 0
                )
                if not is_refresh and i > start:
                    base_step_s = (
                        dt_step if base_step_s is None
                        else min(base_step_s, dt_step)
                    )
                elif is_refresh and base_step_s is not None:
                    telemetry.observe(
                        "optim.root_refresh_seconds",
                        max(0.0, dt_step - base_step_s),
                    )
            mon.stop(i)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(
                    f"step {i:5d} loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"lr={float(metrics['lr']):.2e}",
                    flush=True,
                )
            if mgr and (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state._asdict())
        if mgr:
            mgr.save(args.steps, state._asdict())
            mgr.wait()
    dt = time.time() - t_start
    tok_s = args.steps * args.batch * args.seq / max(dt, 1e-9)
    telemetry.gauge_set("train.tokens_per_s", tok_s)
    log.info(f"done: {args.steps} steps in {dt:.1f}s ({tok_s:.0f} tok/s)")
    # Optimizer-state memory by dtype: makes the bf16 ``state_dtype``
    # saving (and Shampoo's kron-statistics footprint) visible at exit.
    mem = state_memory_report(state.opt)
    log.info(
        f"optimizer state: {mem['total_bytes'] / 1e6:.2f} MB "
        + " ".join(
            f"{k}={v / 1e6:.2f}MB" for k, v in sorted(mem["by_dtype"].items())
        )
    )
    # ONE merged exit report: guard health carries the telemetry snapshot
    # (counters, gauges, histogram percentiles) when KronScope is live.
    report = guard.health_report()
    report["opt_state_memory"] = mem
    if telemetry.active() or report["events"] or any(
        h["degraded_calls"] or h["errors"] for h in report["ops"].values()
    ):
        log.info(f"health: {report}")
    telemetry.shutdown()


if __name__ == "__main__":
    main()
