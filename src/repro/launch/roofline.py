"""Roofline report generator: experiments/dryrun/*.json -> markdown tables.

    PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]

Per (arch x shape x mesh): the three roofline terms (compute / HBM /
collective seconds per step, per chip), dominant bottleneck, MODEL_FLOPS
vs HLO FLOPs ratio, HBM fit, and a one-line "what would move the dominant
term" note.  Also ranks cells for the §Perf hillclimb (worst roofline
fraction / most collective-bound / most paper-representative).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HW = "TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI"

SUGGESTIONS = {
    ("memory_s", "train"): "fuse norm/residual f32 round-trips; bf16 boundaries",
    ("memory_s", "prefill"): "fuse attention softmax pipeline (flash kernel)",
    ("memory_s", "decode"): "quantize KV cache; fuse cache-update+attention",
    ("collective_s", "train"): "overlap FSDP gathers with compute; bf16 collectives",
    ("collective_s", "prefill"): "shard KV heads not hd; fewer norm reshards",
    ("collective_s", "decode"): "replicate small weights; batch cache collectives",
    ("compute_s", "train"): "already MXU-bound: raise arithmetic intensity",
    ("compute_s", "prefill"): "already MXU-bound",
    ("compute_s", "decode"): "already MXU-bound",
}


def load(dir_: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def step_time(rec) -> float:
    t = rec["roofline"]
    return max(t["compute_s"], t["memory_s"], t["collective_s"])


def roofline_fraction(rec) -> float:
    """ideal/achieved step time.

    train/prefill: ideal = MODEL_FLOPS at peak MXU (compute roofline).
    decode: one token must stream weights+cache once from HBM — the
    bandwidth roofline: ideal = argument bytes / HBM_BW (compute ideal is
    meaningless at batch*1 token granularity)."""
    t = rec["roofline"]
    if rec["meta"]["kind"] == "decode":
        args = rec["memory_analysis"].get("argument_size_in_bytes", 0)
        ideal = args / 819e9
    else:
        ideal = t["model_flops_per_device"] / 197e12
    return ideal / max(step_time(rec), 1e-12)


def table(recs, mesh: str) -> str:
    rows = [
        "| arch | shape | comp (ms) | HBM (ms) | coll (ms) | dominant | "
        "useful | RF | peak GB | fit |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.2f} | "
            f"{t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} | "
            f"{t['dominant'].removesuffix('_s')} | "
            f"{t['useful_flops_ratio']:.2f} | {roofline_fraction(r):.4f} | "
            f"{r['peak_bytes_per_device']/1e9:.2f} | "
            f"{'Y' if r['fits_hbm'] else 'N'} |"
        )
    return "\n".join(rows)


def pick_hillclimb(recs) -> list[tuple[str, str]]:
    single = [r for r in recs if r["mesh"] == "16x16"]
    worst_rf = min(single, key=roofline_fraction)
    most_coll = max(
        single,
        key=lambda r: r["roofline"]["collective_s"] / max(step_time(r), 1e-12)
        * (1 if r["roofline"]["dominant"] == "collective_s" else 0.5),
    )
    return [
        (worst_rf["arch"], worst_rf["shape"], "worst roofline fraction"),
        (most_coll["arch"], most_coll["shape"], "most collective-bound"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print(f"# Roofline ({HW})\n")
    for mesh in ("16x16", "2x16x16"):
        n = sum(1 for r in recs if r["mesh"] == mesh)
        print(f"## mesh {mesh} ({n} cells)\n")
        print(table(recs, mesh))
        print()
    print("## hillclimb candidates (single-pod)\n")
    for arch, shape, why in pick_hillclimb(recs):
        print(f"* {arch} {shape} — {why}")
    print("* (third pick: most paper-representative — set manually)")


if __name__ == "__main__":
    main()
