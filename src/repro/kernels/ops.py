"""Jit'd wrappers + backend dispatch for the Kron-Matmul kernels.

Three backends for one sliced multiply / fused chain:

  * ``xla``     — the pure-jnp einsum formulation (kernels/ref.py semantics,
                  but in the input dtype with f32 accumulation).  On CPU this
                  is the fast path; fused chains additionally run as a
                  ``lax.scan`` over M-tiles so the whole per-tile chain stays
                  cache-resident — the CPU analogue of the Pallas kernel's
                  VMEM fusion (see EXPERIMENTS.md §Backward).
  * ``pallas``  — the Pallas TPU kernels (kron_sliced.py / kron_fused.py /
                  kron_fused_t.py).  ``interpret=True`` is forced
                  automatically off-TPU so the same call sites work in this
                  CPU container (correctness validation) and on real hardware
                  (performance).
  * ``auto``    — pallas on TPU, xla elsewhere.

The wrappers are shape-polymorphic dispatchers, not jitted themselves: the
underlying implementations are jitted (or meant to be called under an outer
jit, e.g. inside train_step).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from . import kron_fused, kron_fused_t, kron_sliced, kron_sliced_t
from . import ref as _ref

Backend = str  # "auto" | "xla" | "pallas"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: Backend) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "xla"
    return backend


def _interpret() -> bool:
    return not _on_tpu()


def acc_dtype_for(dtype) -> jnp.dtype:
    """f32 accumulation for <=f32 inputs, f64 for f64 (never truncate)."""
    return jnp.promote_types(dtype, jnp.float32)


def _sliced_body(x: jax.Array, f: jax.Array) -> jax.Array:
    m, k = x.shape
    p, q = f.shape
    s = k // p
    acc = jax.lax.dot_general(
        x.reshape(m * s, p), f, (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype_for(x.dtype),
    )
    return (
        jnp.swapaxes(acc.reshape(m, s, q), 1, 2).reshape(m, q * s).astype(x.dtype)
    )


_sliced_xla = jax.jit(_sliced_body)


def sliced_multiply(
    x: jax.Array,
    f: jax.Array,
    *,
    backend: Backend = "auto",
    tiles: tuple[int, int, int] | None = None,
) -> jax.Array:
    """One FastKron sliced multiply: (M, K) x (P, Q) -> (M, K//P*Q)."""
    b = resolve_backend(backend)
    if b == "xla":
        return _sliced_xla(x, f)
    t_m, t_s, t_q = tiles or (8, None, None)
    return kron_sliced.sliced_multiply_pallas(
        x, f, t_m=t_m, t_s=t_s, t_q=t_q, interpret=_interpret()
    )


def _sliced_t_body(dy: jax.Array, f: jax.Array) -> jax.Array:
    m, l = dy.shape
    p, q = f.shape
    s = l // q
    acc = jax.lax.dot_general(
        jnp.swapaxes(dy.reshape(m, q, s), 1, 2).reshape(m * s, q),
        jnp.swapaxes(f, 0, 1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype_for(dy.dtype),
    )
    return acc.reshape(m, s * p).astype(dy.dtype)


_sliced_t_xla = jax.jit(_sliced_t_body)


def sliced_multiply_t(
    dy: jax.Array,
    f: jax.Array,
    *,
    backend: Backend = "auto",
    tiles: tuple[int, int, int] | None = None,
) -> jax.Array:
    """Transposed sliced multiply (C1 backward): (M, Q*S) x (P,Q) -> (M, S*P)."""
    b = resolve_backend(backend)
    if b == "xla":
        return _sliced_t_xla(dy, f)
    t_m, t_s, t_q = tiles or (8, None, None)
    return kron_sliced_t.sliced_multiply_t_pallas(
        dy, f, t_m=t_m, t_s=t_s, t_q=t_q, interpret=_interpret()
    )


# ---------------------------------------------------------------------------
# Fused chains (C3): Pallas kernels on TPU, M-tiled lax.scan on XLA/CPU
# ---------------------------------------------------------------------------


def _xla_tile_rows(m: int, t_m: int) -> int | None:
    """Effective M-tile for the scan-fused XLA path, or None to run untiled.

    Tiling pays off only when the tile chain fits cache and there are enough
    tiles to amortize the scan; tiny analytic t_m values (tuned for the TPU
    sublane) are clamped up to a useful CPU tile.
    """
    t = min(m, max(t_m, 8))
    if t >= m or m % t or m // t < 2:
        return None
    return t


@functools.partial(jax.jit, static_argnames=("t_m",))
def _fused_xla(x: jax.Array, factors: tuple[jax.Array, ...], t_m: int) -> jax.Array:
    def chain(y):
        for f in factors:
            y = _sliced_body(y, f)
        return y

    m, k = x.shape
    t = _xla_tile_rows(m, t_m)
    if t is None:
        return chain(x)
    _, yt = jax.lax.scan(
        lambda _, xt: (None, chain(xt)), None, x.reshape(m // t, t, k)
    )
    return yt.reshape(m, -1)


def fused_kron(
    x: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
) -> jax.Array:
    """Chain of sliced multiplies in one kernel (C3).  factors[0] == F^N."""
    b = resolve_backend(backend)
    if b == "xla":
        return _fused_xla(x, tuple(factors_last_first), t_m)
    return kron_fused.fused_kron_pallas(
        x, *factors_last_first, t_m=t_m, t_k=t_k, t_qs=t_qs, interpret=_interpret()
    )


@functools.partial(jax.jit, static_argnames=("t_m",))
def _fused_t_xla(dy: jax.Array, factors: tuple[jax.Array, ...], t_m: int) -> jax.Array:
    def chain(g):
        for f in reversed(factors):
            g = _sliced_t_body(g, f)
        return g

    m, l = dy.shape
    t = _xla_tile_rows(m, t_m)
    if t is None:
        return chain(dy)
    _, gt = jax.lax.scan(
        lambda _, gt_: (None, chain(gt_)), None, dy.reshape(m // t, t, l)
    )
    return gt.reshape(m, -1)


def fused_kron_t(
    dy: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
) -> jax.Array:
    """Transposed fused chain: the input cotangent of ``fused_kron``.

    Takes the SAME factor list as the forward call and un-applies the chain
    (last-applied factor's transpose first).
    """
    b = resolve_backend(backend)
    if b == "xla":
        return _fused_t_xla(dy, tuple(factors_last_first), t_m)
    return kron_fused_t.fused_kron_t_pallas(
        dy, *factors_last_first, t_m=t_m, t_k=t_k, t_qs=t_qs, interpret=_interpret()
    )


def _fused_bwd_tile(us_first, g, factors, acc):
    """Backward of one chain tile: shared relayout per factor feeds both the
    factor-gradient GEMM and the chain-step GEMM."""
    t_m = g.shape[0]
    us = [us_first]
    y = us_first
    for f in factors[:-1]:
        y = _sliced_body(y, f)
        us.append(y)
    dfs = [None] * len(factors)
    cols = g.shape[1]
    for idx in reversed(range(len(factors))):
        f = factors[idx]
        p, q = int(f.shape[0]), int(f.shape[1])
        s = cols // q
        g2 = jnp.swapaxes(g.reshape(t_m, q, s), 1, 2).reshape(t_m * s, q)
        u2 = us[idx].reshape(t_m * s, p)
        dfs[idx] = jax.lax.dot_general(
            u2.astype(acc), g2.astype(acc), (((0,), (0,)), ((), ())),
            preferred_element_type=acc,
        )
        g = jax.lax.dot_general(
            g2, f, (((1,), (1,)), ((), ())), preferred_element_type=acc
        ).reshape(t_m, s * p).astype(g.dtype)
        cols = s * p
    return dfs, g


@functools.partial(jax.jit, static_argnames=("t_m",))
def _fused_bwd_xla(
    x: jax.Array, dy: jax.Array, factors: tuple[jax.Array, ...], t_m: int
):
    acc = acc_dtype_for(dy.dtype)
    m, k = x.shape
    t = _xla_tile_rows(m, t_m)
    if t is None:
        dfs, dx = _fused_bwd_tile(x, dy, factors, acc)
        return dx, tuple(dfs)

    def body(carry, xg):
        dfs, g = _fused_bwd_tile(xg[0], xg[1], factors, acc)
        return tuple(c + d for c, d in zip(carry, dfs)), g

    carry0 = tuple(jnp.zeros(f.shape, acc) for f in factors)
    dfs, dxt = jax.lax.scan(
        body, carry0, (x.reshape(m // t, t, k), dy.reshape(m // t, t, -1))
    )
    return dxt.reshape(m, k), dfs


def fused_kron_bwd(
    x: jax.Array,
    dy: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_m: int = 8,
    t_k: int | None = None,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """Full backward of one fused stage: (dx, per-factor grads).

    x is the stage input, dy the stage output cotangent; factor grads are
    returned in ``factors_last_first`` order, accumulated in f32 (callers
    cast).  On XLA this runs as one M-tiled scan whose per-tile body
    rematerializes the forward chain in cache; on TPU it is a single Pallas
    kernel doing the same in VMEM (kron_fused_t.fused_kron_bwd_pallas).
    """
    b = resolve_backend(backend)
    if b == "xla":
        return _fused_bwd_xla(x, dy, tuple(factors_last_first), t_m)
    return kron_fused_t.fused_kron_bwd_pallas(
        x, dy, *factors_last_first, t_m=t_m, t_k=t_k, interpret=_interpret()
    )


# Re-export the oracles so tests can import one module.
sliced_multiply_ref = _ref.sliced_multiply_ref
fused_kron_ref = _ref.fused_kron_ref
sliced_multiply_t_ref = _ref.sliced_multiply_t_ref
fused_kron_t_ref = _ref.fused_kron_t_ref

__all__ = [
    "sliced_multiply",
    "sliced_multiply_t",
    "fused_kron",
    "fused_kron_t",
    "fused_kron_bwd",
    "resolve_backend",
    "acc_dtype_for",
    "sliced_multiply_ref",
    "sliced_multiply_t_ref",
    "fused_kron_ref",
    "fused_kron_t_ref",
]
