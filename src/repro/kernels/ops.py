"""Jit'd wrappers + backend dispatch for the Kron-Matmul kernels.

Three backends for one sliced multiply / fused chain:

  * ``xla``     — the pure-jnp einsum formulation (kernels/ref.py semantics,
                  but in the input dtype with f32 accumulation).  On CPU this
                  is the fast path; fused chains additionally run as a
                  ``lax.scan`` over M-tiles so the whole per-tile chain stays
                  cache-resident — the CPU analogue of the Pallas kernel's
                  VMEM fusion (see EXPERIMENTS.md §Backward).
  * ``pallas``  — the Pallas TPU kernels (kron_sliced.py / kron_fused.py /
                  kron_fused_t.py).  ``interpret=True`` is forced
                  automatically off-TPU so the same call sites work in this
                  CPU container (correctness validation) and on real hardware
                  (performance).
  * ``auto``    — pallas on TPU, xla elsewhere.

The wrappers are shape-polymorphic dispatchers, not jitted themselves: the
underlying implementations are jitted (or meant to be called under an outer
jit, e.g. inside train_step).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from . import kron_fused, kron_fused_t, kron_sliced, kron_sliced_t
from . import ref as _ref

Backend = str  # "auto" | "xla" | "pallas"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: Backend) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "xla"
    return backend


def _interpret() -> bool:
    return not _on_tpu()


def acc_dtype_for(dtype) -> jnp.dtype:
    """f32 accumulation for <=f32 inputs, f64 for f64 (never truncate)."""
    return jnp.promote_types(dtype, jnp.float32)


def _sliced_body(x: jax.Array, f: jax.Array) -> jax.Array:
    m, k = x.shape
    p, q = f.shape
    s = k // p
    acc = jax.lax.dot_general(
        x.reshape(m * s, p), f, (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype_for(x.dtype),
    )
    return (
        jnp.swapaxes(acc.reshape(m, s, q), 1, 2).reshape(m, q * s).astype(x.dtype)
    )


_sliced_xla = jax.jit(_sliced_body)


def sliced_multiply(
    x: jax.Array,
    f: jax.Array,
    *,
    backend: Backend = "auto",
    tiles: tuple[int, int, int] | None = None,
) -> jax.Array:
    """One FastKron sliced multiply: (M, K) x (P, Q) -> (M, K//P*Q)."""
    b = resolve_backend(backend)
    if b == "xla":
        return _sliced_xla(x, f)
    t_m, t_s, t_q = tiles or (8, None, None)
    return kron_sliced.sliced_multiply_pallas(
        x, f, t_m=t_m, t_s=t_s, t_q=t_q, interpret=_interpret()
    )


def _sliced_t_body(dy: jax.Array, f: jax.Array) -> jax.Array:
    m, l = dy.shape
    p, q = f.shape
    s = l // q
    acc = jax.lax.dot_general(
        jnp.swapaxes(dy.reshape(m, q, s), 1, 2).reshape(m * s, q),
        jnp.swapaxes(f, 0, 1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype_for(dy.dtype),
    )
    return acc.reshape(m, s * p).astype(dy.dtype)


_sliced_t_xla = jax.jit(_sliced_t_body)


def sliced_multiply_t(
    dy: jax.Array,
    f: jax.Array,
    *,
    backend: Backend = "auto",
    tiles: tuple[int, int, int] | None = None,
) -> jax.Array:
    """Transposed sliced multiply (C1 backward): (M, Q*S) x (P,Q) -> (M, S*P)."""
    b = resolve_backend(backend)
    if b == "xla":
        return _sliced_t_xla(dy, f)
    t_m, t_s, t_q = tiles or (8, None, None)
    return kron_sliced_t.sliced_multiply_t_pallas(
        dy, f, t_m=t_m, t_s=t_s, t_q=t_q, interpret=_interpret()
    )


# ---------------------------------------------------------------------------
# Fused chains (C3): Pallas kernels on TPU, M-tiled lax.scan on XLA/CPU
# ---------------------------------------------------------------------------


# CPU cache budget for the scan-fused XLA paths (the L2/L3 analogue of the
# Pallas kernels' VMEM budget): chains whose whole working set fits are run
# UNTILED — one set of full-size GEMMs beats a serializing scan when nothing
# spills (measured: the B=8, M=64, (16,16)^3 batched chain is ~1.8x faster
# untiled, while the M=256, (16,16)^4 fig_bwd chain at 64 MB still tiles).
XLA_CACHE_BUDGET_BYTES = 16 * 1024 * 1024


def _chain_max_cols(cols: int, pqs: Sequence[tuple[int, int]]) -> int:
    """Max column count over the chain states starting from ``cols``."""
    mx = cols
    for p, q in pqs:
        cols = cols // p * q
        mx = max(mx, cols)
    return mx


def _xla_tile_rows(m: int, t_m: int, row_bytes: int | None = None) -> int | None:
    """Effective M-tile for the scan-fused XLA path, or None to run untiled.

    Tiling pays off only when the full chain would spill cache
    (``row_bytes``: widest per-row working set) AND the tile chain fits with
    enough tiles to amortize the scan; tiny analytic t_m values (tuned for
    the TPU sublane) are clamped up to a useful CPU tile.
    """
    if row_bytes is not None and m * row_bytes <= XLA_CACHE_BUDGET_BYTES:
        return None
    t = min(m, max(t_m, 8))
    if t >= m or m % t or m // t < 2:
        return None
    return t


@functools.partial(jax.jit, static_argnames=("t_m",))
def _fused_xla(x: jax.Array, factors: tuple[jax.Array, ...], t_m: int) -> jax.Array:
    def chain(y):
        for f in factors:
            y = _sliced_body(y, f)
        return y

    m, k = x.shape
    row_bytes = _chain_max_cols(
        k, [(int(f.shape[0]), int(f.shape[1])) for f in factors]
    ) * x.dtype.itemsize
    t = _xla_tile_rows(m, t_m, row_bytes)
    if t is None:
        return chain(x)
    _, yt = jax.lax.scan(
        lambda _, xt: (None, chain(xt)), None, x.reshape(m // t, t, k)
    )
    return yt.reshape(m, -1)


def fused_kron(
    x: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
) -> jax.Array:
    """Chain of sliced multiplies in one kernel (C3).  factors[0] == F^N."""
    b = resolve_backend(backend)
    if b == "xla":
        return _fused_xla(x, tuple(factors_last_first), t_m)
    return kron_fused.fused_kron_pallas(
        x, *factors_last_first, t_m=t_m, t_k=t_k, t_qs=t_qs, interpret=_interpret()
    )


@functools.partial(jax.jit, static_argnames=("t_m",))
def _fused_t_xla(dy: jax.Array, factors: tuple[jax.Array, ...], t_m: int) -> jax.Array:
    def chain(g):
        for f in reversed(factors):
            g = _sliced_t_body(g, f)
        return g

    m, l = dy.shape
    row_bytes = _chain_max_cols(
        l, [(int(f.shape[1]), int(f.shape[0])) for f in reversed(factors)]
    ) * dy.dtype.itemsize
    t = _xla_tile_rows(m, t_m, row_bytes)
    if t is None:
        return chain(dy)
    _, gt = jax.lax.scan(
        lambda _, gt_: (None, chain(gt_)), None, dy.reshape(m // t, t, l)
    )
    return gt.reshape(m, -1)


def fused_kron_t(
    dy: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
) -> jax.Array:
    """Transposed fused chain: the input cotangent of ``fused_kron``.

    Takes the SAME factor list as the forward call and un-applies the chain
    (last-applied factor's transpose first).
    """
    b = resolve_backend(backend)
    if b == "xla":
        return _fused_t_xla(dy, tuple(factors_last_first), t_m)
    return kron_fused_t.fused_kron_t_pallas(
        dy, *factors_last_first, t_m=t_m, t_k=t_k, t_qs=t_qs, interpret=_interpret()
    )


def _fused_bwd_tile(us_first, g, factors, acc):
    """Backward of one chain tile: shared relayout per factor feeds both the
    factor-gradient GEMM and the chain-step GEMM."""
    t_m = g.shape[0]
    us = [us_first]
    y = us_first
    for f in factors[:-1]:
        y = _sliced_body(y, f)
        us.append(y)
    dfs = [None] * len(factors)
    cols = g.shape[1]
    for idx in reversed(range(len(factors))):
        f = factors[idx]
        p, q = int(f.shape[0]), int(f.shape[1])
        s = cols // q
        g2 = jnp.swapaxes(g.reshape(t_m, q, s), 1, 2).reshape(t_m * s, q)
        u2 = us[idx].reshape(t_m * s, p)
        dfs[idx] = jax.lax.dot_general(
            u2.astype(acc), g2.astype(acc), (((0,), (0,)), ((), ())),
            preferred_element_type=acc,
        )
        g = jax.lax.dot_general(
            g2, f, (((1,), (1,)), ((), ())), preferred_element_type=acc
        ).reshape(t_m, s * p).astype(g.dtype)
        cols = s * p
    return dfs, g


@functools.partial(jax.jit, static_argnames=("t_m",))
def _fused_bwd_xla(
    x: jax.Array, dy: jax.Array, factors: tuple[jax.Array, ...], t_m: int
):
    acc = acc_dtype_for(dy.dtype)
    m, k = x.shape
    # Backward live set per row: every forward chain state is held (the
    # rematerialized us) plus the gradient at its widest — a sum, not a max.
    live = cols = k
    for f in factors:
        cols = cols // int(f.shape[0]) * int(f.shape[1])
        live += cols
    t = _xla_tile_rows(m, t_m, live * x.dtype.itemsize)
    if t is None:
        dfs, dx = _fused_bwd_tile(x, dy, factors, acc)
        return dx, tuple(dfs)

    def body(carry, xg):
        dfs, g = _fused_bwd_tile(xg[0], xg[1], factors, acc)
        return tuple(c + d for c, d in zip(carry, dfs)), g

    carry0 = tuple(jnp.zeros(f.shape, acc) for f in factors)
    dfs, dxt = jax.lax.scan(
        body, carry0, (x.reshape(m // t, t, k), dy.reshape(m // t, t, -1))
    )
    return dxt.reshape(m, k), dfs


def fused_kron_bwd(
    x: jax.Array,
    dy: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_m: int = 8,
    t_k: int | None = None,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """Full backward of one fused stage: (dx, per-factor grads).

    x is the stage input, dy the stage output cotangent; factor grads are
    returned in ``factors_last_first`` order, accumulated in f32 (callers
    cast).  On XLA this runs as one M-tiled scan whose per-tile body
    rematerializes the forward chain in cache; on TPU it is a single Pallas
    kernel doing the same in VMEM (kron_fused_t.fused_kron_bwd_pallas).
    """
    b = resolve_backend(backend)
    if b == "xla":
        return _fused_bwd_xla(x, dy, tuple(factors_last_first), t_m)
    return kron_fused_t.fused_kron_bwd_pallas(
        x, dy, *factors_last_first, t_m=t_m, t_k=t_k, interpret=_interpret()
    )


# ---------------------------------------------------------------------------
# Batched chains: B independent problems with per-sample factors.  Pallas
# batch-grid kernels on TPU; on XLA a lax.scan over batch tiles whose body
# runs the whole per-tile chain with batch-dimension GEMMs (one dispatch for
# the entire batch — the launch-amortization the batched subsystem is for).
# ---------------------------------------------------------------------------


def _batch_tile(b: int, t_b: int, sample_bytes: int | None = None) -> int | None:
    """Effective batch tile for the scan-batched XLA path, or None untiled.

    ``sample_bytes``: one sample's chain working set — when the whole batch
    fits the cache budget, run untiled (same rule as ``_xla_tile_rows``).
    """
    if sample_bytes is not None and b * sample_bytes <= XLA_CACHE_BUDGET_BYTES:
        return None
    t = min(b, max(t_b, 1))
    if t >= b or b % t or b // t < 2:
        return None
    return t


def _sample_chain_bytes(x: jax.Array, factors, transposed: bool = False) -> int:
    m = int(x.shape[1])
    cols = int(x.shape[2])
    if transposed:
        pqs = [(int(f.shape[2]), int(f.shape[1])) for f in reversed(factors)]
    else:
        pqs = [(int(f.shape[1]), int(f.shape[2])) for f in factors]
    return m * _chain_max_cols(cols, pqs) * x.dtype.itemsize


def _sliced_body_b(x: jax.Array, f: jax.Array) -> jax.Array:
    """Batched sliced multiply: (B, M, S*P) x (B, P, Q) -> (B, M, Q*S)."""
    b, m, k = x.shape
    p, q = f.shape[1], f.shape[2]
    s = k // p
    acc = jax.lax.dot_general(
        x.reshape(b, m * s, p), f, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=acc_dtype_for(x.dtype),
    )
    return (
        jnp.swapaxes(acc.reshape(b, m, s, q), 2, 3)
        .reshape(b, m, q * s)
        .astype(x.dtype)
    )


def _sliced_t_body_b(dy: jax.Array, f: jax.Array) -> jax.Array:
    """Batched transposed sliced multiply: (B, M, Q*S) x (B, P, Q) -> (B, M, S*P)."""
    b, m, l = dy.shape
    p, q = f.shape[1], f.shape[2]
    s = l // q
    g2 = jnp.swapaxes(dy.reshape(b, m, q, s), 2, 3).reshape(b, m * s, q)
    acc = jax.lax.dot_general(
        g2, f, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=acc_dtype_for(dy.dtype),
    )
    return acc.reshape(b, m, s * p).astype(dy.dtype)


@functools.partial(jax.jit, static_argnames=("t_b",))
def _fused_batched_xla(
    x: jax.Array, factors: tuple[jax.Array, ...], t_b: int
) -> jax.Array:
    def chain(yt, fts):
        for f in fts:
            yt = _sliced_body_b(yt, f)
        return yt

    b = x.shape[0]
    t = _batch_tile(b, t_b, _sample_chain_bytes(x, factors))
    if t is None:
        return chain(x, factors)
    xs = (
        x.reshape(b // t, t, *x.shape[1:]),
        tuple(f.reshape(b // t, t, *f.shape[1:]) for f in factors),
    )
    _, yt = jax.lax.scan(lambda _, xf: (None, chain(xf[0], xf[1])), None, xs)
    return yt.reshape(b, x.shape[1], -1)


def fused_kron_batched(
    x: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
) -> jax.Array:
    """Batched fused chain: x (B, M, K), per-sample factors (B, P_i, Q_i)."""
    b = resolve_backend(backend)
    if b == "xla":
        return _fused_batched_xla(x, tuple(factors_last_first), t_b)
    return kron_fused.fused_kron_batched_pallas(
        x, *factors_last_first, t_b=t_b, t_m=t_m, t_k=t_k, t_qs=t_qs,
        interpret=_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("t_b",))
def _fused_t_batched_xla(
    dy: jax.Array, factors: tuple[jax.Array, ...], t_b: int
) -> jax.Array:
    def chain(gt, fts):
        for f in reversed(fts):
            gt = _sliced_t_body_b(gt, f)
        return gt

    b = dy.shape[0]
    t = _batch_tile(b, t_b, _sample_chain_bytes(dy, factors, transposed=True))
    if t is None:
        return chain(dy, factors)
    xs = (
        dy.reshape(b // t, t, *dy.shape[1:]),
        tuple(f.reshape(b // t, t, *f.shape[1:]) for f in factors),
    )
    _, gt = jax.lax.scan(lambda _, gf: (None, chain(gf[0], gf[1])), None, xs)
    return gt.reshape(b, dy.shape[1], -1)


def fused_kron_t_batched(
    dy: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
) -> jax.Array:
    """Batched transposed fused chain (input cotangent of fused_kron_batched)."""
    b = resolve_backend(backend)
    if b == "xla":
        return _fused_t_batched_xla(dy, tuple(factors_last_first), t_b)
    return kron_fused_t.fused_kron_t_batched_pallas(
        dy, *factors_last_first, t_b=t_b, t_m=t_m, t_k=t_k, t_qs=t_qs,
        interpret=_interpret(),
    )


def _fused_bwd_tile_b(us_first, g, factors, acc):
    """Batched backward of one chain tile (cf. _fused_bwd_tile): per-sample
    factor grads, so the batch dim rides every GEMM instead of being summed."""
    t_b, t_m = g.shape[0], g.shape[1]
    us = [us_first]
    y = us_first
    for f in factors[:-1]:
        y = _sliced_body_b(y, f)
        us.append(y)
    dfs = [None] * len(factors)
    cols = g.shape[2]
    for idx in reversed(range(len(factors))):
        f = factors[idx]
        p, q = int(f.shape[1]), int(f.shape[2])
        s = cols // q
        g2 = jnp.swapaxes(g.reshape(t_b, t_m, q, s), 2, 3).reshape(
            t_b, t_m * s, q
        )
        u2 = us[idx].reshape(t_b, t_m * s, p)
        dfs[idx] = jax.lax.dot_general(
            u2.astype(acc), g2.astype(acc), (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=acc,
        )  # (t_b, p, q)
        g = jax.lax.dot_general(
            g2, f, (((2,), (2,)), ((0,), (0,))), preferred_element_type=acc
        ).reshape(t_b, t_m, s * p).astype(g.dtype)
        cols = s * p
    return dfs, g


@functools.partial(jax.jit, static_argnames=("t_b",))
def _fused_bwd_batched_xla(
    x: jax.Array, dy: jax.Array, factors: tuple[jax.Array, ...], t_b: int
):
    acc = acc_dtype_for(dy.dtype)
    b, m, k = x.shape
    live = cols = k
    for f in factors:
        cols = cols // int(f.shape[1]) * int(f.shape[2])
        live += cols
    t = _batch_tile(b, t_b, m * live * x.dtype.itemsize)
    if t is None:
        dfs, dx = _fused_bwd_tile_b(x, dy, factors, acc)
        return dx, tuple(dfs)

    def body(_, xs):
        xt, dyt, fts = xs
        dfs, g = _fused_bwd_tile_b(xt, dyt, fts, acc)
        return None, (g, tuple(dfs))

    xs = (
        x.reshape(b // t, t, m, k),
        dy.reshape(b // t, t, m, -1),
        tuple(f.reshape(b // t, t, *f.shape[1:]) for f in factors),
    )
    _, (dxt, dfts) = jax.lax.scan(body, None, xs)
    return dxt.reshape(b, m, k), tuple(
        d.reshape(b, *d.shape[2:]) for d in dfts
    )


def fused_kron_bwd_batched(
    x: jax.Array,
    dy: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """Batched full stage backward: per-sample (dx, factor grads).

    x (B, M, K), dy (B, M, prod(Q)*S), factors (B, P_i, Q_i); dfs returned in
    ``factors_last_first`` order, each (B, P_i, Q_i), accumulated in f32.
    """
    b = resolve_backend(backend)
    if b == "xla":
        return _fused_bwd_batched_xla(x, dy, tuple(factors_last_first), t_b)
    return kron_fused_t.fused_kron_bwd_batched_pallas(
        x, dy, *factors_last_first, t_b=t_b, t_m=t_m, t_k=t_k,
        interpret=_interpret(),
    )


# Re-export the oracles so tests can import one module.
sliced_multiply_ref = _ref.sliced_multiply_ref
fused_kron_ref = _ref.fused_kron_ref
sliced_multiply_t_ref = _ref.sliced_multiply_t_ref
fused_kron_t_ref = _ref.fused_kron_t_ref

__all__ = [
    "sliced_multiply",
    "sliced_multiply_t",
    "fused_kron",
    "fused_kron_t",
    "fused_kron_bwd",
    "fused_kron_batched",
    "fused_kron_t_batched",
    "fused_kron_bwd_batched",
    "resolve_backend",
    "acc_dtype_for",
    "sliced_multiply_ref",
    "sliced_multiply_t_ref",
    "fused_kron_ref",
    "fused_kron_t_ref",
]
