"""Jit'd wrappers + backend dispatch for the Kron-Matmul kernels.

Three backends for one sliced multiply / fused chain:

  * ``xla``     — the pure-jnp einsum formulation (kernels/ref.py semantics,
                  but in the input dtype with f32 accumulation).  On CPU this
                  is the fast path; on TPU XLA fuses it reasonably but cannot
                  chain factors in VMEM.
  * ``pallas``  — the Pallas TPU kernels (kron_sliced.py / kron_fused.py).
                  ``interpret=True`` is forced automatically off-TPU so the
                  same call sites work in this CPU container (correctness
                  validation) and on real hardware (performance).
  * ``auto``    — pallas on TPU, xla elsewhere.

The wrappers are shape-polymorphic dispatchers, not jitted themselves: the
underlying implementations are jitted (or meant to be called under an outer
jit, e.g. inside train_step).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from . import kron_fused, kron_sliced, kron_sliced_t
from . import ref as _ref

Backend = str  # "auto" | "xla" | "pallas"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: Backend) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "xla"
    return backend


def _interpret() -> bool:
    return not _on_tpu()


def acc_dtype_for(dtype) -> jnp.dtype:
    """f32 accumulation for <=f32 inputs, f64 for f64 (never truncate)."""
    return jnp.promote_types(dtype, jnp.float32)


@jax.jit
def _sliced_xla(x: jax.Array, f: jax.Array) -> jax.Array:
    m, k = x.shape
    p, q = f.shape
    s = k // p
    acc = jax.lax.dot_general(
        x.reshape(m * s, p), f, (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype_for(x.dtype),
    )
    return (
        jnp.swapaxes(acc.reshape(m, s, q), 1, 2).reshape(m, q * s).astype(x.dtype)
    )


def sliced_multiply(
    x: jax.Array,
    f: jax.Array,
    *,
    backend: Backend = "auto",
    tiles: tuple[int, int, int] | None = None,
) -> jax.Array:
    """One FastKron sliced multiply: (M, K) x (P, Q) -> (M, K//P*Q)."""
    b = resolve_backend(backend)
    if b == "xla":
        return _sliced_xla(x, f)
    t_m, t_s, t_q = tiles or (8, None, None)
    return kron_sliced.sliced_multiply_pallas(
        x, f, t_m=t_m, t_s=t_s, t_q=t_q, interpret=_interpret()
    )


@jax.jit
def _sliced_t_xla(dy: jax.Array, f: jax.Array) -> jax.Array:
    m, l = dy.shape
    p, q = f.shape
    s = l // q
    acc = jax.lax.dot_general(
        jnp.swapaxes(dy.reshape(m, q, s), 1, 2).reshape(m * s, q),
        jnp.swapaxes(f, 0, 1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype_for(dy.dtype),
    )
    return acc.reshape(m, s * p).astype(dy.dtype)


def sliced_multiply_t(
    dy: jax.Array,
    f: jax.Array,
    *,
    backend: Backend = "auto",
    tiles: tuple[int, int, int] | None = None,
) -> jax.Array:
    """Transposed sliced multiply (C1 backward): (M, Q*S) x (P,Q) -> (M, S*P)."""
    b = resolve_backend(backend)
    if b == "xla":
        return _sliced_t_xla(dy, f)
    t_m, t_s, t_q = tiles or (8, None, None)
    return kron_sliced_t.sliced_multiply_t_pallas(
        dy, f, t_m=t_m, t_s=t_s, t_q=t_q, interpret=_interpret()
    )


def fused_kron(
    x: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_m: int = 8,
    t_k: int | None = None,
) -> jax.Array:
    """Chain of sliced multiplies in one kernel (C3).  factors[0] == F^N."""
    b = resolve_backend(backend)
    if b == "xla":
        y = x
        for f in factors_last_first:
            y = _sliced_xla(y, f)
        return y
    return kron_fused.fused_kron_pallas(
        x, *factors_last_first, t_m=t_m, t_k=t_k, interpret=_interpret()
    )


# Re-export the oracles so tests can import one module.
sliced_multiply_ref = _ref.sliced_multiply_ref
fused_kron_ref = _ref.fused_kron_ref
sliced_multiply_t_ref = _ref.sliced_multiply_t_ref

__all__ = [
    "sliced_multiply",
    "sliced_multiply_t",
    "fused_kron",
    "resolve_backend",
    "sliced_multiply_ref",
    "sliced_multiply_t_ref",
    "fused_kron_ref",
]
