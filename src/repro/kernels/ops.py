"""Jit'd wrappers + backend dispatch for the Kron-Matmul kernels.

Three backends for one sliced multiply / fused chain:

  * ``xla``     — the pure-jnp einsum formulation (kernels/ref.py semantics,
                  but in the input dtype with f32 accumulation).  On CPU this
                  is the fast path; fused chains additionally run as a
                  ``lax.scan`` over M-tiles so the whole per-tile chain stays
                  cache-resident — the CPU analogue of the Pallas kernel's
                  VMEM fusion (see EXPERIMENTS.md §Backward).
  * ``pallas``  — the Pallas TPU kernels.  ``interpret=True`` is forced
                  automatically off-TPU so the same call sites work in this
                  CPU container (correctness validation) and on real hardware
                  (performance).
  * ``auto``    — pallas on TPU, xla elsewhere.

Since the StageProgram refactor the fused-chain execution lives in
``kernels/emit.py`` (one kernel template + one scan executor interpreting
``StageInstr``s); the six ``fused_kron*`` wrappers here are DEPRECATED
compatibility shims that build a one-instruction program and call the
emitter.  Each warns once per process; the engine's hot paths call ``emit``
directly and never enter them.  ``sliced_multiply`` / ``sliced_multiply_t``
remain first-class: they dispatch the per-factor C1/C2 kernels
(kron_sliced.py / kron_sliced_t.py) that the unfused baseline and the
distributed per-iteration mode use.
"""
from __future__ import annotations

import warnings
from typing import Sequence

import jax

from . import emit, kron_sliced, kron_sliced_t
from . import ref as _ref
from .emit import XLA_CACHE_BUDGET_BYTES, acc_dtype_for, resolve_backend  # noqa: F401

Backend = str  # "auto" | "xla" | "pallas"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


_SHIM_WARNED: set[str] = set()


def warn_shim(name: str) -> None:
    """Emit ONE DeprecationWarning per process per legacy fused_kron* shim."""
    if name in _SHIM_WARNED:
        return
    _SHIM_WARNED.add(name)
    warnings.warn(
        f"kernels.ops.{name} is deprecated: build a StageInstr/StageProgram "
        "and call kernels.emit (run_stage / run_stage_grad); the engine's "
        "planned paths do this automatically.",
        DeprecationWarning,
        stacklevel=3,
    )


_sliced_xla = jax.jit(lambda x, f: emit.sliced_apply(x, f))
_sliced_t_xla = jax.jit(lambda dy, f: emit.sliced_apply_t(dy, f))


def sliced_multiply(
    x: jax.Array,
    f: jax.Array,
    *,
    backend: Backend = "auto",
    tiles: tuple[int, int, int] | None = None,
) -> jax.Array:
    """One FastKron sliced multiply: (M, K) x (P, Q) -> (M, K//P*Q)."""
    b = resolve_backend(backend)
    if b == "xla":
        return _sliced_xla(x, f)
    t_m, t_s, t_q = tiles or (8, None, None)
    return kron_sliced.sliced_multiply_pallas(
        x, f, t_m=t_m, t_s=t_s, t_q=t_q, interpret=_interpret()
    )


def sliced_multiply_t(
    dy: jax.Array,
    f: jax.Array,
    *,
    backend: Backend = "auto",
    tiles: tuple[int, int, int] | None = None,
) -> jax.Array:
    """Transposed sliced multiply (C1 backward): (M, Q*S) x (P,Q) -> (M, S*P)."""
    b = resolve_backend(backend)
    if b == "xla":
        return _sliced_t_xla(dy, f)
    t_m, t_s, t_q = tiles or (8, None, None)
    return kron_sliced_t.sliced_multiply_t_pallas(
        dy, f, t_m=t_m, t_s=t_s, t_q=t_q, interpret=_interpret()
    )


# ---------------------------------------------------------------------------
# DEPRECATED fused-chain shims (one StageInstr each, executed by the emitter)
# ---------------------------------------------------------------------------


def _chain_instr(factors, *, kind, t_b=None, t_m=8, t_k=None, t_qs=None):
    off = 0 if t_b is None else 1
    return emit.StageInstr(
        kind=kind,
        ps=tuple(int(f.shape[off]) for f in factors),
        qs=tuple(int(f.shape[off + 1]) for f in factors),
        t_m=t_m, t_k=t_k, t_qs=t_qs, t_b=t_b,
    )


def fused_kron(
    x: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
) -> jax.Array:
    """DEPRECATED shim: chain of sliced multiplies in one kernel (C3).

    ``factors_last_first[0] == F^N``.  Equivalent to ``emit.run_stage`` on a
    ``multiply`` instruction.
    """
    warn_shim("fused_kron")
    fs = tuple(factors_last_first)
    instr = _chain_instr(fs, kind=emit.MULTIPLY, t_m=t_m, t_k=t_k, t_qs=t_qs)
    return emit.run_stage(x, fs, instr, backend=backend)


def fused_kron_t(
    dy: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
) -> jax.Array:
    """DEPRECATED shim: transposed fused chain (input cotangent of
    ``fused_kron``); a ``transposed_multiply`` instruction on the emitter.

    Takes the SAME factor list as the forward call and un-applies the chain
    (last-applied factor's transpose first).
    """
    warn_shim("fused_kron_t")
    fs = tuple(factors_last_first)
    instr = _chain_instr(
        fs, kind=emit.TRANSPOSED_MULTIPLY, t_m=t_m, t_k=t_k, t_qs=t_qs
    )
    return emit.run_stage(dy, fs, instr, backend=backend)


def fused_kron_bwd(
    x: jax.Array,
    dy: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_m: int = 8,
    t_k: int | None = None,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """DEPRECATED shim: full backward of one fused stage (dx, factor grads)
    via ``emit.run_stage_grad``.

    x is the stage input, dy the stage output cotangent; factor grads are
    returned in ``factors_last_first`` order, accumulated in f32 (callers
    cast).
    """
    warn_shim("fused_kron_bwd")
    fs = tuple(factors_last_first)
    instr = _chain_instr(fs, kind=emit.MULTIPLY, t_m=t_m, t_k=t_k)
    return emit.run_stage_grad(x, dy, fs, instr, backend=backend)


def fused_kron_batched(
    x: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
) -> jax.Array:
    """DEPRECATED shim: batched fused chain — x (B, M, K), per-sample factors
    (B, P_i, Q_i) — via a batched ``multiply`` instruction."""
    warn_shim("fused_kron_batched")
    fs = tuple(factors_last_first)
    instr = _chain_instr(
        fs, kind=emit.MULTIPLY, t_b=t_b, t_m=t_m, t_k=t_k, t_qs=t_qs
    )
    return emit.run_stage(x, fs, instr, backend=backend)


def fused_kron_t_batched(
    dy: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
) -> jax.Array:
    """DEPRECATED shim: batched transposed fused chain (input cotangent of
    ``fused_kron_batched``)."""
    warn_shim("fused_kron_t_batched")
    fs = tuple(factors_last_first)
    instr = _chain_instr(
        fs, kind=emit.TRANSPOSED_MULTIPLY, t_b=t_b, t_m=t_m, t_k=t_k, t_qs=t_qs
    )
    return emit.run_stage(dy, fs, instr, backend=backend)


def fused_kron_bwd_batched(
    x: jax.Array,
    dy: jax.Array,
    factors_last_first: Sequence[jax.Array],
    *,
    backend: Backend = "auto",
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """DEPRECATED shim: batched full stage backward — per-sample (dx, factor
    grads each (B, P_i, Q_i)) — via ``emit.run_stage_grad``."""
    warn_shim("fused_kron_bwd_batched")
    fs = tuple(factors_last_first)
    instr = _chain_instr(fs, kind=emit.MULTIPLY, t_b=t_b, t_m=t_m, t_k=t_k)
    return emit.run_stage_grad(x, dy, fs, instr, backend=backend)


# Re-export the oracles so tests can import one module.
sliced_multiply_ref = _ref.sliced_multiply_ref
fused_kron_ref = _ref.fused_kron_ref
sliced_multiply_t_ref = _ref.sliced_multiply_t_ref
fused_kron_t_ref = _ref.fused_kron_t_ref

__all__ = [
    "sliced_multiply",
    "sliced_multiply_t",
    "fused_kron",
    "fused_kron_t",
    "fused_kron_bwd",
    "fused_kron_batched",
    "fused_kron_t_batched",
    "fused_kron_bwd_batched",
    "resolve_backend",
    "acc_dtype_for",
    "sliced_multiply_ref",
    "sliced_multiply_t_ref",
    "fused_kron_ref",
    "fused_kron_t_ref",
]
