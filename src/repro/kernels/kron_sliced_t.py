"""Pallas TPU kernel for the TRANSPOSED sliced multiply — the backward of
FastKron's C1 (beyond-paper: the paper only treats inference/forward).

The VJP of ``Y[m, q*S+s] = sum_p X[m, s*P+p] F[p, q]`` w.r.t. X is

    dX[m, s*P + p] = sum_q dY[m, q*S + s] * F[p, q]

which is itself Kron-shaped: view dY as (M, Q, S) (the same output view the
forward kernel writes) and contract the Q axis.  The BlockSpec mirror of
kron_sliced.py: dY blocks are read as (T_M, T_Q, T_S) tiles of the 3-D
view, dX written as contiguous (T_M, T_S*P) tiles — again no scatter, no
transpose pass.

Accumulation: the Q-tile grid dimension is innermost and sequential on
TPU, so the kernel revisits its output block and accumulates across
``l`` iterations (init at l == 0) — the standard Pallas reduction layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sliced_t_kernel(dy_ref, f_ref, dx_ref, *, acc_dtype):
    l = pl.program_id(2)
    t_m, t_q, t_s = dy_ref.shape
    p = f_ref.shape[0]
    dy = dy_ref[...]  # (T_M, T_Q, T_S)
    f = f_ref[...]    # (P, T_Q)
    # (T_M*T_S, T_Q) x (T_Q, P) on the MXU
    dy2 = jnp.swapaxes(dy, 1, 2).reshape(t_m * t_s, t_q)
    part = jax.lax.dot_general(
        dy2, jnp.swapaxes(f, 0, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )  # (T_M*T_S, P)
    part = part.reshape(t_m, t_s * p).astype(dx_ref.dtype)

    @pl.when(l == 0)
    def _init():
        dx_ref[...] = part

    @pl.when(l > 0)
    def _acc():
        dx_ref[...] += part


@functools.partial(
    jax.jit, static_argnames=("t_m", "t_s", "t_q", "interpret", "acc_dtype")
)
def sliced_multiply_t_pallas(
    dy: jax.Array,
    f: jax.Array,
    *,
    t_m: int = 8,
    t_s: int | None = None,
    t_q: int | None = None,
    interpret: bool = False,
    acc_dtype=None,
) -> jax.Array:
    """dX for one sliced multiply.  dy: (M, Q*S), f: (P, Q) -> (M, S*P)."""
    if acc_dtype is None:
        acc_dtype = jnp.promote_types(dy.dtype, jnp.float32)
    m, l_cols = dy.shape
    p, q = f.shape
    if l_cols % q:
        raise ValueError(f"dY cols {l_cols} not divisible by Q={q}")
    s = l_cols // q
    t_m = min(t_m, m)
    t_s = min(t_s or max(1, min(s, 512)), s)
    t_q = min(t_q or q, q)
    if m % t_m or s % t_s or q % t_q:
        raise ValueError(f"tiles must divide dims: {(m, s, q)} vs {(t_m, t_s, t_q)}")

    grid = (m // t_m, s // t_s, q // t_q)  # q innermost: accumulation dim
    out = pl.pallas_call(
        functools.partial(_sliced_t_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_m, t_q, t_s), lambda i, j, l: (i, l, j)),
            pl.BlockSpec((p, t_q), lambda i, j, l: (0, l)),
        ],
        out_specs=pl.BlockSpec((t_m, t_s * p), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s * p), dy.dtype),
        interpret=interpret,
    )(dy.reshape(m, q, s), f)
    return out


__all__ = ["sliced_multiply_t_pallas"]
