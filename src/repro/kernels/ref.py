"""Pure-jnp oracles for the Pallas kernels.

Each function mirrors the signature of its kernel wrapper in ``ops.py`` and is
the ground truth for the per-kernel allclose sweeps in tests/.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def sliced_multiply_ref(x: jax.Array, f: jax.Array) -> jax.Array:
    """Y[m, q*S+s] = sum_p X[m, s*P+p] * F[p, q]  (paper Figure 2)."""
    m, k = x.shape
    p, q = f.shape
    s = k // p
    acc = jnp.einsum(
        "msp,pq->mqs",
        x.reshape(m, s, p).astype(jnp.float32),
        f.astype(jnp.float32),
    )
    return acc.reshape(m, q * s).astype(x.dtype)


def fused_kron_ref(x: jax.Array, factors: Sequence[jax.Array]) -> jax.Array:
    """Chain of sliced multiplies, applied last factor first (Algorithm 1)."""
    y = x
    for f in reversed(list(factors)):
        y = sliced_multiply_ref(y, f)
    return y


def sliced_multiply_t_ref(dy: jax.Array, f: jax.Array) -> jax.Array:
    """dX[m, s*P+p] = sum_q dY[m, q*S+s] F[p, q]  (backward of C1)."""
    m, l = dy.shape
    p, q = f.shape
    s = l // q
    acc = jnp.einsum(
        "mqs,pq->msp",
        dy.reshape(m, q, s).astype(jnp.float32),
        f.astype(jnp.float32),
    )
    return acc.reshape(m, s * p).astype(dy.dtype)


def fused_kron_t_ref(dy: jax.Array, factors: Sequence[jax.Array]) -> jax.Array:
    """Transposed chain: un-applies ``factors`` (problem order, F^1 first) in
    reverse of the forward application order, i.e. F^1's transpose first."""
    g = dy
    for f in factors:
        g = sliced_multiply_t_ref(g, f)
    return g
