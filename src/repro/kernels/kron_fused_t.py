"""Compatibility shims: the fused TRANSPOSED / BACKWARD Pallas entry points.

The four kernel bodies that used to live here (transposed chain and full
stage backward, single and batched) are now emitted by the unified templates
in ``kernels/emit.py``: ``emit.chain_pallas`` with ``direction="bwd"`` (one
``transposed_multiply`` ``StageInstr``) and ``emit.grad_pallas`` (the factor-
gradient stage backward).  These wrappers keep the historical signatures;
new code should build a ``StageInstr``/``StageProgram`` and call the emitter.
"""
from __future__ import annotations

import jax

from . import emit
from .emit import VMEM_BUDGET_ELEMS, transposed_growth  # noqa: F401
from .kron_fused import _acc_name


def fused_kron_t_pallas(
    dy: jax.Array,
    *factors_last_first: jax.Array,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> jax.Array:
    """dX for a fused chain (shim over ``emit``): dy (M, prod(Q)*S) -> (M, K).

    ``factors_last_first`` is the SAME list the forward kernel was given
    (f[0] applied first); the emitted kernel applies their transposes in
    reverse.
    """
    instr = emit.StageInstr(
        kind=emit.TRANSPOSED_MULTIPLY,
        ps=tuple(int(f.shape[0]) for f in factors_last_first),
        qs=tuple(int(f.shape[1]) for f in factors_last_first),
        t_m=t_m, t_k=t_k, t_qs=t_qs, acc_dtype=_acc_name(acc_dtype),
    )
    return emit.run_stage(
        dy, factors_last_first, instr, backend="pallas", interpret=interpret,
        vmem_budget_elems=vmem_budget_elems,
    )


def fused_kron_t_batched_pallas(
    dy: jax.Array,
    *factors_last_first: jax.Array,
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> jax.Array:
    """Batched transposed fused chain (shim over ``emit``):
    dy (B, M, prod(Q)*S) -> dx (B, M, K), per-sample (B, P_i, Q_i) factors."""
    instr = emit.StageInstr(
        kind=emit.TRANSPOSED_MULTIPLY,
        ps=tuple(int(f.shape[1]) for f in factors_last_first),
        qs=tuple(int(f.shape[2]) for f in factors_last_first),
        t_m=t_m, t_k=t_k, t_qs=t_qs, t_b=t_b, acc_dtype=_acc_name(acc_dtype),
    )
    return emit.run_stage(
        dy, factors_last_first, instr, backend="pallas", interpret=interpret,
        vmem_budget_elems=vmem_budget_elems,
    )


def fused_kron_bwd_pallas(
    x: jax.Array,
    dy: jax.Array,
    *factors_last_first: jax.Array,
    t_m: int = 8,
    t_k: int | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """Full backward of one fused stage (shim over ``emit.grad_pallas``).

    x: (M, K) stage input; dy: (M, prod(Q)*S) stage output cotangent.
    Returns (dx, dfs) with dfs in ``factors_last_first`` order, accumulated
    in the stage's acc dtype.
    """
    instr = emit.StageInstr(
        kind=emit.MULTIPLY,
        ps=tuple(int(f.shape[0]) for f in factors_last_first),
        qs=tuple(int(f.shape[1]) for f in factors_last_first),
        t_m=t_m, t_k=t_k, acc_dtype=_acc_name(acc_dtype),
    )
    return emit.run_stage_grad(
        x, dy, factors_last_first, instr, backend="pallas",
        interpret=interpret, vmem_budget_elems=vmem_budget_elems,
    )


def fused_kron_bwd_batched_pallas(
    x: jax.Array,
    dy: jax.Array,
    *factors_last_first: jax.Array,
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """Batched full stage backward (shim over ``emit.grad_pallas``): per-sample
    (dx (B, M, K), dfs each (B, P_i, Q_i) in ``factors_last_first`` order)."""
    instr = emit.StageInstr(
        kind=emit.MULTIPLY,
        ps=tuple(int(f.shape[1]) for f in factors_last_first),
        qs=tuple(int(f.shape[2]) for f in factors_last_first),
        t_m=t_m, t_k=t_k, t_b=t_b, acc_dtype=_acc_name(acc_dtype),
    )
    return emit.run_stage_grad(
        x, dy, factors_last_first, instr, backend="pallas",
        interpret=interpret, vmem_budget_elems=vmem_budget_elems,
    )


__all__ = [
    "fused_kron_t_pallas",
    "fused_kron_bwd_pallas",
    "fused_kron_t_batched_pallas",
    "fused_kron_bwd_batched_pallas",
    "transposed_growth",
]
