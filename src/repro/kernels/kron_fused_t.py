"""Pallas TPU kernels for the fused TRANSPOSED chain — the backward of the
fused kernel (beyond-paper: the paper only treats inference/forward).

Two kernels:

``fused_kron_t_pallas``
    Chains transposed sliced multiplies in VMEM, mirroring
    ``kron_fused.fused_kron_pallas``: the forward kernel maps a contiguous
    ``(T_M, T_K)`` input tile to one ``(T_M, prod(Q), T_K/prod(P))`` block of
    the output view, and that map is a linear bijection per tile — so its
    transpose reads the same output block and inverts the chain factor by
    factor entirely in VMEM, storing the contiguous ``(T_M, T_K)`` dX tile
    once.  n-1 intermediate HBM round-trips of the per-factor transposed
    path are eliminated.  An optional composite Q-tile grid axis (innermost,
    sequential on TPU) splits the contraction over each factor's Q and
    accumulates partial dX tiles across Q-tiles — the VMEM-growth relief of
    the forward kernel, applied to the contracted side.

``fused_kron_bwd_pallas``
    The full training backward of one fused stage: per ``(T_M, T_K)`` tile it
    rematerializes the forward chain in VMEM, then walks the transposed chain
    computing both the input gradient and every factor gradient.  Per factor
    it performs ONE in-VMEM relayout of the gradient tile to ``(T_M*S, Q)``,
    shared by the factor-gradient GEMM (``U^T G``) and the chain-step GEMM
    (``G F^T``) — the relayout the unfused path pays one HBM round-trip for.
    Factor gradients accumulate across the whole grid into revisited
    ``(P_i, Q_i)`` output blocks (grid is sequential on TPU).
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .kron_fused import VMEM_BUDGET_ELEMS


def transposed_growth(
    ps: Sequence[int], qs: Sequence[int], t_qs: Sequence[int] | None = None
) -> float:
    """Max live-set multiplier of the inverse chain, relative to T_K.

    Walking the chain backwards, the per-tile column count goes
    ``prod(t_q)*ts_out -> ... -> t_k``; the max over those states bounds VMEM.
    """
    t_qs = tuple(t_qs) if t_qs is not None else tuple(qs)
    pprod = math.prod(ps)
    cols = math.prod(t_qs) / pprod  # in units of t_k
    g = max(1.0, cols)
    for p, tq in zip(reversed(ps), reversed(t_qs)):
        cols = cols / tq * p
        g = max(g, cols)
    return g


def _fused_t_kernel(dy_ref, *refs, ps: tuple[int, ...], qs: tuple[int, ...], acc_dtype):
    f_refs, (dx_ref,) = refs[:-1], refs[-1:]
    jq = pl.program_id(2)
    t_m = dy_ref.shape[0]
    g = dy_ref[...].reshape(t_m, -1).astype(acc_dtype)
    cols = g.shape[1]
    # Invert the chain: the forward applied f_refs[0] first, so its transpose
    # is applied last; the most-recently-applied factor's q is the major
    # digit of the current layout and is contracted first.
    for f_ref, p, q in reversed(list(zip(f_refs, ps, qs))):
        s = cols // q
        g2 = jnp.swapaxes(g.reshape(t_m, q, s), 1, 2).reshape(t_m * s, q)
        acc = jax.lax.dot_general(
            g2, f_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=acc_dtype,
        )  # (t_m*s, p)
        g = acc.reshape(t_m, s * p)
        cols = s * p
    # dx_ref is acc_dtype (cast to the input dtype by the wrapper) so the
    # cross-Q-tile accumulation never rounds through a low-precision type.
    part = g.astype(dx_ref.dtype)

    @pl.when(jq == 0)
    def _init():
        dx_ref[...] = part

    @pl.when(jq > 0)
    def _acc():
        dx_ref[...] += part


@functools.partial(
    jax.jit,
    static_argnames=("t_m", "t_k", "t_qs", "interpret", "acc_dtype", "vmem_budget_elems"),
)
def fused_kron_t_pallas(
    dy: jax.Array,
    *factors_last_first: jax.Array,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> jax.Array:
    """dX for a fused chain: dy (M, prod(Q)*S) -> (M, K) with K = prod(P)*S.

    ``factors_last_first`` is the SAME list the forward kernel was given
    (f[0] applied first); this kernel applies their transposes in reverse.
    """
    if acc_dtype is None:
        acc_dtype = jnp.promote_types(dy.dtype, jnp.float32)
    m, l_cols = dy.shape
    n = len(factors_last_first)
    ps = tuple(int(f.shape[0]) for f in factors_last_first)
    qs = tuple(int(f.shape[1]) for f in factors_last_first)
    pprod = math.prod(ps)
    qprod = math.prod(qs)
    if l_cols % qprod:
        raise ValueError(f"dY cols {l_cols} not divisible by prod(Q)={qprod}")
    s_out = l_cols // qprod
    k = s_out * pprod
    t_m = min(t_m, m)
    t_k = min(t_k or k, k)
    if t_qs is None:
        t_qs = qs
    t_qs = tuple(min(t, q) for t, q in zip(t_qs, qs))
    if any(q % t for q, t in zip(qs, t_qs)):
        raise ValueError(f"t_qs must divide factor Q dims: {t_qs} vs {qs}")
    if t_k % pprod:
        raise ValueError(f"T_K={t_k} must be a multiple of prod(P)={pprod}")
    growth = transposed_growth(ps, qs, t_qs)
    if t_m * t_k * growth > vmem_budget_elems:
        raise ValueError(
            f"tile {t_m}x{t_k} (growth {growth:.2f}) exceeds VMEM budget; "
            f"reduce t_k or tile Q via t_qs"
        )
    if m % t_m or k % t_k:
        raise ValueError(f"tiles must divide dims: {(m, k)} vs {(t_m, t_k)}")

    ts_out = t_k // pprod
    nq = tuple(q // t for q, t in zip(qs, t_qs))
    strides = [1] * n
    for i in range(1, n):
        strides[i] = strides[i - 1] * nq[i - 1]
    nq_tiles = math.prod(nq)

    def q_digit(jq, i):
        return (jq // strides[i]) % nq[i]

    # Q innermost: sequential accumulation dim (kron_sliced_t layout).
    grid = (m // t_m, k // t_k, nq_tiles)
    dy_view = (m,) + tuple(reversed(qs)) + (s_out,)
    dy_block = (t_m,) + tuple(reversed(t_qs)) + (ts_out,)

    def dy_index(i_m, j, jq):
        return (i_m,) + tuple(q_digit(jq, i) for i in reversed(range(n))) + (j,)

    in_specs = [pl.BlockSpec(dy_block, dy_index)]
    for i, f in enumerate(factors_last_first):
        in_specs.append(
            pl.BlockSpec((ps[i], t_qs[i]), lambda i_m, j, jq, i=i: (0, q_digit(jq, i)))
        )
    out = pl.pallas_call(
        functools.partial(_fused_t_kernel, ps=ps, qs=t_qs, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((t_m, t_k), lambda i_m, j, jq: (i_m, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), acc_dtype),
        interpret=interpret,
    )(dy.reshape(dy_view), *factors_last_first)
    return out.astype(dy.dtype)


def _fused_bwd_kernel(
    x_ref, dy_ref, *refs, ps: tuple[int, ...], qs: tuple[int, ...], acc_dtype
):
    f_refs = refs[: len(ps)]
    dx_ref = refs[len(ps)]
    df_refs = refs[len(ps) + 1 :]
    i_m, j = pl.program_id(0), pl.program_id(1)
    first = jnp.logical_and(i_m == 0, j == 0)
    t_m = x_ref.shape[0]
    # In-VMEM rematerialization of the forward chain (stage-local residuals).
    us = []
    y = x_ref[...].astype(acc_dtype)
    cols = y.shape[1]
    for f_ref, p, q in zip(f_refs, ps, qs):
        us.append(y)
        s = cols // p
        acc = jax.lax.dot_general(
            y.reshape(t_m * s, p), f_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype,
        )
        y = jnp.swapaxes(acc.reshape(t_m, s, q), 1, 2).reshape(t_m, q * s)
        cols = q * s
    # Transposed chain with one shared relayout per factor.
    g = dy_ref[...].reshape(t_m, -1).astype(acc_dtype)
    cols = g.shape[1]
    for idx in reversed(range(len(f_refs))):
        p, q = ps[idx], qs[idx]
        s = cols // q
        g2 = jnp.swapaxes(g.reshape(t_m, q, s), 1, 2).reshape(t_m * s, q)
        u2 = us[idx].reshape(t_m * s, p)
        df_part = jax.lax.dot_general(
            u2, g2, (((0,), (0,)), ((), ())), preferred_element_type=acc_dtype
        )  # (p, q)

        @pl.when(first)
        def _init(df_ref=df_refs[idx], df_part=df_part):
            df_ref[...] = df_part

        @pl.when(jnp.logical_not(first))
        def _acc(df_ref=df_refs[idx], df_part=df_part):
            df_ref[...] += df_part

        g = jax.lax.dot_general(
            g2, f_refs[idx][...], (((1,), (1,)), ((), ())),
            preferred_element_type=acc_dtype,
        ).reshape(t_m, s * p)
        cols = s * p
    dx_ref[...] = g.astype(dx_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("t_m", "t_k", "interpret", "acc_dtype", "vmem_budget_elems"),
)
def fused_kron_bwd_pallas(
    x: jax.Array,
    dy: jax.Array,
    *factors_last_first: jax.Array,
    t_m: int = 8,
    t_k: int | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """Full backward of one fused stage.

    x: (M, K) stage input; dy: (M, prod(Q)*S) stage output cotangent.
    Returns (dx, dfs) with dfs in ``factors_last_first`` order, accumulated
    in ``acc_dtype``.
    """
    if acc_dtype is None:
        acc_dtype = jnp.promote_types(dy.dtype, jnp.float32)
    m, k = x.shape
    ps = tuple(int(f.shape[0]) for f in factors_last_first)
    qs = tuple(int(f.shape[1]) for f in factors_last_first)
    pprod = math.prod(ps)
    qprod = math.prod(qs)
    if k % pprod:
        raise ValueError(f"K={k} not divisible by prod(P)={pprod}")
    s_out = k // pprod
    if dy.shape != (m, qprod * s_out):
        raise ValueError(f"dy shape {dy.shape} != {(m, qprod * s_out)}")
    t_m = min(t_m, m)
    t_k = min(t_k or k, k)
    if t_k % pprod:
        raise ValueError(f"T_K={t_k} must be a multiple of prod(P)={pprod}")
    # Live set: all forward intermediates of the tile chain plus the gradient
    # tile — a sum over chain states, not just the max.
    cols = float(t_k)
    live = cols
    for p, q in zip(ps, qs):
        cols = cols / p * q
        live += cols
    if t_m * (live + cols) > vmem_budget_elems:
        raise ValueError(
            f"bwd tile {t_m}x{t_k} live set {int(t_m * (live + cols))} elems "
            f"exceeds VMEM budget; reduce t_k or split the stage"
        )
    if m % t_m or k % t_k:
        raise ValueError(f"tiles must divide dims: {(m, k)} vs {(t_m, t_k)}")

    ts_out = t_k // pprod
    grid = (m // t_m, k // t_k)
    in_specs = [
        pl.BlockSpec((t_m, t_k), lambda i, j: (i, j)),
        pl.BlockSpec((t_m, qprod, ts_out), lambda i, j: (i, 0, j)),
    ]
    for p, q in zip(ps, qs):
        in_specs.append(pl.BlockSpec((p, q), lambda i, j: (0, 0)))
    out_specs = [pl.BlockSpec((t_m, t_k), lambda i, j: (i, j))]
    out_shapes = [jax.ShapeDtypeStruct((m, k), x.dtype)]
    for p, q in zip(ps, qs):
        out_specs.append(pl.BlockSpec((p, q), lambda i, j: (0, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((p, q), acc_dtype))
    outs = pl.pallas_call(
        functools.partial(_fused_bwd_kernel, ps=ps, qs=qs, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(x, dy.reshape(m, qprod, s_out), *factors_last_first)
    return outs[0], tuple(outs[1:])


# ---------------------------------------------------------------------------
# Batched variants: B independent problems, per-sample factors (batch grid axis)
# ---------------------------------------------------------------------------


def _fused_t_batched_kernel(
    dy_ref, *refs, ps: tuple[int, ...], qs: tuple[int, ...], acc_dtype
):
    f_refs, (dx_ref,) = refs[:-1], refs[-1:]
    jq = pl.program_id(3)
    t_b, t_m = dy_ref.shape[0], dy_ref.shape[1]
    g = dy_ref[...].reshape(t_b, t_m, -1).astype(acc_dtype)
    cols = g.shape[2]
    for f_ref, p, q in reversed(list(zip(f_refs, ps, qs))):
        s = cols // q
        g2 = jnp.swapaxes(g.reshape(t_b, t_m, q, s), 2, 3).reshape(
            t_b, t_m * s, q
        )
        acc = jax.lax.dot_general(
            g2, f_ref[...], (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=acc_dtype,
        )  # (t_b, t_m*s, p)
        g = acc.reshape(t_b, t_m, s * p)
        cols = s * p
    part = g.astype(dx_ref.dtype)

    @pl.when(jq == 0)
    def _init():
        dx_ref[...] = part

    @pl.when(jq > 0)
    def _acc():
        dx_ref[...] += part


@functools.partial(
    jax.jit,
    static_argnames=(
        "t_b", "t_m", "t_k", "t_qs", "interpret", "acc_dtype", "vmem_budget_elems",
    ),
)
def fused_kron_t_batched_pallas(
    dy: jax.Array,
    *factors_last_first: jax.Array,
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> jax.Array:
    """Batched transposed fused chain: dy (B, M, prod(Q)*S) -> dx (B, M, K).

    Per-sample factors ``(B, P_i, Q_i)``; the grid gains a leading batch axis
    tiled by ``t_b`` (Q-tiles stay innermost: the sequential accumulation dim).
    """
    if acc_dtype is None:
        acc_dtype = jnp.promote_types(dy.dtype, jnp.float32)
    b, m, l_cols = dy.shape
    n = len(factors_last_first)
    ps = tuple(int(f.shape[1]) for f in factors_last_first)
    qs = tuple(int(f.shape[2]) for f in factors_last_first)
    for f in factors_last_first:
        if int(f.shape[0]) != b:
            raise ValueError(f"factor batch {f.shape[0]} != dy batch {b}")
    pprod = math.prod(ps)
    qprod = math.prod(qs)
    if l_cols % qprod:
        raise ValueError(f"dY cols {l_cols} not divisible by prod(Q)={qprod}")
    s_out = l_cols // qprod
    k = s_out * pprod
    t_b = min(t_b, b)
    t_m = min(t_m, m)
    t_k = min(t_k or k, k)
    if t_qs is None:
        t_qs = qs
    t_qs = tuple(min(t, q) for t, q in zip(t_qs, qs))
    if any(q % t for q, t in zip(qs, t_qs)):
        raise ValueError(f"t_qs must divide factor Q dims: {t_qs} vs {qs}")
    if t_k % pprod:
        raise ValueError(f"T_K={t_k} must be a multiple of prod(P)={pprod}")
    growth = transposed_growth(ps, qs, t_qs)
    if t_b * t_m * t_k * growth > vmem_budget_elems:
        raise ValueError(
            f"batched tile {t_b}x{t_m}x{t_k} (growth {growth:.2f}) exceeds "
            f"VMEM budget; reduce t_b / t_k or tile Q via t_qs"
        )
    if b % t_b or m % t_m or k % t_k:
        raise ValueError(
            f"tiles must divide dims: {(b, m, k)} vs {(t_b, t_m, t_k)}"
        )

    ts_out = t_k // pprod
    nq = tuple(q // t for q, t in zip(qs, t_qs))
    strides = [1] * n
    for i in range(1, n):
        strides[i] = strides[i - 1] * nq[i - 1]
    nq_tiles = math.prod(nq)

    def q_digit(jq, i):
        return (jq // strides[i]) % nq[i]

    grid = (b // t_b, m // t_m, k // t_k, nq_tiles)
    dy_view = (b, m) + tuple(reversed(qs)) + (s_out,)
    dy_block = (t_b, t_m) + tuple(reversed(t_qs)) + (ts_out,)

    def dy_index(ib, im, j, jq):
        return (ib, im) + tuple(q_digit(jq, i) for i in reversed(range(n))) + (j,)

    in_specs = [pl.BlockSpec(dy_block, dy_index)]
    for i, f in enumerate(factors_last_first):
        in_specs.append(
            pl.BlockSpec(
                (t_b, ps[i], t_qs[i]),
                lambda ib, im, j, jq, i=i: (ib, 0, q_digit(jq, i)),
            )
        )
    out = pl.pallas_call(
        functools.partial(
            _fused_t_batched_kernel, ps=ps, qs=t_qs, acc_dtype=acc_dtype
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((t_b, t_m, t_k), lambda ib, im, j, jq: (ib, im, j)),
        out_shape=jax.ShapeDtypeStruct((b, m, k), acc_dtype),
        interpret=interpret,
    )(dy.reshape(dy_view), *factors_last_first)
    return out.astype(dy.dtype)


def _fused_bwd_batched_kernel(
    x_ref, dy_ref, *refs, ps: tuple[int, ...], qs: tuple[int, ...], acc_dtype
):
    f_refs = refs[: len(ps)]
    dx_ref = refs[len(ps)]
    df_refs = refs[len(ps) + 1 :]
    im, j = pl.program_id(1), pl.program_id(2)
    # Factor grads are PER SAMPLE: accumulate over the (M, K) grid for a fixed
    # batch block only (batch is the outermost grid axis, so (im, j) == (0, 0)
    # marks the first visit of each df block).
    first = jnp.logical_and(im == 0, j == 0)
    t_b, t_m = x_ref.shape[0], x_ref.shape[1]
    us = []
    y = x_ref[...].astype(acc_dtype)
    cols = y.shape[2]
    for f_ref, p, q in zip(f_refs, ps, qs):
        us.append(y)
        s = cols // p
        acc = jax.lax.dot_general(
            y.reshape(t_b, t_m * s, p), f_ref[...], (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=acc_dtype,
        )
        y = jnp.swapaxes(acc.reshape(t_b, t_m, s, q), 2, 3).reshape(
            t_b, t_m, q * s
        )
        cols = q * s
    g = dy_ref[...].reshape(t_b, t_m, -1).astype(acc_dtype)
    cols = g.shape[2]
    for idx in reversed(range(len(f_refs))):
        p, q = ps[idx], qs[idx]
        s = cols // q
        g2 = jnp.swapaxes(g.reshape(t_b, t_m, q, s), 2, 3).reshape(
            t_b, t_m * s, q
        )
        u2 = us[idx].reshape(t_b, t_m * s, p)
        df_part = jax.lax.dot_general(
            u2, g2, (((1,), (1,)), ((0,), (0,))), preferred_element_type=acc_dtype
        )  # (t_b, p, q)

        @pl.when(first)
        def _init(df_ref=df_refs[idx], df_part=df_part):
            df_ref[...] = df_part

        @pl.when(jnp.logical_not(first))
        def _acc(df_ref=df_refs[idx], df_part=df_part):
            df_ref[...] += df_part

        g = jax.lax.dot_general(
            g2, f_refs[idx][...], (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=acc_dtype,
        ).reshape(t_b, t_m, s * p)
        cols = s * p
    dx_ref[...] = g.astype(dx_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("t_b", "t_m", "t_k", "interpret", "acc_dtype", "vmem_budget_elems"),
)
def fused_kron_bwd_batched_pallas(
    x: jax.Array,
    dy: jax.Array,
    *factors_last_first: jax.Array,
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """Batched full stage backward: per-sample (dx, factor grads).

    x: (B, M, K); dy: (B, M, prod(Q)*S); factors (B, P_i, Q_i).  Returns
    (dx (B, M, K), dfs each (B, P_i, Q_i) in ``factors_last_first`` order,
    accumulated in ``acc_dtype``).
    """
    if acc_dtype is None:
        acc_dtype = jnp.promote_types(dy.dtype, jnp.float32)
    b, m, k = x.shape
    ps = tuple(int(f.shape[1]) for f in factors_last_first)
    qs = tuple(int(f.shape[2]) for f in factors_last_first)
    for f in factors_last_first:
        if int(f.shape[0]) != b:
            raise ValueError(f"factor batch {f.shape[0]} != x batch {b}")
    pprod = math.prod(ps)
    qprod = math.prod(qs)
    if k % pprod:
        raise ValueError(f"K={k} not divisible by prod(P)={pprod}")
    s_out = k // pprod
    if dy.shape != (b, m, qprod * s_out):
        raise ValueError(f"dy shape {dy.shape} != {(b, m, qprod * s_out)}")
    t_b = min(t_b, b)
    t_m = min(t_m, m)
    t_k = min(t_k or k, k)
    if t_k % pprod:
        raise ValueError(f"T_K={t_k} must be a multiple of prod(P)={pprod}")
    cols = float(t_k)
    live = cols
    for p, q in zip(ps, qs):
        cols = cols / p * q
        live += cols
    if t_b * t_m * (live + cols) > vmem_budget_elems:
        raise ValueError(
            f"batched bwd tile {t_b}x{t_m}x{t_k} live set "
            f"{int(t_b * t_m * (live + cols))} elems exceeds VMEM budget; "
            f"reduce t_b / t_k or split the stage"
        )
    if b % t_b or m % t_m or k % t_k:
        raise ValueError(
            f"tiles must divide dims: {(b, m, k)} vs {(t_b, t_m, t_k)}"
        )

    ts_out = t_k // pprod
    grid = (b // t_b, m // t_m, k // t_k)
    in_specs = [
        pl.BlockSpec((t_b, t_m, t_k), lambda ib, im, j: (ib, im, j)),
        pl.BlockSpec((t_b, t_m, qprod, ts_out), lambda ib, im, j: (ib, im, 0, j)),
    ]
    for p, q in zip(ps, qs):
        in_specs.append(pl.BlockSpec((t_b, p, q), lambda ib, im, j: (ib, 0, 0)))
    out_specs = [pl.BlockSpec((t_b, t_m, t_k), lambda ib, im, j: (ib, im, j))]
    out_shapes = [jax.ShapeDtypeStruct((b, m, k), x.dtype)]
    for p, q in zip(ps, qs):
        out_specs.append(pl.BlockSpec((t_b, p, q), lambda ib, im, j: (ib, 0, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((b, p, q), acc_dtype))
    outs = pl.pallas_call(
        functools.partial(
            _fused_bwd_batched_kernel, ps=ps, qs=qs, acc_dtype=acc_dtype
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(x, dy.reshape(b, m, qprod, s_out), *factors_last_first)
    return outs[0], tuple(outs[1:])


__all__ = [
    "fused_kron_t_pallas",
    "fused_kron_bwd_pallas",
    "fused_kron_t_batched_pallas",
    "fused_kron_bwd_batched_pallas",
    "transposed_growth",
]
