"""Pallas TPU kernel for one FastKron sliced multiply (contributions C1+C2).

Semantics: for ``X: (M, K)`` and ``F: (P, Q)`` with ``S = K // P`` compute

    Y[m, q*S + s] = sum_p X[m, s*P + p] * F[p, q]

The TPU-native realization of the paper's "write at the final index" insight:
declare the output as the 3-D view ``(M, Q, S)`` — row-major it flattens to
exactly ``(M, Q*S)`` with the FastKron layout — and tile it with a regular
``BlockSpec`` of shape ``(T_M, T_Q, T_S)``.  The strided scatter the CUDA
kernel performs by hand becomes a *contiguous* block store; the layout fix
happens in registers between the MXU and the store, never as a second pass
over HBM.

Tiling (mirrors the paper's {T_M, T_K, T_Q} thread-block tile):
  grid = (M/T_M, S/T_S, Q/T_Q)
  X block   (T_M, T_S*P)  — 2-D so the minor-most dim stays long/lane-aligned
  F block   (P, T_Q)
  Y block   (T_M, T_Q, T_S) of the (M, Q, S) view

The per-thread register tile (R_K, R_Q, R_P) of the CUDA kernel has no direct
analogue: VREG scheduling belongs to Mosaic.  Our levers are T_M/T_S/T_Q,
searched by core/autotune.py.  Shift caching (C2's bank-conflict fix) is
replaced by layout choice — see DESIGN.md §2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sliced_kernel(x_ref, f_ref, y_ref, *, p: int, acc_dtype):
    """One (T_M, T_S*P) x (P, T_Q) -> (T_M, T_Q, T_S) sliced multiply."""
    t_m, t_k = x_ref.shape
    t_s = t_k // p
    x = x_ref[...].reshape(t_m * t_s, p)
    f = f_ref[...]
    # MXU contraction over P; accumulate in f32.
    acc = jax.lax.dot_general(
        x,
        f,
        (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )  # (T_M*T_S, T_Q)
    t_q = f.shape[1]
    acc = acc.reshape(t_m, t_s, t_q)
    # In-VMEM relayout to the FastKron output order (m, q, s): this is the
    # transpose the shuffle algorithm pays an HBM round-trip for.
    y_ref[...] = jnp.swapaxes(acc, 1, 2).astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("t_m", "t_s", "t_q", "interpret", "acc_dtype")
)
def sliced_multiply_pallas(
    x: jax.Array,
    f: jax.Array,
    *,
    t_m: int = 8,
    t_s: int | None = None,
    t_q: int | None = None,
    interpret: bool = False,
    acc_dtype=None,
) -> jax.Array:
    """Single sliced multiply via pallas_call.  Returns (M, Q*S)."""
    if acc_dtype is None:
        acc_dtype = jnp.promote_types(x.dtype, jnp.float32)
    m, k = x.shape
    p, q = f.shape
    if k % p:
        raise ValueError(f"K={k} not divisible by P={p}")
    s = k // p
    t_m = min(t_m, m)
    t_s = min(t_s or max(1, min(s, 512)), s)
    t_q = min(t_q or q, q)
    if m % t_m or s % t_s or q % t_q:
        raise ValueError(f"tiles must divide dims: {(m, s, q)} vs {(t_m, t_s, t_q)}")

    grid = (m // t_m, s // t_s, q // t_q)
    out = pl.pallas_call(
        functools.partial(_sliced_kernel, p=p, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_m, t_s * p), lambda i, j, l: (i, j)),
            pl.BlockSpec((p, t_q), lambda i, j, l: (0, l)),
        ],
        out_specs=pl.BlockSpec((t_m, t_q, t_s), lambda i, j, l: (i, l, j)),
        out_shape=jax.ShapeDtypeStruct((m, q, s), x.dtype),
        interpret=interpret,
    )(x, f)
    return out.reshape(m, q * s)
