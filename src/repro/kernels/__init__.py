"""Pallas TPU kernels for the Kron-Matmul hot spots the paper optimizes.

kron_sliced.py — one sliced multiply (contributions C1+C2), BlockSpec-tiled.
kron_fused.py  — VMEM-resident chain of sliced multiplies (contribution C3).
ops.py         — jit'd wrappers + backend dispatch (pallas on TPU, xla else).
ref.py         — pure-jnp oracles for the allclose sweeps in tests/.
"""
