"""Pallas TPU kernels for the Kron-Matmul hot spots the paper optimizes.

emit.py         — StageProgram IR + THE kernel emitter: one parameterized
                  Pallas chain template (+ stage-backward template) and one
                  XLA lax.scan executor behind every fused path.
kron_sliced.py  — one sliced multiply (contributions C1+C2), BlockSpec-tiled.
kron_sliced_t.py— its transpose (the per-factor backward kernel).
kron_fused.py   — DEPRECATED shims: the legacy fused forward entry points.
kron_fused_t.py — DEPRECATED shims: legacy transposed/backward entry points.
ops.py          — sliced-multiply backend dispatch + the six deprecated
                  fused_kron* one-instruction shims over emit.
ref.py          — pure-jnp oracles for the allclose sweeps in tests/.
"""
