"""Pallas TPU kernel fusing consecutive sliced multiplies (contribution C3).

The paper's fused kernel keeps intermediates in shared memory for up to
``N_fused = floor(log_P T_K)`` factors.  The TPU analogue holds the whole
``(T_M, T_K)`` tile chain in VMEM: one ``pallas_call`` multiplies the tile
through ``n`` factors and stores the final block once, eliminating the
``n-1`` intermediate HBM round-trips of the per-factor path.

Correctness of per-tile fusion (why a tile can be pushed through several
factors independently): after ``j`` multiplies the global intermediate column
index is ``(q_vec, s)`` with ``s`` strictly inherited from the source tile's
column range; slices of factor ``j+1`` group ``P`` *adjacent* ``s`` values of
one ``q_vec``, so as long as ``prod(P_i) | T_K`` no slice ever crosses a tile
boundary.  The final store target is the contiguous block
``(T_M, prod(Q_i), T_K/prod(P_i))`` of the ``(M, prod(Q), K/prod(P))`` output
view — the paper's STOREFUSEDSHMEM index arithmetic, expressed as a BlockSpec.

Q-tiling (lifts the VMEM-growth restriction): later factors never contract
the ``q`` indices produced by earlier ones — they only slice along ``s`` — so
each factor's output columns are pure batch indices.  Restricting factor
``i`` to a ``T_Qi``-column slice therefore computes exactly the output block
whose ``q_i`` digit lies in that slice, independently of all other Q-tiles.
The grid gains a composite Q axis (``grid = (M/T_M, Q-tiles, K/T_K)``) whose
index decomposes into one digit per factor, the output becomes the
``(M, Q_n, ..., Q_1, K/prod(P))`` view tiled per digit, and the in-VMEM
growth bound uses ``prod(T_Qi)`` instead of ``prod(Q_i)`` — fusion stays
legal when ``prod(Q)/prod(P)`` is large.

VMEM budget: the live set is two tiles of ``T_M * T_K * max(1, growth_j)``
elements (f32 accumulation) where ``growth_j = prod(T_Qi)/prod(P_i)`` over
chain prefixes, so the wrapper checks
``T_M * T_K * growth <= vmem_budget_elems``.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Conservative usable-VMEM budget (f32 elements): ~16 MiB VMEM, keep half for
# double buffering / Mosaic temporaries.
VMEM_BUDGET_ELEMS = 2 * 1024 * 1024


def _fused_kernel(x_ref, *refs, ps: tuple[int, ...], qs: tuple[int, ...], acc_dtype):
    f_refs, (y_ref,) = refs[:-1], refs[-1:]
    t_m = x_ref.shape[0]
    y = x_ref[...]
    cols = x_ref.shape[1]
    # Chain the factors, last factor first (Algorithm 1 order: callers pass
    # factors already reversed so f_refs[0] is F^N).  ``qs`` are the per-tile
    # Q sizes (== full Q when the Q axis is not tiled).
    for f_ref, p, q in zip(f_refs, ps, qs):
        s = cols // p
        x2 = y.reshape(t_m * s, p)
        acc = jax.lax.dot_general(
            x2, f_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype,
        )  # (t_m*s, q)
        # FastKron layout (m, q, s) — stays in VMEM between factors.
        y = jnp.swapaxes(acc.reshape(t_m, s, q), 1, 2).reshape(t_m, q * s)
        cols = q * s
    y_ref[...] = y.reshape(y_ref.shape).astype(y_ref.dtype)


def fused_growth(
    ps: Sequence[int], qs: Sequence[int], t_qs: Sequence[int] | None = None
) -> float:
    """Max live-set multiplier over chain prefixes, with optional Q-tiling."""
    t_qs = tuple(t_qs) if t_qs is not None else tuple(qs)
    g = 1.0
    pprod = qprod = 1
    for p, tq in zip(ps, t_qs):
        pprod *= p
        qprod *= tq
        g = max(g, qprod / pprod)
    return g


@functools.partial(
    jax.jit,
    static_argnames=("t_m", "t_k", "t_qs", "interpret", "acc_dtype", "vmem_budget_elems"),
)
def fused_kron_pallas(
    x: jax.Array,
    *factors_last_first: jax.Array,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> jax.Array:
    """Apply ``n`` sliced multiplies in one kernel.

    ``factors_last_first[0]`` is applied first (i.e. it is F^N).  Returns the
    (M, K * prod(Q)/prod(P)) intermediate after all given factors.
    ``t_qs`` (one entry per factor, each dividing Q_i) tiles the composite
    output-Q axis so the in-VMEM growth uses prod(t_qs) instead of prod(Q).
    """
    if acc_dtype is None:
        acc_dtype = jnp.promote_types(x.dtype, jnp.float32)
    m, k = x.shape
    n = len(factors_last_first)
    ps = tuple(int(f.shape[0]) for f in factors_last_first)
    qs = tuple(int(f.shape[1]) for f in factors_last_first)
    pprod = math.prod(ps)
    qprod = math.prod(qs)
    if k % pprod:
        raise ValueError(f"K={k} not divisible by prod(P)={pprod}")
    t_m = min(t_m, m)
    t_k = min(t_k or k, k)
    if t_qs is None:
        t_qs = qs
    t_qs = tuple(min(t, q) for t, q in zip(t_qs, qs))
    if len(t_qs) != n:
        raise ValueError(f"t_qs needs one entry per factor: {t_qs} vs {n}")
    if any(q % t for q, t in zip(qs, t_qs)):
        raise ValueError(f"t_qs must divide factor Q dims: {t_qs} vs {qs}")
    # Fusion validity: every slice of every fused stage stays inside the tile.
    if t_k % pprod:
        raise ValueError(f"T_K={t_k} must be a multiple of prod(P)={pprod}")
    growth = fused_growth(ps, qs, t_qs)
    if t_m * t_k * growth > vmem_budget_elems:
        raise ValueError(
            f"tile {t_m}x{t_k} (growth {growth:.2f}) exceeds VMEM budget; "
            f"reduce t_k / n_fused or tile Q via t_qs"
        )
    if m % t_m or k % t_k:
        raise ValueError(f"tiles must divide dims: {(m, k)} vs {(t_m, t_k)}")

    s_out = k // pprod          # global output minor dim
    ts_out = t_k // pprod       # per-tile share of it
    # Composite Q-tile grid axis: one mixed-radix digit per factor, factor 0
    # (applied first) minor — matching the output layout (q_n, ..., q_1, s).
    nq = tuple(q // t for q, t in zip(qs, t_qs))
    strides = [1] * n
    for i in range(1, n):
        strides[i] = strides[i - 1] * nq[i - 1]
    nq_tiles = math.prod(nq)

    def q_digit(jq, i):
        return (jq // strides[i]) % nq[i]

    grid = (m // t_m, nq_tiles, k // t_k)
    in_specs = [pl.BlockSpec((t_m, t_k), lambda i, jq, j: (i, j))]
    for i, f in enumerate(factors_last_first):
        p = ps[i]
        in_specs.append(
            pl.BlockSpec((p, t_qs[i]), lambda i_m, jq, j, i=i: (0, q_digit(jq, i)))
        )
    # Output view (M, Q_{n-1}, ..., Q_0, S): row-major it flattens to the
    # FastKron layout (M, prod(Q)*S); each Q axis is tiled by its own digit.
    out_view = (m,) + tuple(reversed(qs)) + (s_out,)
    out_block = (t_m,) + tuple(reversed(t_qs)) + (ts_out,)

    def out_index(i_m, jq, j):
        return (i_m,) + tuple(q_digit(jq, i) for i in reversed(range(n))) + (j,)

    out = pl.pallas_call(
        functools.partial(_fused_kernel, ps=ps, qs=t_qs, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(out_block, out_index),
        out_shape=jax.ShapeDtypeStruct(out_view, x.dtype),
        interpret=interpret,
    )(x, *factors_last_first)
    return out.reshape(m, qprod * s_out)


def max_n_fused(t_k: int, p: int) -> int:
    """Paper: N_fused = floor(log_P T_K)."""
    n = 0
    while t_k >= p and t_k % p == 0:
        t_k //= p
        n += 1
    return n


# ---------------------------------------------------------------------------
# Batched fused kernel: B independent problems, per-sample factors
# ---------------------------------------------------------------------------


def _fused_batched_kernel(
    x_ref, *refs, ps: tuple[int, ...], qs: tuple[int, ...], acc_dtype
):
    f_refs, (y_ref,) = refs[:-1], refs[-1:]
    t_b, t_m = x_ref.shape[0], x_ref.shape[1]
    y = x_ref[...]
    cols = x_ref.shape[2]
    # Same chain as _fused_kernel, with a leading batch dim carried through
    # every GEMM as a dot_general batch dimension: sample b's tile only ever
    # contracts against sample b's factor slice.
    for f_ref, p, q in zip(f_refs, ps, qs):
        s = cols // p
        x2 = y.reshape(t_b, t_m * s, p)
        acc = jax.lax.dot_general(
            x2, f_ref[...], (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=acc_dtype,
        )  # (t_b, t_m*s, q)
        y = jnp.swapaxes(acc.reshape(t_b, t_m, s, q), 2, 3).reshape(
            t_b, t_m, q * s
        )
        cols = q * s
    y_ref[...] = y.reshape(y_ref.shape).astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "t_b", "t_m", "t_k", "t_qs", "interpret", "acc_dtype", "vmem_budget_elems",
    ),
)
def fused_kron_batched_pallas(
    x: jax.Array,
    *factors_last_first: jax.Array,
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> jax.Array:
    """Batch-grid fused chain: B independent Kron-Matmuls in one launch.

    ``x: (B, M, K)``; each factor ``(B, P_i, Q_i)`` (per-sample factors, the
    Jhurani arXiv 1304.7054 regime).  The grid gains a leading batch axis
    tiled by ``t_b`` samples per block; VMEM now holds ``t_b`` tile chains,
    so the legality check is ``t_b * t_m * t_k * growth <= budget`` — the
    planner trades ``t_m`` against ``t_b`` under the same budget.
    """
    if acc_dtype is None:
        acc_dtype = jnp.promote_types(x.dtype, jnp.float32)
    b, m, k = x.shape
    n = len(factors_last_first)
    ps = tuple(int(f.shape[1]) for f in factors_last_first)
    qs = tuple(int(f.shape[2]) for f in factors_last_first)
    for f in factors_last_first:
        if int(f.shape[0]) != b:
            raise ValueError(f"factor batch {f.shape[0]} != x batch {b}")
    pprod = math.prod(ps)
    qprod = math.prod(qs)
    if k % pprod:
        raise ValueError(f"K={k} not divisible by prod(P)={pprod}")
    t_b = min(t_b, b)
    t_m = min(t_m, m)
    t_k = min(t_k or k, k)
    if t_qs is None:
        t_qs = qs
    t_qs = tuple(min(t, q) for t, q in zip(t_qs, qs))
    if any(q % t for q, t in zip(qs, t_qs)):
        raise ValueError(f"t_qs must divide factor Q dims: {t_qs} vs {qs}")
    if t_k % pprod:
        raise ValueError(f"T_K={t_k} must be a multiple of prod(P)={pprod}")
    growth = fused_growth(ps, qs, t_qs)
    if t_b * t_m * t_k * growth > vmem_budget_elems:
        raise ValueError(
            f"batched tile {t_b}x{t_m}x{t_k} (growth {growth:.2f}) exceeds "
            f"VMEM budget; reduce t_b / t_m / t_k or tile Q via t_qs"
        )
    if b % t_b or m % t_m or k % t_k:
        raise ValueError(
            f"tiles must divide dims: {(b, m, k)} vs {(t_b, t_m, t_k)}"
        )

    s_out = k // pprod
    ts_out = t_k // pprod
    nq = tuple(q // t for q, t in zip(qs, t_qs))
    strides = [1] * n
    for i in range(1, n):
        strides[i] = strides[i - 1] * nq[i - 1]
    nq_tiles = math.prod(nq)

    def q_digit(jq, i):
        return (jq // strides[i]) % nq[i]

    grid = (b // t_b, m // t_m, nq_tiles, k // t_k)
    in_specs = [
        pl.BlockSpec((t_b, t_m, t_k), lambda ib, im, jq, j: (ib, im, j))
    ]
    for i, f in enumerate(factors_last_first):
        in_specs.append(
            pl.BlockSpec(
                (t_b, ps[i], t_qs[i]),
                lambda ib, im, jq, j, i=i: (ib, 0, q_digit(jq, i)),
            )
        )
    out_view = (b, m) + tuple(reversed(qs)) + (s_out,)
    out_block = (t_b, t_m) + tuple(reversed(t_qs)) + (ts_out,)

    def out_index(ib, im, jq, j):
        return (ib, im) + tuple(q_digit(jq, i) for i in reversed(range(n))) + (j,)

    out = pl.pallas_call(
        functools.partial(_fused_batched_kernel, ps=ps, qs=t_qs, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(out_block, out_index),
        out_shape=jax.ShapeDtypeStruct(out_view, x.dtype),
        interpret=interpret,
    )(x, *factors_last_first)
    return out.reshape(b, m, qprod * s_out)
