"""Pallas TPU kernel fusing consecutive sliced multiplies (contribution C3).

The paper's fused kernel keeps intermediates in shared memory for up to
``N_fused = floor(log_P T_K)`` factors.  The TPU analogue holds the whole
``(T_M, T_K)`` tile chain in VMEM: one ``pallas_call`` multiplies the tile
through ``n`` factors and stores the final block once, eliminating the
``n-1`` intermediate HBM round-trips of the per-factor path.

Correctness of per-tile fusion (why a tile can be pushed through several
factors independently): after ``j`` multiplies the global intermediate column
index is ``(q_vec, s)`` with ``s`` strictly inherited from the source tile's
column range; slices of factor ``j+1`` group ``P`` *adjacent* ``s`` values of
one ``q_vec``, so as long as ``prod(P_i) | T_K`` no slice ever crosses a tile
boundary.  The final store target is the contiguous block
``(T_M, prod(Q_i), T_K/prod(P_i))`` of the ``(M, prod(Q), K/prod(P))`` output
view — the paper's STOREFUSEDSHMEM index arithmetic, expressed as a BlockSpec.

VMEM budget: the live set is two tiles of ``T_M * T_K * max(1, (Q/P)^j)``
elements (f32 accumulation), so the wrapper checks
``T_M * T_K * growth <= vmem_budget_elems``.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Conservative usable-VMEM budget (f32 elements): ~16 MiB VMEM, keep half for
# double buffering / Mosaic temporaries.
VMEM_BUDGET_ELEMS = 2 * 1024 * 1024


def _fused_kernel(x_ref, *refs, ps: tuple[int, ...], qs: tuple[int, ...], acc_dtype):
    f_refs, (y_ref,) = refs[:-1], refs[-1:]
    t_m = x_ref.shape[0]
    y = x_ref[...]
    cols = x_ref.shape[1]
    # Chain the factors, last factor first (Algorithm 1 order: callers pass
    # factors already reversed so f_refs[0] is F^N).
    for f_ref, p, q in zip(f_refs, ps, qs):
        s = cols // p
        x2 = y.reshape(t_m * s, p)
        acc = jax.lax.dot_general(
            x2, f_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype,
        )  # (t_m*s, q)
        # FastKron layout (m, q, s) — stays in VMEM between factors.
        y = jnp.swapaxes(acc.reshape(t_m, s, q), 1, 2).reshape(t_m, q * s)
        cols = q * s
    y_ref[...] = y.reshape(y_ref.shape).astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("t_m", "t_k", "interpret", "acc_dtype", "vmem_budget_elems"),
)
def fused_kron_pallas(
    x: jax.Array,
    *factors_last_first: jax.Array,
    t_m: int = 8,
    t_k: int | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> jax.Array:
    """Apply ``n`` sliced multiplies in one kernel.

    ``factors_last_first[0]`` is applied first (i.e. it is F^N).  Returns the
    (M, K * prod(Q)/prod(P)) intermediate after all given factors.
    """
    if acc_dtype is None:
        acc_dtype = jnp.promote_types(x.dtype, jnp.float32)
    m, k = x.shape
    ps = tuple(int(f.shape[0]) for f in factors_last_first)
    qs = tuple(int(f.shape[1]) for f in factors_last_first)
    pprod = math.prod(ps)
    qprod = math.prod(qs)
    if k % pprod:
        raise ValueError(f"K={k} not divisible by prod(P)={pprod}")
    t_m = min(t_m, m)
    t_k = min(t_k or k, k)
    # Fusion validity: every slice of every fused stage stays inside the tile.
    if t_k % pprod:
        raise ValueError(f"T_K={t_k} must be a multiple of prod(P)={pprod}")
    growth = max(
        [1.0]
        + [math.prod(qs[: i + 1]) / math.prod(ps[: i + 1]) for i in range(len(ps))]
    )
    if t_m * t_k * growth > vmem_budget_elems:
        raise ValueError(
            f"tile {t_m}x{t_k} (growth {growth:.2f}) exceeds VMEM budget; "
            f"reduce t_k or n_fused"
        )
    if m % t_m or k % t_k:
        raise ValueError(f"tiles must divide dims: {(m, k)} vs {(t_m, t_k)}")

    s_out = k // pprod          # global output minor dim
    ts_out = t_k // pprod       # per-tile share of it
    grid = (m // t_m, k // t_k)
    in_specs = [pl.BlockSpec((t_m, t_k), lambda i, j: (i, j))]
    for f in factors_last_first:
        p, q = f.shape
        in_specs.append(pl.BlockSpec((p, q), lambda i, j: (0, 0)))
    out = pl.pallas_call(
        functools.partial(_fused_kernel, ps=ps, qs=qs, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((t_m, qprod, ts_out), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((m, qprod, s_out), x.dtype),
        interpret=interpret,
    )(x, *factors_last_first)
    return out.reshape(m, qprod * s_out)


def max_n_fused(t_k: int, p: int) -> int:
    """Paper: N_fused = floor(log_P T_K)."""
    n = 0
    while t_k >= p and t_k % p == 0:
        t_k //= p
        n += 1
    return n
