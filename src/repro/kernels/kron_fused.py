"""Compatibility shims: the fused FORWARD Pallas entry points (contribution C3).

The kernel bodies that used to live here — the single-problem fused chain and
its batch-grid twin — are now emitted by the ONE parameterized template in
``kernels/emit.py`` (``emit.chain_pallas`` interpreting a ``multiply``
``StageInstr``; see that module's docstring for the fusion-correctness and
Q-tiling arguments that previously headed this file).  These wrappers keep
the historical signatures for tests/benchmarks; new code should build a
``StageInstr``/``StageProgram`` and call the emitter.
"""
from __future__ import annotations

import jax

from . import emit
from .emit import VMEM_BUDGET_ELEMS, fused_growth, max_n_fused  # noqa: F401


def _acc_name(acc_dtype) -> str | None:
    import jax.numpy as jnp

    return None if acc_dtype is None else jnp.dtype(acc_dtype).name


def fused_kron_pallas(
    x: jax.Array,
    *factors_last_first: jax.Array,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> jax.Array:
    """Apply ``n`` sliced multiplies in one kernel (shim over ``emit``).

    ``factors_last_first[0]`` is applied first (i.e. it is F^N).  Returns the
    (M, K * prod(Q)/prod(P)) intermediate after all given factors.
    """
    instr = emit.StageInstr(
        kind=emit.MULTIPLY,
        ps=tuple(int(f.shape[0]) for f in factors_last_first),
        qs=tuple(int(f.shape[1]) for f in factors_last_first),
        t_m=t_m, t_k=t_k, t_qs=t_qs, acc_dtype=_acc_name(acc_dtype),
    )
    return emit.run_stage(
        x, factors_last_first, instr, backend="pallas", interpret=interpret,
        vmem_budget_elems=vmem_budget_elems,
    )


def fused_kron_batched_pallas(
    x: jax.Array,
    *factors_last_first: jax.Array,
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
    interpret: bool = False,
    acc_dtype=None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> jax.Array:
    """Batch-grid fused chain (shim over ``emit``): ``x (B, M, K)``, factors
    ``(B, P_i, Q_i)`` per-sample, ``t_b`` samples per block."""
    instr = emit.StageInstr(
        kind=emit.MULTIPLY,
        ps=tuple(int(f.shape[1]) for f in factors_last_first),
        qs=tuple(int(f.shape[2]) for f in factors_last_first),
        t_m=t_m, t_k=t_k, t_qs=t_qs, t_b=t_b, acc_dtype=_acc_name(acc_dtype),
    )
    return emit.run_stage(
        x, factors_last_first, instr, backend="pallas", interpret=interpret,
        vmem_budget_elems=vmem_budget_elems,
    )
