"""StageProgram IR + the ONE kernel emitter behind every fused Kron-Matmul path.

Before this module the engine's twelve fused paths — forward / transposed /
backward x single / batched, each written once as a Pallas kernel
(kron_fused.py / kron_fused_t.py) and once as an XLA scan analogue (ops.py) —
were near-duplicate code.  The IR collapses them:

* a ``StageInstr`` is one kernel launch, typed ``multiply`` /
  ``transposed_multiply`` / ``prekron`` and carrying everything the emitter
  needs (``ps, qs, t_m, t_k, t_qs, t_b, direction, acc_dtype``).  ``t_b=None``
  means *unbatched*: batch is just a leading grid axis of size one, not a
  separate code path.
* a ``StageProgram`` is a tuple of instructions; ``transpose(prog)`` derives
  the backward program mechanically (reverse the instructions, flip each
  kind/direction) — no hand-mirrored stage lists anywhere.
* ``run_stage`` / ``run_stage_grad`` / ``run_program`` / ``emit`` interpret
  any program through exactly ONE parameterized Pallas kernel template
  (``_chain_kernel``, plus ``_grad_kernel`` for the factor-gradient stage
  backward) and ONE XLA ``lax.scan`` executor (``_chain_xla`` / ``_grad_xla``).

Planner lowering lives in ``core.autotune.lower`` (KronPlan -> StageProgram);
this module is deliberately core-free so both layers can import it.

Per-stage heterogeneity is first-class: every instruction carries its own
``(p_i, q_i)`` list and its own ``acc_dtype``, so mixed-shape chains like
``ps=(8, 16, 32)`` and per-stage accumulation policies flow through planning,
emission, and the VJP without new code paths.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.runtime import chaos, guard, telemetry
from repro.runtime.guard import LoweringError, VmemOverflowError

# Conservative usable-VMEM budget (f32 elements): ~16 MiB VMEM, keep half for
# double buffering / Mosaic temporaries.
VMEM_BUDGET_ELEMS = 2 * 1024 * 1024

# CPU cache budget for the scan-fused XLA executor (the L2/L3 analogue of the
# Pallas kernels' VMEM budget): chains whose whole working set fits are run
# UNTILED — one set of full-size GEMMs beats a serializing scan when nothing
# spills (measured: the B=8, M=64, (16,16)^3 batched chain is ~1.8x faster
# untiled, while the M=256, (16,16)^4 fig_bwd chain at 64 MB still tiles).
XLA_CACHE_BUDGET_BYTES = 16 * 1024 * 1024

MULTIPLY = "multiply"
TRANSPOSED_MULTIPLY = "transposed_multiply"
PREKRON = "prekron"
_KINDS = (MULTIPLY, TRANSPOSED_MULTIPLY, PREKRON)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str) -> str:
    """Resolve ``"auto"``: pallas on TPU, xla elsewhere.

    ``FASTKRON_FORCE_BACKEND=pallas|xla`` overrides the auto rule (explicit
    backends are untouched) — CI's interpret-mode matrix uses it to route
    every auto-dispatched path through the emitted Pallas templates on a
    CPU runner.
    """
    if backend == "auto":
        forced = os.environ.get("FASTKRON_FORCE_BACKEND")
        if forced in ("pallas", "xla"):
            return forced
        return "pallas" if _on_tpu() else "xla"
    return backend


def acc_dtype_for(dtype) -> jnp.dtype:
    """f32 accumulation for <=f32 inputs, f64 for f64 (never truncate)."""
    return jnp.promote_types(dtype, jnp.float32)


def _resolve_acc(acc_dtype: str | None, dtype):
    if acc_dtype is None:
        return acc_dtype_for(dtype)
    return jnp.dtype(acc_dtype)


# ---------------------------------------------------------------------------
# The IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StageInstr:
    """One kernel launch of a stage program.

    ``ps``/``qs`` are the per-chained-factor dims in APPLICATION order (the
    factor applied first is entry 0).  ``kind`` selects the data flow:
    ``multiply`` chains sliced multiplies, ``transposed_multiply`` un-applies
    them (the input cotangent), ``prekron`` first combines the stage's
    factors into their explicit Kronecker product and applies it as one
    sliced multiply (forward or transposed per ``direction``).

    Tiling: ``t_m`` rows, ``t_k`` input columns (a multiple of ``prod(ps)``;
    None = full), ``t_qs`` per-factor Q-tiles, ``t_b`` samples per block —
    ``t_b=None`` means unbatched, executed as a batch-of-one grid.
    ``acc_dtype`` (a dtype name, e.g. ``"float32"``) is this stage's
    accumulation dtype; None promotes the input dtype against f32.
    ``t_m_bwd`` is the planner's tuned M-tile for the transposed instruction;
    ``transpose()`` swaps it in mechanically.
    """

    kind: str
    ps: tuple[int, ...]
    qs: tuple[int, ...]
    factor_ids: tuple[int, ...] = ()
    t_m: int = 8
    t_k: int | None = None
    t_qs: tuple[int, ...] | None = None
    t_b: int | None = None
    direction: str = "fwd"
    acc_dtype: str | None = None
    t_m_bwd: int | None = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown stage kind {self.kind!r}")
        if self.direction not in ("fwd", "bwd"):
            raise ValueError(f"unknown direction {self.direction!r}")
        if len(self.ps) != len(self.qs) or not self.ps:
            raise ValueError(f"ps/qs must be equal-length, non-empty: {self}")
        # kind implies direction for the non-prekron instructions.
        if self.kind == MULTIPLY and self.direction != "fwd":
            object.__setattr__(self, "direction", "fwd")
        if self.kind == TRANSPOSED_MULTIPLY and self.direction != "bwd":
            object.__setattr__(self, "direction", "bwd")

    @property
    def pprod(self) -> int:
        return math.prod(self.ps)

    @property
    def qprod(self) -> int:
        return math.prod(self.qs)

    @property
    def batched(self) -> bool:
        return self.t_b is not None

    def transpose(self) -> "StageInstr":
        """The instruction computing this instruction's input cotangent."""
        if self.kind == PREKRON:
            kind = PREKRON
            direction = "bwd" if self.direction == "fwd" else "fwd"
        elif self.kind == MULTIPLY:
            kind, direction = TRANSPOSED_MULTIPLY, "bwd"
        else:
            kind, direction = MULTIPLY, "fwd"
        return dataclasses.replace(
            self,
            kind=kind,
            direction=direction,
            t_m=self.t_m_bwd if self.t_m_bwd is not None else self.t_m,
            t_m_bwd=self.t_m,
        )

    def describe(self) -> str:
        tag = f"{self.kind}[{list(self.ps)}x{list(self.qs)}]@(t_m={self.t_m},t_k={self.t_k}"
        if self.t_qs is not None:
            tag += f",t_qs={list(self.t_qs)}"
        if self.t_b is not None:
            tag += f",t_b={self.t_b}"
        if self.acc_dtype is not None:
            tag += f",acc={self.acc_dtype}"
        return tag + ")"


@dataclasses.dataclass(frozen=True)
class StageProgram:
    """A planner-emitted sequence of stage instructions.

    ``factor_ids`` on each instruction index into the REVERSED (application
    order) factor list of an ``n_factors``-long chain; ``run_program`` /
    ``emit`` take factors in PROBLEM order and reverse internally.
    """

    instrs: tuple[StageInstr, ...]
    n_factors: int

    def __post_init__(self):
        seen = [i for ins in self.instrs for i in ins.factor_ids]
        if sorted(seen) != list(range(self.n_factors)):
            raise ValueError(
                f"program instrs must cover factors 0..{self.n_factors - 1} "
                f"exactly once, got {seen}"
            )

    @property
    def batched(self) -> bool:
        return any(ins.batched for ins in self.instrs)

    def describe(self) -> str:
        return " -> ".join(ins.describe() for ins in self.instrs)


def transpose(prog: StageProgram) -> StageProgram:
    """The backward program: reversed instructions, each transposed.

    ``emit(transpose(prog))`` computes the input cotangent of ``emit(prog)``
    (the ``jax.vjp`` of the emitted function with respect to ``x``) — this is
    how the engine derives its backward pass instead of hand-mirroring stage
    lists.  ``transpose`` is an involution up to tile hints.
    """
    return StageProgram(
        tuple(ins.transpose() for ins in reversed(prog.instrs)), prog.n_factors
    )


# ---------------------------------------------------------------------------
# Batch-polymorphic primitive bodies (the deduped `_sliced_body*` family)
# ---------------------------------------------------------------------------


def sliced_apply(y: jax.Array, f: jax.Array, acc_dtype=None) -> jax.Array:
    """One FastKron sliced multiply, batch-polymorphic.

    ``y: (M, S*P)`` with ``f: (P, Q)`` -> ``(M, Q*S)``; or ``y: (B, M, S*P)``
    with per-sample ``f: (B, P, Q)`` -> ``(B, M, Q*S)``.  A 3-D ``y`` with a
    shared 2-D ``f`` folds the batch into rows (pure row-parallelism).
    """
    acc = _resolve_acc(None, y.dtype) if acc_dtype is None else acc_dtype
    if f.ndim == 2:
        if y.ndim == 3:
            b, m, k = y.shape
            return sliced_apply(y.reshape(b * m, k), f, acc).reshape(b, m, -1)
        m, k = y.shape
        p, q = f.shape
        s = k // p
        out = jax.lax.dot_general(
            y.reshape(m * s, p), f, (((1,), (0,)), ((), ())),
            preferred_element_type=acc,
        )
        return (
            jnp.swapaxes(out.reshape(m, s, q), 1, 2).reshape(m, q * s)
            .astype(y.dtype)
        )
    b, m, k = y.shape
    p, q = int(f.shape[1]), int(f.shape[2])
    s = k // p
    out = jax.lax.dot_general(
        y.reshape(b, m * s, p), f, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=acc,
    )
    return (
        jnp.swapaxes(out.reshape(b, m, s, q), 2, 3).reshape(b, m, q * s)
        .astype(y.dtype)
    )


def sliced_apply_t(g: jax.Array, f: jax.Array, acc_dtype=None) -> jax.Array:
    """Transposed sliced multiply (the input cotangent), batch-polymorphic.

    ``g: (M, Q*S)`` with ``f: (P, Q)`` -> ``(M, S*P)``; batched analogue with
    3-D ``g``/``f`` as in ``sliced_apply``.
    """
    acc = _resolve_acc(None, g.dtype) if acc_dtype is None else acc_dtype
    if f.ndim == 2:
        if g.ndim == 3:
            b, m, l = g.shape
            return sliced_apply_t(g.reshape(b * m, l), f, acc).reshape(b, m, -1)
        m, l = g.shape
        p, q = f.shape
        s = l // q
        out = jax.lax.dot_general(
            jnp.swapaxes(g.reshape(m, q, s), 1, 2).reshape(m * s, q),
            jnp.swapaxes(f, 0, 1),
            (((1,), (0,)), ((), ())),
            preferred_element_type=acc,
        )
        return out.reshape(m, s * p).astype(g.dtype)
    b, m, l = g.shape
    p, q = int(f.shape[1]), int(f.shape[2])
    s = l // q
    g2 = jnp.swapaxes(g.reshape(b, m, q, s), 2, 3).reshape(b, m * s, q)
    out = jax.lax.dot_general(
        g2, f, (((2,), (2,)), ((0,), (0,))), preferred_element_type=acc
    )
    return out.reshape(b, m, s * p).astype(g.dtype)


def prekron_product(stage_factors: Sequence[jax.Array]) -> jax.Array:
    """Explicit Kronecker product of a stage's factors, batch-polymorphic.

    ``stage_factors`` are in APPLICATION order (rev[i], rev[i+1], ...); the
    explicit product must be formed in PROBLEM order, i.e. kron(rev[i+1],
    rev[i]): ``x @ (A (x) B)`` applies B first.  3-D per-sample factors run a
    vmapped ``jnp.kron`` chain.
    """
    stage_factors = tuple(stage_factors)
    kron = jax.vmap(jnp.kron) if stage_factors[0].ndim == 3 else jnp.kron
    f = stage_factors[-1]
    for g in reversed(stage_factors[:-1]):
        f = kron(f, g)
    return f


# ---------------------------------------------------------------------------
# Slab-sliced execution (the distributed round pipeline's view of a program)
# ---------------------------------------------------------------------------


def effective_slabs(size: int, n_slabs: int) -> int:
    """Clamp a requested slab count to what the axis can actually carry: the
    largest divisor of ``size`` that is ``<= n_slabs``.  Slabs must tile the
    axis exactly — a ragged tail slab would change the per-slab payload and
    break the exact comm-accounting invariant (per-slab all_to_all payloads
    sum to the serial total), so we never allow one.  ``n_slabs <= 1`` (and
    ``size == 0``) degenerate to 1, the serial schedule."""
    n = max(1, min(int(n_slabs), int(size) if size else 1))
    while size % n:
        n -= 1
    return n


def split_slabs(y: jax.Array, n_slabs: int, axis: int = 0) -> list[jax.Array]:
    """Split ``y`` into ``n_slabs`` equal slabs along ``axis``.

    The slabs partition an embarrassingly-parallel axis (rows of a 2-D
    operand, samples of a batched one), so running any stage/chain per slab
    and concatenating is BITWISE-identical to the unsliced run — the property
    the slab-pipelined distributed rounds rely on for their serial-parity
    guarantee.  Callers clamp via ``effective_slabs`` first; a non-dividing
    count here is a programming error."""
    size = int(y.shape[axis])
    if n_slabs <= 1:
        return [y]
    if size % n_slabs:
        raise ValueError(
            f"n_slabs={n_slabs} does not divide axis {axis} of size {size}; "
            f"clamp with effective_slabs first"
        )
    return list(jnp.split(y, n_slabs, axis=axis))


# ---------------------------------------------------------------------------
# VMEM-growth models (shared by the emitter and the planner)
# ---------------------------------------------------------------------------


def fused_growth(
    ps: Sequence[int], qs: Sequence[int], t_qs: Sequence[int] | None = None
) -> float:
    """Max live-set multiplier over chain prefixes, with optional Q-tiling."""
    t_qs = tuple(t_qs) if t_qs is not None else tuple(qs)
    g = 1.0
    pprod = qprod = 1
    for p, tq in zip(ps, t_qs):
        pprod *= p
        qprod *= tq
        g = max(g, qprod / pprod)
    return g


def transposed_growth(
    ps: Sequence[int], qs: Sequence[int], t_qs: Sequence[int] | None = None
) -> float:
    """Max live-set multiplier of the inverse chain, relative to T_K.

    Walking the chain backwards, the per-tile column count goes
    ``prod(t_q)*ts_out -> ... -> t_k``; the max over those states bounds VMEM.
    """
    t_qs = tuple(t_qs) if t_qs is not None else tuple(qs)
    pprod = math.prod(ps)
    cols = math.prod(t_qs) / pprod  # in units of t_k
    g = max(1.0, cols)
    for p, tq in zip(reversed(tuple(ps)), reversed(t_qs)):
        cols = cols / tq * p
        g = max(g, cols)
    return g


def max_n_fused(t_k: int, p: int) -> int:
    """Paper: N_fused = floor(log_P T_K)."""
    n = 0
    while t_k >= p and t_k % p == 0:
        t_k //= p
        n += 1
    return n


# ---------------------------------------------------------------------------
# THE Pallas kernel template (chain, both directions, batch grid axis)
# ---------------------------------------------------------------------------


def _chain_kernel(
    x_ref, *refs, ps: tuple[int, ...], qs: tuple[int, ...], direction: str,
    acc_dtype,
):
    """One parameterized kernel body for every fused chain.

    Tiles always carry a leading batch axis (size 1 when the instruction is
    unbatched); every GEMM is a ``dot_general`` with a batch dimension, so
    sample b's tile only ever contracts against sample b's factor slice.
    ``direction="fwd"`` chains the factors (Algorithm 1 order, f_refs[0]
    first); ``"bwd"`` inverts the chain with transposed contractions and
    accumulates partial dX tiles across the sequential Q-tile grid axis.
    """
    f_refs, (y_ref,) = refs[:-1], refs[-1:]
    t_b, t_m = x_ref.shape[0], x_ref.shape[1]
    if direction == "fwd":
        y = x_ref[...]
        cols = x_ref.shape[2]
        for f_ref, p, q in zip(f_refs, ps, qs):
            s = cols // p
            acc = jax.lax.dot_general(
                y.reshape(t_b, t_m * s, p), f_ref[...],
                (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=acc_dtype,
            )  # (t_b, t_m*s, q)
            # FastKron layout (b, m, q, s) — stays in VMEM between factors.
            y = jnp.swapaxes(acc.reshape(t_b, t_m, s, q), 2, 3).reshape(
                t_b, t_m, q * s
            )
            cols = q * s
        y_ref[...] = y.reshape(y_ref.shape).astype(y_ref.dtype)
        return
    # Transposed chain: the forward applied f_refs[0] first, so its transpose
    # is applied last; the most-recently-applied factor's q is the major
    # digit of the current layout and is contracted first.
    jq = pl.program_id(3)
    g = x_ref[...].reshape(t_b, t_m, -1).astype(acc_dtype)
    cols = g.shape[2]
    for f_ref, p, q in reversed(list(zip(f_refs, ps, qs))):
        s = cols // q
        g2 = jnp.swapaxes(g.reshape(t_b, t_m, q, s), 2, 3).reshape(
            t_b, t_m * s, q
        )
        acc = jax.lax.dot_general(
            g2, f_ref[...], (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=acc_dtype,
        )  # (t_b, t_m*s, p)
        g = acc.reshape(t_b, t_m, s * p)
        cols = s * p
    # y_ref is acc_dtype (cast to the input dtype by the wrapper) so the
    # cross-Q-tile accumulation never rounds through a low-precision type.
    part = g.astype(y_ref.dtype)

    @pl.when(jq == 0)
    def _init():
        y_ref[...] = part

    @pl.when(jq > 0)
    def _acc():
        y_ref[...] += part


def _q_tiling(qs, t_qs, n):
    nq = tuple(q // t for q, t in zip(qs, t_qs))
    strides = [1] * n
    for i in range(1, n):
        strides[i] = strides[i - 1] * nq[i - 1]

    def q_digit(jq, i):
        return (jq // strides[i]) % nq[i]

    return math.prod(nq), q_digit


@functools.partial(
    jax.jit,
    static_argnames=(
        "t_b", "t_m", "t_k", "t_qs", "direction", "interpret", "acc_dtype",
        "vmem_budget_elems",
    ),
)
def chain_pallas(
    x: jax.Array,
    *factors: jax.Array,
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    t_qs: tuple[int, ...] | None = None,
    direction: str = "fwd",
    interpret: bool = False,
    acc_dtype: str | None = None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> jax.Array:
    """The single Pallas entry point for any chain instruction.

    ``x: (B, M, C)``; each factor ``(B, P_i, Q_i)`` (B=1 replays the
    unbatched kernels).  ``direction="fwd"``: C = K, returns the
    ``(B, M, prod(Q) * K/prod(P))`` chain output.  ``direction="bwd"``:
    ``x`` is the cotangent at C = prod(Q)*S, returns dX ``(B, M, prod(P)*S)``.
    The grid is always ``(B/t_b, M/t_m, Q-tiles, K/t_k)`` (Q-tiles innermost
    for "bwd": the sequential accumulation axis).
    """
    acc = _resolve_acc(acc_dtype, x.dtype)
    b, m, cols = x.shape
    n = len(factors)
    ps = tuple(int(f.shape[1]) for f in factors)
    qs = tuple(int(f.shape[2]) for f in factors)
    for f in factors:
        if int(f.shape[0]) != b:
            raise LoweringError(f"factor batch {f.shape[0]} != x batch {b}")
    pprod = math.prod(ps)
    qprod = math.prod(qs)
    if direction == "fwd":
        if cols % pprod:
            raise LoweringError(f"K={cols} not divisible by prod(P)={pprod}")
        k = cols
    else:
        if cols % qprod:
            raise LoweringError(
                f"dY cols {cols} not divisible by prod(Q)={qprod}"
            )
        k = cols // qprod * pprod
    s_out = k // pprod
    t_b = min(t_b, b)
    t_m = min(t_m, m)
    t_k = min(t_k or k, k)
    if t_qs is None:
        t_qs = qs
    t_qs = tuple(min(t, q) for t, q in zip(t_qs, qs))
    if len(t_qs) != n:
        raise LoweringError(f"t_qs needs one entry per factor: {t_qs} vs {n}")
    if any(q % t for q, t in zip(qs, t_qs)):
        raise LoweringError(f"t_qs must divide factor Q dims: {t_qs} vs {qs}")
    # Fusion validity: every slice of every fused stage stays inside the tile.
    if t_k % pprod:
        raise LoweringError(f"T_K={t_k} must be a multiple of prod(P)={pprod}")
    growth_fn = fused_growth if direction == "fwd" else transposed_growth
    growth = growth_fn(ps, qs, t_qs)
    if t_b * t_m * t_k * growth > vmem_budget_elems:
        raise VmemOverflowError(
            f"tile {t_b}x{t_m}x{t_k} (growth {growth:.2f}) exceeds VMEM "
            f"budget; reduce t_b / t_m / t_k or tile Q via t_qs"
        )
    if b % t_b or m % t_m or k % t_k:
        raise LoweringError(
            f"tiles must divide dims: {(b, m, k)} vs {(t_b, t_m, t_k)}"
        )

    ts_out = t_k // pprod
    # Composite Q-tile grid axis: one mixed-radix digit per factor, factor 0
    # (applied first) minor — matching the output layout (q_n, ..., q_1, s).
    nq_tiles, q_digit = _q_tiling(qs, t_qs, n)
    # The (B, M, Q_{n-1}, ..., Q_0, S) view: row-major it flattens to the
    # FastKron layout (B, M, prod(Q)*S); each Q axis is tiled by its own digit.
    q_view = (b, m) + tuple(reversed(qs)) + (s_out,)
    q_block = (t_b, t_m) + tuple(reversed(t_qs)) + (ts_out,)

    if direction == "fwd":
        grid = (b // t_b, m // t_m, nq_tiles, k // t_k)

        def q_index(ib, im, jq, j):
            return (ib, im) + tuple(
                q_digit(jq, i) for i in reversed(range(n))
            ) + (j,)

        in_specs = [
            pl.BlockSpec((t_b, t_m, t_k), lambda ib, im, jq, j: (ib, im, j))
        ]
        for i in range(n):
            in_specs.append(
                pl.BlockSpec(
                    (t_b, ps[i], t_qs[i]),
                    lambda ib, im, jq, j, i=i: (ib, 0, q_digit(jq, i)),
                )
            )
        out = pl.pallas_call(
            functools.partial(
                _chain_kernel, ps=ps, qs=t_qs, direction="fwd", acc_dtype=acc
            ),
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(q_block, q_index),
            out_shape=jax.ShapeDtypeStruct(q_view, x.dtype),
            interpret=interpret,
        )(x, *factors)
        return out.reshape(b, m, qprod * s_out)

    # bwd: Q innermost — the sequential accumulation dim.
    grid = (b // t_b, m // t_m, k // t_k, nq_tiles)

    def q_index(ib, im, j, jq):
        return (ib, im) + tuple(
            q_digit(jq, i) for i in reversed(range(n))
        ) + (j,)

    in_specs = [pl.BlockSpec(q_block, q_index)]
    for i in range(n):
        in_specs.append(
            pl.BlockSpec(
                (t_b, ps[i], t_qs[i]),
                lambda ib, im, j, jq, i=i: (ib, 0, q_digit(jq, i)),
            )
        )
    out = pl.pallas_call(
        functools.partial(
            _chain_kernel, ps=ps, qs=t_qs, direction="bwd", acc_dtype=acc
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (t_b, t_m, t_k), lambda ib, im, j, jq: (ib, im, j)
        ),
        out_shape=jax.ShapeDtypeStruct((b, m, k), acc),
        interpret=interpret,
    )(x.reshape(q_view), *factors)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# The stage-backward Pallas template (dx + factor grads in one launch)
# ---------------------------------------------------------------------------


def _grad_kernel(
    x_ref, dy_ref, *refs, ps: tuple[int, ...], qs: tuple[int, ...], acc_dtype
):
    """Full stage backward: rematerialize the forward chain in VMEM, then
    walk the transposed chain computing the input gradient and every factor
    gradient.  Per factor ONE in-VMEM relayout of the gradient tile is shared
    by the factor-gradient GEMM (``U^T G``) and the chain-step GEMM
    (``G F^T``).  Factor grads are per batch block: they accumulate over the
    (M, K) grid for a fixed batch block only (batch is the outermost grid
    axis, sequential on TPU), which reduces to the whole-grid accumulation
    of the unbatched kernel when B = t_b = 1.
    """
    f_refs = refs[: len(ps)]
    dx_ref = refs[len(ps)]
    df_refs = refs[len(ps) + 1 :]
    im, j = pl.program_id(1), pl.program_id(2)
    first = jnp.logical_and(im == 0, j == 0)
    t_b, t_m = x_ref.shape[0], x_ref.shape[1]
    # In-VMEM rematerialization of the forward chain (stage-local residuals).
    us = []
    y = x_ref[...].astype(acc_dtype)
    cols = y.shape[2]
    for f_ref, p, q in zip(f_refs, ps, qs):
        us.append(y)
        s = cols // p
        acc = jax.lax.dot_general(
            y.reshape(t_b, t_m * s, p), f_ref[...], (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=acc_dtype,
        )
        y = jnp.swapaxes(acc.reshape(t_b, t_m, s, q), 2, 3).reshape(
            t_b, t_m, q * s
        )
        cols = q * s
    g = dy_ref[...].reshape(t_b, t_m, -1).astype(acc_dtype)
    cols = g.shape[2]
    for idx in reversed(range(len(f_refs))):
        p, q = ps[idx], qs[idx]
        s = cols // q
        g2 = jnp.swapaxes(g.reshape(t_b, t_m, q, s), 2, 3).reshape(
            t_b, t_m * s, q
        )
        u2 = us[idx].reshape(t_b, t_m * s, p)
        df_part = jax.lax.dot_general(
            u2, g2, (((1,), (1,)), ((0,), (0,))), preferred_element_type=acc_dtype
        )  # (t_b, p, q)

        @pl.when(first)
        def _init(df_ref=df_refs[idx], df_part=df_part):
            df_ref[...] = df_part

        @pl.when(jnp.logical_not(first))
        def _acc(df_ref=df_refs[idx], df_part=df_part):
            df_ref[...] += df_part

        g = jax.lax.dot_general(
            g2, f_refs[idx][...], (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=acc_dtype,
        ).reshape(t_b, t_m, s * p)
        cols = s * p
    dx_ref[...] = g.astype(dx_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "t_b", "t_m", "t_k", "interpret", "acc_dtype", "vmem_budget_elems",
    ),
)
def grad_pallas(
    x: jax.Array,
    dy: jax.Array,
    *factors: jax.Array,
    t_b: int = 1,
    t_m: int = 8,
    t_k: int | None = None,
    interpret: bool = False,
    acc_dtype: str | None = None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """The single Pallas stage-backward: (dx, per-factor grads).

    ``x: (B, M, K)`` stage input, ``dy: (B, M, prod(Q)*S)`` stage output
    cotangent, factors ``(B, P_i, Q_i)``; dfs returned in application order,
    each ``(B, P_i, Q_i)``, accumulated in the stage's acc dtype.  B = 1
    replays the unbatched kernel exactly.
    """
    acc = _resolve_acc(acc_dtype, dy.dtype)
    b, m, k = x.shape
    ps = tuple(int(f.shape[1]) for f in factors)
    qs = tuple(int(f.shape[2]) for f in factors)
    for f in factors:
        if int(f.shape[0]) != b:
            raise LoweringError(f"factor batch {f.shape[0]} != x batch {b}")
    pprod = math.prod(ps)
    qprod = math.prod(qs)
    if k % pprod:
        raise LoweringError(f"K={k} not divisible by prod(P)={pprod}")
    s_out = k // pprod
    if dy.shape != (b, m, qprod * s_out):
        raise LoweringError(f"dy shape {dy.shape} != {(b, m, qprod * s_out)}")
    t_b = min(t_b, b)
    t_m = min(t_m, m)
    t_k = min(t_k or k, k)
    if t_k % pprod:
        raise LoweringError(f"T_K={t_k} must be a multiple of prod(P)={pprod}")
    # Live set: all forward intermediates of the tile chain plus the gradient
    # tile — a sum over chain states, not just the max.
    cols = float(t_k)
    live = cols
    for p, q in zip(ps, qs):
        cols = cols / p * q
        live += cols
    if t_b * t_m * (live + cols) > vmem_budget_elems:
        raise VmemOverflowError(
            f"bwd tile {t_b}x{t_m}x{t_k} live set "
            f"{int(t_b * t_m * (live + cols))} elems exceeds VMEM budget; "
            f"reduce t_b / t_k or split the stage"
        )
    if b % t_b or m % t_m or k % t_k:
        raise LoweringError(
            f"tiles must divide dims: {(b, m, k)} vs {(t_b, t_m, t_k)}"
        )

    ts_out = t_k // pprod
    grid = (b // t_b, m // t_m, k // t_k)
    in_specs = [
        pl.BlockSpec((t_b, t_m, t_k), lambda ib, im, j: (ib, im, j)),
        pl.BlockSpec((t_b, t_m, qprod, ts_out), lambda ib, im, j: (ib, im, 0, j)),
    ]
    for p, q in zip(ps, qs):
        in_specs.append(pl.BlockSpec((t_b, p, q), lambda ib, im, j: (ib, 0, 0)))
    out_specs = [pl.BlockSpec((t_b, t_m, t_k), lambda ib, im, j: (ib, im, j))]
    out_shapes = [jax.ShapeDtypeStruct((b, m, k), x.dtype)]
    for p, q in zip(ps, qs):
        out_specs.append(pl.BlockSpec((t_b, p, q), lambda ib, im, j: (ib, 0, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((b, p, q), acc))
    outs = pl.pallas_call(
        functools.partial(_grad_kernel, ps=ps, qs=qs, acc_dtype=acc),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(x, dy.reshape(b, m, qprod, s_out), *factors)
    return outs[0], tuple(outs[1:])


# ---------------------------------------------------------------------------
# THE XLA lax.scan executor (chain, both directions, both batch modes)
# ---------------------------------------------------------------------------


def _chain_max_cols(cols: int, pqs: Sequence[tuple[int, int]]) -> int:
    """Max column count over the chain states starting from ``cols``."""
    mx = cols
    for p, q in pqs:
        cols = cols // p * q
        mx = max(mx, cols)
    return mx


def _xla_tile_rows(m: int, t_m: int, row_bytes: int | None = None) -> int | None:
    """Effective M-tile for the scan-fused XLA path, or None to run untiled.

    Tiling pays off only when the full chain would spill cache
    (``row_bytes``: widest per-row working set) AND the tile chain fits with
    enough tiles to amortize the scan; tiny analytic t_m values (tuned for
    the TPU sublane) are clamped up to a useful CPU tile.
    """
    if row_bytes is not None and m * row_bytes <= XLA_CACHE_BUDGET_BYTES:
        return None
    t = min(m, max(t_m, 8))
    if t >= m or m % t or m // t < 2:
        return None
    return t


def _batch_tile(b: int, t_b: int, sample_bytes: int | None = None) -> int | None:
    """Effective batch tile for the scan-batched XLA path, or None untiled.

    ``sample_bytes``: one sample's chain working set — when the whole batch
    fits the cache budget, run untiled (same rule as ``_xla_tile_rows``).
    """
    if sample_bytes is not None and b * sample_bytes <= XLA_CACHE_BUDGET_BYTES:
        return None
    t = min(b, max(t_b, 1))
    if t >= b or b % t or b // t < 2:
        return None
    return t


def _chain_pqs(factors, direction: str) -> list[tuple[int, int]]:
    """(contract, expand) dims in traversal order for the working-set model."""
    if direction == "fwd":
        return [(int(f.shape[-2]), int(f.shape[-1])) for f in factors]
    return [(int(f.shape[-1]), int(f.shape[-2])) for f in reversed(factors)]


def _chain_apply(y, fs, direction: str, acc) -> jax.Array:
    """The shared chain body: sliced multiplies (fwd) or their transposes in
    reverse (bwd), batch-polymorphic through ``sliced_apply``/``sliced_apply_t``."""
    if direction == "fwd":
        for f in fs:
            y = sliced_apply(y, f, acc)
        return y
    for f in reversed(tuple(fs)):
        y = sliced_apply_t(y, f, acc)
    return y


@functools.partial(
    jax.jit, static_argnames=("t_m", "t_b", "direction", "acc_dtype")
)
def _chain_xla(
    x: jax.Array,
    factors: tuple[jax.Array, ...],
    t_m: int = 8,
    t_b: int | None = None,
    direction: str = "fwd",
    acc_dtype: str | None = None,
) -> jax.Array:
    """The one lax.scan executor: any chain instruction on the XLA backend.

    Unbatched input (2-D ``x``) tiles over M rows; batched input (3-D ``x``
    with 3-D per-sample factors) tiles over B samples.  Either way the whole
    per-tile chain stays cache-resident — the CPU analogue of the Pallas
    kernel's VMEM fusion — and runs UNTILED when the full working set already
    fits ``XLA_CACHE_BUDGET_BYTES``.
    """
    acc = _resolve_acc(acc_dtype, x.dtype)
    maxcols = _chain_max_cols(int(x.shape[-1]), _chain_pqs(factors, direction))
    if x.ndim == 2:
        m, cols = x.shape
        t = _xla_tile_rows(m, t_m, maxcols * x.dtype.itemsize)
        if t is None:
            return _chain_apply(x, factors, direction, acc)
        _, yt = jax.lax.scan(
            lambda _, xt: (None, _chain_apply(xt, factors, direction, acc)),
            None,
            x.reshape(m // t, t, cols),
        )
        return yt.reshape(m, -1)
    b, m, cols = x.shape
    t = _batch_tile(b, t_b or 1, m * maxcols * x.dtype.itemsize)
    if t is None:
        return _chain_apply(x, factors, direction, acc)
    xs = (
        x.reshape(b // t, t, m, cols),
        tuple(f.reshape(b // t, t, *f.shape[1:]) for f in factors),
    )
    _, yt = jax.lax.scan(
        lambda _, xf: (None, _chain_apply(xf[0], xf[1], direction, acc)),
        None,
        xs,
    )
    return yt.reshape(b, m, -1)


def _grad_tile(us_first, g, factors, acc):
    """Backward of one chain tile, batch-polymorphic: shared relayout per
    factor feeds both the factor-gradient GEMM and the chain-step GEMM.
    2-D tiles sum factor grads over rows; 3-D tiles keep them per sample."""
    us = [us_first]
    y = us_first
    for f in factors[:-1]:
        y = sliced_apply(y, f, acc)
        us.append(y)
    dfs = [None] * len(factors)
    cols = g.shape[-1]
    for idx in reversed(range(len(factors))):
        f = factors[idx]
        p, q = int(f.shape[-2]), int(f.shape[-1])
        s = cols // q
        if g.ndim == 2:
            t_m = g.shape[0]
            g2 = jnp.swapaxes(g.reshape(t_m, q, s), 1, 2).reshape(t_m * s, q)
            u2 = us[idx].reshape(t_m * s, p)
            dfs[idx] = jax.lax.dot_general(
                u2.astype(acc), g2.astype(acc), (((0,), (0,)), ((), ())),
                preferred_element_type=acc,
            )
            g = jax.lax.dot_general(
                g2, f, (((1,), (1,)), ((), ())), preferred_element_type=acc
            ).reshape(t_m, s * p).astype(g.dtype)
        else:
            t_b, t_m = g.shape[0], g.shape[1]
            g2 = jnp.swapaxes(g.reshape(t_b, t_m, q, s), 2, 3).reshape(
                t_b, t_m * s, q
            )
            u2 = us[idx].reshape(t_b, t_m * s, p)
            dfs[idx] = jax.lax.dot_general(
                u2.astype(acc), g2.astype(acc), (((1,), (1,)), ((0,), (0,))),
                preferred_element_type=acc,
            )  # (t_b, p, q)
            g = jax.lax.dot_general(
                g2, f, (((2,), (2,)), ((0,), (0,))), preferred_element_type=acc
            ).reshape(t_b, t_m, s * p).astype(g.dtype)
        cols = s * p
    return dfs, g


def _chain_live_cols(k: int, factors) -> int:
    """Backward live set per row: every forward chain state plus the gradient
    at its widest — a sum over chain states, not a max."""
    live = cols = k
    for f in factors:
        cols = cols // int(f.shape[-2]) * int(f.shape[-1])
        live += cols
    return live


@functools.partial(jax.jit, static_argnames=("t_m", "t_b", "acc_dtype"))
def _grad_xla(
    x: jax.Array,
    dy: jax.Array,
    factors: tuple[jax.Array, ...],
    t_m: int = 8,
    t_b: int | None = None,
    acc_dtype: str | None = None,
):
    """The one lax.scan stage-backward executor (dx + factor grads).

    Unbatched: M-tiled scan whose carry SUMS factor grads across row tiles.
    Batched: batch-tiled scan stacking per-sample factor grads.
    """
    acc = _resolve_acc(acc_dtype, dy.dtype)
    if x.ndim == 2:
        m, k = x.shape
        t = _xla_tile_rows(m, t_m, _chain_live_cols(k, factors) * x.dtype.itemsize)
        if t is None:
            dfs, dx = _grad_tile(x, dy, factors, acc)
            return dx, tuple(dfs)

        def body(carry, xg):
            dfs, g = _grad_tile(xg[0], xg[1], factors, acc)
            return tuple(c + d for c, d in zip(carry, dfs)), g

        carry0 = tuple(jnp.zeros(f.shape, acc) for f in factors)
        dfs, dxt = jax.lax.scan(
            body, carry0, (x.reshape(m // t, t, k), dy.reshape(m // t, t, -1))
        )
        return dxt.reshape(m, k), dfs
    b, m, k = x.shape
    t = _batch_tile(
        b, t_b or 1, m * _chain_live_cols(k, factors) * x.dtype.itemsize
    )
    if t is None:
        dfs, dx = _grad_tile(x, dy, factors, acc)
        return dx, tuple(dfs)

    def body(_, xs):
        dfs, g = _grad_tile(xs[0], xs[1], xs[2], acc)
        return None, (g, tuple(dfs))

    xs = (
        x.reshape(b // t, t, m, k),
        dy.reshape(b // t, t, m, -1),
        tuple(f.reshape(b // t, t, *f.shape[1:]) for f in factors),
    )
    _, (dxt, dfts) = jax.lax.scan(body, None, xs)
    return dxt.reshape(b, m, k), tuple(d.reshape(b, *d.shape[2:]) for d in dfts)


# ---------------------------------------------------------------------------
# Instruction / program interpreters (the emitter's public surface)
# ---------------------------------------------------------------------------


def _interpret_default(interpret: bool | None) -> bool:
    return not _on_tpu() if interpret is None else interpret


def _effective(instr: StageInstr, fs: tuple[jax.Array, ...]):
    """(direction, factors, t_qs) after resolving a prekron instruction into
    its explicit product (a chain of one).  A length-1 ``t_qs`` on a prekron
    instruction is the Q-tile of the COMBINED product and survives the
    substitution; per-original-factor tiles do not apply to the product."""
    if instr.kind == PREKRON:
        t_qs = instr.t_qs if instr.t_qs and len(instr.t_qs) == 1 else None
        return instr.direction, (prekron_product(fs),), t_qs
    return instr.direction, fs, instr.t_qs


def run_stage(
    y: jax.Array,
    stage_factors: Sequence[jax.Array],
    instr: StageInstr,
    *,
    backend: str = "auto",
    interpret: bool | None = None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> jax.Array:
    """Execute one chain instruction on ``y``.

    ``stage_factors`` are the stage's factor arrays in application order —
    2-D when ``instr.t_b is None``, per-sample 3-D otherwise.  Raises
    ``VmemOverflowError`` (a ``ValueError``) when the Pallas tiling cannot
    hold the stage in VMEM (callers fall back to per-factor execution).
    """
    chaos.maybe_fail("stage_execute")
    # One truthiness check when telemetry is off (span() returns a shared
    # no-op): no named_scope enters the trace, compiled HLO is unchanged.
    with telemetry.span("stage", kind=instr.kind, direction=instr.direction):
        fs = tuple(stage_factors)
        direction, fs, t_qs = _effective(instr, fs)
        b = resolve_backend(backend)
        if b == "xla":
            return _chain_xla(
                y, fs, t_m=instr.t_m, t_b=instr.t_b, direction=direction,
                acc_dtype=instr.acc_dtype,
            )
        chaos.maybe_fail("pallas_lowering")
        ip = _interpret_default(interpret)
        if instr.t_b is None:
            out = chain_pallas(
                y[None], *(f[None] for f in fs), t_b=1, t_m=instr.t_m,
                t_k=instr.t_k, t_qs=t_qs, direction=direction, interpret=ip,
                acc_dtype=instr.acc_dtype, vmem_budget_elems=vmem_budget_elems,
            )
            return out[0]
        return chain_pallas(
            y, *fs, t_b=instr.t_b, t_m=instr.t_m, t_k=instr.t_k, t_qs=t_qs,
            direction=direction, interpret=ip, acc_dtype=instr.acc_dtype,
            vmem_budget_elems=vmem_budget_elems,
        )


def run_stage_grad(
    u: jax.Array,
    g: jax.Array,
    stage_factors: Sequence[jax.Array],
    instr: StageInstr,
    *,
    backend: str = "auto",
    interpret: bool | None = None,
    vmem_budget_elems: int = VMEM_BUDGET_ELEMS,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """Full backward of one forward chain instruction: (dx, factor grads).

    ``u`` is the stage input, ``g`` the stage output cotangent; ``instr`` is
    the FORWARD instruction (its transpose is implied).  Factor grads are
    returned in application order, accumulated in the stage's acc dtype
    (callers cast).  Raises ``VmemOverflowError`` (a ``ValueError``) when
    the one-kernel Pallas backward cannot hold the stage's live set in VMEM.
    """
    chaos.maybe_fail("stage_execute")
    with telemetry.span("stage_grad", kind=instr.kind):
        fs = tuple(stage_factors)
        b = resolve_backend(backend)
        if b == "xla":
            dx, dfs = _grad_xla(
                u, g, fs, t_m=instr.t_m, t_b=instr.t_b,
                acc_dtype=instr.acc_dtype,
            )
            return guard.check_finite(dx, "run_stage_grad"), dfs
        chaos.maybe_fail("pallas_lowering")
        ip = _interpret_default(interpret)
        if instr.t_b is None:
            dx, dfs = grad_pallas(
                u[None], g[None], *(f[None] for f in fs), t_b=1,
                t_m=instr.t_m, t_k=instr.t_k, interpret=ip,
                acc_dtype=instr.acc_dtype,
                vmem_budget_elems=vmem_budget_elems,
            )
            return guard.check_finite(dx[0], "run_stage_grad"), tuple(
                d[0] for d in dfs
            )
        dx, dfs = grad_pallas(
            u, g, *fs, t_b=instr.t_b, t_m=instr.t_m, t_k=instr.t_k,
            interpret=ip, acc_dtype=instr.acc_dtype,
            vmem_budget_elems=vmem_budget_elems,
        )
        return guard.check_finite(dx, "run_stage_grad"), dfs


def run_program(
    x: jax.Array,
    factors: Sequence[jax.Array],
    prog: StageProgram,
    *,
    backend: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Interpret a StageProgram: walk its instructions over ``x``.

    ``factors`` is the full chain's factor tuple in PROBLEM order (as the
    engine's entry points take it); each instruction selects its stage's
    factors via ``factor_ids`` into the reversed (application-order) list.
    For a transposed program (``transpose(prog)``), ``x`` is the output
    cotangent and the result is the input cotangent.
    """
    factors = tuple(factors)
    if len(factors) != prog.n_factors:
        raise ValueError(
            f"program expects {prog.n_factors} factors, got {len(factors)}"
        )
    rev = tuple(reversed(factors))
    with telemetry.span("program", stages=len(prog.instrs)):
        y = x
        for instr in prog.instrs:
            y = run_stage(
                y, tuple(rev[i] for i in instr.factor_ids), instr,
                backend=backend, interpret=interpret,
            )
    # Non-finite guard on the program's output — the value downstream layers
    # consume, after every stage's acc_dtype downcast (policy off|warn|raise).
    return guard.check_finite(y, "run_program")


def emit(
    prog: StageProgram, *, backend: str = "auto", interpret: bool | None = None
):
    """Close a StageProgram over a backend: returns ``fn(x, factors)``.

    ``emit(transpose(prog))`` is the x-cotangent of ``emit(prog)`` — the
    property pinned by tests/test_properties.py.
    """

    def fn(x, factors):
        return run_program(x, factors, prog, backend=backend, interpret=interpret)

    return fn


__all__ = [
    "StageInstr",
    "StageProgram",
    "transpose",
    "emit",
    "run_program",
    "run_stage",
    "run_stage_grad",
    "sliced_apply",
    "sliced_apply_t",
    "prekron_product",
    "effective_slabs",
    "split_slabs",
    "chain_pallas",
    "grad_pallas",
    "fused_growth",
    "transposed_growth",
    "max_n_fused",
    "acc_dtype_for",
    "resolve_backend",
    "MULTIPLY",
    "TRANSPOSED_MULTIPLY",
    "PREKRON",
    "VMEM_BUDGET_ELEMS",
    "XLA_CACHE_BUDGET_BYTES",
]
