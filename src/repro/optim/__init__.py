"""Optimizer substrate: AdamW + schedules + gradient compression."""
from .adamw import OptConfig, opt_init, opt_update, lr_at  # noqa: F401
