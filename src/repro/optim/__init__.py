"""Optimizer substrate: AdamW + schedules + gradient compression, and the
Kron-factored Shampoo preconditioner routed through the KronOp engine."""
from .adamw import OptConfig, opt_init, opt_update, lr_at  # noqa: F401
from .shampoo import (  # noqa: F401
    ShampooConfig,
    shampoo_init,
    shampoo_update,
    opt_for,
    state_memory_report,
)
