"""Shampoo-style Kron-factored preconditioning through the KronOp engine.

The preconditioned update ``P = L^{-1/4} G R^{-1/4}`` is a Kron-Matmul:
with the row-major flattening ``vec_row(A^T G B) = vec_row(G) @ (A (x) B)``,
every layer's apply is one row of ``x @ (Lroot (x) Rroot)`` — exactly the
workload the engine accelerates.  So the application step groups same-shape
layers and executes ONE per-sample-factor batched ``KronOp`` call per shape
group (``engine.kron_precond_op``): x = the stacked update directions
reshaped ``(B, 1, p*q)``, factors = the stacked per-layer root pairs
``(B, p, p)`` / ``(B, q, q)``.  Because the inverse roots are symmetric,
``Lroot^T u Rroot = L^{-1/4} u R^{-1/4}``.

Algorithm per step (mirrors ``adamw.opt_update`` bit-for-bit up to the
direction swap, so ineligible params get EXACTLY AdamW):

1. statistics ``L += G G^T``, ``R += G^T G`` (or EMA with ``stats_beta``)
   from the clipped gradient, stored in ``state_dtype`` (bf16 option);
2. on a slow cadence (``precond_every``) refresh the inverse quarter roots
   by eigendecomposition or coupled Newton (``root_method``) inside the
   jitted step via ``lax.cond`` — never a mid-training re-plan;
3. precondition the ADAM direction ``u = m^/(sqrt(v^)+eps)`` through the
   shape-grouped batched op, then **graft** the AdamW step size back:
   ``u_sh = P * ||u|| / ||P||``.  Identity roots therefore reproduce the
   grafted-AdamW step exactly — which is also the degradation target:
   a failed/stale/ill-conditioned refresh flips the layer's ``ok`` flag
   and the step falls back to ``u`` for the interval (guard event
   ``root_refresh_degraded``, chaos site ``root_refresh``).

Eligibility (the rank shortlist): 2-D params with both dims > 1 and
max dim <= ``max_precond_dim`` — embeddings/LM heads (vocab-sized) and
1-D norms/biases fall back to plain AdamW.  Stacked per-layer 3-D leaves
``(S, p, q)`` (the scan-over-periods layout) are S independent layers and
feed S samples into their shape group.

State layout: ``{"m", "v", "step"}`` mirror AdamW (same NamedShardings, so
FSDP/ZeRO-3 partitioning applies unchanged) plus a ``"kron"`` subtree keyed
by ``/``-joined param paths holding per-layer ``l/r`` statistics,
``lroot/rroot`` inverse roots, ``ok`` validity flags and ``stale`` step
counters — replicated (small: 2(p^2+q^2) per layer vs p*q params).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..runtime import chaos, guard, telemetry
from .adamw import OptConfig, global_norm, lr_at, opt_init, opt_update, _quantize

_TINY = 1e-30  # graft-ratio denominator floor: never divides by exact zero


@dataclass(frozen=True)
class ShampooConfig(OptConfig):
    """AdamW knobs plus the Kron-preconditioner cadence/conditioning knobs."""

    precond_every: int = 20      # inverse-root refresh cadence (steps)
    stats_beta: float = 0.95     # EMA on L/R; 1.0 = classic sum accumulation
    matrix_eps: float = 1e-2     # relative ridge (damped whitening; the
                                 # reduced-config sweep in EXPERIMENTS.md
                                 # §Optim shows small ridges over-whiten)
    root_method: str = "eigh"    # "eigh" | "newton" (coupled iteration)
    newton_iters: int = 25       # coupled-Newton iterations
    max_precond_dim: int = 1024  # rank shortlist: larger dims fall to AdamW
    min_precond_dim: int = 4     # smaller dims (stacked norms/biases) too


# ---------------------------------------------------------------------------
# Eligibility / shape grouping
# ---------------------------------------------------------------------------


def _leaf_path(keypath) -> str:
    """``/``-joined path string for a pytree leaf (checkpoint-style keys)."""
    parts = []
    for k in keypath:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _eligible(shape, cfg: ShampooConfig):
    """``(S, p, q)`` for a precondition-eligible leaf shape, else None.

    2-D ``(p, q)`` leaves are one layer (S=1); 3-D ``(S, p, q)`` leaves are
    S stacked layers (the scan-over-layer-periods parameter layout).  The
    ``min_precond_dim`` floor keeps stacked norm/bias vectors — which
    flatten to ``(n_layers, d)`` 2-D leaves — on the plain-AdamW path.
    """
    if len(shape) == 2:
        s, (p, q) = 1, shape
    elif len(shape) == 3:
        s, p, q = shape
    else:
        return None
    if min(p, q) < cfg.min_precond_dim or max(p, q) > cfg.max_precond_dim:
        return None
    return int(s), int(p), int(q)


def shape_groups(params: Any, cfg: ShampooConfig) -> dict:
    """``{(p, q): [(path, S), ...]}`` over precondition-eligible leaves.

    Deterministic (pytree flatten order).  Each group becomes ONE batched
    per-sample ``KronOp`` call of batch ``sum(S)`` in the update.
    """
    groups: dict = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        spq = _eligible(leaf.shape, cfg)
        if spq is None:
            continue
        s, p, q = spq
        groups.setdefault((p, q), []).append((_leaf_path(kp), s))
    return groups


def prewarm(params: Any, cfg: ShampooConfig) -> tuple:
    """Construct the shape-group ops before the first jitted step.

    Mirrors ``train.steps.prebuild_kron_ops``: handles land in the engine's
    bounded memo so the first trace reuses resolved plans instead of
    re-planning mid-training.  ``params`` may be real arrays or
    ``jax.eval_shape`` structs."""
    from ..core.engine import kron_precond_op

    ops = []
    for (p, q), members in shape_groups(params, cfg).items():
        b = sum(s for _, s in members)
        ops.append(kron_precond_op(p, q, b))
    return tuple(ops)


# ---------------------------------------------------------------------------
# Inverse quarter roots
# ---------------------------------------------------------------------------


def _ridge_of(s: jax.Array, eps: float) -> jax.Array:
    """Relative ridge ``eps * lambda_max-upper-bound`` (the symmetric
    inf-norm), with an absolute floor so all-zero statistics still produce
    a scalar-multiple-of-identity root — which grafting maps to exactly the
    AdamW step.  Relative-to-lambda_max caps the post-ridge condition
    number at ~1/eps, which is what keeps the f32 coupled-Newton iteration
    convergent on the rank-deficient statistics of early training (an EMA
    of a few gradient outer products)."""
    lam = jnp.max(jnp.sum(jnp.abs(s), axis=-1))
    return eps * jnp.maximum(lam, eps)


def _root_eigh(s: jax.Array, eps: float) -> tuple[jax.Array, jax.Array]:
    """``(S^{-1/4}, ok)`` by eigendecomposition of one ``(d, d)`` statistic."""
    d = s.shape[-1]
    s = (s + s.T) * 0.5
    ridge = _ridge_of(s, eps)
    w, v = jnp.linalg.eigh(s + ridge * jnp.eye(d, dtype=s.dtype))
    ok = jnp.isfinite(w).all() & jnp.isfinite(v).all() & (w[-1] > 0)
    w = jnp.maximum(w, ridge * jnp.finfo(s.dtype).eps)
    root = (v * (w ** -0.25)) @ v.T
    root = (root + root.T) * 0.5
    ok = ok & jnp.isfinite(root).all()
    return root, ok


def _root_newton(s: jax.Array, eps: float, iters: int) -> tuple[jax.Array, jax.Array]:
    """``(S^{-1/4}, ok)`` by the coupled-Newton iteration for inverse p-th
    roots (p=4): ``X <- X T, M <- T^p M`` with ``T = ((p+1)I - M)/p``,
    converging to ``(zS)^{-1/p}`` for ``z = 1/||S||``."""
    p = 4
    d = s.shape[-1]
    s = (s + s.T) * 0.5
    ridge = _ridge_of(s, eps)
    a = s + ridge * jnp.eye(d, dtype=s.dtype)
    z = 1.0 / jnp.maximum(jnp.linalg.norm(a), _TINY)
    eye = jnp.eye(d, dtype=s.dtype)

    def body(_, xm):
        x, m = xm
        t = ((p + 1) * eye - m) / p
        t2 = t @ t
        return x @ t, (t2 @ t2) @ m

    x, m = jax.lax.fori_loop(0, iters, body, (eye, z * a))
    root = x * (z ** (1.0 / p))
    root = (root + root.T) * 0.5
    ok = (
        jnp.isfinite(root).all()
        # converged: M -> I (the coupled invariant); loose gate, the graft
        # fallback catches anything this lets through
        & (jnp.abs(m - eye).max() < 0.1)
    )
    return root, ok


def inverse_quarter_root(
    stat: jax.Array, *, eps: float = 1e-2, method: str = "eigh", iters: int = 25
) -> tuple[jax.Array, jax.Array]:
    """``(S^{-1/4}, ok)`` for a stacked ``(S, d, d)`` (or ``(d, d)``) PSD
    statistic; ``ok`` is a per-layer validity flag (finite, converged)."""
    if method == "eigh":
        fn = lambda m: _root_eigh(m, eps)
    elif method == "newton":
        fn = lambda m: _root_newton(m, eps, iters)
    else:
        raise guard.PlanError(
            f"unknown root_method {method!r}: want 'eigh' or 'newton'"
        )
    if stat.ndim == 2:
        return fn(stat)
    return jax.vmap(fn)(stat)


# ---------------------------------------------------------------------------
# Preconditioner application (the KronOp hot path)
# ---------------------------------------------------------------------------


def _groups_of_kron(kron: dict) -> dict:
    """Shape groups recovered from the kron state subtree (stable order)."""
    groups: dict = {}
    for path in kron:
        s, p, _ = kron[path]["lroot"].shape
        q = kron[path]["rroot"].shape[-1]
        groups.setdefault((p, q), []).append((path, s))
    return groups


def precondition(updates: dict, kron: dict, *, looped: bool = False) -> dict:
    """Apply ``Lroot^T u Rroot`` to every layer: ``{path: (S, p, q)}`` in,
    same-keyed dict out.

    ``looped=False``: ONE per-sample batched ``KronOp`` per shape group over
    the stacked layers — the headline path.  ``looped=True``: one single-
    sample op call per layer — the reference the batched path must match
    bitwise (tiles never split the contraction dim, so the summation order
    is identical; pinned in tests/test_optim.py and raced in
    benchmarks/fig_optim.py).
    """
    from ..core.engine import kron_precond_op

    out: dict = {}
    for (p, q), members in _groups_of_kron(kron).items():
        if looped:
            op = kron_precond_op(p, q, 1)
            for path, s in members:
                u = updates[path].reshape(s, 1, 1, p * q)
                lr_ = kron[path]["lroot"]
                rr_ = kron[path]["rroot"]
                ys = [
                    op(u[i], (lr_[i : i + 1], rr_[i : i + 1]))
                    for i in range(s)
                ]
                out[path] = jnp.concatenate(ys, axis=0).reshape(s, p, q)
            continue
        b = sum(s for _, s in members)
        x = jnp.concatenate(
            [updates[path].reshape(s, 1, p * q) for path, s in members], axis=0
        )
        ls = jnp.concatenate([kron[path]["lroot"] for path, _ in members], 0)
        rs = jnp.concatenate([kron[path]["rroot"] for path, _ in members], 0)
        y = kron_precond_op(p, q, b)(x, (ls, rs)).reshape(b, p, q)
        off = 0
        for path, s in members:
            out[path] = y[off : off + s]
            off += s
    return out


# ---------------------------------------------------------------------------
# init / update
# ---------------------------------------------------------------------------


def shampoo_init(params: Any, cfg: ShampooConfig) -> dict:
    """AdamW state (m/v mirror params -> same shardings) plus the ``kron``
    subtree.  Roots start at identity with ``ok=True``: the first interval
    IS the grafted-AdamW step, so warmup needs no special casing."""
    state = opt_init(params, cfg)
    sd = jnp.dtype(cfg.state_dtype)
    kron: dict = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        spq = _eligible(leaf.shape, cfg)
        if spq is None:
            continue
        s, p, q = spq
        eye = lambda d, dt: jnp.tile(jnp.eye(d, dtype=dt)[None], (s, 1, 1))
        kron[_leaf_path(kp)] = {
            "l": jnp.zeros((s, p, p), sd),
            "r": jnp.zeros((s, q, q), sd),
            "lroot": eye(p, jnp.float32),
            "rroot": eye(q, jnp.float32),
            "ok": jnp.ones((s,), bool),
            "stale": jnp.zeros((s,), jnp.int32),
        }
    state["kron"] = kron
    return state


def _refresh_leaf(entry: dict, l32, r32, refresh, cfg: ShampooConfig):
    """New ``(lroot, rroot, ok, did, n_bad)`` for one leaf's stacked layers.

    ``lax.cond`` keeps the eigh/Newton work off the non-refresh steps; a
    chaos-injected ``NumericsError`` (site ``root_refresh``) degrades the
    leaf to its grafted-AdamW fallback for the interval — recorded in guard
    health, never crashing the step."""
    s = entry["ok"].shape[0]
    try:
        chaos.maybe_fail("root_refresh")
    except guard.NumericsError as e:
        guard.record_event("root_refresh_degraded", e)
        guard.warn_once(
            ("root_refresh", "chaos"),
            f"shampoo: inverse-root refresh failed ({e}) — layer degraded "
            f"to grafted AdamW for this interval",
        )
        return (
            entry["lroot"], entry["rroot"],
            jnp.zeros((s,), bool), jnp.zeros((s,), bool),
            jnp.zeros((), jnp.int32),
        )

    def do(args):
        l, r, lroot, rroot = args
        nl, okl = inverse_quarter_root(
            l, eps=cfg.matrix_eps, method=cfg.root_method,
            iters=cfg.newton_iters,
        )
        nr, okr = inverse_quarter_root(
            r, eps=cfg.matrix_eps, method=cfg.root_method,
            iters=cfg.newton_iters,
        )
        ok = okl & okr
        sel = ok[:, None, None]
        return (
            jnp.where(sel, nl, lroot),
            jnp.where(sel, nr, rroot),
            ok,
            ok,
            jnp.sum(~ok).astype(jnp.int32),
        )

    def keep(args):
        _, _, lroot, rroot = args
        return (
            lroot, rroot, entry["ok"], jnp.zeros((s,), bool),
            jnp.zeros((), jnp.int32),
        )

    return jax.lax.cond(
        refresh, do, keep, (l32, r32, entry["lroot"], entry["rroot"])
    )


def _report_refresh_failures(n_bad, policy: str) -> None:
    """Host-side numerics report (``jax.debug.callback`` target)."""
    n = int(n_bad)
    if n <= 0:
        return
    msg = (
        f"shampoo inverse-root refresh produced {n} invalid root pair(s) "
        f"(non-finite or non-positive statistics) — affected layers "
        f"degraded to grafted AdamW until the next refresh"
    )
    guard.record_event("root_refresh_degraded", guard.NumericsError(msg))
    if policy == "raise":
        raise guard.NumericsError(msg)
    guard.warn_once(("root_refresh", "nonfinite"), f"kron guard: {msg}")


def shampoo_update(
    grads: Any, state: dict, params: Any, cfg: ShampooConfig
) -> tuple[Any, dict, dict]:
    """Returns ``(new_params, new_state, metrics)`` — the AdamW contract.

    Ineligible leaves run the exact AdamW update; eligible leaves swap the
    Adam direction for its grafted Kron-preconditioned image (one batched
    ``KronOp`` call per shape group).
    """
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    if cfg.compress:
        compensated = jax.tree.map(lambda g, e: g + e, grads, state["err"])
        quant = jax.tree.map(lambda g: _quantize(g, cfg.compress), compensated)
        new_err = jax.tree.map(lambda c, q: c - q, compensated, quant)
        grads = quant
    else:
        new_err = state.get("err")

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sd = jnp.dtype(cfg.state_dtype)
    kron = state["kron"]
    refresh = (step == 1) | (step % max(cfg.precond_every, 1) == 0)

    # Adam moments + direction for EVERY leaf (ineligible leaves stop here).
    flat = jax.tree_util.tree_flatten_with_path(params)
    paths = [_leaf_path(kp) for kp, _ in flat[0]]
    treedef = flat[1]
    flat_p = [l for _, l in flat[0]]
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    new_m, new_v, u_adam = [], [], []
    for g, m, v in zip(flat_g, flat_m, flat_v):
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g * g * (1 - b2)
        new_m.append(m32)
        new_v.append(v32)
        u_adam.append((m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps))

    # Statistics + amortized root refresh for the eligible leaves.
    new_kron: dict = {}
    n_bad = jnp.zeros((), jnp.int32)
    with telemetry.span("optim.root_refresh", every=cfg.precond_every):
        for i, path in enumerate(paths):
            if path not in kron:
                continue
            entry = kron[path]
            s, p, _ = entry["l"].shape
            q = entry["r"].shape[-1]
            g3 = flat_g[i].reshape(s, p, q)
            ggt = jnp.einsum("spq,skq->spk", g3, g3)
            gtg = jnp.einsum("spq,spk->sqk", g3, g3)
            l32 = entry["l"].astype(jnp.float32)
            r32 = entry["r"].astype(jnp.float32)
            if cfg.stats_beta >= 1.0:
                l32, r32 = l32 + ggt, r32 + gtg
            else:
                bs = cfg.stats_beta
                l32 = l32 * bs + ggt * (1 - bs)
                r32 = r32 * bs + gtg * (1 - bs)
            lroot, rroot, ok, did, bad = _refresh_leaf(
                entry, l32, r32, refresh, cfg
            )
            n_bad = n_bad + bad
            new_kron[path] = {
                "l": l32.astype(sd),
                "r": r32.astype(sd),
                "lroot": lroot,
                "rroot": rroot,
                "ok": ok,
                "stale": jnp.where(did, 0, entry["stale"] + 1),
            }

    # Same contract as guard.check_finite: policy read at trace time, eager
    # values report synchronously (raise raises on the spot), traced values
    # report through jax.debug.callback when the step is consumed.
    policy = guard.numerics_policy()
    if policy != "off":
        if isinstance(n_bad, jax.core.Tracer):
            jax.debug.callback(
                lambda nb, p=policy: _report_refresh_failures(nb, p), n_bad
            )
        else:
            _report_refresh_failures(int(n_bad), policy)

    # Shape-grouped batched preconditioning of the Adam direction + graft.
    u_final = list(u_adam)
    if new_kron:
        with telemetry.span(
            "optim.precondition", groups=len(_groups_of_kron(new_kron))
        ):
            idx = {path: i for i, path in enumerate(paths)}
            shapes = {
                path: (e["ok"].shape[0], e["l"].shape[-1], e["r"].shape[-1])
                for path, e in new_kron.items()
            }
            updates = {
                path: u_adam[idx[path]].reshape(shapes[path])
                for path in new_kron
            }
            pre = precondition(updates, new_kron)
            for path, y3 in pre.items():
                u3 = updates[path]
                unorm = jnp.sqrt(jnp.sum(u3 * u3, axis=(1, 2)))
                pnorm = jnp.sqrt(jnp.sum(y3 * y3, axis=(1, 2)))
                grafted = y3 * (unorm / (pnorm + _TINY))[:, None, None]
                # runtime fallback: stale/failed roots OR a degenerate
                # apply (zero/non-finite norm) -> the grafted-AdamW step
                ok = (
                    new_kron[path]["ok"]
                    & jnp.isfinite(pnorm)
                    & (pnorm > 0)
                )
                u_final[idx[path]] = jnp.where(
                    ok[:, None, None], grafted, u3
                ).reshape(u_adam[idx[path]].shape)

    new_params = []
    for p_, u in zip(flat_p, u_final):
        if p_.ndim >= 2:  # decay matrices only, exactly as AdamW
            u = u + cfg.weight_decay * p_.astype(jnp.float32)
        new_params.append((p_.astype(jnp.float32) - lr * u).astype(p_.dtype))

    new_state = {
        "m": jax.tree.unflatten(treedef, [m.astype(sd) for m in new_m]),
        "v": jax.tree.unflatten(treedef, [v.astype(sd) for v in new_v]),
        "step": step,
        "kron": new_kron,
    }
    if cfg.compress:
        new_state["err"] = new_err
    stale = (
        jnp.max(jnp.concatenate([e["stale"] for e in new_kron.values()]))
        if new_kron
        else jnp.zeros((), jnp.int32)
    )
    metrics = {
        "grad_norm": gnorm,
        "lr": lr,
        "precond_stale_steps": stale,
        "precond_ok_frac": (
            jnp.mean(
                jnp.concatenate(
                    [e["ok"] for e in new_kron.values()]
                ).astype(jnp.float32)
            )
            if new_kron
            else jnp.ones(())
        ),
    }
    return (
        jax.tree.unflatten(treedef, new_params),
        new_state,
        metrics,
    )


# ---------------------------------------------------------------------------
# Dispatch + reporting
# ---------------------------------------------------------------------------


def opt_for(cfg: OptConfig) -> tuple[Callable, Callable]:
    """``(init_fn, update_fn)`` for a config: ``ShampooConfig`` routes to
    the Kron-preconditioned path, plain ``OptConfig`` to AdamW."""
    if isinstance(cfg, ShampooConfig):
        return shampoo_init, shampoo_update
    return opt_init, opt_update


def state_memory_report(opt_state: Any) -> dict:
    """``{"total_bytes", "by_dtype": {dtype: bytes}}`` over an optimizer
    state pytree — the launcher's exit-report line that makes the bf16
    ``state_dtype`` saving (and the kron subtree's footprint) visible."""
    by: dict[str, int] = {}
    for leaf in jax.tree.leaves(opt_state):
        dt = jnp.dtype(leaf.dtype)
        by[dt.name] = by.get(dt.name, 0) + int(leaf.size) * dt.itemsize
    return {"total_bytes": sum(by.values()), "by_dtype": by}


__all__ = [
    "ShampooConfig",
    "shampoo_init",
    "shampoo_update",
    "opt_for",
    "shape_groups",
    "prewarm",
    "precondition",
    "inverse_quarter_root",
    "state_memory_report",
]
