"""AdamW with cosine schedule, global-norm clipping, configurable state
dtype (ZeRO-friendly: m/v can be bf16 to fit 100B+ models on small meshes)
and gradient compression with error feedback.

State sharding: m/v mirror the parameter pytree, so the same NamedShardings
apply — with FSDP'd params the optimizer state is automatically ZeRO-3
partitioned.

Gradient compression (``compress="bf16"|"int8"``): quantize gradients with a
persistent error-feedback residual (the standard trick that keeps SGD/Adam
convergence unharmed).  On a real pod this quantization is what rides the
DP reduce-scatter (half / quarter traffic); under a single jit the reduction
is XLA-inserted, so the hook quantizes post-reduce — same numerics, comm
saving documented in DESIGN.md §8.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"      # "float32" | "bfloat16"
    compress: str | None = None        # None | "bf16" | "int8"


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(math.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def opt_init(params: Any, cfg: OptConfig) -> dict:
    sd = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, sd)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compress:
        state["err"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def _quantize(g: jax.Array, mode: str) -> jax.Array:
    if mode == "bf16":
        return g.astype(jnp.bfloat16).astype(jnp.float32)
    if mode == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127)
        return q * scale
    raise ValueError(mode)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    )


def opt_update(
    grads: Any, state: dict, params: Any, cfg: OptConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    # gradient compression with error feedback
    if cfg.compress:
        compensated = jax.tree.map(lambda g, e: g + e, grads, state["err"])
        quant = jax.tree.map(lambda g: _quantize(g, cfg.compress), compensated)
        new_err = jax.tree.map(lambda c, q: c - q, compensated, quant)
        grads = quant
    else:
        new_err = state.get("err")

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sd = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g * g * (1 - b2)
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard: skip norms/bias)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return newp, m32.astype(sd), v32.astype(sd)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])

    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.compress:
        new_state["err"] = new_err
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics


__all__ = ["OptConfig", "opt_init", "opt_update", "lr_at", "global_norm"]
