"""KronLinear: a projection stored as Kronecker factors (paper's ML-compression
use case, Table 4 rows 6-8 / Kronecker Recurrent Units).

``W = F^1 (x) ... (x) F^N`` replaces a dense ``(d_in, d_out)`` matrix with
``sum_i P_i*Q_i`` parameters; the forward pass is a FastKron Kron-Matmul.
Used by the model zoo when a config sets ``kron_ffn``/``kron_proj``.

Execution is rewired onto the ``KronOp`` engine: every apply fetches its op
from the engine's bounded signature cache (``kron_op_for``) instead of
re-entering per-call plan memos, and the ``KronLinear`` class holds spec,
params, AND the resolved op — the plan is built at init, not per apply.
Params stay plain pytrees (dicts of factor arrays) so the optimizer and
``jax.grad`` see them unchanged.
"""
from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from .engine import KronOp, kron_op_for, signature_of

# Active distributed-KronLinear scopes (innermost last).  Entered via
# ``kron_distributed``; while active, batched KronLinear applies route
# through the mesh KronOp (distributed batched rounds) on the scope's mesh.
_DIST_SCOPES: list[tuple] = []


@contextlib.contextmanager
def kron_distributed(mesh, *, data_axis="data", model_axis="model"):
    """Route batched KronLinear applies through the distributed Kron-Matmul.

    Inside the scope, ``kron_linear_apply`` on ``(B, T, d)`` activations uses
    the mesh ``KronOp`` (shared factors: B·T collapses into the data-sharded
    row axis, paper §5 round schedule) on ``mesh`` instead of the
    single-device batched launch.  Shapes the mesh cannot host (row count not
    divisible by the data axis, or no legal relocation round — the mesh op's
    constructor validates the round schedule) fall back to the local path —
    the scope is an optimization, never an error.  This is what
    ``launch/serve.py --kron-ffn --distributed`` wraps the serving loop in.

    The routing decision is made at TRACE time: enter the scope before the
    first call of a jitted function (as serve.py does).  A function traced
    outside the scope keeps its local path on later same-shape calls inside
    it (jit cache hit), and vice versa — the scope does not participate in
    the jit cache key.
    """
    _DIST_SCOPES.append((mesh, data_axis, model_axis))
    try:
        yield
    finally:
        _DIST_SCOPES.pop()


def _mesh_op_maybe(ps, qs, b, m, k, backend) -> KronOp | None:
    """The innermost scope's mesh op when it can host this shape, else None."""
    if not _DIST_SCOPES:
        return None
    mesh, data_axis, model_axis = _DIST_SCOPES[-1]
    try:
        op = kron_op_for(
            ps, qs, batch=b, shared_factors=True, mesh=mesh,
            data_axis=data_axis, model_axis=model_axis, backend=backend,
        )
    except ValueError:
        # K not divisible by the model axis, or no legal relocation round
        # for this (K, G_K) — run local.
        return None
    if (b * m) % op.g_m:
        return None
    return op


def _apply_batched_maybe_distributed(factors, x, backend, plan):
    ps, qs = signature_of(factors, shared_factors=True)
    if x.ndim == 3:
        b, m = int(x.shape[0]), int(x.shape[1])
        op = _mesh_op_maybe(ps, qs, b, m, int(x.shape[2]), backend)
        if op is not None:
            return op(x, factors)
    op = kron_op_for(
        ps, qs, batch=int(x.shape[0]), shared_factors=True, backend=backend,
        plan=plan,
    )
    return op(x, factors)


def balanced_factorization(d: int, n: int) -> tuple[int, ...]:
    """Split ``d`` into ``n`` integer factors as geometrically balanced as
    possible (largest factors first).  Exact: prod(out) == d."""
    if n <= 0:
        raise ValueError("n must be >= 1")
    if d <= 0:
        raise ValueError(f"d must be a positive dimension, got {d}")
    # prime factorization
    primes: list[int] = []
    x = d
    f = 2
    while f * f <= x:
        while x % f == 0:
            primes.append(f)
            x //= f
        f += 1
    if x > 1:
        primes.append(x)
    out = [1] * n
    for p in sorted(primes, reverse=True):
        # put the next prime on the currently-smallest bucket
        out[min(range(n), key=lambda i: out[i])] *= p
    return tuple(sorted(out, reverse=True))


@dataclass(frozen=True)
class KronLinearSpec:
    ps: tuple[int, ...]
    qs: tuple[int, ...]
    use_bias: bool = False

    @property
    def d_in(self) -> int:
        return math.prod(self.ps)

    @property
    def d_out(self) -> int:
        return math.prod(self.qs)

    @property
    def n_params(self) -> int:
        return sum(p * q for p, q in zip(self.ps, self.qs)) + (
            self.d_out if self.use_bias else 0
        )

    @classmethod
    def balanced(
        cls, d_in: int, d_out: int, n_factors: int = 2, use_bias: bool = False
    ) -> "KronLinearSpec":
        return cls(
            balanced_factorization(d_in, n_factors),
            balanced_factorization(d_out, n_factors),
            use_bias,
        )

    def op(self, **op_kwargs) -> KronOp:
        """The (shared, bounded-cached) KronOp executing this projection."""
        return kron_op_for(self.ps, self.qs, **op_kwargs)


def kron_linear_init(
    key: jax.Array, spec: KronLinearSpec, dtype=jnp.float32
) -> dict:
    """Init so the composed operator matches dense fan-in scaling:
    Var(W) = prod Var(F^i) = 1/d_in  =>  std_i = d_in^(-1/(2N))."""
    n = len(spec.ps)
    std = spec.d_in ** (-1.0 / (2 * n))
    keys = jax.random.split(key, n)
    params = {
        "factors": tuple(
            (jax.random.normal(k, (p, q)) * std).astype(dtype)
            for k, p, q in zip(keys, spec.ps, spec.qs)
        )
    }
    if spec.use_bias:
        params["bias"] = jnp.zeros((spec.d_out,), dtype)
    return params


def kron_linear_apply(
    params: dict, x: jax.Array, *, backend: str = "auto", plan="auto"
) -> jax.Array:
    if x.ndim >= 3:
        # Serving/training batches (B, ..., d_in): the batched op — shared
        # factors collapse B into the row axis, one launch for the whole
        # batch.  Inside a ``kron_distributed`` scope, 3-D activations
        # additionally route through the mesh op on the scope's mesh.
        y = _apply_batched_maybe_distributed(params["factors"], x, backend, plan)
    else:
        ps, qs = signature_of(params["factors"], shared_factors=True)
        op = kron_op_for(ps, qs, backend=backend, plan=plan)
        y = op(x, params["factors"])
    if "bias" in params:
        y = y + params["bias"]
    return y


def kron_linear_apply_batched(
    params: dict, x: jax.Array, *, backend: str = "auto", plan="auto"
) -> jax.Array:
    """Per-sample KronLinear: one factor set per batch element (per-expert
    Kronecker projections).  ``params["factors"][i]: (B, P_i, Q_i)``,
    ``x: (B, ..., d_in)``; an optional bias is ``(d_out,)`` or ``(B, d_out)``.
    """
    ps, qs = signature_of(params["factors"], shared_factors=False)
    op = kron_op_for(
        ps, qs, batch=int(x.shape[0]), shared_factors=False, backend=backend,
        plan=plan,
    )
    y = op(x, params["factors"])
    if "bias" in params:
        bias = params["bias"]
        if bias.ndim == 2:  # per-sample bias broadcasts over the lead dims
            bias = bias.reshape(bias.shape[0], *([1] * (y.ndim - 2)), -1)
        y = y + bias
    return y


class KronLinear:
    """Operator-holding KronLinear: spec + params + the resolved ``KronOp``.

    The plan is built at init (op construction), not per apply — the module
    object is what serving and GP consumers hold across requests.  ``params``
    is a plain pytree (swap it for trained weights freely); ``__call__``
    accepts ``(..., d_in)`` of any rank — leading dims collapse into the
    op's row axis.  Inside a ``kron_distributed`` scope, 3-D activations
    route through the scope's mesh op exactly like ``kron_linear_apply``.
    """

    def __init__(
        self,
        key: jax.Array,
        spec: KronLinearSpec,
        dtype=jnp.float32,
        *,
        backend: str = "auto",
        m: int | None = None,
    ):
        self.spec = spec
        self.params = kron_linear_init(key, spec, dtype)
        self.op = kron_op_for(spec.ps, spec.qs, m=m, backend=backend)

    def __call__(self, x: jax.Array, params: dict | None = None) -> jax.Array:
        params = self.params if params is None else params
        if x.ndim >= 3 and _DIST_SCOPES:
            return kron_linear_apply(params, x, backend=self.op.backend)
        y = self.op(x, params["factors"])
        if "bias" in params:
            y = y + params["bias"]
        return y


def kron_linear_materialize(params: dict) -> jax.Array:
    """Dense (d_in, d_out) equivalent — test oracle / export."""
    w = params["factors"][0]
    for f in params["factors"][1:]:
        w = jnp.kron(w, f)
    return w


__all__ = [
    "KronLinearSpec",
    "KronLinear",
    "kron_linear_init",
    "kron_linear_apply",
    "kron_linear_apply_batched",
    "kron_linear_materialize",
    "kron_distributed",
    "balanced_factorization",
]
