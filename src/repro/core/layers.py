"""KronLinear: a projection stored as Kronecker factors (paper's ML-compression
use case, Table 4 rows 6-8 / Kronecker Recurrent Units).

``W = F^1 (x) ... (x) F^N`` replaces a dense ``(d_in, d_out)`` matrix with
``sum_i P_i*Q_i`` parameters; the forward pass is a FastKron Kron-Matmul.
Used by the model zoo when a config sets ``kron_ffn``/``kron_proj``.
"""
from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from .fastkron import kron_matmul, kron_matmul_batched

# Active distributed-KronLinear scopes (innermost last).  Entered via
# ``kron_distributed``; while active, batched KronLinear applies route
# through ``kron_matmul_batched_distributed`` on the scope's mesh.
_DIST_SCOPES: list[tuple] = []


@contextlib.contextmanager
def kron_distributed(mesh, *, data_axis="data", model_axis="model"):
    """Route batched KronLinear applies through the distributed Kron-Matmul.

    Inside the scope, ``kron_linear_apply`` on ``(B, T, d)`` activations uses
    ``kron_matmul_batched_distributed`` (shared factors: B·T collapses into
    the data-sharded row axis, paper §5 round schedule) on ``mesh`` instead
    of the single-device batched launch.  Shapes the mesh cannot host (row
    count not divisible by the data axis, or no legal relocation round) fall
    back to the local path — the scope is an optimization, never an error.
    This is what ``launch/serve.py --kron-ffn --distributed`` wraps the
    serving loop in.

    The routing decision is made at TRACE time: enter the scope before the
    first call of a jitted function (as serve.py does).  A function traced
    outside the scope keeps its local path on later same-shape calls inside
    it (jit cache hit), and vice versa — the scope does not participate in
    the jit cache key.
    """
    _DIST_SCOPES.append((mesh, data_axis, model_axis))
    try:
        yield
    finally:
        _DIST_SCOPES.pop()


def _apply_batched_maybe_distributed(factors, x, backend, plan):
    if _DIST_SCOPES and x.ndim == 3:
        from .distributed import (
            _mesh_size, kron_matmul_batched_distributed, plan_rounds,
        )

        mesh, data_axis, model_axis = _DIST_SCOPES[-1]
        b, m, k = (int(d) for d in x.shape)
        g_m = _mesh_size(mesh, data_axis)
        g_k = mesh.shape[model_axis]
        if (b * m) % g_m == 0 and k % g_k == 0:

            # Pre-flight ONLY the round-schedule feasibility — any other
            # error from the distributed path stays loud.
            try:
                plan_rounds(
                    k // g_k,
                    [int(f.shape[0]) for f in reversed(factors)],
                    [int(f.shape[1]) for f in reversed(factors)],
                    g_k,
                )
            except ValueError:
                pass  # no legal round schedule for this (K, G_K) — run local
            else:
                return kron_matmul_batched_distributed(
                    x, factors, mesh, shared_factors=True,
                    data_axis=data_axis, model_axis=model_axis, backend=backend,
                )
    return kron_matmul_batched(
        x, factors, shared_factors=True, backend=backend, plan=plan
    )


def balanced_factorization(d: int, n: int) -> tuple[int, ...]:
    """Split ``d`` into ``n`` integer factors as geometrically balanced as
    possible (largest factors first).  Exact: prod(out) == d."""
    if n <= 0:
        raise ValueError("n must be >= 1")
    if d <= 0:
        raise ValueError(f"d must be a positive dimension, got {d}")
    # prime factorization
    primes: list[int] = []
    x = d
    f = 2
    while f * f <= x:
        while x % f == 0:
            primes.append(f)
            x //= f
        f += 1
    if x > 1:
        primes.append(x)
    out = [1] * n
    for p in sorted(primes, reverse=True):
        # put the next prime on the currently-smallest bucket
        out[min(range(n), key=lambda i: out[i])] *= p
    return tuple(sorted(out, reverse=True))


@dataclass(frozen=True)
class KronLinearSpec:
    ps: tuple[int, ...]
    qs: tuple[int, ...]
    use_bias: bool = False

    @property
    def d_in(self) -> int:
        return math.prod(self.ps)

    @property
    def d_out(self) -> int:
        return math.prod(self.qs)

    @property
    def n_params(self) -> int:
        return sum(p * q for p, q in zip(self.ps, self.qs)) + (
            self.d_out if self.use_bias else 0
        )

    @classmethod
    def balanced(
        cls, d_in: int, d_out: int, n_factors: int = 2, use_bias: bool = False
    ) -> "KronLinearSpec":
        return cls(
            balanced_factorization(d_in, n_factors),
            balanced_factorization(d_out, n_factors),
            use_bias,
        )


def kron_linear_init(
    key: jax.Array, spec: KronLinearSpec, dtype=jnp.float32
) -> dict:
    """Init so the composed operator matches dense fan-in scaling:
    Var(W) = prod Var(F^i) = 1/d_in  =>  std_i = d_in^(-1/(2N))."""
    n = len(spec.ps)
    std = spec.d_in ** (-1.0 / (2 * n))
    keys = jax.random.split(key, n)
    params = {
        "factors": tuple(
            (jax.random.normal(k, (p, q)) * std).astype(dtype)
            for k, p, q in zip(keys, spec.ps, spec.qs)
        )
    }
    if spec.use_bias:
        params["bias"] = jnp.zeros((spec.d_out,), dtype)
    return params


def kron_linear_apply(
    params: dict, x: jax.Array, *, backend: str = "auto", plan="auto"
) -> jax.Array:
    if x.ndim >= 3:
        # Serving/training batches (B, ..., d_in): the batched entry point —
        # shared factors collapse B into the row axis and the plan is keyed
        # on the batch size, so one launch covers the whole batch.  Inside a
        # ``kron_distributed`` scope, 3-D activations additionally route
        # through the distributed batched path on the scope's mesh.
        y = _apply_batched_maybe_distributed(params["factors"], x, backend, plan)
    else:
        y = kron_matmul(x, params["factors"], backend=backend, plan=plan)
    if "bias" in params:
        y = y + params["bias"]
    return y


def kron_linear_apply_batched(
    params: dict, x: jax.Array, *, backend: str = "auto", plan="auto"
) -> jax.Array:
    """Per-sample KronLinear: one factor set per batch element (per-expert
    Kronecker projections).  ``params["factors"][i]: (B, P_i, Q_i)``,
    ``x: (B, ..., d_in)``; an optional bias is ``(d_out,)`` or ``(B, d_out)``.
    """
    y = kron_matmul_batched(
        x, params["factors"], shared_factors=False, backend=backend, plan=plan
    )
    if "bias" in params:
        bias = params["bias"]
        if bias.ndim == 2:  # per-sample bias broadcasts over the lead dims
            bias = bias.reshape(bias.shape[0], *([1] * (y.ndim - 2)), -1)
        y = y + bias
    return y


def kron_linear_materialize(params: dict) -> jax.Array:
    """Dense (d_in, d_out) equivalent — test oracle / export."""
    w = params["factors"][0]
    for f in params["factors"][1:]:
        w = jnp.kron(w, f)
    return w


__all__ = [
    "KronLinearSpec",
    "kron_linear_init",
    "kron_linear_apply",
    "kron_linear_apply_batched",
    "kron_linear_materialize",
    "kron_distributed",
    "balanced_factorization",
]
