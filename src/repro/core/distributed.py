"""Distributed Kron-Matmul (paper §5, contribution C4) via shard_map.

Device grid ``(G_M, G_K)`` = mesh axes ``(data, model)``; ``X`` is sharded
``P(data, model)``.  Each round performs ``L = N_local`` *local* sliced
multiplies (valid while ``prod(P) | K_loc``), then relocates the distributed
intermediate with ONE ``jax.lax.all_to_all`` + a local transpose.

Why one collective suffices (DESIGN.md §5): after ``L`` local multiplies,
local column ``(q_vec, s)`` on device ``g_k`` is global column
``(q_vec*G_K + g_k)*U + s`` with ``U = K_loc / prod(P)``.  The canonical
redistribution (device d' owns a contiguous stripe) needs exactly the rows
``q_vec`` in d'-th chunk of the q-axis — so: reshape the q-axis into
``(G_K, Q^L/G_K)``, all_to_all the leading chunk axis, swap the received
device axis with the q-chunk axis, flatten.  This is the paper's
STOREGPUTILE index arithmetic expressed as a layout permutation.

Communication per device per round: ``M_loc * C_loc * (G_K-1)/G_K`` elements
with ``ceil(N/L)`` rounds — vs ``N`` rounds for the per-iteration baseline
(CTF / DISTAL), implemented here as ``kron_matmul_distributed_periter`` for
the Figure-11 comparison.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """Version shim: jax.shard_map(check_vma=...) landed after 0.4.x; fall
    back to jax.experimental.shard_map.shard_map(check_rep=...)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)

from ..kernels import ops


# ---------------------------------------------------------------------------
# Static round planning
# ---------------------------------------------------------------------------


def plan_rounds(
    k_loc: int, ps: Sequence[int], qs: Sequence[int], g_k: int,
    *, minimal: bool = False,
) -> list[int]:
    """Split the reversed factor list into rounds of local multiplies.

    Round length L must satisfy (i) ``prod(P) | K_loc`` (all slices stay
    device-local, paper's ``N_local = floor(log_P TG_K)``) and (ii)
    ``G_K | prod(Q)`` (the q-axis can be chunked over devices for the
    relocation).  FastKron (``minimal=False``) takes the LARGEST valid L —
    the paper's communication-minimizing batching; the CTF/DISTAL-style
    baseline (``minimal=True``) relocates as OFTEN as expressible, i.e. the
    smallest valid L (exactly every factor when ``G_K | Q``).  Raises if
    even L=1 is invalid.
    """
    rounds: list[int] = []
    i = 0
    n = len(ps)
    while i < n:
        best = 0
        pprod = qprod = 1
        for j in range(i, n):
            pprod *= ps[j]
            qprod *= qs[j]
            if k_loc % pprod != 0:
                break
            if qprod % g_k == 0:
                best = j - i + 1
                if minimal:
                    break
        if best == 0:
            raise ValueError(
                f"cannot relocate: need G_K={g_k} | prod(Q) for some prefix "
                f"with prod(P) | K_loc={k_loc}; got ps={ps[i:]}, qs={qs[i:]}"
            )
        # advance K_loc through the chosen round
        pprod = math.prod(ps[i : i + best])
        qprod = math.prod(qs[i : i + best])
        k_loc = (k_loc // pprod) * qprod
        rounds.append(best)
        i += best
    return rounds


def comm_elems_per_device(
    m_loc: int, k_loc: int, ps: Sequence[int], qs: Sequence[int], g_k: int,
    rounds: Sequence[int] | None = None,
) -> int:
    """Analytic all_to_all payload (elements sent per device, all rounds)."""
    ps, qs = list(ps), list(qs)
    if rounds is None:
        rounds = plan_rounds(k_loc, ps, qs, g_k)
    total = 0
    i = 0
    c = k_loc
    for r in rounds:
        pprod = math.prod(ps[i : i + r])
        qprod = math.prod(qs[i : i + r])
        c = (c // pprod) * qprod
        total += m_loc * c * (g_k - 1) // g_k
        i += r
    return total


# ---------------------------------------------------------------------------
# shard_map body
# ---------------------------------------------------------------------------


def _relocate(y: jax.Array, q_prod: int, g_k: int, model_axis: str) -> jax.Array:
    """One all_to_all relocation (see module docstring)."""
    m_loc, c = y.shape
    u = c // q_prod
    chunk = q_prod // g_k
    y4 = y.reshape(m_loc, g_k, chunk, u)
    y4 = jax.lax.all_to_all(y4, model_axis, split_axis=1, concat_axis=1)
    # axis 1 is now the sender index g_k; target local col = (q_lo*G_K+g_k)*U+s
    y4 = jnp.swapaxes(y4, 1, 2)
    return y4.reshape(m_loc, c)


def _local_multiply(y: jax.Array, f: jax.Array, backend: str) -> jax.Array:
    return ops.sliced_multiply(y, f, backend=backend)


def _dist_body(
    x_loc: jax.Array,
    factors_rev: tuple[jax.Array, ...],
    *,
    g_k: int,
    model_axis: str,
    backend: str,
    per_iteration: bool,
) -> jax.Array:
    ps = [int(f.shape[0]) for f in factors_rev]
    qs = [int(f.shape[1]) for f in factors_rev]
    k_loc = int(x_loc.shape[1])
    rounds = plan_rounds(k_loc, ps, qs, g_k, minimal=per_iteration)
    y = x_loc
    i = 0
    for r in rounds:
        qprod = 1
        for f in factors_rev[i : i + r]:
            y = _local_multiply(y, f, backend)
            qprod *= int(f.shape[1])
        if g_k > 1:
            y = _relocate(y, qprod, g_k, model_axis)
        i += r
    return y


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def kron_matmul_distributed(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mesh: Mesh,
    *,
    data_axis: str | tuple[str, ...] = "data",
    model_axis: str = "model",
    backend: str = "auto",
    per_iteration: bool = False,
) -> jax.Array:
    """Distributed ``x @ (F^1 (x) ... (x) F^N)`` on a (data, model) mesh.

    ``x``: (M, K) sharded P(data_axis, model_axis); factors replicated
    (paper §5: factors are small and live on every GPU).  Returns (M, K')
    with the same sharding.  ``per_iteration=True`` selects the CTF/DISTAL-
    style baseline that relocates after every factor.
    """
    factors = tuple(factors)
    g_k = mesh.shape[model_axis]
    body = partial(
        _dist_body,
        g_k=g_k,
        model_axis=model_axis,
        backend=backend,
        per_iteration=per_iteration,
    )
    spec_x = P(data_axis, model_axis)
    fn = _shard_map(
        lambda x_loc, fs: body(x_loc, tuple(reversed(fs))),
        mesh=mesh,
        in_specs=(spec_x, P()),
        out_specs=spec_x,
    )
    return fn(x, factors)


def sharded_input(x, mesh, data_axis="data", model_axis="model"):
    """Place (M, K) onto the grid the distributed algorithm expects."""
    return jax.device_put(x, NamedSharding(mesh, P(data_axis, model_axis)))


__all__ = [
    "kron_matmul_distributed",
    "plan_rounds",
    "comm_elems_per_device",
    "sharded_input",
]
