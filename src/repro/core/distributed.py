"""Distributed Kron-Matmul (paper §5, contribution C4) via shard_map.

Device grid ``(G_M, G_K)`` = mesh axes ``(data, model)``; ``X`` is sharded
``P(data, model)``.  Each round performs ``L = N_local`` *local* sliced
multiplies (valid while ``prod(P) | K_loc``), then relocates the distributed
intermediate with ONE ``jax.lax.all_to_all`` + a local transpose.

Why one collective suffices (DESIGN.md §5): after ``L`` local multiplies,
local column ``(q_vec, s)`` on device ``g_k`` is global column
``(q_vec*G_K + g_k)*U + s`` with ``U = K_loc / prod(P)``.  The canonical
redistribution (device d' owns a contiguous stripe) needs exactly the rows
``q_vec`` in d'-th chunk of the q-axis — so: reshape the q-axis into
``(G_K, Q^L/G_K)``, all_to_all the leading chunk axis, swap the received
device axis with the q-chunk axis, flatten.  This is the paper's
STOREGPUTILE index arithmetic expressed as a layout permutation.

Communication per device per round: ``M_loc * C_loc * (G_K-1)/G_K`` elements
with ``ceil(N/L)`` rounds — vs ``N`` rounds for the per-iteration baseline
(CTF / DISTAL), implemented here as ``kron_matmul_distributed_periter`` for
the Figure-11 comparison.

Batched rounds (beyond paper, PR 3): ``kron_matmul_batched_distributed``
carries a whole batch of B independent Kron-Matmul problems through ONE
collective round per stage.  Shared factors collapse B into the data-sharded
M axis and reuse the single-problem round schedule unchanged; per-sample
factors run a batched ``_dist_body`` whose relocation all-to-all moves a
``(B, M_local, C_local)`` slab per stage — one collective for the batch where
a per-problem loop would issue B.  The payload per device per round becomes
``B * M_loc * C_loc * (G_K-1)/G_K`` (``comm_elems_per_device(batch=B)``); the
LATENCY per round is paid once instead of B times, which is the whole win in
the small-problem regime (see EXPERIMENTS.md §Distributed-Batched).  Each
round's local multiplies are ONE chain ``StageInstr`` on the unified emitter
(``kernels/emit.py`` — the same template every other fused path runs; batched
rounds set ``t_b`` from ``autotune.make_batched_plan(g_k=...)``, which trades
it against the per-round relocation slab).

Comm/compute overlap (paper §multi-GPU; the 16-GPU 7.85x): a serial round is
``chain; all_to_all`` — the collective sits on the critical path.  The slab
pipeline splits the row axis into ``n_slabs`` independent slabs and issues
slab ``s-1``'s ``all_to_all`` while slab ``s``'s chain runs (rows are never
communicated, so slabs stay independent across EVERY round: split once before
round 0, concatenate once after the last).  Per round that exposes only one
slab's payload instead of the whole round's — ``comm_hidden_elems`` is the
analytic form of what the pipeline hides, ``KronOp.cost()`` folds it into the
critical-path estimate, and ``autotune.make_batched_plan(g_k=..)`` owns the
``n_slabs``-vs-``t_b`` trade.  Both runners take ``n_slabs`` and share ONE
slab-scheduled body (``_dist_body``; serial = the n=1 degenerate case) with a
custom VJP whose backward rounds pipeline the inverse relocations
symmetrically.  Slab boundaries are row boundaries, so the slabbed schedule
is BITWISE-identical to the serial one, forward and gradients — pinned by
``tests/overlap_distributed_driver.py``.
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """Version shim: jax.shard_map(check_vma=...) landed after 0.4.x; fall
    back to jax.experimental.shard_map.shard_map(check_rep=...)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)

from ..kernels import emit
from ..runtime import chaos, guard, telemetry


# ---------------------------------------------------------------------------
# Static round planning
# ---------------------------------------------------------------------------


def plan_rounds(
    k_loc: int, ps: Sequence[int], qs: Sequence[int], g_k: int,
    *, minimal: bool = False,
) -> list[int]:
    """Split the reversed factor list into rounds of local multiplies.

    Round length L must satisfy (i) ``prod(P) | K_loc`` (all slices stay
    device-local, paper's ``N_local = floor(log_P TG_K)``) and (ii)
    ``G_K | prod(Q)`` (the q-axis can be chunked over devices for the
    relocation).  FastKron (``minimal=False``) takes the LARGEST valid L —
    the paper's communication-minimizing batching; the CTF/DISTAL-style
    baseline (``minimal=True``) relocates as OFTEN as expressible, i.e. the
    smallest valid L (exactly every factor when ``G_K | Q``).  Raises if
    even L=1 is invalid.
    """
    rounds: list[int] = []
    i = 0
    n = len(ps)
    while i < n:
        best = 0
        pprod = qprod = 1
        for j in range(i, n):
            pprod *= ps[j]
            qprod *= qs[j]
            if k_loc % pprod != 0:
                break
            if qprod % g_k == 0:
                best = j - i + 1
                if minimal:
                    break
        if best == 0:
            raise guard.PlanError(
                f"cannot relocate: need G_K={g_k} | prod(Q) for some prefix "
                f"with prod(P) | K_loc={k_loc}; got ps={ps[i:]}, qs={qs[i:]}"
            )
        # advance K_loc through the chosen round
        pprod = math.prod(ps[i : i + best])
        qprod = math.prod(qs[i : i + best])
        k_loc = (k_loc // pprod) * qprod
        rounds.append(best)
        i += best
    return rounds


def comm_elems_per_device(
    m_loc: int, k_loc: int, ps: Sequence[int], qs: Sequence[int], g_k: int,
    rounds: Sequence[int] | None = None, *, batch: int = 1, n_slabs: int = 1,
) -> int:
    """Analytic all_to_all payload (elements sent per device, all rounds).

    ``batch``: number of independent problems riding the SAME collective
    round (``kron_matmul_batched_distributed``) — each round's slab is
    ``batch * M_loc * C * (G_K-1)/G_K`` elements.  The round COUNT does not
    change with ``batch``: that is the latency amortization the batched path
    exists for (a per-problem loop pays ``batch`` times the rounds instead).

    ``n_slabs``: accepted for signature symmetry with the slab-pipelined
    schedule and deliberately inert — slabs REPARTITION each round's payload
    (``m_loc`` rows split into equal row slabs, each relocated by its own
    all_to_all), they never change the total.  The per-slab payloads sum
    exactly to this value because slab counts are clamped to divisors of the
    row axis (``emit.effective_slabs``) and every round's column count is a
    multiple of ``G_K``; the comm-accounting test pins the identity.  What
    overlap changes is the EXPOSED fraction — see ``comm_hidden_elems``.
    """
    del n_slabs  # total is slab-invariant by construction (docstring)
    ps, qs = list(ps), list(qs)
    if rounds is None:
        rounds = plan_rounds(k_loc, ps, qs, g_k)
    total = 0
    i = 0
    c = k_loc
    for r in rounds:
        pprod = math.prod(ps[i : i + r])
        qprod = math.prod(qs[i : i + r])
        c = (c // pprod) * qprod
        total += batch * m_loc * c * (g_k - 1) // g_k
        i += r
    return total


def comm_hidden_elems(
    m_loc: int, k_loc: int, ps: Sequence[int], qs: Sequence[int], g_k: int,
    rounds: Sequence[int] | None = None, *, batch: int = 1, n_slabs: int = 1,
) -> int:
    """Overlap term of the slab pipeline: of the ``comm_elems_per_device``
    total, the elements whose transfer the schedule can hide under a
    neighbouring slab's chain compute (``KronCost.comm_hidden_elems``).

    Per round the pipeline exposes exactly one slab's payload — the last
    slab's all_to_all has nothing left to overlap — so the hidden share is
    ``payload - payload/n`` with ``n`` clamped to the row axis exactly like
    the executor clamps (``emit.effective_slabs``).  The division is exact:
    ``n | m_loc`` and ``G_K | C`` make the per-slab payload an integer, which
    is also why the slab payloads reconcile with the per-slab telemetry
    gauges in ``KronOp.profile()``.  ``n_slabs=1`` (the serial schedule) and
    ``g_k=1`` (no collectives at all) hide nothing.  This is an upper bound
    on real hardware — it assumes each slab's chain is long enough to cover a
    slab transfer; the measured tuner, not this bound, owns the final
    slabbed-vs-serial call (host-mesh collectives run at memcpy speed).
    """
    n = emit.effective_slabs(m_loc, n_slabs)
    if n <= 1 or g_k <= 1:
        return 0
    ps, qs = list(ps), list(qs)
    if rounds is None:
        rounds = plan_rounds(k_loc, ps, qs, g_k)
    hidden = 0
    i = 0
    c = k_loc
    for r in rounds:
        pprod = math.prod(ps[i : i + r])
        qprod = math.prod(qs[i : i + r])
        c = (c // pprod) * qprod
        payload = batch * m_loc * c * (g_k - 1) // g_k
        hidden += payload - payload // n
        i += r
    return hidden


# ---------------------------------------------------------------------------
# shard_map body
# ---------------------------------------------------------------------------


def _record_round_comm(shapes: Sequence[tuple], g_k: int, k: int) -> None:
    """Per-round all_to_all payload metrics — static trace-time ints, so the
    one-truthiness-check contract holds and nothing enters the traced HLO.

    ``shapes`` holds one entry PER SLAB (length 1 for the serial schedule).
    Every slab's payload is observed and gauged individually, and the round
    gauge is their sum — which equals the serial schedule's single payload
    because slabs partition the row axis exactly (no double count, no missing
    slab; the comm-accounting test asserts the identity against
    ``comm_elems_per_device``)."""
    if not telemetry.active():
        return
    n = len(shapes)
    total = 0
    for s, shape in enumerate(shapes):
        elems = math.prod(int(d) for d in shape) * (g_k - 1) // g_k
        total += elems
        telemetry.observe("comm_elems_per_device", elems)
        if n > 1:
            telemetry.gauge_set(f"comm.round{k}.slab{s}.elems_per_device", elems)
    telemetry.gauge_set(f"comm.round{k}.elems_per_device", total)


def _relocate(y: jax.Array, q_prod: int, g_k: int, model_axis: str) -> jax.Array:
    """One all_to_all relocation (see module docstring).  The index
    arithmetic lives in ``_relocate_batched``; the single-problem case is
    the batch-of-one view (the extra reshape is a layout no-op under jit)."""
    return _relocate_batched(y[None], q_prod, g_k, model_axis)[0]


def _local_multiply_round(
    y: jax.Array, fs: Sequence[jax.Array], backend: str, t_b: int | None
) -> jax.Array:
    """One round's local multiplies as ONE chain instruction on the unified
    emitter — the same template every other fused path runs.  ``t_b=None``
    is the single-problem body (2-D operands); an int selects the batch-grid
    kernels with ``t_b`` samples per block, tiles re-fitted per round because
    the round grouping follows the COMM schedule, not the compute plan."""
    fs = tuple(fs)
    off = 0 if t_b is None else 1
    ps = [int(f.shape[off]) for f in fs]
    qs = [int(f.shape[off + 1]) for f in fs]
    tb, t_m, t_k = _round_tiles(
        int(y.shape[-2]), int(y.shape[-1]), ps, qs, t_b or 1
    )
    instr = emit.StageInstr(
        kind=emit.MULTIPLY, ps=tuple(ps), qs=tuple(qs), t_m=t_m, t_k=t_k,
        t_b=None if t_b is None else tb,
    )
    try:
        chaos.maybe_fail("round_chain")
        return emit.run_stage(y, fs, instr, backend=backend)
    except guard.KronError as e:
        # Round chain cannot fit VMEM even at the degenerate tile (huge
        # Q-growth rounds): fall back to per-factor multiplies — the
        # pre-refactor behavior of the single-problem rounds, batch-
        # polymorphic through the engine's conservative fallback.  Same
        # contraction, same one-collective-per-round schedule (the fallback
        # is strictly local) — the property pinned by the chaos driver.
        from .engine import _sliced_batched

        guard.record_event("round_per_factor", e)
        guard.warn_once(
            ("round_per_factor", tuple(ps), tuple(qs)),
            f"kron guard: round chain {ps}x{qs} degraded to per-factor "
            f"multiplies ({type(e).__name__}: {e})",
        )
        for f in fs:
            y = _sliced_batched(y, f, backend)
        return y


# ---------------------------------------------------------------------------
# Shared (single AND batched) slab-scheduled shard_map body
# ---------------------------------------------------------------------------


def _relocate_batched(y: jax.Array, q_prod: int, g_k: int, model_axis: str) -> jax.Array:
    """One all_to_all relocation for the WHOLE batch (the canonical
    implementation — ``_relocate`` is the batch-of-one view).

    The collective moves one ``(B, M_loc, C)`` slab per round instead of B
    separate ``(M_loc, C)`` payloads — same bytes, 1/B the latency."""
    chaos.maybe_fail("collective")
    b, m_loc, c = y.shape
    u = c // q_prod
    chunk = q_prod // g_k
    y5 = y.reshape(b, m_loc, g_k, chunk, u)
    y5 = jax.lax.all_to_all(y5, model_axis, split_axis=2, concat_axis=2)
    # axis 2 is now the sender index g_k; target local col = (q_lo*G_K+g_k)*U+s
    y5 = jnp.swapaxes(y5, 2, 3)
    return y5.reshape(b, m_loc, c)


def _round_tiles(
    m: int, k: int, ps: Sequence[int], qs: Sequence[int], t_b: int
) -> tuple[int, int, int]:
    """(t_b, t_m, t_k) for one round chain that provably fits the unified
    kernel's VMEM legality (``t_b * t_m * t_k * growth <= budget``).
    The round grouping follows the COMM schedule, not the compute plan's
    stages, so tiles are re-fitted here; prefers the planner's ``t_b`` and
    trades it down only if even (t_m=1, t_s=1) cannot hold it."""
    from ..kernels.emit import VMEM_BUDGET_ELEMS, fused_growth

    pprod = math.prod(ps)
    s = k // pprod
    growth = fused_growth(list(ps), list(qs), None)
    for tb in sorted({d for d in range(1, t_b + 1) if t_b % d == 0}, reverse=True):
        t_m = min(8, m)
        while m % t_m:
            t_m -= 1
        while t_m >= 1:
            fits = [
                d for d in range(1, s + 1)
                if s % d == 0 and tb * t_m * d * pprod * growth <= VMEM_BUDGET_ELEMS
            ]
            if fits:
                return tb, t_m, max(fits) * pprod
            t_m = max((d for d in range(1, t_m) if m % d == 0), default=0)
    return 1, 1, pprod  # degenerate problems; XLA path ignores tiles anyway


def _relocate_batched_t(
    y: jax.Array, q_prod: int, g_k: int, model_axis: str
) -> jax.Array:
    """Linear transpose of ``_relocate_batched`` — also its inverse, since a
    relocation is a pure layout permutation: undo the chunk flatten, undo the
    swap, and apply the all_to_all again (``split_axis == concat_axis`` makes
    it an involution).  The backward rounds run this in place of the forward
    relocation, so the slab pipeline overlaps symmetrically under grad."""
    b, m_loc, c = y.shape
    u = c // q_prod
    chunk = q_prod // g_k
    y5 = y.reshape(b, m_loc, chunk, g_k, u)
    y5 = jnp.swapaxes(y5, 2, 3)
    y5 = jax.lax.all_to_all(y5, model_axis, split_axis=2, concat_axis=2)
    return y5.reshape(b, m_loc, c)


def _relocate_slab(
    y: jax.Array, q_prod: int, g_k: int, model_axis: str, n_slabs: int
) -> jax.Array:
    """Relocate ONE slab (2-D single-problem or 3-D batched).  Pipelined
    schedules (``n_slabs > 1``) get their own chaos site so tests can fail a
    single slab's collective mid-round and pin the slabbed → serial-rounds →
    local degradation ladder."""
    if n_slabs > 1:
        chaos.maybe_fail("slab_collective")
    if y.ndim == 2:
        return _relocate(y, q_prod, g_k, model_axis)
    return _relocate_batched(y, q_prod, g_k, model_axis)


def _relocate_slab_t(
    g: jax.Array, q_prod: int, g_k: int, model_axis: str, n_slabs: int
) -> jax.Array:
    """Transposed twin of ``_relocate_slab`` for the backward rounds."""
    if n_slabs > 1:
        chaos.maybe_fail("slab_collective")
    if g.ndim == 2:
        return _relocate_batched_t(g[None], q_prod, g_k, model_axis)[0]
    return _relocate_batched_t(g, q_prod, g_k, model_axis)


def _slab_round(
    slabs: list[jax.Array],
    fs: tuple[jax.Array, ...],
    qprod: int,
    g_k: int,
    model_axis: str,
    backend: str,
    t_b: int | None,
    k: int,
    *,
    record: bool = True,
) -> list[jax.Array]:
    """One slab-scheduled round: run slab ``s``'s chain, and only THEN issue
    slab ``s-1``'s all_to_all — the two are data-independent, so the compiled
    schedule is free to run the collective under the neighbouring slab's
    ``StageInstr`` chain (the double-buffer pipeline; the serial schedule is
    the ``n=1`` degenerate case, which traces to exactly the pre-slab HLO).
    Rows are never communicated, so the returned slabs remain valid
    independent chains for the NEXT round — no per-round re-split."""
    n = len(slabs)
    outs: list[jax.Array] = []
    shapes: list[tuple] = []
    pending = None
    for s in range(n):
        y_s = _local_multiply_round(slabs[s], fs, backend, t_b)
        shapes.append(tuple(int(d) for d in y_s.shape))
        if pending is not None:
            outs.append(_relocate_slab(pending, qprod, g_k, model_axis, n))
        if g_k > 1:
            pending = y_s
        else:
            outs.append(y_s)
    if pending is not None:
        outs.append(_relocate_slab(pending, qprod, g_k, model_axis, n))
    if g_k > 1 and record:
        _record_round_comm(shapes, g_k, k)
    return outs


def _dist_body(
    x_loc: jax.Array,
    factors_rev: tuple[jax.Array, ...],
    *,
    g_k: int,
    model_axis: str,
    backend: str,
    per_iteration: bool,
    t_b: int | None,
    n_slabs: int,
    record: bool = True,
) -> jax.Array:
    """The ONE shard_map body behind both mesh runners: ``t_b=None`` is the
    single-problem schedule (2-D operands, shared factors), an int selects
    the batched per-sample schedule (3-D operands, batch-grid kernels).  The
    row axis is split into ``n_slabs`` slabs ONCE, every round runs the slab
    pipeline (``_slab_round``), and the slabs are concatenated once at the
    end — row-slab boundaries make the result bitwise-identical to the
    serial schedule for any ``n_slabs``."""
    off = 0 if t_b is None else 1
    ps = [int(f.shape[off]) for f in factors_rev]
    qs = [int(f.shape[off + 1]) for f in factors_rev]
    rounds = plan_rounds(int(x_loc.shape[-1]), ps, qs, g_k, minimal=per_iteration)
    n = emit.effective_slabs(int(x_loc.shape[-2]), n_slabs)
    slabs = emit.split_slabs(x_loc, n, axis=-2)
    i = 0
    for k, r in enumerate(rounds):
        fs = tuple(factors_rev[i : i + r])
        qprod = math.prod(qs[i : i + r])
        with telemetry.span(
            "round", k=k, n_factors=r, n_slabs=n, batched=t_b is not None
        ):
            slabs = _slab_round(
                slabs, fs, qprod, g_k, model_axis, backend, t_b, k,
                record=record,
            )
        i += r
    return slabs[0] if n == 1 else jnp.concatenate(slabs, axis=-2)


def _dist_body_bwd(
    x_loc: jax.Array,
    factors_rev: tuple[jax.Array, ...],
    g: jax.Array,
    *,
    g_k: int,
    model_axis: str,
    backend: str,
    per_iteration: bool,
    t_b: int | None,
    n_slabs: int,
) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """Backward of ``_dist_body`` with the SAME slab pipeline run in reverse:
    per round, slab ``s+1``'s inverse all_to_all is issued while slab ``s``'s
    transposed chain runs, mirroring the forward overlap.

    Bitwise parity with the serial schedule's gradients is structural, not
    numerical luck: per slab the walk only ever computes row-parallel
    transposed multiplies (exact under row splits), and each FACTOR gradient
    is ONE full-row ``_sliced_vjp_factor`` contraction over the concatenated
    slab inputs/cotangents — never a per-slab partial sum, whose float
    association would differ from serial.  Per-round inputs are
    re-materialized from ``x_loc`` (CSE'd against the primal under jit — the
    ``engine._program_bwd`` remat idiom) with telemetry recording off so a
    grad trace does not double-count comm observations."""
    from .engine import _sliced_batched, _sliced_t_batched, _sliced_vjp_factor

    off = 0 if t_b is None else 1
    qs = [int(f.shape[off + 1]) for f in factors_rev]
    rounds = plan_rounds(
        int(x_loc.shape[-1]),
        [int(f.shape[off]) for f in factors_rev],
        qs,
        g_k,
        minimal=per_iteration,
    )
    n = emit.effective_slabs(int(x_loc.shape[-2]), n_slabs)
    slabs = emit.split_slabs(x_loc, n, axis=-2)
    meta: list[tuple[int, tuple, int]] = []
    per_round_in: list[list[jax.Array]] = []
    i = 0
    for k, r in enumerate(rounds):
        fs = tuple(factors_rev[i : i + r])
        qprod = math.prod(qs[i : i + r])
        meta.append((i, fs, qprod))
        per_round_in.append(slabs)
        if k + 1 < len(rounds):
            slabs = _slab_round(
                slabs, fs, qprod, g_k, model_axis, backend, t_b, k,
                record=False,
            )
        i += r

    dfs: list[jax.Array | None] = [None] * len(factors_rev)
    g_slabs = emit.split_slabs(g, n, axis=-2)
    for k in reversed(range(len(rounds))):
        i0, fs, qprod = meta[k]
        with telemetry.span(
            "round_bwd", k=k, n_factors=len(fs), n_slabs=n,
            batched=t_b is not None,
        ):
            def _undo(gs):
                if g_k > 1:
                    return _relocate_slab_t(gs, qprod, g_k, model_axis, n)
                return gs

            inp = [[None] * n for _ in fs]
            cot = [[None] * n for _ in fs]
            new_g: list[jax.Array | None] = [None] * n
            pending = _undo(g_slabs[0])
            for s in range(n):
                # issue slab s+1's inverse relocation first, THEN retire
                # slab s's transposed chain — the mirror of _slab_round
                nxt = _undo(g_slabs[s + 1]) if s + 1 < n else None
                ins = [per_round_in[k][s]]
                for f in fs[:-1]:
                    ins.append(_sliced_batched(ins[-1], f, backend))
                gg = pending
                for idx in reversed(range(len(fs))):
                    inp[idx][s] = ins[idx]
                    cot[idx][s] = gg
                    gg = _sliced_t_batched(gg, fs[idx], backend)
                new_g[s] = gg
                pending = nxt
            for idx, f in enumerate(fs):
                u = inp[idx][0] if n == 1 else jnp.concatenate(inp[idx], axis=-2)
                gg = cot[idx][0] if n == 1 else jnp.concatenate(cot[idx], axis=-2)
                p, q = int(f.shape[-2]), int(f.shape[-1])
                dfs[i0 + idx] = _sliced_vjp_factor(u, gg, p, q).astype(f.dtype)
            g_slabs = new_g
    dx = g_slabs[0] if n == 1 else jnp.concatenate(g_slabs, axis=-2)
    return dx.astype(x_loc.dtype), tuple(dfs)


@lru_cache(maxsize=64)
def _rounds_fn(
    g_k: int,
    model_axis: str,
    backend: str,
    per_iteration: bool,
    t_b: int | None,
    n_slabs: int,
):
    """Custom-VJP round loop for one static config — cached so repeated mesh
    calls reuse one traced callable (the ``engine._kron_fn`` idiom).  The VJP
    exists to keep the BACKWARD rounds slab-pipelined too: plain autodiff
    would transpose the forward graph op-by-op, serializing each inverse
    collective against the transposed chain that produced its operand."""
    cfg = dict(
        g_k=g_k, model_axis=model_axis, backend=backend,
        per_iteration=per_iteration, t_b=t_b, n_slabs=n_slabs,
    )

    @jax.custom_vjp
    def rounds(x_loc, factors_rev):
        return _dist_body(x_loc, factors_rev, **cfg)

    def fwd(x_loc, factors_rev):
        return _dist_body(x_loc, factors_rev, **cfg), (x_loc, factors_rev)

    def bwd(res, g):
        x_loc, factors_rev = res
        return _dist_body_bwd(x_loc, factors_rev, g, **cfg)

    rounds.defvjp(fwd, bwd)
    return rounds


# ---------------------------------------------------------------------------
# Mesh runners (the engine's distributed execution layer) + legacy shims
# ---------------------------------------------------------------------------


def run_distributed_rounds(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mesh: Mesh,
    *,
    data_axis: str | tuple[str, ...] = "data",
    model_axis: str = "model",
    backend: str = "auto",
    per_iteration: bool = False,
    n_slabs: int = 1,
) -> jax.Array:
    """Distributed ``x @ (F^1 (x) ... (x) F^N)`` on a (data, model) mesh —
    the single-problem round schedule the ``KronOp`` mesh path executes.

    ``x``: (M, K) sharded P(data_axis, model_axis); factors replicated
    (paper §5: factors are small and live on every GPU).  Returns (M, K')
    with the same sharding.  ``per_iteration=True`` selects the CTF/DISTAL-
    style baseline that relocates after every factor.  ``n_slabs > 1``
    pipelines each round's all_to_all under the neighbouring row slab's
    chain (bitwise-identical output, clamped to divisors of the local row
    count); the default is the serial schedule — ``KronOp`` owns the choice
    through the planner.
    """
    factors = tuple(factors)
    g_k = mesh.shape[model_axis]
    body = _rounds_fn(
        g_k, model_axis, backend, per_iteration, None, int(n_slabs)
    )
    spec_x = P(data_axis, model_axis)
    fn = _shard_map(
        lambda x_loc, fs: body(x_loc, tuple(reversed(fs))),
        mesh=mesh,
        in_specs=(spec_x, P()),
        out_specs=spec_x,
    )
    return fn(x, factors)


def _mesh_size(mesh: Mesh, axis: str | tuple[str, ...]) -> int:
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def run_batched_distributed_rounds(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mesh: Mesh,
    *,
    t_b: int = 1,
    data_axis: str | tuple[str, ...] = "data",
    model_axis: str = "model",
    backend: str = "auto",
    per_iteration: bool = False,
    n_slabs: int = 1,
) -> jax.Array:
    """Per-sample-factors batched distributed rounds — the ``KronOp`` mesh
    path for ``shared_factors=False`` (the shared mode collapses B into the
    sharded row axis and runs ``run_distributed_rounds``).

    ``x``: (B, M, K) sharded ``P(None, data_axis, model_axis)``; per-sample
    factors ``F^i: (B, P_i, Q_i)`` replicated.  Each round's local multiplies
    are one batch-grid chain instruction on the emitter (``t_b``
    samples per block) and each round's relocation is ONE all_to_all moving
    the ``(B·M_local, C_local)`` slab — where a per-problem loop would issue
    B collectives per round.  ``n_slabs > 1`` splits the per-sample row axis
    into slabs and pipelines each slab's all_to_all under the next slab's
    chain (``rounds * n_slabs`` collectives carrying the same total payload).
    The plan (``t_b`` and ``n_slabs``) is resolved by the op via
    ``autotune.make_batched_plan(g_k=...)``.
    """
    factors = tuple(factors)
    if x.ndim != 3:
        raise ValueError(f"x must be (B, M, K), got shape {x.shape}")
    if any(f.ndim != 3 for f in factors):
        raise ValueError("expects 3-D (B, P_i, Q_i) per-sample factors")
    b = int(x.shape[0])
    for f in factors:
        if int(f.shape[0]) != b:
            raise ValueError(f"factor batch {f.shape[0]} != x batch {b}")
    body = _rounds_fn(
        mesh.shape[model_axis], model_axis, backend, per_iteration,
        int(t_b), int(n_slabs),
    )
    spec_x = P(None, data_axis, model_axis)
    fn = _shard_map(
        lambda x_loc, fs: body(x_loc, tuple(reversed(fs))),
        mesh=mesh,
        in_specs=(spec_x, P()),
        out_specs=spec_x,
    )
    return fn(x, factors)


def kron_matmul_distributed(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mesh: Mesh,
    *,
    data_axis: str | tuple[str, ...] = "data",
    model_axis: str = "model",
    backend: str = "auto",
    per_iteration: bool = False,
) -> jax.Array:
    """DEPRECATED shim over ``KronOp(ps, qs, mesh=mesh)``: distributed
    Kron-Matmul on a (data, model) mesh (see ``run_distributed_rounds``)."""
    from . import engine

    engine.warn_deprecated("kron_matmul_distributed", "KronOp(ps, qs, mesh=mesh)")
    factors = tuple(factors)
    ps, qs = engine.signature_of(factors, shared_factors=True)
    op = engine.kron_op_for(
        ps, qs, mesh=mesh, data_axis=data_axis, model_axis=model_axis,
        backend=backend, per_iteration=per_iteration,
    )
    return op(x, factors)


def kron_matmul_batched_distributed(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mesh: Mesh,
    *,
    shared_factors: bool,
    data_axis: str | tuple[str, ...] = "data",
    model_axis: str = "model",
    backend: str = "auto",
    per_iteration: bool = False,
    plan="auto",
) -> jax.Array:
    """DEPRECATED shim over ``KronOp(ps, qs, batch=B, shared_factors=...,
    mesh=mesh)``: ``B`` independent distributed Kron-Matmuls with ONE
    collective round per stage for the whole batch.

    ``x``: (B, M, K) sharded ``P(None, data_axis, model_axis)``
    (``sharded_input_batched``).  shared_factors=True collapses B into the
    data-sharded M axis (requires ``G_M | B*M``); shared_factors=False runs
    the batch-grid kernels inside ``run_batched_distributed_rounds`` under a
    plan from ``autotune.make_batched_plan(g_k=G_K)`` (``plan=None``: untiled
    ``t_b=1``; or pass an explicit ``KronPlan``).
    """
    from . import engine

    engine.warn_deprecated(
        "kron_matmul_batched_distributed",
        "KronOp(ps, qs, batch=B, shared_factors=..., mesh=mesh)",
    )
    factors = tuple(factors)
    if x.ndim != 3:
        raise ValueError(f"x must be (B, M, K), got shape {x.shape}")
    ps, qs = engine.signature_of(factors, shared_factors=shared_factors)
    op = engine.kron_op_for(
        ps, qs, batch=int(x.shape[0]), shared_factors=shared_factors,
        mesh=mesh, data_axis=data_axis, model_axis=model_axis,
        backend=backend, per_iteration=per_iteration, plan=plan,
    )
    return op(x, factors)


def sharded_input(x, mesh, data_axis="data", model_axis="model"):
    """Place (M, K) onto the grid the distributed algorithm expects."""
    return jax.device_put(x, NamedSharding(mesh, P(data_axis, model_axis)))


def sharded_input_batched(x, mesh, data_axis="data", model_axis="model"):
    """Place (B, M, K) onto the grid ``kron_matmul_batched_distributed``
    expects: batch replicated, rows over ``data_axis``, cols over
    ``model_axis``."""
    return jax.device_put(x, NamedSharding(mesh, P(None, data_axis, model_axis)))


__all__ = [
    "kron_matmul_distributed",
    "kron_matmul_batched_distributed",
    "run_distributed_rounds",
    "run_batched_distributed_rounds",
    "plan_rounds",
    "comm_elems_per_device",
    "comm_hidden_elems",
    "sharded_input",
    "sharded_input_batched",
]
