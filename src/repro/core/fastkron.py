"""Public FastKron API: planned, differentiable Kron-Matmul.

``kron_matmul(x, factors)`` computes ``x @ (F^1 (x) F^2 (x) ... (x) F^N)``
for ``x: (..., prod P_i)`` and ``F^i: (P_i, Q_i)`` without materializing the
Kronecker matrix, using the FastKron sliced-multiply algorithm (paper §3)
with an execution plan (fusion grouping C3 + tile sizes C5 + beyond-paper
pre-kronization) chosen by ``core.autotune.make_plan``.
``kron_matmul_batched`` runs B independent problems in one launch; the
multi-device entry points (``kron_matmul_distributed`` and its batched
sibling ``kron_matmul_batched_distributed``) live in ``core.distributed``.
User-facing reference: docs/api.md; layer map: docs/architecture.md.

Differentiation: the VJP of a Kron-Matmul is itself Kron-shaped —
``dX = dY @ (F^1 (x) ... (x) F^N)^T`` — so the backward pass reuses the same
sliced-multiply machinery with per-stage transposed contractions, rather than
relying on autodiff tracing through ``pallas_call``.  When a plan is active
the backward is PLAN-DRIVEN end to end: stage inputs are rematerialized with
the forward plan's fused stages (CSE'd against the forward pass under jit),
the input cotangent runs through the fused transposed kernels
(``ops.fused_kron_t`` / ``ops.fused_kron_bwd``), and factor gradients are
computed inside the same fused stage backward — no unfused per-factor XLA
loop.  ``symbolic_zeros`` perturbation flags skip factor-gradient work
entirely when only ``dx`` is needed (inference-style ``jax.grad`` over x).
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import autotune
from .autotune import KronPlan, Stage, TileConfig
from .kron import KronProblem


# ---------------------------------------------------------------------------
# Stage execution (forward)
# ---------------------------------------------------------------------------


def _prekron_factor(stage_factors: Sequence[jax.Array]) -> jax.Array:
    # stage_factors are in APPLICATION order (rev[i], rev[i+1], ...);
    # the explicit Kronecker product must be formed in PROBLEM order,
    # i.e. kron(rev[i+1], rev[i]):  x @ (A (x) B) applies B first.
    f = stage_factors[-1]
    for g in reversed(stage_factors[:-1]):
        f = jnp.kron(f, g)
    return f


def _stage_forward(
    y: jax.Array, stage_factors: Sequence[jax.Array], stage: Stage, backend: str
) -> jax.Array:
    if stage.prekron:
        f = _prekron_factor(stage_factors)
        return ops.sliced_multiply(y, f, backend=backend, tiles=stage.tiles.as_tuple)
    if len(stage_factors) == 1:
        return ops.sliced_multiply(
            y, stage_factors[0], backend=backend, tiles=stage.tiles.as_tuple
        )
    pprod = math.prod(int(f.shape[0]) for f in stage_factors)
    t_k = stage.tiles.t_s * pprod
    return ops.fused_kron(
        y, stage_factors, backend=backend, t_m=stage.tiles.t_m, t_k=t_k,
        t_qs=stage.t_qs,
    )


# ---------------------------------------------------------------------------
# VJP building blocks
# ---------------------------------------------------------------------------


def _sliced_vjp_input(g: jax.Array, f: jax.Array, backend: str = "xla") -> jax.Array:
    """du for y = sliced(u, f):  du[m, s*P+p] = sum_q g[m, q*S+s] f[p, q].

    This is the TRANSPOSED sliced multiply — itself Kron-shaped, with its
    own Pallas kernel (kernels/kron_sliced_t.py) on TPU."""
    return ops.sliced_multiply_t(g, f, backend=backend)


def _sliced_vjp_factor(u: jax.Array, g: jax.Array, p: int, q: int) -> jax.Array:
    """df[p,q] = sum_{m,s} u[m, s*P+p] g[m, q*S+s]."""
    m, k = u.shape
    s = k // p
    acc = jnp.promote_types(g.dtype, jnp.float32)
    u3 = u.reshape(m, s, p)
    g3 = g.reshape(m, q, s)
    return jnp.einsum("msp,mqs->pq", u3.astype(acc), g3.astype(acc))


def _prekron_vjp(dK: jax.Array, stage_factors: Sequence[jax.Array]) -> tuple:
    """Split the cotangent of kron(rev[i+1], ..., rev[i]) back into per-factor
    cotangents, in ``stage_factors`` (application) order."""
    if len(stage_factors) == 1:
        return (dK,)
    a = stage_factors[0]
    b = _prekron_factor(stage_factors[1:])
    pa, qa = int(a.shape[0]), int(a.shape[1])
    pb, qb = int(b.shape[0]), int(b.shape[1])
    acc = jnp.promote_types(dK.dtype, jnp.float32)
    dk4 = dK.reshape(pb, pa, qb, qa).astype(acc)
    da = jnp.einsum("bpcq,bc->pq", dk4, b.astype(acc))
    db = jnp.einsum("bpcq,pq->bc", dk4, a.astype(acc))
    return (da,) + _prekron_vjp(db, stage_factors[1:])


# ---------------------------------------------------------------------------
# Planned, differentiable core
# ---------------------------------------------------------------------------


def _default_bwd_stages(plan: KronPlan) -> tuple[Stage, ...]:
    return plan.bwd_stages or tuple(reversed(plan.stages))


def _stage_bwd_per_factor(u, g, stage_factors, backend):
    """Stage backward as per-factor planned ops — the fallback when the
    one-kernel fused backward cannot hold the stage's growth in VMEM (e.g.
    Q-tiled stages: the forward tiles Q, but the backward needs every
    factor-gradient pair).  Still stage-local and dispatch-routed."""
    inputs = [u]
    for f in stage_factors[:-1]:
        inputs.append(ops.sliced_multiply(inputs[-1], f, backend=backend))
    dfs = [None] * len(stage_factors)
    for idx in reversed(range(len(stage_factors))):
        f = stage_factors[idx]
        p, q = int(f.shape[0]), int(f.shape[1])
        dfs[idx] = _sliced_vjp_factor(inputs[idx], g, p, q)
        g = ops.sliced_multiply_t(g, f, backend=backend)
    return g, tuple(dfs)


def _planned_bwd(plan: KronPlan, backend: str, x, factors, g, f_pert: bool):
    """Execute the backward plan: returns (dx, dfs_by_rev_id or None)."""
    rev = tuple(reversed(factors))
    stage_factors = [tuple(rev[i] for i in st.factor_ids) for st in plan.stages]
    # Stage inputs rematerialized with the FORWARD plan (fused stages, not an
    # unfused per-factor loop); under jit XLA CSEs these against the primal
    # forward chain, so the remat is effectively free at stage granularity.
    stage_inputs = []
    y = x
    for idx, (st, sf) in enumerate(zip(plan.stages, stage_factors)):
        stage_inputs.append(y)
        if idx + 1 < len(plan.stages):
            y = _stage_forward(y, sf, st, backend)
    bwd_sts = _default_bwd_stages(plan)
    dfs_by_id: dict[int, jax.Array] = {}
    for rev_idx in range(len(plan.stages) - 1, -1, -1):
        st = plan.stages[rev_idx]
        bst = bwd_sts[len(plan.stages) - 1 - rev_idx]
        sf = stage_factors[rev_idx]
        u = stage_inputs[rev_idx]
        pprod = math.prod(int(f.shape[0]) for f in sf)
        t_k = st.tiles.t_s * pprod
        if st.prekron:
            fk = _prekron_factor(sf)
            if f_pert:
                try:
                    g, (dk,) = ops.fused_kron_bwd(
                        u, g, (fk,), backend=backend, t_m=bst.tiles.t_m
                    )
                except ValueError:
                    g, (dk,) = _stage_bwd_per_factor(u, g, (fk,), backend)
                for fid, d in zip(st.factor_ids, _prekron_vjp(dk, sf)):
                    dfs_by_id[fid] = d
            else:
                g = ops.sliced_multiply_t(
                    g, fk, backend=backend, tiles=bst.tiles.as_tuple
                )
        elif f_pert:
            try:
                g, dfs = ops.fused_kron_bwd(
                    u, g, sf, backend=backend, t_m=bst.tiles.t_m, t_k=t_k
                )
            except ValueError:
                # Fused backward tile exceeds VMEM (Q-tiled forward stages
                # have no Q relief on the gradient-pair side) — run the
                # stage per factor, still through planned dispatch.
                g, dfs = _stage_bwd_per_factor(u, g, sf, backend)
            for fid, d in zip(st.factor_ids, dfs):
                dfs_by_id[fid] = d
        elif len(sf) == 1:
            g = ops.sliced_multiply_t(
                g, sf[0], backend=backend, tiles=bst.tiles.as_tuple
            )
        else:
            g = ops.fused_kron_t(
                g, sf, backend=backend, t_m=bst.tiles.t_m, t_k=t_k, t_qs=st.t_qs
            )
    return g, (dfs_by_id if f_pert else None)


@functools.lru_cache(maxsize=None)
def _build_kron_fn(n: int, backend: str, plan: KronPlan | None):
    """Returns a custom-vjp function of (x, factors_tuple) for N factors."""

    def fwd_only(x, factors):
        # Application order: last factor first (Algorithm 1).
        rev = tuple(reversed(factors))
        y = x
        if plan is None:
            for f in rev:
                y = ops.sliced_multiply(y, f, backend=backend)
            return y
        for stage in plan.stages:
            y = _stage_forward(y, [rev[i] for i in stage.factor_ids], stage, backend)
        return y

    @jax.custom_vjp
    def kron_fn(x, factors):
        return fwd_only(x, factors)

    def kron_fwd(x_p, factors_p):
        x = x_p.value
        factors = tuple(f.value for f in factors_p)
        # Residuals: just (x, factors) plus static perturbation flags.  The
        # per-factor intermediates are recomputed in bwd (rematerialization):
        # storing them would cost ~N*M*K extra memory, while recompute adds
        # <= 1x forward FLOPs and is CSE'd against the primal under jit.
        f_pert = any(bool(f.perturbed) for f in factors_p)
        return fwd_only(x, factors), (x, factors, f_pert)

    def kron_bwd(res, g):
        x, factors, f_pert = res
        if isinstance(g, jax.custom_derivatives.SymbolicZero):
            return jnp.zeros_like(x), tuple(jnp.zeros_like(f) for f in factors)
        rev = tuple(reversed(factors))
        if plan is None:
            # Paper-faithful unfused loop (the C1 baseline's backward): one
            # transposed sliced multiply + factor contraction per factor.
            inputs = []
            y = x
            for i, f in enumerate(rev):
                inputs.append(y)
                if i + 1 < len(rev):
                    y = ops.sliced_multiply(y, f, backend="xla")
            dfs_rev = []
            for i in reversed(range(len(rev))):  # last applied stage first
                f = rev[i]
                p, q = int(f.shape[0]), int(f.shape[1])
                u = inputs[i]
                dfs_rev.append(_sliced_vjp_factor(u, g, p, q).astype(f.dtype))
                g = _sliced_vjp_input(g, f, backend=backend)
            dfactors = tuple(dfs_rev)  # appended rev[n-1]..rev[0] == F^1..F^N
            return g, dfactors
        dx, dfs_by_id = _planned_bwd(plan, backend, x, factors, g, f_pert)
        nf = len(factors)
        if dfs_by_id is None:
            dfactors = tuple(jnp.zeros_like(f) for f in factors)
        else:
            dfactors = tuple(
                dfs_by_id[nf - 1 - j].astype(factors[j].dtype) for j in range(nf)
            )
        return dx.astype(x.dtype), dfactors

    kron_fn.defvjp(kron_fwd, kron_bwd, symbolic_zeros=True)
    return kron_fn


@functools.lru_cache(maxsize=None)
def _plan_for(
    m: int,
    ps: tuple[int, ...],
    qs: tuple[int, ...],
    dtype_bytes: int,
    backend: str,
    enable_prekron: bool,
    tune: str,
    cache_path: str | None,
) -> KronPlan:
    """Memoized make_plan: repeated kron_matmul calls skip Python planning
    overhead entirely (and, in tune="measure" mode, re-measurement — the
    on-disk cache covers new processes)."""
    return autotune.make_plan(
        KronProblem(m, ps, qs),
        dtype_bytes=dtype_bytes,
        enable_prekron=enable_prekron,
        tune=tune,
        backend=backend,
        cache_path=cache_path,
    )


def kron_matmul(
    x: jax.Array,
    factors: Sequence[jax.Array],
    *,
    backend: str = "auto",
    plan: KronPlan | str | None = "auto",
    tune: str = "analytic",
    cache_path: str | None = None,
) -> jax.Array:
    """``x @ (F^1 (x) ... (x) F^N)`` for ``x: (..., prod P_i)``.

    plan: ``"auto"`` builds one with autotune.make_plan; ``None`` runs the
    paper-faithful unfused per-factor path; or pass an explicit KronPlan.
    tune: ``"analytic"`` (model-ranked tiles) or ``"measure"`` (wall-clock
    ranked via autotune.measure_best, persisted in the on-disk plan cache).
    """
    factors = tuple(factors)
    ps = tuple(int(f.shape[0]) for f in factors)
    qs = tuple(int(f.shape[1]) for f in factors)
    k = math.prod(ps)
    if x.shape[-1] != k:
        raise ValueError(f"x last dim {x.shape[-1]} != prod(P)={k} for {ps}")
    lead = x.shape[:-1]
    m = math.prod(lead) if lead else 1
    prob = KronProblem(m, ps, qs)
    if plan == "auto":
        # pre-kronization trades FLOPs for MXU contraction depth — a win on
        # the 128x128 systolic array, measured a LOSS on CPU AVX (see
        # EXPERIMENTS.md §Perf); auto-plans enable it only on TPU.
        plan = _plan_for(
            m, ps, qs,
            x.dtype.itemsize,
            backend,
            jax.default_backend() == "tpu",
            tune,
            cache_path,
        )
    fn = _build_kron_fn(len(factors), backend, plan)
    y = fn(x.reshape(m, k), factors)
    return y.reshape(*lead, prob.k_out)


def kron_matmul_unfused(
    x: jax.Array, factors: Sequence[jax.Array], *, backend: str = "auto"
) -> jax.Array:
    """Paper-faithful Algorithm 1 without fusion/pairing (the C1 baseline)."""
    return kron_matmul(x, factors, backend=backend, plan=None)


# ---------------------------------------------------------------------------
# Batched Kron-Matmul: B independent problems in one launch
# ---------------------------------------------------------------------------


def _stage_forward_batched(
    y: jax.Array, stage_factors: Sequence[jax.Array], stage: Stage, backend: str,
    t_b: int,
) -> jax.Array:
    # Single-factor stages run through the same batched fused dispatcher (a
    # chain of length 1) — one uniform batch-grid entry point per stage.
    pprod = math.prod(int(f.shape[1]) for f in stage_factors)
    t_k = stage.tiles.t_s * pprod
    return ops.fused_kron_batched(
        y, stage_factors, backend=backend, t_b=t_b, t_m=stage.tiles.t_m,
        t_k=t_k, t_qs=stage.t_qs,
    )


def _sliced_vjp_factor_b(u: jax.Array, g: jax.Array, p: int, q: int) -> jax.Array:
    """Per-sample factor grad: df[b,p,q] = sum_{m,s} u[b,m,s*P+p] g[b,m,q*S+s]."""
    b, m, k = u.shape
    s = k // p
    acc = jnp.promote_types(g.dtype, jnp.float32)
    u4 = u.reshape(b, m, s, p)
    g4 = g.reshape(b, m, q, s)
    return jnp.einsum("bmsp,bmqs->bpq", u4.astype(acc), g4.astype(acc))


def _conservative_batched_tiles(m: int, k: int, p: int, q: int) -> tuple[int, int]:
    """(t_m, t_k) for a single-factor batched call at t_b=1 that provably fits
    the kernel's VMEM budget — the fallback path must never itself raise."""
    from ..kernels.kron_fused import VMEM_BUDGET_ELEMS

    t_m = min(8, m)
    while m % t_m:
        t_m -= 1
    growth = max(1.0, q / p)
    s = k // p
    t_s = max(
        d for d in range(1, s + 1)
        if s % d == 0 and t_m * d * p * growth <= VMEM_BUDGET_ELEMS
    )
    return t_m, t_s * p


def _sliced_batched(y, f, backend):
    """One batched sliced multiply through the fused dispatcher, tiled so the
    Pallas kernel always fits VMEM."""
    t_m, t_k = _conservative_batched_tiles(
        int(y.shape[1]), int(y.shape[2]), int(f.shape[1]), int(f.shape[2])
    )
    return ops.fused_kron_batched(y, (f,), backend=backend, t_b=1, t_m=t_m, t_k=t_k)


def _sliced_t_batched(g, f, backend):
    p, q = int(f.shape[1]), int(f.shape[2])
    # transposed call: the input has Q-sized slices, dX has P-sized ones.
    t_m, t_k = _conservative_batched_tiles(
        int(g.shape[1]), int(g.shape[2]) // q * p, p, q
    )
    return ops.fused_kron_t_batched(g, (f,), backend=backend, t_b=1, t_m=t_m, t_k=t_k)


def _stage_bwd_per_factor_batched(u, g, stage_factors, backend):
    """Batched analogue of _stage_bwd_per_factor: the fallback when the
    one-kernel batched stage backward cannot hold the stage in VMEM.  Runs at
    t_b=1 with conservatively-fitted tiles so it cannot overflow in turn."""
    inputs = [u]
    for f in stage_factors[:-1]:
        inputs.append(_sliced_batched(inputs[-1], f, backend))
    dfs = [None] * len(stage_factors)
    for idx in reversed(range(len(stage_factors))):
        f = stage_factors[idx]
        p, q = int(f.shape[1]), int(f.shape[2])
        dfs[idx] = _sliced_vjp_factor_b(inputs[idx], g, p, q)
        g = _sliced_t_batched(g, f, backend)
    return g, tuple(dfs)


def _planned_bwd_batched(plan: KronPlan, backend: str, x, factors, g, f_pert: bool):
    """Batched backward plan: (dx (B,M,K), per-sample dfs_by_rev_id or None).

    Mirrors _planned_bwd without the prekron branch — batched plans are built
    with pre-kronization disabled (per-sample explicit krons are a follow-on).
    """
    rev = tuple(reversed(factors))
    stage_factors = [tuple(rev[i] for i in st.factor_ids) for st in plan.stages]
    stage_inputs = []
    y = x
    for idx, (st, sf) in enumerate(zip(plan.stages, stage_factors)):
        stage_inputs.append(y)
        if idx + 1 < len(plan.stages):
            y = _stage_forward_batched(y, sf, st, backend, plan.t_b)
    bwd_sts = _default_bwd_stages(plan)
    dfs_by_id: dict[int, jax.Array] = {}
    for rev_idx in range(len(plan.stages) - 1, -1, -1):
        st = plan.stages[rev_idx]
        bst = bwd_sts[len(plan.stages) - 1 - rev_idx]
        sf = stage_factors[rev_idx]
        u = stage_inputs[rev_idx]
        pprod = math.prod(int(f.shape[1]) for f in sf)
        t_k = st.tiles.t_s * pprod
        if f_pert:
            try:
                g, dfs = ops.fused_kron_bwd_batched(
                    u, g, sf, backend=backend, t_b=plan.t_b,
                    t_m=bst.tiles.t_m, t_k=t_k,
                )
            except ValueError:
                g, dfs = _stage_bwd_per_factor_batched(u, g, sf, backend)
            for fid, d in zip(st.factor_ids, dfs):
                dfs_by_id[fid] = d
        else:
            try:
                g = ops.fused_kron_t_batched(
                    g, sf, backend=backend, t_b=plan.t_b, t_m=bst.tiles.t_m,
                    t_k=t_k, t_qs=st.t_qs,
                )
            except ValueError:
                # The planner validated t_b against FORWARD block sizes; the
                # mirrored bwd t_m can overflow on the transposed shapes —
                # walk the stage per factor with fitted tiles instead.
                for f in reversed(sf):
                    g = _sliced_t_batched(g, f, backend)
    return g, (dfs_by_id if f_pert else None)


@functools.lru_cache(maxsize=None)
def _build_batched_kron_fn(n: int, backend: str, plan: KronPlan):
    """custom-vjp function of (x (B,M,K), factors each (B,P_i,Q_i))."""

    def fwd_only(x, factors):
        rev = tuple(reversed(factors))
        y = x
        for stage in plan.stages:
            y = _stage_forward_batched(
                y, tuple(rev[i] for i in stage.factor_ids), stage, backend,
                plan.t_b,
            )
        return y

    @jax.custom_vjp
    def kron_fn(x, factors):
        return fwd_only(x, factors)

    def kron_fwd(x_p, factors_p):
        x = x_p.value
        factors = tuple(f.value for f in factors_p)
        f_pert = any(bool(f.perturbed) for f in factors_p)
        return fwd_only(x, factors), (x, factors, f_pert)

    def kron_bwd(res, g):
        x, factors, f_pert = res
        if isinstance(g, jax.custom_derivatives.SymbolicZero):
            return jnp.zeros_like(x), tuple(jnp.zeros_like(f) for f in factors)
        dx, dfs_by_id = _planned_bwd_batched(plan, backend, x, factors, g, f_pert)
        nf = len(factors)
        if dfs_by_id is None:
            dfactors = tuple(jnp.zeros_like(f) for f in factors)
        else:
            dfactors = tuple(
                dfs_by_id[nf - 1 - j].astype(factors[j].dtype) for j in range(nf)
            )
        return dx.astype(x.dtype), dfactors

    kron_fn.defvjp(kron_fwd, kron_bwd, symbolic_zeros=True)
    return kron_fn


@functools.lru_cache(maxsize=None)
def _batched_plan_for(
    batch: int,
    m: int,
    ps: tuple[int, ...],
    qs: tuple[int, ...],
    dtype_bytes: int,
    backend: str,
    shared_factors: bool,
    tune: str,
    cache_path: str | None,
) -> KronPlan:
    return autotune.make_batched_plan(
        KronProblem(m, ps, qs),
        batch,
        shared_factors=shared_factors,
        dtype_bytes=dtype_bytes,
        # pre-kronization only applies to the shared/collapse path (per-sample
        # explicit krons are not implemented); TPU-only as in kron_matmul.
        enable_prekron=shared_factors and jax.default_backend() == "tpu",
        tune=tune,
        backend=backend,
        cache_path=cache_path,
    )


def _unfused_batched_plan(n: int, m: int) -> KronPlan:
    """plan=None semantics for the per-sample path: one batched sliced
    multiply per factor (the paper-faithful loop, batch-dispatched)."""
    t_m = min(m, 8)
    while m % t_m:
        t_m -= 1
    return KronPlan(
        tuple(Stage((i,), False, TileConfig(t_m, 1, 1)) for i in range(n))
    )


def kron_matmul_batched(
    x: jax.Array,
    factors: Sequence[jax.Array],
    *,
    shared_factors: bool,
    backend: str = "auto",
    plan: KronPlan | str | None = "auto",
    tune: str = "analytic",
    cache_path: str | None = None,
) -> jax.Array:
    """``B`` independent Kron-Matmuls in one launch: ``x: (B, ..., prod P_i)``.

    shared_factors=True: one factor set ``F^i: (P_i, Q_i)`` applied to every
    sample (KronLinear under a serving batch, vmap'd layers).  The batch
    axis collapses into M — the layout allows it because both are pure row
    indices of the same contiguous array — and the whole batch runs through
    the single-problem planned path with a plan keyed on the collapsed
    ``B*M`` rows.

    shared_factors=False: per-sample factors ``F^i: (B, P_i, Q_i)`` (the
    Jhurani arXiv 1304.7054 regime — many small independent problems, e.g.
    multi-kernel GP solves or per-expert projections).  Runs the batch-grid
    kernels (``ops.fused_kron_batched`` and friends) under a batch-aware
    plan whose ``t_b`` tile trades against the M-tile in VMEM.

    Both paths are differentiable; per-sample factor grads have shape
    ``(B, P_i, Q_i)``.
    """
    factors = tuple(factors)
    if not factors:
        raise ValueError("need at least one factor")
    if x.ndim < 2:
        raise ValueError(f"x needs a leading batch axis: (B, ..., K), got {x.shape}")
    b = int(x.shape[0])
    lead = x.shape[1:-1]
    m = math.prod(lead) if lead else 1
    if shared_factors:
        if any(f.ndim != 2 for f in factors):
            raise ValueError("shared_factors=True expects 2-D (P_i, Q_i) factors")
        ps = tuple(int(f.shape[0]) for f in factors)
        qs = tuple(int(f.shape[1]) for f in factors)
        k = math.prod(ps)
        if x.shape[-1] != k:
            raise ValueError(f"x last dim {x.shape[-1]} != prod(P)={k} for {ps}")
        # Collapse B into M and DELEGATE: the shared-factors batched problem
        # is exactly the single problem on (B*M, K) rows, so it shares
        # kron_matmul's plan memo and custom-VJP path rather than duplicating
        # them (make_batched_plan(shared_factors=True) builds the same plan).
        y = kron_matmul(
            x.reshape(b * m, k), factors, backend=backend, plan=plan,
            tune=tune, cache_path=cache_path,
        )
        return y.reshape(b, *lead, math.prod(qs))
    if any(f.ndim != 3 for f in factors):
        raise ValueError("shared_factors=False expects 3-D (B, P_i, Q_i) factors")
    for f in factors:
        if int(f.shape[0]) != b:
            raise ValueError(f"factor batch {f.shape[0]} != x batch {b}")
    ps = tuple(int(f.shape[1]) for f in factors)
    qs = tuple(int(f.shape[2]) for f in factors)
    k = math.prod(ps)
    if x.shape[-1] != k:
        raise ValueError(f"x last dim {x.shape[-1]} != prod(P)={k} for {ps}")
    if plan == "auto":
        plan = _batched_plan_for(
            b, m, ps, qs, x.dtype.itemsize, backend, False, tune, cache_path
        )
    elif plan is None:
        plan = _unfused_batched_plan(len(factors), m)
    fn = _build_batched_kron_fn(len(factors), backend, plan)
    y = fn(x.reshape(b, m, k), factors)
    return y.reshape(b, *lead, math.prod(qs))


__all__ = [
    "kron_matmul",
    "kron_matmul_unfused",
    "kron_matmul_batched",
    "KronPlan",
    "Stage",
    "TileConfig",
]
