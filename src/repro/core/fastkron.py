"""Public FastKron API: planned, differentiable Kron-Matmul.

``kron_matmul(x, factors)`` computes ``x @ (F^1 (x) F^2 (x) ... (x) F^N)``
for ``x: (..., prod P_i)`` and ``F^i: (P_i, Q_i)`` without materializing the
Kronecker matrix, using the FastKron sliced-multiply algorithm (paper §3)
with an execution plan (fusion grouping C3 + tile sizes C5 + beyond-paper
pre-kronization) chosen by ``core.autotune.make_plan``.

Differentiation: the VJP of a Kron-Matmul is itself Kron-shaped —
``dX = dY @ (F^1 (x) ... (x) F^N)^T`` — so the backward pass reuses the same
sliced-multiply machinery with per-stage transposed contractions, rather than
relying on autodiff tracing through ``pallas_call``.  This makes the Pallas
and XLA backends interchangeable inside ``jax.grad``.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import autotune
from .autotune import KronPlan, Stage, TileConfig
from .kron import KronProblem


# ---------------------------------------------------------------------------
# Stage execution (forward)
# ---------------------------------------------------------------------------


def _stage_forward(
    y: jax.Array, stage_factors: Sequence[jax.Array], stage: Stage, backend: str
) -> jax.Array:
    if stage.prekron:
        # stage_factors are in APPLICATION order (rev[i], rev[i+1], ...);
        # the explicit Kronecker product must be formed in PROBLEM order,
        # i.e. kron(rev[i+1], rev[i]):  x @ (A (x) B) applies B first.
        f = stage_factors[-1]
        for g in reversed(stage_factors[:-1]):
            f = jnp.kron(f, g)
        return ops.sliced_multiply(y, f, backend=backend, tiles=stage.tiles.as_tuple)
    if len(stage_factors) == 1:
        return ops.sliced_multiply(
            y, stage_factors[0], backend=backend, tiles=stage.tiles.as_tuple
        )
    pprod = math.prod(int(f.shape[0]) for f in stage_factors)
    t_k = stage.tiles.t_s * pprod
    return ops.fused_kron(
        y, stage_factors, backend=backend, t_m=stage.tiles.t_m, t_k=t_k
    )


# ---------------------------------------------------------------------------
# VJP building blocks (pure jnp; MXU-friendly einsums on TPU)
# ---------------------------------------------------------------------------


def _sliced_vjp_input(g: jax.Array, f: jax.Array, backend: str = "xla") -> jax.Array:
    """du for y = sliced(u, f):  du[m, s*P+p] = sum_q g[m, q*S+s] f[p, q].

    This is the TRANSPOSED sliced multiply — itself Kron-shaped, with its
    own Pallas kernel (kernels/kron_sliced_t.py) on TPU."""
    return ops.sliced_multiply_t(g, f, backend=backend)


def _sliced_vjp_factor(u: jax.Array, g: jax.Array, p: int, q: int) -> jax.Array:
    """df[p,q] = sum_{m,s} u[m, s*P+p] g[m, q*S+s]."""
    m, k = u.shape
    s = k // p
    acc = jnp.promote_types(g.dtype, jnp.float32)
    u3 = u.reshape(m, s, p)
    g3 = g.reshape(m, q, s)
    return jnp.einsum("msp,mqs->pq", u3.astype(acc), g3.astype(acc))


# ---------------------------------------------------------------------------
# Planned, differentiable core
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_kron_fn(n: int, backend: str, plan: KronPlan | None):
    """Returns a custom-vjp function of (x, factors_tuple) for N factors."""

    def fwd_only(x, factors):
        # Application order: last factor first (Algorithm 1).
        rev = tuple(reversed(factors))
        y = x
        if plan is None:
            for f in rev:
                y = ops.sliced_multiply(y, f, backend=backend)
            return y
        for stage in plan.stages:
            y = _stage_forward(y, [rev[i] for i in stage.factor_ids], stage, backend)
        return y

    @jax.custom_vjp
    def kron_fn(x, factors):
        return fwd_only(x, factors)

    def kron_fwd(x, factors):
        # Residuals: just (x, factors).  The per-factor intermediates are
        # recomputed in bwd (rematerialization): storing them would cost
        # ~N*M*K extra memory, while recompute adds <= 1x forward FLOPs —
        # the right trade inside LM training where this op lives under scan.
        return fwd_only(x, factors), (x, factors)

    def kron_bwd(res, g):
        x, factors = res
        rev = tuple(reversed(factors))
        inputs = []
        y = x
        for i, f in enumerate(rev):
            inputs.append(y)
            if i + 1 < len(rev):
                y = ops.sliced_multiply(y, f, backend="xla")
        dfs_rev = []
        for i in reversed(range(len(rev))):  # last applied stage first
            f = rev[i]
            p, q = int(f.shape[0]), int(f.shape[1])
            u = inputs[i]
            dfs_rev.append(_sliced_vjp_factor(u, g, p, q).astype(f.dtype))
            g = _sliced_vjp_input(g, f, backend=backend)
        dfs = tuple(reversed(dfs_rev))  # back to application order
        dfactors = tuple(reversed(dfs))  # back to problem order F^1..F^N
        return g, dfactors

    kron_fn.defvjp(kron_fwd, kron_bwd)
    return kron_fn


def kron_matmul(
    x: jax.Array,
    factors: Sequence[jax.Array],
    *,
    backend: str = "auto",
    plan: KronPlan | str | None = "auto",
) -> jax.Array:
    """``x @ (F^1 (x) ... (x) F^N)`` for ``x: (..., prod P_i)``.

    plan: ``"auto"`` builds one with autotune.make_plan; ``None`` runs the
    paper-faithful unfused per-factor path; or pass an explicit KronPlan.
    """
    factors = tuple(factors)
    ps = tuple(int(f.shape[0]) for f in factors)
    qs = tuple(int(f.shape[1]) for f in factors)
    k = math.prod(ps)
    if x.shape[-1] != k:
        raise ValueError(f"x last dim {x.shape[-1]} != prod(P)={k} for {ps}")
    lead = x.shape[:-1]
    m = math.prod(lead) if lead else 1
    prob = KronProblem(m, ps, qs)
    if plan == "auto":
        # pre-kronization trades FLOPs for MXU contraction depth — a win on
        # the 128x128 systolic array, measured a LOSS on CPU AVX (see
        # EXPERIMENTS.md §Perf); auto-plans enable it only on TPU.
        plan = autotune.make_plan(
            prob,
            dtype_bytes=x.dtype.itemsize,
            enable_prekron=jax.default_backend() == "tpu",
        )
    fn = _build_kron_fn(len(factors), backend, plan)
    y = fn(x.reshape(m, k), factors)
    return y.reshape(*lead, prob.k_out)


def kron_matmul_unfused(
    x: jax.Array, factors: Sequence[jax.Array], *, backend: str = "auto"
) -> jax.Array:
    """Paper-faithful Algorithm 1 without fusion/pairing (the C1 baseline)."""
    return kron_matmul(x, factors, backend=backend, plan=None)


__all__ = ["kron_matmul", "kron_matmul_unfused", "KronPlan", "Stage", "TileConfig"]
