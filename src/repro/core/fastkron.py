"""Compatibility shims: the legacy functional Kron-Matmul entry points.

The execution engine lives in ``core.engine`` as the handle-based ``KronOp``
(resolve the plan once, call many times).  ``kron_matmul`` and
``kron_matmul_batched`` remain as thin shims that look an op up in the
bounded ``engine.kron_op_for`` cache and call it — one dispatch spine, no
duplicated stage loops here.  Each shim emits a single ``DeprecationWarning``
per process pointing at ``KronOp``; new code should hold an op:

    from repro.core import KronOp
    op = KronOp(ps, qs)                  # plan resolved here
    y = op(x, factors)                   # planned fwd + plan-driven VJP

Numerics, differentiation (plan-driven custom VJP with ``symbolic_zeros``),
and the batched factor-sharing modes are exactly the op path's — the shims
add nothing but the cache lookup.  The distributed shims live in
``core.distributed``.  User-facing reference: docs/api.md ("compatibility
shims"); layer map: docs/architecture.md.
"""
from __future__ import annotations

from typing import Sequence

import jax

from . import engine
from .autotune import KronPlan, Stage, TileConfig  # noqa: F401  (re-export)
from .engine import KronOp, kron_op_for, signature_of


def kron_matmul(
    x: jax.Array,
    factors: Sequence[jax.Array],
    *,
    backend: str = "auto",
    plan: KronPlan | str | None = "auto",
    tune: str = "analytic",
    cache_path: str | None = None,
) -> jax.Array:
    """``x @ (F^1 (x) ... (x) F^N)`` for ``x: (..., prod P_i)``.

    DEPRECATED shim over ``KronOp(ps, qs, backend=..., plan=..., ...)``.
    plan: ``"auto"`` builds one with autotune.make_plan; ``None`` runs the
    paper-faithful unfused per-factor path; or pass an explicit KronPlan.
    tune: ``"analytic"`` (model-ranked tiles) or ``"measure"`` (wall-clock
    ranked via autotune.measure_best, persisted in the on-disk plan cache).
    """
    engine.warn_deprecated("kron_matmul", "KronOp(ps, qs)")
    factors = tuple(factors)
    ps, qs = signature_of(factors, shared_factors=True)
    op = kron_op_for(
        ps, qs, backend=backend, plan=plan, tune=tune, cache_path=cache_path
    )
    return op(x, factors)


def kron_matmul_unfused(
    x: jax.Array, factors: Sequence[jax.Array], *, backend: str = "auto"
) -> jax.Array:
    """Paper-faithful Algorithm 1 without fusion/pairing (the C1 baseline)."""
    return kron_matmul(x, factors, backend=backend, plan=None)


def kron_matmul_batched(
    x: jax.Array,
    factors: Sequence[jax.Array],
    *,
    shared_factors: bool,
    backend: str = "auto",
    plan: KronPlan | str | None = "auto",
    tune: str = "analytic",
    cache_path: str | None = None,
) -> jax.Array:
    """``B`` independent Kron-Matmuls in one launch: ``x: (B, ..., prod P_i)``.

    DEPRECATED shim over ``KronOp(ps, qs, batch=B, shared_factors=...)``.

    shared_factors=True: one factor set ``F^i: (P_i, Q_i)`` applied to every
    sample — the batch axis collapses into M and the whole batch runs the
    single-problem planned path.  shared_factors=False: per-sample factors
    ``F^i: (B, P_i, Q_i)`` on the batch-grid kernels under a batch-aware
    plan (``t_b`` sample tiles).  Both paths are differentiable; per-sample
    factor grads have shape ``(B, P_i, Q_i)``.
    """
    engine.warn_deprecated(
        "kron_matmul_batched", "KronOp(ps, qs, batch=B, shared_factors=...)"
    )
    factors = tuple(factors)
    if x.ndim < 2:
        raise ValueError(f"x needs a leading batch axis: (B, ..., K), got {x.shape}")
    ps, qs = signature_of(factors, shared_factors=shared_factors)
    op = kron_op_for(
        ps, qs, batch=int(x.shape[0]), shared_factors=shared_factors,
        backend=backend, plan=plan, tune=tune, cache_path=cache_path,
    )
    return op(x, factors)


__all__ = [
    "kron_matmul",
    "kron_matmul_unfused",
    "kron_matmul_batched",
    "KronOp",
    "KronPlan",
    "Stage",
    "TileConfig",
]
