"""Core Kron-Matmul algorithms.

Implements, in pure JAX:
  * a naive oracle (materialize the Kronecker matrix),
  * the shuffle algorithm  [Davio'81; GPyTorch/PyKronecker baseline],
  * the FTMMT-style fused contraction baseline,
  * FastKron's sliced-multiply algorithm (paper §3, contribution C1).

All support non-uniform factor shapes (P_i, Q_i).  Shapes follow the paper:
``X: (M, prod_i P_i)``, ``F^i: (P_i, Q_i)``, ``Y: (M, prod_i Q_i)`` and the
product applied is ``Y = X @ (F^1 ⊗ F^2 ⊗ ... ⊗ F^N)``.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Problem description
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KronProblem:
    """Static description of a Kron-Matmul problem."""

    m: int
    ps: tuple[int, ...]  # (P_1, ..., P_N) row dims of factors
    qs: tuple[int, ...]  # (Q_1, ..., Q_N) col dims of factors

    @property
    def n(self) -> int:
        return len(self.ps)

    @property
    def k(self) -> int:
        return math.prod(self.ps)

    @property
    def k_out(self) -> int:
        return math.prod(self.qs)

    @property
    def flops(self) -> int:
        """MAC*2 FLOPs of the sliced-multiply algorithm (paper §3).

        Iteration i multiplies an (M, K_i) intermediate by F^i (P_i, Q_i):
        output elems M*K_i*Q_i/P_i each needing P_i MACs.
        """
        total = 0
        k = self.k
        for p, q in zip(reversed(self.ps), reversed(self.qs)):
            out_cols = (k // p) * q
            total += 2 * self.m * out_cols * p
            k = out_cols
        return total

    @property
    def intermediate_elems(self) -> int:
        """Max #elements of any intermediate (paper line 3 of Algorithm 1)."""
        best = self.k
        k = self.k
        for p, q in zip(reversed(self.ps), reversed(self.qs)):
            k = (k // p) * q
            best = max(best, k)
        return best

    @classmethod
    def uniform(cls, m: int, p: int, q: int, n: int) -> "KronProblem":
        return cls(m, (p,) * n, (q,) * n)


def _check(x: jax.Array, factors: Sequence[jax.Array]) -> KronProblem:
    ps = tuple(int(f.shape[0]) for f in factors)
    qs = tuple(int(f.shape[1]) for f in factors)
    prob = KronProblem(int(x.shape[0]), ps, qs)
    if x.ndim != 2:
        raise ValueError(f"x must be 2-D, got {x.shape}")
    if x.shape[1] != prob.k:
        raise ValueError(f"x cols {x.shape[1]} != prod(P_i) {prob.k} for {ps}")
    return prob


# ---------------------------------------------------------------------------
# Naive oracle
# ---------------------------------------------------------------------------


def kron_matrix(factors: Sequence[jax.Array]) -> jax.Array:
    """Materialize F^1 ⊗ ... ⊗ F^N (test oracle only; O(prod P * prod Q))."""
    g = factors[0]
    for f in factors[1:]:
        g = jnp.kron(g, f)
    return g


def kron_matmul_naive(x: jax.Array, factors: Sequence[jax.Array]) -> jax.Array:
    """Oracle: X @ (F^1 ⊗ ... ⊗ F^N) by materializing the Kronecker matrix."""
    _check(x, factors)
    return x @ kron_matrix(factors)


# ---------------------------------------------------------------------------
# Shuffle algorithm (the GPyTorch/PyKronecker baseline)
# ---------------------------------------------------------------------------


def shuffle_iteration(y: jax.Array, f: jax.Array) -> jax.Array:
    """One shuffle-algorithm iteration: reshape -> matmul -> transpose -> reshape.

    This is the paper's Figure 1 (steps a-c).  The transpose materializes a
    shuffled intermediate — the expensive step FastKron removes.
    """
    m, k = y.shape
    p, q = f.shape
    s = k // p
    t = y.reshape(m * s, p) @ f          # (a) reshape + GEMM
    t = t.reshape(m, s, q)
    t = jnp.swapaxes(t, 1, 2)            # (b) transpose inner dims
    return t.reshape(m, q * s)           # (c) reshape

def shuffle_transpose_only(t: jax.Array, m: int, s: int, q: int) -> jax.Array:
    """The isolated transpose step (for the Table-1 cost-breakdown benchmark)."""
    return jnp.swapaxes(t.reshape(m, s, q), 1, 2).reshape(m, q * s)


def kron_matmul_shuffle(x: jax.Array, factors: Sequence[jax.Array]) -> jax.Array:
    """Full shuffle algorithm, iterating factors from last to first."""
    _check(x, factors)
    y = x
    for f in reversed(factors):
        y = shuffle_iteration(y, f)
    return y


# ---------------------------------------------------------------------------
# FTMMT-style baseline (transpose fused into a tensor contraction)
# ---------------------------------------------------------------------------


def kron_matmul_ftmmt(x: jax.Array, factors: Sequence[jax.Array]) -> jax.Array:
    """FTMMT algorithm: represent the intermediate as a 3-D tensor and contract.

    ``einsum('msp,pq->mqs')`` fuses transpose+multiply like COGENT/cuTensor —
    but each intermediate still round-trips through "global memory" (a
    materialized array) every iteration.  Mathematically identical to FastKron's
    per-iteration result; the difference on real hardware is kernel-level
    (fusion across iterations, C3), which our Pallas kernels implement.
    """
    _check(x, factors)
    m = x.shape[0]
    y = x
    for f in reversed(factors):
        p, q = f.shape
        s = y.shape[1] // p
        y = jnp.einsum("msp,pq->mqs", y.reshape(m, s, p), f).reshape(m, q * s)
    return y


# ---------------------------------------------------------------------------
# FastKron sliced-multiply algorithm (contribution C1)
# ---------------------------------------------------------------------------


def sliced_multiply(y: jax.Array, f: jax.Array) -> jax.Array:
    """One FastKron iteration: Y'[m, q*S + s] = sum_p Y[m, s*P+p] * F[p, q].

    Output elements are written at their final indices (paper Figure 2); on
    TPU the Pallas kernel (kernels/kron_sliced.py) performs this with a
    BlockSpec over the (M, Q, S) view of the output so no shuffled
    intermediate ever exists.  This jnp version is the XLA path and oracle.
    """
    m, k = y.shape
    p, q = f.shape
    s = k // p
    return jnp.einsum("msp,pq->mqs", y.reshape(m, s, p), f).reshape(m, q * s)


def kron_matmul_fastkron(x: jax.Array, factors: Sequence[jax.Array]) -> jax.Array:
    """FastKron Algorithm 1 (pure-JAX path)."""
    _check(x, factors)
    y = x
    for f in reversed(factors):
        y = sliced_multiply(y, f)
    return y


# ---------------------------------------------------------------------------
# Beyond-paper: factor pre-kronization for small P (MXU utilization)
# ---------------------------------------------------------------------------


def pair_factors(
    factors: Sequence[jax.Array], max_p: int = 16, max_pair_dim: int = 256
) -> list[jax.Array]:
    """Fuse adjacent small factors into their explicit Kronecker product.

    TPU MXU contracts 128 elements per pass; a P=8 factor leaves 94% of the
    systolic array idle.  Multiplying by (F^i ⊗ F^{i+1}) (contraction dim P^2)
    costs ~Q/2 x more FLOPs but lifts MXU utilization min(P^2,128)/P x and
    halves the passes over HBM — a net win for P <= 16 (see EXPERIMENTS.md
    §Perf for the napkin math + measured deltas).  Adjacency matters:
    (A ⊗ B) ⊗ C == A ⊗ (B ⊗ C), so pairing preserves the product.
    """
    out: list[jax.Array] = []
    i = 0
    fs = list(factors)
    while i < len(fs):
        f = fs[i]
        if (
            i + 1 < len(fs)
            and f.shape[0] <= max_p
            and fs[i + 1].shape[0] <= max_p
            and f.shape[0] * fs[i + 1].shape[0] <= max_pair_dim
            and f.shape[1] * fs[i + 1].shape[1] <= max_pair_dim
        ):
            out.append(jnp.kron(f, fs[i + 1]))
            i += 2
        else:
            out.append(f)
            i += 1
    return out


__all__ = [
    "KronProblem",
    "kron_matrix",
    "kron_matmul_naive",
    "kron_matmul_shuffle",
    "kron_matmul_ftmmt",
    "kron_matmul_fastkron",
    "sliced_multiply",
    "shuffle_iteration",
    "shuffle_transpose_only",
    "pair_factors",
]
