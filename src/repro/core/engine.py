"""KronOp: the unified, handle-based Kron-Matmul execution engine.

The FastKron paper ships its library as a handle API (init -> size query ->
tuned execute) because Kron-Matmul performance lives in a *plan* that should
be resolved once and reused across calls.  ``KronOp`` is that handle for this
repro: constructed once from the problem signature, it resolves its
``KronPlan`` (and, on a mesh, the communication round schedule) up front and
owns the custom-VJP closures, so repeated calls never re-enter plan memo
lookups.  The four legacy entry points (``kron_matmul``,
``kron_matmul_batched``, ``kron_matmul_distributed``,
``kron_matmul_batched_distributed``) are thin deprecation shims over this
one dispatch spine — two orthogonal axes, (local | mesh) x (single |
batched), instead of four parallel code paths.

    op = KronOp((16, 16), (16, 16))          # plan resolved here
    y = op(x, factors)                       # planned fwd + plan-driven VJP
    op_b = op.with_batch(8, shared_factors=False)
    op_d = op.with_mesh(mesh)                # round schedule resolved here

Since the StageProgram refactor the spine is **program-driven end to end**:
a resolved ``KronPlan`` is lowered once (``autotune.lower``, memoized in
``_lowered``) into a ``kernels.emit.StageProgram``, the forward walks its
instructions through the ONE kernel emitter (``emit.run_stage``), and the
backward executes ``emit.transpose`` of the forward program — the twelve
near-duplicate fused paths (fwd/transposed/bwd x single/batched x
Pallas/XLA) and the hand-mirrored ``_*_batched`` twins this module used to
carry are gone; batchedness lives in the program's ``t_b`` and the operand
ranks, not in parallel code.

Execution is expressed through two JAX primitives, ``kron_matmul_p`` and
``kron_matmul_batched_p``, whose **custom batching rules** are what make
``jax.vmap`` a first-class consumer: ``vmap`` over ``x`` alone collapses the
batch into the row axis (shared factors are a pure row-parallel problem),
while ``vmap`` over ``(x, factors)`` re-binds the batched primitive so the
PR-2 batch-grid kernels run instead of the generic per-op batching fallback
(the ROADMAP's "vmap lowering" item; pinned by jaxpr/HLO inspection in
``tests/test_batched.py``).  Nested ``vmap`` folds outer batch axes into the
existing batch axis.

The batched executor here also carries the per-sample **pre-kronization**
stage (vmapped ``jnp.kron`` + one batched sliced multiply), so
``make_batched_plan(shared_factors=False, enable_prekron=True)`` plans are
executable end to end — forward and backward.

Plan memoization is bounded: ops own their resolved plans/functions, and the
shim path shares small ``lru_cache``s (``kron_op_for``) instead of the old
unbounded ``maxsize=None`` memos.  Layer map: docs/architecture.md; public
surface: docs/api.md.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
import warnings
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.extend.core import Primitive
from jax.interpreters import batching, mlir

from ..kernels import emit, ops
from ..runtime import chaos, guard, telemetry
from . import autotune
from .autotune import KronPlan, Stage, TileConfig
from .kron import KronProblem


# ---------------------------------------------------------------------------
# Plan lowering (KronPlan -> StageProgram, memoized) + program execution
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=512)
def _lowered(
    plan: KronPlan, ps: tuple[int, ...], qs: tuple[int, ...], batched: bool
) -> emit.StageProgram:
    """The op spine's bounded lowering memo: one StageProgram per (plan,
    signature, batchedness).  The backward program is NOT cached separately —
    it is ``emit.transpose`` of this one, derived mechanically."""
    return autotune.lower(plan, ps, qs, batched=batched)


def _signature(factors: Sequence[jax.Array]) -> tuple[tuple[int, ...], tuple[int, ...]]:
    off = 1 if factors[0].ndim == 3 else 0
    return (
        tuple(int(f.shape[off]) for f in factors),
        tuple(int(f.shape[off + 1]) for f in factors),
    )


# ---------------------------------------------------------------------------
# VJP building blocks (batch-polymorphic: one set for single AND batched)
# ---------------------------------------------------------------------------


def _sliced_vjp_factor(u: jax.Array, g: jax.Array, p: int, q: int) -> jax.Array:
    """df[p,q] = sum_{m,s} u[m, s*P+p] g[m, q*S+s]; per-sample ``(B, P, Q)``
    grads when ``u``/``g`` carry a leading batch axis."""
    s = int(u.shape[-1]) // p
    acc = jnp.promote_types(g.dtype, jnp.float32)
    if u.ndim == 2:
        u3 = u.reshape(u.shape[0], s, p)
        g3 = g.reshape(g.shape[0], q, s)
        return jnp.einsum("msp,mqs->pq", u3.astype(acc), g3.astype(acc))
    b, m = u.shape[0], u.shape[1]
    u4 = u.reshape(b, m, s, p)
    g4 = g.reshape(b, m, q, s)
    return jnp.einsum("bmsp,bmqs->bpq", u4.astype(acc), g4.astype(acc))


def _prekron_vjp(dK: jax.Array, stage_factors: Sequence[jax.Array]) -> tuple:
    """Split the cotangent of kron(rev[i+1], ..., rev[i]) back into per-factor
    cotangents, in ``stage_factors`` (application) order; vmapped over the
    leading batch axis for per-sample 3-D factors."""
    stage_factors = tuple(stage_factors)
    if dK.ndim == 3:
        return jax.vmap(lambda dk, fs: _prekron_vjp(dk, fs))(dK, stage_factors)
    if len(stage_factors) == 1:
        return (dK,)
    a = stage_factors[0]
    b = emit.prekron_product(stage_factors[1:])
    pa, qa = int(a.shape[0]), int(a.shape[1])
    pb, qb = int(b.shape[0]), int(b.shape[1])
    acc = jnp.promote_types(dK.dtype, jnp.float32)
    dk4 = dK.reshape(pb, pa, qb, qa).astype(acc)
    da = jnp.einsum("bpcq,bc->pq", dk4, b.astype(acc))
    db = jnp.einsum("bpcq,pq->bc", dk4, a.astype(acc))
    return (da,) + _prekron_vjp(db, stage_factors[1:])


def _conservative_batched_tiles(m: int, k: int, p: int, q: int) -> tuple[int, int]:
    """(t_m, t_k) for a single-factor batched call at t_b=1 that provably fits
    the kernel's VMEM budget — the fallback path must never itself raise."""
    t_m = min(8, m)
    while m % t_m:
        t_m -= 1
    growth = max(1.0, q / p)
    s = k // p
    t_s = max(
        d for d in range(1, s + 1)
        if s % d == 0 and t_m * d * p * growth <= emit.VMEM_BUDGET_ELEMS
    )
    return t_m, t_s * p


def _sliced_batched(y, f, backend):
    """One sliced multiply through the emitter, batch-polymorphic: 2-D
    operands run the per-factor sliced kernel; 3-D per-sample operands run a
    batched chain-of-one instruction tiled so Pallas always fits VMEM."""
    if f.ndim == 2:
        return ops.sliced_multiply(y, f, backend=backend)
    t_m, t_k = _conservative_batched_tiles(
        int(y.shape[1]), int(y.shape[2]), int(f.shape[1]), int(f.shape[2])
    )
    instr = emit.StageInstr(
        kind=emit.MULTIPLY, ps=(int(f.shape[1]),), qs=(int(f.shape[2]),),
        t_m=t_m, t_k=t_k, t_b=1,
    )
    return emit.run_stage(y, (f,), instr, backend=backend)


def _sliced_t_batched(g, f, backend):
    """Transposed twin of ``_sliced_batched`` (the input has Q-sized slices,
    dX has P-sized ones)."""
    if f.ndim == 2:
        return ops.sliced_multiply_t(g, f, backend=backend)
    p, q = int(f.shape[1]), int(f.shape[2])
    t_m, t_k = _conservative_batched_tiles(
        int(g.shape[1]), int(g.shape[2]) // q * p, p, q
    )
    instr = emit.StageInstr(
        kind=emit.TRANSPOSED_MULTIPLY, ps=(p,), qs=(q,), t_m=t_m, t_k=t_k, t_b=1
    )
    return emit.run_stage(g, (f,), instr, backend=backend)


def _stage_bwd_per_factor(u, g, stage_factors, backend):
    """Stage backward as per-factor planned ops — the fallback when the
    one-kernel fused backward cannot hold the stage's growth in VMEM (e.g.
    Q-tiled stages: the forward tiles Q, but the backward needs every
    factor-gradient pair).  Batch-polymorphic: the same loop serves single
    2-D stages and per-sample 3-D ones through the deduped emit bodies."""
    inputs = [u]
    for f in stage_factors[:-1]:
        inputs.append(_sliced_batched(inputs[-1], f, backend))
    dfs = [None] * len(stage_factors)
    for idx in reversed(range(len(stage_factors))):
        f = stage_factors[idx]
        p, q = int(f.shape[-2]), int(f.shape[-1])
        dfs[idx] = _sliced_vjp_factor(inputs[idx], g, p, q)
        g = _sliced_t_batched(g, f, backend)
    return g, tuple(dfs)


# ---------------------------------------------------------------------------
# Program-driven backward (ONE implementation for single and batched)
# ---------------------------------------------------------------------------


def _program_bwd(plan: KronPlan, backend: str, x, factors, g, f_pert: bool,
                 batched: bool):
    """Execute the backward of a lowered plan: (dx, dfs_by_rev_id or None).

    The dx chain is ``emit.transpose`` of the forward program — derived, not
    hand-mirrored; batched vs single is carried entirely by the program's
    ``t_b`` and the operands' rank.  Stage inputs are rematerialized with the
    FORWARD program (under jit XLA CSEs them against the primal chain, so the
    remat is effectively free at stage granularity).  When factor grads are
    needed, each transposed instruction is replaced by the one-kernel stage
    backward (``emit.run_stage_grad``), falling back to per-factor planned
    ops when the stage's live set cannot fit VMEM.
    """
    ps, qs = _signature(factors)
    prog = _lowered(plan, ps, qs, batched)
    rev = tuple(reversed(factors))
    stage_factors = [tuple(rev[i] for i in ins.factor_ids) for ins in prog.instrs]
    stage_inputs = []
    y = x
    for idx, (ins, sf) in enumerate(zip(prog.instrs, stage_factors)):
        stage_inputs.append(y)
        if idx + 1 < len(prog.instrs):
            y = emit.run_stage(y, sf, ins, backend=backend)
    bwd_prog = emit.transpose(prog)
    n_st = len(prog.instrs)
    dfs_by_id: dict[int, jax.Array] = {}
    for pos, t_ins in enumerate(bwd_prog.instrs):
        fwd_idx = n_st - 1 - pos
        f_ins = prog.instrs[fwd_idx]
        sf = stage_factors[fwd_idx]
        u = stage_inputs[fwd_idx]
        if f_ins.kind == emit.PREKRON:
            fk = emit.prekron_product(sf)
            pk_ins = dataclasses.replace(
                f_ins, kind=emit.MULTIPLY, ps=(int(fk.shape[-2]),),
                qs=(int(fk.shape[-1]),),
                t_qs=f_ins.t_qs if f_ins.t_qs and len(f_ins.t_qs) == 1 else None,
            )
            if f_pert:
                try:
                    g, (dk,) = emit.run_stage_grad(
                        u, g, (fk,), dataclasses.replace(pk_ins, t_m=t_ins.t_m),
                        backend=backend,
                    )
                except guard.KronError as e:
                    guard.record_event("bwd_per_factor", e)
                    g, (dk,) = _stage_bwd_per_factor(u, g, (fk,), backend)
                for fid, d in zip(f_ins.factor_ids, _prekron_vjp(dk, sf)):
                    dfs_by_id[fid] = d
            else:
                try:
                    g = emit.run_stage(g, (fk,), pk_ins.transpose(), backend=backend)
                except guard.KronError as e:
                    guard.record_event("bwd_per_factor", e)
                    g = _sliced_t_batched(g, fk, backend)
        elif f_pert:
            try:
                # Grad instr: the forward stage shape with the transposed
                # instruction's tuned M-tile (plan.bwd_stages via transpose()).
                g, dfs = emit.run_stage_grad(
                    u, g, sf, dataclasses.replace(f_ins, t_m=t_ins.t_m),
                    backend=backend,
                )
            except guard.KronError as e:
                # Fused backward tile exceeds VMEM (Q-tiled forward stages
                # have no Q relief on the gradient-pair side) — run the
                # stage per factor, still through planned dispatch.
                guard.record_event("bwd_per_factor", e)
                g, dfs = _stage_bwd_per_factor(u, g, sf, backend)
            for fid, d in zip(f_ins.factor_ids, dfs):
                dfs_by_id[fid] = d
        else:
            try:
                g = emit.run_stage(g, sf, t_ins, backend=backend)
            except guard.KronError as e:
                # The planner validated tiles against FORWARD block sizes;
                # the transposed shapes can overflow — walk the stage per
                # factor with fitted tiles instead.
                guard.record_event("bwd_per_factor", e)
                for f in reversed(sf):
                    g = _sliced_t_batched(g, f, backend)
    return g, (dfs_by_id if f_pert else None)


def _unfused_batched_plan(n: int, m: int) -> KronPlan:
    """plan=None semantics for the per-sample path: one batched sliced
    multiply per factor (the paper-faithful loop, batch-dispatched)."""
    t_m = min(m, 8)
    while m % t_m:
        t_m -= 1
    return KronPlan(
        tuple(Stage((i,), False, TileConfig(t_m, 1, 1)) for i in range(n))
    )


# ---------------------------------------------------------------------------
# Plan resolution (bounded memoization replacing the old unbounded memos)
# ---------------------------------------------------------------------------

_PLAN_MEMO_SIZE = 128


def _auto_prekron() -> bool:
    # pre-kronization trades FLOPs for MXU contraction depth — a win on the
    # 128x128 systolic array, measured a LOSS on CPU AVX (EXPERIMENTS.md
    # §Perf); auto-plans enable it only on TPU.  Applies to both the single
    # path and (now that the batched executor has a per-sample explicit-kron
    # stage) the per-sample batched path.
    return jax.default_backend() == "tpu"


@functools.lru_cache(maxsize=_PLAN_MEMO_SIZE)
def _resolve_plan(
    m: int,
    ps: tuple[int, ...],
    qs: tuple[int, ...],
    dtype_bytes: int,
    backend: str,
    enable_prekron: bool,
    tune: str,
    cache_path: str | None,
) -> KronPlan:
    with telemetry.span("plan", m=m, ps=ps, qs=qs, tune=tune):
        return autotune.make_plan(
            KronProblem(m, ps, qs),
            dtype_bytes=dtype_bytes,
            enable_prekron=enable_prekron,
            tune=tune,
            backend=backend,
            cache_path=cache_path,
        )


@functools.lru_cache(maxsize=_PLAN_MEMO_SIZE)
def _resolve_batched_plan(
    batch: int,
    m: int,
    ps: tuple[int, ...],
    qs: tuple[int, ...],
    dtype_bytes: int,
    backend: str,
    enable_prekron: bool,
    tune: str,
    cache_path: str | None,
    g_k: int,
) -> KronPlan:
    with telemetry.span("plan", m=m, ps=ps, qs=qs, tune=tune, batch=batch):
        return autotune.make_batched_plan(
            KronProblem(m, ps, qs),
            batch,
            shared_factors=False,
            dtype_bytes=dtype_bytes,
            enable_prekron=enable_prekron,
            tune=tune,
            backend=backend,
            cache_path=cache_path,
            g_k=g_k,
        )


class _PlanCtx(NamedTuple):
    """Static re-planning context carried on the primitives so batching rules
    can resolve the right plan for the transformed problem."""

    auto: bool  # plan came from the planner (re-plan on reshape) vs explicit
    tune: str
    cache_path: str | None
    prekron: bool


# ---------------------------------------------------------------------------
# The primitives: kron_matmul_p / kron_matmul_batched_p
# ---------------------------------------------------------------------------

kron_matmul_p = Primitive("kron_matmul")
kron_matmul_batched_p = Primitive("kron_matmul_batched")


def _fwd_ladder(x, factors, plan, backend, batched):
    """The per-op forward degradation ladder (docs/robustness.md):

      rung 0  planned     the lowered StageProgram (fused pallas chain / tuned
                          XLA scan — whatever the plan says)
      rung 1  per-factor  one conservatively-tiled sliced multiply per factor
      rung 2  xla-scan    the whole chain through the lax.scan executor

    Run under ``guard.run_ladder``: a typed failure degrades THE CALL with a
    once-per-process warning; ``patience`` consecutive degraded calls pin the
    op's signature to the surviving rung.  All rungs compute the identical
    contraction (tiles never split the reduction dim), so degradation is
    numerically invisible — the bitwise-parity property pinned by
    tests/test_guard.py.  Health is trace-time state: under jit the rung is
    chosen when the call is traced.

    Only CAPACITY failures degrade (VMEM overflow, illegal lowering): a
    ``NumericsError`` means the DATA is bad — every rung would compute the
    same non-finite values, so it propagates immediately instead of paying
    for three doomed attempts.
    """
    ps, qs = _signature(factors)
    prog = _lowered(plan, ps, qs, batched)
    rev = tuple(reversed(factors))
    key = ("kron", ps, qs, backend, batched)

    def _planned():
        return emit.run_program(x, factors, prog, backend=backend)

    def _per_factor():
        chaos.maybe_fail("per_factor")
        y = x
        for f in rev:
            y = _sliced_batched(y, f, backend)
        return guard.check_finite(y, "per_factor")

    def _xla_scan():
        y = emit._chain_xla(x, rev, t_b=1 if batched else None)
        return guard.check_finite(y, "xla_scan")

    return guard.run_ladder(
        key,
        (
            ("planned", _planned),
            ("per-factor", _per_factor),
            ("xla-scan", _xla_scan),
        ),
        catch=(guard.VmemOverflowError, guard.LoweringError),
    )


def _kron_impl(x, *factors, plan, backend, pctx):
    if plan is None:
        # Paper-faithful unfused loop (the C1 baseline): application order is
        # last factor first (Algorithm 1).
        y = x
        for f in reversed(factors):
            y = ops.sliced_multiply(y, f, backend=backend)
        return y
    return _fwd_ladder(x, factors, plan, backend, batched=False)


def _kron_abstract(x, *factors, plan, backend, pctx):
    k_out = math.prod(int(f.shape[1]) for f in factors)
    return jax.core.ShapedArray((x.shape[0], k_out), x.dtype)


def _kron_batched_impl(x, *factors, plan, backend, pctx):
    return _fwd_ladder(x, factors, plan, backend, batched=True)


def _kron_batched_abstract(x, *factors, plan, backend, pctx):
    k_out = math.prod(int(f.shape[2]) for f in factors)
    return jax.core.ShapedArray((x.shape[0], x.shape[1], k_out), x.dtype)


kron_matmul_p.def_impl(_kron_impl)
kron_matmul_p.def_abstract_eval(_kron_abstract)
mlir.register_lowering(
    kron_matmul_p, mlir.lower_fun(_kron_impl, multiple_results=False)
)
kron_matmul_batched_p.def_impl(_kron_batched_impl)
kron_matmul_batched_p.def_abstract_eval(_kron_batched_abstract)
mlir.register_lowering(
    kron_matmul_batched_p, mlir.lower_fun(_kron_batched_impl, multiple_results=False)
)


def _front(a, d, size):
    """Move the mapped axis to the front, or broadcast an unmapped operand."""
    if d is batching.not_mapped:
        return jnp.broadcast_to(a[None], (size, *a.shape))
    return jnp.moveaxis(a, d, 0)


def _axis_size(args, dims) -> int:
    for a, d in zip(args, dims):
        if d is not batching.not_mapped:
            return int(a.shape[d])
    raise ValueError("no mapped operand")  # unreachable under vmap


def _kron_batch_rule(args, dims, *, plan, backend, pctx):
    """vmap(kron_matmul): the ROADMAP's custom batching rule.

    * only ``x`` mapped (shared factors): the batch is a pure row-parallel
      axis, so it COLLAPSES into M and the single-problem planned path runs
      on the (B*M, K) rows — re-planned for the collapsed row count when the
      plan was auto-resolved.
    * any factor mapped (per-sample factors): route to the batch-grid
      kernels via ``kron_matmul_batched_p`` under a batched plan, instead of
      the generic per-op batching fallback.
    """
    b = _axis_size(args, dims)
    x, factors = args[0], args[1:]
    xd, fds = dims[0], tuple(dims[1:])
    ps = tuple(int(f.shape[-2]) for f in factors)
    qs = tuple(int(f.shape[-1]) for f in factors)
    if all(d is batching.not_mapped for d in fds):
        xb = _front(x, xd, b)
        m = int(xb.shape[1])
        p2 = plan
        if pctx.auto and plan is not None:
            p2 = _resolve_plan(
                b * m, ps, qs, x.dtype.itemsize, backend, pctx.prekron,
                pctx.tune, pctx.cache_path,
            )
        y = kron_matmul_p.bind(
            xb.reshape(b * m, -1), *factors, plan=p2, backend=backend, pctx=pctx
        )
        return y.reshape(b, m, -1), 0
    xb = _front(x, xd, b)
    fbs = tuple(_front(f, d, b) for f, d in zip(factors, fds))
    m = int(xb.shape[1])
    if plan is None:
        p2 = _unfused_batched_plan(len(factors), m)
    elif pctx.auto:
        p2 = _resolve_batched_plan(
            b, m, ps, qs, x.dtype.itemsize, backend, _auto_prekron(),
            pctx.tune, pctx.cache_path, 1,
        )
    else:
        p2 = plan
    y = kron_matmul_batched_p.bind(xb, *fbs, plan=p2, backend=backend, pctx=pctx)
    return y, 0


def _kron_batched_batch_rule(args, dims, *, plan, backend, pctx):
    """Nested vmap: fold the new batch axis into the existing one (C problems
    of B samples == one batch of C*B samples) and re-bind."""
    c = _axis_size(args, dims)
    x, factors = args[0], args[1:]
    xb = _front(x, dims[0], c)  # (C, B, M, K)
    fbs = tuple(_front(f, d, c) for f, d in zip(factors, dims[1:]))
    b = int(xb.shape[1])
    m = int(xb.shape[2])
    ps = tuple(int(f.shape[-2]) for f in fbs)
    qs = tuple(int(f.shape[-1]) for f in fbs)
    if pctx.auto:
        p2 = _resolve_batched_plan(
            c * b, m, ps, qs, x.dtype.itemsize, backend, _auto_prekron(),
            pctx.tune, pctx.cache_path, 1,
        )
    else:
        p2 = plan
    y = kron_matmul_batched_p.bind(
        xb.reshape(c * b, m, -1),
        *(f.reshape(c * b, *f.shape[2:]) for f in fbs),
        plan=p2, backend=backend, pctx=pctx,
    )
    return y.reshape(c, b, m, -1), 0


batching.primitive_batchers[kron_matmul_p] = _kron_batch_rule
batching.primitive_batchers[kron_matmul_batched_p] = _kron_batched_batch_rule


# ---------------------------------------------------------------------------
# Custom-VJP closures (op-owned; shared through small bounded caches)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=256)
def _kron_fn(plan: KronPlan | None, backend: str, pctx: _PlanCtx, batched: bool):
    """THE custom-vjp closure: one factory for both execution modes.

    ``batched=False``: (x (M, K), 2-D factors_tuple); ``batched=True``:
    (x (B, M, K), per-sample 3-D factors).  The forward binds the matching
    primitive; the backward is the program-driven ``_program_bwd`` either
    way — batchedness lives in the lowered program's ``t_b`` and the operand
    ranks, not in a second code path.
    """
    prim = kron_matmul_batched_p if batched else kron_matmul_p

    def fwd_only(x, factors):
        return prim.bind(x, *factors, plan=plan, backend=backend, pctx=pctx)

    @jax.custom_vjp
    def kron_fn(x, factors):
        return fwd_only(x, factors)

    def kron_fwd(x_p, factors_p):
        x = x_p.value
        factors = tuple(f.value for f in factors_p)
        # Residuals: just (x, factors) plus static perturbation flags.  The
        # per-factor intermediates are recomputed in bwd (rematerialization):
        # storing them would cost ~N*M*K extra memory, while recompute adds
        # <= 1x forward FLOPs and is CSE'd against the primal under jit.
        f_pert = any(bool(f.perturbed) for f in factors_p)
        return fwd_only(x, factors), (x, factors, f_pert)

    def kron_bwd(res, g):
        x, factors, f_pert = res
        if isinstance(g, jax.custom_derivatives.SymbolicZero):
            return jnp.zeros_like(x), tuple(jnp.zeros_like(f) for f in factors)
        if plan is None and not batched:
            # Paper-faithful unfused loop (the C1 baseline's backward): one
            # transposed sliced multiply + factor contraction per factor.
            rev = tuple(reversed(factors))
            inputs = []
            y = x
            for i, f in enumerate(rev):
                inputs.append(y)
                if i + 1 < len(rev):
                    y = ops.sliced_multiply(y, f, backend="xla")
            dfs_rev = []
            for i in reversed(range(len(rev))):  # last applied stage first
                f = rev[i]
                p, q = int(f.shape[0]), int(f.shape[1])
                dfs_rev.append(_sliced_vjp_factor(inputs[i], g, p, q).astype(f.dtype))
                g = ops.sliced_multiply_t(g, f, backend=backend)
            dfactors = tuple(dfs_rev)  # appended rev[n-1]..rev[0] == F^1..F^N
            return g, dfactors
        dx, dfs_by_id = _program_bwd(plan, backend, x, factors, g, f_pert, batched)
        nf = len(factors)
        if dfs_by_id is None:
            dfactors = tuple(jnp.zeros_like(f) for f in factors)
        else:
            dfactors = tuple(
                dfs_by_id[nf - 1 - j].astype(factors[j].dtype) for j in range(nf)
            )
        return dx.astype(x.dtype), dfactors

    kron_fn.defvjp(kron_fwd, kron_bwd, symbolic_zeros=True)
    return kron_fn


# ---------------------------------------------------------------------------
# KronOp
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KronCost:
    """Analytic per-call cost of a KronOp (``KronOp.cost()``).

    The last three fields describe the slab-pipelined round schedule: with
    ``n_slabs > 1`` each round's all_to_all is split into per-row-slab
    collectives issued under the NEXT slab's chain compute, so of the
    ``comm_elems_per_device`` total only the exposed remainder sits on the
    critical path.  ``comm_hidden_elems`` is the analytic upper bound on the
    hidden share (``distributed.comm_hidden_elems``) and
    ``critical_path_s`` the resulting per-call wall-clock estimate —
    compute at the dtype's peak plus the EXPOSED transfer at ``ICI_BW``
    plus one launch latency per collective.  Defaults keep local ops (and
    serial mesh schedules) at the historical ``KronCost(flops, comm,
    rounds)`` shape: nothing hidden, one collective per round.
    """

    flops: int
    comm_elems_per_device: int  # all_to_all payload; 0 for local ops
    rounds: int  # collective rounds; 0 for local ops
    comm_hidden_elems: int = 0  # payload hidden under slab-pipelined compute
    n_slabs: int = 1  # resolved slab count of the round schedule
    critical_path_s: float = 0.0  # analytic wall-clock (0.0 for local ops)


def _stage_flops_bytes(
    y_shape: Sequence[int], instr: emit.StageInstr, dtype_bytes: int
) -> tuple[int, int]:
    """Analytic (flops, hbm_bytes) of one stage launch on input ``y_shape``.

    Flops follow the sliced-multiply count (KronProblem.flops, per chained
    factor); bytes are the input + output intermediates plus the factor
    panels — the same two quantities the planner's analytic model trades off,
    so ``profile()`` drift is measured against the model that CHOSE the plan.
    """
    rows = math.prod(int(d) for d in y_shape[:-1]) or 1
    k = int(y_shape[-1])
    flops = 0
    factor_elems = 0
    if instr.kind == emit.PREKRON:
        pairs = [(instr.pprod, instr.qprod)]
        factor_elems = sum(p * q for p, q in zip(instr.ps, instr.qs))
    else:
        pairs = list(zip(instr.ps, instr.qs))
        factor_elems = sum(p * q for p, q in pairs)
    cur = k
    for p, q in pairs:
        out = (cur // p) * q
        flops += 2 * rows * out * p
        cur = out
    bytes_ = (rows * k + rows * cur + factor_elems) * dtype_bytes
    return flops, bytes_


def _stage_drift(
    measured: Sequence[float], predicted: Sequence[float], threshold: float
) -> list[bool]:
    """Per-stage cost-model drift flags for ``KronOp.profile()``.

    Absolute measured/predicted ratios are hardware-calibration, not drift —
    the model's PEAK/BW constants are TPU numbers and the host may be
    anything.  What the model does promise is the SPLIT of time across
    stages, so each stage's ratio is normalised by the whole-program ratio
    and flagged when it deviates by more than ``threshold``x either way.
    """
    total_m = sum(measured)
    total_p = sum(predicted)
    if total_m <= 0 or total_p <= 0 or threshold <= 0:
        return [False] * len(list(measured))
    overall = total_m / total_p
    flags = []
    for m_i, p_i in zip(measured, predicted):
        if p_i <= 0:
            flags.append(m_i > 0)
            continue
        drift = (m_i / p_i) / overall
        flags.append(drift > threshold or drift < 1.0 / threshold)
    return flags


_OP_STATE_SIZE = 8  # per-op (rows, dtype) -> plan/fn entries kept


def signature_of(
    factors: Sequence[jax.Array], shared_factors: bool
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """(ps, qs) of a factor list, validating the ndim for the sharing mode."""
    factors = tuple(factors)
    if not factors:
        raise ValueError("need at least one factor")
    if shared_factors:
        if any(f.ndim != 2 for f in factors):
            raise ValueError("shared_factors=True expects 2-D (P_i, Q_i) factors")
        return (
            tuple(int(f.shape[0]) for f in factors),
            tuple(int(f.shape[1]) for f in factors),
        )
    if any(f.ndim != 3 for f in factors):
        raise ValueError("shared_factors=False expects 3-D (B, P_i, Q_i) factors")
    return (
        tuple(int(f.shape[1]) for f in factors),
        tuple(int(f.shape[2]) for f in factors),
    )


class KronOp:
    """A Kron-Matmul problem resolved into an executable operator.

    ``KronOp(ps, qs)`` describes ``x @ (F^1 (x) ... (x) F^N)`` with factor
    shapes ``F^i: (P_i, Q_i)``; calling the op executes it with the plan
    (and, on a mesh, the round schedule) resolved ONCE and owned by the op —
    repeated calls never re-enter plan memo lookups, and two ops with the
    same signature share one plan object through a bounded module cache.

    Parameters
    ----------
    ps, qs : factor row/column dims, problem order.
    m : optional row count the plan is resolved for at construction.  When
        omitted, plans resolve lazily on first call per distinct row count
        (kept in a small op-owned table) and ``.plan`` defaults to the
        paper's M=16 CG-block row count.
    batch : B for the batched execution modes; None = single-problem.
    shared_factors : with ``batch``: one 2-D factor set for every sample
        (B collapses into the row axis) vs per-sample 3-D ``(B, P_i, Q_i)``
        factors (the batch-grid kernels).
    mesh : a ``(data, model)`` jax Mesh — execution becomes the paper §5
        distributed rounds; the round schedule is validated at construction
        (raises ``ValueError`` when no legal relocation schedule exists).
    backend / plan / tune / cache_path : as in the legacy entry points;
        ``plan`` may be ``"auto"``, ``None`` (paper-faithful unfused loop),
        or an explicit ``KronPlan``.
    n_slabs : row-slab count for the mesh round pipeline.  ``"auto"`` lets
        the planner decide (per-sample batched plans carry it as
        ``KronPlan.n_slabs``; the shared/single path asks
        ``autotune.choose_n_slabs``); an explicit int forces the schedule,
        clamped to a divisor of the local row axis.  Ignored off-mesh.

    The dispatch spine is two orthogonal axes — (local | mesh) x (single |
    batched) — and every legacy ``kron_matmul*`` entry point is a shim over
    it.  ``vmap`` over a KronOp-backed call routes through the custom
    batching rules on the op's primitives (see module docstring).
    """

    def __init__(
        self,
        ps: Sequence[int],
        qs: Sequence[int],
        *,
        m: int | None = None,
        batch: int | None = None,
        shared_factors: bool = True,
        mesh=None,
        data_axis: str | tuple[str, ...] = "data",
        model_axis: str = "model",
        per_iteration: bool = False,
        backend: str = "auto",
        plan: KronPlan | str | None = "auto",
        tune: str = "analytic",
        cache_path: str | None = None,
        dtype_bytes: int = 4,
        enable_prekron: bool | None = None,
        n_slabs: int | str = "auto",
    ):
        self.ps = tuple(int(p) for p in ps)
        self.qs = tuple(int(q) for q in qs)
        if len(self.ps) != len(self.qs) or not self.ps:
            raise ValueError(f"ps/qs must be equal-length and non-empty: {ps}, {qs}")
        if any(d <= 0 for d in self.ps + self.qs):
            raise ValueError(f"factor dims must be positive: {ps}, {qs}")
        if batch is not None and batch <= 0:
            raise ValueError(f"batch must be positive, got {batch}")
        if isinstance(plan, str) and plan != "auto":
            raise ValueError(f"plan must be 'auto', None, or a KronPlan: {plan!r}")
        if isinstance(n_slabs, str):
            if n_slabs != "auto":
                raise ValueError(f"n_slabs must be 'auto' or an int: {n_slabs!r}")
        elif int(n_slabs) <= 0:
            raise ValueError(f"n_slabs must be positive, got {n_slabs}")
        self.n = len(self.ps)
        self.k = math.prod(self.ps)
        self.k_out = math.prod(self.qs)
        self.batch = batch
        self.shared_factors = bool(shared_factors)
        self.backend = backend
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axis = model_axis
        self.per_iteration = bool(per_iteration)
        # "auto" defers the slab count to the planner (per-sample batched
        # plans own it as KronPlan.n_slabs; the shared/single round path asks
        # autotune.choose_n_slabs); an int forces it (clamped to a divisor of
        # the local row axis by the executor).  Meaningless off-mesh.
        self._n_slabs_arg = n_slabs if n_slabs == "auto" else int(n_slabs)
        self._m = m
        self._dtype_bytes = dtype_bytes
        self._plan_arg = plan
        # ``enable_prekron=None`` keeps the backend auto-gate (TPU on, else
        # off); an explicit bool overrides it — e.g. the optimizer's
        # preconditioner apply must NEVER densify kron(L, R) per layer.
        self._enable_prekron = enable_prekron
        prekron = _auto_prekron() if enable_prekron is None else bool(enable_prekron)
        self._ctx = _PlanCtx(plan == "auto", tune, cache_path, prekron)
        if mesh is not None:
            from .distributed import _mesh_size, plan_rounds

            self.g_m = _mesh_size(mesh, data_axis)
            self.g_k = int(mesh.shape[model_axis])
            if self.k % self.g_k:
                raise ValueError(
                    f"K={self.k} not divisible by model axis G_K={self.g_k}"
                )
            # Round schedule resolved (and validated) at construction.
            self.rounds = tuple(
                plan_rounds(
                    self.k // self.g_k,
                    tuple(reversed(self.ps)),
                    tuple(reversed(self.qs)),
                    self.g_k,
                    minimal=self.per_iteration,
                )
            )
        else:
            self.g_m = self.g_k = 1
            self.rounds = None
        # Op-owned resolved state: (rows-or-(b,m), dtype_bytes) -> plan / fn.
        self._plans: dict = {}
        self._fns: dict = {}
        if m is not None and mesh is None:
            if batch is not None and not self.shared_factors:
                self._ensure_batched(batch, m, dtype_bytes)
            else:
                rows = m if batch is None else batch * m
                self._ensure_single(rows, dtype_bytes)

    # -- plan / fn resolution (op-owned, bounded) ---------------------------

    def _remember(self, cache: dict, key, value):
        cache[key] = value
        while len(cache) > _OP_STATE_SIZE:
            cache.pop(next(iter(cache)))
        return value

    def _single_plan(self, rows: int, dtype_bytes: int) -> KronPlan | None:
        if self._plan_arg == "auto":
            return _resolve_plan(
                rows, self.ps, self.qs, dtype_bytes, self.backend,
                self._ctx.prekron, self._ctx.tune, self._ctx.cache_path,
            )
        return self._plan_arg

    def _batched_plan(self, b: int, m: int, dtype_bytes: int) -> KronPlan:
        if self._plan_arg == "auto":
            if self.mesh is not None and self._ctx.tune == "measure":
                # The measured distributed tuner wall-clocks candidate
                # (t_b, n_slabs) schedules ON the mesh, so it needs the mesh
                # itself — bypass the hashable-args memo; the plan cache
                # (``;gk=`` key) deduplicates across ops instead.
                with telemetry.span(
                    "plan", m=m, ps=self.ps, qs=self.qs, tune="measure",
                    batch=b, g_k=self.g_k,
                ):
                    return autotune.make_batched_plan(
                        KronProblem(m, self.ps, self.qs), b,
                        shared_factors=False, dtype_bytes=dtype_bytes,
                        enable_prekron=self._ctx.prekron, tune="measure",
                        backend=self.backend,
                        cache_path=self._ctx.cache_path, g_k=self.g_k,
                        mesh=self.mesh, data_axis=self.data_axis,
                        model_axis=self.model_axis,
                    )
            return _resolve_batched_plan(
                b, m, self.ps, self.qs, dtype_bytes, self.backend,
                self._ctx.prekron, self._ctx.tune, self._ctx.cache_path,
                self.g_k,
            )
        if self._plan_arg is None:
            return _unfused_batched_plan(self.n, m)
        return self._plan_arg

    def _ensure_single(self, rows: int, dtype_bytes: int):
        key = ("single", rows, dtype_bytes)
        fn = self._fns.get(key)
        if fn is None:
            plan = self._single_plan(rows, dtype_bytes)
            self._remember(self._plans, key, plan)
            fn = self._remember(
                self._fns, key, _kron_fn(plan, self.backend, self._ctx, False)
            )
        return fn

    def _ensure_batched(self, b: int, m: int, dtype_bytes: int):
        key = ("batched", b, m, dtype_bytes)
        fn = self._fns.get(key)
        if fn is None:
            plan = self._batched_plan(b, m, dtype_bytes)
            self._remember(self._plans, key, plan)
            fn = self._remember(
                self._fns, key, _kron_fn(plan, self.backend, self._ctx, True)
            )
        return fn

    def _default_rows(self) -> int:
        # The paper's M=16 CG-block row count when no row hint exists.
        return self._m if self._m is not None else 16

    def _resolve_n_slabs(self, m_loc: int, plan: KronPlan | None = None) -> int:
        """Resolved slab count of the round schedule for ``m_loc`` local rows.

        Explicit ints are honoured (clamped to a divisor of the row axis —
        the same clamp the executor applies); ``"auto"`` reads the batched
        plan's ``n_slabs`` when one is supplied (the per-sample mesh path,
        where the planner traded slabs against ``t_b``) and otherwise asks
        the analytic model.  Always 1 without a model axis to overlap."""
        if self.mesh is None or self.g_k <= 1 or m_loc <= 1:
            return 1
        if self._n_slabs_arg != "auto":
            return emit.effective_slabs(m_loc, int(self._n_slabs_arg))
        if plan is not None:
            return emit.effective_slabs(m_loc, int(getattr(plan, "n_slabs", 1)))
        b = 1 if (self.batch is None or self.shared_factors) else self.batch
        n = autotune.choose_n_slabs(
            KronProblem(m_loc, self.ps, self.qs), self.g_k,
            batch=b, dtype_bytes=self._dtype_bytes,
        )
        return emit.effective_slabs(m_loc, n)

    @property
    def plan(self) -> KronPlan | None:
        """The op's resolved KronPlan (last resolved; resolves for the
        construction-time ``m`` or the M=16 default when none seen yet).

        Mesh ops on the single/shared path return None: that path executes
        the ROUND schedule (``self.rounds``), not a stage plan — resolving
        one here would report (and under tune="measure", measure) a plan
        that never runs.  Per-sample mesh ops do use a batched plan (its
        ``t_b`` tiles the round kernels), so they resolve normally."""
        if self.mesh is not None and (self.batch is None or self.shared_factors):
            return None
        if self._plans:
            return next(reversed(self._plans.values()))
        m = self._default_rows()
        if self.batch is not None and not self.shared_factors:
            return self._batched_plan(self.batch, m, self._dtype_bytes)
        rows = m if self.batch is None else self.batch * m
        return self._single_plan(rows, self._dtype_bytes)

    # -- derivations --------------------------------------------------------

    def _derive(self, **changes) -> "KronOp":
        kw = dict(
            m=self._m, batch=self.batch, shared_factors=self.shared_factors,
            mesh=self.mesh, data_axis=self.data_axis,
            model_axis=self.model_axis, per_iteration=self.per_iteration,
            backend=self.backend, plan=self._plan_arg, tune=self._ctx.tune,
            cache_path=self._ctx.cache_path, dtype_bytes=self._dtype_bytes,
            enable_prekron=self._enable_prekron, n_slabs=self._n_slabs_arg,
        )
        kw.update(changes)
        return KronOp(self.ps, self.qs, **kw)

    def with_mesh(
        self, mesh, *, data_axis="data", model_axis="model",
        per_iteration: bool = False,
    ) -> "KronOp":
        """The same problem executed as distributed rounds on ``mesh``."""
        return self._derive(
            mesh=mesh, data_axis=data_axis, model_axis=model_axis,
            per_iteration=per_iteration,
        )

    def with_batch(
        self, batch: int | None, *, shared_factors: bool | None = None
    ) -> "KronOp":
        """The same problem over ``batch`` independent samples.

        The row-count hint is dropped in the derivation: a single op's ``m``
        is TOTAL rows while a batched op's ``m`` is rows PER SAMPLE, so
        carrying it over would eagerly resolve a plan for the wrong shape.
        The derived op resolves lazily on its first call instead."""
        if shared_factors is None:
            shared_factors = self.shared_factors
        return self._derive(batch=batch, shared_factors=shared_factors, m=None)

    # -- size / cost queries -------------------------------------------------

    def out_shape(self, x_shape: Sequence[int]) -> tuple[int, ...]:
        """Output shape for an input of shape ``x_shape`` (the handle API's
        size query: allocate outputs without tracing)."""
        x_shape = tuple(int(d) for d in x_shape)
        if not x_shape or x_shape[-1] != self.k:
            raise ValueError(
                f"x last dim {x_shape[-1] if x_shape else None} != "
                f"prod(P)={self.k} for {self.ps}"
            )
        if self.batch is not None:
            if len(x_shape) < 2 or x_shape[0] != self.batch:
                raise ValueError(
                    f"batched op expects (B={self.batch}, ..., K), got {x_shape}"
                )
        return (*x_shape[:-1], self.k_out)

    def cost(self, m: int | None = None) -> KronCost:
        """Analytic cost of one call: sliced-multiply FLOPs plus, on a mesh,
        the all_to_all payload (elements per device, all rounds), the share
        of it the slab pipeline hides under compute, and the resulting
        critical-path wall-clock estimate (``KronCost`` docstring)."""
        m = m if m is not None else self._default_rows()
        b = self.batch or 1
        if self.batch is not None and not self.shared_factors:
            flops = b * KronProblem(m, self.ps, self.qs).flops
        else:
            flops = KronProblem(b * m, self.ps, self.qs).flops
        if self.mesh is None:
            return KronCost(flops, 0, 0)
        from .distributed import comm_elems_per_device, comm_hidden_elems

        rows = b * m if self.shared_factors else m
        m_loc = max(1, rows // self.g_m)
        comm_batch = 1 if self.shared_factors else b
        ps_rev = tuple(reversed(self.ps))
        qs_rev = tuple(reversed(self.qs))
        comm = comm_elems_per_device(
            m_loc, self.k // self.g_k, ps_rev, qs_rev, self.g_k,
            rounds=self.rounds, batch=comm_batch,
        )
        n = self._resolve_n_slabs(m_loc)
        hidden = comm_hidden_elems(
            m_loc, self.k // self.g_k, ps_rev, qs_rev, self.g_k,
            rounds=self.rounds, batch=comm_batch, n_slabs=n,
        )
        # Critical path: per-device compute at the dtype's peak, the EXPOSED
        # transfer at ICI_BW, one launch latency per collective issued.
        peak = (
            autotune.PEAK_FLOPS if self._dtype_bytes <= 2
            else autotune.PEAK_FLOPS_F32
        )
        critical = (
            flops / (self.g_m * self.g_k) / peak
            + (comm - hidden) * self._dtype_bytes / autotune.ICI_BW
            + len(self.rounds) * n * autotune.A2A_LATENCY_S
        )
        return KronCost(flops, comm, len(self.rounds), hidden, n, critical)

    def profile(
        self,
        x: jax.Array,
        factors: Sequence[jax.Array],
        *,
        warmup: int = 1,
        iters: int = 3,
        drift_threshold: float | None = None,
    ) -> dict:
        """Measure the lowered StageProgram stage by stage and compare the
        wall-clock split against the planner's analytic cost model.

        Each stage of the op's forward program is executed eagerly (the same
        ``emit.run_stage`` calls ``run_program`` chains) with
        ``jax.block_until_ready`` timing — min over ``iters`` runs after
        ``warmup`` discarded ones.  The analytic prediction per stage is the
        planner's own two-term model (flops/peak + bytes/bandwidth); a stage
        whose measured share deviates from its predicted share by more than
        ``drift_threshold`` (default ``telemetry.DRIFT_THRESHOLD``) in either
        direction is flagged as cost-model drift (see ``_stage_drift`` for
        why the SPLIT, not the absolute ratio, is the contract).

        Mesh ops profile their local-equivalent plan — per-stage timing
        inside a ``shard_map`` body is not observable from the host — and the
        report carries the analytic collective cost as predicted-only under
        ``"comm"``.  ``plan=None`` (paper-faithful unfused) ops have no
        StageProgram and raise ``PlanError``.

        When telemetry is active the report is stamped into the registry
        (``telemetry.mark_profile``) and each flagged stage emits a
        ``cost_model_drift`` event; with telemetry off the dict is simply
        returned.
        """
        factors = tuple(factors)
        self._check_factors(factors)
        threshold = (
            telemetry.DRIFT_THRESHOLD
            if drift_threshold is None
            else float(drift_threshold)
        )
        op = self._derive(mesh=None, m=None) if self.mesh is not None else self
        report = op._profile_stages(
            x, factors, warmup=int(warmup), iters=int(iters), threshold=threshold
        )
        if self.mesh is not None:
            cost = self.cost(report["signature"]["m"])
            report["signature"]["mesh"] = [self.g_m, self.g_k]
            report["comm"] = {
                "elems_per_device": cost.comm_elems_per_device,
                "rounds": cost.rounds,
                "n_slabs": cost.n_slabs,
                "hidden_elems": cost.comm_hidden_elems,
                "critical_path_s": cost.critical_path_s,
                "predicted_s": cost.comm_elems_per_device
                * self._dtype_bytes
                / autotune.HBM_BW,
                "measured_s": None,  # rounds run inside shard_map bodies
            }
            # Reconcile the analytic overlap term against the per-slab
            # telemetry gauges (comm.round{k}.slab{s}.elems_per_device): the
            # registry's hidden total is per-round ``total - max(slab)``,
            # which equals the model's ``payload - payload/n`` when the
            # executor ran the schedule cost() predicted.
            tele = telemetry.comm_summary()
            if tele:
                observed_hidden = sum(r["hidden"] for r in tele.values())
                report["comm"]["telemetry_hidden_elems"] = observed_hidden
                report["comm"]["telemetry_rounds"] = tele
        telemetry.mark_profile(report)
        for i in report["drift_flagged"]:
            st = report["stages"][i]
            telemetry.event(
                "cost_model_drift",
                stage=i,
                drift=st["drift"],
                instr=st["instr"],
            )
        return report

    def _profile_stages(
        self, x: jax.Array, factors: tuple, *, warmup: int, iters: int,
        threshold: float,
    ) -> dict:
        dtype_bytes = x.dtype.itemsize
        if self.batch is not None and not self.shared_factors:
            b = self.batch
            m_rows = math.prod(int(d) for d in x.shape[1:-1]) or 1
            plan = self._batched_plan(b, m_rows, dtype_bytes)
            batched = True
            y = x.reshape(b, m_rows, self.k)
        else:
            rows = math.prod(int(d) for d in x.shape[:-1]) or 1
            plan = self._single_plan(rows, dtype_bytes)
            batched = False
            y = x.reshape(rows, self.k)
            m_rows = rows // (self.batch or 1)
        if plan is None:
            raise guard.PlanError(
                "profile() needs a planned op (plan='auto' or an explicit "
                "KronPlan): plan=None runs the paper-faithful unfused loop, "
                "which has no StageProgram to time stage by stage"
            )
        prog = _lowered(plan, self.ps, self.qs, batched)
        rev = tuple(reversed(factors))
        peak = (
            autotune.PEAK_FLOPS if dtype_bytes <= 2 else autotune.PEAK_FLOPS_F32
        )
        stages: list[dict] = []
        measured: list[float] = []
        predicted: list[float] = []
        with telemetry.span("profile", ps=self.ps, qs=self.qs):
            for idx, instr in enumerate(prog.instrs):
                sf = tuple(rev[i] for i in instr.factor_ids)
                y_in = y

                def run(y_in=y_in, sf=sf, instr=instr):
                    return emit.run_stage(y_in, sf, instr, backend=self.backend)

                for _ in range(max(0, warmup)):
                    jax.block_until_ready(run())
                best = float("inf")
                out = None
                for _ in range(max(1, iters)):
                    t0 = time.perf_counter()
                    out = run()
                    jax.block_until_ready(out)
                    best = min(best, time.perf_counter() - t0)
                flops, nbytes = _stage_flops_bytes(y.shape, instr, dtype_bytes)
                pred = flops / peak + nbytes / autotune.HBM_BW
                measured.append(best)
                predicted.append(pred)
                stages.append(
                    {
                        "stage": idx,
                        "instr": instr.describe(),
                        "factor_ids": list(instr.factor_ids),
                        "measured_s": best,
                        "predicted_s": pred,
                        "flops": flops,
                        "bytes": nbytes,
                    }
                )
                y = out
        flags = _stage_drift(measured, predicted, threshold)
        total_m = sum(measured)
        total_p = sum(predicted)
        overall = total_m / total_p if total_p > 0 else float("nan")
        for st, m_i, p_i, flag in zip(stages, measured, predicted, flags):
            st["share_measured"] = m_i / total_m if total_m > 0 else 0.0
            st["share_predicted"] = p_i / total_p if total_p > 0 else 0.0
            st["drift"] = (
                (m_i / p_i) / overall
                if p_i > 0 and overall == overall
                else float("inf")
            )
            st["drift_flagged"] = flag
        cost = self.cost(m_rows)
        return {
            "signature": {
                "ps": list(self.ps),
                "qs": list(self.qs),
                "m": m_rows,
                "batch": self.batch,
                "backend": self.backend,
            },
            "plan": plan.describe(),
            "program": prog.describe(),
            "stages": stages,
            "measured_s": total_m,
            "predicted_s": total_p,
            "cost_flops": cost.flops,
            "measured_gflops_s": (
                cost.flops / total_m / 1e9 if total_m > 0 else 0.0
            ),
            "drift_threshold": threshold,
            "drift_flagged": [i for i, f in enumerate(flags) if f],
            "warmup": warmup,
            "iters": iters,
        }

    def describe(self) -> str:
        mode = "batched" if self.batch is not None else "single"
        shared = "" if self.batch is None else (
            ", shared" if self.shared_factors else ", per-sample"
        )
        where = (
            f"mesh({self.g_m}x{self.g_k})" if self.mesh is not None else "local"
        )
        plan = self.plan
        if plan is not None:
            pdesc = plan.describe()
        elif self.rounds is not None:
            pdesc = f"rounds{list(self.rounds)}"  # mesh path: the schedule IS the plan
        else:
            pdesc = "unfused"
        base = (
            f"KronOp(ps={list(self.ps)}, qs={list(self.qs)}, {mode}"
            f"{shared}, {where}, backend={self.backend}) :: {pdesc}"
        )
        return base + self._health_suffix() + self._telemetry_suffix()

    def _telemetry_suffix(self) -> str:
        """One-line KronScope state when telemetry is live — empty when off,
        so ``describe()`` stays byte-stable for untelemetered processes."""
        if not telemetry.active():
            return ""
        return " :: " + telemetry.summary_line()

    def _health_suffix(self) -> str:
        """Guard-layer health for this op's signature — empty while healthy,
        a `:: guard[...]` tail once any ladder keyed on (ps, qs) degraded."""
        parts = []
        for key, h in guard.health_entries():
            if (
                isinstance(key, tuple)
                and len(key) >= 3
                and key[1] == self.ps
                and key[2] == self.qs
                and (h.degraded_calls or h.pinned or h.errors)
            ):
                rung = f"rung={h.rung}{' pinned' if h.pinned else ''}"
                errs = ",".join(f"{k}x{v}" for k, v in sorted(h.errors.items()))
                parts.append(
                    f"{key[0]}: {rung} degraded={h.degraded_calls}/{h.calls}"
                    + (f" [{errs}]" if errs else "")
                )
        return f" :: guard[{'; '.join(parts)}]" if parts else ""

    def __repr__(self) -> str:
        return self.describe()

    # -- execution -----------------------------------------------------------

    def _check_factors(self, factors: tuple[jax.Array, ...]):
        shared = self.batch is None or self.shared_factors
        ps, qs = signature_of(factors, shared)
        if (ps, qs) != (self.ps, self.qs):
            raise ValueError(
                f"factor shapes {ps}x{qs} do not match op signature "
                f"{self.ps}x{self.qs}"
            )
        if not shared:
            for f in factors:
                if int(f.shape[0]) != self.batch:
                    raise ValueError(
                        f"factor batch {f.shape[0]} != x batch {self.batch}"
                    )

    def __call__(self, x: jax.Array, factors: Sequence[jax.Array]) -> jax.Array:
        factors = tuple(factors)
        self._check_factors(factors)
        if self.batch is None:
            if x.shape[-1] != self.k:
                raise ValueError(
                    f"x last dim {x.shape[-1]} != prod(P)={self.k} for {self.ps}"
                )
            if self.mesh is not None:
                return self._run_mesh_single(x, factors)
            lead = x.shape[:-1]
            m = math.prod(lead) if lead else 1
            fn = self._ensure_single(m, x.dtype.itemsize)
            y = fn(x.reshape(m, self.k), factors)
            return y.reshape(*lead, self.k_out)
        # batched modes
        if x.ndim < 2:
            raise ValueError(
                f"x needs a leading batch axis: (B, ..., K), got {x.shape}"
            )
        if int(x.shape[0]) != self.batch:
            raise ValueError(f"x batch {x.shape[0]} != op batch {self.batch}")
        if x.shape[-1] != self.k:
            raise ValueError(
                f"x last dim {x.shape[-1]} != prod(P)={self.k} for {self.ps}"
            )
        b = self.batch
        lead = x.shape[1:-1]
        m = math.prod(lead) if lead else 1
        if self.shared_factors:
            # Collapse B into M and run the single-problem spine: both are
            # pure row indices of the same contiguous array.
            if self.mesh is not None:
                y = self._run_mesh_single(x.reshape(b * m, self.k), factors)
            else:
                fn = self._ensure_single(b * m, x.dtype.itemsize)
                y = fn(x.reshape(b * m, self.k), factors)
            return y.reshape(b, *lead, self.k_out)
        if self.mesh is not None:
            if x.ndim != 3:
                raise ValueError(f"x must be (B, M, K), got shape {x.shape}")
            return self._run_mesh_batched(x, factors)
        fn = self._ensure_batched(b, m, x.dtype.itemsize)
        y = fn(x.reshape(b, m, self.k), factors)
        return y.reshape(b, *lead, self.k_out)

    def _run_mesh_single(self, x, factors):
        from . import distributed

        if x.ndim != 2:
            raise ValueError(f"distributed op expects x (M, K), got {x.shape}")
        n_slabs = self._resolve_n_slabs(max(1, int(x.shape[0]) // self.g_m))

        def _mesh_slabbed():
            return distributed.run_distributed_rounds(
                x, factors, self.mesh,
                data_axis=self.data_axis, model_axis=self.model_axis,
                backend=self.backend, per_iteration=self.per_iteration,
                n_slabs=n_slabs,
            )

        def _mesh():
            return distributed.run_distributed_rounds(
                x, factors, self.mesh,
                data_axis=self.data_axis, model_axis=self.model_axis,
                backend=self.backend, per_iteration=self.per_iteration,
            )

        def _local():
            fn = self._ensure_single(int(x.shape[0]), x.dtype.itemsize)
            return fn(x, factors)

        # Mesh ladder: a failed slab relocation degrades to the serial round
        # schedule, a failed round to single-host execution on the
        # (replicated) operands — same contraction, no collectives.  Only
        # CollectiveError degrades; anything else is a bug.
        rungs = (("mesh-rounds", _mesh), ("local", _local))
        if n_slabs > 1:
            rungs = (("mesh-slabbed", _mesh_slabbed),) + rungs
        return guard.run_ladder(
            ("mesh", self.ps, self.qs, self.backend, "single"),
            rungs,
            catch=(guard.CollectiveError,),
        )

    def _run_mesh_batched(self, x, factors):
        from . import distributed

        b, m = int(x.shape[0]), int(x.shape[1])
        key = ("mesh-batched", b, m, x.dtype.itemsize)
        plan = self._plans.get(key)
        if plan is None:
            plan = self._remember(
                self._plans, key,
                self._batched_plan(b, max(1, m // self.g_m), x.dtype.itemsize),
            )
        n_slabs = self._resolve_n_slabs(max(1, m // self.g_m), plan)

        def _mesh_slabbed():
            return distributed.run_batched_distributed_rounds(
                x, factors, self.mesh, t_b=plan.t_b,
                data_axis=self.data_axis, model_axis=self.model_axis,
                backend=self.backend, per_iteration=self.per_iteration,
                n_slabs=n_slabs,
            )

        def _mesh():
            return distributed.run_batched_distributed_rounds(
                x, factors, self.mesh, t_b=plan.t_b,
                data_axis=self.data_axis, model_axis=self.model_axis,
                backend=self.backend, per_iteration=self.per_iteration,
            )

        def _local():
            fn = self._ensure_batched(b, m, x.dtype.itemsize)
            return fn(x, factors)

        rungs = (("mesh-rounds", _mesh), ("local", _local))
        if n_slabs > 1:
            rungs = (("mesh-slabbed", _mesh_slabbed),) + rungs
        return guard.run_ladder(
            ("mesh", self.ps, self.qs, self.backend, "batched"),
            rungs,
            catch=(guard.CollectiveError,),
        )


# ---------------------------------------------------------------------------
# Bounded op factory (the shim path) + deprecation bookkeeping
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def kron_op_for(
    ps: tuple[int, ...],
    qs: tuple[int, ...],
    *,
    m: int | None = None,
    batch: int | None = None,
    shared_factors: bool = True,
    mesh=None,
    data_axis="data",
    model_axis: str = "model",
    per_iteration: bool = False,
    backend: str = "auto",
    plan: KronPlan | str | None = "auto",
    tune: str = "analytic",
    cache_path: str | None = None,
    dtype_bytes: int = 4,
    enable_prekron: bool | None = None,
    n_slabs: int | str = "auto",
) -> KronOp:
    """Shared, bounded ``KronOp`` factory: same signature -> same op object.

    This is the cache behind the legacy ``kron_matmul*`` shims and the
    consumers that key ops on runtime shapes (layers, GP kernels, serving).
    Plans themselves are additionally shared through the engine's bounded
    plan memo, so even two DISTINCT ops with one signature hold one plan.
    """
    return KronOp(
        ps, qs, m=m, batch=batch, shared_factors=shared_factors, mesh=mesh,
        data_axis=data_axis, model_axis=model_axis,
        per_iteration=per_iteration, backend=backend, plan=plan, tune=tune,
        cache_path=cache_path, dtype_bytes=dtype_bytes,
        enable_prekron=enable_prekron, n_slabs=n_slabs,
    )


def kron_precond_op(
    p: int, q: int, batch: int, *, dtype_bytes: int = 4, backend: str = "auto"
) -> KronOp:
    """The op behind one Kron-factored-preconditioner shape group.

    A Shampoo-style update ``P_l = A_l G_l B_l`` (per-layer root pairs
    ``A_l = L_l^{-1/4}``, ``B_l = R_l^{-1/4}``) over ``batch`` same-shape
    ``(p, q)`` layers is exactly ONE per-sample-factor batched Kron-Matmul:
    ``x = vec_row(G)`` stacked to ``(B, 1, p*q)``, ``factors = (A, B)``
    stacked to ``((B, p, p), (B, q, q))`` — ``row @ (A (x) B) ==
    vec_row(A^T G B)``, and the roots are symmetric.  Resolved through the
    shared bounded factory so constructing it at step-builder time IS the
    prewarming: the traced update hits this op object, never a re-plan.

    Pre-kronization is forced OFF: densifying ``kron(A_l, B_l)`` is a
    ``(p*q)^2`` buffer per layer per step — the exact materialization the
    Kron-factored preconditioner exists to avoid.
    """
    if batch <= 0:
        raise ValueError(f"batch must be positive, got {batch}")
    return kron_op_for(
        (int(p), int(q)), (int(p), int(q)), m=1, batch=int(batch),
        shared_factors=False, backend=backend, dtype_bytes=dtype_bytes,
        enable_prekron=False,
    )


_DEPRECATION_WARNED: set[str] = set()


def warn_deprecated(name: str, hint: str) -> None:
    """Emit ONE DeprecationWarning per process per legacy entry point."""
    if name in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated: construct a repro.core.KronOp once "
        f"({hint}) and call it; the shim re-dispatches through a bounded "
        "op cache on every call.",
        DeprecationWarning,
        stacklevel=3,
    )


__all__ = [
    "KronOp",
    "KronCost",
    "kron_op_for",
    "kron_precond_op",
    "signature_of",
    "kron_matmul_p",
    "kron_matmul_batched_p",
]
