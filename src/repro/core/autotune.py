"""Autotuner for FastKron tile sizes + execution plans (contribution C5).

The paper's autotuner compiles ~10k CUDA kernels and times them.  On TPU the
equivalent search space is the Pallas block shapes; since this container has
no TPU, candidates are scored *analytically* with a two-term (compute, HBM)
model that knows the MXU's 128x128 systolic shape and the (8,128) VMEM tile —
the same "narrow by resource limits, then rank" structure as the paper's §4.3.
``tune="measure"`` ranks the narrowed candidates by wall clock instead
(``measure_best``), for use on real hardware — and persists the winner in an
on-disk JSON plan cache keyed by (M, Ps, Qs, dtype, backend) so repeated
calls and the benchmark harness skip both Python planning overhead and
re-measurement (format documented in EXPERIMENTS.md §Plan-cache).

Plan construction additionally decides, per the paper + our beyond-paper
extensions:

  * fusion grouping (C3): how many consecutive factors one kernel chains,
    bounded by ``N_fused = floor(log_P T_K)`` and the VMEM budget — with
    per-factor Q-tiling (``Stage.t_qs``) to keep fusion legal when
    ``prod(Q)/prod(P)`` alone would blow the budget;
  * factor pre-kronization (beyond paper): explicitly form F^i (x) F^{i+1}
    when P is too small to feed the MXU's 128-deep contraction;
  * a BACKWARD plan (``KronPlan.bwd_stages``): the mirrored stages executed
    by the VJP — per-stage transposed chains + factor-gradient contractions —
    with tiles tuned for the transposed shapes;
  * a BATCH tile (``KronPlan.t_b``, ``make_batched_plan``): samples per
    block for the per-sample-factor batch-grid kernels, traded against the
    M-tile — and, in distributed mode (``g_k > 1``), against the per-round
    relocation payload — under the same VMEM budget.

Plan fields and how the planner picks them: docs/architecture.md#kronplan;
cache location/format: docs/api.md#plan-cache.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import math
import os
import tempfile
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from ..kernels import emit as emit_mod
from ..kernels.emit import StageInstr, StageProgram, fused_growth
from ..runtime import chaos, guard, telemetry
from .kron import KronProblem

# TPU v5e hardware model (same constants as EXPERIMENTS.md).
PEAK_FLOPS = 197e12  # bf16
PEAK_FLOPS_F32 = 98.5e12
HBM_BW = 819e9  # bytes/s
VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128
SUBLANE = 8

# Interconnect model for the distributed slab pipeline (TPU v5e ICI): the
# per-device all_to_all streams at ICI_BW and each collective launch pays
# A2A_LATENCY_S regardless of payload.  Slabbing a round multiplies the
# latency term by n_slabs while letting up to (n-1)/n of the payload hide
# under chain compute — so the analytic model only picks n_slabs > 1 once
# per-round payloads clear the ~latency*BW product (~100 KB), which keeps
# every small test problem on the serial schedule.  Host-mesh collectives
# run at memcpy speed, so ``tune="measure"`` (not this model) owns the final
# call on real fabrics — see ``make_batched_plan``.
ICI_BW = 45e9  # bytes/s per device
A2A_LATENCY_S = 1e-6

PLAN_CACHE_VERSION = 1


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


@dataclasses.dataclass(frozen=True)
class TileConfig:
    t_m: int
    t_s: int  # slices per block (T_K = t_s * P)
    t_q: int

    @property
    def as_tuple(self) -> tuple[int, int, int]:
        return (self.t_m, self.t_s, self.t_q)


def vmem_elems(cfg: TileConfig, p: int, growth: float = 1.0) -> int:
    """f32-elements resident per block (x tile, f tile, y tile), x2 buffered."""
    x_t = cfg.t_m * cfg.t_s * p
    f_t = p * cfg.t_q
    y_t = int(cfg.t_m * cfg.t_q * cfg.t_s * growth)
    return 2 * (x_t + f_t + y_t)


def predict_seconds(
    prob_m: int, s: int, p: int, q: int, cfg: TileConfig, dtype_bytes: int = 4
) -> float:
    """Two-term analytic time model for one sliced multiply on one chip."""
    flops = 2.0 * prob_m * s * p * q
    # MXU utilization: contraction dim padded to 128, lanes to 128, rows to 8.
    u_c = p / _ceil_to(p, MXU_DIM)
    u_q = cfg.t_q / _ceil_to(cfg.t_q, MXU_DIM)
    rows = cfg.t_m * cfg.t_s
    u_r = rows / _ceil_to(rows, SUBLANE)
    peak = PEAK_FLOPS if dtype_bytes <= 2 else PEAK_FLOPS_F32
    t_compute = flops / (peak * max(u_c * u_q * u_r, 1e-6))
    # HBM traffic: X re-read once per Q-tile sweep; F negligible; Y written once.
    x_bytes = prob_m * s * p * dtype_bytes * (q // cfg.t_q)
    y_bytes = prob_m * s * q * dtype_bytes
    f_bytes = p * q * dtype_bytes * (prob_m // cfg.t_m) * (s // cfg.t_s)
    t_mem = (x_bytes + y_bytes + f_bytes) / HBM_BW
    return max(t_compute, t_mem)


def candidate_tiles(m: int, s: int, p: int, q: int) -> list[TileConfig]:
    """Paper §4.3 search-space narrowing, restated for Pallas blocks."""
    t_ms = [t for t in (1, 2, 4, 8, 16, 32) if t <= m and m % t == 0]
    t_ss = [t for t in _divisors(s) if t <= 2048]
    # keep lane-friendly slice tiles preferentially but allow all divisors
    t_qs = _divisors(q)
    out = []
    for t_m, t_s, t_q in itertools.product(t_ms, t_ss, t_qs):
        cfg = TileConfig(t_m, t_s, t_q)
        if vmem_elems(cfg, p) * 4 > VMEM_BYTES * 3 // 4:
            continue  # resource-limit pruning (paper: smem + regs cap)
        out.append(cfg)
    return out


def tune_sliced(
    m: int, s: int, p: int, q: int, *, dtype_bytes: int = 4
) -> TileConfig:
    """Best analytic tile config for a single sliced multiply."""
    cands = candidate_tiles(m, s, p, q)
    if not cands:
        return TileConfig(min(m, 8), 1, 1)
    return min(cands, key=lambda c: predict_seconds(m, s, p, q, c, dtype_bytes))


def measure_best(
    fn_of_cfg: Callable[[object], Callable[[], jax.Array]],
    cands: Sequence[object],
    *,
    warmup: int = 2,
    iters: int = 5,
) -> tuple[object, float]:
    """Wall-clock ranking of candidates (for real hardware).

    Generic over the candidate type: tile configs for one kernel, or whole
    ``KronPlan``s in ``make_plan(tune="measure")``.
    """
    best, best_t = None, float("inf")
    for cfg in cands:
        try:
            fn = fn_of_cfg(cfg)
            for _ in range(warmup):
                jax.block_until_ready(fn())
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(fn())
            dt = (time.perf_counter() - t0) / iters
        except Exception:
            continue
        if dt < best_t:
            best, best_t = cfg, dt
    if best is None:
        raise guard.PlanError("no candidate executed successfully")
    return best, best_t


# ---------------------------------------------------------------------------
# Plan: pairing + fusion grouping + tiles per stage (+ mirrored backward)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stage:
    """One kernel launch: chain ``factor_ids`` (in application order, i.e.
    reversed problem order) inside a single fused kernel.

    ``prekron=True`` means the stage's factors are first combined into their
    explicit Kronecker product (beyond-paper MXU-utilization optimization)
    and applied as ONE sliced multiply.

    ``t_qs`` (fused stages only; application order, one entry per factor)
    tiles the composite Q axis of the fused kernel so its in-VMEM growth is
    bounded by ``prod(t_qs)/prod(P)`` — None means no Q-tiling.

    ``acc_dtype`` (a dtype name, e.g. ``"float32"``) is THIS stage's
    accumulation dtype — per-stage dtype policies flow from here through
    ``lower`` into the emitted kernels and the VJP.  None promotes the input
    dtype against f32 (the historical behavior).
    """

    factor_ids: tuple[int, ...]
    prekron: bool
    tiles: TileConfig
    t_qs: tuple[int, ...] | None = None
    acc_dtype: str | None = None


@dataclasses.dataclass(frozen=True)
class KronPlan:
    stages: tuple[Stage, ...]
    # Backward stages in EXECUTION order (last forward stage first); None
    # falls back to a derived mirror of ``stages`` at run time.
    bwd_stages: tuple[Stage, ...] | None = None
    # Batch tile for the batched (per-sample-factors) execution path: how many
    # samples one kernel block carries.  The batched kernels' VMEM legality is
    # ``t_b * t_m * t_k * growth <= budget`` — make_batched_plan trades the
    # M-tile against this axis.  1 == unbatched semantics (ignored by the
    # single-problem path).
    t_b: int = 1
    # Slab-pipeline depth for the DISTRIBUTED rounds: how many row slabs each
    # mesh round is split into so one slab's all_to_all overlaps the next
    # slab's chain.  1 == the serial round schedule; only the mesh path reads
    # it (local execution ignores it, like the single-problem path ignores
    # t_b).  make_batched_plan(g_k>1) trades this axis against t_b under the
    # VMEM budget: more slabs shrink the resident relocation payload.
    n_slabs: int = 1

    def describe(self) -> str:
        parts = []
        for st in self.stages:
            kind = "prekron" if st.prekron else ("fused" if len(st.factor_ids) > 1 else "sliced")
            tag = f"{kind}{list(st.factor_ids)}@{st.tiles.as_tuple}"
            if st.t_qs is not None:
                tag += f"/tq{list(st.t_qs)}"
            parts.append(tag)
        head = f"[t_b={self.t_b}] " if self.t_b != 1 else ""
        if self.n_slabs != 1:
            head += f"[slabs={self.n_slabs}] "
        return head + " -> ".join(parts)


def mirror_bwd_stages(
    prob: KronProblem, stages: Sequence[Stage], *, dtype_bytes: int = 4
) -> tuple[Stage, ...]:
    """Backward stages for a forward plan: same grouping, reversed execution
    order, tiles tuned for the transposed contraction (P and Q swap roles)."""
    ps = list(reversed(prob.ps))
    qs = list(reversed(prob.qs))
    # Column count at each stage OUTPUT (the backward stage's input).
    k = prob.k
    outs = []
    for st in stages:
        pprod = math.prod(ps[i] for i in st.factor_ids)
        qprod = math.prod(qs[i] for i in st.factor_ids)
        k = k // pprod * qprod
        outs.append((st, pprod, qprod, k))
    bwd = []
    for st, pprod, qprod, k_out in reversed(outs):
        s = k_out // qprod
        tiles = tune_sliced(prob.m, s, qprod, pprod, dtype_bytes=dtype_bytes)
        bwd.append(Stage(st.factor_ids, st.prekron, tiles, st.t_qs, st.acc_dtype))
    return tuple(bwd)


def lower(
    plan: KronPlan,
    ps: Sequence[int],
    qs: Sequence[int],
    *,
    batched: bool = False,
    acc_dtype: str | None = None,
) -> StageProgram:
    """Lower a ``KronPlan`` into the emitter's ``StageProgram`` IR.

    This is the single contract between planning and execution: one typed
    instruction per stage (``multiply`` or ``prekron``), each carrying its
    per-factor ``(p_i, q_i)`` list, its tiles (``t_k = t_s * prod(P)``), its
    batch tile (``t_b=None`` when ``batched=False`` — batch is then just a
    leading grid axis, not a separate code path), its accumulation dtype
    (``Stage.acc_dtype``, falling back to ``acc_dtype``), and the tuned
    transposed M-tile from ``plan.bwd_stages`` so ``emit.transpose`` can swap
    it in mechanically.  ``ps``/``qs`` are the problem-order factor dims.
    """
    rps = tuple(reversed(tuple(int(p) for p in ps)))
    rqs = tuple(reversed(tuple(int(q) for q in qs)))
    bwd_sts = plan.bwd_stages or tuple(reversed(plan.stages))
    n_st = len(plan.stages)
    instrs = []
    for i, st in enumerate(plan.stages):
        sps = tuple(rps[j] for j in st.factor_ids)
        sqs = tuple(rqs[j] for j in st.factor_ids)
        bst = bwd_sts[n_st - 1 - i]
        t_qs = st.t_qs
        if t_qs is None and (st.prekron or len(st.factor_ids) == 1):
            # Single-multiply stages (one factor, or a prekron product): the
            # stage's TUNED Q-tile is tiles.t_q — without it the chain
            # template would see full Q and huge-Q factors would fail the
            # VMEM growth check that the old kron_sliced kernel's t_q tiling
            # made irrelevant.  Injected ONLY when full-Q growth actually
            # overflows the budget: everything else keeps t_qs=None so the
            # emitted grid matches the pre-refactor kernels exactly, and
            # placeholder tiles (t_q=1 in engine-built fallback plans) are
            # never mistaken for a tuned Q-tile.  Prekron stages' tiles are
            # tuned for the combined product, so the 1-tuple applies to it
            # (run_stage keeps a length-1 t_qs across the substitution).
            eff_p = math.prod(sps)
            eff_q = math.prod(sqs)
            t_k = st.tiles.t_s * eff_p
            full = st.tiles.t_m * t_k * max(1.0, eff_q / eff_p)
            if (
                (plan.t_b if batched else 1) * full > emit_mod.VMEM_BUDGET_ELEMS
                and 1 < st.tiles.t_q < eff_q
                and eff_q % st.tiles.t_q == 0
            ):
                t_qs = (st.tiles.t_q,)
        instrs.append(
            StageInstr(
                kind=emit_mod.PREKRON if st.prekron else emit_mod.MULTIPLY,
                ps=sps,
                qs=sqs,
                factor_ids=st.factor_ids,
                t_m=st.tiles.t_m,
                t_k=st.tiles.t_s * math.prod(sps),
                t_qs=t_qs,
                t_b=plan.t_b if batched else None,
                acc_dtype=st.acc_dtype if st.acc_dtype is not None else acc_dtype,
                t_m_bwd=bst.tiles.t_m,
            )
        )
    return StageProgram(tuple(instrs), len(rps))


def make_plan(
    prob: KronProblem,
    *,
    dtype_bytes: int = 4,
    enable_fusion: bool = True,
    enable_prekron: bool = True,
    prekron_max_p: int = 16,
    prekron_max_dim: int = 256,
    vmem_budget_elems: int = 2 * 1024 * 1024,
    tune: str = "analytic",
    backend: str = "auto",
    cache_path: str | None = None,
    acc_dtype: str | None = None,
) -> KronPlan:
    """Greedy plan over the reversed factor list (application order).

    Stage selection per position i (0 = last factor, applied first):
      1. If P_i and P_{i+1} are both small, pre-kronize the pair (MXU win).
      2. Else fuse as many consecutive factors as N_fused/VMEM allow (C3),
         Q-tiling factors whose growth would otherwise end the group.
      3. Else a single tuned sliced multiply.

    ``acc_dtype`` stamps every stage's accumulation dtype (per-stage policies
    are set by replacing individual ``Stage.acc_dtype`` fields); None keeps
    the promote-against-f32 default.

    ``tune="measure"`` wall-clock-ranks a narrowed set of plan variants via
    ``measure_best`` — the candidates are EMITTED as StagePrograms and timed
    through ``kernels.emit`` — and memoizes the winner in the on-disk plan
    cache.
    """
    if tune == "measure":
        return _measured_plan(
            prob,
            dtype_bytes=dtype_bytes,
            enable_fusion=enable_fusion,
            enable_prekron=enable_prekron,
            prekron_max_p=prekron_max_p,
            prekron_max_dim=prekron_max_dim,
            vmem_budget_elems=vmem_budget_elems,
            backend=backend,
            cache_path=cache_path,
            acc_dtype=acc_dtype,
        )
    if tune != "analytic":
        raise guard.PlanError(f"unknown tune mode {tune!r}")
    ps = list(reversed(prob.ps))
    qs = list(reversed(prob.qs))
    n = len(ps)
    stages: list[Stage] = []
    k = prob.k
    i = 0
    while i < n:
        p, q = ps[i], qs[i]
        # -- beyond-paper pre-kronization --
        if (
            enable_prekron
            and i + 1 < n
            and p <= prekron_max_p
            and ps[i + 1] <= prekron_max_p
            and p * ps[i + 1] <= prekron_max_dim
            and q * qs[i + 1] <= prekron_max_dim
        ):
            pp, qq = p * ps[i + 1], q * qs[i + 1]
            s = k // pp
            tiles = tune_sliced(prob.m, s, pp, qq, dtype_bytes=dtype_bytes)
            stages.append(Stage((i, i + 1), True, tiles, None, acc_dtype))
            k = s * qq
            i += 2
            continue
        # -- C3 fusion grouping (VMEM-bounded, with Q-tiling relief) --
        group = [i]
        group_tqs = [q]
        if enable_fusion:
            pprod, tqprod = p, q
            j = i + 1
            while j < n:
                np_ = pprod * ps[j]
                if np_ > k:
                    break  # N_fused cap: T_K can hold at most log_P K factors
                # Largest Q-tile of factor j whose growth fits the budget with
                # a T_M of 8 (T_K refined below); full Q when it already fits.
                tq_j = None
                for cand in sorted(_divisors(qs[j]), reverse=True):
                    growth = max(1.0, tqprod * cand / np_)
                    if 8 * np_ * growth * 4 <= vmem_budget_elems:
                        tq_j = cand
                        break
                if tq_j is None:
                    break
                pprod, tqprod = np_, tqprod * tq_j
                group.append(j)
                group_tqs.append(tq_j)
                j += 1
        pprod = math.prod(ps[g] for g in group)
        qprod = math.prod(qs[g] for g in group)
        s = k // pprod
        if len(group) > 1:
            # Repair pass: the grouping loop's fit proxy measures growth
            # against the RUNNING prefix product, but the emitted tile's
            # T_K is a multiple of the FULL prod(P) — and the first factor
            # is admitted with full Q unchecked — so early-prefix growth
            # can exceed the budget even at the minimal (t_m=1, t_s=1)
            # tile.  Shrink the worst-contributing Q-tile until it fits
            # (t_qs=1 everywhere bounds growth at 1, so this terminates).
            sps = [ps[g] for g in group]
            sqs = [qs[g] for g in group]
            while (
                pprod * fused_growth(sps, sqs, group_tqs) > vmem_budget_elems
                and any(t > 1 for t in group_tqs)
            ):
                i_big = max(
                    range(len(group_tqs)),
                    key=lambda j: group_tqs[j] / sps[j],
                )
                group_tqs[i_big] = max(
                    (d for d in _divisors(sqs[i_big]) if d < group_tqs[i_big]),
                    default=1,
                )
        tiles = tune_sliced(prob.m, s, pprod, qprod, dtype_bytes=dtype_bytes)
        t_qs = tuple(group_tqs) if group_tqs != [qs[g] for g in group] else None
        if len(group) > 1:
            # Clamp (T_M, T_K = t_s * prod(P)) so the fused tile respects the
            # budget (the grouping loop guaranteed a fit at T_M=8, t_s=1).
            growth = fused_growth([ps[g] for g in group], [qs[g] for g in group], t_qs)
            t_m = tiles.t_m
            while t_m > 1 and t_m * pprod * growth > vmem_budget_elems:
                t_m = max(d for d in _divisors(prob.m) if d < t_m)
            max_ts = max(1, int(vmem_budget_elems // (t_m * pprod * growth)))
            ts = tiles.t_s
            if ts > max_ts:
                ts = max(d for d in _divisors(s) if d <= max_ts)
            if (t_m, ts) != (tiles.t_m, tiles.t_s):
                tiles = TileConfig(t_m, ts, tiles.t_q)
        stages.append(Stage(tuple(group), False, tiles, t_qs, acc_dtype))
        k = s * qprod
        i = group[-1] + 1
    fwd = tuple(stages)
    return KronPlan(fwd, mirror_bwd_stages(prob, fwd, dtype_bytes=dtype_bytes))


# ---------------------------------------------------------------------------
# Batched plans: B independent problems (kron_matmul_batched)
# ---------------------------------------------------------------------------


def _dist_round_payload_elems(prob: KronProblem, g_k: int) -> int:
    """Worst-round per-sample relocation slab for the batched DISTRIBUTED
    path: one device's all_to_all staging buffer holds ``M_loc * C`` elements
    per sample at the round's output width ``C`` (the ``(G_K-1)/G_K`` send
    fraction still occupies the buffer — received chunks land in place).
    ``prob`` is the LOCAL problem (``m = M_loc``); columns start at
    ``K / G_K``.  Returns 0 when the mesh has no model axis or the round
    schedule is infeasible (the caller then plans compute-only)."""
    if g_k <= 1:
        return 0
    from .distributed import plan_rounds

    ps = list(reversed(prob.ps))
    qs = list(reversed(prob.qs))
    k_loc = prob.k // g_k
    try:
        rounds = plan_rounds(k_loc, ps, qs, g_k)
    except ValueError:
        return 0
    worst = 0
    c = k_loc
    i = 0
    for r in rounds:
        c = c // math.prod(ps[i : i + r]) * math.prod(qs[i : i + r])
        worst = max(worst, prob.m * c)
        i += r
    return worst


def _dist_round_costs(
    prob: KronProblem, g_k: int, batch: int, dtype_bytes: int
) -> list[tuple[float, float]]:
    """Per-round ``(compute_s, comm_s)`` on one device of the mesh round
    schedule: chain flops against the dtype's peak, all_to_all payload
    against ``ICI_BW``.  ``prob`` is the LOCAL problem (``m = M_loc``).
    Raises ``PlanError`` when no round schedule exists (callers fall back to
    the serial schedule)."""
    from .distributed import plan_rounds

    ps = list(reversed(prob.ps))
    qs = list(reversed(prob.qs))
    k_loc = prob.k // g_k
    rounds = plan_rounds(k_loc, ps, qs, g_k)
    peak = PEAK_FLOPS if dtype_bytes <= 2 else PEAK_FLOPS_F32
    costs = []
    c = k_loc
    i = 0
    for r in rounds:
        flops = 0.0
        for j in range(i, i + r):
            flops += 2.0 * batch * prob.m * c * qs[j]
            c = c // ps[j] * qs[j]
        payload = batch * prob.m * c * (g_k - 1) / g_k
        costs.append((flops / peak, payload * dtype_bytes / ICI_BW))
        i += r
    return costs


def _slab_schedule_seconds(
    costs: Sequence[tuple[float, float]], n_slabs: int
) -> float:
    """Analytic time of the slab-pipelined round schedule: per round, up to
    ``(n-1)/n`` of the overlappable ``min(compute, comm)`` hides, and every
    slab's all_to_all pays the launch latency.  ``n_slabs=1`` recovers the
    serial ``compute + comm + latency`` sum."""
    total = 0.0
    for comp, comm in costs:
        hidden = min(comp, comm) * (n_slabs - 1) / n_slabs
        total += comp + comm - hidden + n_slabs * A2A_LATENCY_S
    return total


def choose_n_slabs(
    prob: KronProblem,
    g_k: int,
    *,
    batch: int = 1,
    dtype_bytes: int = 4,
    candidates: Sequence[int] = (1, 2, 4),
) -> int:
    """Analytic slab count for the distributed round pipeline.

    ``prob`` is the LOCAL problem (``m = M_loc`` — the slab axis; for the
    shared-factors path that is the collapsed ``B*M/G_M`` row count).  Each
    candidate is clamped to a divisor of the row axis, scored with
    ``_slab_schedule_seconds``, and the serial schedule wins ties — the
    latency term means slabbing only pays once per-round payloads clear
    roughly ``A2A_LATENCY_S * ICI_BW`` (~100 KB per collective), so small
    problems always plan serial.  This is the HBM-class analytic model;
    ``make_batched_plan(tune="measure", mesh=...)`` overrules it with a wall
    clock on the emitted program."""
    if g_k <= 1 or prob.m <= 1:
        return 1
    try:
        costs = _dist_round_costs(prob, g_k, batch, dtype_bytes)
    except guard.PlanError:
        return 1
    best_n, best_t = 1, _slab_schedule_seconds(costs, 1)
    for n in candidates:
        n_eff = emit_mod.effective_slabs(prob.m, n)
        if n_eff == best_n:
            continue
        t = _slab_schedule_seconds(costs, n_eff)
        if t < best_t:
            best_n, best_t = n_eff, t
    return best_n


def _batch_tiled(
    base: KronPlan,
    prob: KronProblem,
    batch: int,
    vmem_budget_elems: int,
    dtype_bytes: int,
    extra_per_sample_elems: int = 0,
) -> KronPlan:
    """Batch-aware tiling for the per-sample batch-grid kernels.

    A block of the batched kernel holds ``t_b`` sample chains, so the budget
    constraint becomes ``t_b * t_m * t_k * growth <= budget``.  Small-M
    batched problems amortize grid steps across samples, so the M-tile is
    traded DOWN to buy batch tiles: while ``t_b`` is below the sublane width
    (8 rows is what the TPU needs to fill a register row anyway), the largest
    stage M-tile is reduced and ``t_b`` recomputed under the same budget.

    ``extra_per_sample_elems`` (distributed mode): per-sample elements that
    share the budget with the compute block — the per-round relocation slab —
    so the effective constraint is ``t_b * (block + extra) <= budget``.  This
    is the t_b-vs-payload trade: a bigger batch tile buys launch amortization
    but inflates the round's resident communication slab.
    """
    ps = list(reversed(prob.ps))
    qs = list(reversed(prob.qs))
    stages = list(base.stages)

    def block_elems(st: Stage) -> float:
        sps = [ps[i] for i in st.factor_ids]
        sqs = [qs[i] for i in st.factor_ids]
        t_k = st.tiles.t_s * math.prod(sps)
        return st.tiles.t_m * t_k * fused_growth(sps, sqs, st.t_qs)

    def best_t_b() -> int:
        worst = max(block_elems(st) for st in stages) + extra_per_sample_elems
        cap = max(1, int(vmem_budget_elems // max(worst, 1.0)))
        return max(d for d in _divisors(batch) if d <= cap)

    t_b = best_t_b()
    while t_b < min(batch, SUBLANE):
        reducible = [i for i, st in enumerate(stages) if st.tiles.t_m > 1]
        if not reducible:
            break
        i = max(reducible, key=lambda i: stages[i].tiles.t_m)
        st = stages[i]
        new_tm = max(d for d in _divisors(prob.m) if d < st.tiles.t_m)
        stages[i] = dataclasses.replace(
            st, tiles=TileConfig(new_tm, st.tiles.t_s, st.tiles.t_q)
        )
        t_b = max(t_b, best_t_b())
    fwd = tuple(stages)
    return KronPlan(
        fwd, mirror_bwd_stages(prob, fwd, dtype_bytes=dtype_bytes), t_b
    )


def make_batched_plan(
    prob: KronProblem,
    batch: int,
    *,
    shared_factors: bool = True,
    dtype_bytes: int = 4,
    enable_fusion: bool = True,
    enable_prekron: bool = False,
    prekron_max_p: int = 16,
    prekron_max_dim: int = 256,
    vmem_budget_elems: int = 2 * 1024 * 1024,
    tune: str = "analytic",
    backend: str = "auto",
    cache_path: str | None = None,
    g_k: int = 1,
    acc_dtype: str | None = None,
    mesh=None,
    data_axis="data",
    model_axis: str = "model",
) -> KronPlan:
    """Plan for ``batch`` independent copies of ``prob`` in one launch.

    shared_factors=True (one factor set, batched X): the batch collapses into
    M, so this is the single-problem planner on the ``(batch*M, Ps, Qs)``
    problem — the M-tile is tuned for the collapsed row count.

    shared_factors=False (per-sample factors): the single-problem plan is
    re-tiled by ``_batch_tiled`` so every stage block carries ``t_b`` samples
    under the same VMEM budget.  ``enable_prekron=True`` lets the planner
    emit pre-kronization stages here too — the batched executor runs them as
    a vmapped ``jnp.kron`` + one batched sliced multiply
    (``emit.prekron_product`` inside ``run_stage``); callers enable it where
    the analytic model
    favors it (TPU MXU, same gate as the single-problem path).
    ``tune="measure"`` wall-clock ranks ``t_b`` variants BY MEASURING THE
    EMITTED PROGRAM (the same ``_measured_plan``/``measure_best`` path the
    single-problem planner uses — one measured path, not a split) and
    persists the winner keyed on B, with the widened candidate set recorded
    in the plan-cache entry.

    ``g_k > 1`` selects DISTRIBUTED mode (``kron_matmul_batched_distributed``
    on a mesh with a ``G_K``-way model axis): ``prob`` is the per-device
    LOCAL problem (``m = M_loc``).  The plan gains TWO distributed axes,
    traded jointly under the VMEM budget: the batch tile ``t_b`` and the
    slab-pipeline depth ``n_slabs``.  For each candidate slab count the
    worst-round relocation slab (``_dist_round_payload_elems``) SHRINKS by
    the slab factor — only one slab's payload is resident at a time — so the
    constraint is ``t_b * (block + payload/n) <= budget``: more slabs buy
    back batch tiles.  Candidates are scored with the analytic overlap model
    (``_slab_schedule_seconds``: hidden comm vs the per-slab collective
    latency), which keeps small problems on the serial schedule.  With
    ``tune="measure"`` AND a ``mesh``, candidates are instead wall-clock
    ranked on the emitted program through the real mesh runner and persisted
    in the plan cache under a key with a ``;gk=`` component
    (``_measured_dist_plan``) — host-mesh collectives run at memcpy speed,
    so measuring is the only honest way to rank slabbed vs serial schedules
    off-fabric; without a mesh, measure falls back to the analytic
    distributed plan and nothing is cached.  Distributed SHARED-factor plans
    do not exist: the shared path collapses B into the sharded row axis and
    needs no batched plan, so ``g_k > 1`` with ``shared_factors=True``
    raises rather than silently planning a single-device problem.
    """
    if batch <= 0:
        raise ValueError(f"batch must be positive, got {batch}")
    if g_k > 1 and shared_factors:
        raise ValueError(
            "g_k > 1 (distributed mode) requires shared_factors=False: the "
            "shared-factors distributed path collapses the batch into the "
            "data-sharded row axis and takes no batched plan"
        )
    if g_k > 1 and not shared_factors:
        if tune == "measure" and mesh is not None:
            return _measured_dist_plan(
                prob,
                batch=batch,
                g_k=g_k,
                mesh=mesh,
                data_axis=data_axis,
                model_axis=model_axis,
                dtype_bytes=dtype_bytes,
                enable_fusion=enable_fusion,
                vmem_budget_elems=vmem_budget_elems,
                backend=backend,
                cache_path=cache_path,
                acc_dtype=acc_dtype,
            )
        return _analytic_dist_plan(
            prob, batch, g_k,
            dtype_bytes=dtype_bytes,
            enable_fusion=enable_fusion,
            vmem_budget_elems=vmem_budget_elems,
            backend=backend,
            acc_dtype=acc_dtype,
        )
    if shared_factors:
        return make_plan(
            KronProblem(batch * prob.m, prob.ps, prob.qs),
            dtype_bytes=dtype_bytes,
            enable_fusion=enable_fusion,
            enable_prekron=enable_prekron,
            prekron_max_p=prekron_max_p,
            prekron_max_dim=prekron_max_dim,
            vmem_budget_elems=vmem_budget_elems,
            tune=tune,
            backend=backend,
            cache_path=cache_path,
            acc_dtype=acc_dtype,
        )
    if tune == "measure":
        return _measured_plan(
            prob,
            batch=batch,
            dtype_bytes=dtype_bytes,
            enable_fusion=enable_fusion,
            enable_prekron=enable_prekron,
            prekron_max_p=prekron_max_p,
            prekron_max_dim=prekron_max_dim,
            vmem_budget_elems=vmem_budget_elems,
            backend=backend,
            cache_path=cache_path,
            acc_dtype=acc_dtype,
        )
    if tune != "analytic":
        raise guard.PlanError(f"unknown tune mode {tune!r}")
    base = make_plan(
        prob,
        dtype_bytes=dtype_bytes,
        enable_fusion=enable_fusion,
        enable_prekron=enable_prekron,
        prekron_max_p=prekron_max_p,
        prekron_max_dim=prekron_max_dim,
        vmem_budget_elems=vmem_budget_elems,
        tune="analytic",
        backend=backend,
        acc_dtype=acc_dtype,
    )
    return _batch_tiled(base, prob, batch, vmem_budget_elems, dtype_bytes)


def _dist_plan_candidates(
    prob: KronProblem,
    batch: int,
    g_k: int,
    *,
    dtype_bytes: int,
    enable_fusion: bool,
    vmem_budget_elems: int,
    backend: str,
    acc_dtype: str | None,
    slab_candidates: Sequence[int] = (1, 2, 4),
) -> list[KronPlan]:
    """One distributed plan per feasible slab count, serial first.  Each
    candidate re-runs the t_b fit with the per-slab payload share
    (``payload // n``) so deeper pipelines can legitimately carry bigger
    batch tiles — the n_slabs-vs-t_b trade as an explicit candidate axis."""
    base = make_plan(
        prob,
        dtype_bytes=dtype_bytes,
        enable_fusion=enable_fusion,
        enable_prekron=False,
        vmem_budget_elems=vmem_budget_elems,
        tune="analytic",
        backend=backend,
        acc_dtype=acc_dtype,
    )
    payload = _dist_round_payload_elems(prob, g_k)
    cands = []
    for n in sorted({emit_mod.effective_slabs(prob.m, n) for n in slab_candidates}):
        plan_n = _batch_tiled(
            base, prob, batch, vmem_budget_elems, dtype_bytes,
            extra_per_sample_elems=payload // n,
        )
        cands.append(dataclasses.replace(plan_n, n_slabs=n))
    return cands


def _analytic_dist_plan(
    prob: KronProblem, batch: int, g_k: int, *, dtype_bytes, enable_fusion,
    vmem_budget_elems, backend, acc_dtype,
) -> KronPlan:
    """Analytic distributed batched plan: pick the candidate whose slab
    schedule minimizes the overlap model's time; on a tie the BIGGER batch
    tile wins (the whole point of trading the axes), then the shallower
    pipeline (serial is listed first)."""
    cands = _dist_plan_candidates(
        prob, batch, g_k, dtype_bytes=dtype_bytes, enable_fusion=enable_fusion,
        vmem_budget_elems=vmem_budget_elems, backend=backend,
        acc_dtype=acc_dtype,
    )
    try:
        costs = _dist_round_costs(prob, g_k, batch, dtype_bytes)
    except guard.PlanError:
        return cands[0]
    best, best_t = cands[0], _slab_schedule_seconds(costs, cands[0].n_slabs)
    for plan in cands[1:]:
        t = _slab_schedule_seconds(costs, plan.n_slabs)
        if t < best_t or (t == best_t and plan.t_b > best.t_b):
            best, best_t = plan, t
    return best


# ---------------------------------------------------------------------------
# Measured tuning + on-disk plan cache
# ---------------------------------------------------------------------------


def default_cache_path() -> str:
    return os.environ.get(
        "FASTKRON_PLAN_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "fastkron", "plans.json"),
    )


def plan_cache_key(
    prob: KronProblem,
    dtype_bytes: int,
    backend: str,
    *,
    enable_fusion: bool = True,
    enable_prekron: bool = True,
    prekron_max_p: int = 16,
    prekron_max_dim: int = 256,
    vmem_budget_elems: int = 2 * 1024 * 1024,
    batch: int = 0,
    shared_factors: bool = True,
    acc_dtype: str | None = None,
) -> str:
    """Cache key covers every plan-shaping input (defaults mirror make_plan):
    a hit must satisfy the caller's constraints, not just the problem shape.
    ``batch > 0`` marks a batched-plan entry (keyed on B and the factor-
    sharing mode); 0 keeps the single-problem key format stable, and a
    non-default ``acc_dtype`` is appended only when set for the same reason.
    Distributed MEASURED plans (``make_batched_plan(g_k > 1, tune="measure",
    mesh=...)``) append a ``;gk=<G_K>`` component to this key — append-only
    like ``;B=``/``;acc=``, so pre-slab cache files load unchanged and
    single-host entries never collide with distributed ones; analytic
    distributed plans are still never cached."""
    ps = ",".join(map(str, prob.ps))
    qs = ",".join(map(str, prob.qs))
    key = (
        f"m={prob.m};ps={ps};qs={qs};dtype={dtype_bytes};backend={backend}"
        f";fuse={int(enable_fusion)};prekron={int(enable_prekron)}"
        f";pmax={prekron_max_p};pdim={prekron_max_dim};vmem={vmem_budget_elems}"
    )
    if batch > 0:
        key += f";B={batch};shared={int(shared_factors)}"
    if acc_dtype is not None:
        key += f";acc={acc_dtype}"
    return key


def _stage_to_json(st: Stage) -> dict:
    return {
        "factor_ids": list(st.factor_ids),
        "prekron": st.prekron,
        "tiles": list(st.tiles.as_tuple),
        "t_qs": list(st.t_qs) if st.t_qs is not None else None,
        "acc_dtype": st.acc_dtype,
    }


def _stage_from_json(d: dict) -> Stage:
    return Stage(
        tuple(d["factor_ids"]),
        bool(d["prekron"]),
        TileConfig(*d["tiles"]),
        tuple(d["t_qs"]) if d.get("t_qs") is not None else None,
        d.get("acc_dtype"),
    )


def plan_to_json(plan: KronPlan) -> dict:
    return {
        "stages": [_stage_to_json(s) for s in plan.stages],
        "bwd_stages": (
            [_stage_to_json(s) for s in plan.bwd_stages]
            if plan.bwd_stages is not None
            else None
        ),
        "t_b": plan.t_b,
        "n_slabs": plan.n_slabs,
    }


def plan_from_json(d: dict) -> KronPlan:
    return KronPlan(
        tuple(_stage_from_json(s) for s in d["stages"]),
        (
            tuple(_stage_from_json(s) for s in d["bwd_stages"])
            if d.get("bwd_stages") is not None
            else None
        ),
        int(d.get("t_b", 1)),
        int(d.get("n_slabs", 1)),  # pre-slab cache entries default to serial
    )


def load_plan_cache(path: str) -> dict:
    """Best-effort load: a corrupt / truncated / wrong-schema file (e.g. a
    concurrent writer died mid-rename on a non-atomic filesystem) degrades to
    an empty cache, never an exception — the next save rewrites it whole.
    Corruption is routed through ``PlanCacheError`` bookkeeping: a once-per-
    process ``GuardWarning`` plus a ``plan_cache_rebuild`` health event, so
    lost tuning work is visible instead of silent.  A missing file or a
    version bump is a normal condition and stays quiet."""
    try:
        chaos.maybe_fail("plan_cache_load")
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    except (OSError, ValueError) as e:  # PlanCacheError is an OSError
        guard.record_event("plan_cache_rebuild", guard.PlanCacheError(str(e)))
        guard.warn_once(
            ("plan_cache_load", path),
            f"kron guard: plan cache at {path!r} unreadable "
            f"({type(e).__name__}: {e}) — rebuilding from scratch",
        )
        return {}
    if not isinstance(data, dict) or data.get("version") != PLAN_CACHE_VERSION:
        return {}
    entries = data.get("entries", {})
    if not isinstance(entries, dict):
        return {}
    return {
        k: v
        for k, v in entries.items()
        if isinstance(v, dict) and isinstance(v.get("plan"), dict)
    }


PLAN_CACHE_SAVE_RETRIES = 3


def save_plan_cache(
    path: str, entries: dict, *, retries: int = PLAN_CACHE_SAVE_RETRIES
) -> None:
    """Atomic write: temp file in the target directory + ``os.replace`` so a
    reader never sees a partial file and concurrent benchmark/CI runs can't
    poison each other.  On-disk entries written since our load are merged in
    (ours win on key conflict) so parallel tuners lose at most a race, not
    their work.  Lock/rename contention (heavy on network filesystems) gets a
    bounded retry with exponential backoff; exhausting it warns once per path
    (``PlanCacheError`` bookkeeping) instead of silently dropping entries."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    merged = {**load_plan_cache(path), **entries}
    payload = {"version": PLAN_CACHE_VERSION, "entries": merged}
    last: OSError | None = None
    for attempt in range(max(1, retries)):
        tmp = None
        try:
            chaos.maybe_fail("plan_cache_save")
            fd, tmp = tempfile.mkstemp(
                dir=os.path.dirname(path) or ".", suffix=".tmp"
            )
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
            return
        except OSError as e:  # PlanCacheError is an OSError
            last = e
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
            if attempt + 1 < max(1, retries):
                time.sleep(0.01 * (2 ** attempt))
    guard.record_event("plan_cache_save_failed", last)
    guard.warn_once(
        ("plan_cache_save", path),
        f"kron guard: plan-cache save to {path!r} failed after "
        f"{max(1, retries)} attempts ({type(last).__name__}: {last}) — "
        "tuning results not persisted",
    )


def _plan_vmem_legal(plan: KronPlan, prob: KronProblem, batched: bool) -> bool:
    """Would every instruction of the lowered plan (both directions) fit the
    Pallas VMEM budget?  Measured tuning filters its widened sweep with this
    so an XLA wall clock (which ignores tiles) can never cache a plan that
    crashes the Pallas backend later."""
    from ..kernels.emit import (
        PREKRON, VMEM_BUDGET_ELEMS, fused_growth, transposed_growth,
    )

    try:
        prog = lower(plan, prob.ps, prob.qs, batched=batched)
    except Exception:
        return False
    for ins in prog.instrs:
        if ins.kind == PREKRON:
            eff_ps = (math.prod(ins.ps),)
            eff_qs = (math.prod(ins.qs),)
            t_qs = ins.t_qs if ins.t_qs and len(ins.t_qs) == 1 else None
        else:
            eff_ps, eff_qs, t_qs = ins.ps, ins.qs, ins.t_qs
        tb = ins.t_b or 1
        for growth_fn, t_m in (
            (fused_growth, ins.t_m),
            (transposed_growth, ins.t_m_bwd or ins.t_m),
        ):
            if tb * t_m * ins.t_k * growth_fn(eff_ps, eff_qs, t_qs) > (
                VMEM_BUDGET_ELEMS
            ):
                return False
    return True


def _measured_candidates(
    base: KronPlan, prob: KronProblem, batch: int | None
) -> list[KronPlan]:
    """Narrowed candidate set (paper §4.3 structure): the analytic winner
    plus T_M sweeps applied to every stage (forward and backward) and — for
    batched plans — a WIDENED t_b sweep over every divisor of B up to 32 (the
    ROADMAP "batched measured tuning" follow-on: let the wall clock overrule
    the analytic t_b/t_m trade).  Sweep variants that would overflow the
    Pallas VMEM budget are dropped (``_plan_vmem_legal``): the wall clock
    here may be an XLA one that ignores tiles, and a cached Pallas-illegal
    winner would crash a later TPU process."""
    cands = [base]
    for t_m in (4, 8, 16, 32):
        if t_m > prob.m or prob.m % t_m:
            continue
        retile = lambda st: Stage(
            st.factor_ids, st.prekron,
            TileConfig(t_m, st.tiles.t_s, st.tiles.t_q), st.t_qs, st.acc_dtype,
        )
        cands.append(
            KronPlan(
                tuple(retile(s) for s in base.stages),
                tuple(retile(s) for s in (base.bwd_stages or ())) or None,
                base.t_b,
            )
        )
    if batch is not None:
        for plan in list(cands):
            for t_b in (1, 2, 4, 8, 16, 32):
                if t_b > batch or batch % t_b or t_b == plan.t_b:
                    continue
                cands.append(dataclasses.replace(plan, t_b=t_b))
    return [
        c for c in cands
        if c is base or _plan_vmem_legal(c, prob, batch is not None)
    ]


def _measured_plan(
    prob: KronProblem,
    *,
    batch: int | None = None,
    dtype_bytes: int,
    backend: str,
    cache_path: str | None,
    vmem_budget_elems: int = 2 * 1024 * 1024,
    **plan_kwargs,
) -> KronPlan:
    """ONE measured-tuning path for single and batched plans.

    Candidates are ranked by timing the engine's program-driven forward +
    full VJP for each plan — i.e. the EMITTED programs as training actually
    runs them: the lowered forward chain, its ``transpose`` for the input
    cotangent, and the one-kernel factor-gradient stage backward
    (``run_stage_grad``) — so what is ranked is exactly what will run.  The
    winner is persisted in the plan cache together with the candidate set
    that was measured (``"candidates"``) so a later widening of the sweep is
    visible in the cache entry.
    """
    path = cache_path or default_cache_path()
    key = plan_cache_key(
        prob, dtype_bytes, backend,
        vmem_budget_elems=vmem_budget_elems,
        **plan_kwargs,
        **({"batch": batch, "shared_factors": False} if batch is not None else {}),
    )
    entries = load_plan_cache(path)
    hit = entries.get(key)
    if hit is not None:
        telemetry.counter_inc("plan_cache.hit")
        return plan_from_json(hit["plan"])
    telemetry.counter_inc("plan_cache.miss")

    base = make_plan(
        prob, dtype_bytes=dtype_bytes, tune="analytic", backend=backend,
        vmem_budget_elems=vmem_budget_elems, **plan_kwargs,
    )
    if batch is not None:
        base = _batch_tiled(base, prob, batch, vmem_budget_elems, dtype_bytes)
    cands = _measured_candidates(base, prob, batch)

    dtype = {2: jnp.bfloat16, 4: jnp.float32, 8: jnp.float64}.get(
        dtype_bytes, jnp.float32
    )
    lead = () if batch is None else (batch,)
    keys = jax.random.split(jax.random.PRNGKey(0), prob.n + 1)
    x = jax.random.normal(keys[0], (*lead, prob.m, prob.k)).astype(dtype)
    factors = tuple(
        jax.random.normal(kk, (*lead, p, q)).astype(dtype)
        for kk, p, q in zip(keys[1:], prob.ps, prob.qs)
    )
    # Deferred import: engine imports this module at load time.
    from . import engine

    def fn_of_plan(plan):
        op = engine.KronOp(
            prob.ps, prob.qs, backend=backend, plan=plan,
            **({} if batch is None else
               {"batch": batch, "shared_factors": False}),
        )
        f = jax.jit(
            jax.grad(
                lambda x, fs: op(x, fs).sum().astype(jnp.float32),
                argnums=(0, 1),
            )
        )
        return lambda: f(x, factors)

    try:
        with telemetry.span("measure_plan", candidates=len(cands)):
            best, seconds = measure_best(fn_of_plan, cands, warmup=1, iters=3)
    except (RuntimeError, guard.PlanError):
        # No candidate executed (e.g. unsupported backend/dtype combination):
        # fall back to the analytic plan and don't poison the cache.
        return base
    entries[key] = {
        "plan": plan_to_json(best),
        "seconds": seconds,
        "measured_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "candidates": [c.describe() for c in cands],
    }
    save_plan_cache(path, entries)
    return best


def _measured_dist_plan(
    prob: KronProblem,
    *,
    batch: int,
    g_k: int,
    mesh,
    data_axis,
    model_axis: str,
    dtype_bytes: int,
    enable_fusion: bool,
    vmem_budget_elems: int,
    backend: str,
    cache_path: str | None,
    acc_dtype: str | None,
) -> KronPlan:
    """Measured tuning for DISTRIBUTED batched plans: wall-clock rank the
    slab-count candidates by running the real mesh runner (forward + full
    VJP of the emitted round schedule) on the caller's mesh, so slabbed vs
    serial is decided by what the fabric actually does — the analytic ICI
    model cannot see that host-mesh collectives run at memcpy speed (and,
    symmetrically, a real ICI's latency).  The winner is persisted under the
    batched cache key plus a ``;gk=`` component: an APPEND-ONLY extension of
    the key schema, so existing single-host entries keep their keys and old
    cache files load unchanged (distributed entries simply never collide
    with them)."""
    path = cache_path or default_cache_path()
    key = plan_cache_key(
        prob, dtype_bytes, backend,
        enable_fusion=enable_fusion,
        enable_prekron=False,
        vmem_budget_elems=vmem_budget_elems,
        batch=batch,
        shared_factors=False,
        acc_dtype=acc_dtype,
    ) + f";gk={g_k}"
    entries = load_plan_cache(path)
    hit = entries.get(key)
    if hit is not None:
        telemetry.counter_inc("plan_cache.hit")
        return plan_from_json(hit["plan"])
    telemetry.counter_inc("plan_cache.miss")

    cands = _dist_plan_candidates(
        prob, batch, g_k, dtype_bytes=dtype_bytes, enable_fusion=enable_fusion,
        vmem_budget_elems=vmem_budget_elems, backend=backend,
        acc_dtype=acc_dtype,
    )
    fallback = _analytic_dist_plan(
        prob, batch, g_k, dtype_bytes=dtype_bytes, enable_fusion=enable_fusion,
        vmem_budget_elems=vmem_budget_elems, backend=backend,
        acc_dtype=acc_dtype,
    )

    from . import distributed

    g_m = distributed._mesh_size(mesh, data_axis)
    dtype = {2: jnp.bfloat16, 4: jnp.float32, 8: jnp.float64}.get(
        dtype_bytes, jnp.float32
    )
    keys = jax.random.split(jax.random.PRNGKey(0), prob.n + 1)
    x = jax.random.normal(keys[0], (batch, prob.m * g_m, prob.k)).astype(dtype)
    x = distributed.sharded_input_batched(x, mesh, data_axis, model_axis)
    factors = tuple(
        jax.random.normal(kk, (batch, p, q)).astype(dtype)
        for kk, p, q in zip(keys[1:], prob.ps, prob.qs)
    )

    def fn_of_plan(plan):
        f = jax.jit(
            jax.grad(
                lambda x, fs: distributed.run_batched_distributed_rounds(
                    x, fs, mesh,
                    t_b=plan.t_b,
                    data_axis=data_axis,
                    model_axis=model_axis,
                    backend=backend,
                    n_slabs=plan.n_slabs,
                ).sum().astype(jnp.float32),
                argnums=(0, 1),
            )
        )
        return lambda: f(x, factors)

    try:
        with telemetry.span(
            "measure_dist_plan", candidates=len(cands), g_k=g_k
        ):
            best, seconds = measure_best(fn_of_plan, cands, warmup=1, iters=3)
    except (RuntimeError, guard.PlanError):
        # No candidate ran on this mesh (e.g. rows not shardable): analytic
        # fallback, nothing cached.
        return fallback
    entries[key] = {
        "plan": plan_to_json(best),
        "seconds": seconds,
        "measured_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "candidates": [c.describe() for c in cands],
    }
    save_plan_cache(path, entries)
    return best


__all__ = [
    "TileConfig",
    "Stage",
    "KronPlan",
    "make_plan",
    "make_batched_plan",
    "choose_n_slabs",
    "lower",
    "mirror_bwd_stages",
    "tune_sliced",
    "candidate_tiles",
    "predict_seconds",
    "measure_best",
    "vmem_elems",
    "plan_cache_key",
    "plan_to_json",
    "plan_from_json",
    "load_plan_cache",
    "save_plan_cache",
    "default_cache_path",
    "PEAK_FLOPS",
    "HBM_BW",
    "VMEM_BYTES",
    "ICI_BW",
    "A2A_LATENCY_S",
]
