"""Autotuner for FastKron tile sizes + execution plans (contribution C5).

The paper's autotuner compiles ~10k CUDA kernels and times them.  On TPU the
equivalent search space is the Pallas block shapes; since this container has
no TPU, candidates are scored *analytically* with a two-term (compute, HBM)
model that knows the MXU's 128x128 systolic shape and the (8,128) VMEM tile —
the same "narrow by resource limits, then rank" structure as the paper's §4.3.
``measure=True`` ranks the narrowed candidates by wall clock instead, for use
on real hardware (and exercised on CPU in tests with the XLA backend).

Plan construction additionally decides, per the paper + our beyond-paper
extension:

  * fusion grouping (C3): how many consecutive factors one kernel chains,
    bounded by ``N_fused = floor(log_P T_K)`` and the VMEM budget;
  * factor pre-kronization (beyond paper): explicitly form F^i (x) F^{i+1}
    when P is too small to feed the MXU's 128-deep contraction.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kron import KronProblem

# TPU v5e hardware model (same constants as EXPERIMENTS.md).
PEAK_FLOPS = 197e12  # bf16
PEAK_FLOPS_F32 = 98.5e12
HBM_BW = 819e9  # bytes/s
VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128
SUBLANE = 8


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


@dataclasses.dataclass(frozen=True)
class TileConfig:
    t_m: int
    t_s: int  # slices per block (T_K = t_s * P)
    t_q: int

    @property
    def as_tuple(self) -> tuple[int, int, int]:
        return (self.t_m, self.t_s, self.t_q)


def vmem_elems(cfg: TileConfig, p: int, growth: float = 1.0) -> int:
    """f32-elements resident per block (x tile, f tile, y tile), x2 buffered."""
    x_t = cfg.t_m * cfg.t_s * p
    f_t = p * cfg.t_q
    y_t = int(cfg.t_m * cfg.t_q * cfg.t_s * growth)
    return 2 * (x_t + f_t + y_t)


def predict_seconds(
    prob_m: int, s: int, p: int, q: int, cfg: TileConfig, dtype_bytes: int = 4
) -> float:
    """Two-term analytic time model for one sliced multiply on one chip."""
    flops = 2.0 * prob_m * s * p * q
    # MXU utilization: contraction dim padded to 128, lanes to 128, rows to 8.
    u_c = p / _ceil_to(p, MXU_DIM)
    u_q = cfg.t_q / _ceil_to(cfg.t_q, MXU_DIM)
    rows = cfg.t_m * cfg.t_s
    u_r = rows / _ceil_to(rows, SUBLANE)
    peak = PEAK_FLOPS if dtype_bytes <= 2 else PEAK_FLOPS_F32
    t_compute = flops / (peak * max(u_c * u_q * u_r, 1e-6))
    # HBM traffic: X re-read once per Q-tile sweep; F negligible; Y written once.
    x_bytes = prob_m * s * p * dtype_bytes * (q // cfg.t_q)
    y_bytes = prob_m * s * q * dtype_bytes
    f_bytes = p * q * dtype_bytes * (prob_m // cfg.t_m) * (s // cfg.t_s)
    t_mem = (x_bytes + y_bytes + f_bytes) / HBM_BW
    return max(t_compute, t_mem)


def candidate_tiles(m: int, s: int, p: int, q: int) -> list[TileConfig]:
    """Paper §4.3 search-space narrowing, restated for Pallas blocks."""
    t_ms = [t for t in (1, 2, 4, 8, 16, 32) if t <= m and m % t == 0]
    t_ss = [t for t in _divisors(s) if t <= 2048 and (t * p) % 1 == 0]
    # keep lane-friendly slice tiles preferentially but allow all divisors
    t_qs = _divisors(q)
    out = []
    for t_m, t_s, t_q in itertools.product(t_ms, t_ss, t_qs):
        cfg = TileConfig(t_m, t_s, t_q)
        if vmem_elems(cfg, p) * 4 > VMEM_BYTES * 3 // 4:
            continue  # resource-limit pruning (paper: smem + regs cap)
        out.append(cfg)
    return out


def tune_sliced(
    m: int, s: int, p: int, q: int, *, dtype_bytes: int = 4
) -> TileConfig:
    """Best analytic tile config for a single sliced multiply."""
    cands = candidate_tiles(m, s, p, q)
    if not cands:
        return TileConfig(min(m, 8), 1, 1)
    return min(cands, key=lambda c: predict_seconds(m, s, p, q, c, dtype_bytes))


def measure_best(
    fn_of_cfg: Callable[[TileConfig], Callable[[], jax.Array]],
    cands: Sequence[TileConfig],
    *,
    warmup: int = 2,
    iters: int = 5,
) -> tuple[TileConfig, float]:
    """Wall-clock ranking of candidates (for real hardware)."""
    best, best_t = None, float("inf")
    for cfg in cands:
        try:
            fn = fn_of_cfg(cfg)
            for _ in range(warmup):
                fn().block_until_ready()
            t0 = time.perf_counter()
            for _ in range(iters):
                fn().block_until_ready()
            dt = (time.perf_counter() - t0) / iters
        except Exception:
            continue
        if dt < best_t:
            best, best_t = cfg, dt
    if best is None:
        raise RuntimeError("no candidate executed successfully")
    return best, best_t


# ---------------------------------------------------------------------------
# Plan: pairing + fusion grouping + tiles per stage
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stage:
    """One kernel launch: chain ``factor_ids`` (in application order, i.e.
    reversed problem order) inside a single fused kernel.

    ``prekron=True`` means the stage's factors are first combined into their
    explicit Kronecker product (beyond-paper MXU-utilization optimization)
    and applied as ONE sliced multiply.
    """

    factor_ids: tuple[int, ...]
    prekron: bool
    tiles: TileConfig


@dataclasses.dataclass(frozen=True)
class KronPlan:
    stages: tuple[Stage, ...]

    def describe(self) -> str:
        parts = []
        for st in self.stages:
            kind = "prekron" if st.prekron else ("fused" if len(st.factor_ids) > 1 else "sliced")
            parts.append(f"{kind}{list(st.factor_ids)}@{st.tiles.as_tuple}")
        return " -> ".join(parts)


def make_plan(
    prob: KronProblem,
    *,
    dtype_bytes: int = 4,
    enable_fusion: bool = True,
    enable_prekron: bool = True,
    prekron_max_p: int = 16,
    prekron_max_dim: int = 256,
    vmem_budget_elems: int = 2 * 1024 * 1024,
) -> KronPlan:
    """Greedy plan over the reversed factor list (application order).

    Stage selection per position i (0 = last factor, applied first):
      1. If P_i and P_{i+1} are both small, pre-kronize the pair (MXU win).
      2. Else fuse as many consecutive factors as N_fused/VMEM allow (C3).
      3. Else a single tuned sliced multiply.
    """
    ps = list(reversed(prob.ps))
    qs = list(reversed(prob.qs))
    n = len(ps)
    stages: list[Stage] = []
    k = prob.k
    i = 0
    while i < n:
        p, q = ps[i], qs[i]
        # -- beyond-paper pre-kronization --
        if (
            enable_prekron
            and i + 1 < n
            and p <= prekron_max_p
            and ps[i + 1] <= prekron_max_p
            and p * ps[i + 1] <= prekron_max_dim
            and q * qs[i + 1] <= prekron_max_dim
        ):
            pp, qq = p * ps[i + 1], q * qs[i + 1]
            s = k // pp
            tiles = tune_sliced(prob.m, s, pp, qq, dtype_bytes=dtype_bytes)
            stages.append(Stage((i, i + 1), True, tiles))
            k = s * qq
            i += 2
            continue
        # -- C3 fusion grouping --
        group = [i]
        if enable_fusion:
            pprod, qprod = p, q
            j = i + 1
            while j < n:
                np_, nq = pprod * ps[j], qprod * qs[j]
                growth = max(1.0, nq / np_)
                # T_K must be a multiple of prod(P); try the largest T_K that
                # fits VMEM with a T_M of 8 (refined below).
                t_k = min(k, np_ * max(1, (vmem_budget_elems // (8 * np_ * 4))) * 1)
                if np_ > k or 8 * np_ * growth * 4 > vmem_budget_elems:
                    break
                pprod, qprod = np_, nq
                group.append(j)
                j += 1
        pprod = math.prod(ps[g] for g in group)
        qprod = math.prod(qs[g] for g in group)
        s = k // pprod
        tiles = tune_sliced(prob.m, s, pprod, qprod, dtype_bytes=dtype_bytes)
        stages.append(Stage(tuple(group), False, tiles))
        k = s * qprod
        i = group[-1] + 1
    return KronPlan(tuple(stages))


__all__ = [
    "TileConfig",
    "Stage",
    "KronPlan",
    "make_plan",
    "tune_sliced",
    "candidate_tiles",
    "predict_seconds",
    "measure_best",
    "vmem_elems",
    "PEAK_FLOPS",
    "HBM_BW",
    "VMEM_BYTES",
]
