"""Core: the paper's contribution — FastKron Kron-Matmul in JAX."""
from .kron import (  # noqa: F401
    KronProblem,
    kron_matrix,
    kron_matmul_naive,
    kron_matmul_shuffle,
    kron_matmul_ftmmt,
    kron_matmul_fastkron,
    sliced_multiply,
    pair_factors,
)
from .fastkron import (  # noqa: F401
    kron_matmul,
    kron_matmul_batched,
    kron_matmul_unfused,
)
from .autotune import (  # noqa: F401
    KronPlan,
    Stage,
    TileConfig,
    make_plan,
    make_batched_plan,
)
from .layers import (  # noqa: F401
    KronLinearSpec,
    kron_linear_init,
    kron_linear_apply,
    kron_linear_materialize,
    balanced_factorization,
)
