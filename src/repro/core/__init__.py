"""Core: the paper's contribution — FastKron Kron-Matmul in JAX.

The execution surface is the handle-based ``KronOp`` (``core.engine``); the
functional ``kron_matmul*`` entry points remain as compatibility shims.
"""
from .kron import (  # noqa: F401
    KronProblem,
    kron_matrix,
    kron_matmul_naive,
    kron_matmul_shuffle,
    kron_matmul_ftmmt,
    kron_matmul_fastkron,
    sliced_multiply,
    pair_factors,
)
from .engine import (  # noqa: F401
    KronOp,
    KronCost,
    kron_op_for,
)
from .fastkron import (  # noqa: F401
    kron_matmul,
    kron_matmul_batched,
    kron_matmul_unfused,
)
from .autotune import (  # noqa: F401
    KronPlan,
    Stage,
    TileConfig,
    make_plan,
    make_batched_plan,
)
from .layers import (  # noqa: F401
    KronLinearSpec,
    KronLinear,
    kron_linear_init,
    kron_linear_apply,
    kron_linear_materialize,
    balanced_factorization,
)

__all__ = [
    # engine (the primary surface)
    "KronOp",
    "KronCost",
    "kron_op_for",
    # compatibility shims
    "kron_matmul",
    "kron_matmul_batched",
    "kron_matmul_unfused",
    # plans
    "KronPlan",
    "Stage",
    "TileConfig",
    "make_plan",
    "make_batched_plan",
    # problem description + reference algorithms
    "KronProblem",
    "kron_matrix",
    "kron_matmul_naive",
    "kron_matmul_shuffle",
    "kron_matmul_ftmmt",
    "kron_matmul_fastkron",
    "sliced_multiply",
    "pair_factors",
    # layers
    "KronLinearSpec",
    "KronLinear",
    "kron_linear_init",
    "kron_linear_apply",
    "kron_linear_materialize",
    "balanced_factorization",
]
