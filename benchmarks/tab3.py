"""Table 3: small-M (M=16) performance, float32 and float64.

Paper: with M=16 (the GP conjugate-gradient batch size) FastKron reaches
up to 13.4x (float) / 15.2x (double) over GPyTorch's shuffle algorithm —
small M makes the shuffle GEMMs extra skinny and the transpose relatively
costlier.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import kron as K
from repro.core.fastkron import kron_matmul
from repro.core.kron import KronProblem

from .util import csv_row, gflops, largest_n, make_inputs, timeit


def run(quick: bool = False):
    jax.config.update("jax_enable_x64", True)
    rows = []
    m = 16
    for p in ([8, 32] if quick else [8, 16, 32, 64]):
        n = largest_n(m, p, p, budget_elems=(8 if quick else 48) * 10**6)
        prob = KronProblem.uniform(m, p, p, n)
        for dtype, tag in [(jnp.float32, "float"), (jnp.float64, "double")]:
            if quick and tag == "double":
                continue
            x, fs = make_inputs(m, prob.ps, prob.qs, dtype)
            sh = jax.jit(lambda x, fs: K.kron_matmul_shuffle(x, fs))
            fk = jax.jit(lambda x, fs: kron_matmul(x, fs))
            t_sh = timeit(lambda: sh(x, fs))
            t_fk = timeit(lambda: fk(x, fs))
            rows.append(csv_row(
                "tab3",
                size=f"{p}^{n}",
                dtype=tag,
                gflops_shuffle=f"{gflops(prob, t_sh):.2f}",
                gflops_fastkron=f"{gflops(prob, t_fk):.2f}",
                speedup=f"{t_sh/t_fk:.2f}",
            ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
