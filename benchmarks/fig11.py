"""Figure 11: weak scaling of distributed Kron-Matmul, 1-16 "GPUs".

The paper's 16-V100 measurement becomes, on this CPU container, a
communication-volume comparison from the compiled HLO (hardware-
independent) plus a bandwidth model: FastKron's batched relocation
(N_local multiplies per round) vs the per-iteration baseline (CTF/DISTAL
communicate after EVERY factor).  Weak scaling: M grows with G, per-device
block constant (paper: P=64, N=4).

Runs in a subprocess with 16 fake devices so the parent process keeps its
single-device view.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

from .util import csv_row

ICI_BW = 50e9  # bytes/s per link (same model as the roofline)

_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json, math, sys
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as Pspec
from repro.core.distributed import kron_matmul_distributed
from repro.runtime.hlo_cost import analyze

P, N = (int(sys.argv[1]), int(sys.argv[2])) if len(sys.argv) > 2 else (64, 4)
quick = len(sys.argv) > 3 and sys.argv[3] == "quick"
out = []
for g in ([1, 4, 16] if quick else [1, 2, 4, 8, 16]):
    g_m = 1
    m = 4 * g          # weak scaling: rows grow with devices
    k = P ** N
    mesh = jax.make_mesh((g_m, g), ("data", "model"),
                         devices=jax.devices()[: g_m * g])
    # dry lowering: ShapeDtypeStructs only, no allocation (paper sizes are
    # GPU-memory-scale; comm volume comes from the compiled HLO)
    xs = jax.ShapeDtypeStruct(
        (m, k), jnp.float32,
        sharding=NamedSharding(mesh, Pspec("data", "model")))
    fs = [jax.ShapeDtypeStruct((P, P), jnp.float32,
                               sharding=NamedSharding(mesh, Pspec()))
          for _ in range(N)]
    rec = {"g": g, "m": m}
    for name, per_it in [("fastkron", False), ("periter", True)]:
        fn = lambda x_, f_: kron_matmul_distributed(
            x_, f_, mesh, per_iteration=per_it)
        txt = jax.jit(fn).lower(xs, fs).compile().as_text()
        c = analyze(txt)
        rec[name + "_coll_bytes"] = c.total_collective_bytes
        rec[name + "_flops"] = c.dot_flops
    out.append(rec)
print(json.dumps(out))
"""


def run(quick: bool = False):
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    # paper sizes (P=64, N=4): lowering is allocation-free so the full size
    # compiles fine on CPU
    args = [sys.executable, "-c", _DRIVER, "64", "4"] + (["quick"] if quick else [])
    proc = subprocess.run(args, env=env, capture_output=True, text=True,
                          timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = []
    for rec in data:
        fb, pb = rec["fastkron_coll_bytes"], rec["periter_coll_bytes"]
        rows.append(csv_row(
            "fig11",
            gpus=rec["g"],
            m=rec["m"],
            comm_bytes_fastkron=int(fb),
            comm_bytes_periter=int(pb),
            comm_reduction=f"{pb/max(fb,1):.2f}",
            modeled_comm_ms_fastkron=f"{fb/ICI_BW*1e3:.3f}",
            modeled_comm_ms_periter=f"{pb/ICI_BW*1e3:.3f}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
