"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig9,tab5]

Prints one CSV row per measurement (name,key=value,...).  CPU container:
absolute GFLOP/s are not paper-comparable; the reproduced claims are the
RATIOS (FastKron vs shuffle vs FTMMT) and the HLO-derived bytes / comm
volumes, which are hardware-independent.  Roofline/§Perf numbers come from
launch/dryrun.py, not from here.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

ALL = ["fig9", "fig_bwd", "fig_batched", "fig_dist_batched",
       "fig_dist_overlap", "fig_serve", "fig_optim", "tab1", "tab2", "tab3",
       "fig10", "fig11", "tab5"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(ALL))
    ap.add_argument("--telemetry", metavar="OUT.jsonl", default=None,
                    help="KronScope JSONL event sink for the whole run")
    ap.add_argument("--trace", metavar="OUT.trace.json", default=None,
                    help="Chrome-trace export of host-side spans at exit")
    args = ap.parse_args()
    if args.telemetry or args.trace:
        from repro.runtime import telemetry

        telemetry.configure(jsonl=args.telemetry, trace=args.trace)
    names = args.only.split(",") if args.only else ALL
    failures = []
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            for row in mod.run(quick=args.quick):
                print(row, flush=True)
            print(f"# {name} done in {time.time()-t0:.0f}s", flush=True)
        except Exception as e:
            failures.append(name)
            traceback.print_exc()
            print(f"# {name} FAILED: {e}", flush=True)
    if args.telemetry or args.trace:
        from repro.runtime import telemetry

        telemetry.shutdown()
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# ALL BENCHMARKS OK")


if __name__ == "__main__":
    main()
