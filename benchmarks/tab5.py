"""Table 5: GP (SKI) training speedup from swapping the Kron-Matmul engine.

Paper: integrating FastKron into GPyTorch speeds SKI/SKIP/LOVE training by
1.1x-2.2x on one GPU (the rest of the epoch is non-Kron work).  Here the
epoch = 10-iteration CG solve with M=16, kernel = (x) of 1-D RBF grids
(paper grid sizes 8^n..64^n capped to the CPU budget); backends: shuffle
(GPyTorch's engine) vs FastKron.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.gp import KronKernel, gp_train_epoch, rbf_kernel_1d

from .util import csv_row, timeit

SIZES = [  # (tag, P, N) — paper's P^N grids, CPU-capped
    ("8^5", 8, 5),
    ("16^4", 16, 4),
    ("32^3", 32, 3),
    ("64^3", 64, 3),
]


def run(quick: bool = False):
    rows = []
    m = 16
    for tag, p, n in (SIZES[:2] if quick else SIZES):
        grid = jnp.linspace(0, 1, p)
        kernel = KronKernel(tuple(rbf_kernel_1d(grid) for _ in range(n)))
        v = jax.random.normal(jax.random.PRNGKey(0), (m, kernel.dim))
        fns = {}
        for backend in ("shuffle", "fastkron"):
            fns[backend] = jax.jit(
                lambda v, b=backend: gp_train_epoch(kernel, v, backend=b)[0]
            )
        t_sh = timeit(lambda: fns["shuffle"](v), iters=3)
        t_fk = timeit(lambda: fns["fastkron"](v), iters=3)
        # correctness: both solve to the same result
        import numpy as np

        np.testing.assert_allclose(
            np.asarray(fns["shuffle"](v)), np.asarray(fns["fastkron"](v)),
            rtol=1e-3, atol=1e-4,
        )
        rows.append(csv_row(
            "tab5",
            grid=tag,
            epoch_ms_shuffle=f"{t_sh*1e3:.1f}",
            epoch_ms_fastkron=f"{t_fk*1e3:.1f}",
            speedup=f"{t_sh/t_fk:.2f}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
