"""Figure 10: the 28 real-world Kron-Matmul sizes of Table 4.

Covers odd / non-power-of-two M, rectangular and distinct factors, N from
1 to 8 — the shape diversity the paper uses to show FastKron generalizes
beyond cube sizes (paper: 5.7x-40.7x over GPyTorch, 1.4x-8.1x over COGENT).

Cases exceeding the CPU element budget run with N reduced (flagged
``scaled=1``) — same shape family, smaller exponent.
"""
from __future__ import annotations

import math

import jax

from repro.core import kron as K
from repro.core.fastkron import kron_matmul
from repro.core.kron import KronProblem

from .util import csv_row, gflops, make_inputs, timeit

# (id, source, M, [(P,Q), ...]) — Table 4 verbatim
TABLE4 = [
    (1, "lstm", 20, [(128, 128)]),
    (2, "lstm", 20, [(512, 512)]),
    (3, "lstm", 50, [(512, 512)]),
    (4, "lstm", 20, [(1024, 1024)]),
    (5, "lstm", 1, [(2048, 2048)]),
    (6, "compress", 10, [(52, 50), (65, 20)]),
    (7, "compress", 50, [(32, 8), (64, 128)]),
    (8, "compress", 10, [(52, 65), (50, 20)]),
    (9, "hypa", 4, [(512, 512)]),
    (10, "hypa", 8, [(512, 512)]),
    (11, "hypa", 16, [(512, 512)]),
    (12, "hypa", 20, [(512, 512)]),
    (13, "hypa", 4, [(8, 8)] * 3),
    (14, "hypa", 8, [(8, 8)] * 3),
    (15, "hypa", 16, [(8, 8)] * 3),
    (16, "hypa", 20, [(8, 8)] * 3),
    (17, "graphs", 1024, [(3, 3)] * 7),
    (18, "graphs", 1024, [(4, 4)] * 7),
    (19, "graphs", 1024, [(6, 6)] * 7),
    (20, "biology", 1, [(5, 5)] * 3 + [(2, 2)]),
    (21, "biology", 1, [(5, 5)] * 2 + [(2, 2), (25, 25)]),
    (22, "drug", 1526, [(4, 4)] * 6),
    (23, "drug", 156, [(8, 8)] * 3),
    (24, "drug", 2967, [(4, 4)] * 7),
    (25, "gp", 16, [(8, 8)] * 8),
    (26, "gp", 16, [(16, 16)] * 6),
    (27, "gp", 16, [(32, 32)] * 6),
    (28, "gp", 16, [(64, 64)] * 3),
]

BUDGET = 3 * 10**7  # elements per intermediate (CPU RAM/time cap)


def _cap(m, factors):
    """Drop trailing factors until intermediates fit the budget."""
    scaled = 0
    while factors:
        ps = [p for p, _ in factors]
        qs = [q for _, q in factors]
        prob = KronProblem(m, tuple(ps), tuple(qs))
        if m * prob.intermediate_elems <= BUDGET:
            return factors, scaled
        factors = factors[:-1]
        scaled = 1
    raise ValueError("empty")


def run(quick: bool = False):
    rows = []
    cases = TABLE4[::4] if quick else TABLE4
    for cid, src, m, factors in cases:
        factors, scaled = _cap(m, list(factors))
        ps = tuple(p for p, _ in factors)
        qs = tuple(q for _, q in factors)
        prob = KronProblem(m, ps, qs)
        x, fs = make_inputs(m, ps, qs)
        sh = jax.jit(lambda x, fs: K.kron_matmul_shuffle(x, fs))
        ft = jax.jit(lambda x, fs: K.kron_matmul_ftmmt(x, fs))
        fk = jax.jit(lambda x, fs: kron_matmul(x, fs))
        t_sh = timeit(lambda: sh(x, fs), iters=3)
        t_ft = timeit(lambda: ft(x, fs), iters=3)
        t_fk = timeit(lambda: fk(x, fs), iters=3)
        rows.append(csv_row(
            "fig10",
            id=cid,
            source=src,
            m=m,
            shape="x".join(f"{p}x{q}" for p, q in factors),
            scaled=scaled,
            speedup_vs_shuffle=f"{t_sh/t_fk:.2f}",
            speedup_vs_ftmmt=f"{t_ft/t_fk:.2f}",
            gflops_fastkron=f"{gflops(prob, t_fk):.2f}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
