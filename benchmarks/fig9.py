"""Figure 9: GFLOP/s of shuffle / FTMMT / FastKron (planned) / FastKron
without fusion, for M=1024, P in {8..64}, the largest N fitting the budget.

Paper claims reproduced (on CPU, as ratios):
  * FastKron beats the shuffle algorithm at every size (paper: 3.1x-7.6x);
  * fusion (C3 planning) helps most at small P (paper: 2.2x at 8^5 -> 1.15x
    at 32^3);
  * throughput grows with P (arithmetic intensity = P).
"""
from __future__ import annotations

import functools

import jax

from repro.core import kron as K
from repro.core.autotune import make_plan
from repro.core.fastkron import kron_matmul
from repro.core.kron import KronProblem

from .util import csv_row, gflops, largest_n, make_inputs, timeit


def run(quick: bool = False):
    rows = []
    m = 1024
    ps = [8, 16, 32] if quick else [8, 16, 32, 64]
    for p in ps:
        n = largest_n(m, p, p, budget_elems=(8 if quick else 48) * 10**6)
        prob = KronProblem.uniform(m, p, p, n)
        x, fs = make_inputs(m, prob.ps, prob.qs)

        shuffle = jax.jit(lambda x, fs: K.kron_matmul_shuffle(x, fs))
        ftmmt = jax.jit(lambda x, fs: K.kron_matmul_ftmmt(x, fs))
        fk = jax.jit(lambda x, fs: kron_matmul(x, fs, plan="auto"))
        fk_nofuse = jax.jit(lambda x, fs: kron_matmul(x, fs, plan=None))

        t_sh = timeit(lambda: shuffle(x, fs))
        t_ft = timeit(lambda: ftmmt(x, fs))
        t_fk = timeit(lambda: fk(x, fs))
        t_nf = timeit(lambda: fk_nofuse(x, fs))
        # the plan actually executed on this backend (prekron is TPU-only)
        plan = make_plan(prob, enable_prekron=jax.default_backend() == "tpu")
        rows.append(csv_row(
            "fig9",
            size=f"{p}^{n}",
            gflops_shuffle=f"{gflops(prob, t_sh):.2f}",
            gflops_ftmmt=f"{gflops(prob, t_ft):.2f}",
            gflops_fastkron=f"{gflops(prob, t_fk):.2f}",
            gflops_fastkron_nofuse=f"{gflops(prob, t_nf):.2f}",
            speedup_vs_shuffle=f"{t_sh / t_fk:.2f}",
            fusion_gain=f"{t_nf / t_fk:.2f}",
            plan=plan.describe().replace(",", ";"),
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
