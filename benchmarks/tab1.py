"""Table 1: where the shuffle algorithm's time goes (Matmul vs transpose)
vs FastKron total.

Paper claim: the transpose/reshuffle pass costs up to 80% of GPyTorch's
total time; FastKron removes it entirely.  We time the shuffle algorithm's
two phases separately (same decomposition as GPyTorch: cuBLAS GEMM +
transpose kernel) and FastKron end-to-end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import kron as K
from repro.core.fastkron import kron_matmul
from repro.core.kron import KronProblem

from .util import csv_row, largest_n, make_inputs, timeit


def _shuffle_matmul_only(x, fs):
    """The GEMM part of every shuffle iteration (no transpose/reshape)."""
    y = x
    m = x.shape[0]
    for f in reversed(fs):
        p, q = f.shape
        s = y.shape[1] // p
        t = y.reshape(m * s, p) @ f
        y = t.reshape(m, s * q)  # WRONG layout on purpose: no shuffle pass
    return y


def _shuffle_transpose_only(x, fs):
    """Only the transpose passes (on same-shaped intermediates)."""
    y = x
    m = x.shape[0]
    for f in reversed(fs):
        p, q = f.shape
        s = y.shape[1] // p
        y = jnp.swapaxes(y.reshape(m, s, q), 1, 2).reshape(m, q * s)
    return y


def run(quick: bool = False):
    rows = []
    m = 1024
    for p in ([8, 32] if quick else [8, 16, 32, 64]):
        n = largest_n(m, p, p, budget_elems=(8 if quick else 48) * 10**6)
        prob = KronProblem.uniform(m, p, p, n)
        x, fs = make_inputs(m, prob.ps, prob.qs)
        mm = jax.jit(lambda x, fs: _shuffle_matmul_only(x, fs))
        tr = jax.jit(lambda x, fs: _shuffle_transpose_only(x, fs))
        full = jax.jit(lambda x, fs: K.kron_matmul_shuffle(x, fs))
        fk = jax.jit(lambda x, fs: kron_matmul(x, fs))
        t_mm = timeit(lambda: mm(x, fs))
        t_tr = timeit(lambda: tr(x, fs))
        t_full = timeit(lambda: full(x, fs))
        t_fk = timeit(lambda: fk(x, fs))
        rows.append(csv_row(
            "tab1",
            size=f"{p}^{n}",
            shuffle_matmul_ms=f"{t_mm*1e3:.2f}",
            shuffle_transpose_ms=f"{t_tr*1e3:.2f}",
            shuffle_total_ms=f"{t_full*1e3:.2f}",
            transpose_frac=f"{t_tr/(t_mm+t_tr):.2f}",
            fastkron_ms=f"{t_fk*1e3:.2f}",
            speedup=f"{t_full/t_fk:.2f}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
