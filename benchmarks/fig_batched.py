"""Batched Kron-Matmul benchmark (beyond paper — serving/multi-kernel loads).

Compares ``kron_matmul_batched`` (ONE launch for B independent problems)
against the looped baseline a user would otherwise write — a Python loop of B
per-sample ``kron_matmul`` dispatches — for both factor-sharing modes:

  * shared factors (KronLinear under a serving batch): the batch collapses
    into M, so the batched path is one dispatch with B-times-taller GEMMs;
  * per-sample factors (the Jhurani arXiv 1304.7054 regime, e.g. multi-kernel
    GP solves): the batched path runs the batch-grid kernels / scan-batched
    XLA analogue.

Problem: B=8, M=64, (16,16)^3 (the PR-2 acceptance shape).  Emits
``BENCH_batched.json``; reproduced claim: batched >= 1.5x looped throughput.
Methodology (block-interleaved min-of-N timing) as EXPERIMENTS.md §Batched.
"""
from __future__ import annotations

import json
import math
import os
import pathlib

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.fastkron import kron_matmul, kron_matmul_batched
from repro.core.kron import KronProblem

from .util import bench_meta, csv_row

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_JSON = ROOT / "BENCH_batched.json"


def _bench_pair(fn_a, fn_b, iters: int, rounds: int = 6) -> tuple[float, float]:
    """Block-interleaved min-of-N timing (same estimator as fig_bwd: block
    interleaving cancels shared-container drift, min is least-noise).  More,
    smaller blocks than fig_bwd: this container's noisy-neighbor bursts last
    whole seconds, so each side needs samples spread across several bursts."""
    import time

    for _ in range(2):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())

    def block(fn, out):
        for _ in range(max(1, iters // rounds)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            out.append(time.perf_counter() - t0)

    ta, tb = [], []
    for _ in range(rounds):
        block(fn_a, ta)
        block(fn_b, tb)
    return min(ta), min(tb)


def _make(b, m, ps, qs, *, per_sample, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ps) + 1)
    x = jax.random.normal(keys[0], (b, m, math.prod(ps)), jnp.float32)
    shape = (lambda p, q: (b, p, q)) if per_sample else (lambda p, q: (p, q))
    fs = tuple(
        jax.random.normal(k, shape(p, q), jnp.float32)
        for k, p, q in zip(keys[1:], ps, qs)
    )
    return x, fs


def run(quick: bool = False):
    b, m, ps, qs = 8, 64, (16,) * 3, (16,) * 3
    iters = 12 if quick else 24
    record = {
        "problem": {"b": b, "m": m, "ps": list(ps), "qs": list(qs),
                    "dtype": "float32"},
        "backend": jax.default_backend(),
    }

    setups = {}
    for mode in ("shared", "per_sample"):
        per_sample = mode == "per_sample"
        x, fs = _make(b, m, ps, qs, per_sample=per_sample)
        # Looped baseline: ONE compile (same per-sample shape), then the full
        # loop a batched consumer would otherwise run — slice each sample out,
        # dispatch it, and reassemble the (B, M, out) batch.  The slice/stack
        # is part of the baseline because the batched entry point's contract
        # (batch in, batch out) replaces exactly that loop.
        loop_fn = jax.jit(kron_matmul)

        def looped(x=x, fs=fs, per_sample=per_sample):
            return jnp.stack([
                loop_fn(x[i], tuple(f[i] for f in fs) if per_sample else fs)
                for i in range(b)
            ])

        batched_fn = jax.jit(
            lambda x, fs, per_sample=per_sample: kron_matmul_batched(
                x, fs, shared_factors=not per_sample
            )
        )

        def batched(x=x, fs=fs, batched_fn=batched_fn):
            return batched_fn(x, fs)

        setups[mode] = (looped, batched)

    # Global warm-up: compile + run EVERY path before timing ANY — the first
    # timed pair in a fresh process otherwise absorbs allocator/codegen
    # warm-up that has nothing to do with either algorithm.
    for looped, batched in setups.values():
        jax.block_until_ready(looped())
        jax.block_until_ready(batched())

    for mode, (looped, batched) in setups.items():
        per_sample = mode == "per_sample"
        t_loop, t_batch = _bench_pair(looped, batched, iters)
        plan = autotune.make_batched_plan(
            KronProblem(m, ps, qs), b, shared_factors=not per_sample,
            enable_prekron=False,
        )
        record[mode] = {
            "looped_s": t_loop,
            "batched_s": t_batch,
            "speedup": t_loop / t_batch,
            "plan": plan.describe(),
        }
        yield csv_row(
            "fig_batched",
            mode=mode,
            b=b,
            m=m,
            size="16^3",
            looped_s=f"{t_loop:.4f}",
            batched_s=f"{t_batch:.4f}",
            speedup=f"{t_loop / t_batch:.2f}",
            plan=plan.describe().replace(",", ";"),
        )

    # Headline batched-vs-looped number (acceptance: >= 1.5x at B>=8): the
    # per-sample-factors mode is the launch-bound regime batching targets;
    # report the best mode and name it.
    best = max(("shared", "per_sample"), key=lambda k: record[k]["speedup"])
    record["speedup"] = record[best]["speedup"]
    record["headline_mode"] = best
    record["meta"] = bench_meta()
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)
    yield csv_row(
        "fig_batched",
        speedup=f"{record['speedup']:.2f}",
        headline_mode=best,
        artifact=os.fspath(OUT_JSON),
    )


if __name__ == "__main__":
    for r in run():
        print(r)
