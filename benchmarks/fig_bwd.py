"""Backward-pass benchmark (beyond paper — training workloads).

Compares ``jax.grad`` through the PLANNED Kron-Matmul (fused stage backward:
M-tiled cache-resident chain + shared-relayout factor grads, tiles from the
measured autotuner) against the seed's unfused per-factor backward loop
(``plan=None``), on the M=256, (16,16)^4 problem from the PR-1 acceptance
criteria.  Emits ``BENCH_bwd.json`` next to the repo root for CI artifacts.

Reproduced claim: the planned backward is >= 1.5x faster than the unfused
loop on CPU (the fusion win the paper demonstrates for the forward pass,
carried over to the gradient contractions).  Methodology in EXPERIMENTS.md
§Backward.
"""
from __future__ import annotations

import json
import math
import os
import pathlib

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.fastkron import kron_matmul
from repro.core.kron import KronProblem

from .util import bench_meta, csv_row, make_inputs

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_JSON = ROOT / "BENCH_bwd.json"
PLAN_CACHE = ROOT / "BENCH_plan_cache.json"


def _bench_pair(fn_a, fn_b, iters: int) -> tuple[float, float]:
    """Block-interleaved min-of-N timing: A-block, B-block, repeated.  Block
    interleaving cancels slow machine drift (this container shares 2 vCPUs)
    without the per-call cache pollution of strict alternation, and min is
    the least-noise estimator for a fixed workload."""
    import time

    for _ in range(2):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())

    def block(fn, out):
        for _ in range(max(1, iters // 3)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            out.append(time.perf_counter() - t0)

    ta, tb = [], []
    for _ in range(3):
        block(fn_a, ta)
        block(fn_b, tb)
    return min(ta), min(tb)


def run(quick: bool = False):
    m, ps, qs = 256, (16,) * 4, (16,) * 4
    prob = KronProblem(m, ps, qs)
    x, fs = make_inputs(m, ps, qs)
    fs = tuple(fs)
    iters = 9 if quick else 12
    # Runtime cotangent: a .sum() loss makes dY a compile-time constant and
    # XLA folds the (x-independent) input-gradient chain away — for BOTH
    # paths that can be folded, which would compare folding, not kernels.
    gy = jax.random.normal(jax.random.PRNGKey(7), (m, math.prod(qs)), x.dtype)

    def loss(plan):
        return lambda x, fs, gy: (kron_matmul(x, fs, plan=plan) * gy).sum()

    # Measured plan, persisted in the on-disk cache so re-runs skip tuning.
    plan = autotune.make_plan(
        prob, tune="measure", backend="xla", cache_path=str(PLAN_CACHE),
        enable_prekron=jax.default_backend() == "tpu",
    )

    # Training-style backward: cotangents for x AND every factor.
    g_seed = jax.jit(jax.grad(loss(None), argnums=(0, 1)))
    g_plan = jax.jit(jax.grad(loss(plan), argnums=(0, 1)))
    t_seed, t_plan = _bench_pair(
        lambda: g_seed(x, fs, gy), lambda: g_plan(x, fs, gy), iters
    )

    # Inference-style backward: cotangent for x only (symbolic-zeros path —
    # the planned version runs the fused transposed chain, nothing else).
    gx_seed = jax.jit(jax.grad(lambda x, gy: loss(None)(x, fs, gy)))
    gx_plan = jax.jit(jax.grad(lambda x, gy: loss(plan)(x, fs, gy)))
    tx_seed, tx_plan = _bench_pair(
        lambda: gx_seed(x, gy), lambda: gx_plan(x, gy), iters
    )

    record = {
        "problem": {"m": m, "ps": list(ps), "qs": list(qs), "dtype": "float32"},
        "backend": jax.default_backend(),
        "plan": plan.describe(),
        "grad_x_and_factors": {
            "seed_unfused_s": t_seed,
            "planned_s": t_plan,
            "speedup": t_seed / t_plan,
        },
        "grad_x_only": {
            "seed_unfused_s": tx_seed,
            "planned_s": tx_plan,
            "speedup": tx_seed / tx_plan,
        },
        "meta": bench_meta(),
    }
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)

    yield csv_row(
        "fig_bwd",
        size="16^4",
        m=m,
        grad="x+factors",
        seed_s=f"{t_seed:.4f}",
        planned_s=f"{t_plan:.4f}",
        speedup=f"{t_seed / t_plan:.2f}",
        plan=plan.describe().replace(",", ";"),
    )
    yield csv_row(
        "fig_bwd",
        size="16^4",
        m=m,
        grad="x-only",
        seed_s=f"{tx_seed:.4f}",
        planned_s=f"{tx_plan:.4f}",
        speedup=f"{tx_seed / tx_plan:.2f}",
        artifact=os.fspath(OUT_JSON),
    )


if __name__ == "__main__":
    for r in run():
        print(r)
