"""Shared benchmark utilities: timing, FLOP accounting, CSV emission.

All benchmarks run on CPU (the container has no TPU): absolute numbers are
not paper-comparable, but the RATIOS between algorithms on identical inputs
are the reproduction target (FastKron vs shuffle vs FTMMT), plus HLO-derived
bytes/comm which are hardware-independent.
"""
from __future__ import annotations

import datetime
import json
import math
import subprocess
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.kron import KronProblem


def timeit(fn: Callable[[], jax.Array], *, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def gflops(prob: KronProblem, seconds: float) -> float:
    return prob.flops / seconds / 1e9


def make_inputs(m: int, ps, qs, dtype=jnp.float32, seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ps) + 1)
    x = jax.random.normal(keys[0], (m, math.prod(ps))).astype(dtype)
    fs = [
        jax.random.normal(k, (p, q)).astype(dtype)
        for k, p, q in zip(keys[1:], ps, qs)
    ]
    return x, fs


def csv_row(name: str, **fields) -> str:
    parts = [name] + [f"{k}={v}" for k, v in fields.items()]
    return ",".join(parts)


def largest_n(m: int, p: int, q: int, budget_elems: int = 3 * 10**7) -> int:
    """Largest N with all intermediates (M x cols) under the element budget
    (CPU-RAM/time analogue of 'largest allocatable P^N on a 32GB GPU')."""
    n = 1
    while True:
        prob = KronProblem.uniform(m, p, q, n + 1)
        if m * prob.intermediate_elems > budget_elems:
            return n
        n += 1


def _git_sha() -> str | None:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
        ).stdout.strip() or None
    except Exception:
        return None


def bench_meta() -> dict:
    """Provenance block stamped into every ``BENCH_*.json`` record.

    A number without the software stack and hardware it ran on is not
    comparable run-to-run — nightly CI archives these files, so each one
    carries enough to explain a regression: versions, device kind,
    platform, date, and the git SHA that produced it.
    """
    dev = jax.devices()[0]
    return {
        "jax": jax.__version__,
        "jaxlib": getattr(
            __import__("jaxlib"), "__version__", jax.__version__
        ),
        "device_kind": dev.device_kind,
        "platform": dev.platform,
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "git_sha": _git_sha(),
    }


def load_bench(path: str) -> dict:
    """Read a ``BENCH_*.json`` record, tolerating the pre-meta schema.

    Returns the record with a ``"meta"`` key always present (``{}`` for
    files written before provenance stamping) so comparison scripts can
    index it unconditionally.
    """
    with open(path) as f:
        record = json.load(f)
    if not isinstance(record.get("meta"), dict):
        record["meta"] = {}
    return record


__all__ = [
    "timeit", "gflops", "make_inputs", "csv_row", "largest_n",
    "bench_meta", "load_bench",
]
