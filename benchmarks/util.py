"""Shared benchmark utilities: timing, FLOP accounting, CSV emission.

All benchmarks run on CPU (the container has no TPU): absolute numbers are
not paper-comparable, but the RATIOS between algorithms on identical inputs
are the reproduction target (FastKron vs shuffle vs FTMMT), plus HLO-derived
bytes/comm which are hardware-independent.
"""
from __future__ import annotations

import math
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.kron import KronProblem


def timeit(fn: Callable[[], jax.Array], *, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def gflops(prob: KronProblem, seconds: float) -> float:
    return prob.flops / seconds / 1e9


def make_inputs(m: int, ps, qs, dtype=jnp.float32, seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ps) + 1)
    x = jax.random.normal(keys[0], (m, math.prod(ps))).astype(dtype)
    fs = [
        jax.random.normal(k, (p, q)).astype(dtype)
        for k, p, q in zip(keys[1:], ps, qs)
    ]
    return x, fs


def csv_row(name: str, **fields) -> str:
    parts = [name] + [f"{k}={v}" for k, v in fields.items()]
    return ",".join(parts)


def largest_n(m: int, p: int, q: int, budget_elems: int = 3 * 10**7) -> int:
    """Largest N with all intermediates (M x cols) under the element budget
    (CPU-RAM/time analogue of 'largest allocatable P^N on a 32GB GPU')."""
    n = 1
    while True:
        prob = KronProblem.uniform(m, p, q, n + 1)
        if m * prob.intermediate_elems > budget_elems:
            return n
        n += 1


__all__ = ["timeit", "gflops", "make_inputs", "csv_row", "largest_n"]
