"""Comm/compute-overlapped distributed rounds benchmark (PR 10).

Times the slab-pipelined round schedule against the serial schedule on the
forced 8-device CPU host mesh (``(2, 4)`` = ``(data, model)``), at
``n_slabs`` in {1, 2, 4} on the single/shared spine, and records which
schedule the MEASURED distributed tuner picks for the per-sample batched
problem (``make_batched_plan(tune="measure", mesh=...)``).

The measurement runs in a SUBPROCESS (same pattern as fig_dist_batched):
the device-count flag must be set before jax initializes.

CAVEAT — host-mesh numbers UNDERSTATE the overlap win: the "collectives"
here are memcpys between host buffers, so there is almost no transfer time
for the pipeline to hide and the slabbed schedules mostly measure their own
launch overhead.  The reproduced claims are therefore (a) ``n_slabs=1`` is
within noise (<5%) of the serial schedule — the pipeline machinery is free
when unused — and (b) the compiled collective counts scale exactly as
``rounds * n_slabs`` while the total collective BYTES stay constant (the
per-slab payloads repartition, never duplicate, the serial payload).  On a
real ICI mesh the analytic model (``autotune._slab_schedule_seconds``)
predicts the crossover near ``A2A_LATENCY_S * ICI_BW`` (~100 KB) per-round
payloads; the measured tuner owns the final call.  Emits
``BENCH_dist_overlap.json``; methodology as EXPERIMENTS.md
§Distributed-Overlap.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

from .util import bench_meta, csv_row

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_JSON = ROOT / "BENCH_dist_overlap.json"

N_DEVICES = 8
MESH_SHAPE = (2, 4)
SLAB_COUNTS = (1, 2, 4)


# ---------------------------------------------------------------------------
# Child process: owns the forced multi-device jax runtime
# ---------------------------------------------------------------------------


def _child(quick: bool) -> None:
    import math
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import autotune
    from repro.core.distributed import (
        comm_elems_per_device,
        comm_hidden_elems,
        plan_rounds,
        run_distributed_rounds,
        sharded_input,
    )
    from repro.runtime.hlo_analysis import collective_stats

    # Full mode keeps the same m as quick and spends the extra budget on
    # timing iterations: m=1024 pushes the measured-tuner candidate sweep
    # past 20 minutes on the 2-vCPU CI host (8 fake devices share 2 cores),
    # and m=512 is already past the analytic break-even where the measured
    # tuner selects a slabbed schedule.
    m, ps, qs = 512, (4, 4, 4), (4, 4, 4)
    b_tuner = 8
    iters = 12 if quick else 24
    g_m, g_k = MESH_SHAPE
    mesh = jax.make_mesh(MESH_SHAPE, ("data", "model"))

    rev_ps, rev_qs = list(reversed(ps)), list(reversed(qs))
    k_loc = math.prod(ps) // g_k
    rounds = plan_rounds(k_loc, rev_ps, rev_qs, g_k)
    m_loc = m // g_m

    keys = jax.random.split(jax.random.PRNGKey(23), len(ps) + 1)
    x = jax.random.normal(keys[0], (m, math.prod(ps)), jnp.float32)
    fs = tuple(
        jax.random.normal(k, (p, q), jnp.float32)
        for k, p, q in zip(keys[1:], ps, qs)
    )
    xs = sharded_input(x, mesh)

    # One jitted program per schedule; "serial" is the default entry point
    # (no n_slabs argument at all), the others force the slab count.
    fns = {"serial": jax.jit(
        lambda x, fs: run_distributed_rounds(x, fs, mesh)
    )}
    for n in SLAB_COUNTS:
        fns[f"n{n}"] = jax.jit(
            lambda x, fs, n=n: run_distributed_rounds(x, fs, mesh, n_slabs=n)
        )

    a2a = {}
    nbytes = {}
    hlo = {}
    for name, fn in fns.items():
        hlo[name] = fn.lower(xs, fs).compile().as_text()
        st = collective_stats(hlo[name])
        a2a[name] = st.count_by_op.get("all-to-all", 0)
        nbytes[name] = st.total_bytes
    assert a2a["serial"] == len(rounds), a2a
    for n in SLAB_COUNTS:
        assert a2a[f"n{n}"] == len(rounds) * n, (a2a, rounds)
        assert nbytes[f"n{n}"] == nbytes["serial"], nbytes
    # n_slabs=1 IS the serial schedule: same traced body, same compiled
    # program — the "overhead when unused" claim is structural, not a
    # wall-clock coin flip (the timing below just corroborates it).
    n1_same_program = hlo["n1"] == hlo["serial"]

    # Block-interleaved min-of-N across all schedules (same estimator as
    # fig_dist_batched): each timing block revisits every schedule so drift
    # hits them equally.  One SAMPLE is ``reps`` back-to-back dispatches —
    # a single call is sub-millisecond here and dispatch jitter would
    # otherwise dominate the serial-vs-n1 comparison (identical programs).
    for fn in fns.values():
        jax.block_until_ready(fn(xs, fs))

    reps = 8
    best = {name: float("inf") for name in fns}
    for _ in range(6):
        for name, fn in fns.items():
            for _ in range(max(1, iters // 6)):
                t0 = time.perf_counter()
                for _ in range(reps):
                    y = fn(xs, fs)
                jax.block_until_ready(y)
                best[name] = min(
                    best[name], (time.perf_counter() - t0) / reps
                )

    schedules = {}
    for n in SLAB_COUNTS:
        schedules[str(n)] = {
            "time_s": best[f"n{n}"],
            "all_to_all": a2a[f"n{n}"],
            "collective_bytes": nbytes[f"n{n}"],
            "hidden_elems": comm_hidden_elems(
                m_loc, k_loc, rev_ps, rev_qs, g_k, n_slabs=n
            ),
        }
    # Byte-identical programs have 0 overhead by definition; the raw
    # timings stay in the record (schedules / serial_s) for the skeptical.
    overhead = (
        0.0 if n1_same_program else best["n1"] / best["serial"] - 1.0
    )
    fastest = min(SLAB_COUNTS, key=lambda n: best[f"n{n}"])

    # The measured distributed tuner's pick for the per-sample batched
    # problem (wall-clocked candidates on THIS mesh, fresh cache).
    import tempfile

    prob = autotune.KronProblem(m_loc, ps, qs)
    with tempfile.TemporaryDirectory() as td:
        plan = autotune.make_batched_plan(
            prob, b_tuner, shared_factors=False, tune="measure", g_k=g_k,
            cache_path=os.path.join(td, "plans.json"), mesh=mesh,
        )
    analytic_n = autotune.choose_n_slabs(
        prob, g_k, batch=b_tuner, dtype_bytes=4
    )

    record = {
        "problem": {"m": m, "ps": list(ps), "qs": list(qs),
                    "dtype": "float32"},
        "mesh": {"devices": N_DEVICES, "data": g_m, "model": g_k,
                 "backend": jax.default_backend()},
        "rounds": len(rounds),
        "comm_elems_per_device": comm_elems_per_device(
            m_loc, k_loc, rev_ps, rev_qs, g_k
        ),
        "serial_s": best["serial"],
        "schedules": schedules,
        "n1_overhead_vs_serial": overhead,
        "n1_same_program": n1_same_program,
        "fastest_n_slabs": fastest,
        "tuner": {
            "batch": b_tuner,
            "measured_n_slabs": plan.n_slabs,
            "measured_t_b": plan.t_b,
            "analytic_n_slabs": analytic_n,
        },
        "caveat": (
            "host mesh: collectives run at memcpy speed, so overlap has "
            "almost nothing to hide and these numbers UNDERSTATE the "
            "slabbed schedules vs a real ICI mesh (moduledoc)"
        ),
        "meta": bench_meta(),
    }
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)


# ---------------------------------------------------------------------------
# Parent: spawn the multi-device child, report its artifact
# ---------------------------------------------------------------------------


def run(quick: bool = False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEVICES}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(ROOT / "src"), str(ROOT), env.get("PYTHONPATH")) if p
    )
    cmd = [sys.executable, "-m", "benchmarks.fig_dist_overlap", "--child"]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(
        cmd, env=env, cwd=ROOT, capture_output=True, text=True, timeout=1200
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"fig_dist_overlap child failed:\n{proc.stdout}\n{proc.stderr}"
        )
    with open(OUT_JSON) as f:
        record = json.load(f)
    for n, r in record["schedules"].items():
        yield csv_row(
            "fig_dist_overlap",
            n_slabs=n,
            m=record["problem"]["m"],
            mesh=f"{record['mesh']['data']}x{record['mesh']['model']}",
            time_s=f"{r['time_s']:.4f}",
            all_to_all=r["all_to_all"],
            hidden_elems=r["hidden_elems"],
        )
    yield csv_row(
        "fig_dist_overlap",
        serial_s=f"{record['serial_s']:.4f}",
        n1_overhead=f"{record['n1_overhead_vs_serial']:+.1%}",
        n1_same_program=record["n1_same_program"],
        fastest_n_slabs=record["fastest_n_slabs"],
        tuner_n_slabs=record["tuner"]["measured_n_slabs"],
        tuner_t_b=record["tuner"]["measured_t_b"],
        artifact=os.fspath(OUT_JSON),
    )


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child(quick="--quick" in sys.argv)
    else:
        for row in run(quick="--quick" in sys.argv):
            print(row)
