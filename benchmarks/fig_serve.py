"""Serving benchmark (beyond paper — the continuous-batching engine).

Drives the ``ServeEngine`` (launch/serve.py + the pure scheduler in
launch/scheduler.py) with a synthetic open-loop Poisson arrival process at
several arrival rates and reports tail latency (p50/p95/p99 TTFT, TPOT)
plus delivered tokens/s, against the one-shot fixed-batch baseline the
repo served with before PR 8.

The baseline is what ``serve.py`` without ``--arrival-rate`` does, applied
to the same request set: collect ``slots`` requests into a fixed batch, pad
every prompt to the LARGEST bucket, decode until the LONGEST request in the
batch finishes, repeat.  Continuous batching wins at saturation on exactly
the two wastes that policy bakes in — prompt padding to the worst case and
decode slots held by already-finished requests (no recycling).  Reproduced
claim (ISSUE 8): continuous tokens/s > one-shot tokens/s at the saturating
rate.  Model: reduced gemma-2b with Kron-FFN, so every bucket shape runs
the pre-resolved per-shape ``KronOp`` serving path.

Emits ``BENCH_serve.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.scheduler import SchedulerConfig, poisson_trace
from repro.launch.serve import ServeEngine
from repro.models import model as M
from repro.models.config import reduced
from repro.train import make_prefill_step, make_serve_step

from .util import bench_meta, csv_row

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_JSON = ROOT / "BENCH_serve.json"

RATES = (0.2, 0.5, 4.0)  # requests per scheduler step: idle .. saturating
PROMPT_LENS = (4, 28)
MAX_NEW = (2, 48)        # wide spread: slot recycling is what's measured


def _pcts(xs) -> dict:
    if not xs:
        return {}
    v = sorted(xs)
    at = lambda q: v[min(len(v) - 1, int(q * (len(v) - 1)))]  # noqa: E731
    return {"p50": at(0.5), "p95": at(0.95), "p99": at(0.99),
            "mean": sum(v) / len(v)}


def _make_one_shot(cfg, params, *, slots: int, bucket: int, max_new_cap: int):
    """The pre-PR-8 serving policy as a callable: fixed batches of
    ``slots``, prompts padded to ``bucket``, each batch decoded to its
    longest member.  Sampling is the SAME host-side greedy step the engine
    uses (a server streams, so every policy pays the per-step logits
    materialization) — the only measured difference is scheduling.
    Compiles once; the returned ``run(reqs)`` gives
    (delivered_tokens, wall_seconds)."""
    prefill = jax.jit(make_prefill_step(cfg, max_len=bucket + max_new_cap))
    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    wtok = jnp.zeros((slots, bucket), jnp.int32)
    logits, cache = prefill(params, wtok)
    jax.block_until_ready(
        step(params, cache, jnp.zeros((slots, 1), jnp.int32),
             jnp.int32(bucket))[0])

    def run(reqs):
        rng = np.random.RandomState(0)
        prompts = {r.rid: rng.randint(0, cfg.vocab, size=(r.prompt_len,))
                   .astype(np.int32) for r in reqs}
        delivered = 0
        t0 = time.perf_counter()
        for i in range(0, len(reqs), slots):
            chunk = reqs[i : i + slots]
            tokens = np.zeros((slots, bucket), np.int32)
            for j, r in enumerate(chunk):
                tokens[j, : r.prompt_len] = prompts[r.rid]
            logits, cache = prefill(params, tokens)
            lg = np.asarray(logits)[:, -1, : cfg.vocab]
            tok = np.argmax(lg, axis=-1)[:, None].astype(np.int32)
            # every request's first token comes from the padded position,
            # and the whole batch decodes until its slowest member is done
            n_steps = max(r.max_new for r in chunk) - 1
            for s in range(n_steps):
                logits, cache = step(params, cache, tok,
                                     np.int32(bucket + s))
                lg = np.asarray(logits)[:, -1, : cfg.vocab]
                tok = np.argmax(lg, axis=-1)[:, None].astype(np.int32)
            delivered += sum(r.max_new for r in chunk)
        return delivered, time.perf_counter() - t0

    return run


def run(quick: bool = False):
    # Wider than the test-suite reduced model on purpose: a decode step
    # must cost milliseconds (as it does on a real deployment) so the
    # measurement is launch-count-bound — the regime where the scheduling
    # policy is what matters — not python-dispatch-bound.
    cfg = reduced(get_config("gemma-2b"), dtype="float32",
                  d_model=256, d_ff=1024, head_dim=32)
    cfg = dataclasses.replace(cfg, kron_ffn=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SchedulerConfig(buckets=(8, 16, 32), max_slots=8, max_prefill=4,
                           max_wait=8)
    n = 16 if quick else 32
    engine = ServeEngine(cfg, params, scfg, max_new=MAX_NEW[1])
    engine.prewarm()          # every KronOp plan, before any trace
    engine.compile_shapes()   # every XLA executable, before any timing

    record: dict = {
        "model": "gemma-2b/reduced+kron_ffn",
        "scheduler": {"buckets": list(scfg.buckets),
                      "max_slots": scfg.max_slots,
                      "max_prefill": scfg.max_prefill,
                      "max_wait": scfg.max_wait},
        "requests": n,
        "prompt_lens": list(PROMPT_LENS),
        "max_new": list(MAX_NEW),
        "backend": jax.default_backend(),
        "rates": {},
    }
    reqs_by_rate = {}
    for rate in RATES:
        reqs = poisson_trace(seed=17, rate=rate, n=n,
                             prompt_lens=PROMPT_LENS, max_new=MAX_NEW)
        reqs_by_rate[rate] = reqs
        rep = engine.run(reqs)
        entry = {
            "ttft_s": _pcts(rep.ttft_s),
            "tpot_s": _pcts(rep.tpot_s),
            "tokens_per_s": rep.tokens_per_s,
            "total_tokens": rep.total_tokens,
            "duration_s": rep.duration_s,
            "scheduler_steps": rep.steps,
        }
        record["rates"][str(rate)] = entry
        yield csv_row(
            "fig_serve", mode="continuous", rate=rate, n=n,
            ttft_p50=f"{entry['ttft_s']['p50']:.4f}",
            ttft_p95=f"{entry['ttft_s']['p95']:.4f}",
            ttft_p99=f"{entry['ttft_s']['p99']:.4f}",
            tokens_per_s=f"{rep.tokens_per_s:.1f}",
        )

    # Headline: continuous vs one-shot on the saturating-rate request set
    # (arrivals are effectively instant there, so back-to-back fixed
    # batches is exactly what the old launcher would do).  Block-
    # interleaved min-of-N timing, same estimator as fig_batched: this
    # container's noisy-neighbor bursts last whole seconds, so each side
    # needs samples spread across several bursts and min is least-noise.
    sat = max(RATES)
    sat_reqs = list(reqs_by_rate[sat])
    one_shot = _make_one_shot(cfg, params, slots=scfg.max_slots,
                              bucket=max(scfg.buckets),
                              max_new_cap=max(r.max_new for r in sat_reqs))
    rounds = 3 if quick else 6
    cont_wall, one_wall, cont_tokens, one_tokens = [], [], 0, 0
    for _ in range(rounds):
        rep = engine.run(sat_reqs)
        cont_wall.append(rep.duration_s)
        cont_tokens = rep.total_tokens
        one_tokens, w = one_shot(sat_reqs)
        one_wall.append(w)
    cont_tps = cont_tokens / min(cont_wall)
    one_tps = one_tokens / min(one_wall)
    record["one_shot"] = {"tokens_per_s": one_tps,
                          "delivered_tokens": one_tokens,
                          "best_s": min(one_wall)}
    record["continuous_at_saturation"] = {"tokens_per_s": cont_tps,
                                          "delivered_tokens": cont_tokens,
                                          "best_s": min(cont_wall)}
    record["saturation_rate"] = sat
    record["timing_rounds"] = rounds
    record["speedup_at_saturation"] = cont_tps / max(one_tps, 1e-9)
    record["meta"] = bench_meta()
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)
    yield csv_row(
        "fig_serve", mode="one_shot", rate=sat,
        tokens_per_s=f"{one_tps:.1f}",
        continuous_speedup=f"{record['speedup_at_saturation']:.2f}",
        artifact=os.fspath(OUT_JSON),
    )


if __name__ == "__main__":
    for r in run():
        print(r)
