"""Table 2: memory-traffic reduction of FastKron vs the shuffle baseline.

The paper counts shared-memory load/store transactions (FastKron does up
to 3.1x fewer loads / 3.2x fewer stores than COGENT).  The CPU-observable
analogue is HLO bytes-accessed of the compiled program: the shuffle
algorithm's transpose pass re-reads and re-writes every intermediate from
"global memory", FastKron's fused plan does not — the ratio is the same
claim one level up the memory hierarchy.
"""
from __future__ import annotations

import jax

from repro.core import kron as K
from repro.core.fastkron import kron_matmul
from repro.core.kron import KronProblem
from repro.runtime.hlo_cost import analyze

from .util import csv_row, largest_n, make_inputs


def _bytes(fn, *args) -> float:
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze(txt).bytes_accessed


def run(quick: bool = False):
    rows = []
    m = 1024
    for p in ([8, 32] if quick else [8, 16, 32, 64]):
        n = largest_n(m, p, p, budget_elems=(8 if quick else 48) * 10**6)
        prob = KronProblem.uniform(m, p, p, n)
        x, fs = make_inputs(m, prob.ps, prob.qs)
        b_sh = _bytes(lambda x, fs: K.kron_matmul_shuffle(x, fs), x, fs)
        b_ft = _bytes(lambda x, fs: K.kron_matmul_ftmmt(x, fs), x, fs)
        b_fk = _bytes(lambda x, fs: kron_matmul(x, fs), x, fs)
        rows.append(csv_row(
            "tab2",
            size=f"{p}^{n}",
            bytes_shuffle=f"{b_sh/1e6:.1f}MB",
            bytes_ftmmt=f"{b_ft/1e6:.1f}MB",
            bytes_fastkron=f"{b_fk/1e6:.1f}MB",
            reduction_vs_shuffle=f"{b_sh/max(b_fk,1):.2f}",
            reduction_vs_ftmmt=f"{b_ft/max(b_fk,1):.2f}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
