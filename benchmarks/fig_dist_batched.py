"""Batched DISTRIBUTED Kron-Matmul benchmark (beyond paper, PR 3).

Compares ``kron_matmul_batched_distributed`` (ONE collective round per stage
for the whole batch) against the looped baseline a user would otherwise
write — a Python loop of B per-problem ``kron_matmul_distributed``
dispatches, each paying its own all_to_all rounds — on a forced multi-device
CPU host mesh (``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
mesh ``(2, 4)``), for both factor-sharing modes.

The measurement runs in a SUBPROCESS (same pattern as
tests/test_distributed.py): the device-count flag must be set before jax
initializes, and the parent benchmark harness keeps its single-device view.

Problem: B=8, M=32, (4,4)^3 per sample.  Emits ``BENCH_dist_batched.json``;
reproduced claim: batched >= 1.5x looped wall clock (the looped path pays
B x rounds collective latencies; the batched path pays rounds).  Also
records the compiled collective counts (batched == rounds, looped ==
B*rounds) and the batch-aware analytic comm volume
(``comm_elems_per_device(batch=B)``).  Methodology (block-interleaved
min-of-N timing) as EXPERIMENTS.md §Distributed-Batched.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

from .util import bench_meta, csv_row

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_JSON = ROOT / "BENCH_dist_batched.json"

N_DEVICES = 8
MESH_SHAPE = (2, 4)


# ---------------------------------------------------------------------------
# Child process: owns the forced multi-device jax runtime
# ---------------------------------------------------------------------------


def _child(quick: bool) -> None:
    import math
    import time

    import jax
    import jax.numpy as jnp

    from repro.core.distributed import (
        comm_elems_per_device,
        kron_matmul_batched_distributed,
        kron_matmul_distributed,
        plan_rounds,
        sharded_input_batched,
    )
    from repro.runtime.hlo_analysis import collective_stats

    b, m, ps, qs = 8, 32, (4, 4, 4), (4, 4, 4)
    iters = 12 if quick else 24
    g_m, g_k = MESH_SHAPE
    mesh = jax.make_mesh(MESH_SHAPE, ("data", "model"))

    def bench_pair(fn_a, fn_b, rounds_=6):
        """Block-interleaved min-of-N (same estimator as fig_batched)."""
        for _ in range(2):
            jax.block_until_ready(fn_a())
            jax.block_until_ready(fn_b())

        def block(fn, out):
            for _ in range(max(1, iters // rounds_)):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                out.append(time.perf_counter() - t0)

        ta, tb = [], []
        for _ in range(rounds_):
            block(fn_a, ta)
            block(fn_b, tb)
        return min(ta), min(tb)

    rev_ps, rev_qs = list(reversed(ps)), list(reversed(qs))
    k_loc = math.prod(ps) // g_k
    n_rounds = len(plan_rounds(k_loc, rev_ps, rev_qs, g_k))
    record = {
        "problem": {"b": b, "m": m, "ps": list(ps), "qs": list(qs),
                    "dtype": "float32"},
        "mesh": {"devices": N_DEVICES, "data": g_m, "model": g_k,
                 "backend": jax.default_backend()},
        "rounds": n_rounds,
        "comm_elems_per_device": {
            "per_problem": comm_elems_per_device(
                m // g_m, k_loc, rev_ps, rev_qs, g_k
            ),
            "batched": comm_elems_per_device(
                m // g_m, k_loc, rev_ps, rev_qs, g_k, batch=b
            ),
        },
    }

    setups = {}
    for mode in ("shared", "per_sample"):
        per_sample = mode == "per_sample"
        keys = jax.random.split(jax.random.PRNGKey(17), len(ps) + 1)
        x = jax.random.normal(keys[0], (b, m, math.prod(ps)), jnp.float32)
        shape = (lambda p, q: (b, p, q)) if per_sample else (lambda p, q: (p, q))
        fs = tuple(
            jax.random.normal(k, shape(p, q), jnp.float32)
            for k, p, q in zip(keys[1:], ps, qs)
        )
        xs = sharded_input_batched(x, mesh)

        # Looped baseline: B per-problem distributed dispatches, reassembled.
        # Jitted as one program so the comparison is collectives + compute,
        # not Python dispatch overhead (which would only flatter the batched
        # side further).
        looped_fn = jax.jit(lambda x, fs, per_sample=per_sample: jnp.stack([
            kron_matmul_distributed(
                x[i], tuple(f[i] for f in fs) if per_sample else fs, mesh
            )
            for i in range(b)
        ]))
        batched_fn = jax.jit(
            lambda x, fs, per_sample=per_sample: kron_matmul_batched_distributed(
                x, fs, mesh, shared_factors=not per_sample
            )
        )

        counts = {
            side: collective_stats(
                fn.lower(xs, fs).compile().as_text()
            ).count_by_op.get("all-to-all", 0)
            for side, fn in (("looped", looped_fn), ("batched", batched_fn))
        }
        setups[mode] = (
            lambda x=xs, fs=fs, fn=looped_fn: fn(x, fs),
            lambda x=xs, fs=fs, fn=batched_fn: fn(x, fs),
            counts,
        )

    # Global warm-up before timing anything (see fig_batched).
    for looped, batched, _ in setups.values():
        jax.block_until_ready(looped())
        jax.block_until_ready(batched())

    for mode, (looped, batched, counts) in setups.items():
        t_loop, t_batch = bench_pair(looped, batched)
        record[mode] = {
            "looped_s": t_loop,
            "batched_s": t_batch,
            "speedup": t_loop / t_batch,
            "all_to_all": counts,
        }

    best = max(("shared", "per_sample"), key=lambda k: record[k]["speedup"])
    record["speedup"] = record[best]["speedup"]
    record["headline_mode"] = best
    record["meta"] = bench_meta()
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)


# ---------------------------------------------------------------------------
# Parent: spawn the multi-device child, report its artifact
# ---------------------------------------------------------------------------


def run(quick: bool = False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEVICES}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(ROOT / "src"), str(ROOT), env.get("PYTHONPATH")) if p
    )
    cmd = [sys.executable, "-m", "benchmarks.fig_dist_batched", "--child"]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(
        cmd, env=env, cwd=ROOT, capture_output=True, text=True, timeout=1200
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"fig_dist_batched child failed:\n{proc.stdout}\n{proc.stderr}"
        )
    with open(OUT_JSON) as f:
        record = json.load(f)
    for mode in ("shared", "per_sample"):
        r = record[mode]
        yield csv_row(
            "fig_dist_batched",
            mode=mode,
            b=record["problem"]["b"],
            m=record["problem"]["m"],
            mesh=f"{record['mesh']['data']}x{record['mesh']['model']}",
            looped_s=f"{r['looped_s']:.4f}",
            batched_s=f"{r['batched_s']:.4f}",
            speedup=f"{r['speedup']:.2f}",
            a2a_batched=r["all_to_all"]["batched"],
            a2a_looped=r["all_to_all"]["looped"],
        )
    yield csv_row(
        "fig_dist_batched",
        speedup=f"{record['speedup']:.2f}",
        headline_mode=record["headline_mode"],
        rounds=record["rounds"],
        artifact=os.fspath(OUT_JSON),
    )


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child(quick="--quick" in sys.argv)
    else:
        for row in run(quick="--quick" in sys.argv):
            print(row)
