"""Shampoo optimizer benchmark (beyond paper — the Kron-preconditioner path).

Two questions, answered on reduced (CPU-sized) configs of >= 2 real archs:

  * **apply**: is the shape-grouped batched ``KronOp`` application of
    ``L^{-1/4} G R^{-1/4}`` (ONE per-sample batched call per shape group,
    traced into the jitted update exactly as ``shampoo_update`` runs it)
    faster than the looped baseline a user would otherwise write — a Python
    loop of per-layer engine-op dispatches (fig_batched's looped-baseline
    contract)?  (acceptance: speedup > 1x)
  * **step**: what does Shampoo cost end-to-end vs AdamW at the same model —
    steady-state step time (roots cached, ``lax.cond`` skips the refresh),
    refresh-step time (eigh inside the jitted step), and the amortized
    overhead at the default ``precond_every`` cadence.

Emits ``BENCH_optim.json``.  Methodology: block-interleaved min-of-N timing
(same estimator as fig_batched; see EXPERIMENTS.md §Optim).
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import model as M
from repro.models.config import reduced as reduce_cfg
from repro.optim import OptConfig, ShampooConfig
from repro.optim import shampoo as sh
from repro.train import make_train_step, train_state_init

from .util import bench_meta, csv_row

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_JSON = ROOT / "BENCH_optim.json"

ARCHS = ("qwen3-4b", "gemma-2b")
PRECOND_EVERY = 10


def _bench_pair(fn_a, fn_b, iters: int, rounds: int = 6) -> tuple[float, float]:
    """Block-interleaved min-of-N (fig_batched's estimator: interleaving
    cancels shared-container drift, min is the least-noise statistic)."""
    for _ in range(2):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())

    def block(fn, out):
        for _ in range(max(1, iters // rounds)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            out.append(time.perf_counter() - t0)

    ta, tb = [], []
    for _ in range(rounds):
        block(fn_a, ta)
        block(fn_b, tb)
    return min(ta), min(tb)


def _apply_setup(cfg, scfg):
    """(updates, kron) for the real reduced model's eligible layers, with
    refreshed (non-identity) roots so both paths do representative work."""
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = sh.shampoo_init(params, scfg)
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape, jnp.float32)
        if jnp.issubdtype(p.dtype, jnp.floating) else jnp.zeros_like(p),
        params,
    )
    # one real update refreshes the roots (step 1 always refreshes)
    _, state, _ = jax.jit(partial(sh.shampoo_update, cfg=scfg))(
        grads, state, params
    )
    kron = state["kron"]
    updates = {
        path: jax.random.normal(
            jax.random.PRNGKey(2),
            (e["ok"].shape[0], e["l"].shape[-1], e["r"].shape[-1]),
            jnp.float32,
        )
        for path, e in kron.items()
    }
    return updates, kron


def _advance(step_fn, state, data, n):
    """Run n real steps so the optimizer step counter lands where the
    refresh ``lax.cond`` predicate needs it."""
    start = int(state.opt["step"])
    for i in range(n):
        batch = dict(
            zip(("tokens", "labels"), data.global_batch(start + i))
        )
        state, _ = step_fn(state, batch)
    jax.block_until_ready(state.opt["step"])
    return state


def run(quick: bool = False):
    iters = 12 if quick else 24
    batch_size, seq = 4, 32
    record = {"backend": jax.default_backend(),
              "precond_every": PRECOND_EVERY, "configs": {}}

    for arch in ARCHS:
        cfg = reduce_cfg(get_config(arch), dtype="float32")
        opt_kw = dict(lr=1e-3, warmup_steps=2, decay_steps=100)
        adamw_cfg = OptConfig(**opt_kw)
        scfg = ShampooConfig(precond_every=PRECOND_EVERY, **opt_kw)

        # -- apply: batched shape groups vs looped per-layer reference ----
        updates, kron = _apply_setup(cfg, scfg)
        groups = sh.shape_groups(M.init_params(cfg, jax.random.PRNGKey(0)),
                                 scfg)
        n_layers = sum(s for ms in groups.values() for _, s in ms)
        # batched = the ONE jitted call per shape group, exactly as traced
        # into the jitted train step; looped = the per-layer dispatch loop
        # it replaces (slice + per-sample op call + reassemble, eager —
        # same baseline contract as fig_batched).
        batched_fn = jax.jit(lambda u, k: sh.precondition(u, k))
        t_loop, t_batch = _bench_pair(
            lambda: sh.precondition(updates, kron, looped=True),
            lambda: batched_fn(updates, kron),
            iters,
        )
        apply = {
            "groups": {f"{p}x{q}": sum(s for _, s in ms)
                       for (p, q), ms in groups.items()},
            "layers": n_layers,
            "looped_s": t_loop,
            "batched_s": t_batch,
            "speedup": t_loop / t_batch,
        }

        # -- step: jitted train_step, AdamW vs Shampoo ---------------------
        data = SyntheticLM(vocab=cfg.vocab, seq_len=seq, batch=batch_size)
        batch = dict(zip(("tokens", "labels"), data.global_batch(0)))
        step_a = jax.jit(make_train_step(cfg, adamw_cfg, microbatches=1))
        step_s = jax.jit(make_train_step(cfg, scfg, microbatches=1))
        state_a = _advance(
            step_a, train_state_init(cfg, adamw_cfg, jax.random.PRNGKey(0)),
            data, 2,
        )
        # steady: next step is 3 (no refresh); refresh: next step is 10
        state_steady = _advance(
            step_s, train_state_init(cfg, scfg, jax.random.PRNGKey(0)),
            data, 2,
        )
        state_refresh = _advance(step_s, state_steady, data,
                                 PRECOND_EVERY - 3)
        t_adamw, t_steady = _bench_pair(
            lambda: step_a(state_a, batch),
            lambda: step_s(state_steady, batch),
            iters,
        )
        t_refresh = min(
            _bench_pair(
                lambda: step_s(state_refresh, batch),
                lambda: step_s(state_refresh, batch),
                max(6, iters // 2),
            )
        )
        amortized = (
            t_steady * (PRECOND_EVERY - 1) + t_refresh
        ) / PRECOND_EVERY
        step = {
            "adamw_s": t_adamw,
            "shampoo_steady_s": t_steady,
            "shampoo_refresh_s": t_refresh,
            "steady_overhead": t_steady / t_adamw,
            "amortized_overhead": amortized / t_adamw,
        }
        record["configs"][arch] = {
            "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "params": cfg.param_count(),
            "apply": apply, "step": step,
        }
        yield csv_row(
            "fig_optim",
            arch=arch,
            layers=n_layers,
            apply_speedup=f"{apply['speedup']:.2f}",
            adamw_s=f"{t_adamw:.4f}",
            shampoo_steady_s=f"{t_steady:.4f}",
            shampoo_refresh_s=f"{t_refresh:.4f}",
            steady_overhead=f"{step['steady_overhead']:.2f}",
            amortized_overhead=f"{step['amortized_overhead']:.2f}",
        )

    # Headline batched-vs-looped apply number (acceptance: > 1x): report the
    # best config and name it, mirroring fig_batched's headline convention.
    best = max(record["configs"],
               key=lambda a: record["configs"][a]["apply"]["speedup"])
    record["speedup"] = record["configs"][best]["apply"]["speedup"]
    record["headline_config"] = best
    record["meta"] = bench_meta()
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)
    yield csv_row(
        "fig_optim",
        speedup=f"{record['speedup']:.2f}",
        headline_config=best,
        artifact=os.fspath(OUT_JSON),
    )


if __name__ == "__main__":
    for r in run():
        print(r)
